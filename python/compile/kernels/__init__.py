"""Layer-1 kernels: Pallas SAC (sac_conv) and pure-jnp oracles (ref)."""

from . import ref, sac_conv  # noqa: F401
