"""Pallas SAC kernels — the paper's compute hot-spot re-architected for
TPU (Layer 1).

Hardware adaptation (DESIGN.md §3): the ASIC's per-bit splitter fabric
becomes *bit-plane matmuls* on the MXU. A quantized matmul is computed
as ``sum_b 2**b * (A @ P_b)`` where ``P_b`` is the signed {-1,0,1}
bit-plane of the weights:

* each plane matmul is the segment-adder array — the per-bit-position
  accumulation S_b of Eq. (2);
* the grid's plane dimension walks bit positions the way the splitter
  walks kneaded slots;
* the final ``<< b`` accumulation is the rear adder tree, performed once
  per output block, off the per-pair critical path;
* all-zero planes are skipped (``@pl.when``) — the MXU image of slack
  elimination.

Kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU efficiency is estimated from the BlockSpec
footprint in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def sac_matmul(
    a: jnp.ndarray,
    planes: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    skip_zero_planes: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Bit-plane SAC matmul.

    Args:
      a: activations, (M, K) int32.
      planes: signed weight bit-planes, (B, K, N) int8 in {-1, 0, 1}
        (see ``ref.decompose_planes``).
      block_m / block_n: VMEM tile sizes. 128×128 matches the MXU
        systolic array; K is kept whole per tile (conv lanes are ≤ a few
        thousand weights — they fit VMEM comfortably: a 128×2304 int32
        tile is ~1.2 MB).
      skip_zero_planes: skip the segment matmul for all-zero planes
        (slack elimination).
      interpret: must stay True on CPU-PJRT (see module docs).

    Returns:
      (M, N) int32, exactly equal to ``a @ compose(planes)``.
    """
    b_planes, k, n = planes.shape
    m = a.shape[0]
    if a.shape[1] != k:
        raise ValueError(f"K mismatch: a {a.shape} vs planes {planes.shape}")
    bm, bn = min(block_m, m), min(block_n, n)
    # Pad M/N to tile multiples; sliced off at the end.
    m_pad, n_pad = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn
    a_p = jnp.pad(a, ((0, m_pad - m), (0, 0)))
    planes_p = jnp.pad(planes, ((0, 0), (0, 0), (0, n_pad - n)))

    def kernel(a_ref, p_ref, o_ref):
        b = pl.program_id(2)

        @pl.when(b == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        plane = p_ref[0].astype(jnp.int32)

        def segment():
            # Segment adder array: S_b for this (M, N) tile.
            seg = jnp.dot(a_ref[...], plane, preferred_element_type=jnp.int32)
            # Rear adder tree contribution: shift once per plane.
            o_ref[...] += seg << b

        if skip_zero_planes:
            @pl.when(jnp.any(plane != 0))
            def _():
                segment()
        else:
            segment()

    out = pl.pallas_call(
        kernel,
        grid=(m_pad // bm, n_pad // bn, b_planes),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j, b: (i, 0)),
            pl.BlockSpec((1, k, bn), lambda i, j, b: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, b: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.int32),
        interpret=interpret,
    )(a_p, planes_p)
    return out[:m, :n]


def sac_conv2d(
    x: jnp.ndarray,
    planes: jnp.ndarray,
    *,
    stride: int = 1,
    pad: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """SAC convolution via im2col + bit-plane matmul.

    Args:
      x: input feature map, (N, C, H, W) int32.
      planes: signed bit-planes of OIHW weights, (B, O, C, kh, kw) int8.

    Returns:
      (N, O, OH, OW) int32, exactly equal to the integer convolution.
    """
    from . import ref

    b_planes, o, c, kh, kw = planes.shape
    if kh != kw:
        raise ValueError("square kernels only")
    n, c_in, h, w_ = x.shape
    if c_in != c:
        raise ValueError(f"channel mismatch: x {x.shape} vs planes {planes.shape}")
    cols = ref.im2col(x, kh, stride=stride, pad=pad)  # (N*OH*OW, C*k*k)
    w_planes = planes.reshape(b_planes, o, c * kh * kw).transpose(0, 2, 1)
    out = sac_matmul(cols, w_planes, interpret=interpret)  # (N*OH*OW, O)
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w_ + 2 * pad - kw) // stride + 1
    return out.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)


@functools.partial(jax.jit, static_argnames=("bits",))
def decompose_planes_jnp(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """jnp version of ``ref.decompose_planes`` for in-graph use."""
    mag = jnp.abs(w)
    sign = jnp.sign(w).astype(jnp.int8)
    shifts = jnp.arange(bits, dtype=w.dtype)
    planes = ((mag[None, ...] >> shifts.reshape(-1, *([1] * w.ndim))) & 1).astype(jnp.int8)
    return planes * sign[None, ...]
