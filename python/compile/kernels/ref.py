"""Pure-jnp oracles for the SAC kernels.

The correctness contract (invariant I5 in DESIGN.md): the bit-plane SAC
computation must equal the plain quantized matmul / conv **exactly** in
integer arithmetic — SAC is a re-association of the same sum, so there is
no tolerance, only equality.
"""

import jax.numpy as jnp
import numpy as np


def decompose_planes(w: np.ndarray, bits: int) -> np.ndarray:
    """Decompose signed integer weights into signed bit-planes.

    ``w`` (K, N) int32 with |w| < 2**(bits-1)  →  planes (bits, K, N)
    int8 in {-1, 0, +1} such that ``w == sum_b 2**b * planes[b]``.

    This is the software image of weight kneading's input: plane ``b``
    holds the essential bits at position ``b``, with the weight's sign
    riding on the dispatched value (the splitter negates the routed
    activation — sign-magnitude, §III.B of the paper).
    """
    w = np.asarray(w, dtype=np.int64)
    if np.any(np.abs(w) >= 2 ** (bits - 1)):
        raise ValueError(f"weight magnitude overflows {bits}-bit sign-magnitude")
    mag = np.abs(w)
    sign = np.sign(w)
    planes = np.stack(
        [((mag >> b) & 1).astype(np.int8) * sign.astype(np.int8) for b in range(bits)]
    )
    return planes


def compose_planes(planes: np.ndarray) -> np.ndarray:
    """Inverse of :func:`decompose_planes` (losslessness check)."""
    bits = planes.shape[0]
    scale = (2 ** np.arange(bits, dtype=np.int64)).reshape(bits, 1, 1)
    return (planes.astype(np.int64) * scale).sum(axis=0).astype(np.int32)


def matmul_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Exact integer matmul oracle: (M,K) i32 × (K,N) i32 → (M,N) i32."""
    return jnp.matmul(a.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32)


def sac_matmul_ref(a: jnp.ndarray, planes: jnp.ndarray) -> jnp.ndarray:
    """SAC semantics in plain jnp: per-bit segment sums, one rear
    shift-and-add (Eq. 2 of the paper)."""
    bits = planes.shape[0]
    # Segment S_b = A @ P_b — the per-bit-position accumulation.
    segments = jnp.einsum(
        "mk,bkn->bmn", a.astype(jnp.int32), planes.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    scale = (2 ** jnp.arange(bits, dtype=jnp.int32)).reshape(bits, 1, 1)
    return (segments * scale).sum(axis=0).astype(jnp.int32)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, pad: int = 0) -> jnp.ndarray:
    """Exact integer conv oracle: x (N,C,H,W) i32, w (O,C,kh,kw) i32."""
    import jax

    y = jax.lax.conv_general_dilated(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.int32,
    )
    return y.astype(jnp.int32)


def im2col(x: jnp.ndarray, k: int, stride: int = 1, pad: int = 0) -> jnp.ndarray:
    """Unfold (N,C,H,W) into (N*OH*OW, C*k*k) patches, NCHW/OIHW order
    compatible with ``w.reshape(O, C*k*k).T``."""
    n, c, h, w_ = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w_ + 2 * pad - k) // stride + 1
    cols = []
    for i in range(k):
        for j in range(k):
            cols.append(
                xp[:, :, i : i + stride * oh : stride, j : j + stride * ow : stride]
            )
    # (k*k, N, C, OH, OW) → (N, OH, OW, C, k*k) → (N*OH*OW, C*k*k)
    patches = jnp.stack(cols)  # (k*k, N, C, OH, OW)
    patches = patches.transpose(1, 3, 4, 2, 0)  # N, OH, OW, C, k*k
    return patches.reshape(n * oh * ow, c * k * k)
