"""AOT artifact builder (build-time only; never on the request path).

``python -m compile.aot --out-dir ../artifacts`` produces:

* ``golden_cnn.hlo.txt``   — float forward of the trained tiny CNN with
  weights baked in, input (BATCH,1,16,16) f32 → (BATCH,4) logits.
* ``sac_matmul.hlo.txt``   — the Pallas SAC bit-plane matmul lowered to
  HLO (interpret mode), inputs (A, planes) → product. Demonstrates the
  L1 kernel surviving the full AOT → PJRT → rust round trip.
* ``weights.bin``          — TTW1 quantized weights (fp16 Q1.15) for the
  rust side (kneading, SAC functional path, timing sims).
* ``weights_int8.bin``     — same in int8 Q1.7.
* ``metadata.json``        — shapes, scales, training summary.
* ``train_log.json``       — loss curve for EXPERIMENTS.md.

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref, sac_conv

GOLDEN_BATCH = 8
SAC_DEMO_M, SAC_DEMO_K, SAC_DEMO_N = 64, 72, 16
SAC_DEMO_BITS = 16


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser).

    `print_large_constants=True` is load-bearing: the default printer
    elides big constants as `{...}`, which the HLO text parser silently
    accepts as zeros — baked-in trained weights would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax 0.8's metadata attributes (source_end_line etc.) are unknown to
    # the xla_extension 0.5.1 text parser on the rust side.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def write_ttw1(path: pathlib.Path, layers: list[tuple[str, np.ndarray, int]], mode: str):
    """Write the TTW1 weight file (see rust/src/model/io.rs)."""
    header_layers = []
    payload = bytearray()
    offset = 0
    for name, w, frac_bits in layers:
        w4 = w.reshape(w.shape[0], -1, 1, 1) if w.ndim == 2 else w
        count = int(w4.size)
        header_layers.append(
            {
                "name": name,
                "shape": list(w4.shape),
                "frac_bits": frac_bits,
                "offset": offset,
                "count": count,
            }
        )
        payload += w4.astype("<i2").tobytes()
        offset += count
    header = json.dumps({"mode": mode, "layers": header_layers}).encode()
    with open(path, "wb") as f:
        f.write(b"TTW1")
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        f.write(bytes(payload))


def build(out_dir: pathlib.Path, seed: int, steps: int) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    t0 = time.time()

    # 1. Train the tiny CNN on synthetic data.
    params, log = model.train(seed=seed, steps=steps)
    train_s = time.time() - t0
    print(f"[aot] trained tiny CNN: eval acc {log['eval_accuracy']:.3f} in {train_s:.1f}s")

    # 2. Golden float model → HLO text (weights baked in).
    spec = jax.ShapeDtypeStruct((GOLDEN_BATCH, 1, model.IMAGE_HW, model.IMAGE_HW), jnp.float32)
    golden = lambda x: (model.forward_float(params, x),)
    golden_hlo = to_hlo_text(jax.jit(golden).lower(spec))
    (out_dir / "golden_cnn.hlo.txt").write_text(golden_hlo)

    # 3. Pallas SAC matmul demo → HLO text.
    a_spec = jax.ShapeDtypeStruct((SAC_DEMO_M, SAC_DEMO_K), jnp.int32)
    p_spec = jax.ShapeDtypeStruct((SAC_DEMO_BITS, SAC_DEMO_K, SAC_DEMO_N), jnp.int8)
    sac_fn = lambda a, p: (sac_conv.sac_matmul(a, p, block_m=64, block_n=16),)
    sac_hlo = to_hlo_text(jax.jit(sac_fn).lower(a_spec, p_spec))
    (out_dir / "sac_matmul.hlo.txt").write_text(sac_hlo)

    # 4. Quantized weights for the rust side (per-layer frac bits).
    for mode, fname in [("fp16", "weights.bin"), ("int8", "weights_int8.bin")]:
        qw = model.quantize_weights(params, mode)
        write_ttw1(
            out_dir / fname,
            [
                ("conv1", qw["conv1"], qw["conv1_frac"]),
                ("conv2", qw["conv2"], qw["conv2_frac"]),
                ("conv3", qw["conv3"], qw["conv3_frac"]),
                ("fc", qw["fc_w"].T, qw["fc_w_frac"]),  # (4,16) OI → OIHW
            ],
            mode,
        )

    # 5. Quantized-model agreement: SAC path vs float model (sanity) and
    #    vs the integer oracle (exactness).
    key = jax.random.PRNGKey(seed + 1)
    x, y = model.make_dataset(key, 128)
    x_q = model.quantize_acts(x)
    qw16 = model.quantize_weights(params, "fp16")
    logits_sac = model.forward_sac_quantized(qw16, x_q, "fp16")
    logits_ref = model.forward_ref_quantized(qw16, x_q, "fp16")
    assert (np.array(logits_sac) == np.array(logits_ref)).all(), "SAC != integer oracle"
    q_acc = float((np.array(logits_sac).argmax(1) == np.array(y)).mean())
    f_acc = float((np.array(model.forward_float(params, x)).argmax(1) == np.array(y)).mean())
    print(f"[aot] quantized fp16 accuracy {q_acc:.3f} (float {f_acc:.3f})")

    # 6. Golden-model reference vector for the rust runtime smoke test.
    x_ref = np.array(x[:GOLDEN_BATCH], dtype=np.float32)
    logits_ref_f = np.array(model.forward_float(params, jnp.asarray(x_ref)))
    np.save(out_dir / "golden_input.npy", x_ref)
    np.save(out_dir / "golden_logits.npy", logits_ref_f)
    # Flat binary copies for the rust loader (no npy parser needed).
    x_ref.astype("<f4").tofile(out_dir / "golden_input.f32")
    logits_ref_f.astype("<f4").tofile(out_dir / "golden_logits.f32")

    # 6b. Cross-language bit-exactness vectors: the rust integer SAC
    #     pipeline must reproduce these logits *exactly* (invariant I3
    #     across languages). Inputs are the quantized Q8.8 images.
    x_q8 = np.array(model.quantize_acts(jnp.asarray(x_ref)), dtype=np.int32)
    quant_logits = np.array(model.forward_sac_quantized(qw16, jnp.asarray(x_q8), "fp16"))
    x_q8.astype("<i4").tofile(out_dir / "quant_input.i32")
    quant_logits.astype("<i4").tofile(out_dir / "quant_logits.i32")

    # 7. SAC demo reference vectors.
    rng = np.random.default_rng(seed)
    a_demo = rng.integers(0, 1 << 10, (SAC_DEMO_M, SAC_DEMO_K)).astype(np.int32)
    w_demo = rng.integers(-(1 << 14), 1 << 14, (SAC_DEMO_K, SAC_DEMO_N)).astype(np.int32)
    p_demo = ref.decompose_planes(w_demo, SAC_DEMO_BITS)
    out_demo = np.array(a_demo.astype(np.int64) @ w_demo.astype(np.int64), dtype=np.int32)
    a_demo.astype("<i4").tofile(out_dir / "sac_demo_a.i32")
    p_demo.astype("<i1").tofile(out_dir / "sac_demo_planes.i8")
    out_demo.astype("<i4").tofile(out_dir / "sac_demo_out.i32")

    metadata = {
        "seed": seed,
        "train_steps": steps,
        "train_seconds": round(train_s, 2),
        "eval_accuracy": log["eval_accuracy"],
        "quantized_fp16_accuracy": q_acc,
        "float_accuracy_on_same_batch": f_acc,
        "golden": {
            "file": "golden_cnn.hlo.txt",
            "input_shape": [GOLDEN_BATCH, 1, model.IMAGE_HW, model.IMAGE_HW],
            "output_shape": [GOLDEN_BATCH, model.NUM_CLASSES],
        },
        "sac_demo": {
            "file": "sac_matmul.hlo.txt",
            "a_shape": [SAC_DEMO_M, SAC_DEMO_K],
            "planes_shape": [SAC_DEMO_BITS, SAC_DEMO_K, SAC_DEMO_N],
            "out_shape": [SAC_DEMO_M, SAC_DEMO_N],
        },
        "weights": {"fp16": "weights.bin", "int8": "weights_int8.bin"},
        "quant": {
            "input": "quant_input.i32",
            "logits": "quant_logits.i32",
            "input_shape": [GOLDEN_BATCH, 1, model.IMAGE_HW, model.IMAGE_HW],
            "logits_shape": [GOLDEN_BATCH, model.NUM_CLASSES],
            "act_frac_bits": model.ACT_FRAC_BITS,
        },
    }
    (out_dir / "metadata.json").write_text(json.dumps(metadata, indent=2) + "\n")
    (out_dir / "train_log.json").write_text(json.dumps(log, indent=2) + "\n")
    print(f"[aot] artifacts written to {out_dir} in {time.time() - t0:.1f}s")
    return metadata


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", type=pathlib.Path, default=pathlib.Path("../artifacts"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()
    build(args.out_dir, args.seed, args.steps)


if __name__ == "__main__":
    main()
