"""Layer 2 — the JAX model: a small CNN trained on synthetic data at
build time, plus the quantized integer inference pipeline that calls the
L1 SAC kernels.

The CNN must stay in sync with ``rust/src/model/zoo.rs::tiny_cnn``:

    conv1: 1→8  3×3 pad1 @16×16, relu, maxpool2   → 8×8
    conv2: 8→16 3×3 pad1 @8×8,  relu, maxpool2    → 4×4
    conv3: 16→16 3×3 pad1 @4×4, relu, global-mean → 16
    fc:    16→4 logits

The quantized path is integer-only and deterministic (activations Q8.8,
weights Q1.15 or Q1.7), so the rust functional SAC pipeline can be
checked bit-exactly against it.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref, sac_conv

# Keep in sync with rust/src/model/zoo.rs::tiny_cnn.
TINY_CNN_SPEC = (
    ("conv1", 1, 8, 3, 1, 1, 16),
    ("conv2", 8, 16, 3, 1, 1, 8),
    ("conv3", 16, 16, 3, 1, 1, 4),
)
NUM_CLASSES = 4
IMAGE_HW = 16

# Q formats (match rust/src/quant/fixed.rs).
ACT_FRAC_BITS = 8  # activations Q8.8
W_FRAC_BITS = {"fp16": 15, "int8": 7}
W_BITS = {"fp16": 16, "int8": 8}


class Params(NamedTuple):
    conv1: jnp.ndarray  # (8, 1, 3, 3)
    conv2: jnp.ndarray  # (16, 8, 3, 3)
    conv3: jnp.ndarray  # (16, 16, 3, 3)
    fc_w: jnp.ndarray  # (16, 4)
    fc_b: jnp.ndarray  # (4,)


def init_params(key: jax.Array) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    he = lambda k, shape, fan_in: jax.random.normal(k, shape) * np.sqrt(2.0 / fan_in)
    return Params(
        conv1=he(k1, (8, 1, 3, 3), 9),
        conv2=he(k2, (16, 8, 3, 3), 72),
        conv3=he(k3, (16, 16, 3, 3), 144),
        fc_w=he(k4, (16, NUM_CLASSES), 16),
        fc_b=jnp.zeros((NUM_CLASSES,)),
    )


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1), (1, 1)], dimension_numbers=("NCHW", "OIHW", "NCHW")
    )


def _pool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def forward_float(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Float forward: (N,1,16,16) → (N,4) logits. The AOT golden model."""
    h = _pool2(jax.nn.relu(_conv(x, params.conv1)))
    h = _pool2(jax.nn.relu(_conv(h, params.conv2)))
    h = jax.nn.relu(_conv(h, params.conv3))
    feats = h.mean(axis=(2, 3))  # global average pool → (N, 16)
    return feats @ params.fc_w + params.fc_b


# ---------------------------------------------------------------------------
# Synthetic dataset: four oriented-gradient classes + noise. Linearly
# non-separable enough that the CNN must actually learn.
# ---------------------------------------------------------------------------


def make_dataset(key: jax.Array, n: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    k_label, k_noise, k_phase = jax.random.split(key, 3)
    labels = jax.random.randint(k_label, (n,), 0, NUM_CLASSES)
    yy, xx = jnp.mgrid[0:IMAGE_HW, 0:IMAGE_HW].astype(jnp.float32) / IMAGE_HW
    phase = jax.random.uniform(k_phase, (n, 1, 1)) * 2.0
    base = jnp.stack(
        [
            jnp.sin(2 * np.pi * (xx[None] + phase)),          # vertical stripes
            jnp.sin(2 * np.pi * (yy[None] + phase)),          # horizontal stripes
            jnp.sin(2 * np.pi * (xx[None] + yy[None] + phase)),  # diagonal
            jnp.sin(4 * np.pi * ((xx - 0.5)[None] ** 2 + (yy - 0.5)[None] ** 2 + phase)),  # rings
        ]
    )  # (4, n, H, W)
    imgs = base[labels, jnp.arange(n)]
    noise = jax.random.normal(k_noise, imgs.shape) * 0.3
    x = (imgs + noise)[:, None, :, :]  # (N, 1, H, W)
    return x.astype(jnp.float32), labels


# ---------------------------------------------------------------------------
# Training (plain SGD + momentum; no external deps).
# ---------------------------------------------------------------------------


def loss_fn(params: Params, x, y):
    logits = forward_float(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


@functools.partial(jax.jit, donate_argnums=(0, 1))
def train_step(params: Params, momentum: Params, x, y, lr: float = 0.05, beta: float = 0.9):
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    momentum = jax.tree.map(lambda m, g: beta * m + g, momentum, grads)
    params = jax.tree.map(lambda p, m: p - lr * m, params, momentum)
    return params, momentum, loss


def train(seed: int = 0, steps: int = 400, batch: int = 64):
    """Train the tiny CNN; returns (params, log) where log records the
    loss curve and final train/eval accuracy."""
    key = jax.random.PRNGKey(seed)
    k_init, k_data, k_eval = jax.random.split(key, 3)
    params = init_params(k_init)
    momentum = jax.tree.map(jnp.zeros_like, params)
    log = {"loss": [], "step": []}
    for step in range(steps):
        k_data, k_batch = jax.random.split(k_data)
        x, y = make_dataset(k_batch, batch)
        params, momentum, loss = train_step(params, momentum, x, y)
        if step % 10 == 0 or step == steps - 1:
            log["loss"].append(float(loss))
            log["step"].append(step)
    # Final accuracies on held-out data.
    xe, ye = make_dataset(k_eval, 512)
    acc = float((forward_float(params, xe).argmax(1) == ye).mean())
    log["eval_accuracy"] = acc
    return params, log


# ---------------------------------------------------------------------------
# Quantization + integer SAC inference pipeline.
# ---------------------------------------------------------------------------


def quantize_weights(params: Params, mode: str = "fp16") -> dict[str, np.ndarray]:
    """Quantize conv + fc weights to the mode's bit width with a
    *per-layer* fractional-bit count chosen so the layer's max |w| does
    not saturate (round-half-even) — mirrors rust/src/quant/fixed.rs and
    the per-layer precision the paper notes DNNs need (§II.A).

    Returns ``{name: qweights}`` plus ``{name + "_frac": frac_bits}``.
    """
    max_frac = W_FRAC_BITS[mode]
    bound = 2 ** (W_BITS[mode] - 1) - 1

    def q(w):
        w = np.asarray(w, dtype=np.float64)
        max_abs = np.abs(w).max()
        frac = max_frac
        while frac > 0 and max_abs * (1 << frac) > bound:
            frac -= 1
        r = np.rint(w * (1 << frac))
        return np.clip(r, -bound, bound).astype(np.int32), frac

    out: dict[str, np.ndarray | int] = {}
    for name, w in [
        ("conv1", params.conv1),
        ("conv2", params.conv2),
        ("conv3", params.conv3),
        ("fc_w", params.fc_w),
    ]:
        out[name], out[name + "_frac"] = q(w)
    return out


def quantize_acts(x: jnp.ndarray) -> jnp.ndarray:
    """Input images → Q8.8 integers (signed; inputs may be negative)."""
    return jnp.clip(jnp.rint(x * (1 << ACT_FRAC_BITS)), -(1 << 15), (1 << 15) - 1).astype(
        jnp.int32
    )


def _requantize(acc: jnp.ndarray, w_frac: int) -> jnp.ndarray:
    """Conv accumulator (scale 2^(8+w_frac)) → Q8.8 by *rounding*
    arithmetic right shift (add half-ulp then shift — deterministic,
    mirrored by rust/src/runtime/golden.rs)."""
    return jnp.right_shift(acc + (1 << (w_frac - 1)), w_frac)


def _pool2_int(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, jnp.iinfo(jnp.int32).min, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def forward_sac_quantized(
    qw: dict[str, np.ndarray], x_q: jnp.ndarray, mode: str = "fp16", interpret: bool = True
) -> jnp.ndarray:
    """Integer-only forward using the Pallas SAC conv for every layer.

    Returns int32 logits in Q8.8 × 2^w_frac scale (argmax-compatible with
    the float model after training). Bit-exactly reproducible in rust.
    """
    bits = W_BITS[mode]
    h = x_q
    for name in ("conv1", "conv2", "conv3"):
        planes = jnp.asarray(ref.decompose_planes(qw[name], bits))
        acc = sac_conv.sac_conv2d(h, planes, stride=1, pad=1, interpret=interpret)
        h = jnp.maximum(_requantize(acc, qw[name + "_frac"]), 0)  # relu, Q8.8
        if name != "conv3":
            h = _pool2_int(h)
    # Global average pool in integers: sum then floor-divide.
    feats = h.sum(axis=(2, 3)) // (h.shape[2] * h.shape[3])  # (N, 16) Q8.8
    planes_fc = jnp.asarray(ref.decompose_planes(qw["fc_w"], bits))
    logits = sac_conv.sac_matmul(feats, planes_fc, interpret=interpret)
    return logits


def forward_ref_quantized(qw: dict[str, np.ndarray], x_q: jnp.ndarray, mode: str = "fp16"):
    """Same integer pipeline with plain integer convs (oracle for I5)."""
    h = x_q
    for name in ("conv1", "conv2", "conv3"):
        acc = ref.conv2d_ref(h, jnp.asarray(qw[name]), stride=1, pad=1)
        h = jnp.maximum(_requantize(acc, qw[name + "_frac"]), 0)
        if name != "conv3":
            h = _pool2_int(h)
    feats = h.sum(axis=(2, 3)) // (h.shape[2] * h.shape[3])
    return ref.matmul_ref(feats, jnp.asarray(qw["fc_w"]))
