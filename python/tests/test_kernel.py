"""L1 correctness: Pallas SAC kernels vs the pure-jnp oracle.

Invariant I5 (DESIGN.md): exact integer equality, no tolerances —
SAC is a re-association of the same integer sum.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sac_conv


def rand_weights(rng, shape, bits):
    bound = 2 ** (bits - 1)
    return rng.integers(-(bound - 1), bound, shape).astype(np.int32)


# ---------------------------------------------------------------------------
# Plane decomposition.
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    k=st.integers(1, 40),
    n=st.integers(1, 24),
    bits=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decompose_compose_roundtrip(k, n, bits, seed):
    rng = np.random.default_rng(seed)
    w = rand_weights(rng, (k, n), bits)
    planes = ref.decompose_planes(w, bits)
    assert planes.shape == (bits, k, n)
    assert planes.dtype == np.int8
    assert set(np.unique(planes)) <= {-1, 0, 1}
    assert (ref.compose_planes(planes) == w).all()


def test_decompose_rejects_overflow():
    with pytest.raises(ValueError):
        ref.decompose_planes(np.array([[1 << 15]]), 16)
    with pytest.raises(ValueError):
        ref.decompose_planes(np.array([[-(1 << 7)]]), 8)


def test_decompose_planes_jnp_matches_numpy():
    rng = np.random.default_rng(7)
    w = rand_weights(rng, (13, 5), 16)
    a = np.array(sac_conv.decompose_planes_jnp(jnp.asarray(w), 16))
    b = ref.decompose_planes(w, 16)
    assert (a == b).all()


# ---------------------------------------------------------------------------
# SAC matmul vs oracle — the core kernel contract.
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 64),
    n=st.integers(1, 40),
    bits=st.sampled_from([8, 16]),
    block=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sac_matmul_exact(m, k, n, bits, block, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << 12, (m, k)).astype(np.int32)
    w = rand_weights(rng, (k, n), bits)
    planes = ref.decompose_planes(w, bits)
    got = np.array(
        sac_conv.sac_matmul(jnp.asarray(a), jnp.asarray(planes), block_m=block, block_n=block)
    )
    want = np.array(ref.matmul_ref(jnp.asarray(a), jnp.asarray(w)))
    assert (got == want).all()


def test_sac_matmul_negative_activations():
    # FC layers may see signed activations.
    rng = np.random.default_rng(3)
    a = rng.integers(-(1 << 12), 1 << 12, (9, 17)).astype(np.int32)
    w = rand_weights(rng, (17, 6), 16)
    planes = ref.decompose_planes(w, 16)
    got = np.array(sac_conv.sac_matmul(jnp.asarray(a), jnp.asarray(planes)))
    assert (got == np.array(ref.matmul_ref(jnp.asarray(a), jnp.asarray(w)))).all()


def test_sac_matmul_zero_plane_skip_equivalent():
    # Skipping all-zero planes must not change results.
    rng = np.random.default_rng(11)
    a = rng.integers(0, 1 << 10, (16, 8)).astype(np.int32)
    w = (rng.integers(0, 2, (8, 8)) * 5).astype(np.int32)  # only bits 0 and 2
    planes = ref.decompose_planes(w, 16)  # planes 1, 3.. are all-zero
    on = sac_conv.sac_matmul(jnp.asarray(a), jnp.asarray(planes), skip_zero_planes=True)
    off = sac_conv.sac_matmul(jnp.asarray(a), jnp.asarray(planes), skip_zero_planes=False)
    assert (np.array(on) == np.array(off)).all()


def test_sac_matmul_shape_validation():
    a = jnp.zeros((4, 5), jnp.int32)
    p = jnp.zeros((16, 6, 3), jnp.int8)  # K mismatch
    with pytest.raises(ValueError):
        sac_conv.sac_matmul(a, p)


def test_sac_ref_matches_matmul_ref():
    # The jnp SAC oracle itself re-associates correctly.
    rng = np.random.default_rng(5)
    a = rng.integers(0, 1 << 10, (12, 20)).astype(np.int32)
    w = rand_weights(rng, (20, 7), 16)
    planes = ref.decompose_planes(w, 16)
    got = np.array(ref.sac_matmul_ref(jnp.asarray(a), jnp.asarray(planes)))
    assert (got == np.array(ref.matmul_ref(jnp.asarray(a), jnp.asarray(w)))).all()


# ---------------------------------------------------------------------------
# SAC conv2d vs oracle.
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3),
    c=st.integers(1, 8),
    o=st.integers(1, 8),
    hw=st.integers(3, 12),
    k=st.sampled_from([1, 3]),
    pad=st.sampled_from([0, 1]),
    stride=st.sampled_from([1, 2]),
    bits=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sac_conv2d_exact(n, c, o, hw, k, pad, stride, bits, seed):
    if hw + 2 * pad < k:
        return
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 1 << 9, (n, c, hw, hw)).astype(np.int32)
    w = rand_weights(rng, (o, c, k, k), bits)
    planes = ref.decompose_planes(w, bits)
    got = np.array(
        sac_conv.sac_conv2d(jnp.asarray(x), jnp.asarray(planes), stride=stride, pad=pad)
    )
    want = np.array(ref.conv2d_ref(jnp.asarray(x), jnp.asarray(w), stride=stride, pad=pad))
    assert got.shape == want.shape
    assert (got == want).all()


def test_im2col_matches_conv():
    # im2col × reshaped weights == conv (the bridge sac_conv2d relies on).
    rng = np.random.default_rng(2)
    x = rng.integers(0, 100, (2, 3, 7, 7)).astype(np.int32)
    w = rand_weights(rng, (5, 3, 3, 3), 16)
    cols = ref.im2col(jnp.asarray(x), 3, stride=1, pad=1)
    flat = np.array(cols) @ w.reshape(5, -1).T
    want = np.array(ref.conv2d_ref(jnp.asarray(x), jnp.asarray(w), stride=1, pad=1))
    got = flat.reshape(2, 7, 7, 5).transpose(0, 3, 1, 2)
    assert (got == want).all()
