"""AOT path: HLO text lowering and TTW1 weight-file format."""

import json
import pathlib
import struct

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import sac_conv


def test_to_hlo_text_lowers_plain_jax():
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    fn = lambda x, y: (jnp.matmul(x, y) + 2.0,)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "dot" in text


def test_to_hlo_text_lowers_pallas_interpret():
    a_spec = jax.ShapeDtypeStruct((16, 8), jnp.int32)
    p_spec = jax.ShapeDtypeStruct((8, 8, 8), jnp.int8)
    fn = lambda a, p: (sac_conv.sac_matmul(a, p, block_m=16, block_n=8),)
    text = aot.to_hlo_text(jax.jit(fn).lower(a_spec, p_spec))
    assert "HloModule" in text
    # interpret=True means no Mosaic custom-call survives into HLO.
    assert "tpu_custom_call" not in text


def test_write_ttw1_roundtrip(tmp_path: pathlib.Path):
    w1 = np.arange(-9, 9).reshape(2, 1, 3, 3).astype(np.int32)
    w2 = np.array([[1, -2], [3, -4]]).astype(np.int32)
    path = tmp_path / "w.bin"
    aot.write_ttw1(path, [("conv1", w1, 15), ("fc", w2, 12)], "fp16")
    raw = path.read_bytes()
    assert raw[:4] == b"TTW1"
    (hdr_len,) = struct.unpack("<I", raw[4:8])
    header = json.loads(raw[8 : 8 + hdr_len])
    assert header["mode"] == "fp16"
    assert header["layers"][0]["shape"] == [2, 1, 3, 3]
    assert header["layers"][1]["shape"] == [2, 2, 1, 1]  # 2-D promoted to OIHW
    assert header["layers"][1]["frac_bits"] == 12
    payload = np.frombuffer(raw[8 + hdr_len :], dtype="<i2")
    assert (payload[:18] == w1.flatten()).all()
    assert (payload[18:] == w2.flatten()).all()


def test_build_writes_all_artifacts(tmp_path: pathlib.Path):
    meta = aot.build(tmp_path, seed=3, steps=60)
    for f in [
        "golden_cnn.hlo.txt",
        "sac_matmul.hlo.txt",
        "weights.bin",
        "weights_int8.bin",
        "metadata.json",
        "train_log.json",
        "golden_input.f32",
        "golden_logits.f32",
        "sac_demo_a.i32",
        "sac_demo_planes.i8",
        "sac_demo_out.i32",
    ]:
        assert (tmp_path / f).exists(), f
    assert meta["eval_accuracy"] > 0.5
    # Golden reference vectors are self-consistent with the HLO shapes.
    x = np.fromfile(tmp_path / "golden_input.f32", dtype="<f4")
    logits = np.fromfile(tmp_path / "golden_logits.f32", dtype="<f4")
    assert x.size == aot.GOLDEN_BATCH * model.IMAGE_HW**2
    assert logits.size == aot.GOLDEN_BATCH * model.NUM_CLASSES
