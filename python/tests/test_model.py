"""L2 correctness: model shapes, quantization, training smoke, and the
SAC-vs-oracle agreement of the full quantized pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def trained():
    # Short training run shared across tests (smoke-level).
    params, log = model.train(seed=1, steps=120, batch=32)
    return params, log


def test_forward_shapes():
    params = model.init_params(jax.random.PRNGKey(0))
    x = jnp.zeros((5, 1, 16, 16))
    logits = model.forward_float(params, x)
    assert logits.shape == (5, model.NUM_CLASSES)


def test_dataset_shapes_and_labels():
    x, y = model.make_dataset(jax.random.PRNGKey(3), 64)
    assert x.shape == (64, 1, 16, 16)
    assert y.shape == (64,)
    assert set(np.unique(np.array(y))) <= set(range(model.NUM_CLASSES))
    # All four classes appear in a reasonable batch.
    assert len(np.unique(np.array(y))) == model.NUM_CLASSES


def test_training_reduces_loss_and_learns(trained):
    _, log = trained
    assert log["loss"][0] > log["loss"][-1], "loss must decrease"
    assert log["eval_accuracy"] > 0.7, f"eval acc {log['eval_accuracy']}"


def test_quantize_weights_bounds(trained):
    params, _ = trained
    for mode, bits in [("fp16", 16), ("int8", 8)]:
        qw = model.quantize_weights(params, mode)
        bound = 2 ** (bits - 1)
        for name in ("conv1", "conv2", "conv3", "fc_w"):
            assert np.abs(qw[name]).max() < bound
            frac = qw[name + "_frac"]
            assert 0 < frac <= model.W_FRAC_BITS[mode]
            # Dequantized weights approximate the originals.
            w = np.asarray(getattr(params, name if name != "fc_w" else "fc_w"))
            err = np.abs(qw[name] / (1 << frac) - w).max()
            assert err <= 0.5 / (1 << frac) + 1e-9


def test_sac_pipeline_equals_integer_oracle(trained):
    params, _ = trained
    x, _ = model.make_dataset(jax.random.PRNGKey(5), 16)
    x_q = model.quantize_acts(x)
    for mode in ("fp16", "int8"):
        qw = model.quantize_weights(params, mode)
        sac = np.array(model.forward_sac_quantized(qw, x_q, mode))
        oracle = np.array(model.forward_ref_quantized(qw, x_q, mode))
        assert (sac == oracle).all(), f"mode {mode}: SAC != oracle"


def test_quantized_model_tracks_float(trained):
    params, _ = trained
    x, y = model.make_dataset(jax.random.PRNGKey(7), 256)
    x_q = model.quantize_acts(x)
    qw = model.quantize_weights(params, "fp16")
    qacc = float(
        (np.array(model.forward_ref_quantized(qw, x_q, "fp16")).argmax(1) == np.array(y)).mean()
    )
    facc = float((np.array(model.forward_float(params, x)).argmax(1) == np.array(y)).mean())
    assert qacc >= facc - 0.05, f"quantized acc {qacc} vs float {facc}"


def test_quantize_acts_is_saturating():
    x = jnp.array([[300.0, -300.0, 0.5]])
    q = np.array(model.quantize_acts(x))
    assert q[0, 0] == (1 << 15) - 1
    assert q[0, 1] == -(1 << 15)
    assert q[0, 2] == 128
