//! Quickstart: the engine façade in five minutes.
//!
//! Builds a serving [`Engine`] — typed options, one registered model,
//! compiled (kneaded) exactly once — then submits images through an
//! [`InferSession`] and shows
//!
//!   1. the uniform submit/wait surface and its serving metrics
//!      (exact p50/p95/p99 latency percentiles);
//!   2. the compile-once plan behind it: kneaded footprint and the
//!      kneading compression ratio the accelerator exploits;
//!   3. bit-exactness: engine-served logits equal the legacy
//!      re-knead-per-call scalar pipeline (invariant I5).
//!
//! [`Engine`]: tetris::engine::Engine
//! [`InferSession`]: tetris::engine::InferSession
//!
//! Run: `cargo run --release --example quickstart`

use tetris::coordinator::demo::synthetic_image;
use tetris::coordinator::SacBackend;
use tetris::engine::Engine;
use tetris::model::{zoo, Tensor};
use tetris::runtime::quantized;
use tetris::util::rng::Rng;

fn main() {
    // One typed builder call configures what used to be scattered
    // across env vars and raw handles.
    let weights = SacBackend::synthetic_weights(42).expect("weights");
    let engine = Engine::builder()
        .workers(2)
        .mem_budget_mb(128)
        .max_batch(8)
        .register("tiny", zoo::tiny_cnn(), weights.clone())
        .build()
        .expect("engine");
    let session = engine.session();

    // Submit a small batch and wait for ordered results.
    let mut rng = Rng::new(7);
    let images: Vec<Tensor<i32>> = (0..8).map(|_| synthetic_image(&mut rng)).collect();
    let responses = session.infer_batch("tiny", &images).expect("infer");
    for (i, r) in responses.iter().enumerate() {
        println!(
            "image {i}: class {} (logits {:?}, batch of {})",
            r.argmax, r.logits, r.batch_size
        );
    }

    // The compile-once plan behind the model registry.
    let meta = &engine.models()[0];
    let plan = meta.plan().expect("sac model");
    println!(
        "model `{}` [{}]: {} source weights kneaded once into {} ({:.2}x compression), \
         fused tile height {}",
        meta.name(),
        meta.backend(),
        plan.source_weights(),
        plan.kneaded_weights(),
        plan.source_weights() as f64 / plan.kneaded_weights() as f64,
        plan.tile_rows,
    );

    // Bit-exactness vs the legacy scalar pipeline (SAC ≡ MAC).
    for (img, resp) in images.iter().zip(&responses) {
        let mut x = img.clone();
        let s = x.shape().to_vec();
        x.reshape(&[1, s[0], s[1], s[2]]).expect("reshape");
        let want = quantized::forward_scalar(&weights, &x).expect("scalar");
        assert_eq!(resp.logits[..], want.data()[..], "engine must be bit-exact");
    }
    println!("bit-exact vs legacy scalar pipeline: true");

    let metrics = engine.shutdown();
    println!("{}", metrics.render());
}
