//! Quickstart: weight kneading + SAC in five minutes.
//!
//! Builds a synaptic lane, kneads it, runs split-and-accumulate, and
//! shows (1) the partial sum is bit-exactly the MAC result and (2) the
//! cycle count shrinks by the kneading ratio.
//!
//! Run: `cargo run --release --example quickstart`

use tetris::config::Mode;
use tetris::kneading::{knead_lane, Lane};
use tetris::model::weights::{profile_with, DensityCalibration};
use tetris::sac::SacUnit;
use tetris::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);

    // A lane: 64 (weight, activation) pairs like one conv reduction.
    let profile = profile_with("vgg16", Mode::Fp16, DensityCalibration::Fig2).unwrap();
    let weights = profile.generate(64, &mut rng);
    let acts: Vec<i32> = (0..64).map(|_| rng.below(1 << 12) as i32).collect();
    let lane = Lane::new(weights, acts);

    // The accelerator's view: knead with stride 16 (the paper default).
    let kneaded = knead_lane(&lane, 16, Mode::Fp16);
    println!("lane weights:          {}", lane.len());
    println!("kneaded weights:       {}", kneaded.kneaded_len());
    println!(
        "kneading ratio:        {:.2}x  (cycles saved: {:.0}%)",
        kneaded.ratio().unwrap(),
        (1.0 - kneaded.kneaded_len() as f64 / lane.len() as f64) * 100.0
    );

    // SAC: splitters route activations to segment adders; one rear
    // shift-and-add finishes the partial sum.
    let mut unit = SacUnit::new(Mode::Fp16);
    let sac = unit.process_kneaded(&kneaded, &lane);
    let mac = lane.mac_reference();
    println!("SAC partial sum:       {sac}");
    println!("MAC reference:         {mac}");
    assert_eq!(sac, mac, "SAC must equal MAC bit-exactly");
    println!("bit-exact:             true");

    let a = unit.activity();
    println!(
        "activity: {} kneaded weights, {} segment adds, {} slot decodes, {} tree drain(s)",
        a.kneaded_weights, a.segment_adds, a.slot_decodes, a.tree_drains
    );
}
