//! Multi-model sessions: register several networks in ONE engine —
//! compiled once each — and serve them concurrently from one shared
//! worker pool through the uniform submit/poll surface.
//!
//! Run: `cargo run --release --example engine_multi_model`

use std::time::Duration;

use tetris::config::Mode;
use tetris::coordinator::demo::synthetic_image_shaped as noise;
use tetris::coordinator::SacBackend;
use tetris::engine::Engine;
use tetris::model::weights::{synthetic_loaded, DensityCalibration};
use tetris::model::zoo;
use tetris::util::rng::Rng;

fn main() {
    // Three models, three shapes: the tiny CNN, a channel-scaled NiN
    // (global-average head → 1000/16 classes), and a scaled GoogleNet
    // inception module (branching topology).
    let nin = zoo::nin().scaled(16, 64);
    let inception = zoo::inception_module("3a").expect("module").scaled(8, 16);
    let nin_w = synthetic_loaded(&nin, Mode::Fp16, 10, "nin", DensityCalibration::Fig2, 3)
        .expect("nin weights");
    let inc_w =
        synthetic_loaded(&inception, Mode::Fp16, 10, "googlenet", DensityCalibration::Fig2, 4)
            .expect("inception weights");

    let engine = Engine::builder()
        .workers(4)
        .max_batch(8)
        .max_wait(Duration::from_micros(500))
        .register("tiny", zoo::tiny_cnn(), SacBackend::synthetic_weights(1).expect("w"))
        .register("nin", nin.clone(), nin_w)
        .register("inception_3a", inception.clone(), inc_w)
        .build()
        .expect("engine");

    for m in engine.models() {
        let plan = m.plan().expect("sac");
        println!(
            "registered `{}` [{}]: {} lanes kneaded once, {} kneaded weights resident, \
             tile height {}, {} sim cycles/image",
            m.name(),
            m.backend(),
            plan.kneads_at_build,
            plan.kneaded_weights(),
            plan.tile_rows,
            m.cycles_per_image(),
        );
    }

    // Interleave submissions across all three models from one session.
    let session = engine.session();
    let mut rng = Rng::new(9);
    let mut tickets = Vec::new();
    for i in 0..24 {
        let ticket = match i % 3 {
            0 => session.submit("tiny", noise(&mut rng, 1, 16)),
            1 => session.submit("nin", noise(&mut rng, nin.layers[0].in_c, 64)),
            _ => session
                .submit("inception_3a", noise(&mut rng, inception.layers[0].in_c, 16)),
        }
        .expect("submit");
        tickets.push(ticket);
    }

    // Poll a bit (non-blocking), then wait out the rest.
    let mut done = 0usize;
    while done < tickets.len() {
        let mut progressed = false;
        for t in &tickets {
            if let Some(resp) = session.poll(t).expect("poll") {
                println!(
                    "ticket (model {}, id {:>2}): {} logits, class {}, {:.0} µs",
                    t.model,
                    t.id,
                    resp.logits.len(),
                    resp.argmax,
                    resp.latency_us
                );
                done += 1;
                progressed = true;
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    let metrics = engine.shutdown();
    println!("{}", metrics.render());
}
