//! Serving-load example: drive the engine with an open-loop arrival
//! process and study batching behaviour under load.
//!
//! Run: `cargo run --release --example serve -- --rps 2000 --seconds 3`

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use tetris::coordinator::SacBackend;
use tetris::engine::Engine;
use tetris::model::{zoo, Tensor};
use tetris::util::cli::Args;
use tetris::util::rng::Rng;

fn main() {
    let args = Args::new("open-loop serving load")
        .opt("rps", "1000", "target arrival rate (requests/second)")
        .opt("seconds", "2", "load duration")
        .opt("max-batch", "8", "batcher bound")
        .opt("max-wait-us", "2000", "batcher deadline in µs")
        .opt("workers", "2", "worker threads")
        .opt("seed", "1", "seed")
        .parse_env(1)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let rps = args.get_f64("rps").expect("rps");
    let seconds = args.get_f64("seconds").expect("seconds");
    let max_batch = args.get_usize("max-batch").expect("max-batch");
    let max_wait = Duration::from_micros(args.get_u64("max-wait-us").expect("wait"));
    let workers = args.get_usize("workers").expect("workers");
    let seed = args.get_u64("seed").expect("seed");

    let use_artifacts = std::path::Path::new("artifacts/weights.bin").exists();
    println!(
        "open-loop load: {rps} req/s for {seconds}s, max_batch {max_batch}, \
         {workers} workers, weights: {}",
        if use_artifacts { "trained" } else { "synthetic" }
    );
    // The engine compiles (kneads) the registered model once; every
    // worker shares the plan, so startup cost ignores `--workers`.
    let weights = if use_artifacts {
        tetris::model::read_weight_file(std::path::Path::new("artifacts/weights.bin"))
            .expect("weights")
    } else {
        SacBackend::synthetic_weights(0xACC).expect("weights")
    };
    let engine = Engine::builder()
        .workers(workers)
        .max_batch(max_batch)
        .max_wait(max_wait)
        .register("tiny", zoo::tiny_cnn(), weights)
        .build()
        .expect("engine");
    let session = engine.session();

    // Open loop: submit on schedule from this thread, redeem tickets
    // from a consumer thread so response backpressure never throttles
    // arrivals. Sessions clone cheaply and share the ticket store.
    let total = (rps * seconds) as u64;
    let interval = Duration::from_secs_f64(1.0 / rps);
    let start = Instant::now();
    let (ticket_tx, ticket_rx) = channel();
    std::thread::scope(|scope| {
        let consumer_session = session.clone();
        let consumer = scope.spawn(move || {
            for _ in 0..total {
                let ticket = ticket_rx.recv().expect("ticket");
                consumer_session.wait(&ticket).expect("wait");
            }
        });
        let mut rng = Rng::new(seed);
        for id in 0..total {
            let target = start + interval.mul_f64(id as f64);
            while Instant::now() < target {
                std::thread::yield_now();
            }
            let mut t = Tensor::zeros(&[1, 16, 16]);
            for v in t.data_mut() {
                *v = rng.range_i64(-300, 300) as i32;
            }
            ticket_tx.send(session.submit("tiny", t).expect("submit")).expect("send");
        }
        consumer.join().expect("consumer");
    });
    let wall = start.elapsed().as_secs_f64();
    let metrics = engine.shutdown();
    println!("{}", metrics.render());
    println!(
        "offered {rps:.0} req/s → achieved {:.0} req/s over {wall:.2}s",
        total as f64 / wall
    );
}
