//! Serving-load example: drive the coordinator with an open-loop
//! arrival process and study batching behaviour under load.
//!
//! Run: `cargo run --release --example serve -- --rps 2000 --seconds 3`

use std::time::{Duration, Instant};

use tetris::coordinator::{BatchPolicy, InferRequest, SacBackend, Server, ServerConfig};
use tetris::model::Tensor;
use tetris::util::cli::Args;
use tetris::util::rng::Rng;

fn main() {
    let args = Args::new("open-loop serving load")
        .opt("rps", "1000", "target arrival rate (requests/second)")
        .opt("seconds", "2", "load duration")
        .opt("max-batch", "8", "batcher bound")
        .opt("max-wait-us", "2000", "batcher deadline in µs")
        .opt("workers", "2", "worker threads")
        .opt("seed", "1", "seed")
        .parse_env(1)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let rps = args.get_f64("rps").expect("rps");
    let seconds = args.get_f64("seconds").expect("seconds");
    let max_batch = args.get_usize("max-batch").expect("max-batch");
    let max_wait = Duration::from_micros(args.get_u64("max-wait-us").expect("wait"));
    let workers = args.get_usize("workers").expect("workers");
    let seed = args.get_u64("seed").expect("seed");

    let use_artifacts = std::path::Path::new("artifacts/weights.bin").exists();
    println!(
        "open-loop load: {rps} req/s for {seconds}s, max_batch {max_batch}, \
         {workers} workers, weights: {}",
        if use_artifacts { "trained" } else { "synthetic" }
    );
    // Compile the plan once; every worker clones the shared backend,
    // so startup kneading is paid once regardless of `--workers`.
    let prototype = if use_artifacts {
        SacBackend::new(
            tetris::model::read_weight_file(std::path::Path::new("artifacts/weights.bin"))
                .expect("weights"),
        )
        .expect("backend")
    } else {
        SacBackend::synthetic(0xACC).expect("backend")
    };
    let server = Server::start_shared(
        ServerConfig { policy: BatchPolicy { max_batch, max_wait }, workers },
        prototype,
    )
    .expect("server");

    // Open loop: submit on schedule from this thread, drain from a
    // consumer thread so response backpressure never throttles arrivals.
    let total = (rps * seconds) as u64;
    let interval = Duration::from_secs_f64(1.0 / rps);
    let start = Instant::now();
    std::thread::scope(|scope| {
        let server_ref = &server;
        let consumer = scope.spawn(move || {
            for _ in 0..total {
                server_ref.recv().expect("recv");
            }
        });
        let mut rng = Rng::new(seed);
        for id in 0..total {
            let target = start + interval.mul_f64(id as f64);
            while Instant::now() < target {
                std::thread::yield_now();
            }
            let mut t = Tensor::zeros(&[1, 16, 16]);
            for v in t.data_mut() {
                *v = rng.range_i64(-300, 300) as i32;
            }
            server_ref.submit(InferRequest::new(id, t)).expect("submit");
        }
        consumer.join().expect("consumer");
    });
    let wall = start.elapsed().as_secs_f64();
    let metrics = server.shutdown();
    println!("{}", metrics.render());
    println!(
        "offered {rps:.0} req/s → achieved {:.0} req/s over {wall:.2}s",
        total as f64 / wall
    );
}
