//! END-TO-END DRIVER — proves all layers compose on a real workload.
//!
//! Pipeline exercised (no Python on the request path):
//!   1. `make artifacts` (done beforehand) trained a tiny CNN in JAX,
//!      quantized it, and AOT-lowered the golden model + the Pallas SAC
//!      kernel to HLO text.
//!   2. This binary validates all artifacts through PJRT (float golden
//!      model, AOT SAC kernel, and the rust kneaded-SAC integer
//!      pipeline — the last two bit-exactly).
//!   3. It then serves batched inference requests through the
//!      coordinator with the kneaded-SAC backend on the trained
//!      weights, reporting latency/throughput and classification
//!      agreement with the golden model.
//!   4. Finally it reports the simulated Tetris vs DaDN cycles for the
//!      served workload — the paper's headline metric on this model.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference`

use std::time::Duration;

use tetris::config::{AccelConfig, CalibConfig};
use tetris::coordinator::{InferBackend, SacBackend};
use tetris::model::zoo;
use tetris::runtime::{ArtifactDir, Engine};
use tetris::sim::{dadn::DadnSim, sample::samples_from_loaded, simulate_network_with_samples};
use tetris::util::cli::Args;
use tetris::util::rng::Rng;

fn main() {
    let args = Args::new("end-to-end driver")
        .opt("requests", "256", "requests to serve")
        .opt("max-batch", "8", "dynamic batch bound")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("seed", "7", "load-generator seed")
        .parse_env(1)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let dir = std::path::PathBuf::from(args.get("artifacts"));
    let requests = args.get_usize("requests").expect("requests");
    let max_batch = args.get_usize("max-batch").expect("max-batch");
    let seed = args.get_u64("seed").expect("seed");

    // ---- Stage 1: validate every artifact through the runtime. ----
    println!("== stage 1: artifact validation (PJRT + bit-exactness) ==");
    let artifacts = ArtifactDir::open(&dir).expect("artifacts (run `make artifacts`)");
    let report = tetris::runtime::golden::validate(&artifacts).expect("golden validation");
    println!(
        "float golden max|err| {:.2e}; AOT Pallas SAC exact: {}; rust kneaded-SAC exact: {}",
        report.golden_max_abs_err, report.sac_kernel_exact, report.quantized_exact
    );

    // ---- Stage 2: serve a batched load through the engine. ----
    println!("\n== stage 2: batched serving (engine, kneaded-SAC backend, 2 workers) ==");
    let weights = artifacts.load_weights().expect("weights");
    let serving = tetris::engine::Engine::builder()
        .workers(2)
        .max_batch(max_batch)
        .max_wait(Duration::from_millis(1))
        .register("tiny", zoo::tiny_cnn(), weights.clone())
        .build()
        .expect("engine");
    let session = serving.session();

    let mut rng = Rng::new(seed);
    let mut images = Vec::new();
    let mut true_classes = Vec::new();
    let mut tickets = Vec::new();
    for _ in 0..requests {
        let (t, class) = tetris::coordinator::demo::dataset_image(&mut rng);
        images.push(t.clone());
        true_classes.push(class);
        tickets.push(session.submit("tiny", t).expect("submit"));
    }
    let mut responses: Vec<_> =
        tickets.iter().map(|t| session.wait(t).expect("wait")).collect();
    responses.sort_by_key(|r| r.id);
    let metrics = serving.shutdown();
    println!("{}", metrics.render());
    let correct = responses
        .iter()
        .filter(|r| r.argmax == true_classes[r.id as usize])
        .count();
    println!(
        "served accuracy vs true labels: {correct}/{requests} ({:.1}%)",
        correct as f64 / requests as f64 * 100.0
    );

    // ---- Stage 3: agreement with the PJRT golden model. ----
    println!("\n== stage 3: classification agreement vs AOT golden model ==");
    let engine = Engine::cpu().expect("pjrt");
    let golden = engine.load_hlo_text(&dir.join("golden_cnn.hlo.txt")).expect("golden hlo");
    let batch = artifacts.shape("golden", "input_shape").expect("shape")[0] as usize;
    let mut agree = 0usize;
    let mut total = 0usize;
    for chunk in responses.chunks(batch) {
        if chunk.len() < batch {
            break; // golden HLO has a fixed batch dimension
        }
        // Dequantize the Q8.8 images back to f32 for the float model.
        let mut input = Vec::with_capacity(batch * 256);
        for r in chunk {
            input.extend(images[r.id as usize].data().iter().map(|&q| q as f32 / 256.0));
        }
        let logits = golden
            .run_f32(&[(&input, &[batch as i64, 1, 16, 16])])
            .expect("golden run");
        for (i, r) in chunk.iter().enumerate() {
            let row = &logits[i * 4..(i + 1) * 4];
            let gold_argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(k, _)| k)
                .unwrap();
            agree += (gold_argmax == r.argmax) as usize;
            total += 1;
        }
    }
    println!(
        "agreement: {agree}/{total} ({:.1}%) — quantized SAC vs float golden",
        agree as f64 / total as f64 * 100.0
    );

    // ---- Stage 4: the paper's metric on this workload. ----
    println!("\n== stage 4: simulated accelerator comparison (served workload) ==");
    let net = zoo::tiny_cnn();
    let cfg = AccelConfig::default();
    let calib = CalibConfig::default();
    let conv_only: Vec<_> =
        weights.layers.iter().filter(|l| l.name != "fc").cloned().collect();
    let conv_w = tetris::model::LoadedWeights { mode: weights.mode, layers: conv_only };
    let samples = samples_from_loaded(&net, &conv_w).expect("samples");
    let dadn = simulate_network_with_samples(&DadnSim, &net, &samples, &cfg, &calib);
    let tetris_sim = simulate_network_with_samples(
        &tetris::sim::tetris::TetrisSim,
        &net,
        &samples,
        &cfg,
        &calib,
    );
    let backend = SacBackend::new(weights).expect("backend");
    let total_cycles = backend.sim_cycles(requests);
    println!(
        "per-image: DaDN {} cycles, Tetris {} cycles → {:.2}x speedup (real trained weights)",
        dadn.total_cycles(),
        tetris_sim.total_cycles(),
        dadn.total_cycles() as f64 / tetris_sim.total_cycles() as f64
    );
    println!(
        "served {} requests ≙ {} Tetris cycles = {:.3} ms @125 MHz",
        requests,
        total_cycles,
        total_cycles as f64 / 125e6 * 1e3
    );
    println!("\nE2E OK — all layers composed.");
}
