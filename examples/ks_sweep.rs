//! Kneading-stride sensitivity sweep (the paper's §IV.C / Figure 11):
//! how T_ks/T_base and the splitter pointer width trade off as KS grows.
//!
//! Run: `cargo run --release --example ks_sweep [-- --network alexnet]`

use tetris::config::{AccelConfig, KsSweep, Mode};
use tetris::kneading::stats::KneadStats;
use tetris::model::weights::{profile_with, DensityCalibration};
use tetris::model::zoo;
use tetris::util::cli::Args;
use tetris::util::rng::Rng;

fn main() {
    let args = Args::new("kneading stride sweep")
        .opt("network", "alexnet", "network name")
        .opt("samples", "200000", "weights sampled")
        .opt("seed", "42", "seed")
        .parse_env(1)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let net = zoo::by_name(args.get("network")).expect("network");
    let n = args.get_usize("samples").expect("samples");
    let seed = args.get_u64("seed").expect("seed");

    println!("KS sweep for {} ({} sampled weights)\n", net.name, n);
    println!(
        "{:>5} {:>8} {:>12} {:>12} {:>14} {:>12}",
        "KS", "ptr bits", "fp16 T/Tb", "int8 T/Tb", "fp16 speedup", "empty grps"
    );
    let sweep = KsSweep::default();
    for &ks in &sweep.ks_values {
        let cfg = AccelConfig { ks, ..AccelConfig::default() };
        let mut row = Vec::new();
        let mut empties = 0;
        for mode in [Mode::Fp16, Mode::Int8] {
            let profile = profile_with(&net.name, mode, DensityCalibration::Fig2).unwrap();
            let mut rng = Rng::new(seed);
            let ws = profile.generate(n, &mut rng);
            let s = KneadStats::measure(&ws, ks, mode);
            row.push(s.time_fraction() / mode.kneaded_per_splitter() as f64);
            empties = s.empty_groups;
        }
        println!(
            "{:>5} {:>8} {:>12.3} {:>12.3} {:>13.2}x {:>12}",
            ks,
            cfg.pointer_bits(),
            row[0],
            row[1],
            1.0 / row[0],
            empties
        );
    }
    println!(
        "\npaper anchors (AlexNet): fp16 0.751 @ KS=10 → 0.642 @ KS=32; int8 ≈ 0.49 flat.\n\
         Larger KS kneads harder but widens every splitter pointer — the\n\
         paper picks KS=16 as the balance (§IV.C)."
    );
}
