//! Compile-once execution plans on a real zoo topology: build a
//! `CompiledNetwork` for a VGG-16 block with synthetic weights, run it
//! through the parallel plan executor, and show
//!
//!   1. the compile/execute split: kneading cost is paid once, then
//!      amortized over every batch (vs the legacy re-knead-per-call
//!      scalar path, timed side by side on the tiny CNN);
//!   2. bit-exactness: the plan's output equals the legacy scalar
//!      pipeline's on the tiny CNN, and the kneaded footprint the plan
//!      holds resident is reported for the VGG block.
//!
//! Run: `cargo run --release --example plan_vgg16 [-- --block 3 --div 4 --hw 32]`

use std::time::Instant;

use tetris::config::Mode;
use tetris::coordinator::SacBackend;
use tetris::model::weights::{synthetic_loaded, DensityCalibration};
use tetris::model::{zoo, Tensor};
use tetris::plan::CompiledNetwork;
use tetris::runtime::quantized;
use tetris::util::cli::Args;
use tetris::util::rng::Rng;

fn main() {
    let args = Args::new("compile-once plan on a VGG-16 block")
        .opt("block", "3", "VGG-16 block to run (1..=5)")
        .opt("div", "4", "channel divisor (1 = full block, slow)")
        .opt("hw", "32", "input spatial size")
        .opt("batch", "4", "images per executed batch")
        .opt("ks", "16", "kneading stride")
        .opt("seed", "11", "weight seed")
        .parse_env(1)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let block_no = args.get_usize("block").expect("block");
    let div = args.get_usize("div").expect("div");
    let hw = args.get_usize("hw").expect("hw");
    let batch = args.get_usize("batch").expect("batch");
    let ks = args.get_usize("ks").expect("ks");
    let seed = args.get_u64("seed").expect("seed");

    // ---- Compile a VGG-16 block once. ----
    let net = zoo::vgg16_block(block_no).expect("block").scaled(div, hw);
    let weights = synthetic_loaded(&net, Mode::Fp16, 12, "vgg16", DensityCalibration::Fig2, seed)
        .expect("weights");
    let t0 = Instant::now();
    let plan = CompiledNetwork::compile(&net, &weights, ks, Mode::Fp16).expect("compile");
    let compile_s = t0.elapsed().as_secs_f64();
    println!(
        "compiled {} (layers: {}, channels ÷{div}, {hw}×{hw} input) in {:.2} ms",
        net.name,
        net.layers.len(),
        compile_s * 1e3
    );
    println!(
        "kneaded footprint: {} source weights → {} kneaded weights ({:.2}× compression), \
         {} lanes kneaded once",
        plan.source_weights(),
        plan.kneaded_weights(),
        plan.source_weights() as f64 / plan.kneaded_weights() as f64,
        plan.kneads_at_build,
    );

    // ---- Execute batches against the resident plan. ----
    let mut rng = Rng::new(seed ^ 0xA11CE);
    let mut x = Tensor::zeros(&[batch, net.layers[0].in_c, hw, hw]);
    for v in x.data_mut() {
        *v = rng.range_i64(-400, 400) as i32;
    }
    let t1 = Instant::now();
    let out = plan.execute(&x).expect("execute");
    let exec_s = t1.elapsed().as_secs_f64();
    let macs = net.total_macs(); // `scaled` already recorded hw×hw inputs
    println!(
        "executed batch of {batch}: output {:?} in {:.2} ms ({:.1} M MAC-equiv/s)",
        out.shape(),
        exec_s * 1e3,
        macs as f64 * batch as f64 / exec_s / 1e6,
    );

    // ---- Compile-once vs re-knead-per-call on the tiny CNN. ----
    let w = SacBackend::synthetic_weights(seed).expect("tiny weights");
    let tiny_plan = quantized::compile_tiny_cnn(&w).expect("tiny plan");
    let mut imgs = Tensor::zeros(&[8, 1, 16, 16]);
    for v in imgs.data_mut() {
        *v = rng.range_i64(-400, 400) as i32;
    }
    let plan_logits = tiny_plan.execute(&imgs).expect("plan logits");
    let scalar_logits = quantized::forward_scalar(&w, &imgs).expect("scalar logits");
    assert_eq!(plan_logits, scalar_logits, "plan must be bit-exact vs legacy");

    let reps = 20;
    let t2 = Instant::now();
    for _ in 0..reps {
        tiny_plan.execute(&imgs).expect("plan");
    }
    let plan_s = t2.elapsed().as_secs_f64() / reps as f64;
    let t3 = Instant::now();
    for _ in 0..reps {
        quantized::forward_scalar(&w, &imgs).expect("scalar");
    }
    let scalar_s = t3.elapsed().as_secs_f64() / reps as f64;
    println!(
        "tiny CNN batch-8: plan {:.3} ms vs re-knead scalar {:.3} ms → {:.2}× \
         (bit-exact logits)",
        plan_s * 1e3,
        scalar_s * 1e3,
        scalar_s / plan_s
    );
}
