//! Per-layer VGG-16 study (the paper's Figure 9 scenario): simulate
//! every conv layer on DaDN and Tetris, print cycles, speedups, and
//! where the time goes.
//!
//! Run: `cargo run --release --example vgg16_layers [-- --ks 16 --mode fp16]`

use tetris::config::{AccelConfig, CalibConfig, Mode};
use tetris::energy::network_energy;
use tetris::model::zoo;
use tetris::sim::{dadn::DadnSim, simulate_network, tetris::TetrisSim};
use tetris::util::cli::Args;

fn main() {
    let args = Args::new("vgg16 per-layer study")
        .opt("ks", "16", "kneading stride")
        .opt("mode", "fp16", "fp16|int8")
        .opt("seed", "42", "sampling seed")
        .parse_env(1)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let mode: Mode = args.get("mode").parse().expect("mode");
    let ks = args.get_usize("ks").expect("ks");
    let seed = args.get_u64("seed").expect("seed");

    let net = zoo::vgg16();
    let calib = CalibConfig::default();
    let base_cfg = AccelConfig::default();
    let cfg = AccelConfig { ks, mode, ..AccelConfig::default() };

    let dadn = simulate_network(&DadnSim, &net, &base_cfg, &calib, seed).unwrap();
    let tetris = simulate_network(&TetrisSim, &net, &cfg, &calib, seed).unwrap();

    println!("VGG-16, Tetris {mode} ks={ks} vs DaDN @125 MHz\n");
    println!(
        "{:<10} {:>12} {:>14} {:>14} {:>9} {:>8}",
        "layer", "MACs (M)", "DaDN cycles", "Tetris cycles", "speedup", "bound"
    );
    for (i, l) in net.layers.iter().enumerate() {
        let d = &dadn.per_layer[i];
        let t = &tetris.per_layer[i];
        println!(
            "{:<10} {:>12.1} {:>14} {:>14} {:>8.2}x {:>8}",
            l.name,
            l.macs() as f64 / 1e6,
            d.cycles,
            t.cycles,
            d.cycles as f64 / t.cycles as f64,
            if t.memory_bound { "memory" } else { "compute" },
        );
    }
    let speedup = dadn.total_cycles() as f64 / tetris.total_cycles() as f64;
    println!(
        "\ntotal: DaDN {:.2} ms, Tetris {:.2} ms → {speedup:.2}x speedup",
        dadn.time_s() * 1e3,
        tetris.time_s() * 1e3
    );
    let ed = network_energy(&dadn, &calib);
    let et = network_energy(&tetris, &calib);
    println!(
        "energy: DaDN {:.2} mJ, Tetris {:.2} mJ; power ratio {:.2}x (paper: 1.08x)",
        ed.total_j() * 1e3,
        et.total_j() * 1e3,
        (et.total_j() / tetris.time_s()) / (ed.total_j() / dadn.time_s()),
    );
}
