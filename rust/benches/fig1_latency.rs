//! Bench: regenerate Figure 1 (n-operand adder vs multiplier latency).
//!
//! Run: `cargo bench --bench fig1_latency`

use tetris::latency;
use tetris::util::bench::Harness;

fn main() {
    let mut h = Harness::new("Figure 1 — adder (2..16 operands) vs 16-bit multiplier");
    tetris::report::fig1(None).expect("fig1");

    let (adders, mult) = latency::fig1_series(16);
    for (n, d) in &adders {
        h.metric_row(
            &format!("fig1/adder-{n}-operands"),
            vec![("latency_ns".into(), *d), ("mult_over_adder".into(), mult / d)],
        );
    }
    let overhead = mult / adders.last().unwrap().1 - 1.0;
    h.metric_row(
        "fig1/multiplier (paper overhead vs 16-op adder: 12.3%)",
        vec![
            ("latency_ns".into(), mult),
            ("overhead_vs_16op_adder_pct".into(), overhead * 100.0),
        ],
    );

    // Timed: the gate-delay evaluation itself (trivially fast; kept so
    // the model stays regression-benchmarked).
    h.bench("fig1/series-eval", || latency::fig1_series(16).0.len());
    h.report();
}
