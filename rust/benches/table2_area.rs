//! Bench: regenerate Table 2 (area overhead + per-PE breakdown).
//!
//! Run: `cargo bench --bench table2_area`

use tetris::config::{AccelConfig, CalibConfig};
use tetris::energy::chip_area;
use tetris::util::bench::Harness;

fn main() {
    let mut h = Harness::new("Table 2 — area overhead (TSMC 65nm model)");
    tetris::report::table2(None).expect("table2");

    let cfg = AccelConfig::default();
    let calib = CalibConfig::default();
    let d = chip_area("dadn", &cfg, &calib).unwrap().total_mm2();
    for (design, paper) in [("dadn", 79.36), ("pra", 153.65), ("tetris", 89.76)] {
        let rep = chip_area(design, &cfg, &calib).unwrap();
        h.metric_row(
            &format!("table2/{design} (paper {paper} mm²)"),
            vec![
                ("total_mm2".into(), rep.total_mm2()),
                ("vs_dadn".into(), rep.total_mm2() / d),
            ],
        );
    }
    let tetris = chip_area("tetris", &cfg, &calib).unwrap();
    for (name, area) in tetris.per_pe(cfg.pes) {
        h.metric_row(
            &format!("table2/pe-breakdown/{}", name.replace(' ', "-")),
            vec![("mm2".into(), area)],
        );
    }
    h.report();
}
