//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * A1 — density calibration: Fig 8 speedups under the paper's two
//!   (mutually inconsistent) bit-statistics claims.
//! * A2 — eDRAM bandwidth: where the Tetris roofline flips from
//!   compute-bound to memory-bound (the kneaded stream is wider).
//! * A3 — kneading-stride pointer overhead: effective speedup after
//!   charging the wider kneaded-stream traffic at each KS.
//! * A4 — PE scaling: does the speedup survive chip scaling?
//!
//! Run: `cargo bench --bench ablations`

use tetris::config::{AccelConfig, CalibConfig, Mode};
use tetris::model::weights::DensityCalibration;
use tetris::model::zoo;
use tetris::sim::sample::sample_network_calibrated;
use tetris::sim::{
    dadn::DadnSim, simulate_network, simulate_network_with_samples, tetris::TetrisSim,
};
use tetris::util::bench::Harness;

fn main() {
    let mut h = Harness::new("ablations — calibration / bandwidth / stride / scaling");
    let calib = CalibConfig::default();
    let seed = 42;

    // --- A1: density calibration --------------------------------------
    for dc in [DensityCalibration::Fig2, DensityCalibration::Table1] {
        let net = zoo::alexnet();
        let cfg = AccelConfig::default();
        let samples = sample_network_calibrated(&net, Mode::Fp16, seed, dc).unwrap();
        let t = simulate_network_with_samples(&TetrisSim, &net, &samples, &cfg, &calib);
        let d = simulate_network_with_samples(&DadnSim, &net, &samples, &cfg, &calib);
        h.metric_row(
            &format!("a1/density-{dc:?}"),
            vec![(
                "tetris_fp16_speedup".into(),
                d.total_cycles() as f64 / t.total_cycles() as f64,
            )],
        );
    }

    // --- A2: eDRAM bandwidth sweep --------------------------------------
    let net = zoo::vgg16();
    for bw in [1usize, 2, 4, 8, 16, 32, 64] {
        let cfg = AccelConfig { edram_words_per_cycle: bw, ..AccelConfig::default() };
        let t = simulate_network(&TetrisSim, &net, &cfg, &calib, seed).unwrap();
        let d = simulate_network(&DadnSim, &net, &cfg, &calib, seed).unwrap();
        let mem_layers = t.per_layer.iter().filter(|l| l.memory_bound).count();
        h.metric_row(
            &format!("a2/bandwidth-{bw}w-per-cycle"),
            vec![
                ("speedup".into(), d.total_cycles() as f64 / t.total_cycles() as f64),
                ("memory_bound_layers".into(), mem_layers as f64),
            ],
        );
    }

    // --- A3: stride vs pointer overhead ---------------------------------
    let net = zoo::alexnet();
    for ks in [4usize, 8, 16, 32, 64, 128] {
        let cfg = AccelConfig { ks, ..AccelConfig::default() };
        let t = simulate_network(&TetrisSim, &net, &cfg, &calib, seed).unwrap();
        let d = simulate_network(&DadnSim, &net, &cfg, &calib, seed).unwrap();
        h.metric_row(
            &format!("a3/ks-{ks}"),
            vec![
                ("speedup".into(), d.total_cycles() as f64 / t.total_cycles() as f64),
                ("pointer_bits".into(), cfg.pointer_bits() as f64),
            ],
        );
    }

    // --- A4: PE scaling ---------------------------------------------------
    for pes in [4usize, 8, 16, 32, 64] {
        let cfg = AccelConfig { pes, ..AccelConfig::default() };
        let t = simulate_network(&TetrisSim, &net, &cfg, &calib, seed).unwrap();
        let d = simulate_network(&DadnSim, &net, &cfg, &calib, seed).unwrap();
        h.metric_row(
            &format!("a4/pes-{pes}"),
            vec![
                ("speedup".into(), d.total_cycles() as f64 / t.total_cycles() as f64),
                ("tetris_ms".into(), t.time_s() * 1e3),
            ],
        );
    }

    // Timed row so the ablation harness is regression-tracked too.
    let cfg = AccelConfig::default();
    h.bench("a0/simulate-alexnet-tetris", || {
        simulate_network(&TetrisSim, &net, &cfg, &calib, 7).unwrap().total_cycles()
    });
    h.report();
}
