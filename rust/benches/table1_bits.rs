//! Bench: regenerate Table 1 (zero-weight / zero-bit fractions) and
//! time the bit-statistics pass.
//!
//! Run: `cargo bench --bench table1_bits`

use tetris::analysis;
use tetris::config::Mode;
use tetris::model::weights::{profile_with, DensityCalibration};
use tetris::quant::stats::BitStats;
use tetris::util::bench::Harness;
use tetris::util::rng::Rng;

fn main() {
    let mut h = Harness::new("Table 1 — zero weights & zero bits in all weights");

    // The measurement itself (prints the paper-style table).
    tetris::report::table1(42, None).expect("table1");

    // Metric rows: measured vs paper for machine consumption.
    let rows = analysis::table1(42).expect("table1 rows");
    for r in &rows {
        h.metric_row(
            &format!("table1/{}", r.network),
            vec![
                ("zero_weights_pct".into(), r.zero_weights_pct),
                ("zero_bits_pct".into(), r.zero_bits_pct),
            ],
        );
    }
    let gm = analysis::table1_geomean(&rows);
    h.metric_row(
        "table1/geomean (paper: 0.135 / 68.88)",
        vec![
            ("zero_weights_pct".into(), gm.zero_weights_pct),
            ("zero_bits_pct".into(), gm.zero_bits_pct),
        ],
    );

    // Timed: BitStats accumulation throughput (the analysis hot loop).
    let profile = profile_with("vgg16", Mode::Fp16, DensityCalibration::Table1).unwrap();
    let mut rng = Rng::new(7);
    let ws = profile.generate(1_000_000, &mut rng);
    h.bench("bitstats/accumulate-1M-weights", || {
        let mut s = BitStats::new(Mode::Fp16);
        s.add_all(&ws);
        s.zero_bit_fraction()
    });
    h.bench("generator/sample-100k-weights", || {
        let mut r = Rng::new(3);
        profile.generate(100_000, &mut r).len()
    });

    h.report();
    if let Some(dir) = tetris::engine::env::bench_csv_dir() {
        h.write_csv(dir.join("table1_bits.csv").as_path()).ok();
    }
}
