//! Bench: regenerate Figure 11 (T_ks/T_base vs kneading stride for
//! fp16 and int8) and time the kneading compiler across KS.
//!
//! Run: `cargo bench --bench fig11_ks`

use tetris::config::Mode;
use tetris::kneading::stats::KneadStats;
use tetris::model::weights::{profile_with, DensityCalibration};
use tetris::util::bench::Harness;
use tetris::util::rng::Rng;

fn main() {
    let mut h = Harness::new("Figure 11 — T_ks/T_base under the KS sweep");
    tetris::report::fig11(42, None).expect("fig11");

    // Paper anchors: AlexNet fp16 0.751 @ KS=10 → 0.642 @ KS=32;
    // int8 ≈ 0.49 flat (relative to the fp16 unkneaded base).
    for mode in [Mode::Fp16, Mode::Int8] {
        let profile = profile_with("alexnet", mode, DensityCalibration::Fig2).unwrap();
        let mut rng = Rng::new(42);
        let ws = profile.generate(256_000, &mut rng);
        for ks in [10, 16, 24, 32] {
            let s = KneadStats::measure(&ws, ks, mode);
            let tf = s.time_fraction() / mode.kneaded_per_splitter() as f64;
            h.metric_row(
                &format!("fig11/{mode}-alexnet-ks{ks}"),
                vec![("t_ks_over_t_base".into(), tf), ("ratio".into(), s.ratio())],
            );
        }
    }

    // Timed: kneading compiler throughput at several strides.
    let profile = profile_with("vgg16", Mode::Fp16, DensityCalibration::Fig2).unwrap();
    let mut rng = Rng::new(5);
    let ws = profile.generate(256_000, &mut rng);
    for ks in [8, 16, 32] {
        h.bench(&format!("kneader/256k-weights-ks{ks}"), || {
            KneadStats::measure(&ws, ks, Mode::Fp16).kneaded
        });
    }
    h.report();
}
