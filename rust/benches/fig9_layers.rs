//! Bench: regenerate Figure 9 (per-Conv-layer VGG-16 speedup over DaDN
//! under two KS configurations).
//!
//! Run: `cargo bench --bench fig9_layers`

use tetris::config::{AccelConfig, CalibConfig};
use tetris::model::zoo;
use tetris::sim::{dadn::DadnSim, simulate_network, tetris::TetrisSim};
use tetris::util::bench::Harness;

fn main() {
    let mut h = Harness::new("Figure 9 — per-layer VGG-16 speedup (KS=8 vs KS=16)");
    tetris::report::fig9(42, None).expect("fig9");

    let calib = CalibConfig::default();
    let net = zoo::vgg16();
    let base = simulate_network(&DadnSim, &net, &AccelConfig::default(), &calib, 42).unwrap();
    for ks in [8, 16] {
        let cfg = AccelConfig { ks, ..AccelConfig::default() };
        let sim = simulate_network(&TetrisSim, &net, &cfg, &calib, 42).unwrap();
        for (i, l) in net.layers.iter().enumerate() {
            h.metric_row(
                &format!("fig9/ks{ks}/{}", l.name),
                vec![(
                    "speedup".into(),
                    base.per_layer[i].cycles as f64 / sim.per_layer[i].cycles as f64,
                )],
            );
        }
    }
    h.bench("fig9/full-vgg16-two-configs", || {
        let cfg = AccelConfig { ks: 8, ..AccelConfig::default() };
        simulate_network(&TetrisSim, &net, &cfg, &calib, 1).unwrap().total_cycles()
    });
    h.report();
}
