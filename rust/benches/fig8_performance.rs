//! Bench: regenerate Figure 8 (end-to-end inference time, all four
//! design points, five networks) and time the simulators.
//!
//! Run: `cargo bench --bench fig8_performance`

use tetris::config::{AccelConfig, CalibConfig};
use tetris::model::zoo;
use tetris::report::figures::design_points;
use tetris::sim::{simulate_network, tetris::TetrisSim};
use tetris::util::bench::Harness;

fn main() {
    let mut h = Harness::new("Figure 8 — inference time & speedups over DaDN");
    tetris::report::fig8(42, None).expect("fig8");

    let calib = CalibConfig::default();
    let mut geo = (0.0f64, 0.0f64, 0.0f64);
    let nets = zoo::all();
    for net in &nets {
        let p = design_points(net, &calib, 42).expect("design points");
        let d = p.dadn.time_s();
        h.metric_row(
            &format!("fig8/{}", net.name),
            vec![
                ("dadn_ms".into(), d * 1e3),
                ("pra_x".into(), d / p.pra.time_s()),
                ("tetris_fp16_x".into(), d / p.tetris_fp16.time_s()),
                ("tetris_int8_x".into(), d / p.tetris_int8.time_s()),
            ],
        );
        geo.0 += (d / p.pra.time_s()).ln();
        geo.1 += (d / p.tetris_fp16.time_s()).ln();
        geo.2 += (d / p.tetris_int8.time_s()).ln();
    }
    let n = nets.len() as f64;
    h.metric_row(
        "fig8/geomean (paper: PRA 1.15, fp16 1.30, int8 1.50)",
        vec![
            ("pra_x".into(), (geo.0 / n).exp()),
            ("tetris_fp16_x".into(), (geo.1 / n).exp()),
            ("tetris_int8_x".into(), (geo.2 / n).exp()),
        ],
    );

    // Timed: the simulator itself (host cost of one full-network sim).
    let cfg = AccelConfig::default();
    for net in [zoo::alexnet(), zoo::vgg16()] {
        h.bench(&format!("simulate/tetris-{}", net.name), || {
            simulate_network(&TetrisSim, &net, &cfg, &calib, 9).unwrap().total_cycles()
        });
    }
    h.report();
}
