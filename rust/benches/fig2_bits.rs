//! Bench: regenerate Figure 2 (essential-bit distribution across bit
//! positions, 500 kernels × 4 models).
//!
//! Run: `cargo bench --bench fig2_bits`

use tetris::analysis;
use tetris::model::weights::DensityCalibration;
use tetris::util::bench::Harness;

fn main() {
    let mut h = Harness::new("Figure 2 — essential-bit (1s) distribution, bits 0..15");
    tetris::report::fig2(42, None).expect("fig2");

    for calib in [DensityCalibration::Fig2, DensityCalibration::Table1] {
        let series = analysis::fig2(42, calib).expect("fig2 series");
        for s in &series {
            let plateau_mean = (0..15)
                .filter(|b| ![3, 4, 5].contains(b))
                .map(|b| s.density[b])
                .sum::<f64>()
                / 12.0;
            let cliff_mean = [3, 4, 5].iter().map(|&b| s.density[b]).sum::<f64>() / 3.0;
            h.metric_row(
                &format!("fig2/{:?}/{}", calib, s.network),
                vec![
                    ("plateau_density".into(), plateau_mean),
                    ("cliff_density".into(), cliff_mean),
                ],
            );
        }
    }

    h.bench("fig2/measure-4-models-500-kernels", || {
        analysis::fig2(7, DensityCalibration::Fig2).unwrap().len()
    });
    h.report();
}
