//! Bench: hot-path microbenchmarks — the instrument for the §Perf
//! optimization pass (EXPERIMENTS.md §Perf).
//!
//! Covers the kneading compiler, the SAC functional unit, the quantized
//! inference pipeline, and the coordinator batch path.
//!
//! Run: `cargo bench --bench hotpath`

use std::time::Duration;

use tetris::config::Mode;
use tetris::coordinator::{BatchPolicy, InferRequest, SacBackend, Server, ServerConfig};
use tetris::kneading::{knead_group, knead_lane, Lane};
use tetris::model::weights::{profile_with, DensityCalibration};
use tetris::model::Tensor;
use tetris::sac::SacUnit;
use tetris::util::bench::Harness;
use tetris::util::rng::Rng;

fn main() {
    let mut h = Harness::new("hot paths — kneader / SAC / pipeline / coordinator");
    let profile = profile_with("vgg16", Mode::Fp16, DensityCalibration::Fig2).unwrap();
    let mut rng = Rng::new(11);

    // 1. Kneading compiler: one group and one conv lane.
    let group: Vec<i32> = profile.generate(16, &mut rng);
    h.bench("knead/group-16", || knead_group(&group, Mode::Fp16).len());

    let lane_weights = profile.generate(2304, &mut rng); // VGG conv lane 256·3·3
    let lane = Lane::new(lane_weights.clone(), vec![777; 2304]);
    h.bench("knead/lane-2304", || knead_lane(&lane, 16, Mode::Fp16).kneaded_len());

    // 2. SAC functional unit over a pre-kneaded lane.
    let kneaded = knead_lane(&lane, 16, Mode::Fp16);
    h.bench("sac/process-kneaded-lane-2304", || {
        let mut unit = SacUnit::new(Mode::Fp16);
        unit.process_kneaded(&kneaded, &lane)
    });
    h.bench("sac/knead+process-lane-2304", || {
        let mut unit = SacUnit::new(Mode::Fp16);
        unit.process_lane(&lane, 16)
    });

    // 3. Quantized tiny-CNN inference (the serving backend's unit of work).
    let mut backend = SacBackend::synthetic(3).unwrap();
    let mut img = Tensor::zeros(&[4, 1, 16, 16]);
    for (i, v) in img.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 509) - 250;
    }
    use tetris::coordinator::InferBackend;
    h.bench("pipeline/tiny-cnn-batch4", || backend.infer_batch(&img).unwrap().len());

    // 4. Coordinator round trip (16 requests through batcher + workers).
    h.bench("coordinator/serve-16-requests", || {
        let server = Server::start(
            ServerConfig {
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
                workers: 2,
            },
            |_| SacBackend::synthetic(1),
        )
        .unwrap();
        let mut r = Rng::new(1);
        for id in 0..16u64 {
            let mut t = Tensor::zeros(&[1, 16, 16]);
            for v in t.data_mut() {
                *v = r.range_i64(-300, 300) as i32;
            }
            server.submit(InferRequest::new(id, t)).unwrap();
        }
        for _ in 0..16 {
            server.recv().unwrap();
        }
        server.shutdown().requests_done
    });

    h.report();
    if let Ok(dir) = std::env::var("TETRIS_BENCH_CSV") {
        h.write_csv(std::path::Path::new(&dir).join("hotpath.csv").as_path()).ok();
    }
}
