//! Bench: hot-path microbenchmarks — the instrument for the §Perf
//! optimization pass (EXPERIMENTS.md §Perf).
//!
//! Covers the kneading compiler, the SAC functional unit, the quantized
//! inference pipeline, and the coordinator batch path.
//!
//! Run: `cargo bench --bench hotpath`

use std::time::Duration;

use tetris::config::{AccelConfig, CalibConfig, Mode};
use tetris::coordinator::{BatchPolicy, InferRequest, SacBackend, Server, ServerConfig};
use tetris::engine::Engine;
use tetris::kneading::{knead_group, knead_lane, Lane};
use tetris::model::reference::forward_reference;
use tetris::model::weights::{profile_with, synthetic_loaded, DensityCalibration};
use tetris::model::{zoo, Tensor};
use tetris::plan::{CompiledNetwork, ExecOpts, Kernel, Walk, DEFAULT_TILE_ROWS};
use tetris::runtime::quantized;
use tetris::sac::SacUnit;
use tetris::util::bench::Harness;
use tetris::util::rng::Rng;

fn main() {
    let mut h = Harness::new("hot paths — kneader / SAC / pipeline / coordinator");
    let profile = profile_with("vgg16", Mode::Fp16, DensityCalibration::Fig2).unwrap();
    let mut rng = Rng::new(11);

    // 1. Kneading compiler: one group and one conv lane.
    let group: Vec<i32> = profile.generate(16, &mut rng);
    h.bench("knead/group-16", || knead_group(&group, Mode::Fp16).len());

    let lane_weights = profile.generate(2304, &mut rng); // VGG conv lane 256·3·3
    let lane = Lane::new(lane_weights.clone(), vec![777; 2304]);
    h.bench("knead/lane-2304", || knead_lane(&lane, 16, Mode::Fp16).kneaded_len());

    // 2. SAC functional unit over a pre-kneaded lane.
    let kneaded = knead_lane(&lane, 16, Mode::Fp16);
    h.bench("sac/process-kneaded-lane-2304", || {
        let mut unit = SacUnit::new(Mode::Fp16);
        unit.process_kneaded(&kneaded, &lane)
    });
    h.bench("sac/knead+process-lane-2304", || {
        let mut unit = SacUnit::new(Mode::Fp16);
        unit.process_lane(&lane, 16)
    });

    // 3. Quantized tiny-CNN inference (the serving backend's unit of work).
    let mut backend = SacBackend::synthetic(3).unwrap();
    let mut img = Tensor::zeros(&[4, 1, 16, 16]);
    for (i, v) in img.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 509) - 250;
    }
    use tetris::coordinator::InferBackend;
    h.bench("pipeline/tiny-cnn-batch4", || backend.infer_batch(&img).unwrap().len());

    // 4. Coordinator round trip (16 requests through batcher + workers;
    //    both workers clone one shared-plan prototype).
    h.bench("coordinator/serve-16-requests", || {
        let server = Server::start_shared(
            ServerConfig {
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
                workers: 2,
            },
            SacBackend::synthetic(1).unwrap(),
        )
        .unwrap();
        let mut r = Rng::new(1);
        for id in 0..16u64 {
            let mut t = Tensor::zeros(&[1, 16, 16]);
            for v in t.data_mut() {
                *v = r.range_i64(-300, 300) as i32;
            }
            server.submit(InferRequest::new(id, t)).unwrap();
        }
        for _ in 0..16 {
            server.recv().unwrap();
        }
        server.shutdown().requests_done
    });

    // 5. Engine façade round trip: same 16-request load through the
    //    typed builder + session surface (registry lookup + ticket
    //    store on top of the same core — the overhead under test).
    h.bench("engine/session-serve-16-requests", || {
        let engine = Engine::builder()
            .workers(2)
            .max_batch(8)
            .max_wait(Duration::from_micros(200))
            .register("tiny", zoo::tiny_cnn(), SacBackend::synthetic_weights(1).unwrap())
            .build()
            .unwrap();
        let session = engine.session();
        let mut r = Rng::new(1);
        let tickets: Vec<_> = (0..16)
            .map(|_| {
                let mut t = Tensor::zeros(&[1, 16, 16]);
                for v in t.data_mut() {
                    *v = r.range_i64(-300, 300) as i32;
                }
                session.submit("tiny", t).unwrap()
            })
            .collect();
        for t in &tickets {
            session.wait(t).unwrap();
        }
        engine.shutdown().requests_done
    });

    // 6. Compile-once plan vs the legacy re-knead-per-call scalar path
    //    (ISSUE 1 acceptance: ≥2× on a batch of ≥8 images). Same
    //    weights, same images, same logits — only the execution
    //    strategy differs: the plan kneads every lane once at build and
    //    fans the conv hot loop over (image, row) stripes, while the
    //    legacy path re-kneads per call on one thread.
    let w = SacBackend::synthetic_weights(3).unwrap();
    let plan = quantized::compile_tiny_cnn(&w).unwrap();
    let mut batch8 = Tensor::zeros(&[8, 1, 16, 16]);
    for (i, v) in batch8.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 509) - 250;
    }
    assert_eq!(
        plan.execute(&batch8).unwrap(),
        quantized::forward_scalar(&w, &batch8).unwrap(),
        "plan and legacy paths must agree before being compared on speed"
    );
    h.bench("plan/execute-batch8", || plan.execute(&batch8).unwrap().len());
    h.bench("legacy/reknead-scalar-batch8", || {
        quantized::forward_scalar(&w, &batch8).unwrap().len()
    });
    h.bench("plan/compile-tiny-cnn", || {
        quantized::compile_tiny_cnn(&w).unwrap().kneads_at_build
    });
    let plan_median = h
        .results()
        .iter()
        .find(|m| m.name == "plan/execute-batch8")
        .map(|m| m.median_s())
        .unwrap();
    let legacy_median = h
        .results()
        .iter()
        .find(|m| m.name == "legacy/reknead-scalar-batch8")
        .map(|m| m.median_s())
        .unwrap();
    h.metric_row(
        "plan/speedup-vs-reknead-batch8",
        vec![
            ("speedup_x".into(), legacy_median / plan_median),
            ("plan_ms".into(), plan_median * 1e3),
            ("legacy_ms".into(), legacy_median * 1e3),
        ],
    );

    // 7. A non-tiny zoo topology through the plan executor: VGG-16
    //    block 3, channels ÷8, at 16×16 — compile once, execute many.
    let block = zoo::vgg16_block(3).unwrap().scaled(8, 16);
    let bw = synthetic_loaded(&block, Mode::Fp16, 12, "vgg16", DensityCalibration::Fig2, 11)
        .unwrap();
    let bplan = CompiledNetwork::compile(&block, &bw, 16, Mode::Fp16).unwrap();
    let mut bimg = Tensor::zeros(&[1, block.layers[0].in_c, 16, 16]);
    for (i, v) in bimg.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 401) - 200;
    }
    h.bench("plan/compile-vgg16-block3-div8", || {
        CompiledNetwork::compile(&block, &bw, 16, Mode::Fp16).unwrap().kneads_at_build
    });
    h.bench("plan/execute-vgg16-block3-div8", || bplan.execute(&bimg).unwrap().len());
    h.metric_row(
        "plan/vgg16-block3-div8-footprint",
        vec![
            ("source_weights".into(), bplan.source_weights() as f64),
            ("kneaded_weights".into(), bplan.kneaded_weights() as f64),
        ],
    );

    // 8. ISSUE 2: the declared-topology executor on the rest of the
    //    zoo — scaled AlexNet (3×3 stride-2 pools) and a standalone
    //    inception module (four-arm branch + channel concat) — vs the
    //    plain-MAC scalar reference, bit-exactness asserted first.
    let anet = zoo::alexnet().scaled(16, 64);
    let aw = synthetic_loaded(&anet, Mode::Fp16, 12, "alexnet", DensityCalibration::Fig2, 21)
        .unwrap();
    let aplan = CompiledNetwork::compile(&anet, &aw, 16, Mode::Fp16).unwrap();
    let mut aimg = Tensor::zeros(&[2, anet.layers[0].in_c, 64, 64]);
    for (i, v) in aimg.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 409) - 200;
    }
    assert_eq!(
        aplan.execute(&aimg).unwrap(),
        forward_reference(&anet, &aw, &aimg),
        "alexnet plan must be bit-exact vs the MAC reference before speed comparison"
    );
    h.bench("plan/execute-alexnet-div16-hw64", || aplan.execute(&aimg).unwrap().len());
    h.bench("ref/mac-alexnet-div16-hw64", || forward_reference(&anet, &aw, &aimg).len());

    let inet = zoo::inception_module("3a").unwrap().scaled(4, 16);
    let iw = synthetic_loaded(&inet, Mode::Fp16, 12, "googlenet", DensityCalibration::Fig2, 22)
        .unwrap();
    let iplan = CompiledNetwork::compile(&inet, &iw, 16, Mode::Fp16).unwrap();
    let mut iimg = Tensor::zeros(&[2, inet.layers[0].in_c, 16, 16]);
    for (i, v) in iimg.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 389) - 190;
    }
    assert_eq!(
        iplan.execute(&iimg).unwrap(),
        forward_reference(&inet, &iw, &iimg),
        "inception plan must be bit-exact vs the MAC reference before speed comparison"
    );
    h.bench("plan/execute-inception3a-div4-hw16", || iplan.execute(&iimg).unwrap().len());
    h.bench("ref/mac-inception3a-div4-hw16", || forward_reference(&inet, &iw, &iimg).len());
    let median = |results: &[tetris::util::bench::Measurement], name: &str| {
        results.iter().find(|m| m.name == name).map(|m| m.median_s()).unwrap()
    };
    let alex_speedup = median(h.results(), "ref/mac-alexnet-div16-hw64")
        / median(h.results(), "plan/execute-alexnet-div16-hw64");
    let incep_speedup = median(h.results(), "ref/mac-inception3a-div4-hw16")
        / median(h.results(), "plan/execute-inception3a-div4-hw16");
    h.metric_row(
        "plan/zoo-vs-mac-reference",
        vec![
            ("alexnet_speedup_x".into(), alex_speedup),
            ("inception_speedup_x".into(), incep_speedup),
        ],
    );

    // 9. ISSUE 3: the tiled fused walk vs its own materializing
    //    baseline on the same plan — wall time per mode plus the
    //    measured peak feature-map bytes (the memory the fusion is
    //    for). Bit-exactness across tilings is pinned in
    //    tests/plan_tiling.rs; asserted here too before timing.
    assert_eq!(
        aplan.execute_opts(&aimg, ExecOpts::tiled(4)).unwrap(),
        aplan.execute_opts(&aimg, ExecOpts::materializing()).unwrap(),
        "tiled and materializing walks must agree before being timed"
    );
    h.bench("plan/execute-alexnet-tiled4", || {
        aplan.execute_opts(&aimg, ExecOpts::tiled(4)).unwrap().len()
    });
    h.bench("plan/execute-alexnet-materializing", || {
        aplan.execute_opts(&aimg, ExecOpts::materializing()).unwrap().len()
    });
    let (_, trace_tiled) = aplan.execute_traced(&aimg, ExecOpts::tiled(4)).unwrap();
    let (_, trace_full) = aplan.execute_traced(&aimg, ExecOpts::materializing()).unwrap();
    let (peak_tiled, peak_full) = (trace_tiled.peak_bytes(), trace_full.peak_bytes());
    h.metric_row(
        "plan/alexnet-peak-feature-bytes",
        vec![
            ("tiled4".into(), peak_tiled as f64),
            ("materializing".into(), peak_full as f64),
            ("ratio".into(), peak_tiled as f64 / peak_full as f64),
        ],
    );

    // 10. ISSUE 5: streaming vs tiled — the halo win. Same plan, a
    //     batch that covers the worker budget (4 images, 2 workers, so
    //     both walks keep every worker busy); the tiled walk
    //     recomputes halo rows at every 4-row tile boundary while the
    //     streaming walk's rolling rings retain them. Bit-exactness
    //     asserted before timing, as always.
    let mut simg = Tensor::zeros(&[4, anet.layers[0].in_c, 64, 64]);
    for (i, v) in simg.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 421) - 210;
    }
    let stream_opts = ExecOpts::streaming(4).with_workers(2);
    let tiled_opts = ExecOpts::tiled(4).with_workers(2);
    assert_eq!(
        aplan.execute_opts(&simg, stream_opts).unwrap(),
        aplan.execute_opts(&simg, tiled_opts).unwrap(),
        "streaming and tiled walks must agree before being timed"
    );
    h.bench("plan/execute-alexnet-streaming4-batch4", || {
        aplan.execute_opts(&simg, stream_opts).unwrap().len()
    });
    h.bench("plan/execute-alexnet-tiled4-batch4", || {
        aplan.execute_opts(&simg, tiled_opts).unwrap().len()
    });
    let (_, ts) = aplan.execute_traced(&simg, stream_opts).unwrap();
    let (_, tt) = aplan.execute_traced(&simg, tiled_opts).unwrap();
    assert_eq!(ts.halo_recompute_rows(), 0, "streaming walk must not recompute halo rows");
    let stream_median = median(h.results(), "plan/execute-alexnet-streaming4-batch4");
    let tiled_median = median(h.results(), "plan/execute-alexnet-tiled4-batch4");
    h.metric_row(
        "plan/streaming-vs-tiled-batch4",
        vec![
            ("speedup_x".into(), tiled_median / stream_median),
            ("halo_rows_tiled".into(), tt.halo_recompute_rows() as f64),
            ("halo_rows_streaming".into(), ts.halo_recompute_rows() as f64),
            ("peak_streaming".into(), ts.peak_bytes() as f64),
            ("peak_tiled".into(), tt.peak_bytes() as f64),
        ],
    );

    // 11. ISSUE 5: executable FC heads — VGG-16 runs image → logits
    //     through its compiled fc6–8 lanes (flatten → fused heads →
    //     classifier), pinned against the naive reference FC chain.
    let vnet = zoo::vgg16().scaled(16, 32);
    let vw = tetris::model::weights::synthetic_loaded_with_heads(
        &vnet,
        Mode::Fp16,
        10,
        "vgg16",
        DensityCalibration::Fig2,
        31,
    )
    .unwrap();
    let vplan = CompiledNetwork::compile(&vnet, &vw, 16, Mode::Fp16).unwrap();
    let mut vimg = Tensor::zeros(&[2, vnet.layers[0].in_c, 32, 32]);
    for (i, v) in vimg.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 397) - 198;
    }
    assert_eq!(
        vplan.execute(&vimg).unwrap(),
        forward_reference(&vnet, &vw, &vimg),
        "vgg16 fc-head logits must match the reference before timing"
    );
    h.bench("plan/execute-vgg16-fc-heads-div16", || vplan.execute(&vimg).unwrap().len());
    let head_lanes: f64 = vplan.fc_heads().iter().map(|f| f.classes as f64).sum();
    h.metric_row(
        "plan/vgg16-fc-heads",
        vec![
            ("heads".into(), vplan.fc_heads().len() as f64),
            ("head_lanes".into(), head_lanes),
            ("classes".into(), vplan.output_classes().unwrap_or(0) as f64),
        ],
    );

    // 12. ISSUE 6: whole-network streaming — the pipelined walk chains
    //     the per-segment rolling rings across pool boundaries, so a
    //     trunk of any depth streams with only the input map, one ring
    //     set, and the trunk output live. Pipelined vs streaming vs
    //     tiled on scaled VGG-16 (deep chain, fc heads included) and
    //     GoogleNet (inception fan-out: one upstream ring feeding four
    //     arms, one concat ring). Bit-exactness asserted before
    //     timing; `*_peak_bytes` metric keys feed the CI peak-memory
    //     gate (scripts/bench_compare.py).
    let piped_opts = ExecOpts::pipelined(4).with_workers(2);
    assert_eq!(
        vplan.execute_opts(&vimg, piped_opts).unwrap(),
        vplan.execute_opts(&vimg, stream_opts).unwrap(),
        "pipelined and streaming walks must agree on vgg16 before timing"
    );
    h.bench("whole-network-streaming/vgg16-div16-pipelined4", || {
        vplan.execute_opts(&vimg, piped_opts).unwrap().len()
    });
    h.bench("whole-network-streaming/vgg16-div16-streaming4", || {
        vplan.execute_opts(&vimg, stream_opts).unwrap().len()
    });
    h.bench("whole-network-streaming/vgg16-div16-tiled4", || {
        vplan.execute_opts(&vimg, tiled_opts).unwrap().len()
    });
    let (_, vp) = vplan.execute_traced(&vimg, piped_opts).unwrap();
    let (_, vs) = vplan.execute_traced(&vimg, stream_opts).unwrap();
    let (_, vt) = vplan.execute_traced(&vimg, tiled_opts).unwrap();
    assert_eq!(vp.halo_recompute_rows(), 0, "pipelined walk must not recompute halo rows");
    h.metric_row(
        "whole-network-streaming/vgg16-div16-hw32",
        vec![
            ("pipelined_peak_bytes".into(), vp.peak_bytes() as f64),
            ("streaming_peak_bytes".into(), vs.peak_bytes() as f64),
            ("tiled_peak_bytes".into(), vt.peak_bytes() as f64),
            ("halo_rows_pipelined".into(), vp.halo_recompute_rows() as f64),
            ("halo_rows_tiled".into(), vt.halo_recompute_rows() as f64),
            (
                "speedup_vs_tiled_x".into(),
                median(h.results(), "whole-network-streaming/vgg16-div16-tiled4")
                    / median(h.results(), "whole-network-streaming/vgg16-div16-pipelined4"),
            ),
        ],
    );

    let gnet = zoo::googlenet().scaled(16, 64);
    let gw = synthetic_loaded(&gnet, Mode::Fp16, 12, "googlenet", DensityCalibration::Fig2, 23)
        .unwrap();
    let gplan = CompiledNetwork::compile(&gnet, &gw, 16, Mode::Fp16).unwrap();
    let mut gimg = Tensor::zeros(&[2, gnet.layers[0].in_c, 64, 64]);
    for (i, v) in gimg.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 419) - 209;
    }
    assert_eq!(
        gplan.execute_opts(&gimg, piped_opts).unwrap(),
        gplan.execute_opts(&gimg, stream_opts).unwrap(),
        "pipelined and streaming walks must agree on googlenet before timing"
    );
    h.bench("whole-network-streaming/googlenet-div16-pipelined4", || {
        gplan.execute_opts(&gimg, piped_opts).unwrap().len()
    });
    h.bench("whole-network-streaming/googlenet-div16-streaming4", || {
        gplan.execute_opts(&gimg, stream_opts).unwrap().len()
    });
    h.bench("whole-network-streaming/googlenet-div16-tiled4", || {
        gplan.execute_opts(&gimg, tiled_opts).unwrap().len()
    });
    let (_, gp) = gplan.execute_traced(&gimg, piped_opts).unwrap();
    let (_, gs) = gplan.execute_traced(&gimg, stream_opts).unwrap();
    let (_, gt) = gplan.execute_traced(&gimg, tiled_opts).unwrap();
    assert_eq!(gp.halo_recompute_rows(), 0, "pipelined inception must not recompute halo");
    h.metric_row(
        "whole-network-streaming/googlenet-div16-hw64",
        vec![
            ("pipelined_peak_bytes".into(), gp.peak_bytes() as f64),
            ("streaming_peak_bytes".into(), gs.peak_bytes() as f64),
            ("tiled_peak_bytes".into(), gt.peak_bytes() as f64),
            ("halo_rows_pipelined".into(), gp.halo_recompute_rows() as f64),
            ("halo_rows_tiled".into(), gt.halo_recompute_rows() as f64),
            (
                "speedup_vs_tiled_x".into(),
                median(h.results(), "whole-network-streaming/googlenet-div16-tiled4")
                    / median(h.results(), "whole-network-streaming/googlenet-div16-pipelined4"),
            ),
        ],
    );

    // The budget demo (ISSUE 6 acceptance): full-resolution VGG-16
    //     (channels ÷16, 224×224) under 1 MiB. The first conv pair's
    //     in+out maps alone hold ~1.4 MB, so NO tile height fits the
    //     per-segment streaming walk — while the whole-network
    //     pipeline (input map + ring set + trunk output) fits with
    //     room to spare, image → logits, bit-exact, zero halo rows.
    //     One-shot executions: full resolution is too slow to sample
    //     repeatedly, and peak bytes are deterministic anyway.
    let fnet = zoo::vgg16().scaled(16, 224);
    let fw = tetris::model::weights::synthetic_loaded_with_heads(
        &fnet,
        Mode::Fp16,
        10,
        "vgg16",
        DensityCalibration::Fig2,
        32,
    )
    .unwrap();
    let fplan = CompiledNetwork::compile(&fnet, &fw, 16, Mode::Fp16).unwrap();
    let budget: u64 = 1 << 20;
    let stream_rows = fplan.tile_rows_for_budget_walk(budget, 1, Walk::Streaming);
    assert!(
        fplan.streaming_peak_bytes_estimate(stream_rows, 1) > budget,
        "premise: no tile height fits full-res vgg16's streaming walk into 1 MiB"
    );
    let piped_rows = fplan.tile_rows_for_budget_walk(budget, 1, Walk::Pipelined);
    let mut fimg = Tensor::zeros(&[1, fnet.layers[0].in_c, 224, 224]);
    for (i, v) in fimg.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 431) - 215;
    }
    let (fout, fp) = fplan
        .execute_traced(&fimg, ExecOpts::pipelined(piped_rows).with_workers(1))
        .unwrap();
    let (sout, fs) = fplan
        .execute_traced(&fimg, ExecOpts::streaming(stream_rows.max(1)).with_workers(1))
        .unwrap();
    assert_eq!(fout, sout, "full-res pipelined logits must match the streaming walk");
    assert_eq!(fp.halo_recompute_rows(), 0, "full-res pipeline must not recompute halo");
    assert!(
        (fs.peak_bytes() as u64) > budget,
        "premise: the streaming walk's measured peak must exceed the 1 MiB budget"
    );
    assert!(
        (fp.peak_bytes() as u64) <= budget,
        "whole-network streaming must fit full-res vgg16 into 1 MiB (measured {} B)",
        fp.peak_bytes()
    );
    let summary = fplan.pipeline_summary(224, piped_rows).expect("vgg16 trunk must pipeline");
    h.metric_row(
        "whole-network-streaming/vgg16-div16-hw224-budget1mib",
        vec![
            ("budget_bytes".into(), budget as f64),
            ("pipelined_peak_bytes".into(), fp.peak_bytes() as f64),
            ("streaming_peak_bytes".into(), fs.peak_bytes() as f64),
            ("halo_rows_pipelined".into(), fp.halo_recompute_rows() as f64),
            ("pipelined_tile_rows".into(), piped_rows as f64),
            ("chained_segments".into(), summary.segments as f64),
            ("ring_bytes".into(), summary.ring_bytes as f64),
            ("fill_rows".into(), summary.fill_rows as f64),
        ],
    );

    // 13. ISSUE 7: the schedule auto-tuner vs the hand-picked default
    //     (`DEFAULT_TILE_ROWS`, walk left to the batch rule) across
    //     the zoo. Each model's budget is set to the hand-picked
    //     schedule's own tiled estimate, so the tuner must find a
    //     schedule at least as tight — tuned peak ≤ hand peak by
    //     construction of the feasibility-first selection rule — and
    //     bit-exactness of the tuned schedule is asserted before
    //     timing. The `*_peak_bytes` metric keys feed the CI
    //     peak-memory gate (scripts/bench_compare.py).
    let v19net = zoo::vgg19().scaled(16, 32);
    let v19w =
        synthetic_loaded(&v19net, Mode::Fp16, 12, "vgg19", DensityCalibration::Fig2, 24).unwrap();
    let v19plan = CompiledNetwork::compile(&v19net, &v19w, 16, Mode::Fp16).unwrap();
    let mut v19img = Tensor::zeros(&[2, v19net.layers[0].in_c, 32, 32]);
    for (i, v) in v19img.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 383) - 191;
    }
    let nnet = zoo::nin().scaled(16, 64);
    let nw = synthetic_loaded(&nnet, Mode::Fp16, 12, "nin", DensityCalibration::Fig2, 25).unwrap();
    let nplan = CompiledNetwork::compile(&nnet, &nw, 16, Mode::Fp16).unwrap();
    let mut nimg = Tensor::zeros(&[2, nnet.layers[0].in_c, 64, 64]);
    for (i, v) in nimg.data_mut().iter_mut().enumerate() {
        *v = (i as i32 % 379) - 189;
    }
    let tuner_models: Vec<(&str, &CompiledNetwork, &Tensor<i32>)> = vec![
        ("alexnet", &aplan, &aimg),
        ("googlenet", &gplan, &gimg),
        ("vgg16", &vplan, &vimg),
        ("vgg19", &v19plan, &v19img),
        ("nin", &nplan, &nimg),
    ];
    for (name, plan, img) in tuner_models {
        let budget = plan.peak_bytes_estimate(DEFAULT_TILE_ROWS, 2);
        let tuned = tetris::plan::tune(plan, budget, 2);
        let hand = ExecOpts {
            tile_rows: Some(DEFAULT_TILE_ROWS),
            workers: Some(2),
            walk: None,
            arm_threads: None,
            skip_zero_activations: None,
            kernel: None,
        };
        let tuned_opts = ExecOpts {
            tile_rows: Some(tuned.tile_rows),
            workers: Some(2),
            walk: tuned.walk,
            arm_threads: tuned.arm_threads,
            skip_zero_activations: None,
            kernel: None,
        };
        assert_eq!(
            plan.execute_opts(img, tuned_opts).unwrap(),
            plan.execute_opts(img, hand).unwrap(),
            "{name}: tuned and hand-picked schedules must agree before being timed"
        );
        h.bench(&format!("auto-tuner/{name}-tuned"), || {
            plan.execute_opts(img, tuned_opts).unwrap().len()
        });
        h.bench(&format!("auto-tuner/{name}-hand"), || {
            plan.execute_opts(img, hand).unwrap().len()
        });
        let (_, t_trace) = plan.execute_traced(img, tuned_opts).unwrap();
        let (_, h_trace) = plan.execute_traced(img, hand).unwrap();
        let speedup = median(h.results(), &format!("auto-tuner/{name}-hand"))
            / median(h.results(), &format!("auto-tuner/{name}-tuned"));
        h.metric_row(
            &format!("auto-tuner/{name}"),
            vec![
                ("tuned_peak_bytes".into(), t_trace.peak_bytes() as f64),
                ("hand_peak_bytes".into(), h_trace.peak_bytes() as f64),
                ("tuned_tile_rows".into(), tuned.tile_rows as f64),
                ("predicted_peak_bytes".into(), tuned.predicted_peak_bytes as f64),
                ("speedup_vs_hand_x".into(), speedup),
            ],
        );
    }

    // 14. ISSUE 8: the activation-aware skip lane. A zero-banded batch
    //     (top quarter of every channel zero — the band survives every
    //     conv/pool, so post-ReLU zero rows exist at every depth) runs
    //     skip-on vs skip-off, bit-exactness asserted before timing;
    //     then the measured activation profile feeds the three-way
    //     simulated comparison. In scripts/bench_compare.py the
    //     `*_skipped_rows` / `*_skipped_windows` keys gate as
    //     exact-or-better (a drop means the lane lost skips) and the
    //     `*_sim_cycles` keys gate as at-most (a rise is a
    //     timing-model regression); both sides are deterministic.
    let mut zimg = Tensor::zeros(&[4, anet.layers[0].in_c, 64, 64]);
    for (i, v) in zimg.data_mut().iter_mut().enumerate() {
        if (i / 64) % 64 >= 16 {
            *v = (i as i32 % 421) - 210;
        }
    }
    let skip_on = ExecOpts::streaming(4).with_workers(2).with_skip_zero_activations(true);
    let skip_off = ExecOpts::streaming(4).with_workers(2).with_skip_zero_activations(false);
    assert_eq!(
        aplan.execute_opts(&zimg, skip_on).unwrap(),
        aplan.execute_opts(&zimg, skip_off).unwrap(),
        "skip lane must be bit-exact before being timed"
    );
    h.bench("activation-skipping/alexnet-div16-skip-on", || {
        aplan.execute_opts(&zimg, skip_on).unwrap().len()
    });
    h.bench("activation-skipping/alexnet-div16-skip-off", || {
        aplan.execute_opts(&zimg, skip_off).unwrap().len()
    });
    let (_, zt) = aplan.execute_traced(&zimg, skip_on).unwrap();
    assert!(zt.skipped_windows() > 0, "zero-banded batch must produce skips");
    h.metric_row(
        "activation-skipping/alexnet-div16-hw64",
        vec![
            ("alexnet_skipped_rows".into(), zt.skipped_rows() as f64),
            ("alexnet_skipped_windows".into(), zt.skipped_windows() as f64),
            ("total_windows".into(), zt.total_windows() as f64),
            ("window_skip_pct".into(), zt.window_skip_fraction() * 100.0),
            ("zero_pct".into(), zt.activation_zero_fraction() * 100.0),
            (
                "speedup_vs_skip_off_x".into(),
                median(h.results(), "activation-skipping/alexnet-div16-skip-off")
                    / median(h.results(), "activation-skipping/alexnet-div16-skip-on"),
            ),
        ],
    );

    //     Simulated three-way (dense DaDN / Tetris / Tetris+skip) per
    //     full-size model, paired on one sampling seed; the measured
    //     profile comes from one traced image on a channel-scaled copy
    //     (deterministic, so the cycle counts are bit-stable run to
    //     run).
    let sim_cfg = AccelConfig::default();
    let sim_calib = CalibConfig::default();
    for name in ["alexnet", "vgg16"] {
        let net = zoo::by_name(name).unwrap();
        let profile =
            tetris::sim::activation::measure_activation_profile(&net, &sim_cfg, 0x7E).unwrap();
        let dense = tetris::sim::simulate_network(
            &tetris::sim::dadn::DadnSim,
            &net,
            &sim_cfg,
            &sim_calib,
            5,
        )
        .unwrap()
        .total_cycles();
        let tet = tetris::sim::simulate_network(
            &tetris::sim::tetris::TetrisSim,
            &net,
            &sim_cfg,
            &sim_calib,
            5,
        )
        .unwrap()
        .total_cycles();
        let skip = tetris::sim::simulate_network(
            &tetris::sim::activation::TetrisSkipSim { profile },
            &net,
            &sim_cfg,
            &sim_calib,
            5,
        )
        .unwrap()
        .total_cycles();
        assert!(
            skip < tet && tet < dense,
            "{name}: simulated ordering skip {skip} < tetris {tet} < dense {dense} violated"
        );
        h.metric_row(
            &format!("activation-skipping/{name}-simulated"),
            vec![
                (format!("{name}_dense_sim_cycles"), dense as f64),
                (format!("{name}_tetris_sim_cycles"), tet as f64),
                (format!("{name}_skip_sim_cycles"), skip as f64),
                ("zero_pct".into(), profile.zero_fraction * 100.0),
                ("essential_bits_mean".into(), profile.essential_bits_mean),
            ],
        );
    }

    // 15. ISSUE 9: cluster serving. In-process shard servers over
    //     loopback (a bench binary cannot spawn `tetris` children),
    //     the consistent-hash router, and the closed-loop loadgen at
    //     1, 2 and 4 shards — every shard built from the same model
    //     spec + seed, so routing is load-bearing but the answers are
    //     identical. Loadgen throughput and exact percentiles are
    //     one-shot measurements reported as metric rows; the key names
    //     avoid every gated suffix in scripts/bench_compare.py, so on
    //     first sight they report as `new` (informational) and later
    //     runs track them without failing the job on wall-clock noise.
    //     Scaling expectations (≥1.7x at 2 shards, ≥3x at 4, p99
    //     within 2x) are soft-checked with warnings for the same
    //     reason.
    {
        use tetris::cluster::wire::Message;
        use tetris::cluster::{loadgen, ModelSetSpec, Router, RouterConfig, ShardServer};

        const SPEC: &str = "alexnet:16:64,googlenet:16:64,nin:16:64,vgg19:16:32";
        const SEED: u64 = 0x7e7215;
        let spec = ModelSetSpec::parse(SPEC).unwrap();
        let requests = 96;
        let mut observed: Vec<(usize, f64, f64)> = Vec::new(); // (shards, rps, p99)
        for shards in [1usize, 2, 4] {
            let mut handles = Vec::new();
            let mut addrs = Vec::new();
            for i in 0..shards {
                let engine = spec.build_engine(1, SEED, 8).unwrap();
                let handle = ShardServer::spawn(
                    format!("shard-{i}"),
                    engine,
                    "127.0.0.1:0".parse().unwrap(),
                )
                .unwrap();
                addrs.push(handle.addr());
                handles.push(handle);
            }
            let router = Router::connect(
                &addrs,
                RouterConfig { timeout: Duration::from_secs(120), ..RouterConfig::default() },
            )
            .unwrap();
            let report = loadgen::run(
                &router,
                &loadgen::LoadgenConfig { requests, clients: 8, seed: SEED, models: vec![] },
            )
            .unwrap();
            assert_eq!(
                report.done, requests,
                "{shards}-shard run: healthy shards must complete every request"
            );
            let reroutes: u64 = router.metrics().shards.iter().map(|s| s.reroutes).sum();
            h.metric_row(
                &format!("cluster-serving/{shards}-shard"),
                vec![
                    ("throughput_rps".into(), report.throughput_rps),
                    ("p50_us".into(), report.p50_us),
                    ("p95_us".into(), report.p95_us),
                    ("p99_us".into(), report.p99_us),
                    ("completed".into(), report.done as f64),
                    ("failed".into(), report.failed as f64),
                    ("reroutes".into(), reroutes as f64),
                ],
            );
            observed.push((shards, report.throughput_rps, report.p99_us));
            router.close();
            for handle in handles {
                handle.shutdown();
            }
        }
        let rps = |n: usize| observed.iter().find(|o| o.0 == n).unwrap().1;
        let p99 = |n: usize| observed.iter().find(|o| o.0 == n).unwrap().2.max(1e-9);
        let speedup_2 = rps(2) / rps(1);
        let speedup_4 = rps(4) / rps(1);
        h.metric_row(
            "cluster-serving/scaling",
            vec![
                ("speedup_2x".into(), speedup_2),
                ("speedup_4x".into(), speedup_4),
                ("p99_ratio_2x".into(), p99(2) / p99(1)),
                ("p99_ratio_4x".into(), p99(4) / p99(1)),
            ],
        );
        if speedup_2 < 1.7 || speedup_4 < 3.0 {
            eprintln!(
                "warning: cluster scaling below target (2 shards {speedup_2:.2}x, \
                 4 shards {speedup_4:.2}x) — expected ≥1.7x / ≥3x on an unloaded host"
            );
        }
        if p99(2) / p99(1) > 2.0 || p99(4) / p99(1) > 2.0 {
            eprintln!("warning: sharded p99 more than 2x the single-shard p99");
        }

        // The codec itself, timed: one maximal-ish Done frame
        // round-tripped (encode + decode + checksum both ways).
        let frame = Message::Done {
            seq: 1,
            argmax: 7,
            latency_us: 123.5,
            sim_cycles: 99_999,
            batch_size: 8,
            logits: (0..4096u32).map(|i| i.wrapping_mul(2_654_435_761) as i32).collect(),
        };
        h.bench("cluster-serving/wire-roundtrip-4k", || {
            let bytes = frame.encode();
            let back = Message::decode_from(&mut &bytes[..]).unwrap();
            assert!(matches!(back, Message::Done { .. }));
            bytes.len()
        });
    }

    // 16. ISSUE 10: the decoded-lane conv kernel. Every zoo model runs
    //     the same streaming schedule under both conv inner loops —
    //     the compile-time decoded schedule (the default) and the
    //     legacy per-pixel splitter walk — with bit-exactness asserted
    //     before timing. The traced runs also pin the energy
    //     accounting: both kernels must report identical slot-decode /
    //     segment-add totals (the decoded path charges the precomputed
    //     per-window constants; the legacy path counts as it splits).
    //     Key names avoid every gated suffix in
    //     scripts/bench_compare.py (`_peak_bytes`, `_skipped_rows`,
    //     `_skipped_windows`, `_sim_cycles`), so these rows report as
    //     informational and later runs track throughput without
    //     failing CI on wall-clock noise.
    let kernel_models: Vec<(&str, &CompiledNetwork, &Tensor<i32>)> = vec![
        ("alexnet", &aplan, &aimg),
        ("googlenet", &gplan, &gimg),
        ("vgg16", &vplan, &vimg),
        ("vgg19", &v19plan, &v19img),
        ("nin", &nplan, &nimg),
    ];
    for (name, plan, img) in kernel_models {
        let decoded = ExecOpts::streaming(4).with_workers(2).with_kernel(Kernel::Decoded);
        let legacy = ExecOpts::streaming(4).with_workers(2).with_kernel(Kernel::Legacy);
        assert_eq!(
            plan.execute_opts(img, decoded).unwrap(),
            plan.execute_opts(img, legacy).unwrap(),
            "{name}: decoded and legacy kernels must agree before being timed"
        );
        h.bench(&format!("decoded-kernel/{name}-decoded"), || {
            plan.execute_opts(img, decoded).unwrap().len()
        });
        h.bench(&format!("decoded-kernel/{name}-legacy"), || {
            plan.execute_opts(img, legacy).unwrap().len()
        });
        let (_, dt) = plan.execute_traced(img, decoded).unwrap();
        let (_, lt) = plan.execute_traced(img, legacy).unwrap();
        assert_eq!(
            (dt.slot_decodes(), dt.segment_adds()),
            (lt.slot_decodes(), lt.segment_adds()),
            "{name}: kernels must charge identical decode/add energy counters"
        );
        let d_med = median(h.results(), &format!("decoded-kernel/{name}-decoded"));
        let l_med = median(h.results(), &format!("decoded-kernel/{name}-legacy"));
        h.metric_row(
            &format!("decoded-kernel/{name}"),
            vec![
                ("decoded_windows_per_sec".into(), dt.total_windows() as f64 / d_med),
                ("legacy_windows_per_sec".into(), lt.total_windows() as f64 / l_med),
                ("speedup_vs_legacy_x".into(), l_med / d_med),
                ("slot_decodes".into(), dt.slot_decodes() as f64),
                ("segment_adds".into(), dt.segment_adds() as f64),
            ],
        );
    }

    h.emit();
    if let Some(dir) = tetris::engine::env::bench_csv_dir() {
        h.write_csv(dir.join("hotpath.csv").as_path()).ok();
    }
}
