//! Bench: regenerate Figure 10 (energy-delay-product efficiency
//! normalized to DaDN).
//!
//! Run: `cargo bench --bench fig10_edp`

use tetris::config::CalibConfig;
use tetris::energy::{edp, network_energy};
use tetris::model::zoo;
use tetris::report::figures::design_points;
use tetris::util::bench::Harness;

fn main() {
    let mut h = Harness::new("Figure 10 — EDP efficiency vs DaDN (higher is better)");
    tetris::report::fig10(42, None).expect("fig10");

    let calib = CalibConfig::default();
    let mut geo = (0.0f64, 0.0f64, 0.0f64);
    let nets = zoo::all();
    for net in &nets {
        let p = design_points(net, &calib, 42).expect("points");
        let e = |s: &tetris::sim::NetworkSim| edp(network_energy(s, &calib).total_j(), s.time_s());
        let d = e(&p.dadn);
        let (ep, ef, ei) = (d / e(&p.pra), d / e(&p.tetris_fp16), d / e(&p.tetris_int8));
        h.metric_row(
            &format!("fig10/{}", net.name),
            vec![
                ("pra_eff".into(), ep),
                ("tetris_fp16_eff".into(), ef),
                ("tetris_int8_eff".into(), ei),
            ],
        );
        geo.0 += ep.ln();
        geo.1 += ef.ln();
        geo.2 += ei.ln();
    }
    let n = nets.len() as f64;
    h.metric_row(
        "fig10/geomean (paper: PRA 0.35, fp16 1.24, int8 1.46; see EXPERIMENTS.md)",
        vec![
            ("pra_eff".into(), (geo.0 / n).exp()),
            ("tetris_fp16_eff".into(), (geo.1 / n).exp()),
            ("tetris_int8_eff".into(), (geo.2 / n).exp()),
        ],
    );

    let net = zoo::alexnet();
    h.bench("fig10/energy-model-alexnet", || {
        let p = design_points(&net, &calib, 3).unwrap();
        network_energy(&p.tetris_fp16, &calib).total_j()
    });
    h.report();
}
