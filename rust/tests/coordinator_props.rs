//! Coordinator invariants (DESIGN.md I6): routing/batching preserve the
//! request→response mapping, respect batch bounds, and starve nothing —
//! property-tested over random load shapes.

use std::collections::HashSet;
use std::time::Duration;

use tetris::coordinator::{
    BatchPolicy, InferBackend, InferRequest, SacBackend, Server, ServerConfig,
};
use tetris::model::Tensor;
use tetris::util::prop::{run_with, PropConfig};
use tetris::util::rng::Rng;

fn image(rng: &mut Rng) -> Tensor<i32> {
    let mut t = Tensor::zeros(&[1, 16, 16]);
    for v in t.data_mut() {
        *v = rng.range_i64(-400, 400) as i32;
    }
    t
}

/// Every submitted request gets exactly one response with valid fields,
/// across random batch policies / worker counts / load sizes.
#[test]
fn exactly_once_any_policy() {
    run_with(
        PropConfig { cases: 12, seed: 0x60 },
        "exactly-once delivery",
        |r| {
            (
                1 + r.below(16) as usize,       // max_batch
                1 + r.below(3) as usize,        // workers
                1 + r.below(40) as usize,       // requests
                r.below(1500),                  // max_wait µs
            )
        },
        |&(max_batch, workers, n, wait_us)| {
            let server = Server::start(
                ServerConfig {
                    policy: BatchPolicy {
                        max_batch,
                        max_wait: Duration::from_micros(wait_us),
                    },
                    workers,
                },
                |_| SacBackend::synthetic(5),
            )
            .map_err(|e| e.to_string())?;
            let mut rng = Rng::new(42);
            for id in 0..n as u64 {
                server.submit(InferRequest::new(id, image(&mut rng))).map_err(|e| e.to_string())?;
            }
            let mut seen = HashSet::new();
            for _ in 0..n {
                let resp = server.recv().map_err(|e| e.to_string())?;
                if !seen.insert(resp.id) {
                    return Err(format!("duplicate response id {}", resp.id));
                }
                if resp.id >= n as u64 {
                    return Err(format!("unknown id {}", resp.id));
                }
                if resp.batch_size == 0 || resp.batch_size > max_batch {
                    return Err(format!("batch size {} out of bounds", resp.batch_size));
                }
                if resp.logits.len() != 4 || resp.argmax >= 4 {
                    return Err("malformed response".into());
                }
            }
            let m = server.shutdown();
            if m.requests_done != n as u64 {
                return Err(format!("metrics counted {} != {n}", m.requests_done));
            }
            Ok(())
        },
    );
}

/// Batching must not change values: server responses equal direct
/// backend inference for the same images (paired by id).
#[test]
fn batching_is_value_transparent() {
    let server = Server::start(
        ServerConfig {
            policy: BatchPolicy { max_batch: 5, max_wait: Duration::from_micros(300) },
            workers: 3,
        },
        |_| SacBackend::synthetic(77),
    )
    .unwrap();
    let mut direct = SacBackend::synthetic(77).unwrap();
    let mut rng = Rng::new(9);
    let images: Vec<Tensor<i32>> = (0..31).map(|_| image(&mut rng)).collect();
    for (id, img) in images.iter().enumerate() {
        server.submit(InferRequest::new(id as u64, img.clone())).unwrap();
    }
    let mut responses: Vec<_> = (0..31).map(|_| server.recv().unwrap()).collect();
    server.shutdown();
    responses.sort_by_key(|r| r.id);
    for r in responses {
        let mut img = images[r.id as usize].clone();
        let s = img.shape().to_vec();
        img.reshape(&[1, s[0], s[1], s[2]]).unwrap();
        let want = direct.infer_batch(&img).unwrap().remove(0);
        assert_eq!(r.logits, want, "id {}", r.id);
    }
}

/// Metrics stay consistent under concurrent submit/drain.
#[test]
fn metrics_consistent_under_concurrency() {
    let server = Server::start(
        ServerConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            workers: 2,
        },
        |_| SacBackend::synthetic(1),
    )
    .unwrap();
    let n = 64u64;
    std::thread::scope(|scope| {
        let srv = &server;
        scope.spawn(move || {
            let mut rng = Rng::new(1);
            for id in 0..n {
                srv.submit(InferRequest::new(id, image(&mut rng))).unwrap();
            }
        });
        let mut got = 0;
        while got < n {
            server.recv().unwrap();
            got += 1;
        }
    });
    let m = server.shutdown();
    assert_eq!(m.requests_done, n);
    assert!(m.batches_done >= (n / 8) as u64);
    assert!(m.latency.count() == n);
}
