//! ISSUE 10: the decoded-lane conv kernel joins the I5 equivalence
//! class, zoo-wide and property-swept.
//!
//! Pinned here:
//! * a `util::prop` sweep over (network, walk, tile-or-budget,
//!   workers, skip on/off): executing with `Kernel::Decoded` (the
//!   default) is byte-identical to `Kernel::Legacy` and to the naive
//!   scalar reference (logits included where the zoo declares heads),
//!   and the two kernels report identical trace counters — slot
//!   decodes, segment adds, skipped rows/windows, total windows — so
//!   the decoded fast path can never drift from the paper's energy
//!   accounting or from the PR 8 skip lane's CI-gated metrics;
//! * the compile-time decoded schedule's precomputed per-window
//!   constants equal what the legacy kneaded walk actually counts:
//!   decodes = Σ slot-table lengths, adds = Σ essential-bit occupancy,
//!   checked both statically (against the kneaded lanes) and
//!   dynamically (traced counters agree kernel-vs-kernel).
//!
//! The case count honors `TETRIS_PROP_CASES` (scripts/verify.sh and CI
//! run the sweep under an explicit knob); unset, it defaults to 12
//! like the sibling sweeps in plan_skip.rs / plan_streaming.rs.

use tetris::config::Mode;
use tetris::model::reference::forward_reference;
use tetris::model::weights::{synthetic_loaded_with_heads, DensityCalibration};
use tetris::model::{zoo, Network, Tensor};
use tetris::plan::{CompiledNetwork, ExecOpts, Kernel, Walk};
use tetris::util::prop::{run_with, PropConfig};
use tetris::util::rng::Rng;

/// Signed noise with the top quarter of every channel zeroed (same
/// construction as plan_skip.rs): the band survives every conv/pool,
/// so the skip-armed cases in the sweep exercise the decoded kernel's
/// window-zero lane compaction against real skips, not vacuously.
fn banded_input(net: &Network, n: usize, hw: usize, rng: &mut Rng) -> Tensor<i32> {
    let mut x = Tensor::zeros(&[n, net.layers[0].in_c, hw, hw]);
    let band = hw / 4;
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        if (i / hw) % hw >= band {
            *v = rng.range_i64(-512, 512) as i32;
        }
    }
    x
}

/// The scaled evaluation zoo (same scaling the other I5 suites pin),
/// with head weights wherever the zoo declares heads so the
/// equivalence covers image → logits.
fn scaled_zoo() -> Vec<(Network, &'static str, usize)> {
    vec![
        (zoo::alexnet().scaled(16, 64), "alexnet", 64),
        (zoo::googlenet().scaled(16, 64), "googlenet", 64),
        (zoo::vgg16().scaled(16, 32), "vgg16", 32),
        (zoo::vgg19().scaled(16, 32), "vgg19", 32),
        (zoo::nin().scaled(16, 64), "nin", 64),
    ]
}

fn prop_cases() -> usize {
    std::env::var("TETRIS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&c| c > 0)
        .unwrap_or(12)
}

// ---------------- acceptance: decoded ≡ legacy ≡ reference, property-swept ----------------

#[test]
fn decoded_kernel_joins_the_equivalence_class_zoo_wide() {
    let compiled: Vec<(Network, CompiledNetwork, Tensor<i32>, Tensor<i32>)> = scaled_zoo()
        .into_iter()
        .map(|(net, profile, hw)| {
            let w = synthetic_loaded_with_heads(
                &net,
                Mode::Fp16,
                12,
                profile,
                DensityCalibration::Fig2,
                0x8000 + hw as u64,
            )
            .unwrap();
            let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
            let mut rng = Rng::new(0x5C1B + hw as u64);
            let x = banded_input(&net, 1, hw, &mut rng);
            let want = forward_reference(&net, &w, &x);
            (net, plan, x, want)
        })
        .collect();

    run_with(
        PropConfig { cases: prop_cases(), seed: 0x5EED_0010 },
        "decoded ≡ legacy ≡ reference ∧ counters agree",
        |rng| {
            let net_i = rng.below(compiled.len() as u64) as usize;
            let walk = match rng.below(3) {
                0 => Walk::Tiled,
                1 => Walk::Streaming,
                _ => Walk::Pipelined,
            };
            let workers = 1 + rng.below(4) as usize;
            let tile = if rng.chance(0.5) {
                // Direct tile/advance step: 0 (whole image) or 1..=6.
                rng.below(7) as usize
            } else {
                // Budget-derived, like serving: 1..=64 MiB through the
                // walk-aware estimator.
                let budget = (1u64 << rng.below(7)) * 1024 * 1024;
                compiled[net_i].1.tile_rows_for_budget_walk(budget, workers, walk)
            };
            let skip = rng.chance(0.5);
            (net_i, walk, tile, workers, skip)
        },
        |&(net_i, walk, tile, workers, skip)| {
            let (net, plan, x, want) = &compiled[net_i];
            let opts = ExecOpts::tiled(tile)
                .with_workers(workers)
                .with_walk(walk)
                .with_skip_zero_activations(skip);
            let (dec, t_dec) = plan
                .execute_traced(x, opts.with_kernel(Kernel::Decoded))
                .map_err(|e| e.to_string())?;
            let (leg, t_leg) = plan
                .execute_traced(x, opts.with_kernel(Kernel::Legacy))
                .map_err(|e| e.to_string())?;
            if &leg != want {
                return Err(format!(
                    "{}: legacy {walk:?} tile={tile} workers={workers} skip={skip} \
                     diverged from reference",
                    net.name
                ));
            }
            if dec != leg {
                return Err(format!(
                    "{}: decoded {walk:?} tile={tile} workers={workers} skip={skip} \
                     changed the bytes",
                    net.name
                ));
            }
            let dc = (
                t_dec.slot_decodes(),
                t_dec.segment_adds(),
                t_dec.skipped_rows(),
                t_dec.skipped_windows(),
                t_dec.total_windows(),
            );
            let lc = (
                t_leg.slot_decodes(),
                t_leg.segment_adds(),
                t_leg.skipped_rows(),
                t_leg.skipped_windows(),
                t_leg.total_windows(),
            );
            if dc != lc {
                return Err(format!(
                    "{}: kernel counters diverged ({walk:?} tile={tile} workers={workers} \
                     skip={skip}) — decoded {dc:?} vs legacy {lc:?}",
                    net.name
                ));
            }
            if t_dec.slot_decodes() == 0 || t_dec.segment_adds() == 0 {
                return Err(format!(
                    "{}: conv trunk executed but charged no decode/add energy — \
                     the counter equality is vacuous",
                    net.name
                ));
            }
            if skip && t_dec.skipped_windows() == 0 {
                return Err(format!(
                    "{}: zero-banded input produced no skips under the decoded kernel \
                     ({walk:?} tile={tile})",
                    net.name
                ));
            }
            Ok(())
        },
    );
}

// ---------------- the decoded schedule's counts equal the kneaded walk's ----------------

/// Static half: for every compiled zoo conv, the schedule lowered at
/// compile time charges exactly what the legacy splitter walk counts —
/// `decodes_per_window` = Σ slot-table lengths and `adds_per_window` =
/// Σ essential-bit occupancy = entry count, over the conv's kneaded
/// lanes. This is the per-window constant the executor multiplies by
/// executed windows, so it IS the energy model.
#[test]
fn decoded_schedule_constants_match_the_kneaded_lanes_zoo_wide() {
    for (net, profile, hw) in scaled_zoo() {
        let w = synthetic_loaded_with_heads(
            &net,
            Mode::Fp16,
            12,
            profile,
            DensityCalibration::Fig2,
            0x8000 + hw as u64,
        )
        .unwrap();
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        for conv in plan.convs() {
            let mut decodes = 0u64;
            let mut adds = 0u64;
            for lane in &conv.lanes {
                for group in &lane.groups {
                    for kw in &group.kneaded {
                        decodes += kw.slots().len() as u64;
                        adds += kw.occupancy() as u64;
                    }
                }
            }
            assert_eq!(
                conv.decoded.decodes_per_window, decodes,
                "{}/{}: decoded schedule under/over-counts slot decodes",
                net.name, conv.name
            );
            assert_eq!(
                conv.decoded.adds_per_window, adds,
                "{}/{}: decoded schedule under/over-counts segment adds",
                net.name, conv.name
            );
            assert_eq!(
                conv.decoded.entries.len() as u64,
                adds,
                "{}/{}: one decoded entry per essential bit",
                net.name, conv.name
            );
            assert_eq!(
                conv.decoded.offsets.len(),
                conv.lanes.len() + 1,
                "{}/{}: CSR offsets must cover every filter",
                net.name, conv.name
            );
        }
    }
}

/// Dynamic half: one pinned single-worker run per zoo model, both
/// kernels, skip off — the decoded path's `constant × executed
/// windows` charge equals the legacy path's counted-as-it-splits
/// totals exactly (not just statistically), and both are non-zero.
#[test]
fn traced_energy_counters_agree_kernel_vs_kernel_zoo_wide() {
    for (net, profile, hw) in scaled_zoo() {
        let w = synthetic_loaded_with_heads(
            &net,
            Mode::Fp16,
            12,
            profile,
            DensityCalibration::Fig2,
            0x8000 + hw as u64,
        )
        .unwrap();
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let mut rng = Rng::new(0xE7E7 + hw as u64);
        let x = banded_input(&net, 1, hw, &mut rng);
        let opts = ExecOpts::streaming(4).with_workers(1);
        let (dec, t_dec) =
            plan.execute_traced(&x, opts.with_kernel(Kernel::Decoded)).unwrap();
        let (leg, t_leg) =
            plan.execute_traced(&x, opts.with_kernel(Kernel::Legacy)).unwrap();
        assert_eq!(dec, leg, "{}: kernels disagree on bytes", net.name);
        assert!(t_dec.slot_decodes() > 0, "{}: no decodes charged", net.name);
        assert!(t_dec.segment_adds() > 0, "{}: no adds charged", net.name);
        assert_eq!(
            t_dec.slot_decodes(),
            t_leg.slot_decodes(),
            "{}: slot-decode totals diverged",
            net.name
        );
        assert_eq!(
            t_dec.segment_adds(),
            t_leg.segment_adds(),
            "{}: segment-add totals diverged",
            net.name
        );
        assert_eq!(
            t_dec.total_windows(),
            t_leg.total_windows(),
            "{}: window totals diverged",
            net.name
        );
    }
}
