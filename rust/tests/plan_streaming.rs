//! ISSUE 5 tentpole tests: the streaming segment pipeline — rolling
//! rings that slide down the image with **zero halo recompute** — is
//! bit-identical to the PR 3 tiled walk AND to the naive scalar MAC
//! interpreter (`model::reference`) across the whole scaled zoo, and
//! the executable FC stacks take VGG-16 and GoogleNet from image to
//! logits for the first time (invariant I5 extended to
//! logits-after-fc).
//!
//! Pinned here:
//! * streaming ≡ tiled ≡ reference on every zoo network, over tile
//!   heights and thread budgets;
//! * a `util::prop` property: for random (network, tile-or-budget,
//!   workers) cases, the streaming walk's measured peak bytes never
//!   exceed the tiled walk's and its `halo_recompute_rows` reads 0,
//!   while the tiled walk's is positive whenever it actually tiles;
//! * VGG-16 fc6–8 and GoogleNet loss3/classifier execute through
//!   their compiled per-name lanes (flatten → fused heads → logits),
//!   bit-exact vs the reference interpreter's naive FC chain.
//!
//! ISSUE 6 extends the sweep to **whole-network streaming**: the
//! pipelined walk — rings chained across segment boundaries — joins
//! the equivalence class (`pipelined ≡ streaming ≡ tiled ≡ reference`,
//! logits included) across tile heights × memory budgets × workers
//! with `halo_recompute_rows == 0`, and on deep full(er)-resolution
//! trunks its measured peak sits below the per-segment streaming
//! walk's and stays flat in network depth (±the ring working set:
//! VGG-16 vs VGG-19 at the same resolution).

use tetris::config::Mode;
use tetris::model::reference::forward_reference;
use tetris::model::weights::{
    synthetic_loaded, synthetic_loaded_with_heads, DensityCalibration,
};
use tetris::model::{zoo, Network, Tensor};
use tetris::plan::{CompiledNetwork, ExecOpts, Walk};
use tetris::util::prop::{run_with, PropConfig};
use tetris::util::rng::Rng;

fn random_input(net: &Network, n: usize, hw: usize, rng: &mut Rng) -> Tensor<i32> {
    let mut x = Tensor::zeros(&[n, net.layers[0].in_c, hw, hw]);
    for v in x.data_mut() {
        *v = rng.range_i64(-512, 512) as i32;
    }
    x
}

/// The scaled evaluation zoo (same scaling plan_topology pins I5
/// with), conv-trunk weights.
fn scaled_zoo() -> Vec<(Network, &'static str, usize)> {
    vec![
        (zoo::alexnet().scaled(16, 64), "alexnet", 64),
        (zoo::googlenet().scaled(16, 64), "googlenet", 64),
        (zoo::vgg16().scaled(16, 32), "vgg16", 32),
        (zoo::vgg19().scaled(16, 32), "vgg19", 32),
        (zoo::nin().scaled(16, 64), "nin", 64),
    ]
}

// ---------------- acceptance: zoo-wide streaming ≡ tiled ≡ reference ----------------

/// Every network of the paper's evaluation, channel-scaled, runs
/// bit-exact through the streaming walk — against the tiled walk and
/// against one naive-reference output — for dividing and non-dividing
/// advance steps and several thread budgets.
#[test]
fn full_zoo_streaming_bit_exact_vs_tiled_and_reference() {
    for (net, profile, hw) in scaled_zoo() {
        let w = synthetic_loaded(&net, Mode::Fp16, 12, profile, DensityCalibration::Fig2, 0x57E4)
            .unwrap();
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let mut rng = Rng::new(47);
        let x = random_input(&net, 2, hw, &mut rng);
        let want = forward_reference(&net, &w, &x);
        for tile in [2usize, 0] {
            for workers in [1usize, 4] {
                let got = plan
                    .execute_opts(&x, ExecOpts::streaming(tile).with_workers(workers))
                    .unwrap();
                assert_eq!(got, want, "{}: streaming tile={tile} workers={workers}", net.name);
                let tiled = plan
                    .execute_opts(&x, ExecOpts::tiled(tile).with_workers(workers))
                    .unwrap();
                assert_eq!(tiled, want, "{}: tiled tile={tile} workers={workers}", net.name);
            }
        }
        assert_eq!(plan.execute(&x).unwrap(), want, "{}: default path", net.name);
    }
}

// ---------------- acceptance: zero halo recompute + peak ordering, property-swept ----------------

/// `util::prop` sweep over (network, tile-height-or-memory-budget,
/// workers): the streaming walk never recomputes a halo row and never
/// allocates more than the tiled walk at the same settings, while
/// producing identical bytes. Tile heights are drawn directly half
/// the time and derived from a memory budget (the serving path's
/// `tile_rows_for_budget`) the other half, so the budget knob is
/// exercised too.
#[test]
fn streaming_never_recomputes_and_never_outallocates_tiled() {
    // Compile each zoo plan once; the property draws cases over them.
    let compiled: Vec<(Network, CompiledNetwork, Tensor<i32>)> = scaled_zoo()
        .into_iter()
        .map(|(net, profile, hw)| {
            let w = synthetic_loaded(
                &net,
                Mode::Fp16,
                12,
                profile,
                DensityCalibration::Fig2,
                0xA110,
            )
            .unwrap();
            let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
            let mut rng = Rng::new(7);
            let x = random_input(&net, 1, hw, &mut rng);
            (net, plan, x)
        })
        .collect();

    run_with(
        PropConfig { cases: 12, seed: 0x5EED_0005 },
        "streaming peak ≤ tiled peak ∧ zero halo recompute",
        |rng| {
            let net_i = rng.below(compiled.len() as u64) as usize;
            let workers = 1 + rng.below(4) as usize;
            let tile = if rng.chance(0.5) {
                // Direct tile height: 0 (materializing) or 1..=6.
                rng.below(7) as usize
            } else {
                // Budget-derived, like serving: 1..=64 MiB.
                let budget = (1u64 << rng.below(7)) * 1024 * 1024;
                compiled[net_i].1.tile_rows_for_budget(budget, workers)
            };
            (net_i, tile, workers)
        },
        |&(net_i, tile, workers)| {
            let (net, plan, x) = &compiled[net_i];
            let (streamed, ts) = plan
                .execute_traced(x, ExecOpts::streaming(tile).with_workers(workers))
                .map_err(|e| e.to_string())?;
            let (tiled, tt) = plan
                .execute_traced(x, ExecOpts::tiled(tile).with_workers(workers))
                .map_err(|e| e.to_string())?;
            if streamed != tiled {
                return Err(format!("{}: walks diverged", net.name));
            }
            if ts.halo_recompute_rows() != 0 {
                return Err(format!(
                    "{}: streaming recomputed {} halo rows",
                    net.name,
                    ts.halo_recompute_rows()
                ));
            }
            if ts.peak_bytes() > tt.peak_bytes() {
                return Err(format!(
                    "{}: streaming peak {} exceeds tiled peak {}",
                    net.name,
                    ts.peak_bytes(),
                    tt.peak_bytes()
                ));
            }
            // The halo the streaming walk eliminates is real work on
            // the tiled side whenever a fused pool's window overhangs
            // its stride (k > s: the 3×3 stride-2 pools of AlexNet,
            // GoogleNet and NiN — VGG's 2×2 stride-2 windows are
            // disjoint, so its tiled halo is legitimately zero).
            let has_overlapping_pools = matches!(net_i, 0 | 1 | 4);
            if tile == 1 && has_overlapping_pools && tt.halo_recompute_rows() == 0 {
                return Err(format!(
                    "{}: tiled walk at 1-row tiles reported no halo recompute",
                    net.name
                ));
            }
            Ok(())
        },
    );
}

// ---------------- ISSUE 6: whole-network streaming, property-swept ----------------

/// `util::prop` sweep over (network, tile-or-budget, workers): the
/// pipelined walk — rings chained across every pool boundary of the
/// trunk — produces byte-identical output to the streaming walk, the
/// tiled walk, AND the naive reference (logits included: vgg16 runs
/// through fc6–8, googlenet through loss3/classifier), with zero halo
/// recompute. Tile heights are drawn directly half the time and
/// derived from a memory budget through the walk-aware
/// `tile_rows_for_budget_walk` the other half. The case count honors
/// `TETRIS_PROP_CASES` (scripts/verify.sh runs this sweep under an
/// explicit knob); unset, it defaults to the sibling sweep's 12.
#[test]
fn pipelined_walk_joins_the_equivalence_class_zoo_wide() {
    let cases = std::env::var("TETRIS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&c| c > 0)
        .unwrap_or(12);
    // Head-bearing weights wherever the zoo declares heads, so the
    // equivalence covers image → logits, not just the conv trunk.
    let compiled: Vec<(Network, CompiledNetwork, Tensor<i32>, Tensor<i32>)> = scaled_zoo()
        .into_iter()
        .map(|(net, profile, hw)| {
            let w = synthetic_loaded_with_heads(
                &net,
                Mode::Fp16,
                12,
                profile,
                DensityCalibration::Fig2,
                0x6000 + hw as u64,
            )
            .unwrap();
            let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
            let mut rng = Rng::new(0x9E + hw as u64);
            let x = random_input(&net, 1, hw, &mut rng);
            let want = forward_reference(&net, &w, &x);
            (net, plan, x, want)
        })
        .collect();

    run_with(
        PropConfig { cases, seed: 0x5EED_0006 },
        "pipelined ≡ streaming ≡ tiled ≡ reference ∧ zero halo recompute",
        |rng| {
            let net_i = rng.below(compiled.len() as u64) as usize;
            let workers = 1 + rng.below(4) as usize;
            let tile = if rng.chance(0.5) {
                // Direct advance step: 0 (whole image per feed) or 1..=6.
                rng.below(7) as usize
            } else {
                // Budget-derived, like serving under a pinned walk:
                // 1..=64 MiB through the pipelined estimator.
                let budget = (1u64 << rng.below(7)) * 1024 * 1024;
                compiled[net_i].1.tile_rows_for_budget_walk(budget, workers, Walk::Pipelined)
            };
            (net_i, tile, workers)
        },
        |&(net_i, tile, workers)| {
            let (net, plan, x, want) = &compiled[net_i];
            let (piped, tp) = plan
                .execute_traced(x, ExecOpts::pipelined(tile).with_workers(workers))
                .map_err(|e| e.to_string())?;
            if &piped != want {
                return Err(format!(
                    "{}: pipelined tile={tile} workers={workers} diverged from the reference",
                    net.name
                ));
            }
            if tp.halo_recompute_rows() != 0 {
                return Err(format!(
                    "{}: pipelined walk recomputed {} halo rows",
                    net.name,
                    tp.halo_recompute_rows()
                ));
            }
            let streamed = plan
                .execute_opts(x, ExecOpts::streaming(tile).with_workers(workers))
                .map_err(|e| e.to_string())?;
            let tiled = plan
                .execute_opts(x, ExecOpts::tiled(tile).with_workers(workers))
                .map_err(|e| e.to_string())?;
            if piped != streamed || piped != tiled {
                return Err(format!("{}: the three walks diverged", net.name));
            }
            Ok(())
        },
    );
}

/// On a deep trunk at fuller resolution the chained pipeline's peak is
/// strictly below the per-segment streaming walk's (whose floor is the
/// largest segment's in+out maps), and ADDING DEPTH — VGG-16 → VGG-19,
/// three more convs at the same resolution — moves the pipelined peak
/// by no more than the ring working set: depth-independent peak
/// memory, measured, not estimated.
#[test]
fn pipelined_peak_beats_streaming_and_stays_flat_in_depth() {
    let hw = 128;
    let run = |net: Network, profile: &str| {
        let w = synthetic_loaded(&net, Mode::Fp16, 12, profile, DensityCalibration::Fig2, 0xDEE)
            .unwrap();
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let mut rng = Rng::new(0xD0);
        let x = random_input(&net, 1, hw, &mut rng);
        let (_, trace) = plan.execute_traced(&x, ExecOpts::pipelined(1)).unwrap();
        assert_eq!(trace.halo_recompute_rows(), 0, "{profile}: pipelined halo must be 0");
        let summary = plan.pipeline_summary(hw, 1).expect("deep trunk must pipeline");
        (plan, x, trace.peak_bytes(), summary)
    };
    let (plan16, x16, peak16, sum16) = run(zoo::vgg16().scaled(16, hw), "vgg16");
    let (_, _, peak19, sum19) = run(zoo::vgg19().scaled(16, hw), "vgg19");

    // Ordering vs the per-segment streaming walk, measured on VGG-16.
    let (_, ts) = plan16.execute_traced(&x16, ExecOpts::streaming(1)).unwrap();
    assert!(
        peak16 < ts.peak_bytes(),
        "pipelined peak {peak16} must undercut the streaming walk's {} at {hw}²",
        ts.peak_bytes()
    );

    // Depth flatness: VGG-19's three extra convs may only add ring
    // working set, never another live feature map.
    let ring_slack = sum16.ring_bytes.max(sum19.ring_bytes);
    assert!(
        peak19 <= peak16 + ring_slack && peak16 <= peak19 + ring_slack,
        "depth moved the pipelined peak beyond the ring working set: \
         vgg16 {peak16} B vs vgg19 {peak19} B (ring slack {ring_slack} B)"
    );
    // Both chain the full 13/16-segment trunk.
    assert_eq!(sum16.segments, 13);
    assert_eq!(sum19.segments, 16);
}

// ---------------- acceptance: executable FC stacks, image → logits ----------------

/// VGG-16 with fc6–8 weights runs image → logits through the compiled
/// flatten + per-name FC lanes, bit-exact vs the reference
/// interpreter's naive FC chain, with zero halo recompute and the
/// walks agreeing.
#[test]
fn vgg16_fc_stack_executes_to_logits() {
    let net = zoo::vgg16().scaled(16, 32);
    let w =
        synthetic_loaded_with_heads(&net, Mode::Fp16, 10, "vgg16", DensityCalibration::Fig2, 0xF6)
            .unwrap();
    let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
    assert_eq!(plan.fc_heads().len(), 3, "fc6–8 must compile");
    assert_eq!(plan.output_classes(), Some(1000));

    let mut rng = Rng::new(0xF00D);
    let x = random_input(&net, 2, 32, &mut rng);
    let want = forward_reference(&net, &w, &x);
    assert_eq!(want.shape(), &[2, 1000], "reference must reach the logits");

    let (streamed, ts) = plan
        .execute_traced(&x, ExecOpts::streaming(4))
        .unwrap();
    assert_eq!(streamed, want, "streaming logits diverged from the naive FC chain");
    assert_eq!(ts.halo_recompute_rows(), 0);
    let tiled = plan.execute_opts(&x, ExecOpts::tiled(4)).unwrap();
    assert_eq!(tiled, want, "tiled logits diverged");
    assert_eq!(plan.execute(&x).unwrap(), want, "default path diverged");
    // Conv-trunk weights still serve the trunk (declaration-only).
    let trunk_w =
        synthetic_loaded(&net, Mode::Fp16, 10, "vgg16", DensityCalibration::Fig2, 0xF6).unwrap();
    let trunk_plan = CompiledNetwork::compile(&net, &trunk_w, 16, Mode::Fp16).unwrap();
    assert!(trunk_plan.fc_heads().is_empty());
    assert_eq!(trunk_plan.execute(&x).unwrap().shape().len(), 4, "trunk output is a map");
}

/// GoogleNet's loss3/classifier — a single head after the declared
/// global average pool — executes too, through the branch/concat
/// trunk.
#[test]
fn googlenet_classifier_head_executes_to_logits() {
    let net = zoo::googlenet().scaled(16, 64);
    let w = synthetic_loaded_with_heads(
        &net,
        Mode::Fp16,
        10,
        "googlenet",
        DensityCalibration::Fig2,
        0x10553,
    )
    .unwrap();
    let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
    assert_eq!(plan.fc_heads().len(), 1);
    assert_eq!(plan.fc_heads()[0].name, "loss3/classifier");
    assert!(!plan.fc_heads()[0].relu, "a lone head emits raw logits");

    let mut rng = Rng::new(0x6006);
    let x = random_input(&net, 1, 64, &mut rng);
    let want = forward_reference(&net, &w, &x);
    assert_eq!(want.shape(), &[1, 1000]);
    let (got, trace) = plan
        .execute_traced(&x, ExecOpts::streaming(4))
        .unwrap();
    assert_eq!(got, want, "googlenet logits diverged");
    assert_eq!(trace.halo_recompute_rows(), 0);
}

/// The walks and the reference agree on a head-bearing network across
/// modes and kneading strides (values are KS-invariant; the FC lanes
/// ride the same kneaded-lane machinery as convs).
#[test]
fn fc_stacks_are_ks_and_mode_invariant() {
    let net = zoo::vgg16().scaled(32, 32);
    for (mode, frac) in [(Mode::Fp16, 10u32), (Mode::Int8, 5)] {
        let w = synthetic_loaded_with_heads(&net, mode, frac, "vgg16", DensityCalibration::Fig2, 2)
            .unwrap();
        let mut rng = Rng::new(5);
        let x = random_input(&net, 1, 32, &mut rng);
        let want = forward_reference(&net, &w, &x);
        for ks in [4usize, 64] {
            let plan = CompiledNetwork::compile(&net, &w, ks, mode).unwrap();
            assert_eq!(
                plan.execute(&x).unwrap(),
                want,
                "{mode} ks={ks} diverged from the reference FC chain"
            );
        }
    }
}
