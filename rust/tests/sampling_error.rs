//! Filter-sampling error bound for the timing models (referenced from
//! `sim/sample.rs`): capping wide layers at 64 sampled filters must not
//! move the mean kneaded-lane length by more than ~1%.

use tetris::config::Mode;
use tetris::model::weights::{profile_with, DensityCalibration};
use tetris::model::zoo;
use tetris::sim::tetris::measure_kneading;
use tetris::sim::LayerSample;
use tetris::util::rng::Rng;

#[test]
fn filter_cap_error_below_one_percent() {
    // VGG-16 conv5_1: 512 filters of lane length 4608 — the widest
    // sampled-vs-full gap in the zoo.
    let layer = zoo::vgg16().layer("conv5_1").unwrap().clone();
    let profile = profile_with("vgg16", Mode::Fp16, DensityCalibration::Fig2).unwrap();
    let mut rng = Rng::new(1234);

    let full: Vec<Vec<i32>> = (0..layer.out_c)
        .map(|_| profile.generate(layer.lane_len(), &mut rng))
        .collect();
    let full_sample = LayerSample {
        filter_lanes: full.clone(),
        total_filters: layer.out_c,
        mode: Mode::Fp16,
    };
    let capped_sample = LayerSample {
        filter_lanes: full[..64].to_vec(),
        total_filters: layer.out_c,
        mode: Mode::Fp16,
    };
    let m_full = measure_kneading(&full_sample, 16);
    let m_capped = measure_kneading(&capped_sample, 16);
    let rel = (m_full.mean_kneaded_per_lane - m_capped.mean_kneaded_per_lane).abs()
        / m_full.mean_kneaded_per_lane;
    assert!(
        rel < 0.01,
        "sampling error {rel:.4} (full {} vs capped {})",
        m_full.mean_kneaded_per_lane,
        m_capped.mean_kneaded_per_lane
    );
}

#[test]
fn seed_to_seed_variation_is_small() {
    let layer = zoo::alexnet().layer("conv3").unwrap().clone();
    let profile = profile_with("alexnet", Mode::Fp16, DensityCalibration::Fig2).unwrap();
    let mut means = Vec::new();
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed);
        let lanes: Vec<Vec<i32>> =
            (0..64).map(|_| profile.generate(layer.lane_len(), &mut rng)).collect();
        let s = LayerSample { filter_lanes: lanes, total_filters: layer.out_c, mode: Mode::Fp16 };
        means.push(measure_kneading(&s, 16).mean_kneaded_per_lane);
    }
    let mean = means.iter().sum::<f64>() / means.len() as f64;
    let spread = means.iter().map(|m| (m - mean).abs()).fold(0.0, f64::max) / mean;
    assert!(spread < 0.01, "seed spread {spread:.4}");
}
