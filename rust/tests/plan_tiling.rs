//! ISSUE 3 tentpole tests: the tiled fused executor — row-tile walks
//! with halo rings over `Conv → ReluRequant [→ Pool]` segments, and
//! branch arms running under split thread budgets — is bit-identical
//! to the naive scalar MAC interpreter (`model::reference`) for every
//! tile height, every thread budget, and the materializing baseline.
//! This extends DESIGN.md invariant I5 over tilings.
//!
//! Edge cases pinned here: tile heights that do not divide the output
//! rows, AlexNet-conv1-style k=11 stride-4 halos, ceil-mode pool
//! windows straddling a tile boundary, and the peak-allocation claim
//! that the fused walk allocates less than the materializing path.

use tetris::config::Mode;
use tetris::model::reference::forward_reference;
use tetris::model::weights::{synthetic_loaded, DensityCalibration};
use tetris::model::{
    zoo, ConvLayer, LoadedLayer, LoadedWeights, Network, PoolSpec, Tensor, TopoOp,
};
use tetris::plan::{CompiledNetwork, ExecOpts};
use tetris::util::prop::gen;
use tetris::util::rng::Rng;

fn random_input(net: &Network, n: usize, hw: usize, rng: &mut Rng) -> Tensor<i32> {
    let mut x = Tensor::zeros(&[n, net.layers[0].in_c, hw, hw]);
    for v in x.data_mut() {
        *v = rng.range_i64(-512, 512) as i32;
    }
    x
}

fn random_weights(net: &Network, mode: Mode, rng: &mut Rng) -> LoadedWeights {
    let bits = mode.weight_bits() as u32;
    let layers = net
        .layers
        .iter()
        .map(|l| LoadedLayer {
            name: l.name.clone(),
            shape: [l.out_c, l.in_c, l.k, l.k],
            frac_bits: [0u32, 6, 8, 10][rng.below(4) as usize],
            weights: (0..l.weight_count()).map(|_| gen::weight(rng, bits)).collect(),
        })
        .collect();
    LoadedWeights { mode, layers }
}

/// Assert `plan` matches `want` for a sweep of tile heights and thread
/// budgets, plus the materializing baseline and the default path.
fn assert_tile_invariant(
    plan: &CompiledNetwork,
    x: &Tensor<i32>,
    want: &Tensor<i32>,
    tiles: &[usize],
    label: &str,
) {
    for &tile in tiles {
        for workers in [1usize, 4] {
            let got = plan
                .execute_opts(x, ExecOpts::tiled(tile).with_workers(workers))
                .unwrap();
            assert_eq!(&got, want, "{label}: tile={tile} workers={workers}");
        }
    }
    let mat = plan.execute_opts(x, ExecOpts::materializing()).unwrap();
    assert_eq!(&mat, want, "{label}: materializing baseline");
    let dflt = plan.execute(x).unwrap();
    assert_eq!(&dflt, want, "{label}: default adaptive path");
}

// ---------- ISSUE 3 acceptance: the whole zoo through the tiled walk ----------

/// Every network of the paper's evaluation, channel-scaled, runs
/// bit-exact through the tiled fused executor across tile heights
/// (dividing and non-dividing), the materializing baseline, and
/// different thread budgets — all against one naive-reference output.
#[test]
fn full_zoo_bit_exact_across_tile_heights_and_budgets() {
    let cases: [(Network, &str, usize); 5] = [
        (zoo::alexnet().scaled(16, 64), "alexnet", 64),
        (zoo::googlenet().scaled(16, 64), "googlenet", 64),
        (zoo::vgg16().scaled(16, 32), "vgg16", 32),
        (zoo::vgg19().scaled(16, 32), "vgg19", 32),
        (zoo::nin().scaled(16, 64), "nin", 64),
    ];
    for (net, profile, hw) in cases {
        let w = synthetic_loaded(&net, Mode::Fp16, 12, profile, DensityCalibration::Fig2, 0x7117)
            .unwrap();
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let mut rng = Rng::new(31);
        let x = random_input(&net, 1, hw, &mut rng);
        let want = forward_reference(&net, &w, &x);
        assert_tile_invariant(&plan, &x, &want, &[1, 5], &net.name);
    }
}

// ---------- satellite: tile edge cases ----------

fn conv(
    name: &str,
    in_c: usize,
    out_c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    in_hw: usize,
) -> ConvLayer {
    ConvLayer { name: name.into(), in_c, out_c, k, stride, pad, in_hw }
}

/// Tile heights that do not divide the segment's output rows: conv
/// (k3 p1, 15→15) into a 3×3 stride-2 pool (15→7, odd) — tiles of 2
/// and 3 leave a short last tile, and every height must agree.
#[test]
fn tile_height_not_dividing_output_rows() {
    let net = Network::with_schedule(
        "odd_rows",
        vec![conv("c1", 2, 3, 3, 1, 1, 15), conv("c2", 3, 2, 3, 1, 1, 7)],
        vec![
            TopoOp::Conv(0),
            TopoOp::Pool(PoolSpec::max(3, 2, 0)), // 15 → 7
            TopoOp::Conv(1),
        ],
    );
    for seed in [1u64, 2] {
        let mut rng = Rng::new(0x0DD ^ seed);
        let w = random_weights(&net, Mode::Fp16, &mut rng);
        let x = random_input(&net, 2, 15, &mut rng);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let want = forward_reference(&net, &w, &x);
        assert_tile_invariant(&plan, &x, &want, &[1, 2, 3, 4, 6, 7, 100], "odd_rows");
    }
}

/// AlexNet-conv1 geometry: k=11 stride-4 halos. A 2-row tile needs a
/// 15-row input span and adjacent tiles' spans overlap by 7 rows —
/// the widest halo in the zoo, all recomputed per tile.
#[test]
fn k11_stride4_halos_match_reference() {
    let net = Network::with_schedule(
        "wide_halo",
        vec![conv("c1", 1, 4, 11, 4, 0, 35)],
        vec![TopoOp::Conv(0)], // 35 → 7 output rows
    );
    for seed in [1u64, 2] {
        let mut rng = Rng::new(0xA1E ^ seed);
        let w = random_weights(&net, Mode::Fp16, &mut rng);
        let x = random_input(&net, 2, 35, &mut rng);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let want = forward_reference(&net, &w, &x);
        assert_tile_invariant(&plan, &x, &want, &[1, 2, 3, 5, 7], "wide_halo");
    }
}

/// Ceil-mode pool windows straddling a tile boundary: k=3 stride-2 on
/// 8 rows yields 4 output rows, the last window (rows 6..9) clipped to
/// the input. A 3-row tile puts that clipped window alone in the
/// second tile; every split must agree with the reference.
#[test]
fn ceil_mode_pool_window_straddles_tile_boundary() {
    let net = Network::with_schedule(
        "ceil_straddle",
        vec![conv("c1", 2, 3, 3, 1, 1, 8)],
        vec![
            TopoOp::Conv(0),
            TopoOp::Pool(PoolSpec::max(3, 2, 0)), // 8 → 4, last window clipped
        ],
    );
    for seed in [1u64, 2] {
        let mut rng = Rng::new(0xCE1 ^ seed);
        let w = random_weights(&net, Mode::Fp16, &mut rng);
        let x = random_input(&net, 2, 8, &mut rng);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let want = forward_reference(&net, &w, &x);
        assert_tile_invariant(&plan, &x, &want, &[1, 2, 3, 4], "ceil_straddle");
    }
}

/// Average pools take the same tiled path as max pools — floor
/// division over in-bounds taps must survive tiling too.
#[test]
fn avg_pool_tiles_match_reference() {
    let net = Network::with_schedule(
        "avg_tiled",
        vec![conv("c1", 2, 3, 3, 1, 1, 9)],
        vec![
            TopoOp::Conv(0),
            TopoOp::Pool(PoolSpec::avg(3, 2, 1)), // padded avg, 9 → 5
        ],
    );
    let mut rng = Rng::new(0xAF6);
    let w = random_weights(&net, Mode::Fp16, &mut rng);
    let x = random_input(&net, 2, 9, &mut rng);
    let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
    let want = forward_reference(&net, &w, &x);
    assert_tile_invariant(&plan, &x, &want, &[1, 2, 3, 5], "avg_tiled");
}

// ---------- satellite: peak-allocation counter ----------

/// The point of the fusion: the conv's full-size pre-pool map never
/// materializes, so the tiled walk's measured peak feature-map bytes
/// stay strictly below the materializing baseline's — on a
/// conv→pool segment whose conv output dominates.
#[test]
fn fused_walk_allocates_less_than_materializing_path() {
    let net = Network::with_schedule(
        "peak_probe",
        vec![conv("c1", 4, 16, 3, 1, 1, 32)],
        vec![
            TopoOp::Conv(0),
            TopoOp::Pool(PoolSpec::max(2, 2, 0)), // 16ch 32×32 map → 16×16
        ],
    );
    let mut rng = Rng::new(0x9EA4);
    let w = random_weights(&net, Mode::Fp16, &mut rng);
    let x = random_input(&net, 1, 32, &mut rng);
    let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
    let (full, trace_full) = plan
        .execute_traced(&x, ExecOpts::materializing().with_workers(1))
        .unwrap();
    let (tiled, trace_tiled) = plan
        .execute_traced(&x, ExecOpts::tiled(2).with_workers(1))
        .unwrap();
    assert_eq!(full, tiled, "peak probe paths diverged");
    let (peak_tiled, peak_full) = (trace_tiled.peak_bytes(), trace_full.peak_bytes());
    assert!(
        peak_tiled < peak_full,
        "fused peak {peak_tiled} not below materializing peak {peak_full}"
    );
    // The compile-time estimate agrees on the direction (it is the
    // knob tile_rows_for_budget turns).
    assert!(plan.peak_bytes_estimate(2, 1) < plan.peak_bytes_estimate(0, 1));
}

// ---------- satellite: arm-level parallelism ----------

/// Branch arms run concurrently under split budgets; logits must be
/// identical for any budget × tile-height combination — the nested
/// fan-out only moves wall time.
#[test]
fn branch_arm_budgets_never_change_outputs() {
    let net = zoo::inception_module("3a").unwrap().scaled(8, 8);
    let w = synthetic_loaded(&net, Mode::Fp16, 12, "googlenet", DensityCalibration::Fig2, 77)
        .unwrap();
    let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
    let mut rng = Rng::new(5);
    let x = random_input(&net, 2, 8, &mut rng);
    let want = forward_reference(&net, &w, &x);
    for workers in [1usize, 2, 3, 5, 16] {
        for tile in [1usize, 2, 0] {
            let got = plan
                .execute_opts(&x, ExecOpts::tiled(tile).with_workers(workers))
                .unwrap();
            assert_eq!(got, want, "workers={workers} tile={tile}");
        }
    }
}
