//! Paper-zone checks: the quantitative claims of §IV must land in the
//! right zone with the default calibration (see EXPERIMENTS.md for the
//! measured values and the documented paper inconsistencies).

use tetris::config::{AccelConfig, CalibConfig, Mode};
use tetris::energy::{chip_area, edp, network_energy};
use tetris::kneading::stats::KneadStats;
use tetris::model::weights::{profile_with, DensityCalibration};
use tetris::model::zoo;
use tetris::report::figures::design_points;
use tetris::sim::NetworkSim;
use tetris::util::rng::Rng;

fn geomeans(seed: u64) -> (f64, f64, f64, f64, f64, f64) {
    let calib = CalibConfig::default();
    let nets = zoo::all();
    let mut sp = (0.0, 0.0, 0.0); // speedups: pra, fp16, int8
    let mut ef = (0.0, 0.0, 0.0); // edp efficiency
    for net in &nets {
        let p = design_points(net, &calib, seed).unwrap();
        let t = |s: &NetworkSim| s.time_s();
        let e = |s: &NetworkSim| edp(network_energy(s, &calib).total_j(), s.time_s());
        sp.0 += (t(&p.dadn) / t(&p.pra)).ln();
        sp.1 += (t(&p.dadn) / t(&p.tetris_fp16)).ln();
        sp.2 += (t(&p.dadn) / t(&p.tetris_int8)).ln();
        ef.0 += (e(&p.dadn) / e(&p.pra)).ln();
        ef.1 += (e(&p.dadn) / e(&p.tetris_fp16)).ln();
        ef.2 += (e(&p.dadn) / e(&p.tetris_int8)).ln();
    }
    let n = nets.len() as f64;
    (
        (sp.0 / n).exp(),
        (sp.1 / n).exp(),
        (sp.2 / n).exp(),
        (ef.0 / n).exp(),
        (ef.1 / n).exp(),
        (ef.2 / n).exp(),
    )
}

/// Fig 8: paper 1.15 / 1.30 / 1.50.
#[test]
fn fig8_speedup_zones() {
    let (pra, fp16, int8, _, _, _) = geomeans(42);
    assert!((1.05..1.30).contains(&pra), "PRA speedup {pra} (paper 1.15)");
    assert!((1.20..1.45).contains(&fp16), "fp16 speedup {fp16} (paper 1.30)");
    assert!((1.35..1.65).contains(&int8), "int8 speedup {int8} (paper 1.50)");
    assert!(int8 > fp16 && fp16 > pra, "ordering must hold");
}

/// Fig 10 shape: Tetris better than DaDN, PRA worse; int8 best.
#[test]
fn fig10_edp_zones() {
    let (_, _, _, pra, fp16, int8) = geomeans(42);
    assert!(pra < 0.7, "PRA efficiency {pra} must be well below 1 (paper 0.35)");
    assert!(fp16 > 1.1, "fp16 efficiency {fp16} must beat DaDN (paper 1.24)");
    assert!(int8 > fp16, "int8 {int8} must beat fp16 {fp16} (paper 1.46 vs 1.24)");
}

/// Fig 11 anchors: AlexNet fp16 ≈ 0.75 @ KS=10 → ≈ 0.64 @ KS=32;
/// int8 ≈ 0.49 (relative to the fp16 unkneaded base), nearly flat.
#[test]
fn fig11_anchor_zones() {
    let mut rng = Rng::new(42);
    let p16 = profile_with("alexnet", Mode::Fp16, DensityCalibration::Fig2).unwrap();
    let ws16 = p16.generate(400_000, &mut rng);
    let tf = |ks: usize| KneadStats::measure(&ws16, ks, Mode::Fp16).time_fraction();
    let (t10, t32) = (tf(10), tf(32));
    assert!((0.70..0.85).contains(&t10), "fp16 KS=10: {t10} (paper 0.751)");
    assert!((0.60..0.75).contains(&t32), "fp16 KS=32: {t32} (paper 0.642)");
    assert!(t32 < t10, "monotone in KS");

    let p8 = profile_with("alexnet", Mode::Int8, DensityCalibration::Fig2).unwrap();
    let ws8 = p8.generate(400_000, &mut rng);
    for ks in [10, 32] {
        let t = KneadStats::measure(&ws8, ks, Mode::Int8).time_fraction() / 2.0;
        assert!((0.42..0.52).contains(&t), "int8 KS={ks}: {t} (paper ≈0.49)");
    }
}

/// Table 2 anchors: totals within 1% of the paper.
#[test]
fn table2_area_anchors() {
    let cfg = AccelConfig::default();
    let calib = CalibConfig::default();
    for (design, paper) in [("dadn", 79.36), ("pra", 153.65), ("tetris", 89.76)] {
        let got = chip_area(design, &cfg, &calib).unwrap().total_mm2();
        assert!(
            (got - paper).abs() / paper < 0.01,
            "{design}: {got} vs paper {paper}"
        );
    }
}

/// Table 1 anchors: geomean zero bits ≈ 68.9%.
#[test]
fn table1_geomean_anchor() {
    let rows = tetris::analysis::table1(42).unwrap();
    let gm = tetris::analysis::table1_geomean(&rows);
    assert!((gm.zero_bits_pct - 68.88).abs() < 2.0, "{}", gm.zero_bits_pct);
}

/// Fig 1 anchor: multiplier 5–25% slower than the 16-operand adder
/// (paper: 12.3%).
#[test]
fn fig1_overhead_zone() {
    let (adders, mult) = tetris::latency::fig1_series(16);
    let overhead = mult / adders.last().unwrap().1 - 1.0;
    assert!((0.05..0.25).contains(&overhead), "overhead {overhead}");
}

/// §IV.B power anchors: Tetris ~1.08× DaDN, PRA ~3.37×.
#[test]
fn power_ratio_zones() {
    let calib = CalibConfig::default();
    let net = zoo::vgg16();
    let p = design_points(&net, &calib, 42).unwrap();
    let power = |s: &NetworkSim| network_energy(s, &calib).total_j() / s.time_s();
    let tetris_rel = power(&p.tetris_fp16) / power(&p.dadn);
    let pra_rel = power(&p.pra) / power(&p.dadn);
    assert!((0.95..1.45).contains(&tetris_rel), "tetris power {tetris_rel} (paper 1.08)");
    assert!((2.2..4.5).contains(&pra_rel), "pra power {pra_rel} (paper 3.37)");
}
