//! Int8-mode integration: the paper's §III.C.3 precision-tunable path,
//! exercised end to end on the int8 artifact weights and on synthetic
//! populations.

use std::path::Path;

use tetris::config::Mode;
use tetris::kneading::Lane;
use tetris::model::{read_weight_file, Tensor};
use tetris::sac::SacUnit;
use tetris::util::prop::{gen, run_with, PropConfig};
use tetris::util::rng::Rng;

fn int8_weights() -> Option<tetris::model::LoadedWeights> {
    let p = Path::new("../artifacts/weights_int8.bin");
    if !p.exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(read_weight_file(p).expect("int8 weight file"))
}

/// Every loaded int8 weight fits the mode and the per-layer frac bits
/// are sane.
#[test]
fn int8_file_fits_mode() {
    let Some(w) = int8_weights() else { return };
    assert_eq!(w.mode, Mode::Int8);
    for layer in &w.layers {
        assert!(layer.frac_bits <= 7, "{}: frac {}", layer.name, layer.frac_bits);
        for &q in &layer.weights {
            assert!(tetris::quant::fits_mode(q, Mode::Int8), "{}: {q}", layer.name);
        }
    }
}

/// SAC ≡ MAC over the *real* int8 trained weights (per-filter lanes).
#[test]
fn int8_sac_equals_mac_on_trained_weights() {
    let Some(w) = int8_weights() else { return };
    let mut rng = Rng::new(0x18);
    let mut unit = SacUnit::new(Mode::Int8);
    for layer in &w.layers {
        let lane_len = layer.shape[1] * layer.shape[2] * layer.shape[3];
        for f in 0..layer.shape[0].min(8) {
            let ws = layer.weights[f * lane_len..(f + 1) * lane_len].to_vec();
            let acts: Vec<i32> = (0..lane_len).map(|_| gen::activation(&mut rng)).collect();
            let lane = Lane::new(ws, acts);
            assert_eq!(
                unit.process_lane(&lane, 16),
                lane.mac_reference(),
                "{} filter {f}",
                layer.name
            );
        }
    }
}

/// The full rust int8 pipeline runs and is deterministic; outputs stay
/// in plausible logit range (no overflow wrap).
#[test]
fn int8_pipeline_runs_and_is_deterministic() {
    let Some(w) = int8_weights() else { return };
    let mut rng = Rng::new(5);
    let (img, _) = tetris::coordinator::demo::dataset_image(&mut rng);
    let mut x = img;
    let s = x.shape().to_vec();
    x.reshape(&[1, s[0], s[1], s[2]]).unwrap();
    let a = tetris::runtime::quantized::forward(&w, &x).unwrap();
    let b = tetris::runtime::quantized::forward(&w, &x).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.shape(), &[1, 4]);
    for &v in a.data() {
        assert!(v.unsigned_abs() < 1 << 28, "logit {v} suspiciously large");
    }
}

/// Int8 vs fp16 pipelines agree on argmax for dataset images (graceful
/// degradation claim of §III.C.3).
#[test]
fn int8_and_fp16_agree_on_argmax() {
    let Some(w8) = int8_weights() else { return };
    let w16 = read_weight_file(Path::new("../artifacts/weights.bin")).unwrap();
    let mut rng = Rng::new(21);
    let mut agree = 0;
    let n = 32;
    for _ in 0..n {
        let (img, _) = tetris::coordinator::demo::dataset_image(&mut rng);
        let mut x = img;
        let s = x.shape().to_vec();
        x.reshape(&[1, s[0], s[1], s[2]]).unwrap();
        let argmax = |t: &Tensor<i32>| {
            t.data().iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap()
        };
        let a8 = argmax(&tetris::runtime::quantized::forward(&w8, &x).unwrap());
        let a16 = argmax(&tetris::runtime::quantized::forward(&w16, &x).unwrap());
        agree += (a8 == a16) as usize;
    }
    assert!(
        agree * 100 >= n * 90,
        "int8/fp16 argmax agreement {agree}/{n} below 90%"
    );
}

/// Synthetic int8 populations: SAC == MAC under heavy randomization
/// (contract independent of artifacts).
#[test]
fn int8_sac_mac_property() {
    run_with(
        PropConfig { cases: 300, seed: 0x88 },
        "int8 SAC == MAC",
        |r| {
            let len = 1 + r.below(200) as usize;
            let ks = 2 + r.below(62) as usize;
            (
                Lane::random(len, r, |r| gen::weight(r, 8), |r| gen::activation(r)),
                ks,
            )
        },
        |(lane, ks)| {
            let mut unit = SacUnit::new(Mode::Int8);
            let got = unit.process_lane(lane, *ks);
            if got == lane.mac_reference() {
                Ok(())
            } else {
                Err(format!("{got} != {}", lane.mac_reference()))
            }
        },
    );
}
