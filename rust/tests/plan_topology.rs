//! ISSUE 2 tentpole tests: the declared-topology plan executor runs
//! the paper's whole evaluation zoo — AlexNet/NiN's 3×3 stride-2
//! pools, NiN's global-average head, GoogleNet's four-arm inception
//! branching — bit-identical to the naive scalar MAC interpreter of
//! the same declared schedule (`model::reference`, which shares no
//! execution code with `plan::exec`). This extends DESIGN.md invariant
//! I5 from the tiny CNN / VGG chains of `plan_exec.rs` to the full zoo
//! at scaled channel counts.
//!
//! All tests in this binary serialize on `ENV_LOCK`: the thread-count
//! test mutates the process-global `TETRIS_THREADS` variable that
//! `util::pool::par_map` reads, and glibc `setenv` racing `getenv`
//! from concurrently running tests is undefined behavior.

use std::sync::Mutex;

use tetris::config::Mode;
use tetris::model::reference::forward_reference;
use tetris::model::weights::{synthetic_loaded, DensityCalibration};
use tetris::model::{
    zoo, ConvLayer, LoadedLayer, LoadedWeights, Network, PoolKind, PoolSpec, Tensor, TopoOp,
};
use tetris::plan::CompiledNetwork;
use tetris::util::prop::gen;
use tetris::util::rng::Rng;

/// Serializes every test here (see module docs).
static ENV_LOCK: Mutex<()> = Mutex::new(());

// ---------- shared generators ----------

fn random_input(net: &Network, n: usize, hw: usize, rng: &mut Rng) -> Tensor<i32> {
    let mut x = Tensor::zeros(&[n, net.layers[0].in_c, hw, hw]);
    for v in x.data_mut() {
        *v = rng.range_i64(-512, 512) as i32;
    }
    x
}

/// Random weights for an arbitrary chain/branch net: mode-bounded
/// magnitudes, randomized per-layer frac_bits (including 0).
fn random_weights(net: &Network, mode: Mode, rng: &mut Rng) -> LoadedWeights {
    let bits = mode.weight_bits() as u32;
    let frac_choices: [u32; 4] = match mode {
        Mode::Fp16 => [0, 6, 8, 10],
        Mode::Int8 => [0, 3, 5, 7],
    };
    let layers = net
        .layers
        .iter()
        .map(|l| LoadedLayer {
            name: l.name.clone(),
            shape: [l.out_c, l.in_c, l.k, l.k],
            frac_bits: frac_choices[rng.below(4) as usize],
            weights: (0..l.weight_count()).map(|_| gen::weight(rng, bits)).collect(),
        })
        .collect();
    LoadedWeights { mode, layers }
}

// ---------- ISSUE 2 acceptance: the whole zoo, one shared plan path ----------

/// Every network of the paper's evaluation — channel-scaled so debug
/// builds stay fast, spatial sizes re-propagated through the declared
/// schedule — compiles and executes bit-identical to the naive
/// reference. This is invariant I5 over the full zoo: 3×3 stride-2
/// pools (AlexNet, NiN, GoogleNet), ceil-mode extents (GoogleNet),
/// inception branching, and NiN's global-average head all included.
#[test]
fn full_zoo_matches_naive_reference() {
    let _serial = ENV_LOCK.lock().unwrap();
    let cases: [(Network, &str, usize); 5] = [
        (zoo::alexnet().scaled(16, 64), "alexnet", 64),
        (zoo::googlenet().scaled(16, 64), "googlenet", 64),
        (zoo::vgg16().scaled(16, 32), "vgg16", 32),
        (zoo::vgg19().scaled(16, 32), "vgg19", 32),
        (zoo::nin().scaled(16, 64), "nin", 64),
    ];
    for (net, profile, hw) in cases {
        let w = synthetic_loaded(&net, Mode::Fp16, 12, profile, DensityCalibration::Fig2, 0x5EED)
            .unwrap();
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let mut rng = Rng::new(7);
        let x = random_input(&net, 2, hw, &mut rng);
        let got = plan.execute(&x).unwrap();
        let want = forward_reference(&net, &w, &x);
        assert_eq!(got.shape(), want.shape(), "{}: shape drift", net.name);
        assert_eq!(got, want, "{}: plan executor diverged from MAC reference", net.name);
        assert!(
            got.data().iter().any(|&v| v != 0),
            "{}: degenerate all-zero output",
            net.name
        );
    }
}

// ---------- satellite: Pool{Max/Avg, k=3, s=2} property test ----------

/// Two-conv chain around a 3×3 stride-2 pool (the AlexNet/NiN/GoogleNet
/// geometry, with a non-exact extent so ceil windows clip).
fn pooled_chain(kind: PoolKind) -> Network {
    Network::with_schedule(
        match kind {
            PoolKind::Max => "pool3s2_max_chain",
            PoolKind::Avg => "pool3s2_avg_chain",
        },
        vec![
            ConvLayer { name: "c1".into(), in_c: 2, out_c: 3, k: 3, stride: 1, pad: 1, in_hw: 8 },
            ConvLayer { name: "c2".into(), in_c: 3, out_c: 2, k: 3, stride: 1, pad: 1, in_hw: 4 },
        ],
        vec![
            TopoOp::Conv(0),
            TopoOp::Pool(PoolSpec { kind, k: 3, stride: 2, pad: 0 }), // 8 → 4, last window clipped
            TopoOp::Conv(1),
        ],
    )
}

/// Invariant I5 for the parameterized pool kernel: plan ≡ naive
/// reference, bit for bit, across both modes, kneading strides 4/16/64
/// and both pool kinds, on random weights and images.
#[test]
fn pool_3x3_stride2_matches_reference_across_modes_and_strides() {
    let _serial = ENV_LOCK.lock().unwrap();
    for kind in [PoolKind::Max, PoolKind::Avg] {
        let net = pooled_chain(kind);
        for mode in [Mode::Fp16, Mode::Int8] {
            for ks in [4usize, 16, 64] {
                for seed in [1u64, 2] {
                    let mut rng = Rng::new(0xF00D ^ seed ^ ((ks as u64) << 8));
                    let w = random_weights(&net, mode, &mut rng);
                    let x = random_input(&net, 2, 8, &mut rng);
                    let plan = CompiledNetwork::compile(&net, &w, ks, mode).unwrap();
                    let got = plan.execute(&x).unwrap();
                    let want = forward_reference(&net, &w, &x);
                    assert_eq!(got, want, "{kind:?} {mode} ks={ks} seed={seed}");
                }
            }
        }
    }
}

// ---------- satellite: Branch/Concat property test ----------

/// Invariant I5 for branch/concat execution: a standalone inception
/// module (stem → four arms → channel concat) is bit-identical to the
/// naive reference across modes and kneading strides.
#[test]
fn inception_branch_matches_reference_across_modes_and_strides() {
    let _serial = ENV_LOCK.lock().unwrap();
    let net = zoo::inception_module("3a").unwrap().scaled(8, 8);
    for mode in [Mode::Fp16, Mode::Int8] {
        for ks in [4usize, 16, 64] {
            for seed in [1u64, 2] {
                let mut rng = Rng::new(0xB7A ^ seed ^ ((ks as u64) << 8));
                let w = random_weights(&net, mode, &mut rng);
                let x = random_input(&net, 2, 8, &mut rng);
                let plan = CompiledNetwork::compile(&net, &w, ks, mode).unwrap();
                let got = plan.execute(&x).unwrap();
                let want = forward_reference(&net, &w, &x);
                // Concat order is part of the contract: 1x1 | 3x3 |
                // 5x5 | pool_proj channels, in arm order.
                assert_eq!(got.shape(), want.shape());
                assert_eq!(got, want, "{mode} ks={ks} seed={seed}");
            }
        }
    }
}

/// Thread count must never change logits on branching + strided-pool
/// topologies: `par_map`'s striped assignment is order-deterministic
/// and branch arms run in a fixed sequence.
#[test]
fn thread_count_does_not_change_branching_outputs() {
    let _serial = ENV_LOCK.lock().unwrap();
    // Divisor 16 keeps every inception concat sum consistent (all of
    // GoogleNet's branch output counts are multiples of 16).
    let net = zoo::googlenet().scaled(16, 64);
    let w = synthetic_loaded(&net, Mode::Fp16, 12, "googlenet", DensityCalibration::Fig2, 3)
        .unwrap();
    let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
    let mut rng = Rng::new(11);
    let x = random_input(&net, 2, 64, &mut rng);
    std::env::set_var("TETRIS_THREADS", "1");
    let single = plan.execute(&x).unwrap();
    std::env::set_var("TETRIS_THREADS", "8");
    let eight = plan.execute(&x).unwrap();
    std::env::remove_var("TETRIS_THREADS");
    let free = plan.execute(&x).unwrap();
    assert_eq!(single, eight);
    assert_eq!(single, free);
}

/// Executing a scaled plan at a spatial size other than the declared
/// one still works (the executor derives extents from the tensor) and
/// still matches the reference — pools and branches included.
#[test]
fn off_topology_spatial_sizes_still_match_reference() {
    let _serial = ENV_LOCK.lock().unwrap();
    let net = zoo::alexnet().scaled(16, 64);
    let w = synthetic_loaded(&net, Mode::Fp16, 12, "alexnet", DensityCalibration::Fig2, 9)
        .unwrap();
    let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
    let mut rng = Rng::new(13);
    // Declared 64×64; run 80×80.
    let x = random_input(&net, 1, 80, &mut rng);
    let got = plan.execute(&x).unwrap();
    let want = forward_reference(&net, &w, &x);
    assert_eq!(got, want);
}
