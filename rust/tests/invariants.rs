//! Cross-module property tests of the DESIGN.md invariants I1–I4.

use tetris::config::Mode;
use tetris::kneading::{knead_group, knead_lane, unknead_group, Lane};
use tetris::quant::popcount_per_position;
use tetris::sac::SacUnit;
use tetris::util::prop::{self, gen, PropConfig};
use tetris::util::rng::Rng;

fn lane_like_conv(r: &mut Rng, bits: u32) -> Lane {
    // Conv-lane shapes: in_c·k² for k ∈ {1,3,5,7,11}, small channel counts.
    let k = *r.choose(&[1usize, 3, 5, 7, 11]);
    let in_c = 1 + r.below(8) as usize;
    let len = in_c * k * k;
    Lane::random(len, r, |r| gen::weight(r, bits), |r| gen::activation(r))
}

/// I1 — kneading is lossless for conv-shaped lanes at every stride.
#[test]
fn i1_kneading_lossless_at_scale() {
    prop::run_with(
        PropConfig { cases: 300, seed: 0x11 },
        "unknead(knead(lane)) == lane",
        |r| {
            let ks = 2 + r.below(63) as usize;
            (lane_like_conv(r, 16), ks)
        },
        |(lane, ks)| {
            let kneaded = knead_lane(lane, *ks, Mode::Fp16);
            let mut rebuilt = Vec::new();
            for g in &kneaded.groups {
                rebuilt.extend(unknead_group(g, Mode::Fp16));
            }
            if rebuilt == lane.weights {
                Ok(())
            } else {
                Err("weights not reconstructed".into())
            }
        },
    );
}

/// I1b — every essential bit appears exactly once across kneaded slots.
#[test]
fn i1_every_essential_bit_exactly_once() {
    prop::run_with(
        PropConfig { cases: 200, seed: 0x12 },
        "slot multiset == essential bit multiset",
        |r| gen::vec_of(r, 1, 64, |r| gen::weight(r, 16)),
        |ws| {
            let g = knead_group(ws, Mode::Fp16);
            let mut seen = vec![0u32; ws.len()];
            for kw in &g.kneaded {
                for (b, &slot) in kw.slots().iter().enumerate() {
                    if slot != tetris::kneading::EMPTY_SLOT {
                        seen[slot as usize] |= 1 << b;
                    }
                }
            }
            for (i, &w) in ws.iter().enumerate() {
                if seen[i] != w.unsigned_abs() & 0xFFFF {
                    return Err(format!("weight {i} bits {:#x} != seen {:#x}", w, seen[i]));
                }
            }
            Ok(())
        },
    );
}

/// I2/I3 — kneaded SAC == MAC for conv-shaped lanes, both modes, many KS.
#[test]
fn i2_sac_equals_mac_conv_lanes() {
    for mode in [Mode::Fp16, Mode::Int8] {
        let bits = mode.weight_bits() as u32;
        prop::run_with(
            PropConfig { cases: 200, seed: 0x13 ^ bits as u64 },
            "SAC == MAC",
            |r| {
                let ks = 2 + r.below(31) as usize;
                (lane_like_conv(r, bits), ks)
            },
            |(lane, ks)| {
                let mut unit = SacUnit::new(mode);
                let sac = unit.process_lane(lane, *ks);
                if sac == lane.mac_reference() {
                    Ok(())
                } else {
                    Err(format!("SAC {sac} != MAC {}", lane.mac_reference()))
                }
            },
        );
    }
}

/// I4 — kneaded length equals the max per-bit popcount bound, per group;
/// and kneading never expands a lane.
#[test]
fn i4_kneaded_length_bound() {
    prop::run_with(
        PropConfig { cases: 300, seed: 0x14 },
        "kneaded length == Σ max-popcount ≤ source",
        |r| {
            let ks = 2 + r.below(31) as usize;
            (gen::vec_of(r, 1, 256, |r| gen::weight(r, 16)), ks)
        },
        |(ws, ks)| {
            let lane = Lane::new(ws.clone(), vec![0; ws.len()]);
            let kneaded = knead_lane(&lane, *ks, Mode::Fp16);
            let expect: usize = ws
                .chunks(*ks)
                .map(|c| *popcount_per_position(c, 16).iter().max().unwrap() as usize)
                .sum();
            if kneaded.kneaded_len() != expect {
                return Err(format!("kneaded {} != bound {expect}", kneaded.kneaded_len()));
            }
            if kneaded.kneaded_len() > ws.len() {
                return Err("kneading expanded the lane".into());
            }
            Ok(())
        },
    );
}

/// Monotonicity: larger KS never yields more kneaded weights (on the
/// same lane) when strides nest (ks and 2ks).
#[test]
fn nesting_strides_monotone() {
    prop::run_with(
        PropConfig { cases: 150, seed: 0x15 },
        "kneaded(2ks) <= kneaded(ks)",
        |r| {
            let ks = 2 + r.below(16) as usize;
            (gen::vec_of(r, 2, 256, |r| gen::weight(r, 16)), ks)
        },
        |(ws, ks)| {
            let lane = Lane::new(ws.clone(), vec![0; ws.len()]);
            let a = knead_lane(&lane, *ks, Mode::Fp16).kneaded_len();
            let b = knead_lane(&lane, 2 * ks, Mode::Fp16).kneaded_len();
            if b <= a {
                Ok(())
            } else {
                Err(format!("ks={ks}: {a} → 2ks: {b}"))
            }
        },
    );
}
