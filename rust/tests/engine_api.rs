//! ISSUE 4 acceptance: the unified `engine` façade is
//! behavior-preserving and multi-model.
//!
//! Pins: (a) engine-served logits are bit-exact vs the legacy
//! `Server::start_shared` path and vs direct plan execution across the
//! (scaled) zoo, (b) one knead per lane per registered model under W
//! workers, (c) two models served concurrently from one engine without
//! cross-talk, plus builder/env-fallback behavior.
//!
//! All tests serialize on `SERIAL`: the knead counter
//! (`kneading::knead_call_count`) is process-wide, and the env-fallback
//! test mutates process environment (glibc `setenv` racing `getenv`
//! from concurrent tests is undefined behavior).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

use tetris::config::Mode;
use tetris::coordinator::{BatchPolicy, SacBackend, Server, ServerConfig};
use tetris::coordinator::{InferRequest, InferResponse};
use tetris::engine::{BackendKind, Engine};
use tetris::kneading::knead_call_count;
use tetris::model::weights::{synthetic_loaded, DensityCalibration};
use tetris::model::{zoo, Network, Tensor};
use tetris::plan::CompiledNetwork;
use tetris::util::rng::Rng;

/// Serializes every test here (see module docs).
static SERIAL: Mutex<()> = Mutex::new(());

fn tiny_image(rng: &mut Rng) -> Tensor<i32> {
    image_for(rng, 1, 16)
}

fn image_for(rng: &mut Rng, c: usize, hw: usize) -> Tensor<i32> {
    let mut t = Tensor::zeros(&[c, hw, hw]);
    for v in t.data_mut() {
        *v = rng.range_i64(-400, 400) as i32;
    }
    t
}

/// The scaled evaluation zoo (same scaling plan_topology pins I5 with).
fn scaled_zoo() -> Vec<(Network, &'static str, usize)> {
    vec![
        (zoo::alexnet().scaled(16, 64), "alexnet", 64),
        (zoo::googlenet().scaled(16, 64), "googlenet", 64),
        (zoo::vgg16().scaled(16, 32), "vgg16", 32),
        (zoo::vgg19().scaled(16, 32), "vgg19", 32),
        (zoo::nin().scaled(16, 64), "nin", 64),
    ]
}

/// (a) Engine logits ≡ legacy `Server::start_shared` logits on the
/// tiny CNN, request for request.
#[test]
fn engine_matches_legacy_shared_server() {
    let _serial = SERIAL.lock().unwrap();
    let weights = SacBackend::synthetic_weights(33).unwrap();
    let total = 17u64;

    // Legacy path.
    let server = Server::start_shared(
        ServerConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            workers: 2,
        },
        SacBackend::new(weights.clone()).unwrap(),
    )
    .unwrap();
    let mut rng = Rng::new(12);
    let images: Vec<Tensor<i32>> = (0..total).map(|_| tiny_image(&mut rng)).collect();
    for (id, img) in images.iter().enumerate() {
        server.submit(InferRequest::new(id as u64, img.clone())).unwrap();
    }
    let mut legacy: HashMap<u64, InferResponse> = HashMap::new();
    for _ in 0..total {
        let r = server.recv().unwrap();
        legacy.insert(r.id, r);
    }
    server.shutdown();

    // Engine path, same weights and images.
    let engine = Engine::builder()
        .workers(2)
        .max_batch(4)
        .max_wait(Duration::from_millis(1))
        .register("tiny", zoo::tiny_cnn(), weights)
        .build()
        .unwrap();
    let session = engine.session();
    let responses = session.infer_batch("tiny", &images).unwrap();
    for (i, resp) in responses.iter().enumerate() {
        let want = &legacy[&(i as u64)];
        assert_eq!(resp.logits, want.logits, "request {i} diverged from legacy path");
        assert_eq!(resp.argmax, want.argmax);
    }
    let metrics = engine.shutdown();
    assert_eq!(metrics.requests_done, total);
}

/// (a) Across the whole scaled zoo: engine-served logits are bit-exact
/// vs the old `Server::start_shared` path over the same plan AND vs an
/// independently compiled plan executed directly (I5 carries the chain
/// back to the scalar reference).
#[test]
fn engine_serves_zoo_bit_exact() {
    let _serial = SERIAL.lock().unwrap();
    for (net, profile, hw) in scaled_zoo() {
        let w = synthetic_loaded(&net, Mode::Fp16, 9, profile, DensityCalibration::Fig2, 21)
            .unwrap();
        let engine = Engine::builder()
            .workers(2)
            .max_batch(2)
            .max_wait(Duration::from_micros(200))
            .register(net.name.clone(), net.clone(), w.clone())
            .build()
            .unwrap();
        let session = engine.session();
        let mut rng = Rng::new(77);
        let images: Vec<Tensor<i32>> =
            (0..2).map(|_| image_for(&mut rng, net.layers[0].in_c, hw)).collect();
        let responses = session.infer_batch(&net.name, &images).unwrap();
        engine.shutdown();

        // Old path: Server::start_shared over the same compiled plan.
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let server = Server::start_shared(
            ServerConfig {
                policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(200) },
                workers: 2,
            },
            SacBackend::from_parts(std::sync::Arc::new(plan.clone()), 1),
        )
        .unwrap();
        for (id, img) in images.iter().enumerate() {
            server.submit(InferRequest::new(id as u64, img.clone())).unwrap();
        }
        let mut legacy: HashMap<u64, InferResponse> = HashMap::new();
        for _ in 0..images.len() {
            let r = server.recv().unwrap();
            legacy.insert(r.id, r);
        }
        server.shutdown();

        for (i, img) in images.iter().enumerate() {
            // Engine ≡ legacy server path.
            assert_eq!(
                responses[i].logits,
                legacy[&(i as u64)].logits,
                "{}: engine output diverged from the legacy Server path",
                net.name
            );
            // Engine ≡ direct plan execution.
            let mut x = img.clone();
            let s = x.shape().to_vec();
            x.reshape(&[1, s[0], s[1], s[2]]).unwrap();
            let want = plan.execute(&x).unwrap();
            assert_eq!(
                responses[i].logits[..],
                want.data()[..],
                "{}: engine output diverged from direct plan execution",
                net.name
            );
        }
    }
}

/// (b) One knead per lane per registered model, independent of the
/// worker count: building an engine with two models and W workers
/// costs exactly the two solo compiles' worth of knead calls, and
/// serving adds zero more.
#[test]
fn one_knead_per_lane_per_model_under_workers() {
    let _serial = SERIAL.lock().unwrap();
    let tiny_w = SacBackend::synthetic_weights(5).unwrap();
    let nin = zoo::nin().scaled(32, 64);
    let nin_w =
        synthetic_loaded(&nin, Mode::Fp16, 9, "nin", DensityCalibration::Fig2, 6).unwrap();

    // Measure each model's solo registration cost in knead calls
    // (plan compile + the cycle simulation's sampled-lane kneading),
    // via single-model engines with ONE worker.
    let solo_cost = |name: &str, net: &Network, w| {
        let before = knead_call_count();
        let engine =
            Engine::builder().workers(1).register(name, net.clone(), w).build().unwrap();
        engine.shutdown();
        knead_call_count() - before
    };
    let tiny_cost = solo_cost("tiny", &zoo::tiny_cnn(), tiny_w.clone());
    let nin_cost = solo_cost("nin", &nin, nin_w.clone());
    assert!(tiny_cost > 0 && nin_cost > 0, "registration must knead");

    // Engine build with 4 workers: exactly one compile per model.
    let workers = 4;
    let before_build = knead_call_count();
    let engine = Engine::builder()
        .workers(workers)
        .max_batch(3)
        .max_wait(Duration::from_micros(200))
        .register("tiny", zoo::tiny_cnn(), tiny_w)
        .register("nin", nin.clone(), nin_w)
        .build()
        .unwrap();
    let after_build = knead_call_count();
    assert_eq!(
        after_build - before_build,
        tiny_cost + nin_cost,
        "{workers} workers must share one compile per registered model"
    );

    // Serving both models kneads nothing further.
    let session = engine.session();
    let mut rng = Rng::new(3);
    let mut tickets = Vec::new();
    for i in 0..4 * workers {
        tickets.push(if i % 2 == 0 {
            session.submit("tiny", tiny_image(&mut rng)).unwrap()
        } else {
            session.submit("nin", image_for(&mut rng, nin.layers[0].in_c, 64)).unwrap()
        });
    }
    for t in &tickets {
        session.wait(t).unwrap();
    }
    let metrics = engine.shutdown();
    assert_eq!(metrics.requests_done, 4 * workers as u64);
    assert_eq!(
        knead_call_count(),
        after_build,
        "serving path re-kneaded after engine build"
    );
}

/// (c) Two models served concurrently from ONE engine produce exactly
/// the logits each produces when served alone — no cross-talk through
/// the shared pool, batcher, or response routing.
#[test]
fn two_models_interleaved_without_crosstalk() {
    let _serial = SERIAL.lock().unwrap();
    let tiny_w = SacBackend::synthetic_weights(8).unwrap();
    let inception = zoo::inception_module("3a").unwrap().scaled(8, 8);
    let inc_w =
        synthetic_loaded(&inception, Mode::Fp16, 9, "googlenet", DensityCalibration::Fig2, 2)
            .unwrap();

    let mut rng = Rng::new(41);
    let tiny_imgs: Vec<Tensor<i32>> = (0..6).map(|_| tiny_image(&mut rng)).collect();
    let inc_imgs: Vec<Tensor<i32>> =
        (0..6).map(|_| image_for(&mut rng, inception.layers[0].in_c, 8)).collect();

    // Single-model baselines.
    let solo = |name: &str, net: &Network, w, imgs: &[Tensor<i32>]| {
        let engine = Engine::builder()
            .workers(2)
            .max_batch(4)
            .max_wait(Duration::from_micros(200))
            .register(name, net.clone(), w)
            .build()
            .unwrap();
        let out: Vec<Vec<i32>> = engine
            .session()
            .infer_batch(name, imgs)
            .unwrap()
            .into_iter()
            .map(|r| r.logits)
            .collect();
        engine.shutdown();
        out
    };
    let tiny_solo = solo("tiny", &zoo::tiny_cnn(), tiny_w.clone(), &tiny_imgs);
    let inc_solo = solo("inc", &inception, inc_w.clone(), &inc_imgs);

    // One engine, both models, interleaved submissions.
    let engine = Engine::builder()
        .workers(3)
        .max_batch(4)
        .max_wait(Duration::from_micros(200))
        .register("tiny", zoo::tiny_cnn(), tiny_w)
        .register("inc", inception.clone(), inc_w)
        .build()
        .unwrap();
    assert_eq!(engine.models().len(), 2);
    let session = engine.session();
    let mut tickets = Vec::new();
    for i in 0..6 {
        tickets.push(("tiny", i, session.submit("tiny", tiny_imgs[i].clone()).unwrap()));
        tickets.push(("inc", i, session.submit("inc", inc_imgs[i].clone()).unwrap()));
    }
    for (model, i, ticket) in &tickets {
        let resp = session.wait(ticket).unwrap();
        let want = if *model == "tiny" { &tiny_solo[*i] } else { &inc_solo[*i] };
        assert_eq!(
            &resp.logits, want,
            "{model} image {i}: multi-model serving changed the logits"
        );
    }
    let metrics = engine.shutdown();
    assert_eq!(metrics.requests_done, 12);
    assert!(metrics.latency_percentiles().is_some());
}

/// Builder options override env fallbacks, and the memory budget
/// drives each model's fused tile height exactly like
/// `tile_rows_for_budget`.
#[test]
fn builder_options_resolve_budget_and_tiles() {
    let _serial = SERIAL.lock().unwrap();
    let w = SacBackend::synthetic_weights(13).unwrap();

    // Explicit tile height wins over everything.
    let engine = Engine::builder()
        .workers(2)
        .tile_rows(2)
        .register("tiny", zoo::tiny_cnn(), w.clone())
        .build()
        .unwrap();
    assert_eq!(engine.models()[0].plan().unwrap().tile_rows, 2);
    engine.shutdown();

    // Typed budget resolves to the same tile height the plan picks.
    let budget_mb = 64u64;
    let engine = Engine::builder()
        .workers(2)
        .mem_budget_mb(budget_mb)
        .register("tiny", zoo::tiny_cnn(), w.clone())
        .build()
        .unwrap();
    let plan = engine.models()[0].plan().unwrap().clone();
    assert_eq!(
        plan.tile_rows,
        plan.tile_rows_for_budget(budget_mb * 1024 * 1024, engine.workers())
    );
    engine.shutdown();

    // Env fallback: a typed option beats a (valid) env value; with no
    // option, env::mem_budget_mb picks the env value up.
    std::env::set_var("TETRIS_MEM_BUDGET_MB", "7");
    assert_eq!(tetris::engine::env::mem_budget_mb(), 7);
    std::env::set_var("TETRIS_MEM_BUDGET_MB", "not-a-number");
    assert_eq!(
        tetris::engine::env::mem_budget_mb(),
        tetris::engine::env::DEFAULT_MEM_BUDGET_MB,
        "unparsable env value must fall back to the documented default"
    );
    std::env::remove_var("TETRIS_MEM_BUDGET_MB");
}

/// Error surface: unknown models, wrong channel counts, empty
/// registries, duplicate names — all typed errors, not hangs.
#[test]
fn engine_rejects_bad_configurations_and_submissions() {
    let _serial = SERIAL.lock().unwrap();
    // No models.
    assert!(Engine::builder().build().is_err());
    // Duplicate registration.
    let w = SacBackend::synthetic_weights(1).unwrap();
    assert!(Engine::builder()
        .register("tiny", zoo::tiny_cnn(), w.clone())
        .register("tiny", zoo::tiny_cnn(), w.clone())
        .build()
        .is_err());
    // Zero max_batch.
    assert!(Engine::builder()
        .max_batch(0)
        .register("tiny", zoo::tiny_cnn(), w.clone())
        .build()
        .is_err());

    let engine = Engine::builder()
        .workers(1)
        .register("tiny", zoo::tiny_cnn(), w)
        .build()
        .unwrap();
    let session = engine.session();
    // Unknown model name.
    assert!(session.submit("resnet50", Tensor::zeros(&[1, 16, 16])).is_err());
    // Wrong channel count (engine validates instead of hanging).
    assert!(session.submit("tiny", Tensor::zeros(&[3, 16, 16])).is_err());
    // Wrong spatial size — a worker-side failure would silently drop
    // the whole co-batched request set, so submit must reject it.
    assert!(session.submit("tiny", Tensor::zeros(&[1, 20, 20])).is_err());
    // Wrong rank.
    assert!(session.submit("tiny", Tensor::zeros(&[16, 16])).is_err());
    // Double redeem errors immediately instead of hanging forever.
    let mut rng = Rng::new(2);
    let ticket = session.submit("tiny", tiny_image(&mut rng)).unwrap();
    session.wait(&ticket).unwrap();
    assert!(session.wait(&ticket).is_err(), "double redeem must error");
    assert!(session.poll(&ticket).is_err());
    // Submissions after shutdown fail fast.
    engine.shutdown();
    assert!(session.submit("tiny", tiny_image(&mut rng)).is_err());
}

/// ISSUE 5: VGG-16 with fc6–8 weights serves **image → logits** end
/// to end through the engine — the served logits are bit-exact vs the
/// reference interpreter's naive FC chain (I5 extended to
/// logits-after-fc), and the model's meta reports per-head simulated
/// cycles folded into the per-image total.
#[test]
fn engine_serves_vgg16_classifier_heads_end_to_end() {
    use tetris::model::reference::forward_reference;
    use tetris::model::weights::synthetic_loaded_with_heads;
    let _serial = SERIAL.lock().unwrap();
    let net = zoo::vgg16().scaled(16, 32);
    let w = synthetic_loaded_with_heads(&net, Mode::Fp16, 10, "vgg16", DensityCalibration::Fig2, 6)
        .unwrap();
    let engine = Engine::builder()
        .workers(2)
        .max_batch(2)
        .max_wait(Duration::from_micros(200))
        .register("vgg16", net.clone(), w.clone())
        .build()
        .unwrap();
    let meta = &engine.models()[0];
    assert_eq!(meta.head_cycles().len(), 3, "fc6–8 must report cycles");
    assert!(meta.head_cycles().iter().all(|(_, c)| *c > 0));
    let head_sum: u64 = meta.head_cycles().iter().map(|(_, c)| c).sum();
    assert!(
        meta.cycles_per_image() > head_sum,
        "per-image cycles must include trunk + heads"
    );
    assert_eq!(meta.head_cycles()[0].0, "fc6");

    let session = engine.session();
    let mut rng = Rng::new(61);
    let images: Vec<Tensor<i32>> =
        (0..2).map(|_| image_for(&mut rng, net.layers[0].in_c, 32)).collect();
    let responses = session.infer_batch("vgg16", &images).unwrap();
    engine.shutdown();

    for (i, img) in images.iter().enumerate() {
        let mut x = img.clone();
        let s = x.shape().to_vec();
        x.reshape(&[1, s[0], s[1], s[2]]).unwrap();
        let want = forward_reference(&net, &w, &x);
        assert_eq!(want.shape(), &[1, 1000], "reference must reach the logits");
        assert_eq!(
            responses[i].logits[..],
            want.data()[..],
            "image {i}: served logits diverged from the reference FC chain"
        );
    }
}

/// ISSUE 6: the builder's walk pin reaches the compiled plan and the
/// model meta; a pipelined-pinned engine serves the same logits as the
/// default policy; and a memory budget too small for even the
/// streaming walk makes compilation fall over to the pipelined walk
/// on its own (whole-network streaming, depth-independent peak).
#[test]
fn builder_walk_pin_and_budget_fallover_pick_the_pipelined_walk() {
    use tetris::plan::Walk;
    let _serial = SERIAL.lock().unwrap();
    let w = SacBackend::synthetic_weights(19).unwrap();
    let mut rng = Rng::new(29);
    let images: Vec<Tensor<i32>> = (0..5).map(|_| tiny_image(&mut rng)).collect();

    // Default policy: nothing pinned, nothing surfaced.
    let engine = Engine::builder()
        .workers(2)
        .register("tiny", zoo::tiny_cnn(), w.clone())
        .build()
        .unwrap();
    assert_eq!(engine.models()[0].walk(), None);
    let want: Vec<Vec<i32>> = engine
        .session()
        .infer_batch("tiny", &images)
        .unwrap()
        .into_iter()
        .map(|r| r.logits)
        .collect();
    engine.shutdown();

    // Pinned pipelined walk: surfaced in meta + plan, logits identical.
    let engine = Engine::builder()
        .workers(2)
        .walk(Walk::Pipelined)
        .register("tiny", zoo::tiny_cnn(), w)
        .build()
        .unwrap();
    let meta = &engine.models()[0];
    assert_eq!(meta.walk(), Some(Walk::Pipelined));
    assert_eq!(meta.plan().unwrap().walk_hint, Some(Walk::Pipelined));
    let got = engine.session().infer_batch("tiny", &images).unwrap();
    for (i, resp) in got.iter().enumerate() {
        assert_eq!(
            resp.logits, want[i],
            "image {i}: pinned pipelined walk changed the logits"
        );
    }
    engine.shutdown();

    // Budget-demanded fallover: at full 224² resolution the first
    // conv pair of (scaled) VGG-16 alone holds ~1.4 MB of in+out
    // maps, so no tile height fits the per-segment walks into 1 MiB —
    // compilation must pin the pipelined walk without being asked and
    // size its tile with the pipelined estimator.
    let net = zoo::vgg16().scaled(16, 224);
    let vw =
        synthetic_loaded(&net, Mode::Fp16, 9, "vgg16", DensityCalibration::Fig2, 31).unwrap();
    let engine = Engine::builder()
        .workers(1)
        .mem_budget_mb(1)
        .register("vgg16", net, vw)
        .build()
        .unwrap();
    let meta = &engine.models()[0];
    assert_eq!(
        meta.walk(),
        Some(Walk::Pipelined),
        "1 MiB cannot hold a 224² segment map — compile must fall over"
    );
    let plan = meta.plan().unwrap();
    assert_eq!(
        plan.tile_rows,
        plan.tile_rows_for_budget_walk(1024 * 1024, 1, Walk::Pipelined)
    );
    engine.shutdown();
}

/// Session metrics surface exact latency percentiles once requests
/// complete.
#[test]
fn session_metrics_expose_percentiles() {
    let _serial = SERIAL.lock().unwrap();
    let engine = Engine::builder()
        .workers(2)
        .max_batch(4)
        .max_wait(Duration::from_micros(100))
        .register("tiny", zoo::tiny_cnn(), SacBackend::synthetic_weights(3).unwrap())
        .build()
        .unwrap();
    let session = engine.session();
    assert!(session.metrics().latency_percentiles().is_none());
    let mut rng = Rng::new(4);
    let images: Vec<Tensor<i32>> = (0..9).map(|_| tiny_image(&mut rng)).collect();
    session.infer_batch("tiny", &images).unwrap();
    let m = session.metrics();
    let p = m.latency_percentiles().expect("served requests must yield percentiles");
    assert!(p.p50_us > 0.0);
    assert!(p.p50_us <= p.p95_us && p.p95_us <= p.p99_us);
    assert_eq!(m.requests_done, 9);
    engine.shutdown();
}

/// ISSUE 8: the builder's `skip_zero_activations` toggle reaches the
/// compiled plan, the served logits stay bit-exact vs a skip-off
/// engine (I5 — skipping a zero operand changes cycles, never
/// logits), and the skip counters surface in
/// `InferSession::metrics()` alongside the latency percentiles.
#[test]
fn skip_armed_engine_is_bit_exact_and_surfaces_counters() {
    let _serial = SERIAL.lock().unwrap();
    let w = SacBackend::synthetic_weights(23).unwrap();
    let mut rng = Rng::new(57);
    let images: Vec<Tensor<i32>> = (0..8).map(|_| tiny_image(&mut rng)).collect();

    let engine = Engine::builder()
        .workers(2)
        .max_batch(4)
        .max_wait(Duration::from_micros(200))
        .register("tiny", zoo::tiny_cnn(), w.clone())
        .build()
        .unwrap();
    let want: Vec<Vec<i32>> = engine
        .session()
        .infer_batch("tiny", &images)
        .unwrap()
        .into_iter()
        .map(|r| r.logits)
        .collect();
    let off = engine.shutdown();
    assert_eq!(off.total_windows, 0, "skip-off engines must not report skip counters");

    let engine = Engine::builder()
        .workers(2)
        .max_batch(4)
        .max_wait(Duration::from_micros(200))
        .skip_zero_activations(true)
        .register("tiny", zoo::tiny_cnn(), w)
        .build()
        .unwrap();
    assert!(
        engine.models()[0].plan().unwrap().skip_zero_activations,
        "builder toggle must reach the compiled plan"
    );
    let session = engine.session();
    let responses = session.infer_batch("tiny", &images).unwrap();
    for (i, resp) in responses.iter().enumerate() {
        assert_eq!(resp.logits, want[i], "image {i}: skip lane changed the logits");
    }
    let m = session.metrics();
    assert!(m.total_windows > 0, "skip-armed serving must count conv windows");
    assert!(m.skipped_windows_total <= m.total_windows);
    assert!((0.0..=1.0).contains(&m.window_skip_fraction()));
    assert!(m.render().contains("activation skip"), "{}", m.render());
    assert!(m.latency_percentiles().is_some());
    engine.shutdown();
}

/// The PJRT backend kind goes through the same constructor path and
/// fails fast (typed error) when the runtime is not compiled in —
/// callers never branch on backend type, even to handle its absence.
#[cfg(not(all(feature = "xla", feature = "xla-vendored")))]
#[test]
fn pjrt_backend_kind_fails_fast_without_runtime() {
    let _serial = SERIAL.lock().unwrap();
    match Engine::builder().backend(BackendKind::Pjrt).build() {
        Err(tetris::Error::Xla(msg)) => assert!(msg.contains("xla"), "{msg}"),
        Err(other) => panic!("expected Xla error, got {other}"),
        Ok(_) => panic!("stub build must not construct a PJRT engine"),
    }
    // Registering networks on a PJRT engine is a typed config error.
    let w = SacBackend::synthetic_weights(1).unwrap();
    match Engine::builder()
        .backend(BackendKind::Pjrt)
        .register("tiny", zoo::tiny_cnn(), w)
        .build()
    {
        Err(tetris::Error::Config(msg)) => assert!(msg.contains("SAC-only"), "{msg}"),
        other => panic!("expected Config error, got {:?}", other.map(|_| ())),
    }
}
