//! Fine-grained throttle-buffer/pass-mark micro-simulation
//! cross-validating the analytic Tetris cycle model (sim::tetris) on a
//! small workload: the event-level cycle count must track the analytic
//! kneaded-weight count within the modeled overheads.

use tetris::config::Mode;
use tetris::kneading::{knead_lane, Lane};
use tetris::model::weights::{profile_with, DensityCalibration};
use tetris::sim::throttle::{Entry, PassDetector, ThrottleBuffer};
use tetris::util::rng::Rng;

/// Event-level simulation of one SAC unit: `n_splitters` streams with
/// refill bandwidth, pass-mark synchronization, one kneaded weight per
/// splitter per cycle. Returns total cycles.
fn microsim(lanes: &[Lane], ks: usize, n_splitters: usize, bandwidth: usize) -> u64 {
    let mut buffers: Vec<ThrottleBuffer> =
        (0..n_splitters).map(|_| ThrottleBuffer::new(64, 4)).collect();
    // Distribute lanes round-robin across splitters.
    let mut lanes_per_splitter = vec![0usize; n_splitters];
    for (i, lane) in lanes.iter().enumerate() {
        let k = knead_lane(lane, ks, Mode::Fp16);
        buffers[i % n_splitters].push_lane(&k);
        lanes_per_splitter[i % n_splitters] += 1;
    }
    let mut detector = PassDetector::new(n_splitters);
    let mut done = vec![false; n_splitters];
    let mut cycle: u64 = 0;
    let mut drains = 0u64;
    let mut rr = 0usize; // round-robin refill pointer (shared eDRAM port)
    loop {
        // Refill phase: `bandwidth` entries per cycle total, shared
        // across all splitter streams (the eDRAM port model).
        for _ in 0..bandwidth {
            buffers[rr % n_splitters].refill(cycle, 1);
            rr += 1;
        }
        // Each splitter consumes one entry per cycle.
        for (i, b) in buffers.iter_mut().enumerate() {
            if done[i] {
                detector.mark(i);
                continue;
            }
            match b.pop(cycle) {
                Some(Entry::Kneaded) => {}
                Some(Entry::PassMark) => {
                    detector.mark(i);
                    if b.pending() == 0 {
                        done[i] = true;
                    }
                }
                None => {
                    if b.pending() == 0 {
                        done[i] = true;
                        detector.mark(i);
                    }
                }
            }
        }
        if detector.all_passed() {
            drains += 1;
        }
        cycle += 1;
        if done.iter().all(|&d| d) {
            break;
        }
        assert!(cycle < 10_000_000, "microsim runaway");
    }
    let _ = drains;
    cycle
}

#[test]
fn microsim_tracks_analytic_cycles() {
    let profile = profile_with("alexnet", Mode::Fp16, DensityCalibration::Fig2).unwrap();
    let mut rng = Rng::new(77);
    let n_splitters = 16;
    let lanes: Vec<Lane> = (0..n_splitters * 4)
        .map(|_| {
            let ws = profile.generate(128, &mut rng);
            Lane::new(ws, vec![1; 128])
        })
        .collect();
    // Analytic bound: total kneaded weights / splitters.
    let total_kneaded: usize = lanes
        .iter()
        .map(|l| knead_lane(l, 16, Mode::Fp16).kneaded_len())
        .sum();
    let analytic = (total_kneaded as f64 / n_splitters as f64).ceil() as u64;

    // Generous bandwidth → compute-bound: event sim within 20% + pass
    // overhead of the analytic count.
    let cycles = microsim(&lanes, 16, n_splitters, 64);
    assert!(
        cycles >= analytic,
        "event sim {cycles} can't beat the analytic bound {analytic}"
    );
    let overhead = cycles as f64 / analytic as f64;
    assert!(
        overhead < 1.25,
        "event sim {cycles} vs analytic {analytic}: overhead {overhead:.2} too large"
    );
}

#[test]
fn starved_bandwidth_stalls_microsim() {
    let profile = profile_with("vgg16", Mode::Fp16, DensityCalibration::Fig2).unwrap();
    let mut rng = Rng::new(3);
    let lanes: Vec<Lane> = (0..16)
        .map(|_| Lane::new(profile.generate(64, &mut rng), vec![1; 64]))
        .collect();
    let fast = microsim(&lanes, 16, 16, 64);
    let slow = microsim(&lanes, 16, 16, 1); // 1 entry/cycle for 16 splitters
    assert!(
        slow > fast * 3,
        "bandwidth starvation must dominate: fast {fast}, slow {slow}"
    );
}
