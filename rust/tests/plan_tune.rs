//! ISSUE 7 tentpole tests: the schedule auto-tuner (`plan::tune`) and
//! its roofline cost model (`plan::cost`), validated against ground
//! truth.
//!
//! Pinned here:
//! * **peak bracket** — across the zoo × walks × tile heights ×
//!   budgets, `execute_traced`'s measured peak bytes and the cost
//!   model's predicted peak bracket each other within
//!   `PEAK_BRACKET_FACTOR` on both sides (a `util::prop` sweep whose
//!   case count honors `TETRIS_PROP_CASES`);
//! * **exact halo** — the model's predicted tiled-walk halo-recompute
//!   rows equal the measured `halo_recompute_rows` exactly (per image,
//!   explicit tiles disable adaptive shrink);
//! * **budget contract** — the tuner never flags `over_budget` when
//!   any in-budget candidate exists, honors explicit walk/tile pins,
//!   and its in-budget picks reproduce the budget ladder unpinned;
//! * **I5 under tuning** — every tuner-selected schedule (walk, tile,
//!   arm split) executes bit-identical to the scalar MAC reference,
//!   logits included;
//! * **arm serialization** — `ExecOpts::arm_threads = Some(1)` is
//!   bit-exact on a branchy trunk and never raises the measured peak.

use tetris::config::Mode;
use tetris::model::reference::forward_reference;
use tetris::model::weights::{synthetic_loaded_with_heads, DensityCalibration};
use tetris::model::{zoo, Network, Tensor};
use tetris::plan::{tune, CompiledNetwork, CostModel, ExecOpts, Walk, PEAK_BRACKET_FACTOR};
use tetris::util::prop::{run_with, PropConfig};
use tetris::util::rng::Rng;

fn random_input(net: &Network, n: usize, hw: usize, rng: &mut Rng) -> Tensor<i32> {
    let mut x = Tensor::zeros(&[n, net.layers[0].in_c, hw, hw]);
    for v in x.data_mut() {
        *v = rng.range_i64(-512, 512) as i32;
    }
    x
}

/// The scaled evaluation zoo (same scaling the I5 suites pin), with
/// head-bearing weights so tuner-selected schedules cover image →
/// logits.
fn scaled_zoo() -> Vec<(Network, &'static str, usize)> {
    vec![
        (zoo::alexnet().scaled(16, 64), "alexnet", 64),
        (zoo::googlenet().scaled(16, 64), "googlenet", 64),
        (zoo::vgg16().scaled(16, 32), "vgg16", 32),
        (zoo::vgg19().scaled(16, 32), "vgg19", 32),
        (zoo::nin().scaled(16, 64), "nin", 64),
    ]
}

fn compiled_zoo(seed: u64) -> Vec<(Network, CompiledNetwork, Tensor<i32>, usize)> {
    scaled_zoo()
        .into_iter()
        .map(|(net, profile, hw)| {
            let w = synthetic_loaded_with_heads(
                &net,
                Mode::Fp16,
                12,
                profile,
                DensityCalibration::Fig2,
                seed + hw as u64,
            )
            .unwrap();
            let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
            let mut rng = Rng::new(seed ^ 0x11 ^ hw as u64);
            let x = random_input(&net, 1, hw, &mut rng);
            (net, plan, x, hw)
        })
        .collect()
}

fn prop_cases(default: usize) -> usize {
    std::env::var("TETRIS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&c| c > 0)
        .unwrap_or(default)
}

// ---------------- validation contract: predicted peak brackets measured peak ----------------

/// `util::prop` sweep over (network, walk, tile-or-budget, workers):
/// the cost model's predicted peak and the traced measured peak
/// bracket each other within [`PEAK_BRACKET_FACTOR`] on both sides.
/// Workers cap at 2 because the estimators are concurrency bounds (a
/// 1-image batch stripes one thread while the estimate scales rings by
/// the worker budget) — the bracket absorbs that slack, and a wrong
/// ring formula (off by O(depth)) still blows it.
#[test]
fn cost_model_peak_estimates_bracket_traced_ground_truth_zoo_wide() {
    let compiled = compiled_zoo(0x7A11);

    run_with(
        PropConfig { cases: prop_cases(10), seed: 0x5EED_0007 },
        "measured peak within [predicted/F, predicted×F]",
        |rng| {
            let net_i = rng.below(compiled.len() as u64) as usize;
            let workers = 1 + rng.below(2) as usize;
            let walk = match rng.below(3) {
                0 => Walk::Tiled,
                1 => Walk::Streaming,
                _ => Walk::Pipelined,
            };
            let tile = if rng.chance(0.5) {
                1 + rng.below(6) as usize
            } else {
                // Budget-derived through the walk-matched ladder,
                // exactly like serving: 1..=64 MiB.
                let budget = (1u64 << rng.below(7)) * 1024 * 1024;
                compiled[net_i].1.tile_rows_for_budget_walk(budget, workers, walk)
            };
            (net_i, walk, tile, workers)
        },
        |&(net_i, walk, tile, workers)| {
            let (net, plan, x, _) = &compiled[net_i];
            let predicted =
                CostModel::new(plan, workers).estimate(walk, tile).map_err(|e| e.to_string())?;
            let opts = ExecOpts {
                tile_rows: Some(tile),
                workers: Some(workers),
                walk: Some(walk),
                arm_threads: None,
                skip_zero_activations: None,
                kernel: None,
            };
            let (_, stats) = plan.execute_traced(x, opts).map_err(|e| e.to_string())?;
            let (m, p) = (stats.peak_bytes(), predicted.peak_bytes);
            if m > p.saturating_mul(PEAK_BRACKET_FACTOR) || p > m.saturating_mul(PEAK_BRACKET_FACTOR)
            {
                return Err(format!(
                    "{}: {walk:?} tile={tile} workers={workers}: measured peak {m} B vs \
                     predicted {p} B escapes the ×{PEAK_BRACKET_FACTOR} bracket",
                    net.name
                ));
            }
            Ok(())
        },
    );
}

/// The cost model's tiled-walk halo prediction is a line-for-line
/// replica of the executor's boundary walk, so it must match the
/// traced `halo_recompute_rows` **exactly** — per image (explicit
/// `ExecOpts::tile_rows` disables adaptive tile shrinking, so a batch
/// of n recomputes exactly n× the per-image rows).
#[test]
fn predicted_halo_rows_match_traced_exactly() {
    for (net, profile, hw) in scaled_zoo() {
        let w = synthetic_loaded_with_heads(
            &net,
            Mode::Fp16,
            12,
            profile,
            DensityCalibration::Fig2,
            0x4A10,
        )
        .unwrap();
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let mut rng = Rng::new(0xA10);
        let n = 2usize;
        let x = random_input(&net, n, hw, &mut rng);
        for tile in [2usize, 3, 5] {
            let predicted = CostModel::new(&plan, 1).predicted_halo_rows(tile).unwrap();
            for workers in [1usize, 2] {
                let opts = ExecOpts::tiled(tile).with_workers(workers);
                let (_, stats) = plan.execute_traced(&x, opts).unwrap();
                assert_eq!(
                    stats.halo_recompute_rows(),
                    predicted * n as u64,
                    "{}: tile={tile} workers={workers}: measured halo rows diverged from \
                     the model ({} per-image predicted)",
                    net.name,
                    predicted
                );
            }
        }
    }
}

// ---------------- budget contract ----------------

/// The tuner's feasibility pin: whenever **any** enumerated candidate
/// fits the budget, the chosen schedule is in budget (`!over_budget`
/// and predicted peak ≤ budget); in-budget unpinned picks reproduce
/// the budget ladder; a zero budget flags `over_budget`; branchy plans
/// get the arm-serialization lever only when over budget.
#[test]
fn tuner_never_over_budget_when_a_candidate_fits() {
    for (net, plan, _, _) in compiled_zoo(0xB4D6) {
        for budget in [1u64 << 20, 4 << 20, 64 << 20, u64::MAX] {
            let tuned = tune::tune(&plan, budget, 2);
            let any_fits = tune::candidates(&plan, 2, 0)
                .unwrap()
                .iter()
                .any(|c| c.fits(budget));
            if any_fits {
                assert!(
                    !tuned.over_budget,
                    "{}: budget {budget} has a fitting candidate but the tuner flagged \
                     over_budget",
                    net.name
                );
                assert!(
                    tuned.predicted_peak_bytes <= budget,
                    "{}: chosen schedule's predicted peak {} blows the {budget}-byte budget",
                    net.name,
                    tuned.predicted_peak_bytes
                );
            }
            if !tuned.over_budget && tuned.walk.is_none() {
                assert_eq!(
                    tuned.tile_rows,
                    plan.tile_rows_for_budget(budget, 2),
                    "{}: in-budget unpinned pick must reproduce the budget ladder",
                    net.name
                );
            }
            assert_eq!(tuned.streaming_batch_pivot, 2);
        }

        let broke = tune::tune(&plan, 0, 4);
        assert!(broke.over_budget, "{}: nothing fits a zero budget", net.name);
        let branchy = net.name.contains("googlenet");
        assert_eq!(
            broke.arm_threads,
            if branchy { Some(1) } else { None },
            "{}: arm serialization is the over-budget lever for branchy plans only",
            net.name
        );
    }
}

// ---------------- I5 under tuner-selected schedules ----------------

/// Every schedule the tuner selects — across budgets that land on the
/// unpinned ladder, the pipelined fallover, and the over-budget
/// minimum-footprint floor — executes bit-identical to the scalar MAC
/// reference, logits included.
#[test]
fn i5_holds_under_tuner_selected_schedules() {
    for (net, profile, hw) in scaled_zoo() {
        let w = synthetic_loaded_with_heads(
            &net,
            Mode::Fp16,
            12,
            profile,
            DensityCalibration::Fig2,
            0x15 + hw as u64,
        )
        .unwrap();
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let mut rng = Rng::new(0x15E5);
        let x = random_input(&net, 2, hw, &mut rng);
        let want = forward_reference(&net, &w, &x);
        for budget in [1u64 << 20, 8 << 20, u64::MAX] {
            let tuned = tune::tune(&plan, budget, 2);
            let opts = ExecOpts {
                tile_rows: Some(tuned.tile_rows),
                workers: Some(2),
                walk: tuned.walk,
                arm_threads: tuned.arm_threads,
                skip_zero_activations: None,
                kernel: None,
            };
            let got = plan.execute_opts(&x, opts).unwrap();
            assert_eq!(
                got, want,
                "{}: tuner schedule for budget {budget} (walk {:?}, tile {}) diverged from \
                 the reference",
                net.name, tuned.walk, tuned.tile_rows
            );
        }
    }
}

// ---------------- arm serialization lever ----------------

/// Serializing branch arms (`ExecOpts::arm_threads = Some(1)`) on the
/// branchy GoogleNet trunk is bit-exact vs the default arm fan-out and
/// never raises the measured peak — at most one arm's rings + input
/// clone are live on top of the kept arm outputs.
#[test]
fn arm_threads_serializes_branch_arms_bit_exact_and_no_worse_peak() {
    let net = zoo::googlenet().scaled(16, 64);
    let w = synthetic_loaded_with_heads(
        &net,
        Mode::Fp16,
        12,
        "googlenet",
        DensityCalibration::Fig2,
        0xA53,
    )
    .unwrap();
    let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
    let mut rng = Rng::new(0xA54);
    let x = random_input(&net, 1, 64, &mut rng);
    let want = forward_reference(&net, &w, &x);

    let base = ExecOpts::streaming(4).with_workers(4);
    let serial = ExecOpts::streaming(4).with_workers(4).with_arm_threads(1);
    let (got_base, tb) = plan.execute_traced(&x, base).unwrap();
    let (got_serial, ts) = plan.execute_traced(&x, serial).unwrap();
    assert_eq!(got_base, want, "default arm fan-out diverged from the reference");
    assert_eq!(got_serial, want, "serialized arms diverged from the reference");
    assert!(
        ts.peak_bytes() <= tb.peak_bytes(),
        "serializing arms raised the peak: {} B (serial) > {} B (fan-out)",
        ts.peak_bytes(),
        tb.peak_bytes()
    );
}
