//! ISSUE 9 acceptance: cluster serving — wire codec, consistent-hash
//! router, shard fault tolerance, supervisor restart.
//!
//! Pins: (a) the wire codec round-trips every message kind and rejects
//! truncated/corrupt frames (property-swept under `TETRIS_PROP_CASES`),
//! (b) routed logits are **bit-exact** against a single in-process
//! engine across the scaled zoo (same model spec + seed on every
//! shard ⇒ identical weights), (c) the rendezvous ring moves only the
//! keys of an added/removed shard, (d) killing a shard mid-flight
//! completes every outstanding ticket as a *typed* failure within the
//! deadline — zero hangs — while survivors keep serving, (e) a shard
//! that accepts but never answers is converted to `Timeout`, (f) the
//! supervisor restarts a killed `tetris shard` child end-to-end.
//!
//! Tests serialize on `SERIAL`: each spins up engines/sockets and the
//! heavier ones are wall-clock sensitive under contention.

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use tetris::cluster::wire::{FailKind, Message, WireModel};
use tetris::cluster::{
    loadgen, rendezvous_rank, ClusterError, ModelSetSpec, Router, RouterConfig, ShardServer,
    Supervisor, SupervisorConfig,
};
use tetris::model::Tensor;
use tetris::util::prop;
use tetris::util::rng::Rng;

/// Serializes every test here (see module docs).
static SERIAL: Mutex<()> = Mutex::new(());

fn image_for(rng: &mut Rng, c: usize, hw: usize) -> Tensor<i32> {
    let mut t = Tensor::zeros(&[c, hw, hw]);
    for v in t.data_mut() {
        *v = rng.range_i64(-400, 400) as i32;
    }
    t
}

/// Draw one arbitrary protocol message.
fn gen_message(rng: &mut Rng) -> Message {
    fn gen_str(rng: &mut Rng, max: u64) -> String {
        let len = rng.below(max);
        (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
    }
    match rng.below(5) {
        0 => Message::Hello {
            shard: gen_str(rng, 12),
            models: (0..rng.below(4))
                .map(|_| WireModel {
                    name: gen_str(rng, 10),
                    in_c: rng.below(16) as u32,
                    in_hw: rng.below(64) as u32,
                })
                .collect(),
        },
        1 => {
            let shape =
                [rng.below(3) as u32 + 1, rng.below(5) as u32 + 1, rng.below(5) as u32 + 1];
            let n = shape.iter().map(|&d| d as usize).product();
            Message::Submit {
                seq: rng.below(u64::MAX),
                model: gen_str(rng, 10),
                shape,
                image: (0..n).map(|_| rng.range_i64(i32::MIN as i64, i32::MAX as i64) as i32).collect(),
            }
        }
        2 => Message::Done {
            seq: rng.below(u64::MAX),
            argmax: rng.below(1000) as u32,
            latency_us: rng.below(1 << 30) as f64 / 7.0,
            sim_cycles: rng.below(u64::MAX),
            batch_size: rng.below(64) as u32,
            logits: (0..rng.below(32))
                .map(|_| rng.range_i64(i32::MIN as i64, i32::MAX as i64) as i32)
                .collect(),
        },
        3 => Message::Failed {
            seq: rng.below(u64::MAX),
            kind: [
                FailKind::Shape,
                FailKind::Config,
                FailKind::Backend,
                FailKind::ShardDown,
                FailKind::Timeout,
                FailKind::Protocol,
            ][rng.below(6) as usize],
            error: gen_str(rng, 40),
        },
        _ => Message::Shutdown,
    }
}

/// (a) Every arbitrary message round-trips bit-exactly through the
/// codec, consuming the frame completely.
#[test]
fn wire_codec_roundtrips_arbitrary_messages() {
    let _serial = SERIAL.lock().unwrap();
    prop::run("wire-roundtrip", gen_message, |m| {
        let bytes = m.encode();
        let mut r = &bytes[..];
        let back = Message::decode_from(&mut r)
            .map_err(|e| format!("decode failed on a clean frame: {e}"))?;
        if !r.is_empty() {
            return Err(format!("{} bytes of the frame were left unread", r.len()));
        }
        if &back != m {
            return Err(format!("round-trip changed the message: {back:?}"));
        }
        Ok(())
    });
}

/// (a) Truncating or corrupting an arbitrary frame anywhere is always
/// rejected — never silently decoded.
#[test]
fn wire_codec_rejects_truncated_and_corrupt_frames() {
    let _serial = SERIAL.lock().unwrap();
    prop::run(
        "wire-damage-rejected",
        |rng| {
            let bytes = gen_message(rng).encode();
            let cut = rng.below(bytes.len() as u64) as usize;
            let flip_at = rng.below(bytes.len() as u64) as usize;
            let flip_bits = (rng.below(255) + 1) as u8; // never 0 = identity
            (bytes, cut, flip_at, flip_bits)
        },
        |(bytes, cut, flip_at, flip_bits)| {
            if Message::decode_from(&mut &bytes[..*cut]).is_ok() {
                return Err(format!("truncation to {cut} bytes decoded"));
            }
            let mut bad = bytes.clone();
            bad[*flip_at] ^= flip_bits;
            if let Ok(m) = Message::decode_from(&mut &bad[..]) {
                return Err(format!("flip at {flip_at} (^{flip_bits:#04x}) decoded as {m:?}"));
            }
            Ok(())
        },
    );
}

/// (c) Rendezvous ring stability: removing a shard only moves the keys
/// that mapped to it; adding one only pulls keys onto the newcomer.
#[test]
fn rendezvous_ring_moves_only_the_affected_keys() {
    let _serial = SERIAL.lock().unwrap();
    let shards = ["shard-0", "shard-1", "shard-2", "shard-3"];
    let models: Vec<String> = (0..200).map(|i| format!("model-{i}")).collect();

    let pick = |names: &[&str], model: &str| -> String {
        names[rendezvous_rank(model, names)[0]].to_string()
    };

    // Remove shard-1: every key that chose another shard keeps it.
    let without: Vec<&str> =
        shards.iter().copied().filter(|s| *s != "shard-1").collect();
    let mut moved = 0;
    for m in &models {
        let before = pick(&shards, m);
        let after = pick(&without, m);
        if before == "shard-1" {
            moved += 1;
            assert_ne!(after, "shard-1");
        } else {
            assert_eq!(before, after, "key `{m}` moved although its shard survived");
        }
    }
    assert!(moved > 0, "no key ever mapped to the removed shard — hash is degenerate");

    // Add shard-4: keys either stay put or move onto the newcomer.
    let grown = ["shard-0", "shard-1", "shard-2", "shard-3", "shard-4"];
    let mut gained = 0;
    for m in &models {
        let before = pick(&shards, m);
        let after = pick(&grown, m);
        if after != before {
            assert_eq!(after, "shard-4", "key `{m}` moved between surviving shards");
            gained += 1;
        }
    }
    assert!(gained > 0, "the added shard attracted no keys");

    // The full ranking is deterministic.
    assert_eq!(rendezvous_rank("m", &shards), rendezvous_rank("m", &shards));
}

/// (b) Routed logits ≡ a single in-process engine, bit for bit, across
/// the scaled zoo — same model spec + seed on both shards and the
/// reference engine.
#[test]
fn routed_logits_match_single_engine_zoo_wide() {
    let _serial = SERIAL.lock().unwrap();
    const SPEC: &str =
        "tiny,alexnet:16:64,googlenet:16:64,vgg16:16:32,vgg19:16:32,nin:16:64";
    const SEED: u64 = 0x7e7215;
    let spec = ModelSetSpec::parse(SPEC).unwrap();

    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for i in 0..2 {
        let engine = spec.build_engine(1, SEED, 2).unwrap();
        let h = ShardServer::spawn(
            format!("shard-{i}"),
            engine,
            "127.0.0.1:0".parse().unwrap(),
        )
        .unwrap();
        addrs.push(h.addr());
        handles.push(h);
    }
    let router = Router::connect(
        &addrs,
        RouterConfig { timeout: Duration::from_secs(60), ..RouterConfig::default() },
    )
    .unwrap();
    let reference = spec.build_engine(1, SEED, 2).unwrap();
    let session = reference.session();

    let mut names = router.model_names();
    names.sort();
    assert_eq!(names, ["alexnet", "googlenet", "nin", "tiny", "vgg16", "vgg19"]);

    let mut rng = Rng::new(41);
    for model in &names {
        let (c, hw) = router.model_shape(model).expect("Hello advertises the shape");
        for k in 0..2 {
            let image = image_for(&mut rng, c, hw);
            let routed = router.infer(model, &image).unwrap();
            let local = session.infer_batch(model, &[image]).unwrap();
            assert_eq!(
                routed.logits, local[0].logits,
                "model `{model}` image {k}: routed logits diverged from the single engine"
            );
            assert_eq!(routed.argmax, local[0].argmax);
        }
    }

    // Router accounting: everything submitted completed, nothing is
    // still in flight, and no shard died.
    let m = router.metrics();
    let submitted: u64 = m.shards.iter().map(|s| s.submitted).sum();
    let completed: u64 = m.shards.iter().map(|s| s.completed).sum();
    assert_eq!(submitted, completed);
    assert_eq!(submitted, 2 * names.len() as u64);
    assert!(m.shards.iter().all(|s| s.alive && s.inflight == 0 && s.failed == 0));

    router.close();
    reference.shutdown();
    for h in handles {
        h.shutdown();
    }
}

/// (d) The kill drill: a shard dying with tickets outstanding fails
/// every one of them *typed* within the deadline (no hangs), and the
/// surviving shard keeps serving.
#[test]
fn killed_shard_fails_outstanding_tickets_and_survivors_serve() {
    let _serial = SERIAL.lock().unwrap();
    const SEED: u64 = 0x7e7215;
    let spec = ModelSetSpec::parse("tiny").unwrap();
    let timeout = Duration::from_secs(5);

    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for i in 0..2 {
        let engine = spec.build_engine(1, SEED, 4).unwrap();
        let h = ShardServer::spawn(
            format!("shard-{i}"),
            engine,
            "127.0.0.1:0".parse().unwrap(),
        )
        .unwrap();
        addrs.push(h.addr());
        handles.push(Some(h));
    }
    let router =
        Router::connect(&addrs, RouterConfig { timeout, ..RouterConfig::default() }).unwrap();

    // Flood the primary with submissions, then kill it immediately —
    // the engine cannot have drained them all.
    let mut rng = Rng::new(17);
    let tickets: Vec<_> = (0..64)
        .map(|_| router.submit("tiny", &image_for(&mut rng, 1, 16)).unwrap())
        .collect();
    let primary = tickets[0].shard;
    handles[primary].take().unwrap().kill();

    let t0 = Instant::now();
    let mut ok = 0usize;
    let mut down = 0usize;
    for t in &tickets {
        match router.wait(t) {
            Ok(_) => ok += 1,
            Err(ClusterError::ShardDown { .. }) | Err(ClusterError::Timeout { .. }) => down += 1,
            Err(other) => panic!("ticket {} got a non-drill error: {other}", t.seq),
        }
    }
    let waited = t0.elapsed();
    assert_eq!(ok + down, tickets.len(), "every ticket must reach a terminal state");
    assert!(down > 0, "the kill caught no outstanding ticket — drill did not exercise the sweep");
    assert!(
        waited < timeout + Duration::from_secs(5),
        "draining 64 tickets took {waited:?} — the sweep must not serialize on the deadline"
    );
    assert_eq!(router.alive_count(), 1);

    // The survivor serves: the router routes around the dead shard.
    for _ in 0..4 {
        let resp = router.infer("tiny", &image_for(&mut rng, 1, 16)).unwrap();
        assert_eq!(resp.shard, format!("shard-{}", 1 - primary));
    }

    // And the loadgen sees typed failures as data, not a wedge.
    let report = loadgen::run(
        &router,
        &loadgen::LoadgenConfig { requests: 8, clients: 2, seed: 3, models: vec![] },
    )
    .unwrap();
    assert_eq!(report.done + report.failed, 8);
    assert_eq!(report.done, 8, "survivor-only load must fully succeed");

    router.close();
    if let Some(h) = handles[1 - primary].take() {
        h.shutdown();
    }
}

/// (e) A shard that accepts and says Hello but never answers converts
/// to `Timeout` at the deadline — a stall is never a hang.
#[test]
fn black_hole_shard_times_out_at_the_deadline() {
    let _serial = SERIAL.lock().unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let hole = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let hello = Message::Hello {
            shard: "black-hole".into(),
            models: vec![WireModel { name: "tiny".into(), in_c: 1, in_hw: 16 }],
        };
        hello.encode_to(&mut stream).unwrap();
        stream.flush().unwrap();
        // Swallow everything until the router hangs up.
        let mut sink = [0u8; 4096];
        while matches!(stream.read(&mut sink), Ok(n) if n > 0) {}
    });

    let timeout = Duration::from_millis(300);
    let router =
        Router::connect(&[addr], RouterConfig { timeout, ..RouterConfig::default() }).unwrap();
    let mut rng = Rng::new(5);
    let t0 = Instant::now();
    let err = router.infer("tiny", &image_for(&mut rng, 1, 16)).unwrap_err();
    let waited = t0.elapsed();
    assert!(
        matches!(err, ClusterError::Timeout { .. }),
        "expected Timeout, got: {err}"
    );
    assert_eq!(err.kind(), FailKind::Timeout);
    assert!(waited >= timeout, "returned before the deadline: {waited:?}");
    assert!(waited < timeout + Duration::from_secs(5), "deadline overshot: {waited:?}");

    router.close();
    hole.join().unwrap();
}

/// (f) Supervisor end-to-end over real `tetris shard` child processes:
/// ready handshake, serving, kill → restart, shutdown.
#[test]
fn supervisor_restarts_a_killed_shard_process() {
    let _serial = SERIAL.lock().unwrap();
    let sup = Supervisor::start(SupervisorConfig {
        program: Some(env!("CARGO_BIN_EXE_tetris").into()),
        shards: 2,
        models: "tiny".into(),
        workers: 1,
        seed: 0x7e7215,
        max_batch: 4,
        ..SupervisorConfig::default()
    })
    .unwrap();
    let addrs = sup.addrs();
    assert_eq!(addrs.len(), 2);

    let config = RouterConfig { timeout: Duration::from_secs(30), ..RouterConfig::default() };
    let router = Router::connect(&addrs, config.clone()).unwrap();
    let mut rng = Rng::new(23);
    router.infer("tiny", &image_for(&mut rng, 1, 16)).unwrap();
    router.close();

    // The drill: kill child 0 and wait for the monitor to respawn it.
    assert!(sup.kill_shard(0), "slot 0 had no live child");
    let deadline = Instant::now() + Duration::from_secs(60);
    while sup.restarts(0) == 0 {
        assert!(!sup.is_broken(0), "breaker tripped on a single kill");
        assert!(Instant::now() < deadline, "shard-0 was not restarted in time");
        std::thread::sleep(Duration::from_millis(50));
    }

    // A fresh router reaches the restarted cluster and serving works.
    let router = Router::connect(&sup.addrs(), config).unwrap();
    let resp = router.infer("tiny", &image_for(&mut rng, 1, 16)).unwrap();
    assert!(!resp.logits.is_empty());
    router.close();
    sup.shutdown();
}
