//! ISSUE 1 tentpole tests: the plan executor is bit-identical to the
//! legacy scalar pipeline (DESIGN.md invariant I5) for every mode,
//! kneading stride and thread count, and it runs a non-tiny zoo
//! topology (a VGG-16 block) end-to-end against a plain MAC reference.

use std::sync::Mutex;

use tetris::config::Mode;
use tetris::coordinator::SacBackend;
use tetris::model::weights::{synthetic_loaded, DensityCalibration};
use tetris::model::{zoo, LoadedLayer, LoadedWeights, Tensor};
use tetris::plan::CompiledNetwork;
use tetris::quant::requantize;
use tetris::runtime::quantized;
use tetris::util::prop::gen;
use tetris::util::rng::Rng;

/// Serializes every test in this binary: the thread-count test mutates
/// the process-global `TETRIS_THREADS` that `util::pool::par_map`
/// reads, and glibc `setenv` racing `getenv` from concurrent tests is
/// undefined behavior.
static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Random tiny-CNN weight set: mode-bounded magnitudes, randomized
/// per-layer frac_bits (including 0, the requantize regression case).
fn random_tiny_weights(mode: Mode, rng: &mut Rng) -> LoadedWeights {
    let bits = mode.weight_bits() as u32;
    let frac_choices: [u32; 4] = match mode {
        Mode::Fp16 => [0, 6, 8, 10],
        Mode::Int8 => [0, 3, 5, 7],
    };
    let net = zoo::tiny_cnn();
    let mut layers: Vec<LoadedLayer> = net
        .layers
        .iter()
        .map(|l| LoadedLayer {
            name: l.name.clone(),
            shape: [l.out_c, l.in_c, l.k, l.k],
            frac_bits: frac_choices[rng.below(4) as usize],
            weights: (0..l.weight_count()).map(|_| gen::weight(rng, bits)).collect(),
        })
        .collect();
    layers.push(LoadedLayer {
        name: "fc".into(),
        shape: [4, 16, 1, 1],
        frac_bits: frac_choices[rng.below(4) as usize],
        weights: (0..64).map(|_| gen::weight(rng, bits)).collect(),
    });
    LoadedWeights { mode, layers }
}

fn random_images(n: usize, rng: &mut Rng) -> Tensor<i32> {
    let mut t = Tensor::zeros(&[n, 1, 16, 16]);
    for v in t.data_mut() {
        *v = rng.range_i64(-512, 512) as i32;
    }
    t
}

/// Invariant I5: plan executor ≡ legacy scalar forward, bit for bit,
/// across both modes and kneading strides 4/16/64 on random weights and
/// images. (The scalar path always kneads at KS=16; values are KS-
/// invariant because SAC ≡ MAC for any stride, so every plan stride
/// must reproduce it exactly.)
#[test]
fn plan_matches_scalar_forward_across_modes_and_strides() {
    let _serial = ENV_LOCK.lock().unwrap();
    let net = zoo::tiny_cnn();
    for mode in [Mode::Fp16, Mode::Int8] {
        for ks in [4usize, 16, 64] {
            for seed in [1u64, 2] {
                let mut rng = Rng::new(0x5EED ^ seed ^ ((ks as u64) << 8));
                let w = random_tiny_weights(mode, &mut rng);
                let x = random_images(2, &mut rng);
                let plan = CompiledNetwork::compile(&net, &w, ks, mode).unwrap();
                let got = plan.execute(&x).unwrap();
                let want = quantized::forward_scalar(&w, &x).unwrap();
                assert_eq!(got, want, "{mode} ks={ks} seed={seed}");
            }
        }
    }
}

/// Thread count must never change logits: `par_map`'s striped
/// assignment is order-deterministic and every stripe's arithmetic is
/// independent.
#[test]
fn thread_count_does_not_change_logits() {
    let _serial = ENV_LOCK.lock().unwrap();
    let w = SacBackend::synthetic_weights(23).unwrap();
    let plan = quantized::compile_tiny_cnn(&w).unwrap();
    let mut rng = Rng::new(99);
    let x = random_images(5, &mut rng);
    std::env::set_var("TETRIS_THREADS", "1");
    let single = plan.execute(&x).unwrap();
    std::env::set_var("TETRIS_THREADS", "8");
    let eight = plan.execute(&x).unwrap();
    std::env::remove_var("TETRIS_THREADS");
    let free = plan.execute(&x).unwrap();
    assert_eq!(single, eight);
    assert_eq!(single, free);
}

/// Plain integer MAC conv — the SAC-free scalar reference.
fn ref_conv(x: &Tensor<i32>, wl: &LoadedLayer, pad: usize) -> Tensor<i32> {
    let [o, c, kh, kw] = wl.shape;
    let (n, h, w) = match *x.shape() {
        [n, cx, h, w] => {
            assert_eq!(cx, c);
            (n, h, w)
        }
        _ => panic!("4-D input"),
    };
    let (oh, ow) = (h + 2 * pad - kh + 1, w + 2 * pad - kw + 1);
    let mut out: Tensor<i32> = Tensor::zeros(&[n, o, oh, ow]);
    let lane = c * kh * kw;
    for b in 0..n {
        for f in 0..o {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for cc in 0..c {
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let (iy, ix) = (oy + ky, ox + kx);
                                if iy < pad || ix < pad || iy - pad >= h || ix - pad >= w {
                                    continue;
                                }
                                let wv = wl.weights[f * lane + (cc * kh + ky) * kw + kx] as i64;
                                acc += wv * x.get4(b, cc, iy - pad, ix - pad) as i64;
                            }
                        }
                    }
                    out.set4(b, f, oy, ox, acc as i32);
                }
            }
        }
    }
    out
}

/// A (channel-scaled) VGG-16 block runs through the plan executor with
/// bit-exact agreement against the plain MAC reference — the executor
/// is not married to the tiny CNN's layer names or shapes.
#[test]
fn vgg16_block_matches_mac_reference() {
    let _serial = ENV_LOCK.lock().unwrap();
    // Block 3 of VGG-16 (conv3_1..conv3_3), channels ÷16 (8→16→16),
    // run at 8×8 so the debug-build test stays fast. Conv-only weight
    // set → the derived graph is Conv→ReluRequant ×3, no head.
    let net = zoo::vgg16_block(3).unwrap().scaled(16, 8);
    let w = synthetic_loaded(&net, Mode::Fp16, 12, "vgg16", DensityCalibration::Fig2, 0xB10C)
        .unwrap();
    let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
    assert!(plan.fc().is_none());

    let mut rng = Rng::new(7);
    let mut x = Tensor::zeros(&[2, net.layers[0].in_c, 8, 8]);
    for v in x.data_mut() {
        *v = rng.range_i64(-512, 512) as i32;
    }

    let got = plan.execute(&x).unwrap();

    // Scalar reference: MAC conv + fused ReLU/requantize per layer.
    let mut want = x.clone();
    for wl in &w.layers {
        let mut acc = ref_conv(&want, wl, 1);
        for v in acc.data_mut() {
            *v = requantize(*v, wl.frac_bits).max(0);
        }
        want = acc;
    }
    assert_eq!(got.shape(), want.shape());
    assert_eq!(got, want, "plan executor diverged from MAC reference");
    // Sanity: the scaled block still dwarfs the tiny CNN's conv layers
    // (8·9 + 16·72 + 16·144 = 3528 weights) and produced live activity.
    assert!(plan.source_weights() > 5_000);
    assert!(got.data().iter().any(|&v| v != 0));
}

/// The one-shot wrapper and a compiled-once plan agree (compiling per
/// call changes cost, never values).
#[test]
fn wrapper_and_reused_plan_agree() {
    let _serial = ENV_LOCK.lock().unwrap();
    let w = SacBackend::synthetic_weights(31).unwrap();
    let plan = quantized::compile_tiny_cnn(&w).unwrap();
    let mut rng = Rng::new(3);
    for _ in 0..3 {
        let x = random_images(1, &mut rng);
        assert_eq!(plan.execute(&x).unwrap(), quantized::forward(&w, &x).unwrap());
    }
}
