//! ISSUE 1 + ISSUE 2 acceptance: the serving path performs **zero**
//! kneading after plan construction — and a server with W workers
//! sharing one `Arc<CompiledNetwork>` (via `Server::start_shared`)
//! performs exactly *one compile's worth* of knead calls, not W.
//!
//! These are the only tests in this binary on purpose: the knead
//! counter (`kneading::knead_call_count`) is process-wide, and cargo
//! runs a binary's tests on concurrent threads. Isolating them here —
//! and serializing the two through `KNEAD_LOCK` — keeps the counter
//! free of unrelated kneading traffic, so every assertion can be an
//! exact equality instead of a tolerance.

use std::sync::Mutex;
use std::time::Duration;

use tetris::coordinator::{BatchPolicy, InferBackend, InferRequest, SacBackend, Server, ServerConfig};
use tetris::kneading::knead_call_count;
use tetris::model::Tensor;
use tetris::util::rng::Rng;

/// Serializes the two counter-sensitive tests in this binary.
static KNEAD_LOCK: Mutex<()> = Mutex::new(());

fn image_batch(n: usize, seed: u64) -> Tensor<i32> {
    let mut rng = Rng::new(seed);
    let mut images = Tensor::zeros(&[n, 1, 16, 16]);
    for v in images.data_mut() {
        *v = rng.range_i64(-400, 400) as i32;
    }
    images
}

#[test]
fn infer_batch_performs_zero_kneading_calls() {
    let _serial = KNEAD_LOCK.lock().unwrap();
    let mut backend = SacBackend::synthetic(7).expect("backend");
    let built = knead_call_count();
    // Construction must have kneaded something (8+16+16 filters + 4
    // classes worth of lanes, one knead_group call per KS-chunk).
    assert!(built > 0, "compile performed no kneading");
    assert_eq!(backend.plan().kneads_at_build, 8 + 16 + 16 + 4);

    let images = image_batch(4, 1);
    let first = backend.infer_batch(&images).expect("infer");
    assert_eq!(first.len(), 4);

    let before = knead_call_count();
    assert_eq!(before, built, "first infer_batch kneaded");
    for _ in 0..3 {
        backend.infer_batch(&images).expect("infer");
    }
    assert_eq!(
        knead_call_count(),
        before,
        "serving path re-kneaded after construction"
    );

    // The legacy scalar path, by contrast, re-kneads on every call —
    // the very cost the plan subsystem removed from serving.
    let w = SacBackend::synthetic_weights(7).expect("weights");
    tetris::runtime::quantized::forward_scalar(&w, &images).expect("scalar");
    assert!(
        knead_call_count() > before,
        "scalar reference unexpectedly stopped kneading"
    );
}

/// ISSUE 2 satellite: W workers ⇒ exactly one compile's worth of knead
/// calls. `Server::start_shared` clones one prototype `SacBackend`
/// into every worker; the clones alias its `Arc<CompiledNetwork>`, so
/// worker count must not appear anywhere in the knead accounting.
#[test]
fn w_workers_share_exactly_one_compile_of_kneading() {
    let _serial = KNEAD_LOCK.lock().unwrap();

    // Measure what ONE compile costs, in knead calls, for this seed.
    let before_solo = knead_call_count();
    let solo = SacBackend::synthetic(21).expect("solo backend");
    let per_compile = knead_call_count() - before_solo;
    assert!(per_compile > 0, "compile performed no kneading");
    drop(solo);

    // Build the shared prototype: exactly one more compile.
    let before_proto = knead_call_count();
    let prototype = SacBackend::synthetic(21).expect("prototype");
    let after_build = knead_call_count();
    assert_eq!(after_build - before_proto, per_compile);

    // Serve through 4 workers. Every batch, on every worker, must
    // stream the shared pre-kneaded lanes — zero further kneading.
    let workers = 4;
    let server = Server::start_shared(
        ServerConfig {
            policy: BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(1) },
            workers,
        },
        prototype,
    )
    .expect("server");
    let total = 4 * workers as u64;
    let mut rng = Rng::new(5);
    for id in 0..total {
        let mut img = Tensor::zeros(&[1, 16, 16]);
        for v in img.data_mut() {
            *v = rng.range_i64(-300, 300) as i32;
        }
        server.submit(InferRequest::new(id, img)).expect("submit");
    }
    for _ in 0..total {
        server.recv().expect("recv");
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests_done, total);
    assert_eq!(
        knead_call_count(),
        after_build,
        "{workers} workers kneaded beyond the one shared compile"
    );
}
