//! ISSUE 1 acceptance: `SacBackend::infer_batch` performs **zero**
//! kneading calls after construction — kneading happens once, inside
//! the `CompiledNetwork` build.
//!
//! This is the only test in this binary on purpose: the knead counter
//! (`kneading::knead_call_count`) is process-wide, and cargo runs the
//! tests *within* one binary on concurrent threads. Isolating the test
//! keeps the counter free of unrelated kneading traffic, so the
//! assertion can be an exact equality instead of a tolerance.

use tetris::coordinator::{InferBackend, SacBackend};
use tetris::kneading::knead_call_count;
use tetris::model::Tensor;
use tetris::util::rng::Rng;

#[test]
fn infer_batch_performs_zero_kneading_calls() {
    let mut backend = SacBackend::synthetic(7).expect("backend");
    let built = knead_call_count();
    // Construction must have kneaded something (8+16+16 filters + 4
    // classes worth of lanes, one knead_group call per KS-chunk).
    assert!(built > 0, "compile performed no kneading");
    assert_eq!(backend.plan().kneads_at_build, 8 + 16 + 16 + 4);

    let mut rng = Rng::new(1);
    let mut images = Tensor::zeros(&[4, 1, 16, 16]);
    for v in images.data_mut() {
        *v = rng.range_i64(-400, 400) as i32;
    }
    let first = backend.infer_batch(&images).expect("infer");
    assert_eq!(first.len(), 4);

    let before = knead_call_count();
    assert_eq!(before, built, "first infer_batch kneaded");
    for _ in 0..3 {
        backend.infer_batch(&images).expect("infer");
    }
    assert_eq!(
        knead_call_count(),
        before,
        "serving path re-kneaded after construction"
    );

    // The legacy scalar path, by contrast, re-kneads on every call —
    // the very cost the plan subsystem removed from serving.
    let w = SacBackend::synthetic_weights(7).expect("weights");
    tetris::runtime::quantized::forward_scalar(&w, &images).expect("scalar");
    assert!(
        knead_call_count() > before,
        "scalar reference unexpectedly stopped kneading"
    );
}
