//! Calibration robustness: the paper's qualitative conclusions
//! (orderings, crossovers) must survive substantial perturbation of the
//! calibration constants — otherwise the reproduction would be an
//! artifact of tuning. Referenced from `config/calib.rs` docs.

use tetris::config::{AccelConfig, CalibConfig, Mode};
use tetris::energy::{edp, network_energy};
use tetris::model::zoo;
use tetris::sim::{dadn::DadnSim, pra::PraSim, simulate_network, tetris::TetrisSim, NetworkSim};

fn run_all(calib: &CalibConfig, seed: u64) -> (NetworkSim, NetworkSim, NetworkSim, NetworkSim) {
    let net = zoo::alexnet();
    let fp16 = AccelConfig::default();
    let int8 = AccelConfig { mode: Mode::Int8, ..AccelConfig::default() };
    (
        simulate_network(&DadnSim, &net, &fp16, calib, seed).unwrap(),
        simulate_network(&PraSim, &net, &fp16, calib, seed).unwrap(),
        simulate_network(&TetrisSim, &net, &fp16, calib, seed).unwrap(),
        simulate_network(&TetrisSim, &net, &int8, calib, seed).unwrap(),
    )
}

/// Speedup ordering holds for ±30% on every *timing* calibration knob.
#[test]
fn speedup_ordering_robust_to_timing_calib() {
    for scale in [0.7, 1.0, 1.3] {
        let mut calib = CalibConfig::default();
        calib.timing.pipeline_fill = ((calib.timing.pipeline_fill as f64) * scale) as u64;
        calib.timing.tree_drain = ((calib.timing.tree_drain as f64) * scale) as u64;
        calib.timing.pra_frontend_derate *= scale.min(1.2); // keep < 1
        // The int8 derate is the *definition* of int8's frontend limit;
        // perturb it mildly (±10%) — halving it would simply model a
        // different machine where int8 loses, which is not a robustness
        // failure of the conclusions.
        calib.timing.int8_supply_derate =
            (calib.timing.int8_supply_derate * (0.9 + 0.1 * scale)).min(0.99);
        let (dadn, pra, tet, tet8) = run_all(&calib, 7);
        assert!(
            tet.total_cycles() < dadn.total_cycles(),
            "scale {scale}: tetris must beat DaDN"
        );
        assert!(
            tet8.total_cycles() < tet.total_cycles(),
            "scale {scale}: int8 must beat fp16"
        );
        // PRA's margin over DaDN is small in the paper itself (1.15×);
        // under a -30% frontend perturbation it may dip to parity. The
        // robust claim is that PRA stays in the DaDN neighbourhood and
        // never overtakes Tetris.
        let pra_speedup = dadn.total_cycles() as f64 / pra.total_cycles() as f64;
        assert!(
            (0.8..1.7).contains(&pra_speedup),
            "scale {scale}: PRA speedup {pra_speedup} left the plausible band"
        );
        // fp16-Tetris vs PRA closes to near-parity when the perturbation
        // hands PRA +20% frontend throughput (the paper's own gap is
        // only 1.30 vs 1.15) — so the robust cross-design claim is that
        // int8-Tetris still wins outright.
        assert!(
            tet8.total_cycles() < pra.total_cycles(),
            "scale {scale}: tetris int8 must beat PRA"
        );
    }
}

/// EDP conclusions (Tetris beats DaDN, PRA loses to DaDN) hold for ±40%
/// on the dominant energy constants.
#[test]
fn edp_conclusions_robust_to_energy_calib() {
    for scale in [0.6, 1.0, 1.4] {
        let mut calib = CalibConfig::default();
        calib.energy.mult16_pj *= scale;
        calib.energy.sram_read_pj *= 2.0 - scale; // opposite direction
        calib.energy.fifo_pj *= scale;
        let (dadn, pra, tet, _) = run_all(&calib, 7);
        let e = |s: &NetworkSim| edp(network_energy(s, &calib).total_j(), s.time_s());
        assert!(e(&tet) < e(&dadn), "scale {scale}: tetris EDP must beat DaDN");
        assert!(e(&pra) > e(&dadn), "scale {scale}: PRA EDP must lose to DaDN");
    }
}

/// The area ordering (DaDN < Tetris < PRA) holds when the non-anchored
/// components move ±50% (the Table-2-anchored ones are data).
#[test]
fn area_ordering_robust() {
    for scale in [0.5, 1.0, 1.5] {
        let mut calib = CalibConfig::default();
        calib.area.mult_lane_mm2 *= scale;
        calib.area.pra_lane_mm2 *= scale;
        let cfg = AccelConfig::default();
        let a = |d: &str| tetris::energy::chip_area(d, &cfg, &calib).unwrap().total_mm2();
        assert!(a("dadn") < a("tetris"), "scale {scale}");
        assert!(a("tetris") < a("pra"), "scale {scale}");
    }
}

/// Seed independence: conclusions are not a property of one sample.
#[test]
fn conclusions_hold_across_seeds() {
    let calib = CalibConfig::default();
    for seed in [1, 99, 12345, 0xDEAD] {
        let (dadn, pra, tet, tet8) = run_all(&calib, seed);
        assert!(tet.total_cycles() < pra.total_cycles(), "seed {seed}");
        assert!(pra.total_cycles() < dadn.total_cycles(), "seed {seed}");
        assert!(tet8.total_cycles() < tet.total_cycles(), "seed {seed}");
    }
}
