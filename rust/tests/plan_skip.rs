//! ISSUE 8: the activation-aware skip lane joins the I5 equivalence
//! class, zoo-wide and property-swept.
//!
//! Pinned here:
//! * a `util::prop` sweep over (network, walk, tile-or-budget,
//!   workers): executing with `ExecOpts::skip_zero_activations` ON is
//!   byte-identical to skip-OFF and to the naive scalar reference
//!   (logits included where the zoo declares heads), while the trace
//!   counters prove the lane actually elided SAC work — skipping that
//!   never skips would vacuously pass the equivalence;
//! * the activation timing model orders the simulators the
//!   acceptance demands: for every zoo model the measured post-ReLU
//!   profile has real zeros, and Tetris+skip simulates strictly fewer
//!   cycles than dense Tetris, which beats the DaDN baseline — with
//!   the Laconic essential-bit bound at or below the dense count.
//!
//! The case count honors `TETRIS_PROP_CASES` (scripts/verify.sh and CI
//! run the sweep under an explicit knob); unset, it defaults to 12
//! like the sibling sweeps in plan_streaming.rs.

use tetris::config::{AccelConfig, CalibConfig};
use tetris::config::Mode;
use tetris::model::reference::forward_reference;
use tetris::model::weights::{synthetic_loaded_with_heads, DensityCalibration};
use tetris::model::{zoo, Network, Tensor};
use tetris::plan::{CompiledNetwork, ExecOpts, Walk};
use tetris::sim::activation::{
    measure_activation_profile, ActivationProfile, TetrisSkipSim, ACT_OPERAND_BITS,
};
use tetris::sim::dadn::DadnSim;
use tetris::sim::simulate_network;
use tetris::sim::tetris::TetrisSim;
use tetris::util::prop::{run_with, PropConfig};
use tetris::util::rng::Rng;

/// Signed noise with the top quarter of every channel zeroed. The
/// zero band survives every conv/pool (no bias, ReLU fixes zero), so
/// the skip lane is guaranteed real all-zero rows at every depth —
/// the sweep then asserts the counters moved, making the equivalence
/// non-vacuous on every drawn case.
fn banded_input(net: &Network, n: usize, hw: usize, rng: &mut Rng) -> Tensor<i32> {
    let mut x = Tensor::zeros(&[n, net.layers[0].in_c, hw, hw]);
    let band = hw / 4;
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        if (i / hw) % hw >= band {
            *v = rng.range_i64(-512, 512) as i32;
        }
    }
    x
}

/// The scaled evaluation zoo (same scaling the other I5 suites pin),
/// with head weights wherever the zoo declares heads so the
/// equivalence covers image → logits.
fn scaled_zoo() -> Vec<(Network, &'static str, usize)> {
    vec![
        (zoo::alexnet().scaled(16, 64), "alexnet", 64),
        (zoo::googlenet().scaled(16, 64), "googlenet", 64),
        (zoo::vgg16().scaled(16, 32), "vgg16", 32),
        (zoo::vgg19().scaled(16, 32), "vgg19", 32),
        (zoo::nin().scaled(16, 64), "nin", 64),
    ]
}

fn prop_cases() -> usize {
    std::env::var("TETRIS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&c| c > 0)
        .unwrap_or(12)
}

// ---------------- acceptance: skip-on ≡ skip-off ≡ reference, property-swept ----------------

#[test]
fn skip_lane_joins_the_equivalence_class_zoo_wide() {
    let compiled: Vec<(Network, CompiledNetwork, Tensor<i32>, Tensor<i32>)> = scaled_zoo()
        .into_iter()
        .map(|(net, profile, hw)| {
            let w = synthetic_loaded_with_heads(
                &net,
                Mode::Fp16,
                12,
                profile,
                DensityCalibration::Fig2,
                0x8000 + hw as u64,
            )
            .unwrap();
            let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
            let mut rng = Rng::new(0x5C1B + hw as u64);
            let x = banded_input(&net, 1, hw, &mut rng);
            let want = forward_reference(&net, &w, &x);
            (net, plan, x, want)
        })
        .collect();

    run_with(
        PropConfig { cases: prop_cases(), seed: 0x5EED_0008 },
        "skip-on ≡ skip-off ≡ reference ∧ counters moved",
        |rng| {
            let net_i = rng.below(compiled.len() as u64) as usize;
            let walk = match rng.below(3) {
                0 => Walk::Tiled,
                1 => Walk::Streaming,
                _ => Walk::Pipelined,
            };
            let workers = 1 + rng.below(4) as usize;
            let tile = if rng.chance(0.5) {
                // Direct tile/advance step: 0 (whole image) or 1..=6.
                rng.below(7) as usize
            } else {
                // Budget-derived, like serving: 1..=64 MiB through the
                // walk-aware estimator.
                let budget = (1u64 << rng.below(7)) * 1024 * 1024;
                compiled[net_i].1.tile_rows_for_budget_walk(budget, workers, walk)
            };
            (net_i, walk, tile, workers)
        },
        |&(net_i, walk, tile, workers)| {
            let (net, plan, x, want) = &compiled[net_i];
            let opts = ExecOpts::tiled(tile).with_workers(workers).with_walk(walk);
            let (off, t_off) = plan
                .execute_traced(x, opts.with_skip_zero_activations(false))
                .map_err(|e| e.to_string())?;
            let (on, t_on) = plan
                .execute_traced(x, opts.with_skip_zero_activations(true))
                .map_err(|e| e.to_string())?;
            if &off != want {
                return Err(format!(
                    "{}: skip-off {walk:?} tile={tile} workers={workers} diverged from reference",
                    net.name
                ));
            }
            if on != off {
                return Err(format!(
                    "{}: skip-on {walk:?} tile={tile} workers={workers} changed the bytes",
                    net.name
                ));
            }
            if t_off.skipped_windows() != 0 {
                return Err(format!("{}: skip-off run skipped windows", net.name));
            }
            if t_on.skipped_windows() == 0 {
                return Err(format!(
                    "{}: zero-banded input produced no skips ({walk:?} tile={tile}) — \
                     the equivalence check is vacuous",
                    net.name
                ));
            }
            if t_on.skipped_windows() > t_on.total_windows() {
                return Err(format!(
                    "{}: skipped {} of {} windows",
                    net.name,
                    t_on.skipped_windows(),
                    t_on.total_windows()
                ));
            }
            if t_on.activation_values() == 0 || t_on.activation_zero_fraction() <= 0.0 {
                return Err(format!("{}: seal points tallied no distribution", net.name));
            }
            Ok(())
        },
    );
}

// ---------------- acceptance: strictly fewer simulated cycles with skipping ----------------

/// For every zoo model: the measured post-ReLU profile carries real
/// zeros, and the three-way simulation orders exactly as `tetris
/// simulate --activations` reports it — Tetris+skip < Tetris < DaDN —
/// with the Laconic essential-bit bound at or below the dense count.
#[test]
fn measured_skipping_strictly_lowers_simulated_cycles_zoo_wide() {
    let cfg = AccelConfig::default();
    let calib = CalibConfig::default();
    for net in [zoo::alexnet(), zoo::googlenet(), zoo::vgg16(), zoo::vgg19(), zoo::nin()] {
        let profile = measure_activation_profile(&net, &cfg, 0x51_u64).unwrap();
        assert!(
            profile.zero_fraction > 0.0,
            "{}: signed noise through ReLU left no zeros ({profile:?})",
            net.name
        );
        assert!(
            profile.essential_bits_mean > 0.0 && profile.essential_bits_mean < ACT_OPERAND_BITS,
            "{}: essential-bit mean out of range ({profile:?})",
            net.name
        );
        // Same seed throughout: the comparison is paired on identical
        // sampled lanes, so the ordering is the model, not noise.
        let dense = simulate_network(&DadnSim, &net, &cfg, &calib, 9).unwrap();
        let tet = simulate_network(&TetrisSim, &net, &cfg, &calib, 9).unwrap();
        let skip = simulate_network(&TetrisSkipSim { profile }, &net, &cfg, &calib, 9).unwrap();
        assert!(
            skip.total_cycles() < tet.total_cycles(),
            "{}: skipping must strictly lower cycles ({} !< {})",
            net.name,
            skip.total_cycles(),
            tet.total_cycles()
        );
        assert!(
            tet.total_cycles() < dense.total_cycles(),
            "{}: Tetris must beat the dense baseline",
            net.name
        );
        assert!(
            profile.laconic_bound_cycles(tet.total_cycles()) <= tet.total_cycles(),
            "{}: the essential-bit bound cannot exceed the dense count",
            net.name
        );
    }
}

/// A dense profile (no zeros anywhere) must leave the skip model
/// cycle-identical to plain Tetris — the guard that the sim-side
/// scaling only ever acts on measured zeros.
#[test]
fn dense_profile_changes_nothing_zoo_wide() {
    let cfg = AccelConfig::default();
    let calib = CalibConfig::default();
    for net in [zoo::alexnet(), zoo::nin()] {
        let tet = simulate_network(&TetrisSim, &net, &cfg, &calib, 4).unwrap();
        let skip = simulate_network(
            &TetrisSkipSim { profile: ActivationProfile::dense() },
            &net,
            &cfg,
            &calib,
            4,
        )
        .unwrap();
        assert_eq!(skip.total_cycles(), tet.total_cycles(), "{}", net.name);
    }
}
