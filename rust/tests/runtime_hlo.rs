//! Artifact-dependent integration tests: PJRT round trip, golden
//! validation, cross-language bit-exactness, serving on trained weights.
//!
//! These need `make artifacts` to have run (the Makefile's `test`
//! target guarantees it). When artifacts are absent (bare `cargo test`
//! in a fresh clone) they skip with a notice rather than fail.

use std::path::Path;

use tetris::runtime::{ArtifactDir, Engine};

fn artifacts() -> Option<ArtifactDir> {
    let root = Path::new("../artifacts");
    match ArtifactDir::open(root) {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            None
        }
    }
}

#[test]
fn golden_validation_passes() {
    let Some(dir) = artifacts() else { return };
    let report = tetris::runtime::golden::validate(&dir).expect("validation");
    assert!(report.golden_max_abs_err < 1e-3);
    assert!(report.sac_kernel_exact);
    assert!(report.quantized_exact);
}

#[test]
fn hlo_round_trip_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu().expect("pjrt");
    let model = engine.load_hlo_text(&dir.path("golden_cnn.hlo.txt")).expect("load");
    let input = dir.read_f32("golden_input.f32").unwrap();
    let shape = dir.shape("golden", "input_shape").unwrap();
    let a = model.run_f32(&[(&input, &shape)]).unwrap();
    let b = model.run_f32(&[(&input, &shape)]).unwrap();
    assert_eq!(a, b, "PJRT execution must be deterministic");
}

#[test]
fn weight_file_matches_zoo_shapes() {
    let Some(dir) = artifacts() else { return };
    let w = dir.load_weights().expect("weights");
    let net = tetris::model::zoo::tiny_cnn();
    for layer in &net.layers {
        let ll = w.layer(&layer.name).expect("layer present");
        assert_eq!(ll.shape, [layer.out_c, layer.in_c, layer.k, layer.k], "{}", layer.name);
    }
    assert!(w.layer("fc").is_some());
    // int8 file parses too and has the same layer set.
    let w8 = tetris::model::read_weight_file(&dir.path("weights_int8.bin")).unwrap();
    assert_eq!(w8.layers.len(), w.layers.len());
    assert_eq!(w8.mode, tetris::config::Mode::Int8);
}

#[test]
fn sac_kernel_rejects_wrong_shape_inputs() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu().expect("pjrt");
    let sac = engine.load_hlo_text(&dir.path("sac_matmul.hlo.txt")).expect("load");
    // Wrong input shape must error, not crash.
    let bad = tetris::runtime::pjrt::literal_i32(&[0; 4], &[2, 2]).unwrap();
    let planes = {
        let p = dir.read_i8("sac_demo_planes.i8").unwrap();
        let shape = dir.shape("sac_demo", "planes_shape").unwrap();
        tetris::runtime::pjrt::literal_i8(&p, &shape).unwrap()
    };
    assert!(sac.run(&[bad, planes]).is_err());
}

#[test]
fn serving_on_trained_weights_matches_direct_inference() {
    let Some(dir) = artifacts() else { return };
    use std::time::Duration;
    use tetris::coordinator::*;
    use tetris::model::Tensor;

    let weights = dir.load_weights().unwrap();
    let mut direct = SacBackend::new(weights).unwrap();
    let server = Server::start(
        ServerConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            workers: 2,
        },
        move |_| {
            SacBackend::new(
                tetris::model::read_weight_file(Path::new("../artifacts/weights.bin")).unwrap(),
            )
        },
    )
    .unwrap();
    let mut rng = tetris::util::rng::Rng::new(5);
    let mut images = Vec::new();
    for id in 0..10u64 {
        let (img, _) = demo::dataset_image(&mut rng);
        images.push(img.clone());
        server.submit(InferRequest::new(id, img)).unwrap();
    }
    let mut responses: Vec<_> = (0..10).map(|_| server.recv().unwrap()).collect();
    responses.sort_by_key(|r| r.id);
    server.shutdown();
    for r in responses {
        let mut img = images[r.id as usize].clone();
        let s = img.shape().to_vec();
        img.reshape(&[1, s[0], s[1], s[2]]).unwrap();
        let want = direct.infer_batch(&img).unwrap().remove(0);
        assert_eq!(r.logits, want, "request {}", r.id);
    }
}
