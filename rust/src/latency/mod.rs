//! Gate-delay latency models — the substrate behind the paper's
//! Figure 1 (multi-operand adder vs multiplier RTL latency).
//!
//! The paper measured a Xilinx Z7020 via Vivado HLS; we use standard
//! logic-depth formulas in units of one full-adder delay τ:
//!
//! * n-operand adder: a carry-save (3:2 compressor) tree reduces n
//!   addends to 2 in ⌈log₁.₅(n/2)⌉ CSA levels (1τ each), then one final
//!   carry-propagate adder of ⌈log₂ w⌉τ (carry-lookahead).
//! * w-bit array multiplier: partial-product generation (1τ) + a CSA
//!   reduction over w partial products + the final w-bit CPA — i.e. the
//!   *same* tree as a w-operand adder plus the PP stage. That structural
//!   relationship is exactly why Fig 1 finds a 16-operand adder slightly
//!   *faster* than the 2-operand 16-bit multiplier (by ~12.3%).

/// Full-adder delay τ in nanoseconds. Z7020-class fabric at the paper's
/// 125 MHz: one 16-bit multiply fits in one 8 ns cycle, so τ ≈ 0.55 ns
/// puts the multiplier at ~7.2 ns. Only *ratios* matter for Fig 1.
pub const TAU_NS: f64 = 0.55;

/// CSA (3:2 compressor) tree depth to reduce `n` addends to 2.
pub fn csa_levels(n: usize) -> u32 {
    // Each level maps groups of 3 addends to 2: n → ceil(2n/3).
    let mut n = n;
    let mut levels = 0;
    while n > 2 {
        n = (2 * n).div_ceil(3);
        levels += 1;
    }
    levels
}

/// Final carry-propagate adder delay in τ (carry-lookahead, log depth).
pub fn cpa_delay_tau(width_bits: usize) -> f64 {
    (width_bits as f64).log2().ceil()
}

/// Latency of an `n`-operand, `w`-bit adder in ns.
pub fn adder_delay_ns(operands: usize, width_bits: usize) -> f64 {
    assert!(operands >= 2);
    (csa_levels(operands) as f64 + cpa_delay_tau(width_bits)) * TAU_NS
}

/// Latency of a 2-operand `w`×`w` array multiplier in ns: PP generation
/// + CSA tree over `w` partial products + final 2w-bit CPA.
pub fn multiplier_delay_ns(width_bits: usize) -> f64 {
    let pp_gen = 1.0;
    let tree = csa_levels(width_bits) as f64;
    let cpa = cpa_delay_tau(2 * width_bits);
    (pp_gen + tree + cpa) * TAU_NS
}

/// Figure 1 series: adder latency for 2..=16 operands plus the
/// 16-bit multiplier reference line.
pub fn fig1_series(width_bits: usize) -> (Vec<(usize, f64)>, f64) {
    let adders = (2..=16).map(|n| (n, adder_delay_ns(n, width_bits))).collect();
    (adders, multiplier_delay_ns(width_bits))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csa_levels_known_values() {
        assert_eq!(csa_levels(2), 0);
        assert_eq!(csa_levels(3), 1);
        assert_eq!(csa_levels(4), 2);
        assert_eq!(csa_levels(16), 6);
    }

    #[test]
    fn adder_monotone_in_operands() {
        let mut prev = 0.0;
        for n in 2..=16 {
            let d = adder_delay_ns(n, 16);
            assert!(d >= prev, "n={n}");
            prev = d;
        }
    }

    /// The paper's Figure 1 headline: the 16-bit multiplier takes ~12.3%
    /// more time than even the 16-operand adder.
    #[test]
    fn multiplier_slower_than_16_operand_adder() {
        let add16 = adder_delay_ns(16, 16);
        let mult = multiplier_delay_ns(16);
        let overhead = mult / add16 - 1.0;
        assert!(
            (0.05..0.25).contains(&overhead),
            "multiplier overhead {overhead:.3} (paper: 0.123)"
        );
    }

    /// 125 MHz feasibility (§IV): the multiplier must fit in one 8 ns
    /// cycle — the constraint that pinned the paper's frequency.
    #[test]
    fn multiplier_fits_125mhz_cycle() {
        assert!(multiplier_delay_ns(16) < 8.0);
    }

    #[test]
    fn fig1_series_shape() {
        let (adders, mult) = fig1_series(16);
        assert_eq!(adders.len(), 15);
        assert_eq!(adders[0].0, 2);
        // All adders in the series beat the multiplier.
        assert!(adders.iter().all(|&(_, d)| d < mult));
    }
}
