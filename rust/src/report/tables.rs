//! Table 1 (bit statistics) and Table 2 (area) generators.

use std::path::Path;

use super::fmt::Table;
use crate::analysis;
use crate::config::{AccelConfig, CalibConfig};
use crate::energy::chip_area;

/// Table 1: fraction of zero-valued weights & zero bits in all weights.
pub fn table1(seed: u64, csv_dir: Option<&Path>) -> crate::Result<()> {
    let rows = analysis::table1(seed)?;
    let gm = analysis::table1_geomean(&rows);
    let mut t = Table::new(&["Model", "Zero Weights (%)", "Zero BITs in Weights (%)"]);
    // Paper's reported values for side-by-side comparison.
    let paper: &[(&str, f64, f64)] = &[
        ("alexnet", 0.093, 70.52),
        ("googlenet", 0.050, 65.23),
        ("vgg16", 0.156, 70.52),
        ("vgg19", 0.182, 71.09),
        ("nin", 0.193, 67.02),
    ];
    for r in &rows {
        let p = paper.iter().find(|(n, _, _)| *n == r.network);
        let note = p
            .map(|(_, zw, zb)| format!(" (paper {zw:.3} / {zb:.2})"))
            .unwrap_or_default();
        t.row(&[
            r.network.clone(),
            format!("{:.3}", r.zero_weights_pct),
            format!("{:.2}{note}", r.zero_bits_pct),
        ]);
    }
    t.row(&[
        gm.network.clone(),
        format!("{:.3}", gm.zero_weights_pct),
        format!("{:.2} (paper 0.135 / 68.88)", gm.zero_bits_pct),
    ]);
    t.emit(
        "Table 1: zero-valued weights & zero bits (measured vs paper)",
        "table1",
        csv_dir,
    )
}

/// Table 2: area overhead comparison + Tetris per-PE breakdown.
pub fn table2(csv_dir: Option<&Path>) -> crate::Result<()> {
    let cfg = AccelConfig::default();
    let calib = CalibConfig::default();
    let tetris = chip_area("tetris", &cfg, &calib)?;
    let dadn = chip_area("dadn", &cfg, &calib)?;
    let pra = chip_area("pra", &cfg, &calib)?;

    let mut t = Table::new(&["Design (16 PEs)", "Area mm²", "vs DaDN", "paper"]);
    let d_total = dadn.total_mm2();
    for (rep, paper) in [(&dadn, 79.36), (&pra, 153.65), (&tetris, 89.76)] {
        t.row(&[
            rep.design.to_string(),
            format!("{:.2}", rep.total_mm2()),
            format!("{:.3}x", rep.total_mm2() / d_total),
            format!("{paper:.2}"),
        ]);
    }
    t.emit("Table 2: total area (measured vs paper)", "table2_total", csv_dir)?;

    let mut b = Table::new(&["Tetris PE component", "Area mm²", "Percentage"]);
    let total = tetris.total_mm2();
    for (name, area) in tetris.per_pe(cfg.pes) {
        b.row(&[
            name.to_string(),
            format!("{area:.3}"),
            format!("{:.2}%", area * cfg.pes as f64 / total * 100.0),
        ]);
    }
    b.emit("Table 2 (cont.): area breakdown for 1 PE of Tetris", "table2_breakdown", csv_dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_without_error() {
        table1(123, None).unwrap();
        table2(None).unwrap();
    }

    #[test]
    fn tables_write_csv() {
        let dir = std::env::temp_dir().join(format!("tbl_csv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        table2(Some(&dir)).unwrap();
        assert!(dir.join("table2_total.csv").exists());
        assert!(dir.join("table2_breakdown.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
