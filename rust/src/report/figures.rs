//! Figure generators: Fig 1 (adder vs multiplier latency), Fig 2
//! (essential-bit distribution), Fig 8 (performance), Fig 9 (per-layer
//! VGG-16 speedup), Fig 10 (energy efficiency), Fig 11 (KS sweep).

use std::path::Path;

use super::fmt::Table;
use crate::analysis;
use crate::config::{AccelConfig, CalibConfig, KsSweep, Mode};
use crate::energy::{edp, network_energy};
use crate::latency;
use crate::model::weights::DensityCalibration;
use crate::model::zoo;
use crate::sim::{
    dadn::DadnSim, pra::PraSim, sample::sample_network, simulate_network,
    tetris::measure_kneading, tetris::TetrisSim, NetworkSim,
};

/// Fig 1: temporal overhead of a 16-bit adder with 2..16 operands vs the
/// 2-operand 16-bit multiplier.
pub fn fig1(csv_dir: Option<&Path>) -> crate::Result<()> {
    let (adders, mult) = latency::fig1_series(16);
    let mut t = Table::new(&["operands", "adder ns", "multiplier ns", "mult/adder"]);
    for (n, d) in &adders {
        t.row(&[
            n.to_string(),
            format!("{d:.2}"),
            format!("{mult:.2}"),
            format!("{:.3}", mult / d),
        ]);
    }
    let overhead = mult / adders.last().unwrap().1 - 1.0;
    t.emit(
        &format!(
            "Figure 1: 16-bit adder (varied operands) vs 16-bit multiplier \
             (mult is {:.1}% slower than the 16-operand adder; paper: 12.3%)",
            overhead * 100.0
        ),
        "fig1",
        csv_dir,
    )
}

/// Fig 2: essential-bit density per bit position, 4 networks.
pub fn fig2(seed: u64, csv_dir: Option<&Path>) -> crate::Result<()> {
    for (calib, tag) in [
        (DensityCalibration::Fig2, "fig2-calibrated (performance default)"),
        (DensityCalibration::Table1, "table1-calibrated"),
    ] {
        let series = analysis::fig2(seed, calib)?;
        let mut t = Table::new(&["bit", "alexnet", "googlenet", "vgg16", "nin"]);
        for b in 0..16 {
            let mut row = vec![b.to_string()];
            for s in &series {
                row.push(format!("{:.3}", s.density[b]));
            }
            t.row(&row);
        }
        let name = match calib {
            DensityCalibration::Fig2 => "fig2",
            DensityCalibration::Table1 => "fig2_table1",
        };
        t.emit(
            &format!("Figure 2: essential-bit (1s) distribution across bits 0..15 — {tag}"),
            name,
            csv_dir,
        )?;
    }
    Ok(())
}

/// All four design points of Fig 8/10 for one network.
pub struct DesignPoints {
    pub dadn: NetworkSim,
    pub pra: NetworkSim,
    pub tetris_fp16: NetworkSim,
    pub tetris_int8: NetworkSim,
}

/// Simulate the four Fig 8 design points (paired samples per seed).
pub fn design_points(
    net: &crate::model::Network,
    calib: &CalibConfig,
    seed: u64,
) -> crate::Result<DesignPoints> {
    let fp16 = AccelConfig::default();
    let int8 = AccelConfig { mode: Mode::Int8, ..AccelConfig::default() };
    Ok(DesignPoints {
        dadn: simulate_network(&DadnSim, net, &fp16, calib, seed)?,
        pra: simulate_network(&PraSim, net, &fp16, calib, seed)?,
        tetris_fp16: simulate_network(&TetrisSim, net, &fp16, calib, seed)?,
        tetris_int8: simulate_network(&TetrisSim, net, &int8, calib, seed)?,
    })
}

/// Fig 8: absolute inference time + speedups over DaDN.
pub fn fig8(seed: u64, csv_dir: Option<&Path>) -> crate::Result<()> {
    let calib = CalibConfig::default();
    let mut t = Table::new(&[
        "network",
        "DaDN ms",
        "PRA ms",
        "Tetris-fp16 ms",
        "Tetris-int8 ms",
        "PRA x",
        "fp16 x",
        "int8 x",
    ]);
    let mut speedups = (0.0, 0.0, 0.0);
    let nets = zoo::all();
    for net in &nets {
        let p = design_points(net, &calib, seed)?;
        let ms = |s: &NetworkSim| s.time_s() * 1e3;
        let d = ms(&p.dadn);
        let (sp, sf, si) = (d / ms(&p.pra), d / ms(&p.tetris_fp16), d / ms(&p.tetris_int8));
        speedups.0 += sp.ln();
        speedups.1 += sf.ln();
        speedups.2 += si.ln();
        t.row(&[
            net.name.clone(),
            format!("{d:.2}"),
            format!("{:.2}", ms(&p.pra)),
            format!("{:.2}", ms(&p.tetris_fp16)),
            format!("{:.2}", ms(&p.tetris_int8)),
            format!("{sp:.2}"),
            format!("{sf:.2}"),
            format!("{si:.2}"),
        ]);
    }
    let n = nets.len() as f64;
    t.row(&[
        "geomean".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.2} (paper 1.15)", (speedups.0 / n).exp()),
        format!("{:.2} (paper 1.30)", (speedups.1 / n).exp()),
        format!("{:.2} (paper 1.50)", (speedups.2 / n).exp()),
    ]);
    t.emit("Figure 8: performance comparison (inference time, lower is better)", "fig8", csv_dir)
}

/// Fig 9: per-layer VGG-16 speedup over DaDN under KS=8 and KS=16.
pub fn fig9(seed: u64, csv_dir: Option<&Path>) -> crate::Result<()> {
    let calib = CalibConfig::default();
    let net = zoo::vgg16();
    let base = simulate_network(&DadnSim, &net, &AccelConfig::default(), &calib, seed)?;
    let mut t = Table::new(&["layer", "speedup KS=8", "speedup KS=16"]);
    let mut sims = Vec::new();
    for ks in [8, 16] {
        let cfg = AccelConfig { ks, ..AccelConfig::default() };
        sims.push(simulate_network(&TetrisSim, &net, &cfg, &calib, seed)?);
    }
    for (i, l) in net.layers.iter().enumerate() {
        t.row(&[
            l.name.clone(),
            format!("{:.2}", base.per_layer[i].cycles as f64 / sims[0].per_layer[i].cycles as f64),
            format!("{:.2}", base.per_layer[i].cycles as f64 / sims[1].per_layer[i].cycles as f64),
        ]);
    }
    t.emit(
        "Figure 9: per-Conv-layer speedup of VGG-16 (normalized to DaDN)",
        "fig9",
        csv_dir,
    )
}

/// Fig 10: energy efficiency (1/EDP) normalized to DaDN.
pub fn fig10(seed: u64, csv_dir: Option<&Path>) -> crate::Result<()> {
    let calib = CalibConfig::default();
    let mut t = Table::new(&["network", "PRA", "Tetris-fp16", "Tetris-int8"]);
    let mut geo = (0.0, 0.0, 0.0);
    let nets = zoo::all();
    for net in &nets {
        let p = design_points(net, &calib, seed)?;
        let edp_of =
            |s: &NetworkSim| edp(network_energy(s, &calib).total_j(), s.time_s());
        let d = edp_of(&p.dadn);
        // Efficiency relative to DaDN: >1 means better (lower EDP).
        let (ep, ef, ei) = (
            d / edp_of(&p.pra),
            d / edp_of(&p.tetris_fp16),
            d / edp_of(&p.tetris_int8),
        );
        geo.0 += ep.ln();
        geo.1 += ef.ln();
        geo.2 += ei.ln();
        t.row(&[
            net.name.clone(),
            format!("{ep:.2}"),
            format!("{ef:.2}"),
            format!("{ei:.2}"),
        ]);
    }
    let n = nets.len() as f64;
    t.row(&[
        "geomean".into(),
        format!("{:.2} (paper 0.35)", (geo.0 / n).exp()),
        format!("{:.2} (paper 1.24)", (geo.1 / n).exp()),
        format!("{:.2} (paper 1.46)", (geo.2 / n).exp()),
    ]);
    t.emit(
        "Figure 10: energy efficiency (EDP_DaDN / EDP, higher is better)",
        "fig10",
        csv_dir,
    )
}

/// Fig 11: T_ks/T_base under the KS sweep for fp16 (upper) and int8
/// (lower). T_base is the unkneaded time in the *fp16* datapath — the
/// normalization under which the paper's int8 curve sits at ≈0.49.
pub fn fig11(seed: u64, csv_dir: Option<&Path>) -> crate::Result<()> {
    let sweep = KsSweep::default();
    let nets = zoo::all();
    for mode in [Mode::Fp16, Mode::Int8] {
        let mut headers = vec!["network".to_string()];
        for ks in &sweep.ks_values {
            headers.push(format!("KS={ks}"));
        }
        let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut t = Table::new(&hdr_refs);
        for net in &nets {
            let samples = sample_network(net, mode, seed)?;
            let mut row = vec![net.name.clone()];
            for &ks in &sweep.ks_values {
                // T_ks/T_base: kneaded weights consumed per splitter-slot
                // vs raw weights per multiplier-slot, weighted by each
                // layer's total work. int8 mode halves the consumption.
                let mut kneaded = 0.0;
                let mut base = 0.0;
                for (i, layer) in net.layers.iter().enumerate() {
                    let m = measure_kneading(&samples[i], ks);
                    let weight = (layer.out_c * layer.out_hw() * layer.out_hw()) as f64;
                    kneaded += m.mean_kneaded_per_lane * weight
                        / mode.kneaded_per_splitter() as f64;
                    base += layer.lane_len() as f64 * weight;
                }
                row.push(format!("{:.3}", kneaded / base));
            }
            t.row(&row);
        }
        let (title, name) = match mode {
            Mode::Fp16 => (
                "Figure 11 (upper): T_ks/T_base vs kneading stride, fp16 \
                 (paper AlexNet: 0.751 @ KS=10 → 0.642 @ KS=32)",
                "fig11_fp16",
            ),
            Mode::Int8 => (
                "Figure 11 (lower): T_ks/T_base vs kneading stride, int8 \
                 (paper AlexNet: 0.494 @ KS=10 → 0.488 @ KS=32)",
                "fig11_int8",
            ),
        };
        t.emit(title, name, csv_dir)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_and_fig2_render() {
        fig1(None).unwrap();
        fig2(5, None).unwrap();
    }

    #[test]
    fn fig8_geomeans_land_in_paper_zone() {
        // Computed through the public generator path on a small seed;
        // the detailed zone checks live in rust/tests/paper_results.rs.
        fig8(9, None).unwrap();
    }

    #[test]
    fn design_points_are_paired() {
        let calib = CalibConfig::default();
        let net = zoo::alexnet();
        let a = design_points(&net, &calib, 4).unwrap();
        let b = design_points(&net, &calib, 4).unwrap();
        assert_eq!(a.tetris_fp16.total_cycles(), b.tetris_fp16.total_cycles());
        assert_eq!(a.dadn.total_cycles(), b.dadn.total_cycles());
    }
}
