//! Paper table/figure regeneration.
//!
//! Every table and figure of the paper's evaluation (§IV) has a
//! generator here that prints the same rows/series the paper reports
//! and optionally writes CSV. The `cargo bench` targets call the same
//! functions, so `tetris report all` and the bench suite always agree.

mod fmt;
pub mod figures;
mod tables;

pub use figures::{fig1, fig10, fig11, fig2, fig8, fig9};
pub use fmt::Table;
pub use tables::{table1, table2};

use crate::config::{AccelConfig, CalibConfig, Mode};
use crate::kneading::stats::KneadStats;
use crate::model::weights::{profile_with, synthetic_loaded_with_heads, DensityCalibration};
use crate::model::{Network, Tensor};
use crate::plan::{tune, CompiledNetwork, CostModel, ExecOpts, Walk, DRAM_BYTES_PER_CYCLE};
use crate::sim::sample::samples_from_loaded;
use crate::sim::tetris::TetrisSim;
use crate::sim::{accel_by_name, simulate_network, simulate_network_with_samples};
use crate::util::rng::Rng;

/// Dispatch a report by name (`table1|fig1|fig2|fig8|fig9|fig10|fig11|
/// table2|all`).
pub fn run(which: &str, seed: u64, csv_dir: Option<&std::path::Path>) -> crate::Result<()> {
    if let Some(dir) = csv_dir {
        std::fs::create_dir_all(dir)?;
    }
    match which {
        "table1" => table1(seed, csv_dir),
        "table2" => table2(csv_dir),
        "fig1" => fig1(csv_dir),
        "fig2" => fig2(seed, csv_dir),
        "fig8" => fig8(seed, csv_dir),
        "fig9" => fig9(seed, csv_dir),
        "fig10" => fig10(seed, csv_dir),
        "fig11" => fig11(seed, csv_dir),
        "all" => {
            for w in ["table1", "fig1", "fig2", "fig8", "fig9", "fig10", "fig11", "table2"] {
                run(w, seed, csv_dir)?;
            }
            Ok(())
        }
        other => Err(crate::Error::Config(format!(
            "unknown report `{other}` (want table1|fig1|fig2|fig8|fig9|fig10|fig11|table2|all)"
        ))),
    }
}

/// One-off simulation for the `simulate` subcommand.
///
/// With `include_fc`, the network's declared FC heads (VGG fc6–8,
/// GoogleNet's loss3/classifier — `Network::fc_as_conv_layers`) are
/// simulated as 1×1-conv-equivalent layers after the conv trunk, so
/// cycle/MAC totals cover the whole published model. Each head gets
/// its own `fc:`-tagged row in the per-layer table, and a
/// `conv=…  fc=…` split line keeps the paper's conv-only accounting
/// (`Network::total_macs`) visible next to the full-model totals;
/// without the flag the accounting stays conv-only, matching the
/// paper's evaluation.
///
/// With `activations`, one traced image runs through a channel-scaled
/// copy with the executor's zero-activation skip lane armed
/// ([`measure_activation_profile`](crate::sim::activation)), and the
/// report appends the measured post-ReLU profile plus the three-way
/// cycle comparison — dense baseline (DaDN) vs Tetris vs Tetris with
/// activation skipping — and the Laconic essential-bit bound. The
/// comparison simulates the same layer set as the main table, so
/// `--include-fc --activations` applies the activation model to the
/// FC heads too.
pub fn simulate_one(
    net: &Network,
    accel: &str,
    cfg: &AccelConfig,
    seed: u64,
    include_fc: bool,
    activations: bool,
) -> crate::Result<String> {
    let calib = CalibConfig::default();
    let a = accel_by_name(accel)?;
    let conv_layers = net.layers.len();
    let profile = if activations {
        Some(crate::sim::activation::measure_activation_profile(net, cfg, seed)?)
    } else {
        None
    };
    let sim_net = if include_fc {
        let mut layers = net.layers.clone();
        layers.extend(net.fc_as_conv_layers());
        Network { name: net.name.clone(), layers, schedule: net.schedule.clone() }
    } else {
        net.clone()
    };
    let net = &sim_net;
    let sim = simulate_network(a.as_ref(), net, cfg, &calib, seed)?;
    let energy = crate::energy::network_energy(&sim, &calib);
    let mut out = String::new();
    use std::fmt::Write;
    writeln!(
        out,
        "network={} accel={} mode={} ks={}",
        sim.network, sim.accel, cfg.mode, cfg.ks
    )
    .ok();
    writeln!(
        out,
        "cycles={} time={:.3} ms macs={}",
        sim.total_cycles(),
        sim.time_s() * 1e3,
        sim.total_macs()
    )
    .ok();
    writeln!(
        out,
        "energy={:.3} mJ power={:.3} W edp={:.6e} J*s",
        energy.total_j() * 1e3,
        energy.total_j() / sim.time_s(),
        crate::energy::edp(energy.total_j(), sim.time_s()),
    )
    .ok();
    if include_fc {
        // Trunk rows precede the appended head rows by construction,
        // so the split is a prefix sum: conv-only = the paper's
        // accounting, fc = the declared heads.
        let (conv, fc) = sim.per_layer.split_at(conv_layers);
        let sum = |ls: &[crate::sim::LayerSim]| {
            ls.iter().fold((0u64, 0u64), |(c, m), l| (c + l.cycles, m + l.macs))
        };
        let (cc, cm) = sum(conv);
        let (fc_c, fc_m) = sum(fc);
        writeln!(
            out,
            "conv: cycles={cc} macs={cm} (paper accounting)  fc: cycles={fc_c} macs={fc_m} \
             ({} declared head{})",
            fc.len(),
            if fc.len() == 1 { "" } else { "s" },
        )
        .ok();
    }
    let mut table = fmt::Table::new(&["layer", "cycles", "macs", "bound"]);
    for (i, l) in sim.per_layer.iter().enumerate() {
        let label = if i < conv_layers { l.layer.clone() } else { format!("fc:{}", l.layer) };
        table.row(&[
            label,
            l.cycles.to_string(),
            l.macs.to_string(),
            if l.memory_bound { "memory" } else { "compute" }.to_string(),
        ]);
    }
    out.push_str(&table.render());
    if let Some(p) = profile {
        use crate::sim::activation::{TetrisSkipSim, ACT_OPERAND_BITS};
        use crate::sim::dadn::DadnSim;
        writeln!(
            out,
            "\nactivation profile (1 traced image, channel-scaled copy): zeros={:.1}% \
             window-skip={:.1}% essential-bits={:.2}/{} skipped rows={} windows={}/{}",
            p.zero_fraction * 100.0,
            p.window_skip_fraction * 100.0,
            p.essential_bits_mean,
            ACT_OPERAND_BITS,
            p.skipped_rows,
            p.skipped_windows,
            p.total_windows,
        )
        .ok();
        let dense = simulate_network(&DadnSim, net, cfg, &calib, seed)?.total_cycles();
        let tet = simulate_network(&TetrisSim, net, cfg, &calib, seed)?.total_cycles();
        let skip = simulate_network(&TetrisSkipSim { profile: p }, net, cfg, &calib, seed)?
            .total_cycles();
        let speed = |c: u64| dense as f64 / c.max(1) as f64;
        let mut cmp = fmt::Table::new(&["model", "cycles", "speedup vs dense"]);
        cmp.row(&["dense (dadn)".into(), dense.to_string(), "1.00x".into()]);
        cmp.row(&["tetris".into(), tet.to_string(), format!("{:.2}x", speed(tet))]);
        cmp.row(&["tetris+skip".into(), skip.to_string(), format!("{:.2}x", speed(skip))]);
        out.push_str(&cmp.render());
        writeln!(
            out,
            "laconic essential-bit bound: {} cycles (dense x {:.3}; optimistic, not gated)",
            p.laconic_bound_cycles(dense),
            p.essential_bits_mean / ACT_OPERAND_BITS,
        )
        .ok();
    }
    Ok(out)
}

/// Human-readable label for a tuned schedule's walk pin.
fn walk_label(walk: Option<Walk>) -> String {
    match walk {
        Some(w) => format!("{w:?}").to_lowercase(),
        None => "auto (batch rule)".into(),
    }
}

/// `tetris tune` report: the auto-tuner's full scored candidate table
/// for one network at one (budget, workers) point, the schedule it
/// picks, and an advisory kneading-stride sweep. With `measure`, the
/// chosen schedule also executes one traced image so predicted and
/// measured peak bytes sit side by side.
pub fn tune_report(
    net: &Network,
    cfg: &AccelConfig,
    budget_bytes: u64,
    workers: usize,
    seed: u64,
    measure: bool,
) -> crate::Result<String> {
    use std::fmt::Write;
    let weights =
        synthetic_loaded_with_heads(net, cfg.mode, 12, &net.name, DensityCalibration::Fig2, seed)?;
    let plan = CompiledNetwork::compile(net, &weights, cfg.ks, cfg.mode)?;
    let calib = CalibConfig::default();
    let samples = samples_from_loaded(net, &weights)?;
    let cycles =
        simulate_network_with_samples(&TetrisSim, net, &samples, cfg, &calib).total_cycles();

    let tuned = tune::tune(&plan, budget_bytes, workers);
    let cands = tune::candidates(&plan, workers, cycles)?;

    let mut out = String::new();
    writeln!(
        out,
        "network={} ks={} mode={} budget={} B workers={}",
        net.name, cfg.ks, cfg.mode, budget_bytes, workers
    )
    .ok();
    let mut table = fmt::Table::new(&[
        "walk", "tile", "peak B", "traffic B", "halo rows", "score", "fits", "chosen",
    ]);
    for c in &cands {
        // An unpinned pick leaves the executor's batch rule choosing
        // between the two per-segment walks, so both rows are "chosen".
        let chosen = c.tile_rows == tuned.tile_rows
            && match tuned.walk {
                Some(w) => c.walk == w,
                None => matches!(c.walk, Walk::Tiled | Walk::Streaming),
            };
        table.row(&[
            format!("{:?}", c.walk).to_lowercase(),
            c.tile_rows.to_string(),
            c.peak_bytes.to_string(),
            c.traffic_bytes.to_string(),
            c.halo_rows.to_string(),
            c.score().to_string(),
            if c.fits(budget_bytes) { "yes" } else { "no" }.to_string(),
            if chosen { "*" } else { "" }.to_string(),
        ]);
    }
    out.push_str(&table.render());
    writeln!(
        out,
        "chosen: walk={} tile_rows={} predicted_peak={} B{}",
        walk_label(tuned.walk),
        tuned.tile_rows,
        tuned.predicted_peak_bytes,
        if tuned.over_budget { " (OVER BUDGET — minimum-footprint schedule)" } else { "" },
    )
    .ok();
    writeln!(
        out,
        "arm_threads={} streaming_batch_pivot={} (unpinned batches of >= pivot stream)",
        match tuned.arm_threads {
            Some(n) => n.to_string(),
            None => "default".into(),
        },
        tuned.streaming_batch_pivot,
    )
    .ok();

    // Advisory kneading-stride sweep: re-kneading would break the
    // compile-once contract, so alternate strides are scored without
    // mutating the plan — the compute leg re-simulates per stride, the
    // traffic leg is the chosen schedule's (walk-invariant MACs).
    let walk_eff = tuned.walk.unwrap_or(Walk::Streaming);
    let traffic = CostModel::new(&plan, workers)
        .estimate(walk_eff, tuned.tile_rows)?
        .traffic_bytes;
    let mut ks_table = fmt::Table::new(&["ks", "sim cycles", "roofline score"]);
    for ks in [8usize, 16, 32] {
        let alt = AccelConfig { ks, mode: cfg.mode, ..AccelConfig::default() };
        let c = simulate_network_with_samples(&TetrisSim, net, &samples, &alt, &calib)
            .total_cycles();
        let score = c.max(traffic.div_ceil(DRAM_BYTES_PER_CYCLE));
        ks_table.row(&[ks.to_string(), c.to_string(), score.to_string()]);
    }
    writeln!(out, "\nkneading-stride sweep (advisory — the plan compiled at ks={}):", cfg.ks)
        .ok();
    out.push_str(&ks_table.render());

    if measure {
        let l0 = &net.layers[0];
        let x = Tensor::zeros(&[1, l0.in_c, l0.in_hw, l0.in_hw]);
        let opts = ExecOpts {
            tile_rows: Some(tuned.tile_rows),
            workers: Some(workers),
            walk: tuned.walk,
            arm_threads: tuned.arm_threads,
            skip_zero_activations: None,
            kernel: None,
        };
        let (_, stats) = plan.execute_traced(&x, opts)?;
        writeln!(
            out,
            "\nmeasured (1 traced image): peak={} B (predicted {} B) halo_rows={}",
            stats.peak_bytes(),
            tuned.predicted_peak_bytes,
            stats.halo_recompute_rows(),
        )
        .ok();
    }
    Ok(out)
}

/// The `tetris simulate --schedule` line: the schedule the auto-tuner
/// would serve this network with under the process memory budget
/// (`TETRIS_MEM_BUDGET_MB`) and worker count.
pub fn schedule_line(net: &Network, cfg: &AccelConfig, seed: u64) -> crate::Result<String> {
    let weights =
        synthetic_loaded_with_heads(net, cfg.mode, 12, &net.name, DensityCalibration::Fig2, seed)?;
    let plan = CompiledNetwork::compile(net, &weights, cfg.ks, cfg.mode)?;
    let budget = crate::engine::env::mem_budget_bytes();
    let workers = crate::util::pool::worker_count();
    let tuned = tune::tune(&plan, budget, workers);
    Ok(format!(
        "schedule: walk={} tile_rows={} predicted_peak={} B budget={} B workers={}{}",
        walk_label(tuned.walk),
        tuned.tile_rows,
        tuned.predicted_peak_bytes,
        budget,
        workers,
        if tuned.over_budget { " OVER-BUDGET" } else { "" },
    ))
}

/// Kneading statistics for the `knead` subcommand.
pub fn knead_stats(net: &Network, ks: usize, mode: Mode, seed: u64) -> crate::Result<()> {
    let mut rng = Rng::new(seed);
    let mut table = fmt::Table::new(&[
        "layer", "weights", "kneaded", "ratio", "T_ks/T_base", "empty groups",
    ]);
    for (i, layer) in net.layers.iter().enumerate() {
        let profile = profile_with(&net.name, mode, DensityCalibration::Fig2)?;
        let mut lrng = rng.fork(i as u64);
        let sample_n = (layer.lane_len() * layer.out_c.min(16)).max(1024);
        let ws = profile.generate(sample_n, &mut lrng);
        let s = KneadStats::measure(&ws, ks, mode);
        table.row(&[
            layer.name.clone(),
            s.source.to_string(),
            s.kneaded.to_string(),
            format!("{:.3}", s.ratio()),
            format!("{:.3}", s.time_fraction()),
            s.empty_groups.to_string(),
        ]);
    }
    println!("== kneading stats: {} (ks={ks}, {mode}) ==", net.name);
    print!("{}", table.render());
    Ok(())
}
