//! Plain-text table rendering + CSV emission for reports.

use std::path::Path;

/// A simple column-aligned text table.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(c);
                for _ in c.chars().count()..widths[i] {
                    out.push(' ');
                }
            }
            // Trim trailing spaces.
            while out.ends_with(' ') {
                out.pop();
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> crate::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        writeln!(f, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","))?;
        }
        Ok(())
    }

    /// Print with a title and optionally write `<dir>/<name>.csv`.
    pub fn emit(&self, title: &str, name: &str, csv_dir: Option<&Path>) -> crate::Result<()> {
        println!("\n== {title} ==");
        print!("{}", self.render());
        if let Some(dir) = csv_dir {
            self.write_csv(&dir.join(format!("{name}.csv")))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["long-name", "2"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("long-name  2"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn wrong_width_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let dir = std::env::temp_dir().join(format!("csv_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut t = Table::new(&["n", "v"]);
        t.row_strs(&["a,b", "say \"hi\""]);
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"a,b\""));
        assert!(s.contains("\"say \"\"hi\"\"\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}
