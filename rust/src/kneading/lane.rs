//! Lanes: the weight/activation streams a PE consumes.
//!
//! A lane is one reduction — all (weight, activation) pairs that sum
//! into one output-feature-map partial sum (a filter's receptive field
//! across input channels, §III.C).

use crate::quant::{QAct, QWeight};
use crate::util::rng::Rng;

/// One synaptic lane: parallel arrays of weights and activations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lane {
    pub weights: Vec<QWeight>,
    pub activations: Vec<QAct>,
}

impl Lane {
    pub fn new(weights: Vec<QWeight>, activations: Vec<QAct>) -> Self {
        assert_eq!(
            weights.len(),
            activations.len(),
            "lane weight/activation length mismatch"
        );
        Self { weights, activations }
    }

    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// Exact MAC reduction — the golden value every SAC path must match.
    pub fn mac_reference(&self) -> i64 {
        self.weights
            .iter()
            .zip(&self.activations)
            .map(|(&w, &a)| w as i64 * a as i64)
            .sum()
    }

    /// Random lane from a weight sampler + activation sampler.
    pub fn random(
        len: usize,
        rng: &mut Rng,
        mut weight: impl FnMut(&mut Rng) -> QWeight,
        mut act: impl FnMut(&mut Rng) -> QAct,
    ) -> Self {
        let weights = (0..len).map(|_| weight(rng)).collect();
        let activations = (0..len).map(|_| act(rng)).collect();
        Self { weights, activations }
    }

    /// Activation slice for group `g` of stride `ks` (what the splitter's
    /// KS-wide activation window sees).
    pub fn group_acts(&self, g: usize, ks: usize) -> &[QAct] {
        let start = g * ks;
        let end = (start + ks).min(self.activations.len());
        &self.activations[start..end]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_reference_simple() {
        let lane = Lane::new(vec![2, -3, 0], vec![10, 5, 999]);
        assert_eq!(lane.mac_reference(), 20 - 15);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        Lane::new(vec![1], vec![1, 2]);
    }

    #[test]
    fn group_acts_windows() {
        let lane = Lane::new(vec![0; 10], (0..10).collect());
        assert_eq!(lane.group_acts(0, 4), &[0, 1, 2, 3]);
        assert_eq!(lane.group_acts(2, 4), &[8, 9]); // tail
    }

    #[test]
    fn mac_reference_no_overflow_at_extremes() {
        // 256 max-magnitude pairs stay well inside i64.
        let lane = Lane::new(vec![32767; 256], vec![32767; 256]);
        assert_eq!(lane.mac_reference(), 256 * 32767i64 * 32767i64);
    }
}
