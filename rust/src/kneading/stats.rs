//! Kneading statistics: compression ratios and group-length
//! distributions — the quantities behind Fig 11's T_ks/T_base curves.

use super::kneader::knead_group;
use crate::config::Mode;
use crate::quant::QWeight;

/// Aggregate kneading outcome over a weight population.
#[derive(Debug, Clone, Default)]
pub struct KneadStats {
    /// Source weights observed.
    pub source: u64,
    /// Kneaded weights produced.
    pub kneaded: u64,
    /// Groups processed.
    pub groups: u64,
    /// Groups that vanished entirely (all-zero weights).
    pub empty_groups: u64,
    /// Histogram of kneaded group lengths (index = length).
    pub group_len_hist: Vec<u64>,
}

impl KneadStats {
    /// Measure kneading of `weights` with stride `ks`.
    pub fn measure(weights: &[QWeight], ks: usize, mode: Mode) -> Self {
        let mut s = KneadStats::default();
        for chunk in weights.chunks(ks) {
            let g = knead_group(chunk, mode);
            s.source += chunk.len() as u64;
            s.kneaded += g.len() as u64;
            s.groups += 1;
            if g.is_empty() {
                s.empty_groups += 1;
            }
            if s.group_len_hist.len() <= g.len() {
                s.group_len_hist.resize(g.len() + 1, 0);
            }
            s.group_len_hist[g.len()] += 1;
        }
        s
    }

    /// Merge partial measurements (parallel accumulation).
    pub fn merge(&mut self, o: &KneadStats) {
        self.source += o.source;
        self.kneaded += o.kneaded;
        self.groups += o.groups;
        self.empty_groups += o.empty_groups;
        if self.group_len_hist.len() < o.group_len_hist.len() {
            self.group_len_hist.resize(o.group_len_hist.len(), 0);
        }
        for (i, &c) in o.group_len_hist.iter().enumerate() {
            self.group_len_hist[i] += c;
        }
    }

    /// Compression ratio source/kneaded (≥ 1); 1.0 for empty input.
    pub fn ratio(&self) -> f64 {
        if self.kneaded == 0 {
            return 1.0;
        }
        self.source as f64 / self.kneaded as f64
    }

    /// The paper's Fig 11 y-axis: T_ks / T_base = kneaded / source
    /// (cycle count is proportional to weights consumed per splitter).
    pub fn time_fraction(&self) -> f64 {
        if self.source == 0 {
            return 1.0;
        }
        self.kneaded as f64 / self.source as f64
    }

    /// Mean kneaded group length.
    pub fn mean_group_len(&self) -> f64 {
        if self.groups == 0 {
            return 0.0;
        }
        self.kneaded as f64 / self.groups as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::profile_for;
    use crate::util::rng::Rng;

    #[test]
    fn dense_weights_do_not_compress() {
        let ws = vec![0x7FFF; 64];
        let s = KneadStats::measure(&ws, 16, Mode::Fp16);
        assert_eq!(s.ratio(), 1.0);
        assert_eq!(s.kneaded, 64);
        assert_eq!(s.empty_groups, 0);
    }

    #[test]
    fn zero_weights_compress_infinitely() {
        let ws = vec![0; 64];
        let s = KneadStats::measure(&ws, 16, Mode::Fp16);
        assert_eq!(s.kneaded, 0);
        assert_eq!(s.empty_groups, 4);
        assert_eq!(s.time_fraction(), 0.0);
    }

    #[test]
    fn calibrated_profile_lands_in_paper_zone() {
        // With Table-1 bit statistics and KS=16, the paper's Fig 11
        // implies T_ks/T_base around 0.6–0.8 for fp16. Our generator
        // should land inside a generous version of that band.
        let mut rng = Rng::new(42);
        let p = profile_for("alexnet", Mode::Fp16).unwrap();
        let ws = p.generate(64_000, &mut rng);
        let s = KneadStats::measure(&ws, 16, Mode::Fp16);
        let tf = s.time_fraction();
        assert!((0.45..0.9).contains(&tf), "T_ks/T_base = {tf}");
    }

    #[test]
    fn larger_ks_kneads_harder() {
        let mut rng = Rng::new(7);
        let p = profile_for("vgg16", Mode::Fp16).unwrap();
        let ws = p.generate(64_000, &mut rng);
        let t10 = KneadStats::measure(&ws, 10, Mode::Fp16).time_fraction();
        let t32 = KneadStats::measure(&ws, 32, Mode::Fp16).time_fraction();
        assert!(t32 < t10, "KS=32 ({t32}) should beat KS=10 ({t10})");
    }

    #[test]
    fn merge_equals_whole() {
        let mut rng = Rng::new(3);
        let p = profile_for("nin", Mode::Fp16).unwrap();
        let ws = p.generate(3_200, &mut rng);
        let whole = KneadStats::measure(&ws, 16, Mode::Fp16);
        let mut a = KneadStats::measure(&ws[..1600], 16, Mode::Fp16);
        let b = KneadStats::measure(&ws[1600..], 16, Mode::Fp16);
        a.merge(&b);
        assert_eq!(a.source, whole.source);
        assert_eq!(a.kneaded, whole.kneaded);
        assert_eq!(a.group_len_hist, whole.group_len_hist);
    }
}
