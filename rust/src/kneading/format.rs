//! The kneaded-weight representation: `<w'_i, p>` of Figure 6.
//!
//! A kneaded weight has one *slot* per bit position. A slot either is
//! empty (the comparator in the splitter sees a zero bit and muxes out
//! zero) or holds the pointer `p` of the source weight — within the
//! kneading group — whose essential bit occupies this position. `p` is
//! ⌈log2 KS⌉ bits in hardware; we store it in a byte (KS ≤ 256).

use crate::quant::QWeight;

/// Marker for an empty (slack) slot.
pub const EMPTY_SLOT: u8 = 0xFF;

/// One kneaded weight: `slots[b]` = source-weight pointer whose bit `b`
/// is packed here, or [`EMPTY_SLOT`].
///
/// Slots are stored inline (`[u8; 16]`, the maximum bit width) with an
/// explicit `width` — a kneaded weight is 17 bytes with no heap
/// allocation, which matters in the kneading hot loop (§Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KneadedWeight {
    slots_buf: [u8; 16],
    width: u8,
    /// Bit `b` set ⇔ slot `b` occupied — lets the splitter walk only
    /// essential slots (the comparator array's wired-OR, in software).
    occ: u16,
}

impl KneadedWeight {
    pub fn empty(bits: usize) -> Self {
        debug_assert!(bits <= 16);
        Self { slots_buf: [EMPTY_SLOT; 16], width: bits as u8, occ: 0 }
    }

    /// The slot array, one entry per bit position (LSB first).
    #[inline]
    pub fn slots(&self) -> &[u8] {
        &self.slots_buf[..self.width as usize]
    }

    /// Occupied-slot bitmask (bit `b` ⇔ slot `b` holds a pointer).
    #[inline]
    pub fn occupied_mask(&self) -> u16 {
        self.occ
    }

    /// Pointer in slot `b` (caller checked occupancy via the mask).
    #[inline]
    pub fn pointer(&self, b: usize) -> u8 {
        self.slots_buf[b]
    }

    /// Set slot `b` to point at source weight `p`.
    #[inline]
    pub fn set_slot(&mut self, b: usize, p: u8) {
        debug_assert!(b < self.width as usize);
        debug_assert!(p != EMPTY_SLOT);
        self.slots_buf[b] = p;
        self.occ |= 1 << b;
    }

    /// Number of occupied slots (essential bits carried).
    pub fn occupancy(&self) -> usize {
        self.occ.count_ones() as usize
    }

    /// True if every slot is empty (can only happen for padding).
    pub fn is_empty(&self) -> bool {
        self.occupancy() == 0
    }

    /// Hardware footprint in bits: per slot, 1 valid bit + pointer.
    pub fn storage_bits(&self, pointer_bits: u32) -> usize {
        self.slots().len() * (1 + pointer_bits as usize)
    }
}

/// A kneaded group: the kneaded weights produced from up to `KS`
/// consecutive source weights, plus the per-source metadata the splitter
/// needs (signs) and the pass-mark bookkeeping (§III.C.2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KneadedGroup {
    /// Kneaded weights, densest first (slot queues drain in lane order).
    pub kneaded: Vec<KneadedWeight>,
    /// Bit `p` set ⇒ source weight `p` is negative. Signs ride with the
    /// activation dispatch, not with the packed magnitude bits.
    /// 256 bits — one per possible pointer value (KS ≤ 256).
    pub signs: [u64; 4],
    /// Number of source weights this group covers (== KS except for the
    /// lane tail).
    pub source_len: usize,
}

impl KneadedGroup {
    /// Empty group covering `source_len` sources.
    pub fn with_sources(source_len: usize) -> Self {
        Self { kneaded: Vec::new(), signs: [0; 4], source_len }
    }

    /// Kneaded length — the cycle cost of this group on one splitter.
    pub fn len(&self) -> usize {
        self.kneaded.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kneaded.is_empty()
    }

    /// Sign of source weight `p` as ±1.
    #[inline]
    pub fn sign_of(&self, p: u8) -> i64 {
        if self.signs[(p >> 6) as usize] >> (p & 63) & 1 == 1 {
            -1
        } else {
            1
        }
    }

    /// Record the sign of a source weight during construction.
    pub(crate) fn set_sign(&mut self, p: usize, w: QWeight) {
        if w < 0 {
            self.signs[p >> 6] |= 1 << (p & 63);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_weight_has_zero_occupancy() {
        let k = KneadedWeight::empty(16);
        assert!(k.is_empty());
        assert_eq!(k.occupancy(), 0);
        assert_eq!(k.slots().len(), 16);
    }

    #[test]
    fn storage_bits_counts_pointer_width() {
        let k = KneadedWeight::empty(16);
        assert_eq!(k.storage_bits(4), 16 * 5); // KS=16 → 4-bit p
        assert_eq!(k.storage_bits(5), 16 * 6); // KS=32
    }

    #[test]
    fn signs_pack_into_bitmask() {
        let mut g = KneadedGroup::with_sources(3);
        g.set_sign(0, -5);
        g.set_sign(1, 5);
        g.set_sign(2, -1);
        assert_eq!(g.sign_of(0), -1);
        assert_eq!(g.sign_of(1), 1);
        assert_eq!(g.sign_of(2), -1);
    }
}
