//! The kneading algorithm (Fig 3) and its exact inverse.

use std::sync::atomic::{AtomicU64, Ordering};

use super::format::{KneadedGroup, KneadedWeight, EMPTY_SLOT};
use super::lane::Lane;
use crate::config::Mode;
use crate::quant::QWeight;

/// Process-wide count of [`knead_group`] invocations.
///
/// Observability hook for the compile/execute split (DESIGN.md §I5):
/// kneading is a *compile-time* step, so the serving hot path must not
/// move this counter after a `plan::CompiledNetwork` is built — see
/// `rust/tests/plan_zero_knead.rs`.
static KNEAD_GROUP_CALLS: AtomicU64 = AtomicU64::new(0);

/// Total [`knead_group`] calls made by this process so far.
pub fn knead_call_count() -> u64 {
    KNEAD_GROUP_CALLS.load(Ordering::Relaxed)
}

/// A fully kneaded lane: one [`KneadedGroup`] per KS-sized chunk of the
/// source lane, in order. Groups whose weights are all zero knead to
/// zero kneaded weights and cost zero cycles — the automatic zero-value
/// elimination the paper highlights (w6 in Fig 3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KneadedLane {
    pub groups: Vec<KneadedGroup>,
    pub ks: usize,
    pub bits: usize,
}

impl KneadedLane {
    /// Total kneaded weights — the lane's cycle cost on one splitter.
    pub fn kneaded_len(&self) -> usize {
        self.groups.iter().map(KneadedGroup::len).sum()
    }

    /// Total source weights covered.
    pub fn source_len(&self) -> usize {
        self.groups.iter().map(|g| g.source_len).sum()
    }

    /// Compression ratio ≥ 1 (source / kneaded); `None` for empty lanes.
    pub fn ratio(&self) -> Option<f64> {
        let k = self.kneaded_len();
        if k == 0 {
            return None;
        }
        Some(self.source_len() as f64 / k as f64)
    }
}

/// Knead one group of at most `KS` weights (Fig 3 a→c).
///
/// For each bit position `b`, the source indices whose bit `b` is
/// essential form a queue in lane order; kneaded weight `k` takes the
/// `k`-th entry of every queue. The group kneads to
/// `max_b queue_len(b)` kneaded weights — the per-bit popcount bound.
pub fn knead_group(weights: &[QWeight], mode: Mode) -> KneadedGroup {
    KNEAD_GROUP_CALLS.fetch_add(1, Ordering::Relaxed);
    let bits = mode.weight_bits();
    debug_assert!(weights.len() <= 256, "KS > 256 unsupported (u8 pointers)");
    debug_assert!(weights.iter().all(|&w| crate::quant::fits_mode(w, mode)));

    // Two passes over the group, no per-bit queue allocation (§Perf):
    // pass 1 counts essential bits per position (the kneaded length is
    // their max); pass 2 drops each essential bit at its cursor — the
    // cursors enforce the same lane-order "queue" semantics.
    let mut group = KneadedGroup::with_sources(weights.len());
    let mut counts = [0u16; 16];
    for (i, &w) in weights.iter().enumerate() {
        group.set_sign(i, w);
        let mut mag = w.unsigned_abs();
        if bits < 32 {
            mag &= (1u32 << bits) - 1;
        }
        while mag != 0 {
            counts[mag.trailing_zeros() as usize] += 1;
            mag &= mag - 1;
        }
    }
    let n_kneaded = counts[..bits].iter().copied().max().unwrap_or(0) as usize;
    group.kneaded.resize(n_kneaded, KneadedWeight::empty(bits));
    let mut cursor = [0u16; 16];
    for (i, &w) in weights.iter().enumerate() {
        let mut mag = w.unsigned_abs();
        if bits < 32 {
            mag &= (1u32 << bits) - 1;
        }
        while mag != 0 {
            let b = mag.trailing_zeros() as usize;
            group.kneaded[cursor[b] as usize].set_slot(b, i as u8);
            cursor[b] += 1;
            mag &= mag - 1;
        }
    }
    debug_assert!(group.kneaded.iter().all(|kw| !kw.is_empty()));
    group
}

/// Knead a whole lane with stride `ks`.
pub fn knead_lane(lane: &Lane, ks: usize, mode: Mode) -> KneadedLane {
    let groups = lane
        .weights
        .chunks(ks)
        .map(|chunk| knead_group(chunk, mode))
        .collect();
    KneadedLane { groups, ks, bits: mode.weight_bits() }
}

/// Exact inverse of [`knead_group`]: reconstruct the source weights.
///
/// Proves losslessness (invariant I1 in DESIGN.md): every essential bit
/// appears in exactly one slot, tagged with its source pointer, so the
/// magnitudes rebuild bit-by-bit and the sign mask restores signs.
pub fn unknead_group(group: &KneadedGroup, _mode: Mode) -> Vec<QWeight> {
    let mut mags = vec![0u32; group.source_len];
    for kw in &group.kneaded {
        for (b, &slot) in kw.slots().iter().enumerate() {
            if slot != EMPTY_SLOT {
                let p = slot as usize;
                debug_assert!(p < group.source_len, "pointer out of range");
                debug_assert!(mags[p] >> b & 1 == 0, "duplicate bit");
                mags[p] |= 1 << b;
            }
        }
    }
    mags.iter()
        .enumerate()
        .map(|(i, &m)| group.sign_of(i as u8) as i32 * m as i32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    /// The paper's Figure 3 example, transcribed: 6 weights where w6 is
    /// zero-valued; kneading shrinks 6 cycles to ⌈max popcount⌉.
    #[test]
    fn fig3_style_example() {
        // Bit patterns chosen so bit 0 is essential in w1, w2, w4:
        let ws = [0b0101, 0b0011, 0b1000, 0b0001, 0b0110, 0b0000];
        let g = knead_group(&ws, Mode::Fp16);
        assert_eq!(g.source_len, 6);
        // popcounts per bit: b0: w0,w1,w3 → 3; b1: w1,w4 → 2; b2: w0,w4 → 2; b3: w2 → 1
        assert_eq!(g.len(), 3);
        // First kneaded weight takes the head of every queue.
        assert_eq!(g.kneaded[0].slots()[0], 0);
        assert_eq!(g.kneaded[0].slots()[1], 1);
        assert_eq!(g.kneaded[0].slots()[2], 0);
        assert_eq!(g.kneaded[0].slots()[3], 2);
        // Second takes the next entries.
        assert_eq!(g.kneaded[1].slots()[0], 1);
        assert_eq!(g.kneaded[1].slots()[1], 4);
        assert_eq!(g.kneaded[1].slots()[2], 4);
        assert_eq!(g.kneaded[1].slots()[3], EMPTY_SLOT);
        // Third: only bit 0 remains (w3).
        assert_eq!(g.kneaded[2].slots()[0], 3);
        assert_eq!(g.kneaded[2].occupancy(), 1);
    }

    #[test]
    fn all_zero_group_vanishes() {
        let g = knead_group(&[0, 0, 0, 0], Mode::Fp16);
        assert_eq!(g.len(), 0);
        assert_eq!(g.source_len, 4);
        assert_eq!(unknead_group(&g, Mode::Fp16), vec![0, 0, 0, 0]);
    }

    #[test]
    fn kneaded_length_equals_max_popcount() {
        prop::run(
            "kneaded len == max per-bit popcount",
            |r: &mut Rng| prop::gen::vec_of(r, 1, 16, |r| prop::gen::weight(r, 16)),
            |ws| {
                let g = knead_group(ws, Mode::Fp16);
                let pc = crate::quant::popcount_per_position(ws, 16);
                let want = *pc.iter().max().unwrap() as usize;
                if g.len() == want {
                    Ok(())
                } else {
                    Err(format!("kneaded {} != max popcount {want}", g.len()))
                }
            },
        );
    }

    #[test]
    fn unknead_is_exact_inverse_fp16_and_int8() {
        for mode in [Mode::Fp16, Mode::Int8] {
            let bits = mode.weight_bits() as u32;
            prop::run(
                "unknead(knead(ws)) == ws",
                |r: &mut Rng| prop::gen::vec_of(r, 1, 32, |r| prop::gen::weight(r, bits)),
                |ws| {
                    let g = knead_group(ws, mode);
                    let back = unknead_group(&g, mode);
                    if &back == ws {
                        Ok(())
                    } else {
                        Err(format!("got {back:?}"))
                    }
                },
            );
        }
    }

    #[test]
    fn lane_groups_respect_stride() {
        let mut rng = Rng::new(1);
        let ws: Vec<i32> = (0..50).map(|_| prop::gen::weight(&mut rng, 16)).collect();
        let lane = Lane::new(ws.clone(), vec![1; 50]);
        let kl = knead_lane(&lane, 16, Mode::Fp16);
        assert_eq!(kl.groups.len(), 4); // 16+16+16+2
        assert_eq!(kl.groups[3].source_len, 2);
        assert_eq!(kl.source_len(), 50);
        // Round-trip through all groups reconstructs the lane.
        let mut back = Vec::new();
        for g in &kl.groups {
            back.extend(unknead_group(g, Mode::Fp16));
        }
        assert_eq!(back, ws);
    }

    #[test]
    fn ratio_reflects_compression() {
        // Dense weights (all bits set) cannot compress: ratio == 1.
        let lane = Lane::new(vec![0x7FFF; 16], vec![1; 16]);
        let kl = knead_lane(&lane, 16, Mode::Fp16);
        assert_eq!(kl.kneaded_len(), 16);
        assert!((kl.ratio().unwrap() - 1.0).abs() < 1e-12);
        // One essential bit per weight, different positions: 16 → 1.
        let ws: Vec<i32> = (0..16).map(|b| 1 << b).collect();
        // Top bit folds? 1<<15 magnitude bound is 2^15 exclusive → use 15 bits.
        let ws: Vec<i32> = ws.into_iter().map(|w| if w >= 1 << 15 { 1 << 14 } else { w }).collect();
        let lane = Lane::new(ws, vec![1; 16]);
        let kl = knead_lane(&lane, 16, Mode::Fp16);
        // bits 0..14 unique + duplicate at 14 → max popcount 2.
        assert_eq!(kl.kneaded_len(), 2);
    }
}
