//! Weight kneading (§III.B) — the paper's core compile-time transform.
//!
//! Within a group of `KS` consecutive lane weights, the essential bits of
//! later weights "bubble up" into the zero-bit slack positions of earlier
//! ones (Fig 3). Each bit slot of a kneaded weight carries the pointer
//! `p` of the source weight it came from (Fig 6), so the splitter can
//! reference the right activation. Kneading is lossless: `unknead`
//! reproduces the original weights exactly, and SAC over kneaded weights
//! produces bit-identical partial sums (see `sac::unit` tests and
//! `rust/tests/invariants.rs`).

mod format;
mod kneader;
mod lane;
pub mod stats;

pub use format::{KneadedGroup, KneadedWeight, EMPTY_SLOT};
pub use kneader::{knead_call_count, knead_group, knead_lane, unknead_group, KneadedLane};
pub use lane::Lane;
