//! Request/response types for the serving path.

use std::time::Instant;

use crate::model::Tensor;

/// Monotonic request identifier.
pub type RequestId = u64;

/// One inference request: a quantized Q8.8 image (1×16×16 for the tiny
/// CNN) plus bookkeeping.
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub id: RequestId,
    /// (C, H, W) int32 Q8.8 image.
    pub image: Tensor<i32>,
    /// Enqueue timestamp (latency accounting).
    pub enqueued: Instant,
}

impl InferRequest {
    pub fn new(id: RequestId, image: Tensor<i32>) -> Self {
        Self { id, image, enqueued: Instant::now() }
    }
}

/// Response: logits + latency + the simulated accelerator cycle cost of
/// the batch this request rode in.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: RequestId,
    pub logits: Vec<i32>,
    pub argmax: usize,
    /// Wall-clock time from enqueue to completion.
    pub latency_us: f64,
    /// Simulated Tetris cycles attributed to this request's batch.
    pub sim_cycles: u64,
    /// Batch size this request was served in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_tracks_enqueue_time() {
        let r = InferRequest::new(1, Tensor::zeros(&[1, 4, 4]));
        assert!(r.enqueued.elapsed().as_secs() < 1);
        assert_eq!(r.id, 1);
    }
}
