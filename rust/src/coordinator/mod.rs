//! Serving substrate — request types, dynamic batcher, metrics and
//! backends the [`engine`](crate::engine) façade drives.
//!
//! A vLLM-router-shaped pipeline scaled to this paper's system:
//! requests enter a queue, the *dynamic batcher* groups them (max batch
//! size or deadline, whichever first), the dispatcher routes batches to
//! a shared worker pool, and each worker runs an [`InferBackend`] —
//! either the pure-rust kneaded-SAC integer pipeline or the
//! AOT-compiled XLA golden model (PJRT). A timing model attaches
//! simulated accelerator latency so the serving metrics reflect the
//! paper's hardware, not the host CPU.
//!
//! The routing loop itself lives in the engine
//! (`engine::serve::EngineCore`, multi-model); [`Server`] remains as a
//! thin single-model shim over it for the pre-engine API. New code
//! should use [`Engine::builder`](crate::engine::Engine::builder).
//!
//! Python is never on this path: backends consume `artifacts/` products
//! only.

pub mod backend;
pub mod batcher;
pub mod demo;
pub mod metrics;
pub mod request;
pub mod server;

pub use backend::{InferBackend, PjrtBackend, SacBackend};
pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{LatencyPercentiles, Metrics};
pub use request::{InferRequest, InferResponse, RequestId};
pub use server::{Server, ServerConfig};
