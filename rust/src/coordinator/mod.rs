//! Serving coordinator — the L3 request path.
//!
//! A vLLM-router-shaped engine scaled to this paper's system: requests
//! enter a queue, the *dynamic batcher* groups them (max batch size or
//! deadline, whichever first), the *scheduler* dispatches batches to PE
//! workers, and each worker runs an [`InferBackend`] — either the
//! AOT-compiled XLA golden model (PJRT) or the pure-rust kneaded-SAC
//! integer pipeline. A timing model attaches simulated accelerator
//! latency so the serving metrics reflect the paper's hardware, not the
//! host CPU.
//!
//! Python is never on this path: backends consume `artifacts/` products
//! only.

pub mod backend;
pub mod batcher;
pub mod demo;
pub mod metrics;
pub mod request;
pub mod server;

pub use backend::{InferBackend, SacBackend};
pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use request::{InferRequest, InferResponse, RequestId};
pub use server::{Server, ServerConfig};
