//! Inference backends the workers run.
//!
//! * [`SacBackend`] — the pure-rust kneaded-SAC integer pipeline over
//!   quantized weights (from `artifacts/weights.bin` or synthetic).
//!   Construction compiles the weights into a
//!   [`plan::CompiledNetwork`](crate::plan::CompiledNetwork) — every
//!   lane is kneaded exactly once, up front — so the per-batch serving
//!   path performs **zero** kneading (pinned by
//!   `rust/tests/plan_zero_knead.rs`). The plan is held behind an
//!   [`Arc`], so cloning the backend *shares* it: an engine with W
//!   workers cloning one prototype kneads one network total, not W.
//! * [`PjrtBackend`] — the AOT XLA golden model through PJRT. Handles
//!   are thread-pinned, so each worker constructs its own backend
//!   (the engine's PJRT lane does exactly that).
//!
//! Callers should not pick between these by hand: the
//! [`engine`](crate::engine) façade constructs either behind one
//! [`BackendKind`](crate::engine::BackendKind) path.
//!
//! Both backends also report a *simulated* Tetris cycle cost per batch
//! so the serving metrics reflect the accelerator, not the host.

use std::path::Path;
use std::sync::Arc;

use crate::config::{AccelConfig, CalibConfig};
use crate::engine::env;
use crate::model::zoo;
use crate::model::{LoadedWeights, Tensor};
use crate::plan::CompiledNetwork;
use crate::runtime::artifacts::ArtifactDir;
use crate::runtime::pjrt::{Engine as PjrtClient, LoadedModel as PjrtModel};
use crate::runtime::quantized;
use crate::sim::{sample::samples_from_loaded, simulate_network_with_samples, tetris::TetrisSim};
use crate::util::pool::worker_count;

/// A batch-inference backend.
pub trait InferBackend {
    /// Run a batch: images (N,C,H,W) Q8.8 → per-request logits.
    fn infer_batch(&mut self, images: &Tensor<i32>) -> crate::Result<Vec<Vec<i32>>>;

    /// Simulated accelerator cycles for a batch of `n` images.
    fn sim_cycles(&self, n: usize) -> u64;

    /// Cumulative activation-skip counters — `(skipped rows, skipped
    /// windows, total windows)` over every batch this backend (and,
    /// for `Arc`-sharing clones, its siblings) has served. `None` for
    /// backends whose plan does not run the zero-activation skip lane
    /// (the default, and always for PJRT).
    fn skip_counters(&self) -> Option<(u64, u64, u64)> {
        None
    }

    /// Cumulative SAC energy counters — `(splitter slot decodes,
    /// segment-register adds)` over every traced batch this backend
    /// (and its `Arc`-sharing clones) has served, matching `sim`'s
    /// activity accounting for the conv trunk. `None` for backends
    /// that don't execute traced (the default, and always for PJRT).
    fn sac_counters(&self) -> Option<(u64, u64)> {
        None
    }

    fn name(&self) -> &'static str;
}

/// Simulated Tetris cycles for ONE image of the tiny CNN under the
/// given weight set's bit statistics — shared by both backends so the
/// serving metrics stay comparable across them.
fn tiny_cnn_sim_cycles(weights: &LoadedWeights) -> crate::Result<u64> {
    let net = zoo::tiny_cnn();
    let cfg = AccelConfig::default();
    let calib = CalibConfig::default();
    let samples = samples_from_loaded(&net, weights)?;
    Ok(simulate_network_with_samples(&TetrisSim, &net, &samples, &cfg, &calib).total_cycles())
}

/// Pure-rust kneaded-SAC backend over a compile-once execution plan.
///
/// Cloning is cheap and *shares* the compiled plan (an `Arc`): clones
/// never re-knead. Hand one prototype to the engine (or the legacy
/// `Server::start_shared` shim) and every worker streams the same
/// resident lanes.
#[derive(Clone)]
pub struct SacBackend {
    /// Pre-kneaded network — built once, shared by every clone.
    plan: Arc<CompiledNetwork>,
    /// Pre-simulated Tetris cycles for ONE image.
    cycles_per_image: u64,
    /// Engine-wide activation-skip totals, shared (like the plan) by
    /// every clone — W workers accumulate into one set of counters,
    /// so `skip_counters` reports the whole engine's skip rate.
    skip_totals: Arc<SkipTotals>,
}

/// Cumulative zero-activation skip + SAC energy counters for one
/// shared plan (populated by traced execution — skip-armed serving).
#[derive(Default)]
struct SkipTotals {
    rows: std::sync::atomic::AtomicU64,
    windows: std::sync::atomic::AtomicU64,
    total_windows: std::sync::atomic::AtomicU64,
    slot_decodes: std::sync::atomic::AtomicU64,
    segment_adds: std::sync::atomic::AtomicU64,
}

impl SacBackend {
    /// Build from loaded weights (tiny-CNN shaped). Kneading happens
    /// here, once; `infer_batch` only streams the kneaded lanes. The
    /// serving tile height — which doubles as the streaming walk's
    /// ring-advance step, so one knob bounds the ring depth of
    /// whichever walk `execute` picks — comes from the
    /// `TETRIS_MEM_BUDGET_MB` fallback ([`env::mem_budget_bytes`]) —
    /// engine-registered models resolve their budget through the typed
    /// builder instead.
    pub fn new(weights: LoadedWeights) -> crate::Result<Self> {
        let cycles = tiny_cnn_sim_cycles(&weights)?;
        let mut plan = quantized::compile_tiny_cnn(&weights)?;
        // Serving schedules through the same auto-tuner entry point as
        // the engine registry (`plan::tune`, memoized), so the legacy
        // path and the engine façade can never disagree on the
        // walk/tile a given (budget, workers) pair yields.
        let tuned = crate::plan::tune::tune(&plan, env::mem_budget_bytes(), worker_count());
        tuned.apply(&mut plan);
        Ok(Self::from_parts(Arc::new(plan), cycles))
    }

    /// Wrap an already-compiled plan (any network, not just the tiny
    /// CNN) plus its pre-simulated per-image cycle cost — the
    /// constructor the engine's model registry uses. Performs no
    /// kneading: the plan was compiled exactly once by the caller.
    pub fn from_parts(plan: Arc<CompiledNetwork>, cycles_per_image: u64) -> Self {
        Self { plan, cycles_per_image, skip_totals: Arc::new(SkipTotals::default()) }
    }

    /// Synthetic-weight backend (no artifacts needed — demos/tests).
    pub fn synthetic(seed: u64) -> crate::Result<Self> {
        Self::new(Self::synthetic_weights(seed)?)
    }

    /// Synthetic tiny-CNN weight set (conv1..conv3 + fc) calibrated to
    /// the Fig 2 bit profile — shared by demos, benches and tests.
    pub fn synthetic_weights(seed: u64) -> crate::Result<LoadedWeights> {
        use crate::config::Mode;
        use crate::model::weights::{profile_with, DensityCalibration};
        use crate::model::LoadedLayer;
        use crate::util::rng::Rng;
        let net = zoo::tiny_cnn();
        let mut rng = Rng::new(seed);
        let profile = profile_with("tiny_cnn", Mode::Fp16, DensityCalibration::Fig2)?;
        let mut layers = Vec::new();
        for l in &net.layers {
            layers.push(LoadedLayer {
                name: l.name.clone(),
                shape: [l.out_c, l.in_c, l.k, l.k],
                frac_bits: 15,
                weights: profile.generate(l.weight_count() as usize, &mut rng),
            });
        }
        layers.push(LoadedLayer {
            name: "fc".into(),
            shape: [4, 16, 1, 1],
            frac_bits: 15,
            weights: profile.generate(64, &mut rng),
        });
        Ok(LoadedWeights { mode: Mode::Fp16, layers })
    }

    /// The backend's compiled plan (introspection: kneaded footprint,
    /// op graph).
    pub fn plan(&self) -> &CompiledNetwork {
        &self.plan
    }

    /// The shared handle to the compiled plan — clone count reveals how
    /// many workers currently share it.
    pub fn shared_plan(&self) -> Arc<CompiledNetwork> {
        Arc::clone(&self.plan)
    }
}

impl InferBackend for SacBackend {
    fn infer_batch(&mut self, images: &Tensor<i32>) -> crate::Result<Vec<Vec<i32>>> {
        use std::sync::atomic::Ordering::Relaxed;
        // Zero kneading here: the plan streams lanes kneaded at build.
        // A skip-armed plan executes traced so the zero-activation
        // counters surface in the serving metrics (the trace costs a
        // handful of atomics, no extra feature-map allocation); logits
        // are bit-identical either way (I5 — skipping is exact).
        let out = if self.plan.skip_zero_activations {
            let (out, stats) = self.plan.execute_traced(images, crate::plan::ExecOpts::default())?;
            self.skip_totals.rows.fetch_add(stats.skipped_rows(), Relaxed);
            self.skip_totals.windows.fetch_add(stats.skipped_windows(), Relaxed);
            self.skip_totals.total_windows.fetch_add(stats.total_windows(), Relaxed);
            self.skip_totals.slot_decodes.fetch_add(stats.slot_decodes(), Relaxed);
            self.skip_totals.segment_adds.fetch_add(stats.segment_adds(), Relaxed);
            out
        } else {
            self.plan.execute(images)?
        };
        let n = match out.shape() {
            [] => return Err(crate::Error::Shape("scalar plan output".into())),
            s => s[0],
        };
        // (N, classes) logits for classifier plans; conv-only plans
        // yield a flattened per-image feature map instead.
        let per = out.len() / n.max(1);
        Ok((0..n).map(|i| out.data()[i * per..(i + 1) * per].to_vec()).collect())
    }

    fn sim_cycles(&self, n: usize) -> u64 {
        self.cycles_per_image * n as u64
    }

    fn skip_counters(&self) -> Option<(u64, u64, u64)> {
        use std::sync::atomic::Ordering::Relaxed;
        if !self.plan.skip_zero_activations {
            return None;
        }
        Some((
            self.skip_totals.rows.load(Relaxed),
            self.skip_totals.windows.load(Relaxed),
            self.skip_totals.total_windows.load(Relaxed),
        ))
    }

    fn sac_counters(&self) -> Option<(u64, u64)> {
        use std::sync::atomic::Ordering::Relaxed;
        // Populated by the same traced branch as the skip counters —
        // untraced serving (skip lane off) has nothing to report.
        if !self.plan.skip_zero_activations {
            return None;
        }
        Some((
            self.skip_totals.slot_decodes.load(Relaxed),
            self.skip_totals.segment_adds.load(Relaxed),
        ))
    }

    fn name(&self) -> &'static str {
        "sac-rust"
    }
}

/// The AOT XLA golden model served through PJRT.
///
/// Construct **per worker thread** — PJRT handles are thread-pinned,
/// so this type is deliberately not `Clone`. The engine's
/// [`BackendKind::Pjrt`](crate::engine::BackendKind) lane calls
/// [`PjrtBackend::from_artifacts`] once per worker. The executable was
/// AOT-lowered at a fixed batch size; incoming batches are chunked and
/// zero-padded to it. The golden model computes in f32, so logits are
/// requantized to Q8.8 on the way out — numerically faithful to the
/// trained model, **not** bit-exact with the integer SAC pipeline.
pub struct PjrtBackend {
    /// Keeps the PJRT client alive for the executable's lifetime.
    _client: PjrtClient,
    model: PjrtModel,
    /// AOT input shape, NCHW: `[batch, c, h, w]`.
    in_shape: [usize; 4],
    classes: usize,
    cycles_per_image: u64,
}

impl PjrtBackend {
    /// Load + compile `golden_cnn.hlo.txt` from an artifacts
    /// directory, simulating the per-image cycle cost from the
    /// directory's trained weights. Errors with [`crate::Error::Xla`]
    /// when built without the `xla` + `xla-vendored` features, and
    /// with an artifact error when the directory lacks the AOT
    /// products.
    pub fn from_artifacts(dir: &Path) -> crate::Result<Self> {
        let cycles = tiny_cnn_sim_cycles(&ArtifactDir::open(dir)?.load_weights()?)?;
        Self::from_artifacts_with_cycles(dir, cycles)
    }

    /// [`PjrtBackend::from_artifacts`] with a precomputed per-image
    /// cycle cost — the engine's PJRT lane simulates once at build and
    /// hands the value to every per-worker construction, so W workers
    /// pay W executable compiles (unavoidable: handles are
    /// thread-pinned) but only one weight load + simulation.
    pub fn from_artifacts_with_cycles(dir: &Path, cycles_per_image: u64) -> crate::Result<Self> {
        let client = PjrtClient::cpu()?;
        let art = ArtifactDir::open(dir)?;
        let model = client.load_hlo_text(&art.path("golden_cnn.hlo.txt"))?;
        let in_shape: Vec<usize> =
            art.shape("golden", "input_shape")?.iter().map(|&d| d as usize).collect();
        let out_shape: Vec<usize> =
            art.shape("golden", "output_shape")?.iter().map(|&d| d as usize).collect();
        let in_shape: [usize; 4] = match in_shape[..] {
            [n, c, h, w] => [n, c, h, w],
            _ => {
                return Err(crate::Error::Artifact(format!(
                    "golden input_shape {in_shape:?} is not NCHW"
                )))
            }
        };
        let classes = match out_shape[..] {
            [n, k] if n == in_shape[0] => k,
            _ => {
                return Err(crate::Error::Artifact(format!(
                    "golden output_shape {out_shape:?} does not match batch {}",
                    in_shape[0]
                )))
            }
        };
        Ok(Self { _client: client, model, in_shape, classes, cycles_per_image })
    }

    /// Input channels the executable expects (submission validation).
    pub fn input_channels(&self) -> usize {
        self.in_shape[1]
    }

    /// Input spatial size the executable expects (square).
    pub fn input_hw(&self) -> usize {
        self.in_shape[2]
    }
}

impl InferBackend for PjrtBackend {
    fn infer_batch(&mut self, images: &Tensor<i32>) -> crate::Result<Vec<Vec<i32>>> {
        let (n, c, h, w) = match *images.shape() {
            [n, c, h, w] => (n, c, h, w),
            _ => return Err(crate::Error::Shape("batch must be 4-D NCHW".into())),
        };
        let [aot_n, ac, ah, aw] = self.in_shape;
        if (c, h, w) != (ac, ah, aw) {
            return Err(crate::Error::Shape(format!(
                "golden model takes {ac}×{ah}×{aw} images, got {c}×{h}×{w}"
            )));
        }
        let plane = c * h * w;
        let dims: Vec<i64> = self.in_shape.iter().map(|&d| d as i64).collect();
        let src = images.data();
        let mut out = Vec::with_capacity(n);
        // Chunk to the AOT batch, zero-padding the tail chunk.
        let mut start = 0;
        while start < n {
            let m = (n - start).min(aot_n);
            let mut buf = vec![0f32; aot_n * plane];
            for (dst, &v) in buf.iter_mut().zip(&src[start * plane..(start + m) * plane]) {
                *dst = v as f32 / 256.0; // Q8.8 → float
            }
            let logits = self.model.run_f32(&[(&buf, &dims)])?;
            if logits.len() != aot_n * self.classes {
                return Err(crate::Error::Xla(format!(
                    "golden model returned {} logits for batch {aot_n}×{}",
                    logits.len(),
                    self.classes
                )));
            }
            for row in logits.chunks(self.classes).take(m) {
                // float → Q8.8
                out.push(row.iter().map(|&v| (v * 256.0).round() as i32).collect());
            }
            start += m;
        }
        Ok(out)
    }

    fn sim_cycles(&self, n: usize) -> u64 {
        self.cycles_per_image * n as u64
    }

    fn name(&self) -> &'static str {
        "pjrt-xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_backend_infers() {
        let mut b = SacBackend::synthetic(7).unwrap();
        let images = Tensor::zeros(&[2, 1, 16, 16]);
        let out = b.infer_batch(&images).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 4);
        assert!(b.sim_cycles(2) > 0);
        assert_eq!(b.sim_cycles(4), 2 * b.sim_cycles(2));
    }

    #[test]
    fn deterministic_outputs() {
        let mut a = SacBackend::synthetic(3).unwrap();
        let mut b = SacBackend::synthetic(3).unwrap();
        let mut img = Tensor::zeros(&[1, 1, 16, 16]);
        for (i, v) in img.data_mut().iter_mut().enumerate() {
            *v = (i as i32 % 61) - 30;
        }
        assert_eq!(a.infer_batch(&img).unwrap(), b.infer_batch(&img).unwrap());
    }

    #[test]
    fn backend_matches_legacy_scalar_pipeline() {
        // The plan-backed serving path must be bit-identical to the
        // seed's re-knead-per-call forward (invariant I5).
        let w = SacBackend::synthetic_weights(11).unwrap();
        let mut backend = SacBackend::new(w.clone()).unwrap();
        let mut img = Tensor::zeros(&[2, 1, 16, 16]);
        for (i, v) in img.data_mut().iter_mut().enumerate() {
            *v = (i as i32 % 251) - 125;
        }
        let got = backend.infer_batch(&img).unwrap();
        let want = quantized::forward_scalar(&w, &img).unwrap();
        for (i, row) in got.iter().enumerate() {
            assert_eq!(row[..], want.data()[i * 4..(i + 1) * 4]);
        }
    }

    #[test]
    fn plan_is_exposed_for_introspection() {
        let b = SacBackend::synthetic(2).unwrap();
        assert_eq!(b.plan().kneads_at_build, 8 + 16 + 16 + 4);
        assert!(b.plan().kneaded_weights() > 0);
        // Serving picked a tile height the default budget can hold.
        let rows = b.plan().tile_rows;
        assert!(rows >= 1);
        assert!(
            b.plan().peak_bytes_estimate(rows, crate::util::pool::worker_count())
                <= env::mem_budget_bytes()
                || rows == 1,
            "serving tile height blows the memory budget"
        );
    }

    #[test]
    fn clones_share_one_compiled_plan() {
        // The clone must alias the prototype's plan, not re-compile it
        // (what makes shared serving knead once for W workers).
        let proto = SacBackend::synthetic(4).unwrap();
        let clone = proto.clone();
        assert!(Arc::ptr_eq(&proto.shared_plan(), &clone.shared_plan()));
        let mut a = proto.clone();
        let mut b = clone.clone();
        let img = Tensor::zeros(&[1, 1, 16, 16]);
        assert_eq!(a.infer_batch(&img).unwrap(), b.infer_batch(&img).unwrap());
    }

    #[test]
    fn from_parts_wraps_arbitrary_plans() {
        // A non-tiny network through the generic constructor: logits
        // rows must match the plan's own execute output.
        use crate::config::Mode;
        use crate::model::weights::{synthetic_loaded, DensityCalibration};
        let net = zoo::nin().scaled(32, 64);
        let w = synthetic_loaded(&net, Mode::Fp16, 10, "nin", DensityCalibration::Fig2, 5)
            .unwrap();
        let plan =
            Arc::new(CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap());
        let mut backend = SacBackend::from_parts(Arc::clone(&plan), 1000);
        let mut x = Tensor::zeros(&[2, net.layers[0].in_c, 64, 64]);
        for (i, v) in x.data_mut().iter_mut().enumerate() {
            *v = (i as i32 % 401) - 200;
        }
        let rows = backend.infer_batch(&x).unwrap();
        let want = plan.execute(&x).unwrap();
        let per = want.len() / 2;
        assert_eq!(rows.len(), 2);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row[..], want.data()[i * per..(i + 1) * per]);
        }
        assert_eq!(backend.sim_cycles(3), 3000);
    }

    #[cfg(not(all(feature = "xla", feature = "xla-vendored")))]
    #[test]
    fn pjrt_backend_reports_missing_runtime() {
        match PjrtBackend::from_artifacts(Path::new("artifacts")) {
            Err(crate::Error::Xla(msg)) => assert!(msg.contains("xla"), "{msg}"),
            other => panic!("expected Xla error, got {:?}", other.map(|_| ())),
        }
    }
}
