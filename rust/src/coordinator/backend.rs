//! Inference backends the workers run.
//!
//! * [`SacBackend`] — the pure-rust kneaded-SAC integer pipeline over
//!   quantized weights (from `artifacts/weights.bin` or synthetic).
//!   Construction compiles the weights into a
//!   [`plan::CompiledNetwork`](crate::plan::CompiledNetwork) — every
//!   lane is kneaded exactly once, up front — so the per-batch serving
//!   path performs **zero** kneading (pinned by
//!   `rust/tests/plan_zero_knead.rs`). The plan is held behind an
//!   [`Arc`], so cloning the backend *shares* it: a server with W
//!   workers cloning one prototype (see
//!   [`Server::start_shared`](super::server::Server::start_shared))
//!   kneads one network total, not W.
//! * `PjrtBackend` (constructed per-thread via
//!   [`super::server::Server::serve_with_pjrt`]) — the AOT XLA golden
//!   model; PJRT handles are thread-pinned.
//!
//! Both also report a *simulated* Tetris cycle cost per batch so the
//! serving metrics reflect the accelerator, not the host.

use std::sync::Arc;

use crate::config::{AccelConfig, CalibConfig};
use crate::model::zoo;
use crate::model::{LoadedWeights, Tensor};
use crate::plan::CompiledNetwork;
use crate::runtime::quantized;
use crate::sim::{sample::samples_from_loaded, simulate_network_with_samples, tetris::TetrisSim};
use crate::util::pool::worker_count;

/// Per-worker feature-map memory budget for serving, in bytes:
/// `TETRIS_MEM_BUDGET_MB` (default 256). Construction-time knob — the
/// backend turns it into a fused-tile height via
/// [`CompiledNetwork::tile_rows_for_budget`], so a tighter budget
/// trades halo recompute for a lower resident peak instead of OOMing.
fn serving_mem_budget_bytes() -> u64 {
    std::env::var("TETRIS_MEM_BUDGET_MB")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(256)
        .max(1)
        * 1024
        * 1024
}

/// A batch-inference backend.
pub trait InferBackend {
    /// Run a batch: images (N,C,H,W) Q8.8 → per-request logits.
    fn infer_batch(&mut self, images: &Tensor<i32>) -> crate::Result<Vec<Vec<i32>>>;

    /// Simulated accelerator cycles for a batch of `n` images.
    fn sim_cycles(&self, n: usize) -> u64;

    fn name(&self) -> &'static str;
}

/// Pure-rust kneaded-SAC backend over a compile-once execution plan.
///
/// Cloning is cheap and *shares* the compiled plan (an `Arc`): clones
/// never re-knead. Hand one prototype to
/// [`Server::start_shared`](super::server::Server::start_shared) and
/// every worker streams the same resident lanes.
#[derive(Clone)]
pub struct SacBackend {
    /// Pre-kneaded network — built once, shared by every clone.
    plan: Arc<CompiledNetwork>,
    /// Pre-simulated Tetris cycles for ONE image of the tiny CNN.
    cycles_per_image: u64,
}

impl SacBackend {
    /// Build from loaded weights (tiny-CNN shaped). Kneading happens
    /// here, once; `infer_batch` only streams the kneaded lanes.
    pub fn new(weights: LoadedWeights) -> crate::Result<Self> {
        let net = zoo::tiny_cnn();
        let cfg = AccelConfig::default();
        let calib = CalibConfig::default();
        // Timing from the real weights' bit statistics.
        let conv_only: Vec<_> = weights
            .layers
            .iter()
            .filter(|l| l.name != "fc")
            .cloned()
            .collect();
        let conv_weights = LoadedWeights { mode: weights.mode, layers: conv_only };
        let samples = samples_from_loaded(&net, &conv_weights)?;
        let sim = simulate_network_with_samples(&TetrisSim, &net, &samples, &cfg, &calib);
        let mut plan = quantized::compile_tiny_cnn(&weights)?;
        // Serving picks its fused-tile height from the memory budget:
        // the largest tile whose estimated peak (per image, at the
        // worker fan-out) stays inside TETRIS_MEM_BUDGET_MB.
        plan.tile_rows = plan.tile_rows_for_budget(serving_mem_budget_bytes(), worker_count());
        let plan = Arc::new(plan);
        Ok(Self { plan, cycles_per_image: sim.total_cycles() })
    }

    /// Synthetic-weight backend (no artifacts needed — demos/tests).
    pub fn synthetic(seed: u64) -> crate::Result<Self> {
        Self::new(Self::synthetic_weights(seed)?)
    }

    /// Synthetic tiny-CNN weight set (conv1..conv3 + fc) calibrated to
    /// the Fig 2 bit profile — shared by demos, benches and tests.
    pub fn synthetic_weights(seed: u64) -> crate::Result<LoadedWeights> {
        use crate::config::Mode;
        use crate::model::weights::{profile_with, DensityCalibration};
        use crate::model::LoadedLayer;
        use crate::util::rng::Rng;
        let net = zoo::tiny_cnn();
        let mut rng = Rng::new(seed);
        let profile = profile_with("tiny_cnn", Mode::Fp16, DensityCalibration::Fig2)?;
        let mut layers = Vec::new();
        for l in &net.layers {
            layers.push(LoadedLayer {
                name: l.name.clone(),
                shape: [l.out_c, l.in_c, l.k, l.k],
                frac_bits: 15,
                weights: profile.generate(l.weight_count() as usize, &mut rng),
            });
        }
        layers.push(LoadedLayer {
            name: "fc".into(),
            shape: [4, 16, 1, 1],
            frac_bits: 15,
            weights: profile.generate(64, &mut rng),
        });
        Ok(LoadedWeights { mode: Mode::Fp16, layers })
    }

    /// The backend's compiled plan (introspection: kneaded footprint,
    /// op graph).
    pub fn plan(&self) -> &CompiledNetwork {
        &self.plan
    }

    /// The shared handle to the compiled plan — clone count reveals how
    /// many workers currently share it.
    pub fn shared_plan(&self) -> Arc<CompiledNetwork> {
        Arc::clone(&self.plan)
    }
}

impl InferBackend for SacBackend {
    fn infer_batch(&mut self, images: &Tensor<i32>) -> crate::Result<Vec<Vec<i32>>> {
        // Zero kneading here: the plan streams lanes kneaded at build.
        let logits = self.plan.execute(images)?;
        let [n, c] = match *logits.shape() {
            [n, c] => [n, c],
            _ => return Err(crate::Error::Shape("logits must be 2-D".into())),
        };
        Ok((0..n).map(|i| logits.data()[i * c..(i + 1) * c].to_vec()).collect())
    }

    fn sim_cycles(&self, n: usize) -> u64 {
        self.cycles_per_image * n as u64
    }

    fn name(&self) -> &'static str {
        "sac-rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_backend_infers() {
        let mut b = SacBackend::synthetic(7).unwrap();
        let images = Tensor::zeros(&[2, 1, 16, 16]);
        let out = b.infer_batch(&images).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 4);
        assert!(b.sim_cycles(2) > 0);
        assert_eq!(b.sim_cycles(4), 2 * b.sim_cycles(2));
    }

    #[test]
    fn deterministic_outputs() {
        let mut a = SacBackend::synthetic(3).unwrap();
        let mut b = SacBackend::synthetic(3).unwrap();
        let mut img = Tensor::zeros(&[1, 1, 16, 16]);
        for (i, v) in img.data_mut().iter_mut().enumerate() {
            *v = (i as i32 % 61) - 30;
        }
        assert_eq!(a.infer_batch(&img).unwrap(), b.infer_batch(&img).unwrap());
    }

    #[test]
    fn backend_matches_legacy_scalar_pipeline() {
        // The plan-backed serving path must be bit-identical to the
        // seed's re-knead-per-call forward (invariant I5).
        let w = SacBackend::synthetic_weights(11).unwrap();
        let mut backend = SacBackend::new(w.clone()).unwrap();
        let mut img = Tensor::zeros(&[2, 1, 16, 16]);
        for (i, v) in img.data_mut().iter_mut().enumerate() {
            *v = (i as i32 % 251) - 125;
        }
        let got = backend.infer_batch(&img).unwrap();
        let want = quantized::forward_scalar(&w, &img).unwrap();
        for (i, row) in got.iter().enumerate() {
            assert_eq!(row[..], want.data()[i * 4..(i + 1) * 4]);
        }
    }

    #[test]
    fn plan_is_exposed_for_introspection() {
        let b = SacBackend::synthetic(2).unwrap();
        assert_eq!(b.plan().kneads_at_build, 8 + 16 + 16 + 4);
        assert!(b.plan().kneaded_weights() > 0);
        // Serving picked a tile height the default budget can hold.
        let rows = b.plan().tile_rows;
        assert!(rows >= 1);
        assert!(
            b.plan().peak_bytes_estimate(rows, crate::util::pool::worker_count())
                <= serving_mem_budget_bytes()
                || rows == 1,
            "serving tile height blows the memory budget"
        );
    }

    #[test]
    fn clones_share_one_compiled_plan() {
        // The clone must alias the prototype's plan, not re-compile it
        // (what makes `Server::start_shared` knead once for W workers).
        let proto = SacBackend::synthetic(4).unwrap();
        let clone = proto.clone();
        assert!(Arc::ptr_eq(&proto.shared_plan(), &clone.shared_plan()));
        let mut a = proto.clone();
        let mut b = clone.clone();
        let img = Tensor::zeros(&[1, 1, 16, 16]);
        assert_eq!(a.infer_batch(&img).unwrap(), b.infer_batch(&img).unwrap());
    }
}
