//! Legacy single-model serving handle — a thin shim over the engine's
//! shared core.
//!
//! The routing loop (request channel → dynamic batcher → worker pool →
//! response channel) now lives in `engine::serve::EngineCore`, where it
//! serves a whole model registry; `Server` wraps a single-lane core to
//! keep the pre-engine API (and its behavior tests — exactly-once
//! delivery, value transparency I6) working unchanged.
//!
//! **Deprecated surface**: new code should build an
//! [`Engine`](crate::engine::Engine) via
//! [`Engine::builder`](crate::engine::Engine::builder) and talk to it
//! through [`InferSession`](crate::engine::InferSession) — see
//! DESIGN.md §Engine API for the old-to-new mapping.

use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};

use crate::engine::serve::{BackendFactory, Completion, EngineCore, ModelLane};

use super::backend::InferBackend;
use super::batcher::BatchPolicy;
use super::metrics::Metrics;
use super::request::{InferRequest, InferResponse};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// Worker threads (each owns one backend instance).
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), workers: 2 }
    }
}

/// Handle to a running single-model server: submit requests, receive
/// responses. Prefer [`Engine`](crate::engine::Engine) — this type
/// remains as a compatibility shim over the same serving core.
pub struct Server {
    core: EngineCore,
    /// Mutex so `recv` takes `&self` and `Server` stays `Sync` (drain
    /// from a different thread than the submitter).
    resp_rx: Mutex<Receiver<Completion>>,
}

impl Server {
    /// Start the server with one prototype backend cloned into every
    /// worker. For backends whose clone shares compiled state — e.g.
    /// [`SacBackend`](super::backend::SacBackend), whose
    /// `Arc<CompiledNetwork>` plan is aliased by clones — W workers
    /// cost exactly one network compile (one knead per lane total,
    /// pinned by `rust/tests/plan_zero_knead.rs`), not W.
    pub fn start_shared<B>(config: ServerConfig, prototype: B) -> crate::Result<Self>
    where
        B: InferBackend + Clone + Send + Sync + 'static,
    {
        Self::start(config, move |_| Ok(prototype.clone()))
    }

    /// Start the server. `make_backend` is called once per worker
    /// thread (backends need not be `Sync`; they must be creatable per
    /// thread — PJRT executables satisfy this). Backends that *are*
    /// cheaply clonable should go through [`Server::start_shared`]
    /// instead, so workers share one compiled plan.
    pub fn start<B, F>(config: ServerConfig, make_backend: F) -> crate::Result<Self>
    where
        B: InferBackend + 'static,
        F: Fn(usize) -> crate::Result<B> + Send + Sync + 'static,
    {
        let factory: BackendFactory =
            Arc::new(move |w| make_backend(w).map(|b| Box::new(b) as Box<dyn InferBackend>));
        let (core, resp_rx) =
            EngineCore::start(config.workers, config.policy, vec![ModelLane { factory }])?;
        Ok(Self { core, resp_rx: Mutex::new(resp_rx) })
    }

    /// Submit a request (non-blocking).
    pub fn submit(&self, req: InferRequest) -> crate::Result<()> {
        self.core.submit(0, req)
    }

    /// Receive the next response (blocking). A request whose batch
    /// failed at the backend surfaces as a typed error (historically
    /// it was dropped and the caller hung).
    pub fn recv(&self) -> crate::Result<InferResponse> {
        let completion = self
            .resp_rx
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| crate::Error::Coordinator("server stopped".into()))?;
        match completion {
            Completion::Done(r) => Ok(r),
            Completion::Failed { id, error } => Err(crate::Error::Coordinator(format!(
                "request {id} failed: {error}"
            ))),
        }
    }

    /// Snapshot metrics.
    pub fn metrics(&self) -> Metrics {
        self.core.metrics()
    }

    /// Stop accepting requests, drain, and join all threads.
    pub fn shutdown(mut self) -> Metrics {
        self.core.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SacBackend;
    use crate::model::Tensor;
    use std::collections::HashSet;
    use std::time::Duration;

    fn image(seed: i32) -> Tensor<i32> {
        let mut t = Tensor::zeros(&[1, 16, 16]);
        for (i, v) in t.data_mut().iter_mut().enumerate() {
            *v = ((i as i32).wrapping_mul(seed + 7)) % 256;
        }
        t
    }

    #[test]
    fn serves_all_requests_exactly_once() {
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            workers: 2,
        };
        // Shared-plan serving: both workers clone one prototype.
        let server =
            Server::start_shared(cfg, SacBackend::synthetic(1).unwrap()).unwrap();
        let total = 23;
        for id in 0..total {
            server.submit(InferRequest::new(id, image(id as i32))).unwrap();
        }
        let mut seen = HashSet::new();
        for _ in 0..total {
            let resp = server.recv().unwrap();
            assert!(seen.insert(resp.id), "duplicate response {}", resp.id);
            assert_eq!(resp.logits.len(), 4);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
        }
        let m = server.shutdown();
        assert_eq!(m.requests_done, total);
        assert_eq!(seen.len(), total as usize);
    }

    #[test]
    fn responses_match_direct_backend() {
        // Routing/batching must not change values (invariant I6).
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(1) },
            workers: 1,
        };
        let server = Server::start(cfg, |_| SacBackend::synthetic(42)).unwrap();
        let mut direct = SacBackend::synthetic(42).unwrap();
        for id in 0..7u64 {
            server.submit(InferRequest::new(id, image(id as i32))).unwrap();
        }
        let mut responses: Vec<_> = (0..7).map(|_| server.recv().unwrap()).collect();
        responses.sort_by_key(|r| r.id);
        for resp in responses {
            let mut img4 = image(resp.id as i32);
            let s = img4.shape().to_vec();
            img4.reshape(&[1, s[0], s[1], s[2]]).unwrap();
            let want = direct.infer_batch(&img4).unwrap().remove(0);
            assert_eq!(resp.logits, want, "request {}", resp.id);
        }
        server.shutdown();
    }
}
