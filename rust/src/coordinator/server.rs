//! The serving loop: request channel → dynamic batcher → worker threads
//! → response channel.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::backend::InferBackend;
use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{InferRequest, InferResponse};
use crate::model::Tensor;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// Worker threads (each owns one backend instance).
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), workers: 2 }
    }
}

/// Handle to a running server: submit requests, receive responses.
pub struct Server {
    req_tx: Option<Sender<InferRequest>>,
    /// Mutex so `recv` takes `&self` and `Server` stays `Sync` (drain
    /// from a different thread than the submitter).
    resp_rx: Mutex<Receiver<InferResponse>>,
    metrics: Arc<Mutex<Metrics>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the server with one prototype backend cloned into every
    /// worker. For backends whose clone shares compiled state — e.g.
    /// [`SacBackend`](super::backend::SacBackend), whose
    /// `Arc<CompiledNetwork>` plan is aliased by clones — W workers
    /// cost exactly one network compile (one knead per lane total,
    /// pinned by `rust/tests/plan_zero_knead.rs`), not W.
    pub fn start_shared<B>(config: ServerConfig, prototype: B) -> crate::Result<Self>
    where
        B: InferBackend + Clone + Send + Sync + 'static,
    {
        Self::start(config, move |_| Ok(prototype.clone()))
    }

    /// Start the server. `make_backend` is called once per worker
    /// thread (backends need not be `Sync`; they must be creatable per
    /// thread — PJRT executables satisfy this). Backends that *are*
    /// cheaply clonable should go through [`Server::start_shared`]
    /// instead, so workers share one compiled plan.
    pub fn start<B, F>(config: ServerConfig, make_backend: F) -> crate::Result<Self>
    where
        B: InferBackend + 'static,
        F: Fn(usize) -> crate::Result<B> + Send + Sync + 'static,
    {
        assert!(config.workers > 0);
        let (req_tx, req_rx) = channel::<InferRequest>();
        let (resp_tx, resp_rx) = channel::<InferResponse>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));

        // Worker pool: each worker pulls batches from its own channel.
        let mut batch_txs = Vec::new();
        let mut worker_handles = Vec::new();
        let make_backend = Arc::new(make_backend);
        for w in 0..config.workers {
            let (btx, brx) = channel::<Vec<InferRequest>>();
            batch_txs.push(btx);
            let resp_tx = resp_tx.clone();
            let metrics = Arc::clone(&metrics);
            let make_backend = Arc::clone(&make_backend);
            worker_handles.push(std::thread::spawn(move || {
                let mut backend = match make_backend(w) {
                    Ok(b) => b,
                    Err(e) => {
                        eprintln!("worker {w}: backend init failed: {e}");
                        return;
                    }
                };
                while let Ok(batch) = brx.recv() {
                    if let Err(e) = run_batch(&mut backend, batch, &resp_tx, &metrics) {
                        eprintln!("worker {w}: batch failed: {e}");
                    }
                }
            }));
        }

        // Dispatcher: batch incoming requests, round-robin to workers.
        let policy = config.policy.clone();
        let dispatcher = std::thread::spawn(move || {
            let mut batcher = Batcher::new(policy);
            let mut next_worker = 0usize;
            let mut open = true;
            while open || batcher.pending() > 0 {
                // Drain the request channel without blocking past the
                // batching deadline.
                loop {
                    match req_rx.try_recv() {
                        Ok(r) => batcher.push(r),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
                let release = if open {
                    batcher.try_release(Instant::now())
                } else {
                    let all = batcher.flush();
                    if all.is_empty() {
                        None
                    } else {
                        Some(all)
                    }
                };
                if let Some(batch) = release {
                    // Flushes can exceed max_batch; split to respect it.
                    for chunk in batch.chunks(16 * 1024) {
                        let _ = batch_txs[next_worker % batch_txs.len()].send(chunk.to_vec());
                        next_worker += 1;
                    }
                } else if open {
                    std::thread::yield_now();
                }
            }
            drop(batch_txs); // close workers
            for h in worker_handles {
                let _ = h.join();
            }
        });

        Ok(Self { req_tx: Some(req_tx), resp_rx: Mutex::new(resp_rx), metrics, dispatcher: Some(dispatcher) })
    }

    /// Submit a request (non-blocking).
    pub fn submit(&self, req: InferRequest) -> crate::Result<()> {
        self.req_tx
            .as_ref()
            .ok_or_else(|| crate::Error::Coordinator("server stopping".into()))?
            .send(req)
            .map_err(|_| crate::Error::Coordinator("server stopped".into()))
    }

    /// Receive the next response (blocking).
    pub fn recv(&self) -> crate::Result<InferResponse> {
        self.resp_rx
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| crate::Error::Coordinator("server stopped".into()))
    }

    /// Snapshot metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop accepting requests, drain, and join all threads.
    pub fn shutdown(mut self) -> Metrics {
        self.req_tx.take(); // close the request channel
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.req_tx.take();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

/// Execute one batch on a backend and fan out responses.
fn run_batch<B: InferBackend>(
    backend: &mut B,
    batch: Vec<InferRequest>,
    resp_tx: &Sender<InferResponse>,
    metrics: &Arc<Mutex<Metrics>>,
) -> crate::Result<()> {
    let n = batch.len();
    if n == 0 {
        return Ok(());
    }
    // Stack images into (N, C, H, W).
    let img_shape = batch[0].image.shape().to_vec();
    let mut stacked_shape = vec![n];
    stacked_shape.extend_from_slice(&img_shape);
    let mut data = Vec::with_capacity(batch.iter().map(|r| r.image.len()).sum());
    for r in &batch {
        if r.image.shape() != img_shape.as_slice() {
            return Err(crate::Error::Shape("heterogeneous image shapes in batch".into()));
        }
        data.extend_from_slice(r.image.data());
    }
    let images = Tensor::from_vec(&stacked_shape, data)?;
    let logits = backend.infer_batch(&images)?;
    if logits.len() != n {
        return Err(crate::Error::Coordinator(format!(
            "backend returned {} results for batch of {n}",
            logits.len()
        )));
    }
    let sim_cycles = backend.sim_cycles(n);
    let done = Instant::now();
    let mut latencies = Vec::with_capacity(n);
    for (req, lg) in batch.into_iter().zip(logits) {
        let latency_us = done.duration_since(req.enqueued).as_secs_f64() * 1e6;
        latencies.push(latency_us);
        let argmax = lg
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let _ = resp_tx.send(InferResponse {
            id: req.id,
            logits: lg,
            argmax,
            latency_us,
            sim_cycles: sim_cycles / n as u64,
            batch_size: n,
        });
    }
    metrics.lock().unwrap().record_batch(n, &latencies, sim_cycles);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SacBackend;
    use std::collections::HashSet;
    use std::time::Duration;

    fn image(seed: i32) -> Tensor<i32> {
        let mut t = Tensor::zeros(&[1, 16, 16]);
        for (i, v) in t.data_mut().iter_mut().enumerate() {
            *v = ((i as i32).wrapping_mul(seed + 7)) % 256;
        }
        t
    }

    #[test]
    fn serves_all_requests_exactly_once() {
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            workers: 2,
        };
        // Shared-plan serving: both workers clone one prototype.
        let server =
            Server::start_shared(cfg, SacBackend::synthetic(1).unwrap()).unwrap();
        let total = 23;
        for id in 0..total {
            server.submit(InferRequest::new(id, image(id as i32))).unwrap();
        }
        let mut seen = HashSet::new();
        for _ in 0..total {
            let resp = server.recv().unwrap();
            assert!(seen.insert(resp.id), "duplicate response {}", resp.id);
            assert_eq!(resp.logits.len(), 4);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
        }
        let m = server.shutdown();
        assert_eq!(m.requests_done, total);
        assert_eq!(seen.len(), total as usize);
    }

    #[test]
    fn responses_match_direct_backend() {
        // Routing/batching must not change values (invariant I6).
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(1) },
            workers: 1,
        };
        let server = Server::start(cfg, |_| SacBackend::synthetic(42)).unwrap();
        let mut direct = SacBackend::synthetic(42).unwrap();
        for id in 0..7u64 {
            server.submit(InferRequest::new(id, image(id as i32))).unwrap();
        }
        let mut responses: Vec<_> = (0..7).map(|_| server.recv().unwrap()).collect();
        responses.sort_by_key(|r| r.id);
        for resp in responses {
            let mut img4 = image(resp.id as i32);
            let s = img4.shape().to_vec();
            img4.reshape(&[1, s[0], s[1], s[2]]).unwrap();
            let want = direct.infer_batch(&img4).unwrap().remove(0);
            assert_eq!(resp.logits, want, "request {}", resp.id);
        }
        server.shutdown();
    }
}
