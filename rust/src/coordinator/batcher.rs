//! Dynamic batcher: group queued requests up to `max_batch` or until
//! `max_wait` elapses since the oldest queued request.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::InferRequest;

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Accumulates requests and releases batches per policy.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<InferRequest>,
    /// Diagnostics: released batches and their sizes.
    pub batches_released: u64,
    pub requests_seen: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0, "max_batch must be positive");
        Self { policy, queue: VecDeque::new(), batches_released: 0, requests_seen: 0 }
    }

    pub fn push(&mut self, req: InferRequest) {
        self.requests_seen += 1;
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Oldest queued request's age, if any.
    pub fn oldest_age(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|r| now.duration_since(r.enqueued))
    }

    /// Release a batch if the policy says so.
    pub fn try_release(&mut self, now: Instant) -> Option<Vec<InferRequest>> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.policy.max_batch;
        let expired = self
            .oldest_age(now)
            .map(|age| age >= self.policy.max_wait)
            .unwrap_or(false);
        if !full && !expired {
            return None;
        }
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<InferRequest> = self.queue.drain(..n).collect();
        self.batches_released += 1;
        Some(batch)
    }

    /// Drain everything (shutdown path).
    pub fn flush(&mut self) -> Vec<InferRequest> {
        if !self.queue.is_empty() {
            self.batches_released += 1;
        }
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Tensor;

    fn req(id: u64) -> InferRequest {
        InferRequest::new(id, Tensor::zeros(&[1, 2, 2]))
    }

    #[test]
    fn releases_when_full() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(60) });
        b.push(req(1));
        b.push(req(2));
        assert!(b.try_release(Instant::now()).is_none());
        b.push(req(3));
        let batch = b.try_release(Instant::now()).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 1); // FIFO order
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::ZERO });
        b.push(req(1));
        let batch = b.try_release(Instant::now()).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn batch_never_exceeds_max() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::ZERO });
        for i in 0..10 {
            b.push(req(i));
        }
        let batch = b.try_release(Instant::now()).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(b.pending(), 6);
    }

    #[test]
    fn flush_drains_all() {
        let mut b = Batcher::new(BatchPolicy::default());
        for i in 0..5 {
            b.push(req(i));
        }
        assert_eq!(b.flush().len(), 5);
        assert_eq!(b.pending(), 0);
        assert!(b.flush().is_empty());
    }

    #[test]
    #[should_panic(expected = "max_batch must be positive")]
    fn zero_batch_rejected() {
        Batcher::new(BatchPolicy { max_batch: 0, max_wait: Duration::ZERO });
    }
}
