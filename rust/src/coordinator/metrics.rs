//! Serving metrics: latency histograms, exact percentiles, throughput,
//! batch-size stats.

use std::time::Instant;

use crate::util::stats::{percentile, LatencyHistogram, Summary};

/// Exact latency percentiles computed from the recorded per-request
/// latencies (not the power-of-two histogram buckets, whose
/// [`LatencyHistogram::approx_percentile_us`] upper bounds can be ~2×
/// off inside a bucket).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyPercentiles {
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// False while every recorded latency is retained (percentiles are
    /// exact); true once the reservoir saturated and replacement
    /// sampling began — the values are then unbiased estimates over a
    /// uniform sample, not exact order statistics. Surfaced so p99
    /// consumers (SLO dashboards, the demo) can tell the difference.
    pub approx: bool,
}

/// Latency samples retained for exact percentiles. Below this many
/// requests the percentiles are exact; past it, reservoir sampling
/// keeps a uniform sample of everything seen, so percentiles stay
/// unbiased while memory stays bounded (512 KiB) for the lifetime of
/// a production engine.
const LATENCY_SAMPLE_CAP: usize = 65_536;

/// Aggregated serving metrics (owned by the engine; snapshot to read).
#[derive(Debug, Clone)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    pub batch_sizes: Summary,
    pub requests_done: u64,
    pub batches_done: u64,
    pub sim_cycles_total: u64,
    /// Cumulative zero-activation skip counters from skip-armed SAC
    /// backends (`InferBackend::skip_counters`): rows and conv windows
    /// whose SAC work the executor elided, and the total window count
    /// they are measured against. All zero while no skip-armed model
    /// has served a batch.
    pub skipped_rows_total: u64,
    pub skipped_windows_total: u64,
    pub total_windows: u64,
    /// Cumulative SAC energy counters from traced SAC backends
    /// (`InferBackend::sac_counters`): splitter slot decodes and
    /// segment-register adds the conv trunks performed, matching
    /// `sim`'s activity accounting. Zero while no traced model has
    /// served a batch.
    pub slot_decodes_total: u64,
    pub segment_adds_total: u64,
    /// Per-request wall-clock latencies in µs — the exact-percentile
    /// source; a uniform reservoir once [`LATENCY_SAMPLE_CAP`] is hit.
    latencies_us: Vec<f64>,
    /// Observations offered to the reservoir (= requests recorded).
    latency_seen: u64,
    /// xorshift state for reservoir replacement (deterministic seed —
    /// metrics snapshots stay reproducible under a fixed request
    /// order).
    reservoir_rng: u64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            latency: LatencyHistogram::new(),
            batch_sizes: Summary::new(),
            requests_done: 0,
            batches_done: 0,
            sim_cycles_total: 0,
            skipped_rows_total: 0,
            skipped_windows_total: 0,
            total_windows: 0,
            slot_decodes_total: 0,
            segment_adds_total: 0,
            latencies_us: Vec::new(),
            latency_seen: 0,
            reservoir_rng: 0x9E37_79B9_7F4A_7C15,
            started: Instant::now(),
        }
    }

    pub fn record_batch(&mut self, batch_size: usize, latencies_us: &[f64], sim_cycles: u64) {
        self.batches_done += 1;
        self.requests_done += batch_size as u64;
        self.batch_sizes.add(batch_size as f64);
        self.sim_cycles_total += sim_cycles;
        for &l in latencies_us {
            self.latency.record_us(l);
            self.record_latency_sample(l);
        }
    }

    /// Install the latest cumulative skip counters from a skip-armed
    /// backend. The counters arrive as engine-wide running totals
    /// (every `SacBackend` clone shares one atomic set), so this
    /// overwrites rather than accumulates — recording after each batch
    /// keeps the snapshot fresh without double counting.
    pub fn set_skip_counters(&mut self, rows: u64, windows: u64, total_windows: u64) {
        self.skipped_rows_total = self.skipped_rows_total.max(rows);
        self.skipped_windows_total = self.skipped_windows_total.max(windows);
        self.total_windows = self.total_windows.max(total_windows);
    }

    /// Install the latest cumulative SAC energy counters from a traced
    /// backend — running totals like the skip counters, so this
    /// overwrites (monotone max) rather than accumulates.
    pub fn set_sac_counters(&mut self, slot_decodes: u64, segment_adds: u64) {
        self.slot_decodes_total = self.slot_decodes_total.max(slot_decodes);
        self.segment_adds_total = self.segment_adds_total.max(segment_adds);
    }

    /// Fraction of conv windows served with their SAC work skipped
    /// (0.0 before any skip-armed batch completes).
    pub fn window_skip_fraction(&self) -> f64 {
        if self.total_windows == 0 {
            0.0
        } else {
            self.skipped_windows_total as f64 / self.total_windows as f64
        }
    }

    /// Algorithm R: keep every sample until the cap, then replace a
    /// uniformly random slot with probability cap/seen.
    fn record_latency_sample(&mut self, l: f64) {
        self.latency_seen += 1;
        if self.latencies_us.len() < LATENCY_SAMPLE_CAP {
            self.latencies_us.push(l);
            return;
        }
        // xorshift64* step.
        let mut x = self.reservoir_rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.reservoir_rng = x;
        let slot = x % self.latency_seen;
        if (slot as usize) < LATENCY_SAMPLE_CAP {
            self.latencies_us[slot as usize] = l;
        }
    }

    /// p50/p95/p99 over the recorded per-request latencies — exact up
    /// to [`LATENCY_SAMPLE_CAP`] requests, computed over an unbiased
    /// uniform reservoir beyond that (flagged via
    /// [`LatencyPercentiles::approx`]); `None` before the first
    /// completion.
    pub fn latency_percentiles(&self) -> Option<LatencyPercentiles> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let mut sorted = self.latencies_us.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        Some(LatencyPercentiles {
            p50_us: percentile(&sorted, 0.50),
            p95_us: percentile(&sorted, 0.95),
            p99_us: percentile(&sorted, 0.99),
            approx: self.percentiles_approx(),
        })
    }

    /// Latency samples currently retained for the percentile
    /// computation (≤ [`LATENCY_SAMPLE_CAP`]).
    pub fn latency_sample_count(&self) -> usize {
        self.latencies_us.len()
    }

    /// Latency observations offered to the reservoir over the
    /// engine's lifetime (= requests recorded).
    pub fn latency_observed(&self) -> u64 {
        self.latency_seen
    }

    /// True once the reservoir saturated: percentiles are estimated
    /// from a uniform sample rather than exact order statistics.
    pub fn percentiles_approx(&self) -> bool {
        self.latency_seen > LATENCY_SAMPLE_CAP as u64
    }

    /// Requests per second since construction.
    pub fn throughput_rps(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed <= 0.0 {
            0.0
        } else {
            self.requests_done as f64 / elapsed
        }
    }

    /// Human summary block.
    pub fn render(&self) -> String {
        let pct = match self.latency_percentiles() {
            Some(p) => {
                let exactness = if p.approx {
                    format!(
                        "  (~estimated: reservoir {}/{} requests)",
                        self.latency_sample_count(),
                        self.latency_observed()
                    )
                } else {
                    String::new()
                };
                format!(
                    "latency: mean {:.1} µs  p50 {:.0} µs  p95 {:.0} µs  p99 {:.0} µs{exactness}",
                    self.latency.mean_us(),
                    p.p50_us,
                    p.p95_us,
                    p.p99_us
                )
            }
            None => "latency: no completed requests".into(),
        };
        let skip = if self.total_windows > 0 {
            format!(
                "\nactivation skip: rows={} windows={}/{} ({:.1}%)",
                self.skipped_rows_total,
                self.skipped_windows_total,
                self.total_windows,
                self.window_skip_fraction() * 100.0,
            )
        } else {
            String::new()
        };
        let sac = if self.slot_decodes_total > 0 || self.segment_adds_total > 0 {
            format!(
                "\nSAC activity: slot decodes={} segment adds={}",
                self.slot_decodes_total, self.segment_adds_total,
            )
        } else {
            String::new()
        };
        format!(
            "requests: {}  batches: {}  mean batch: {:.2}\n\
             {pct}\n\
             host throughput: {:.1} req/s\n\
             simulated Tetris cycles: {} ({:.3} ms @125MHz){skip}{sac}",
            self.requests_done,
            self.batches_done,
            self.batch_sizes.mean(),
            self.throughput_rps(),
            self.sim_cycles_total,
            self.sim_cycles_total as f64 / 125e6 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_batches() {
        let mut m = Metrics::new();
        m.record_batch(4, &[10.0, 20.0, 30.0, 40.0], 1000);
        m.record_batch(2, &[5.0, 15.0], 500);
        assert_eq!(m.requests_done, 6);
        assert_eq!(m.batches_done, 2);
        assert_eq!(m.sim_cycles_total, 1500);
        assert!((m.batch_sizes.mean() - 3.0).abs() < 1e-12);
        assert_eq!(m.latency.count(), 6);
        assert!(m.render().contains("requests: 6"));
    }

    #[test]
    fn exact_percentiles_from_recorded_latencies() {
        let mut m = Metrics::new();
        assert!(m.latency_percentiles().is_none());
        assert!(m.render().contains("no completed requests"));
        // 1..=100 µs, recorded out of order across two batches.
        let (a, b): (Vec<f64>, Vec<f64>) =
            (1..=100).map(|i| i as f64).partition(|v| v % 2.0 == 0.0);
        m.record_batch(a.len(), &a, 10);
        m.record_batch(b.len(), &b, 10);
        let p = m.latency_percentiles().unwrap();
        assert!((p.p50_us - 50.5).abs() < 1e-9, "p50 {}", p.p50_us);
        assert!((p.p95_us - 95.05).abs() < 1e-9, "p95 {}", p.p95_us);
        assert!((p.p99_us - 99.01).abs() < 1e-9, "p99 {}", p.p99_us);
        assert!(p.p50_us <= p.p95_us && p.p95_us <= p.p99_us);
        assert!(!p.approx, "100 samples fit the reservoir exactly");
        assert_eq!(m.latency_sample_count(), 100);
        assert_eq!(m.latency_observed(), 100);
        assert!(m.render().contains("p95"));
        assert!(!m.render().contains("~estimated"));
    }

    #[test]
    fn skip_counters_snapshot_running_totals() {
        let mut m = Metrics::new();
        assert_eq!(m.window_skip_fraction(), 0.0);
        assert!(!m.render().contains("activation skip"));
        // Counters arrive as engine-wide running totals: a later,
        // larger snapshot replaces the earlier one.
        m.set_skip_counters(5, 100, 1_000);
        m.set_skip_counters(8, 150, 2_000);
        assert_eq!(m.skipped_rows_total, 8);
        assert_eq!(m.skipped_windows_total, 150);
        assert_eq!(m.total_windows, 2_000);
        assert!((m.window_skip_fraction() - 0.075).abs() < 1e-12);
        assert!(m.render().contains("activation skip"), "{}", m.render());
    }

    #[test]
    fn sac_counters_snapshot_running_totals() {
        let mut m = Metrics::new();
        assert!(!m.render().contains("SAC activity"));
        // Same overwrite-with-running-totals contract as the skip
        // counters: a later, larger snapshot replaces the earlier one.
        m.set_sac_counters(1_000, 400);
        m.set_sac_counters(2_500, 900);
        assert_eq!(m.slot_decodes_total, 2_500);
        assert_eq!(m.segment_adds_total, 900);
        assert!(m.render().contains("SAC activity"), "{}", m.render());
        assert!(m.render().contains("slot decodes=2500"), "{}", m.render());
    }

    #[test]
    fn latency_reservoir_stays_bounded() {
        let mut m = Metrics::new();
        let batch: Vec<f64> = (0..1024).map(|i| i as f64).collect();
        for _ in 0..80 {
            m.record_batch(batch.len(), &batch, 1);
        }
        assert_eq!(m.requests_done, 80 * 1024);
        assert!(m.latencies_us.len() <= LATENCY_SAMPLE_CAP);
        // Percentiles still ordered and inside the observed range —
        // and flagged as reservoir estimates now the cap is passed.
        let p = m.latency_percentiles().unwrap();
        assert!(p.p50_us <= p.p95_us && p.p95_us <= p.p99_us);
        assert!(p.p99_us <= 1023.0 && p.p50_us >= 0.0);
        assert!(p.approx, "saturated reservoir must flag approximation");
        assert!(m.percentiles_approx());
        assert_eq!(m.latency_sample_count(), LATENCY_SAMPLE_CAP);
        assert_eq!(m.latency_observed(), 80 * 1024);
        assert!(m.render().contains("~estimated"));
    }
}
