//! Serving metrics: latency histograms, throughput, batch-size stats.

use std::time::Instant;

use crate::util::stats::{LatencyHistogram, Summary};

/// Aggregated serving metrics (owned by the server; snapshot to read).
#[derive(Debug, Clone)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    pub batch_sizes: Summary,
    pub requests_done: u64,
    pub batches_done: u64,
    pub sim_cycles_total: u64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            latency: LatencyHistogram::new(),
            batch_sizes: Summary::new(),
            requests_done: 0,
            batches_done: 0,
            sim_cycles_total: 0,
            started: Instant::now(),
        }
    }

    pub fn record_batch(&mut self, batch_size: usize, latencies_us: &[f64], sim_cycles: u64) {
        self.batches_done += 1;
        self.requests_done += batch_size as u64;
        self.batch_sizes.add(batch_size as f64);
        self.sim_cycles_total += sim_cycles;
        for &l in latencies_us {
            self.latency.record_us(l);
        }
    }

    /// Requests per second since construction.
    pub fn throughput_rps(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed <= 0.0 {
            0.0
        } else {
            self.requests_done as f64 / elapsed
        }
    }

    /// Human summary block.
    pub fn render(&self) -> String {
        format!(
            "requests: {}  batches: {}  mean batch: {:.2}\n\
             latency: mean {:.1} µs  p50 ≤ {:.0} µs  p99 ≤ {:.0} µs\n\
             host throughput: {:.1} req/s\n\
             simulated Tetris cycles: {} ({:.3} ms @125MHz)",
            self.requests_done,
            self.batches_done,
            self.batch_sizes.mean(),
            self.latency.mean_us(),
            self.latency.approx_percentile_us(0.50),
            self.latency.approx_percentile_us(0.99),
            self.throughput_rps(),
            self.sim_cycles_total,
            self.sim_cycles_total as f64 / 125e6 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_batches() {
        let mut m = Metrics::new();
        m.record_batch(4, &[10.0, 20.0, 30.0, 40.0], 1000);
        m.record_batch(2, &[5.0, 15.0], 500);
        assert_eq!(m.requests_done, 6);
        assert_eq!(m.batches_done, 2);
        assert_eq!(m.sim_cycles_total, 1500);
        assert!((m.batch_sizes.mean() - 3.0).abs() < 1e-12);
        assert_eq!(m.latency.count(), 6);
        assert!(m.render().contains("requests: 6"));
    }
}
