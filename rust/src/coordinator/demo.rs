//! Synthetic-load demo used by `tetris serve` and the serve example.

use std::time::Duration;

use super::backend::SacBackend;
use super::batcher::BatchPolicy;
use super::request::InferRequest;
use super::server::{Server, ServerConfig};
use crate::model::{Network, Tensor};
use crate::util::rng::Rng;

/// Generate a synthetic Q8.8 image for the tiny CNN input shape
/// (uniform noise — worst case for class agreement).
pub fn synthetic_image(rng: &mut Rng) -> Tensor<i32> {
    let mut t = Tensor::zeros(&[1, 16, 16]);
    for v in t.data_mut() {
        // Q8.8 values in roughly [-1.5, 1.5].
        *v = rng.range_i64(-384, 384) as i32;
    }
    t
}

/// Generate a dataset-distribution image (mirrors
/// `python/compile/model.py::make_dataset`): one of four oriented
/// sinusoid patterns + Gaussian noise, quantized to Q8.8. Returns the
/// image and its true class.
pub fn dataset_image(rng: &mut Rng) -> (Tensor<i32>, usize) {
    let class = rng.below(4) as usize;
    let phase = rng.f64() * 2.0;
    let mut t = Tensor::zeros(&[1, 16, 16]);
    let tau = 2.0 * std::f64::consts::PI;
    for y in 0..16 {
        for x in 0..16 {
            let (xf, yf) = (x as f64 / 16.0, y as f64 / 16.0);
            let v = match class {
                0 => (tau * (xf + phase)).sin(),
                1 => (tau * (yf + phase)).sin(),
                2 => (tau * (xf + yf + phase)).sin(),
                _ => {
                    let r2 = (xf - 0.5).powi(2) + (yf - 0.5).powi(2);
                    (2.0 * tau * (r2 + phase)).sin()
                }
            } + rng.gaussian() * 0.3;
            t.data_mut()[y * 16 + x] = ((v * 256.0).round() as i32).clamp(-(1 << 15), (1 << 15) - 1);
        }
    }
    (t, class)
}

/// Run `requests` synthetic requests through the coordinator with the
/// SAC backend; prints metrics. (`network` is reported for context —
/// the serving model is the tiny CNN whose weights come from artifacts
/// if present, else a synthetic profile.)
pub fn run_synthetic_load(
    network: &Network,
    requests: usize,
    max_batch: usize,
    seed: u64,
) -> crate::Result<()> {
    let artifacts = std::path::Path::new("artifacts/weights.bin");
    let use_artifacts = artifacts.exists();
    println!(
        "serving tiny CNN ({} weights), context network {}, backend sac-rust, workers 2",
        if use_artifacts { "trained" } else { "synthetic" },
        network.name
    );
    let cfg = ServerConfig {
        policy: BatchPolicy { max_batch, max_wait: Duration::from_millis(2) },
        workers: 2,
    };
    // Compile (knead) once; both workers clone the shared plan.
    let prototype = if use_artifacts {
        SacBackend::new(crate::model::read_weight_file(artifacts)?)?
    } else {
        SacBackend::synthetic(0xACC)?
    };
    let server = Server::start_shared(cfg, prototype)?;
    let mut rng = Rng::new(seed);
    for id in 0..requests as u64 {
        server.submit(InferRequest::new(id, synthetic_image(&mut rng)))?;
    }
    let mut class_counts = [0usize; 16];
    for _ in 0..requests {
        let resp = server.recv()?;
        class_counts[resp.argmax.min(15)] += 1;
    }
    let metrics = server.shutdown();
    println!("{}", metrics.render());
    println!(
        "class distribution: {:?}",
        &class_counts[..4]
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn demo_runs_end_to_end() {
        run_synthetic_load(&zoo::tiny_cnn(), 12, 4, 9).unwrap();
    }
}
