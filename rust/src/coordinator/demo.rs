//! Synthetic-load demo used by `tetris serve` and the serve example —
//! driven entirely through the [`engine`](crate::engine) façade.

use std::time::Duration;

use super::backend::SacBackend;
use crate::config::Mode;
use crate::engine::Engine;
use crate::model::weights::{synthetic_loaded_with_heads, DensityCalibration};
use crate::model::{zoo, Network, Tensor};
use crate::util::rng::Rng;

/// Generate a synthetic Q8.8 image for the tiny CNN input shape
/// (uniform noise — worst case for class agreement).
pub fn synthetic_image(rng: &mut Rng) -> Tensor<i32> {
    synthetic_image_shaped(rng, 1, 16)
}

/// Synthetic Q8.8 noise image of an arbitrary (C, hw, hw) shape.
pub fn synthetic_image_shaped(rng: &mut Rng, c: usize, hw: usize) -> Tensor<i32> {
    let mut t = Tensor::zeros(&[c, hw, hw]);
    for v in t.data_mut() {
        // Q8.8 values in roughly [-1.5, 1.5].
        *v = rng.range_i64(-384, 384) as i32;
    }
    t
}

/// Generate a dataset-distribution image (mirrors
/// `python/compile/model.py::make_dataset`): one of four oriented
/// sinusoid patterns + Gaussian noise, quantized to Q8.8. Returns the
/// image and its true class.
pub fn dataset_image(rng: &mut Rng) -> (Tensor<i32>, usize) {
    let class = rng.below(4) as usize;
    let phase = rng.f64() * 2.0;
    let mut t = Tensor::zeros(&[1, 16, 16]);
    let tau = 2.0 * std::f64::consts::PI;
    for y in 0..16 {
        for x in 0..16 {
            let (xf, yf) = (x as f64 / 16.0, y as f64 / 16.0);
            let v = match class {
                0 => (tau * (xf + phase)).sin(),
                1 => (tau * (yf + phase)).sin(),
                2 => (tau * (xf + yf + phase)).sin(),
                _ => {
                    let r2 = (xf - 0.5).powi(2) + (yf - 0.5).powi(2);
                    (2.0 * tau * (r2 + phase)).sin()
                }
            } + rng.gaussian() * 0.3;
            t.data_mut()[y * 16 + x] = ((v * 256.0).round() as i32).clamp(-(1 << 15), (1 << 15) - 1);
        }
    }
    (t, class)
}

/// A channel-scaled copy of a zoo network small enough to serve as the
/// demo's second registered model (the multi-model path).
fn scaled_context(network: &Network) -> Network {
    let hw = if network.name.starts_with("vgg") { 32 } else { 64 };
    network.scaled(16, hw)
}

/// Run `requests` synthetic requests through the engine with the SAC
/// backend; prints metrics (exact latency percentiles included).
///
/// The engine registers **two** models when `network` is not the tiny
/// CNN — the tiny CNN (weights from artifacts if present, else a
/// synthetic profile) plus a channel-scaled copy of `network` — and
/// interleaves traffic across both, demonstrating multi-model serving
/// from one worker pool with one compile per model.
pub fn run_synthetic_load(
    network: &Network,
    requests: usize,
    max_batch: usize,
    workers: usize,
    seed: u64,
) -> crate::Result<()> {
    let artifacts = std::path::Path::new("artifacts/weights.bin");
    let use_artifacts = artifacts.exists();
    let tiny_weights = if use_artifacts {
        crate::model::read_weight_file(artifacts)?
    } else {
        SacBackend::synthetic_weights(0xACC)?
    };

    let context =
        if network.name == "tiny_cnn" { None } else { Some(scaled_context(network)) };
    let mut builder = Engine::builder()
        .workers(workers)
        .max_batch(max_batch)
        .max_wait(Duration::from_millis(2))
        .register("tiny", zoo::tiny_cnn(), tiny_weights);
    if let Some(ctx) = &context {
        // Heads included: a context model declaring a classifier stack
        // (VGG fc6–8, GoogleNet loss3) serves image → logits end to
        // end; conv-only declarations (AlexNet, NiN) serve the trunk.
        let w = synthetic_loaded_with_heads(
            ctx,
            Mode::Fp16,
            10,
            &network.name,
            DensityCalibration::Fig2,
            seed,
        )?;
        builder = builder.register("context", ctx.clone(), w);
    }
    let engine = builder.build()?;
    let session = engine.session();

    println!(
        "engine: {} worker(s), models: {}  (tiny weights: {})",
        engine.workers(),
        engine
            .models()
            .iter()
            .map(|m| format!("{} [{}]", m.name(), m.backend()))
            .collect::<Vec<_>>()
            .join(", "),
        if use_artifacts { "trained" } else { "synthetic" },
    );
    for m in engine.models() {
        if !m.head_cycles().is_empty() {
            let heads: Vec<String> = m
                .head_cycles()
                .iter()
                .map(|(name, cyc)| format!("{name} {cyc}cyc"))
                .collect();
            println!("  {} classifier heads (per image): {}", m.name(), heads.join(", "));
        }
    }

    // Interleave: every 4th request goes to the context model.
    let mut rng = Rng::new(seed);
    let mut tickets = Vec::with_capacity(requests);
    for i in 0..requests {
        let ticket = match &context {
            Some(ctx) if i % 4 == 3 => {
                let c = ctx.layers[0].in_c;
                let hw = ctx.layers[0].in_hw;
                session.submit("context", synthetic_image_shaped(&mut rng, c, hw))?
            }
            _ => session.submit("tiny", synthetic_image(&mut rng))?,
        };
        tickets.push(ticket);
    }
    let mut class_counts = [0usize; 16];
    let tiny_id = session.model_id("tiny").expect("registered above");
    for ticket in &tickets {
        let resp = session.wait(ticket)?;
        if ticket.model == tiny_id {
            class_counts[resp.argmax.min(15)] += 1;
        }
    }
    let metrics = engine.shutdown();
    println!("{}", metrics.render());
    println!("tiny class distribution: {:?}", &class_counts[..4]);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_runs_end_to_end() {
        run_synthetic_load(&zoo::tiny_cnn(), 12, 4, 2, 9).unwrap();
    }

    #[test]
    fn demo_serves_two_models() {
        run_synthetic_load(&zoo::nin(), 8, 4, 2, 5).unwrap();
    }

    #[test]
    fn demo_serves_classifier_head_model_end_to_end() {
        // VGG-16's scaled context model carries fc6–8 weights: the
        // demo serves image → logits and reports per-head cycles.
        run_synthetic_load(&zoo::vgg16(), 8, 4, 2, 3).unwrap();
    }
}
