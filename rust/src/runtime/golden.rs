//! Golden-model validation: execute the AOT artifacts through PJRT and
//! check every reference vector.
//!
//! Three checks, in order of increasing depth:
//! 1. `golden_cnn.hlo.txt` (float model) reproduces the training-time
//!    logits to f32 tolerance.
//! 2. `sac_matmul.hlo.txt` (the *Pallas SAC kernel*, AOT-lowered)
//!    reproduces the integer product exactly.
//! 3. The pure-rust quantized SAC pipeline (`runtime::quantized`)
//!    reproduces `quant_logits.i32` exactly — cross-language
//!    bit-exactness of kneading + SAC.

use std::path::Path;

use super::artifacts::ArtifactDir;
use super::pjrt::{literal_i32, literal_i8, Engine};
use crate::model::Tensor;

/// Summary of a golden validation run.
#[derive(Debug, Clone, Default)]
pub struct GoldenReport {
    pub golden_max_abs_err: f32,
    pub sac_kernel_exact: bool,
    pub quantized_exact: bool,
    pub batch: usize,
}

/// Run all three checks; error on any failure.
pub fn validate(dir: &ArtifactDir) -> crate::Result<GoldenReport> {
    let engine = Engine::cpu()?;
    let mut report = GoldenReport::default();

    // --- 1. Float golden model ------------------------------------------
    let model = engine.load_hlo_text(&dir.path("golden_cnn.hlo.txt"))?;
    let input = dir.read_f32("golden_input.f32")?;
    let want = dir.read_f32("golden_logits.f32")?;
    let in_shape = dir.shape("golden", "input_shape")?;
    report.batch = in_shape[0] as usize;
    let got = model.run_f32(&[(&input, &in_shape)])?;
    if got.len() != want.len() {
        return Err(crate::Error::Artifact(format!(
            "golden output length {} != reference {}",
            got.len(),
            want.len()
        )));
    }
    report.golden_max_abs_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    if report.golden_max_abs_err > 1e-3 {
        return Err(crate::Error::Artifact(format!(
            "golden logits diverge: max |err| = {}",
            report.golden_max_abs_err
        )));
    }

    // --- 2. AOT Pallas SAC kernel ----------------------------------------
    let sac = engine.load_hlo_text(&dir.path("sac_matmul.hlo.txt"))?;
    let a = dir.read_i32("sac_demo_a.i32")?;
    let planes = dir.read_i8("sac_demo_planes.i8")?;
    let want_out = dir.read_i32("sac_demo_out.i32")?;
    let a_shape = dir.shape("sac_demo", "a_shape")?;
    let p_shape = dir.shape("sac_demo", "planes_shape")?;
    let out = sac.run(&[literal_i32(&a, &a_shape)?, literal_i8(&planes, &p_shape)?])?;
    let got_out = out.to_vec::<i32>()?;
    report.sac_kernel_exact = got_out == want_out;
    if !report.sac_kernel_exact {
        return Err(crate::Error::Artifact(
            "AOT SAC kernel output != integer reference".into(),
        ));
    }

    // --- 3. Rust quantized SAC pipeline ----------------------------------
    let weights = dir.load_weights()?;
    let q_in = dir.read_i32("quant_input.i32")?;
    let q_want = dir.read_i32("quant_logits.i32")?;
    let q_shape: Vec<usize> = dir.shape("quant", "input_shape")?.iter().map(|&d| d as usize).collect();
    let x = Tensor::from_vec(&q_shape, q_in)?;
    let logits = super::quantized::forward(&weights, &x)?;
    report.quantized_exact = logits.data() == &q_want[..];
    if !report.quantized_exact {
        let diffs = logits.data().iter().zip(&q_want).filter(|(a, b)| a != b).count();
        return Err(crate::Error::Artifact(format!(
            "rust SAC pipeline != python reference ({diffs}/{} logits differ)",
            q_want.len()
        )));
    }
    Ok(report)
}

/// CLI entry: validate and print the report.
pub fn run_from_dir(dir: &Path) -> crate::Result<()> {
    let artifacts = ArtifactDir::open(dir)?;
    let report = validate(&artifacts)?;
    println!("platform: cpu (PJRT)");
    println!(
        "golden float model:     max |err| = {:.2e} over batch {}",
        report.golden_max_abs_err, report.batch
    );
    println!("AOT Pallas SAC kernel:  exact ({})", report.sac_kernel_exact);
    println!("rust kneaded-SAC path:  exact ({})", report.quantized_exact);
    println!("golden OK");
    Ok(())
}
