//! Runtime layer: PJRT/XLA execution of AOT artifacts and the
//! rust-side quantized SAC inference pipeline.
//!
//! `make artifacts` (Python, build time) writes `artifacts/*.hlo.txt`
//! plus quantized weights and reference vectors; everything in this
//! module is pure rust + the `xla` crate — Python is never on the
//! request path.

pub mod artifacts;
pub mod golden;
pub mod pjrt;
pub mod quantized;

pub use artifacts::ArtifactDir;
pub use pjrt::{Engine, LoadedModel};
