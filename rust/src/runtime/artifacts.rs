//! Artifact directory: metadata + reference-vector loading.

use std::path::{Path, PathBuf};

use crate::util::json::{parse, Json};

/// A parsed `artifacts/` directory.
pub struct ArtifactDir {
    pub root: PathBuf,
    pub metadata: Json,
}

impl ArtifactDir {
    pub fn open(root: &Path) -> crate::Result<Self> {
        let meta_path = root.join("metadata.json");
        if !meta_path.exists() {
            return Err(crate::Error::Artifact(format!(
                "{} not found — run `make artifacts` first",
                meta_path.display()
            )));
        }
        let metadata = parse(&std::fs::read_to_string(&meta_path)?)?;
        Ok(Self { root: root.to_path_buf(), metadata })
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// Load a raw little-endian f32 vector.
    pub fn read_f32(&self, name: &str) -> crate::Result<Vec<f32>> {
        let bytes = std::fs::read(self.path(name))?;
        if bytes.len() % 4 != 0 {
            return Err(crate::Error::Artifact(format!("{name}: length not /4")));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Load a raw little-endian i32 vector.
    pub fn read_i32(&self, name: &str) -> crate::Result<Vec<i32>> {
        let bytes = std::fs::read(self.path(name))?;
        if bytes.len() % 4 != 0 {
            return Err(crate::Error::Artifact(format!("{name}: length not /4")));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Load a raw i8 vector.
    pub fn read_i8(&self, name: &str) -> crate::Result<Vec<i8>> {
        Ok(std::fs::read(self.path(name))?.into_iter().map(|b| b as i8).collect())
    }

    /// Shape helper from metadata, e.g. `metadata.golden.input_shape`.
    pub fn shape(&self, section: &str, key: &str) -> crate::Result<Vec<i64>> {
        self.metadata
            .get(section)
            .get(key)
            .as_arr()
            .ok_or_else(|| crate::Error::Artifact(format!("metadata missing {section}.{key}")))?
            .iter()
            .map(|v| {
                v.as_u64()
                    .map(|u| u as i64)
                    .ok_or_else(|| crate::Error::Artifact("bad shape dim".into()))
            })
            .collect()
    }

    /// The quantized tiny-CNN weights (fp16 file).
    pub fn load_weights(&self) -> crate::Result<crate::model::LoadedWeights> {
        crate::model::read_weight_file(&self.path("weights.bin"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_clean_error() {
        match ArtifactDir::open(Path::new("/nonexistent")) {
            Err(e) => assert!(e.to_string().contains("make artifacts")),
            Ok(_) => panic!("expected error"),
        }
    }

    // Real-artifact tests live in rust/tests/runtime_hlo.rs (they need
    // `make artifacts` to have run — the Makefile guarantees ordering).
}
