//! The rust-side integer SAC inference pipeline for the tiny CNN.
//!
//! Mirrors `python/compile/model.py::forward_sac_quantized` **exactly**:
//! Q8.8 activations, per-layer Q1.f weights, rounding right-shift
//! requantization, integer max-pool, floor-divide global average pool.
//! Every convolution lane is computed through the kneading compiler +
//! SAC unit, so a logit match against `artifacts/quant_logits.i32`
//! certifies the full rust stack (kneading → splitters → segment adders
//! → rear adder tree) bit-for-bit against the Pallas kernel path.
//!
//! Since ISSUE 1 this module is a thin wrapper over the `plan`
//! subsystem: [`forward`] compiles the tiny CNN's declared topology
//! (`zoo::tiny_cnn`'s conv/pool schedule) into a [`CompiledNetwork`]
//! (kneading every lane once) and executes it. The original
//! single-threaded, re-knead-per-call implementation survives as
//! [`forward_scalar`] / [`sac_conv2d`] — the tiny-CNN half of the
//! bit-exactness reference the plan executor is property-tested
//! against (DESIGN.md §I5; the declared-topology zoo half lives in
//! `rust/tests/plan_topology.rs`) and the baseline `benches/hotpath.rs`
//! measures the compile-once speedup over. Serving callers should hold
//! a [`CompiledNetwork`] (as `coordinator::SacBackend` does — one
//! `Arc`-shared plan across all workers) instead of calling [`forward`]
//! in a loop, which re-compiles per call.

use crate::config::Mode;
use crate::kneading::{knead_lane, Lane};
use crate::model::{zoo, LoadedLayer, LoadedWeights, Tensor};
use crate::plan::CompiledNetwork;
use crate::sac::{rear_adder_tree, split_kneaded, SacUnit, SegmentRegisters};

pub use crate::quant::requantize;

/// Kneading stride used by the functional pipeline (any value is
/// correct — values are invariant to KS; 16 matches the paper setup).
pub const PIPELINE_KS: usize = 16;

/// Integer conv through kneaded SAC lanes: x (N,C,H,W) Q8.8,
/// weights OIHW Q1.f → accumulator (N,O,OH,OW) at scale 2^(8+f).
///
/// Legacy scalar path: re-kneads the layer's lanes on every call and
/// walks output pixels on one thread. Kept as the reference for the
/// plan executor (`plan::exec` is bit-identical; see
/// `rust/tests/plan_exec.rs`).
pub fn sac_conv2d(
    x: &Tensor<i32>,
    layer: &LoadedLayer,
    pad: usize,
    mode: Mode,
) -> crate::Result<Tensor<i32>> {
    let [o, c, kh, kw] = layer.shape;
    let (n, cx, h, w) = match *x.shape() {
        [n, c2, h, w] => (n, c2, h, w),
        _ => return Err(crate::Error::Shape("conv input must be 4-D".into())),
    };
    if cx != c {
        return Err(crate::Error::Shape(format!(
            "{}: input channels {cx} != weight channels {c}",
            layer.name
        )));
    }
    let oh = h + 2 * pad - kh + 1;
    let ow = w + 2 * pad - kw + 1;
    let mut out: Tensor<i32> = Tensor::zeros(&[n, o, oh, ow]);

    // Pre-knead each filter's lane once (weights are reused at every
    // output pixel — same reuse the accelerator exploits).
    let lane_len = c * kh * kw;
    let kneaded: Vec<_> = (0..o)
        .map(|f| {
            let ws = layer.weights[f * lane_len..(f + 1) * lane_len].to_vec();
            knead_lane(&Lane::new(ws, vec![0; lane_len]), PIPELINE_KS, mode)
        })
        .collect();

    // Hot loop (§Perf): the activation window is gathered once per
    // output pixel and shared by every filter; each filter's pre-kneaded
    // groups stream straight into one reused set of segment registers —
    // no per-(pixel, filter) allocation.
    let mut acts = vec![0i32; lane_len];
    let mut segs = SegmentRegisters::new(mode.weight_bits());
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                // Gather the activation window (im2col row) in OIHW
                // weight order: (c, ky, kx).
                let mut idx = 0;
                for cc in 0..c {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = oy + ky;
                            let ix = ox + kx;
                            acts[idx] = if iy < pad || ix < pad || iy - pad >= h || ix - pad >= w {
                                0
                            } else {
                                x.get4(b, cc, iy - pad, ix - pad)
                            };
                            idx += 1;
                        }
                    }
                }
                for (f, klane) in kneaded.iter().enumerate() {
                    for (g, group) in klane.groups.iter().enumerate() {
                        let start = g * PIPELINE_KS;
                        let end = (start + PIPELINE_KS).min(lane_len);
                        split_kneaded(group, &acts[start..end], &mut segs);
                    }
                    let acc = rear_adder_tree(segs.values());
                    segs.reset();
                    out.set4(b, f, oy, ox, acc as i32);
                }
            }
        }
    }
    Ok(out)
}

fn relu_requantize(t: &mut Tensor<i32>, frac_bits: u32) {
    for v in t.data_mut() {
        *v = requantize(*v, frac_bits).max(0);
    }
}

fn maxpool2(x: &Tensor<i32>) -> Tensor<i32> {
    let [n, c, h, w] = match *x.shape() {
        [n, c, h, w] => [n, c, h, w],
        _ => panic!("pool input must be 4-D"),
    };
    let mut out: Tensor<i32> = Tensor::zeros(&[n, c, h / 2, w / 2]);
    for b in 0..n {
        for cc in 0..c {
            for y in 0..h / 2 {
                for xph in 0..w / 2 {
                    let m = x
                        .get4(b, cc, 2 * y, 2 * xph)
                        .max(x.get4(b, cc, 2 * y, 2 * xph + 1))
                        .max(x.get4(b, cc, 2 * y + 1, 2 * xph))
                        .max(x.get4(b, cc, 2 * y + 1, 2 * xph + 1));
                    out.set4(b, cc, y, xph, m);
                }
            }
        }
    }
    out
}

/// Full tiny-CNN integer forward: Q8.8 input (N,1,16,16) → int32 logits
/// (N,4).
///
/// Thin wrapper over the plan subsystem: compiles the `zoo::tiny_cnn`
/// topology (kneading each lane exactly once) and executes the plan.
/// Bit-identical to [`forward_scalar`]. One-shot convenience — serving
/// paths should build the [`CompiledNetwork`] once and reuse it.
pub fn forward(weights: &LoadedWeights, x: &Tensor<i32>) -> crate::Result<Tensor<i32>> {
    compile_tiny_cnn(weights)?.execute(x)
}

/// Compile the tiny-CNN topology against `weights` with the pipeline's
/// default stride — the plan `coordinator::SacBackend` holds.
pub fn compile_tiny_cnn(weights: &LoadedWeights) -> crate::Result<CompiledNetwork> {
    CompiledNetwork::compile(&zoo::tiny_cnn(), weights, PIPELINE_KS, weights.mode)
}

/// Legacy scalar forward — the seed implementation, byte-for-byte
/// semantics: re-kneads every lane on each call, single-threaded,
/// hardcoded to the tiny CNN's layer names. Retained as the reference
/// half of invariant I5 and as the baseline for `benches/hotpath.rs`.
pub fn forward_scalar(weights: &LoadedWeights, x: &Tensor<i32>) -> crate::Result<Tensor<i32>> {
    let mode = weights.mode;
    let mut h = x.clone();
    for name in ["conv1", "conv2", "conv3"] {
        let layer = weights
            .layer(name)
            .ok_or_else(|| crate::Error::Artifact(format!("missing layer {name}")))?;
        let acc = sac_conv2d(&h, layer, 1, mode)?;
        h = acc;
        relu_requantize(&mut h, layer.frac_bits);
        if name != "conv3" {
            h = maxpool2(&h);
        }
    }
    // Global average pool: sum then floor-divide (matches jnp `//`).
    let [n, c, hh, ww] = match *h.shape() {
        [n, c, hh, ww] => [n, c, hh, ww],
        _ => unreachable!(),
    };
    let mut feats: Tensor<i32> = Tensor::zeros(&[n, c]);
    for b in 0..n {
        for cc in 0..c {
            let mut s: i64 = 0;
            for y in 0..hh {
                for xx in 0..ww {
                    s += h.get4(b, cc, y, xx) as i64;
                }
            }
            feats.data_mut()[b * c + cc] = (s.div_euclid((hh * ww) as i64)) as i32;
        }
    }
    // FC via SAC lanes: fc stored as (4, 16, 1, 1) OIHW.
    let fc = weights
        .layer("fc")
        .ok_or_else(|| crate::Error::Artifact("missing layer fc".into()))?;
    let classes = fc.shape[0];
    let feat_dim = fc.shape[1];
    let mut unit = SacUnit::new(mode);
    let mut logits: Tensor<i32> = Tensor::zeros(&[n, classes]);
    for b in 0..n {
        let acts: Vec<i32> = (0..feat_dim).map(|i| feats.data()[b * feat_dim + i]).collect();
        for k in 0..classes {
            let ws = fc.weights[k * feat_dim..(k + 1) * feat_dim].to_vec();
            let lane = Lane::new(ws, acts.clone());
            logits.data_mut()[b * classes + k] = unit.process_lane(&lane, PIPELINE_KS) as i32;
        }
    }
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LoadedLayer;

    fn identity_layer() -> LoadedLayer {
        // 1×1 conv, single channel, weight 256 = 1.0 in Q8 (frac_bits 8),
        // so requantizing the accumulator by 8 recovers the input.
        LoadedLayer {
            name: "conv".into(),
            shape: [1, 1, 1, 1],
            frac_bits: 8,
            weights: vec![256], // 1.0 in Q8
        }
    }

    #[test]
    fn conv1x1_identity() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![10, -3, 7, 0]).unwrap();
        let acc = sac_conv2d(&x, &identity_layer(), 0, Mode::Fp16).unwrap();
        // acc = x * 256; requantize by 8 → x.
        let back: Vec<i32> = acc.data().iter().map(|&v| requantize(v, 8)).collect();
        assert_eq!(back, vec![10, -3, 7, 0]);
    }

    #[test]
    fn conv_padding_zero_extends() {
        let layer = LoadedLayer {
            name: "c".into(),
            shape: [1, 1, 3, 3],
            frac_bits: 0,
            weights: vec![1; 9],
        };
        let x = Tensor::from_vec(&[1, 1, 1, 1], vec![5]).unwrap();
        let acc = sac_conv2d(&x, &layer, 1, Mode::Fp16).unwrap();
        // 3×3 all-ones kernel over a single 5 with pad 1 → every output
        // position sums just the 5.
        assert_eq!(acc.shape(), &[1, 1, 1, 1]);
        assert_eq!(acc.data()[0], 5);
    }

    #[test]
    fn requantize_rounds_half_up() {
        assert_eq!(requantize(255, 8), 1);
        assert_eq!(requantize(127, 8), 0);
        assert_eq!(requantize(128, 8), 1);
        assert_eq!(requantize(-128, 8), 0); // (-128+128)>>8
        assert_eq!(requantize(-129, 8), -1);
    }

    #[test]
    fn requantize_zero_frac_bits_is_identity() {
        // Regression: the seed's `1 << (frac_bits - 1)` underflowed
        // (debug panic) for frac_bits == 0.
        assert_eq!(requantize(12345, 0), 12345);
        assert_eq!(requantize(-12345, 0), -12345);
    }

    #[test]
    fn maxpool_picks_max() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 9, -4, 3]).unwrap();
        let p = maxpool2(&x);
        assert_eq!(p.data(), &[9]);
    }

    #[test]
    fn forward_wrapper_matches_scalar_reference() {
        let w = crate::coordinator::SacBackend::synthetic_weights(17).unwrap();
        let mut x = Tensor::zeros(&[2, 1, 16, 16]);
        for (i, v) in x.data_mut().iter_mut().enumerate() {
            *v = (i as i32 % 613) - 300;
        }
        let plan_logits = forward(&w, &x).unwrap();
        let scalar_logits = forward_scalar(&w, &x).unwrap();
        assert_eq!(plan_logits, scalar_logits);
    }

    // Cross-language exactness vs quant_logits.i32 lives in
    // rust/tests/runtime_hlo.rs (needs artifacts).
}
