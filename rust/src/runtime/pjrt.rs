//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange format is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.

use std::path::Path;

/// A PJRT client (one per thread that executes models — the underlying
/// handles are not `Sync`).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// CPU PJRT client.
    pub fn cpu() -> crate::Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo_text(&self, path: &Path) -> crate::Result<LoadedModel> {
        if !path.exists() {
            return Err(crate::Error::Artifact(format!(
                "HLO file {} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "model".into());
        Ok(LoadedModel { exe, name })
    }
}

/// A compiled executable (jax lowers with `return_tuple=True`, so every
/// model returns a 1-tuple).
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl LoadedModel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with literal inputs; returns the untupled first output.
    pub fn run(&self, inputs: &[xla::Literal]) -> crate::Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }

    /// Execute with f32 input tensors, returning the f32 output.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> crate::Result<Vec<f32>> {
        let literals = inputs
            .iter()
            .map(|(data, dims)| Ok(xla::Literal::vec1(data).reshape(dims)?))
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(self.run(&literals)?.to_vec::<f32>()?)
    }
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> crate::Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i8 literal of the given shape (no `NativeType` impl for i8
/// in the crate — go through the untyped-data constructor).
pub fn literal_i8(data: &[i8], dims: &[i64]) -> crate::Result<xla::Literal> {
    let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        &dims_usize,
        bytes,
    )?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT runtime tests that need artifacts live in
    // rust/tests/runtime_hlo.rs (integration). Here: client liveness.
    #[test]
    fn cpu_client_starts() {
        let e = Engine::cpu().unwrap();
        assert!(!e.platform().is_empty());
    }

    #[test]
    fn missing_hlo_is_artifact_error() {
        let e = Engine::cpu().unwrap();
        match e.load_hlo_text(Path::new("/nonexistent/x.hlo.txt")) {
            Err(err) => assert!(matches!(err, crate::Error::Artifact(_))),
            Ok(_) => panic!("expected error"),
        }
    }
}
