//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange format is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! The real client requires the `xla` crate and its XLA C library,
//! which are unavailable offline — so the real implementation sits
//! behind `xla` **and** `xla-vendored` together (see `Cargo.toml`).
//! Any other combination — including `--features xla` alone, which
//! CI's feature-matrix job builds — compiles this module to a typed
//! stub with the identical API whose constructors return
//! [`crate::Error::Xla`]; since [`Engine::cpu`] is the only way to
//! obtain an `Engine` (and from it a `LoadedModel` or `Literal`), the
//! remaining stub methods are statically unreachable.

#[cfg(all(feature = "xla", feature = "xla-vendored"))]
mod real {
    use std::path::Path;

    /// A PJRT client (one per thread that executes models — the
    /// underlying handles are not `Sync`).
    pub struct Engine {
        client: xla::PjRtClient,
    }

    impl Engine {
        /// CPU PJRT client.
        pub fn cpu() -> crate::Result<Self> {
            Ok(Self { client: xla::PjRtClient::cpu()? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text file.
        pub fn load_hlo_text(&self, path: &Path) -> crate::Result<LoadedModel> {
            if !path.exists() {
                return Err(crate::Error::Artifact(format!(
                    "HLO file {} not found — run `make artifacts` first",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let name = path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "model".into());
            Ok(LoadedModel { exe, name })
        }
    }

    /// A compiled executable (jax lowers with `return_tuple=True`, so
    /// every model returns a 1-tuple).
    pub struct LoadedModel {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl LoadedModel {
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with literal inputs; returns the untupled first output.
        pub fn run(&self, inputs: &[xla::Literal]) -> crate::Result<xla::Literal> {
            let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
            Ok(result.to_tuple1()?)
        }

        /// Execute with f32 input tensors, returning the f32 output.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> crate::Result<Vec<f32>> {
            let literals = inputs
                .iter()
                .map(|(data, dims)| Ok(xla::Literal::vec1(data).reshape(dims)?))
                .collect::<crate::Result<Vec<_>>>()?;
            Ok(self.run(&literals)?.to_vec::<f32>()?)
        }
    }

    /// Build an i32 literal of the given shape.
    pub fn literal_i32(data: &[i32], dims: &[i64]) -> crate::Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// Build an i8 literal of the given shape (no `NativeType` impl for
    /// i8 in the crate — go through the untyped-data constructor).
    pub fn literal_i8(data: &[i8], dims: &[i64]) -> crate::Result<xla::Literal> {
        let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S8,
            &dims_usize,
            bytes,
        )?)
    }
}

#[cfg(all(feature = "xla", feature = "xla-vendored"))]
pub use real::{literal_i32, literal_i8, Engine, LoadedModel};

#[cfg(not(all(feature = "xla", feature = "xla-vendored")))]
mod stub {
    use std::path::Path;

    /// Uninhabited carrier: stub handles can never be constructed, so
    /// their methods are `match`-on-never and need no implementations.
    #[derive(Debug, Clone, Copy)]
    enum Never {}

    fn unavailable() -> crate::Error {
        crate::Error::Xla(
            "PJRT runtime unavailable: built without the `xla` + `xla-vendored` \
             cargo features (vendor the xla crate to enable the real client)"
                .into(),
        )
    }

    /// Stub literal — mirrors `xla::Literal` at the type level only.
    #[derive(Debug)]
    pub struct Literal(Never);

    impl Literal {
        pub fn to_vec<T>(&self) -> crate::Result<Vec<T>> {
            match self.0 {}
        }
    }

    /// Stub PJRT client.
    pub struct Engine(Never);

    impl Engine {
        /// Always errors: the `xla` feature is off.
        pub fn cpu() -> crate::Result<Self> {
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            match self.0 {}
        }

        pub fn load_hlo_text(&self, _path: &Path) -> crate::Result<LoadedModel> {
            match self.0 {}
        }
    }

    /// Stub compiled executable.
    pub struct LoadedModel(Never);

    impl LoadedModel {
        pub fn name(&self) -> &str {
            match self.0 {}
        }

        pub fn run(&self, _inputs: &[Literal]) -> crate::Result<Literal> {
            match self.0 {}
        }

        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> crate::Result<Vec<f32>> {
            match self.0 {}
        }
    }

    pub fn literal_i32(_data: &[i32], _dims: &[i64]) -> crate::Result<Literal> {
        Err(unavailable())
    }

    pub fn literal_i8(_data: &[i8], _dims: &[i64]) -> crate::Result<Literal> {
        Err(unavailable())
    }
}

#[cfg(not(all(feature = "xla", feature = "xla-vendored")))]
pub use stub::{literal_i32, literal_i8, Engine, LoadedModel};

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT runtime tests that need artifacts live in
    // rust/tests/runtime_hlo.rs (integration). Here: client liveness.
    #[cfg(all(feature = "xla", feature = "xla-vendored"))]
    #[test]
    fn cpu_client_starts() {
        let e = Engine::cpu().unwrap();
        assert!(!e.platform().is_empty());
    }

    #[cfg(all(feature = "xla", feature = "xla-vendored"))]
    #[test]
    fn missing_hlo_is_artifact_error() {
        let e = Engine::cpu().unwrap();
        match e.load_hlo_text(std::path::Path::new("/nonexistent/x.hlo.txt")) {
            Err(err) => assert!(matches!(err, crate::Error::Artifact(_))),
            Ok(_) => panic!("expected error"),
        }
    }

    #[cfg(not(all(feature = "xla", feature = "xla-vendored")))]
    #[test]
    fn stub_reports_feature_disabled() {
        match Engine::cpu() {
            Err(crate::Error::Xla(msg)) => assert!(msg.contains("xla")),
            other => panic!("expected Xla error, got {:?}", other.map(|_| ())),
        }
        assert!(matches!(literal_i32(&[1], &[1]), Err(crate::Error::Xla(_))));
    }
}
