//! Fault-tolerant closed-loop load generator for a shard cluster.
//!
//! N client threads each run a closed loop — submit one request, wait
//! for its terminal state, repeat — against a [`Router`], round-robin
//! over the advertised models. **Every** outcome is terminal by the
//! router's contract, so the loop never hangs: successes are timed,
//! typed failures ([`ClusterError`]) are *counted by kind* and the
//! loop keeps going — which is exactly what makes the kill-a-shard
//! drill observable as `shard-down: K` in the report instead of a
//! wedged benchmark.
//!
//! Latency percentiles are exact (every sample kept and sorted, the
//! same `util::stats::percentile` the engine metrics use), and
//! throughput is completed requests over wall time.

use std::time::{Duration, Instant};

use crate::model::Tensor;
use crate::util::rng::Rng;
use crate::util::stats::percentile;

use super::router::Router;

/// Loadgen configuration.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Total requests across all clients.
    pub requests: usize,
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    pub seed: u64,
    /// Models to round-robin over; empty = every model the router's
    /// shards advertise.
    pub models: Vec<String>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self { requests: 64, clients: 4, seed: 0x7e7215, models: Vec::new() }
    }
}

/// One loadgen run's outcome.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub requests: usize,
    pub done: usize,
    pub failed: usize,
    /// Failure counts grouped by [`FailKind`](super::wire::FailKind)
    /// display name, sorted by name.
    pub failed_by_kind: Vec<(String, usize)>,
    pub elapsed: Duration,
    /// Completed requests per wall-clock second.
    pub throughput_rps: f64,
    /// Exact client-observed latency percentiles, µs (0 when nothing
    /// completed).
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl LoadgenReport {
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "loadgen: {}/{} ok, {} failed in {:.2}s — {:.1} req/s",
            self.done,
            self.requests,
            self.failed,
            self.elapsed.as_secs_f64(),
            self.throughput_rps
        );
        if self.done > 0 {
            let _ = writeln!(
                out,
                "  latency p50 {:.0} µs · p95 {:.0} µs · p99 {:.0} µs",
                self.p50_us, self.p95_us, self.p99_us
            );
        }
        for (kind, n) in &self.failed_by_kind {
            let _ = writeln!(out, "  failed {kind}: {n}");
        }
        out
    }
}

/// Drive `config.requests` closed-loop requests through the router.
/// Fails only on configuration errors (no models, no image shapes) —
/// runtime failures are data, not errors.
pub fn run(router: &Router, config: &LoadgenConfig) -> crate::Result<LoadgenReport> {
    let models = if config.models.is_empty() {
        router.model_names()
    } else {
        config.models.clone()
    };
    if models.is_empty() {
        return Err(crate::Error::Config("loadgen: the router advertises no models".into()));
    }
    // Resolve every model's input shape up front from the Hello data.
    let shapes: Vec<(usize, usize)> = models
        .iter()
        .map(|m| {
            router.model_shape(m).ok_or_else(|| {
                crate::Error::Config(format!(
                    "loadgen: no shard advertises an input shape for model `{m}`"
                ))
            })
        })
        .collect::<crate::Result<_>>()?;

    let clients = config.clients.max(1);
    let start = Instant::now();
    // Per-client results: (latencies_us, failures by kind name).
    let mut per_client: Vec<(Vec<f64>, Vec<(String, usize)>)> = Vec::with_capacity(clients);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(clients);
        for c in 0..clients {
            let share = config.requests / clients
                + if c < config.requests % clients { 1 } else { 0 };
            let models = &models;
            let shapes = &shapes;
            let seed = config.seed;
            handles.push(scope.spawn(move || {
                let mut rng = Rng::new(seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(c as u64 + 1)));
                let mut latencies = Vec::with_capacity(share);
                let mut failures: Vec<(String, usize)> = Vec::new();
                for k in 0..share {
                    let m = (c + k * clients) % models.len();
                    let (in_c, in_hw) = shapes[m];
                    let mut image = Tensor::zeros(&[in_c, in_hw, in_hw]);
                    for v in image.data_mut() {
                        // Q8.8 noise in roughly [-1.5, 1.5].
                        *v = rng.range_i64(-384, 384) as i32;
                    }
                    let t0 = Instant::now();
                    match router.infer(&models[m], &image) {
                        Ok(_) => latencies.push(t0.elapsed().as_secs_f64() * 1e6),
                        Err(e) => {
                            let kind = e.kind().to_string();
                            match failures.iter_mut().find(|(k, _)| *k == kind) {
                                Some((_, n)) => *n += 1,
                                None => failures.push((kind, 1)),
                            }
                        }
                    }
                }
                (latencies, failures)
            }));
        }
        for h in handles {
            per_client.push(h.join().expect("loadgen client panicked"));
        }
    });
    let elapsed = start.elapsed();

    let mut latencies: Vec<f64> = Vec::with_capacity(config.requests);
    let mut failed_by_kind: Vec<(String, usize)> = Vec::new();
    for (lats, fails) in per_client {
        latencies.extend(lats);
        for (kind, n) in fails {
            match failed_by_kind.iter_mut().find(|(k, _)| *k == kind) {
                Some((_, total)) => *total += n,
                None => failed_by_kind.push((kind, n)),
            }
        }
    }
    failed_by_kind.sort();
    latencies.sort_by(f64::total_cmp);
    let done = latencies.len();
    let failed: usize = failed_by_kind.iter().map(|(_, n)| n).sum();
    debug_assert_eq!(done + failed, config.requests, "every request must reach a terminal state");
    Ok(LoadgenReport {
        requests: config.requests,
        done,
        failed,
        failed_by_kind,
        elapsed,
        throughput_rps: done as f64 / elapsed.as_secs_f64().max(1e-9),
        p50_us: if done > 0 { percentile(&latencies, 0.5) } else { 0.0 },
        p95_us: if done > 0 { percentile(&latencies, 0.95) } else { 0.0 },
        p99_us: if done > 0 { percentile(&latencies, 0.99) } else { 0.0 },
    })
}
