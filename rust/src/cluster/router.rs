//! Client-side consistent-hash router over a set of shard servers.
//!
//! Routing is **bounded rendezvous hashing**: every (model, shard)
//! pair gets a stable FNV-1a score ([`rendezvous_rank`]) and a
//! submission walks the model's ranked shard list — so adding or
//! removing a shard only moves the keys that hashed to it (ring
//! stability, pinned in `tests/cluster.rs`). The *bounded* part is a
//! consistent-hashing-with-bounded-loads spill: when the top-ranked
//! shard already carries more than its fair share of the router's
//! in-flight requests, the submission spills to the next-ranked shard
//! and counts a **reroute** — one hot model cannot starve the pool.
//!
//! Failure semantics extend the engine's typed-completion contract
//! (PR 4) across the socket, which is the part nothing owned before
//! this PR: a shard that dies with tickets outstanding would leave
//! `wait()` blocked forever. The router's per-shard reader thread
//! turns the connection's EOF into [`ClusterError::ShardDown`] for
//! **every** pending ticket on that shard, and a per-request deadline
//! ([`RouterConfig::timeout`]) converts a silent stall (network
//! partition, wedged shard) into [`ClusterError::Timeout`]. Every
//! submitted ticket reaches exactly one terminal state — zero hangs.

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::engine::env;
use crate::model::Tensor;

use super::wire::{fnv1a64, FailKind, Message, WireModel};

/// Router configuration. `Default` resolves the deadline from
/// `TETRIS_RPC_TIMEOUT_MS` (see [`env::rpc_timeout`]).
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Per-request deadline: `wait` returns [`ClusterError::Timeout`]
    /// once it expires, whatever the shard is doing.
    pub timeout: Duration,
    /// Connection attempts per shard (bounded exponential backoff).
    pub connect_attempts: u32,
    /// First retry delay; doubles per attempt, capped at 500 ms.
    pub connect_base_delay: Duration,
    /// Bounded-load spill factor, percent of the fair share (125 =
    /// a shard may run 25% above the mean in-flight load before
    /// submissions spill past it). `0` disables spilling.
    pub load_factor_pct: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            timeout: env::rpc_timeout(),
            connect_attempts: 6,
            connect_base_delay: Duration::from_millis(10),
            load_factor_pct: 125,
        }
    }
}

/// Receipt for one routed submission; redeem with [`Router::wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClusterTicket {
    /// Router-unique sequence number (the wire `seq`).
    pub seq: u64,
    /// Index of the shard the request was routed to.
    pub shard: usize,
}

/// One completed remote inference.
#[derive(Debug, Clone)]
pub struct ClusterResponse {
    pub seq: u64,
    /// Name of the shard that served the request.
    pub shard: String,
    pub logits: Vec<i32>,
    pub argmax: usize,
    /// Engine-side latency as the shard reported it.
    pub latency_us: f64,
    pub sim_cycles: u64,
    pub batch_size: usize,
}

/// Typed routing/transport failure. Remote engine failures arrive as
/// [`ClusterError::Remote`] with the shard's [`FailKind`]; everything
/// else is raised by the router itself.
#[derive(Debug, Clone)]
pub enum ClusterError {
    /// No live shard serves the model.
    NoShards { model: String },
    /// The shard's connection died with this request outstanding.
    ShardDown { shard: String, detail: String },
    /// The per-request deadline expired.
    Timeout { shard: String, waited: Duration },
    /// The shard completed the request as a typed failure.
    Remote { shard: String, kind: FailKind, message: String },
    /// The shard violated the wire protocol.
    Protocol { shard: String, detail: String },
    /// Connecting to a shard failed after every backoff attempt.
    Connect { addr: String, detail: String },
}

impl ClusterError {
    /// The failure's wire-level kind (router-raised errors map onto
    /// the matching [`FailKind`]) — what loadgen groups failures by.
    pub fn kind(&self) -> FailKind {
        match self {
            ClusterError::NoShards { .. } => FailKind::Config,
            ClusterError::ShardDown { .. } => FailKind::ShardDown,
            ClusterError::Timeout { .. } => FailKind::Timeout,
            ClusterError::Remote { kind, .. } => *kind,
            ClusterError::Protocol { .. } => FailKind::Protocol,
            ClusterError::Connect { .. } => FailKind::ShardDown,
        }
    }
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::NoShards { model } => {
                write!(f, "no live shard serves model `{model}`")
            }
            ClusterError::ShardDown { shard, detail } => {
                write!(f, "shard `{shard}` went down with the request outstanding: {detail}")
            }
            ClusterError::Timeout { shard, waited } => {
                write!(f, "request to shard `{shard}` timed out after {waited:?}")
            }
            ClusterError::Remote { shard, kind, message } => {
                write!(f, "shard `{shard}` failed the request ({kind}): {message}")
            }
            ClusterError::Protocol { shard, detail } => {
                write!(f, "shard `{shard}` broke the wire protocol: {detail}")
            }
            ClusterError::Connect { addr, detail } => {
                write!(f, "connecting to shard at {addr} failed: {detail}")
            }
        }
    }
}

impl std::error::Error for ClusterError {}

impl From<ClusterError> for crate::Error {
    fn from(e: ClusterError) -> Self {
        crate::Error::Coordinator(format!("cluster: {e}"))
    }
}

/// Rank shard identities for one model by rendezvous (highest-random-
/// weight) hashing: stable scores, so removing one shard leaves every
/// other shard's relative order — and therefore every key that did
/// not map to the removed shard — unchanged.
pub fn rendezvous_rank(model: &str, shards: &[impl AsRef<str>]) -> Vec<usize> {
    let mut scored: Vec<(u64, usize)> = shards
        .iter()
        .enumerate()
        .map(|(i, s)| (fnv1a64(&[model.as_bytes(), s.as_ref().as_bytes()]), i))
        .collect();
    scored.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    scored.into_iter().map(|(_, i)| i).collect()
}

/// One connected shard's client-side state.
struct ShardConn {
    name: String,
    addr: SocketAddr,
    writer: Mutex<TcpStream>,
    models: Vec<WireModel>,
    alive: AtomicBool,
    inflight: AtomicUsize,
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    connect_retries: u64,
    reroutes: AtomicU64,
}

/// A routed request between submit and its terminal state.
enum Pending {
    Waiting { shard: usize, since: Instant },
    Done(Box<Result<ClusterResponse, ClusterError>>),
}

/// Completion state shared by router clones **and** reader threads.
struct RouterShared {
    shards: Vec<ShardConn>,
    pending: Mutex<HashMap<u64, Pending>>,
    arrived: Condvar,
    next_seq: AtomicU64,
    timeout: Duration,
    load_factor_pct: usize,
    /// Router-observed round-trip latencies, aggregated with the same
    /// reservoir + exact-percentile machinery the engine uses.
    rtt: Mutex<Metrics>,
}

/// Held by router clones only (never by reader threads): when the last
/// clone drops, sockets close, readers unblock on EOF and are joined —
/// no thread or socket outlives the router.
struct Lifecycle {
    shared: Arc<RouterShared>,
    readers: Mutex<Vec<JoinHandle<()>>>,
}

impl Drop for Lifecycle {
    fn drop(&mut self) {
        for conn in &self.shared.shards {
            if let Ok(s) = conn.writer.lock() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        for h in self.readers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// The consistent-hash router: connect once, then submit/wait (or
/// [`Router::infer`]) from any number of threads — clones share the
/// connections, the pending-ticket store, and the metrics.
#[derive(Clone)]
pub struct Router {
    shared: Arc<RouterShared>,
    lifecycle: Arc<Lifecycle>,
}

impl Router {
    /// Connect to every shard address (bounded exponential-backoff
    /// retry per shard), read each shard's `Hello`, and start the
    /// per-shard reader threads. Fails if **any** shard stays
    /// unreachable — a cluster with silently missing shards would
    /// misroute.
    pub fn connect(addrs: &[SocketAddr], config: RouterConfig) -> Result<Router, ClusterError> {
        let mut shards = Vec::with_capacity(addrs.len());
        let mut read_halves = Vec::with_capacity(addrs.len());
        for &addr in addrs {
            let (mut stream, retries) = connect_backoff(addr, &config)?;
            let _ = stream.set_nodelay(true);
            let _ = stream.set_write_timeout(Some(config.timeout));
            // Readiness handshake: the Hello must arrive within the
            // deadline; afterwards the reader blocks indefinitely
            // (shard death reads as EOF, stalls are the waiter
            // deadline's job).
            let _ = stream.set_read_timeout(Some(config.timeout));
            let (name, models) = match Message::decode_from(&mut stream) {
                Ok(Message::Hello { shard, models }) => (shard, models),
                Ok(other) => {
                    return Err(ClusterError::Protocol {
                        shard: addr.to_string(),
                        detail: format!("expected Hello, got {other:?}"),
                    })
                }
                Err(e) => {
                    return Err(ClusterError::Protocol {
                        shard: addr.to_string(),
                        detail: format!("handshake failed: {e}"),
                    })
                }
            };
            let _ = stream.set_read_timeout(None);
            let writer = stream.try_clone().map_err(|e| ClusterError::Connect {
                addr: addr.to_string(),
                detail: format!("socket clone failed: {e}"),
            })?;
            shards.push(ShardConn {
                name,
                addr,
                writer: Mutex::new(writer),
                models,
                alive: AtomicBool::new(true),
                inflight: AtomicUsize::new(0),
                submitted: AtomicU64::new(0),
                completed: AtomicU64::new(0),
                failed: AtomicU64::new(0),
                connect_retries: retries,
                reroutes: AtomicU64::new(0),
            });
            read_halves.push(stream);
        }
        let shared = Arc::new(RouterShared {
            shards,
            pending: Mutex::new(HashMap::new()),
            arrived: Condvar::new(),
            next_seq: AtomicU64::new(0),
            timeout: config.timeout,
            load_factor_pct: config.load_factor_pct,
            rtt: Mutex::new(Metrics::new()),
        });
        let readers = read_halves
            .into_iter()
            .enumerate()
            .map(|(i, stream)| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || read_loop(&shared, i, stream))
            })
            .collect();
        let lifecycle = Arc::new(Lifecycle {
            shared: Arc::clone(&shared),
            readers: Mutex::new(readers),
        });
        Ok(Router { shared, lifecycle })
    }

    /// Route one (C, H, W) Q8.8 image to `model`'s shard and return a
    /// ticket. Never blocks past the socket write.
    pub fn submit(&self, model: &str, image: &Tensor<i32>) -> Result<ClusterTicket, ClusterError> {
        let shard_idx = self.route(model)?;
        let conn = &self.shared.shards[shard_idx];
        let seq = self.shared.next_seq.fetch_add(1, Ordering::Relaxed);
        let shape = match *image.shape() {
            [c, h, w] => [c as u32, h as u32, w as u32],
            _ => {
                return Err(ClusterError::Remote {
                    shard: conn.name.clone(),
                    kind: FailKind::Shape,
                    message: "submit takes one (C, H, W) image".into(),
                })
            }
        };
        // Park the pending entry before the bytes leave, so a fast
        // completion always finds it.
        self.shared.pending.lock().unwrap().insert(
            seq,
            Pending::Waiting { shard: shard_idx, since: Instant::now() },
        );
        conn.inflight.fetch_add(1, Ordering::SeqCst);
        conn.submitted.fetch_add(1, Ordering::Relaxed);
        let frame = Message::Submit {
            seq,
            model: model.to_string(),
            shape,
            image: image.data().to_vec(),
        };
        let write = {
            let mut w = conn.writer.lock().unwrap();
            frame.encode_to(&mut *w).and_then(|()| w.flush())
        };
        if let Err(e) = write {
            // The shard is unreachable: fail it, which completes this
            // seq (and every other pending seq on it) as ShardDown —
            // the ticket stays redeemable, typed, hang-free.
            fail_shard(&self.shared, shard_idx, &format!("write failed: {e}"));
        }
        Ok(ClusterTicket { seq, shard: shard_idx })
    }

    /// Block until the ticket's terminal state, bounded by the
    /// configured deadline. Exactly one of: the shard's response, the
    /// shard's typed failure, [`ClusterError::ShardDown`], or
    /// [`ClusterError::Timeout`] — never a hang.
    pub fn wait(&self, ticket: &ClusterTicket) -> Result<ClusterResponse, ClusterError> {
        let deadline = Instant::now() + self.shared.timeout;
        let mut pending = self.shared.pending.lock().unwrap();
        loop {
            match pending.get(&ticket.seq) {
                Some(Pending::Done(_)) => {
                    let Some(Pending::Done(result)) = pending.remove(&ticket.seq) else {
                        unreachable!("entry vanished under the lock");
                    };
                    return *result;
                }
                Some(Pending::Waiting { shard, .. }) => {
                    let shard = *shard;
                    let now = Instant::now();
                    if now >= deadline {
                        pending.remove(&ticket.seq);
                        drop(pending);
                        let conn = &self.shared.shards[shard];
                        conn.inflight.fetch_sub(1, Ordering::SeqCst);
                        conn.failed.fetch_add(1, Ordering::Relaxed);
                        return Err(ClusterError::Timeout {
                            shard: conn.name.clone(),
                            waited: self.shared.timeout,
                        });
                    }
                    let (guard, _) = self
                        .shared
                        .arrived
                        .wait_timeout(pending, deadline - now)
                        .unwrap();
                    pending = guard;
                }
                None => {
                    return Err(ClusterError::Protocol {
                        shard: "router".into(),
                        detail: format!("ticket {} unknown or already redeemed", ticket.seq),
                    })
                }
            }
        }
    }

    /// Blocking convenience: submit + wait.
    pub fn infer(&self, model: &str, image: &Tensor<i32>) -> Result<ClusterResponse, ClusterError> {
        let t = self.submit(model, image)?;
        self.wait(&t)
    }

    /// Pick a shard for `model`: rendezvous order over live shards
    /// serving it, with the bounded-load spill.
    fn route(&self, model: &str) -> Result<usize, ClusterError> {
        let candidates: Vec<usize> = self
            .shared
            .shards
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.alive.load(Ordering::SeqCst) && s.models.iter().any(|m| m.name == model)
            })
            .map(|(i, _)| i)
            .collect();
        if candidates.is_empty() {
            return Err(ClusterError::NoShards { model: model.to_string() });
        }
        let names: Vec<&str> =
            candidates.iter().map(|&i| self.shared.shards[i].name.as_str()).collect();
        let ranked = rendezvous_rank(model, &names);
        let first = candidates[ranked[0]];
        if self.shared.load_factor_pct == 0 || candidates.len() == 1 {
            return Ok(first);
        }
        let total: usize = candidates
            .iter()
            .map(|&i| self.shared.shards[i].inflight.load(Ordering::SeqCst))
            .sum();
        let bound = (((total + 1) * self.shared.load_factor_pct) as u64)
            .div_ceil((100 * candidates.len()) as u64)
            .max(1) as usize;
        for &r in &ranked {
            let idx = candidates[r];
            if self.shared.shards[idx].inflight.load(Ordering::SeqCst) < bound {
                if idx != first {
                    self.shared.shards[idx].reroutes.fetch_add(1, Ordering::Relaxed);
                }
                return Ok(idx);
            }
        }
        Ok(first) // every candidate at the bound: honor the hash
    }

    /// The declared input shape for a model, from the shards' Hello
    /// frames (`None` when unknown or the shard declared no extent).
    pub fn model_shape(&self, model: &str) -> Option<(usize, usize)> {
        self.shared.shards.iter().find_map(|s| {
            s.models
                .iter()
                .find(|m| m.name == model && m.in_c > 0 && m.in_hw > 0)
                .map(|m| (m.in_c as usize, m.in_hw as usize))
        })
    }

    /// Every model name any connected shard advertises (sorted,
    /// deduplicated).
    pub fn model_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .shared
            .shards
            .iter()
            .flat_map(|s| s.models.iter().map(|m| m.name.clone()))
            .collect();
        names.sort();
        names.dedup();
        names
    }

    /// Shards still considered live.
    pub fn alive_count(&self) -> usize {
        self.shared
            .shards
            .iter()
            .filter(|s| s.alive.load(Ordering::SeqCst))
            .count()
    }

    /// Snapshot per-shard counters + aggregate round-trip latency
    /// percentiles.
    pub fn metrics(&self) -> RouterMetrics {
        RouterMetrics {
            shards: self
                .shared
                .shards
                .iter()
                .map(|s| ShardStats {
                    name: s.name.clone(),
                    addr: s.addr,
                    alive: s.alive.load(Ordering::SeqCst),
                    submitted: s.submitted.load(Ordering::Relaxed),
                    completed: s.completed.load(Ordering::Relaxed),
                    failed: s.failed.load(Ordering::Relaxed),
                    connect_retries: s.connect_retries,
                    reroutes: s.reroutes.load(Ordering::Relaxed),
                    inflight: s.inflight.load(Ordering::SeqCst),
                })
                .collect(),
            rtt: self.shared.rtt.lock().unwrap().clone(),
        }
    }

    /// Close every connection and join the reader threads. (Dropping
    /// the last router clone does the same.)
    pub fn close(self) {
        drop(self);
    }
}

/// Connect with bounded exponential backoff, returning the stream and
/// how many retries it took.
fn connect_backoff(
    addr: SocketAddr,
    config: &RouterConfig,
) -> Result<(TcpStream, u64), ClusterError> {
    let attempts = config.connect_attempts.max(1);
    let mut delay = config.connect_base_delay;
    let mut last = String::new();
    for attempt in 0..attempts {
        match TcpStream::connect_timeout(&addr, config.timeout) {
            Ok(s) => return Ok((s, attempt as u64)),
            Err(e) => last = e.to_string(),
        }
        if attempt + 1 < attempts {
            std::thread::sleep(delay);
            delay = (delay * 2).min(Duration::from_millis(500));
        }
    }
    Err(ClusterError::Connect {
        addr: addr.to_string(),
        detail: format!("{last} (after {attempts} attempts)"),
    })
}

/// Per-shard reader: decode completions until the connection dies,
/// then fail everything still pending on this shard.
fn read_loop(shared: &Arc<RouterShared>, shard_idx: usize, mut stream: TcpStream) {
    loop {
        match Message::decode_from(&mut stream) {
            Ok(Message::Done { seq, argmax, latency_us, sim_cycles, batch_size, logits }) => {
                let resp = ClusterResponse {
                    seq,
                    shard: shared.shards[shard_idx].name.clone(),
                    logits,
                    argmax: argmax as usize,
                    latency_us,
                    sim_cycles,
                    batch_size: batch_size as usize,
                };
                complete(shared, shard_idx, seq, Ok(resp));
            }
            Ok(Message::Failed { seq, kind, error }) => {
                let err = ClusterError::Remote {
                    shard: shared.shards[shard_idx].name.clone(),
                    kind,
                    message: error,
                };
                complete(shared, shard_idx, seq, Err(err));
            }
            Ok(Message::Shutdown) => {
                fail_shard(shared, shard_idx, "shard asked to shut down");
                break;
            }
            Ok(other) => {
                fail_shard(shared, shard_idx, &format!("unexpected frame {other:?}"));
                break;
            }
            Err(e) => {
                let detail = if e.is_disconnect() {
                    "connection closed".to_string()
                } else {
                    e.to_string()
                };
                fail_shard(shared, shard_idx, &detail);
                break;
            }
        }
    }
}

/// Deliver one terminal state. A seq no longer pending already timed
/// out at the waiter — the late completion is dropped on the floor.
fn complete(
    shared: &RouterShared,
    shard_idx: usize,
    seq: u64,
    result: Result<ClusterResponse, ClusterError>,
) {
    let mut pending = shared.pending.lock().unwrap();
    let Some(Pending::Waiting { since, .. }) = pending.get(&seq) else {
        return;
    };
    let rtt_us = since.elapsed().as_secs_f64() * 1e6;
    let conn = &shared.shards[shard_idx];
    conn.inflight.fetch_sub(1, Ordering::SeqCst);
    match &result {
        Ok(resp) => {
            conn.completed.fetch_add(1, Ordering::Relaxed);
            shared.rtt.lock().unwrap().record_batch(1, &[rtt_us], resp.sim_cycles);
        }
        Err(_) => {
            conn.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
    pending.insert(seq, Pending::Done(Box::new(result)));
    shared.arrived.notify_all();
}

/// Mark a shard dead and complete **every** ticket pending on it as
/// [`ClusterError::ShardDown`] — the satellite bugfix: without this
/// sweep, a shard dying mid-batch leaves its waiters blocked forever.
fn fail_shard(shared: &RouterShared, shard_idx: usize, detail: &str) {
    let conn = &shared.shards[shard_idx];
    if !conn.alive.swap(false, Ordering::SeqCst) {
        return; // already swept
    }
    let _ = conn.writer.lock().unwrap().shutdown(Shutdown::Both);
    let mut pending = shared.pending.lock().unwrap();
    let seqs: Vec<u64> = pending
        .iter()
        .filter_map(|(&seq, p)| match p {
            Pending::Waiting { shard, .. } if *shard == shard_idx => Some(seq),
            _ => None,
        })
        .collect();
    for seq in seqs {
        conn.inflight.fetch_sub(1, Ordering::SeqCst);
        conn.failed.fetch_add(1, Ordering::Relaxed);
        pending.insert(
            seq,
            Pending::Done(Box::new(Err(ClusterError::ShardDown {
                shard: conn.name.clone(),
                detail: detail.to_string(),
            }))),
        );
    }
    shared.arrived.notify_all();
}

/// One shard's router-side counters.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub name: String,
    pub addr: SocketAddr,
    pub alive: bool,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub connect_retries: u64,
    pub reroutes: u64,
    pub inflight: usize,
}

/// Router metrics snapshot: per-shard counters plus aggregate
/// router-observed round-trip latency percentiles (same machinery as
/// the engine's serving metrics).
#[derive(Debug, Clone)]
pub struct RouterMetrics {
    pub shards: Vec<ShardStats>,
    pub rtt: Metrics,
}

impl RouterMetrics {
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("router:\n");
        let _ = writeln!(
            out,
            "  {:<12} {:>6} {:>9} {:>9} {:>7} {:>8} {:>8} {:>8}",
            "shard", "alive", "submitted", "completed", "failed", "retries", "reroutes", "inflight"
        );
        for s in &self.shards {
            let _ = writeln!(
                out,
                "  {:<12} {:>6} {:>9} {:>9} {:>7} {:>8} {:>8} {:>8}",
                s.name,
                if s.alive { "yes" } else { "DOWN" },
                s.submitted,
                s.completed,
                s.failed,
                s.connect_retries,
                s.reroutes,
                s.inflight
            );
        }
        if let Some(p) = self.rtt.latency_percentiles() {
            let _ = writeln!(
                out,
                "  rtt p50 {:.0} µs · p95 {:.0} µs · p99 {:.0} µs{}",
                p.p50_us,
                p.p95_us,
                p.p99_us,
                if p.approx { " (~estimated)" } else { "" }
            );
        }
        out
    }
}
