//! Cluster serving: shard the engine registry across processes.
//!
//! The engine façade (PR 4) serves every model from one process; this
//! subsystem is the multi-process layer the ROADMAP's scale-out story
//! plugs into — model *sharding* by consistent hashing (model
//! partitioning across workers stays an open item). Dependency-free,
//! `std`-only, like everything else in the crate:
//!
//! * [`wire`] — length-prefixed, checksummed binary frames carrying
//!   the submit/poll/wait ticket protocol over a socket.
//! * [`shard`] — one engine behind a `TcpListener`, readiness
//!   handshake included.
//! * [`router`] — client-side bounded rendezvous hashing over the
//!   shard set, per-request deadlines, typed fail-fast when a shard
//!   dies mid-batch (zero hangs).
//! * [`supervisor`] — spawn/monitor N `tetris shard` children,
//!   restart-on-crash behind a [`supervisor::CrashLoopBreaker`].
//! * [`loadgen`] — fault-tolerant closed-loop load with exact
//!   percentiles.
//!
//! Every shard is spawned from the same [`ModelSetSpec`] and seed, so
//! all shards carry identical models with identical synthetic weights
//! — which is what makes routed logits bit-exact against a single
//! in-process engine (`tests/cluster.rs` pins this zoo-wide).
//!
//! CLI: `tetris cluster --shards 4` (supervisor + router + loadgen in
//! one command) and `tetris shard --listen 127.0.0.1:0` (one shard,
//! standalone or under a supervisor).

pub mod loadgen;
pub mod router;
pub mod shard;
pub mod supervisor;
pub mod wire;

pub use router::{
    rendezvous_rank, ClusterError, ClusterResponse, ClusterTicket, Router, RouterConfig,
};
pub use shard::{ShardHandle, ShardServer};
pub use supervisor::{CrashLoopBreaker, Supervisor, SupervisorConfig};
pub use wire::{FailKind, Message, WireModel};

use std::net::SocketAddr;
use std::path::PathBuf;
use std::time::Duration;

use crate::config::Mode;
use crate::coordinator::backend::SacBackend;
use crate::engine::Engine;
use crate::model::weights::{synthetic_loaded_with_heads, DensityCalibration};
use crate::model::zoo;

/// One model in a shard's registry: a zoo name plus the channel
/// divisor / spatial size of its scaled serving copy (`tiny` is the
/// un-scaled tiny CNN and ignores both).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelEntry {
    pub name: String,
    pub scale: usize,
    pub hw: usize,
}

/// A parsed `--models` spec: a comma list of `name[:scale[:hw]]`
/// entries, e.g. `tiny,nin:16:64,vgg16:16:32`. Defaults: scale 16;
/// hw 32 for the VGGs, 64 otherwise — the same scaled-zoo sizes the
/// engine tests serve.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSetSpec {
    pub entries: Vec<ModelEntry>,
}

impl ModelSetSpec {
    pub fn parse(spec: &str) -> crate::Result<Self> {
        let mut entries = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let mut fields = part.split(':');
            let name = fields.next().unwrap_or_default().to_string();
            if name != "tiny" {
                // Validate the name now — a shard child failing later
                // with an opaque exit is much harder to diagnose.
                zoo::by_name(&name)?;
            }
            let default_hw = if name.starts_with("vgg") { 32 } else { 64 };
            let scale = match fields.next() {
                None => 16,
                Some(s) => s.parse::<usize>().map_err(|_| {
                    crate::Error::Config(format!("model spec `{part}`: bad scale `{s}`"))
                })?,
            }
            .max(1);
            let hw = match fields.next() {
                None => default_hw,
                Some(s) => s.parse::<usize>().map_err(|_| {
                    crate::Error::Config(format!("model spec `{part}`: bad hw `{s}`"))
                })?,
            }
            .max(1);
            if let Some(extra) = fields.next() {
                return Err(crate::Error::Config(format!(
                    "model spec `{part}`: unexpected `:{extra}` (want name[:scale[:hw]])"
                )));
            }
            entries.push(ModelEntry { name, scale, hw });
        }
        if entries.is_empty() {
            return Err(crate::Error::Config(
                "model set is empty (want e.g. `tiny,nin:16:64`)".into(),
            ));
        }
        Ok(Self { entries })
    }

    /// Build one engine carrying every entry. Weights are synthetic
    /// and **deterministic in `seed`** — every shard built from the
    /// same spec + seed serves bit-identical models.
    pub fn build_engine(
        &self,
        workers: usize,
        seed: u64,
        max_batch: usize,
    ) -> crate::Result<Engine> {
        let mut b = Engine::builder().workers(workers).max_batch(max_batch);
        for e in &self.entries {
            if e.name == "tiny" {
                b = b.register("tiny", zoo::tiny_cnn(), SacBackend::synthetic_weights(seed)?);
            } else {
                let net = zoo::by_name(&e.name)?.scaled(e.scale, e.hw);
                let w = synthetic_loaded_with_heads(
                    &net,
                    Mode::Fp16,
                    10,
                    &e.name,
                    DensityCalibration::Fig2,
                    seed,
                )?;
                b = b.register(e.name.clone(), net, w);
            }
        }
        b.build()
    }
}

/// `tetris shard` options (see `main.rs` for the flag surface).
#[derive(Debug, Clone)]
pub struct ShardCliOpts {
    pub name: String,
    pub listen: SocketAddr,
    pub models: String,
    pub workers: usize,
    pub seed: u64,
    pub max_batch: usize,
    /// Supervised children exit when stdin closes, so no shard
    /// outlives a dead supervisor.
    pub supervised: bool,
}

/// Run one shard until stopped: build the engine, bind, announce
/// readiness on stdout, serve.
pub fn shard_main(opts: ShardCliOpts) -> crate::Result<()> {
    use std::io::{Read, Write};
    let spec = ModelSetSpec::parse(&opts.models)?;
    let engine = spec.build_engine(opts.workers, opts.seed, opts.max_batch)?;
    let handle = ShardServer::spawn(opts.name, engine, opts.listen)?;
    // The process-level readiness handshake the supervisor blocks on.
    println!("{}{}", supervisor::READY_PREFIX, handle.addr());
    std::io::stdout().flush().ok();
    if opts.supervised {
        // Serve until the supervisor hangs up.
        let mut sink = [0u8; 256];
        let mut stdin = std::io::stdin();
        loop {
            match stdin.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
        handle.shutdown();
        Ok(())
    } else {
        eprintln!("tetris shard: serving on {} (ctrl-C to stop)", handle.addr());
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
}

/// `tetris cluster` options.
#[derive(Debug, Clone)]
pub struct ClusterCliOpts {
    pub shards: usize,
    pub models: String,
    pub requests: usize,
    pub clients: usize,
    pub workers: usize,
    pub seed: u64,
    pub max_batch: usize,
    pub timeout: Duration,
    /// The drill: kill one shard after ~¼ of the load completed and
    /// prove every outstanding ticket still terminates (typed, no
    /// hangs) while the survivors keep serving.
    pub kill_one: bool,
    /// Binary for shard children (tests pass the built CLI; `None` =
    /// current executable).
    pub program: Option<PathBuf>,
}

/// Supervisor + router + loadgen in one command: spawn the shards,
/// drive closed-loop load, print the loadgen and router reports.
pub fn cluster_main(opts: ClusterCliOpts) -> crate::Result<()> {
    ModelSetSpec::parse(&opts.models)?; // fail before spawning children
    let sup = Supervisor::start(SupervisorConfig {
        program: opts.program.clone(),
        shards: opts.shards,
        models: opts.models.clone(),
        workers: opts.workers,
        seed: opts.seed,
        max_batch: opts.max_batch,
        ..SupervisorConfig::default()
    })?;
    let addrs = sup.addrs();
    println!(
        "cluster: {} shard(s) ready: {}",
        addrs.len(),
        addrs.iter().map(|a| a.to_string()).collect::<Vec<_>>().join(", ")
    );
    let router = Router::connect(
        &addrs,
        RouterConfig { timeout: opts.timeout, ..RouterConfig::default() },
    )?;

    let report = std::thread::scope(|scope| {
        if opts.kill_one {
            let router = router.clone();
            let sup = &sup;
            let quarter = (opts.requests / 4).max(1) as u64;
            scope.spawn(move || {
                loop {
                    let m = router.metrics();
                    let settled: u64 = m.shards.iter().map(|s| s.completed + s.failed).sum();
                    if settled >= quarter {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                eprintln!("drill: killing shard-0 mid-flight");
                sup.kill_shard(0);
            });
        }
        loadgen::run(
            &router,
            &loadgen::LoadgenConfig {
                requests: opts.requests,
                clients: opts.clients,
                seed: opts.seed,
                models: Vec::new(),
            },
        )
    })?;

    print!("{}", report.render());
    print!("{}", router.metrics().render());
    router.close();
    sup.shutdown();

    // Zero-hang accounting: loadgen returning at all means every
    // request reached a terminal state; make the arithmetic explicit.
    if report.done + report.failed != report.requests {
        return Err(crate::Error::Coordinator(format!(
            "cluster: {} + {} settled of {} submitted — some request never terminated",
            report.done, report.failed, report.requests
        )));
    }
    if opts.kill_one && report.done == 0 {
        return Err(crate::Error::Coordinator(
            "cluster: kill drill left no surviving completions — survivors did not serve".into(),
        ));
    }
    println!("cluster OK ({} ok / {} failed)", report.done, report.failed);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_set_spec_parses_defaults_and_explicit_fields() {
        let s = ModelSetSpec::parse("tiny,nin:16:64,vgg16:16:32").unwrap();
        assert_eq!(s.entries.len(), 3);
        assert_eq!(s.entries[0].name, "tiny");
        assert_eq!(s.entries[1], ModelEntry { name: "nin".into(), scale: 16, hw: 64 });
        assert_eq!(s.entries[2], ModelEntry { name: "vgg16".into(), scale: 16, hw: 32 });
        // Defaults: scale 16, hw 64 (32 for the VGGs).
        let d = ModelSetSpec::parse("alexnet,vgg19").unwrap();
        assert_eq!(d.entries[0], ModelEntry { name: "alexnet".into(), scale: 16, hw: 64 });
        assert_eq!(d.entries[1], ModelEntry { name: "vgg19".into(), scale: 16, hw: 32 });
    }

    #[test]
    fn model_set_spec_rejects_junk() {
        assert!(ModelSetSpec::parse("").is_err());
        assert!(ModelSetSpec::parse("resnet50").is_err(), "unknown zoo name");
        assert!(ModelSetSpec::parse("nin:x").is_err(), "bad scale");
        assert!(ModelSetSpec::parse("nin:16:y").is_err(), "bad hw");
        assert!(ModelSetSpec::parse("nin:16:64:9").is_err(), "trailing field");
    }
}
