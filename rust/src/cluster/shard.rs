//! One cluster shard: an [`Engine`] behind a TCP listener speaking the
//! [`wire`](super::wire) protocol.
//!
//! The readiness handshake mirrors the engine's worker handshake
//! (PR 4): [`ShardServer::spawn`] returns only after the engine is
//! built (every worker reported its backends up) **and** the listener
//! is bound — so a caller holding a [`ShardHandle`] knows the shard
//! serves, the same way `EngineBuilder::build` returning `Ok` means
//! every lane serves. Over a socket the same promise is the `Hello`
//! frame: it is written first on every connection, so a client that
//! has read it knows the models behind the wire are compiled and
//! their workers are up.
//!
//! Per connection, a reader thread decodes `Submit` frames and turns
//! them into engine tickets; a completer thread redeems the tickets in
//! submission order and writes each one's terminal `Done`/`Failed`
//! frame. Submissions the engine rejects up front (shape, unknown
//! model) complete as `Failed` with the engine's typed kind — the
//! in-process "typed completion, never a hang" contract, frame for
//! frame.

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::engine::{Engine, InferSession, Ticket};

use super::wire::{FailKind, Message, WireModel};

/// A running shard server. Dropping the handle performs a graceful
/// [`ShardHandle::shutdown`].
pub struct ShardServer;

/// Control handle for one spawned shard.
pub struct ShardHandle {
    name: String,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Option<JoinHandle<()>>,
}

impl ShardServer {
    /// Bind `listen` and serve `engine` over it. The engine is moved
    /// into the accept thread (engines are `Send` but not `Sync`);
    /// connection threads hold cheap [`InferSession`] clones.
    pub fn spawn(
        name: impl Into<String>,
        engine: Engine,
        listen: SocketAddr,
    ) -> crate::Result<ShardHandle> {
        let name = name.into();
        let listener = TcpListener::bind(listen).map_err(|e| {
            crate::Error::Coordinator(format!("shard `{name}` cannot bind {listen}: {e}"))
        })?;
        let addr = listener.local_addr().map_err(|e| {
            crate::Error::Coordinator(format!("shard `{name}`: local_addr failed: {e}"))
        })?;
        let hello = Message::Hello {
            shard: name.clone(),
            models: engine
                .models()
                .iter()
                .map(|m| WireModel {
                    name: m.name().to_string(),
                    in_c: m.input_channels().unwrap_or(0) as u32,
                    in_hw: m.input_hw().unwrap_or(0) as u32,
                })
                .collect(),
        };
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let shard = name.clone();
            std::thread::spawn(move || {
                let mut workers: Vec<JoinHandle<()>> = Vec::new();
                while !stop.load(Ordering::SeqCst) {
                    let stream = match listener.accept() {
                        Ok((s, _)) => s,
                        Err(e) => {
                            if stop.load(Ordering::SeqCst) {
                                break;
                            }
                            eprintln!("shard `{shard}`: accept failed: {e}");
                            continue;
                        }
                    };
                    if stop.load(Ordering::SeqCst) {
                        break; // the unblocking self-connect
                    }
                    conns.lock().unwrap().push(match stream.try_clone() {
                        Ok(c) => c,
                        Err(e) => {
                            eprintln!("shard `{shard}`: clone failed: {e}");
                            continue;
                        }
                    });
                    match serve_connection(&shard, stream, engine.session(), &hello) {
                        Ok(mut handles) => workers.append(&mut handles),
                        Err(e) => eprintln!("shard `{shard}`: connection setup failed: {e}"),
                    }
                }
                // Connection threads first (their completers may still
                // be redeeming tickets from the live engine), then the
                // engine itself (drains lanes, joins workers).
                for h in workers {
                    let _ = h.join();
                }
                drop(engine);
            })
        };
        Ok(ShardHandle { name, addr, stop, conns, accept: Some(accept) })
    }
}

/// Set up one connection's reader + completer threads. The reader owns
/// the read half, the completer the write half; only the completer
/// writes after the `Hello` below, so frames never interleave.
fn serve_connection(
    shard: &str,
    stream: TcpStream,
    session: InferSession,
    hello: &Message,
) -> std::io::Result<Vec<JoinHandle<()>>> {
    let _ = stream.set_nodelay(true);
    let mut write_half = stream.try_clone()?;
    hello.encode_to(&mut write_half)?;
    write_half.flush()?;

    // Reader → completer: submission order, ticket or up-front typed
    // rejection.
    type Slot = (u64, Result<Ticket, (FailKind, String)>);
    let (tx, rx) = channel::<Slot>();

    let reader = {
        let session = session.clone();
        let shard = shard.to_string();
        std::thread::spawn(move || read_loop(&shard, stream, &session, &tx))
    };
    let completer = {
        let shard = shard.to_string();
        std::thread::spawn(move || complete_loop(&shard, write_half, &session, &rx))
    };
    Ok(vec![reader, completer])
}

/// Decode submissions until the peer hangs up (or violates the
/// protocol) and hand each one to the completer.
fn read_loop(
    shard: &str,
    mut stream: TcpStream,
    session: &InferSession,
    tx: &Sender<(u64, Result<Ticket, (FailKind, String)>)>,
) {
    loop {
        match Message::decode_from(&mut stream) {
            Ok(Message::Submit { seq, model, shape, image }) => {
                let slot = submit_one(session, &model, shape, image);
                if tx.send((seq, slot)).is_err() {
                    break; // completer died (socket gone)
                }
            }
            Ok(Message::Shutdown) => break,
            Ok(other) => {
                eprintln!("shard `{shard}`: client sent unexpected {other:?}; closing");
                break;
            }
            Err(e) => {
                if !e.is_disconnect() {
                    eprintln!("shard `{shard}`: dropping connection: {e}");
                }
                break;
            }
        }
    }
    let _ = stream.shutdown(Shutdown::Read);
}

/// One submission → engine ticket, or its typed up-front rejection.
fn submit_one(
    session: &InferSession,
    model: &str,
    shape: [u32; 3],
    image: Vec<i32>,
) -> Result<Ticket, (FailKind, String)> {
    let dims: Vec<usize> = shape.iter().map(|&d| d as usize).collect();
    let tensor = crate::model::Tensor::from_vec(&dims, image)
        .map_err(|e| (FailKind::from_engine_error(&e), e.to_string()))?;
    session
        .submit(model, tensor)
        .map_err(|e| (FailKind::from_engine_error(&e), e.to_string()))
}

/// Redeem tickets in submission order and write each terminal frame.
/// An engine-side failure (the PR 4 `Completion::Failed` path) crosses
/// the wire as a typed `Failed`, never a dropped seq.
fn complete_loop(
    shard: &str,
    mut stream: TcpStream,
    session: &InferSession,
    rx: &Receiver<(u64, Result<Ticket, (FailKind, String)>)>,
) {
    while let Ok((seq, slot)) = rx.recv() {
        let frame = match slot {
            Ok(ticket) => match session.wait(&ticket) {
                Ok(resp) => Message::Done {
                    seq,
                    argmax: resp.argmax as u32,
                    latency_us: resp.latency_us,
                    sim_cycles: resp.sim_cycles,
                    batch_size: resp.batch_size as u32,
                    logits: resp.logits,
                },
                Err(e) => Message::Failed {
                    seq,
                    kind: FailKind::from_engine_error(&e),
                    error: e.to_string(),
                },
            },
            Err((kind, error)) => Message::Failed { seq, kind, error },
        };
        if frame.encode_to(&mut stream).is_err() {
            // Client is gone; drain remaining tickets so the engine's
            // completion store does not accumulate unredeemed entries.
            for (_, slot) in rx.try_iter() {
                if let Ok(t) = slot {
                    let _ = session.wait(&t);
                }
            }
            break;
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
    let _ = shard; // name kept for symmetry with read_loop diagnostics
}

impl ShardHandle {
    /// The bound address (resolves `:0` requests to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Graceful stop: refuse new connections, half-close every open
    /// connection's read side (clients' in-flight requests still
    /// complete and their frames still flush), drain the engine, join
    /// every thread.
    pub fn shutdown(mut self) {
        self.stop_with(Shutdown::Read);
    }

    /// Abrupt stop — the kill drill. Both socket halves close
    /// immediately, so clients see EOF *while requests are
    /// outstanding*; the router must complete every one of them as a
    /// typed failure (`tests/cluster.rs` pins this).
    pub fn kill(mut self) {
        self.stop_with(Shutdown::Both);
    }

    fn stop_with(&mut self, how: Shutdown) {
        self.stop.store(true, Ordering::SeqCst);
        for c in self.conns.lock().unwrap().iter() {
            let _ = c.shutdown(how);
        }
        // Unblock the accept loop.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardHandle {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_with(Shutdown::Read);
        }
    }
}
