//! Length-prefixed binary wire codec for the cluster serving protocol.
//!
//! The engine's submit/poll/wait ticket contract (PR 4), flattened
//! onto a byte stream: a client sends [`Message::Submit`] frames and
//! the shard answers each `seq` with exactly one terminal
//! [`Message::Done`] or [`Message::Failed`] — the same
//! "typed completion, never a hang" contract `engine::serve::
//! Completion` enforces in-process, extended across a socket. A shard
//! opens every connection with a [`Message::Hello`] advertising its
//! registered models (the readiness handshake, mirroring the engine's
//! worker handshake: a client that read `Hello` knows the engine
//! behind the socket compiled and came up).
//!
//! ## Frame layout (little-endian)
//!
//! ```text
//! byte 0      MAGIC (0x54, 'T')
//! byte 1      VERSION (1)
//! byte 2      message kind
//! bytes 3..7  payload length, u32
//! bytes 7..   payload
//! last 4      FNV-1a-32 checksum of the payload
//! ```
//!
//! Every decode failure is a typed [`WireError`] — truncation, a
//! corrupt checksum, an unknown version or kind, an oversize length —
//! so a router never trusts a damaged frame and a shard never executes
//! one. The codec is pure `std` over `Read`/`Write`, unit-testable on
//! in-memory buffers, and property-swept in `tests/cluster.rs`.

use std::io::{Read, Write};

/// Protocol magic byte (`'T'` for Tetris).
pub const MAGIC: u8 = 0x54;

/// Wire protocol version. Bump on any frame- or payload-layout change;
/// decoders reject every other version with [`WireError::BadVersion`].
pub const WIRE_VERSION: u8 = 1;

/// Upper bound on one frame's payload (64 MiB). A corrupt or hostile
/// length prefix is rejected before any allocation happens.
pub const MAX_PAYLOAD: u32 = 64 << 20;

/// Typed decode failure. `Io` covers transport errors (a peer that
/// vanished mid-frame reads as `Io(UnexpectedEof)`).
#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    BadMagic(u8),
    BadVersion(u8),
    BadKind(u8),
    BadChecksum { want: u32, got: u32 },
    Oversize(u32),
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire I/O: {e}"),
            WireError::BadMagic(b) => write!(f, "bad frame magic 0x{b:02x}"),
            WireError::BadVersion(v) => {
                write!(f, "wire version {v} (this build speaks {WIRE_VERSION})")
            }
            WireError::BadKind(k) => write!(f, "unknown message kind {k}"),
            WireError::BadChecksum { want, got } => {
                write!(f, "frame checksum mismatch (want {want:#010x}, got {got:#010x})")
            }
            WireError::Oversize(n) => {
                write!(f, "frame payload {n} bytes exceeds the {MAX_PAYLOAD}-byte bound")
            }
            WireError::Malformed(msg) => write!(f, "malformed payload: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

impl From<WireError> for crate::Error {
    fn from(e: WireError) -> Self {
        crate::Error::Coordinator(format!("cluster wire: {e}"))
    }
}

impl WireError {
    /// True when the failure is a clean end-of-stream *between* frames
    /// (the peer hung up) rather than damage inside one.
    pub fn is_disconnect(&self) -> bool {
        matches!(self, WireError::Io(e)
            if e.kind() == std::io::ErrorKind::UnexpectedEof
            || e.kind() == std::io::ErrorKind::ConnectionReset
            || e.kind() == std::io::ErrorKind::BrokenPipe)
    }
}

/// Why a request failed, preserved across the wire so the router can
/// surface the same typed error the engine raised — plus the
/// router-side kinds (`ShardDown`, `Timeout`) that only exist once a
/// network sits between submit and completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailKind {
    /// Submission rejected up front (bad image shape).
    Shape,
    /// No such model / configuration rejection.
    Config,
    /// The batch failed at the backend (the PR 4 typed batch-failure
    /// contract, forwarded).
    Backend,
    /// The shard's connection died with this request outstanding —
    /// raised by the *router*, never sent by a healthy shard.
    ShardDown,
    /// The router-side deadline expired before a completion arrived.
    Timeout,
    /// The peer violated the wire protocol.
    Protocol,
}

impl FailKind {
    fn to_u8(self) -> u8 {
        match self {
            FailKind::Shape => 0,
            FailKind::Config => 1,
            FailKind::Backend => 2,
            FailKind::ShardDown => 3,
            FailKind::Timeout => 4,
            FailKind::Protocol => 5,
        }
    }

    fn from_u8(b: u8) -> Result<Self, WireError> {
        Ok(match b {
            0 => FailKind::Shape,
            1 => FailKind::Config,
            2 => FailKind::Backend,
            3 => FailKind::ShardDown,
            4 => FailKind::Timeout,
            5 => FailKind::Protocol,
            other => return Err(WireError::Malformed(format!("failure kind {other}"))),
        })
    }

    /// Classify an engine-side error for the wire (`Shape`/`Config`
    /// rejections keep their kind; everything else is a backend
    /// failure).
    pub fn from_engine_error(e: &crate::Error) -> Self {
        match e {
            crate::Error::Shape(_) => FailKind::Shape,
            crate::Error::Config(_) => FailKind::Config,
            _ => FailKind::Backend,
        }
    }
}

impl std::fmt::Display for FailKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            FailKind::Shape => "shape",
            FailKind::Config => "config",
            FailKind::Backend => "backend",
            FailKind::ShardDown => "shard-down",
            FailKind::Timeout => "timeout",
            FailKind::Protocol => "protocol",
        };
        f.write_str(s)
    }
}

/// One model a shard advertises in its [`Message::Hello`]: the name
/// plus the input shape submissions are validated against (0 = the
/// model declared no fixed extent).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireModel {
    pub name: String,
    pub in_c: u32,
    pub in_hw: u32,
}

/// One protocol message. `Submit` → exactly one `Done` | `Failed` per
/// `seq`; `Hello` opens every shard→client stream; `Shutdown` asks the
/// peer to close cleanly after draining.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Shard → client readiness handshake: identity + registered
    /// models.
    Hello { shard: String, models: Vec<WireModel> },
    /// Client → shard: one (C, H, W) Q8.8 image for `model`.
    Submit { seq: u64, model: String, shape: [u32; 3], image: Vec<i32> },
    /// Shard → client: the request's terminal success (logits +
    /// engine-side latency/cycle accounting).
    Done {
        seq: u64,
        argmax: u32,
        latency_us: f64,
        sim_cycles: u64,
        batch_size: u32,
        logits: Vec<i32>,
    },
    /// Shard → client (or router-internal): the request's terminal
    /// typed failure.
    Failed { seq: u64, kind: FailKind, error: String },
    /// Either direction: drain and close.
    Shutdown,
}

impl Message {
    fn kind_byte(&self) -> u8 {
        match self {
            Message::Hello { .. } => 1,
            Message::Submit { .. } => 2,
            Message::Done { .. } => 3,
            Message::Failed { .. } => 4,
            Message::Shutdown => 5,
        }
    }

    /// Encode one frame onto a writer (header + payload + checksum).
    pub fn encode_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        let payload = self.payload();
        debug_assert!(payload.len() <= MAX_PAYLOAD as usize);
        let mut frame = Vec::with_capacity(payload.len() + 11);
        frame.push(MAGIC);
        frame.push(WIRE_VERSION);
        frame.push(self.kind_byte());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        frame.extend_from_slice(&fnv1a32(&payload).to_le_bytes());
        w.write_all(&frame)
    }

    /// Encode into a fresh byte vector (tests, buffered writers).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode_to(&mut buf).expect("Vec<u8> writes are infallible");
        buf
    }

    /// Decode exactly one frame from a reader. Blocks until the frame
    /// is complete; any damage or truncation is a typed [`WireError`].
    pub fn decode_from(r: &mut impl Read) -> Result<Message, WireError> {
        let mut head = [0u8; 7];
        r.read_exact(&mut head)?;
        if head[0] != MAGIC {
            return Err(WireError::BadMagic(head[0]));
        }
        if head[1] != WIRE_VERSION {
            return Err(WireError::BadVersion(head[1]));
        }
        let kind = head[2];
        let len = u32::from_le_bytes([head[3], head[4], head[5], head[6]]);
        if len > MAX_PAYLOAD {
            return Err(WireError::Oversize(len));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)?;
        let mut sum = [0u8; 4];
        r.read_exact(&mut sum)?;
        let got = u32::from_le_bytes(sum);
        let want = fnv1a32(&payload);
        if got != want {
            return Err(WireError::BadChecksum { want, got });
        }
        Self::from_payload(kind, &payload)
    }

    fn payload(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            Message::Hello { shard, models } => {
                e.str(shard);
                e.u32(models.len() as u32);
                for m in models {
                    e.str(&m.name);
                    e.u32(m.in_c);
                    e.u32(m.in_hw);
                }
            }
            Message::Submit { seq, model, shape, image } => {
                e.u64(*seq);
                e.str(model);
                for d in shape {
                    e.u32(*d);
                }
                e.u32(image.len() as u32);
                for v in image {
                    e.i32(*v);
                }
            }
            Message::Done { seq, argmax, latency_us, sim_cycles, batch_size, logits } => {
                e.u64(*seq);
                e.u32(*argmax);
                e.u64(latency_us.to_bits());
                e.u64(*sim_cycles);
                e.u32(*batch_size);
                e.u32(logits.len() as u32);
                for v in logits {
                    e.i32(*v);
                }
            }
            Message::Failed { seq, kind, error } => {
                e.u64(*seq);
                e.u8(kind.to_u8());
                e.str(error);
            }
            Message::Shutdown => {}
        }
        e.buf
    }

    fn from_payload(kind: u8, payload: &[u8]) -> Result<Message, WireError> {
        let mut d = Dec::new(payload);
        let msg = match kind {
            1 => {
                let shard = d.str()?;
                let n = d.u32()? as usize;
                let mut models = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    models.push(WireModel { name: d.str()?, in_c: d.u32()?, in_hw: d.u32()? });
                }
                Message::Hello { shard, models }
            }
            2 => {
                let seq = d.u64()?;
                let model = d.str()?;
                let shape = [d.u32()?, d.u32()?, d.u32()?];
                let n = d.u32()? as usize;
                let want: usize = shape.iter().map(|&x| x as usize).product();
                if n != want {
                    return Err(WireError::Malformed(format!(
                        "submit image carries {n} values for shape {shape:?} ({want})"
                    )));
                }
                let mut image = Vec::with_capacity(n);
                for _ in 0..n {
                    image.push(d.i32()?);
                }
                Message::Submit { seq, model, shape, image }
            }
            3 => {
                let seq = d.u64()?;
                let argmax = d.u32()?;
                let latency_us = f64::from_bits(d.u64()?);
                let sim_cycles = d.u64()?;
                let batch_size = d.u32()?;
                let n = d.u32()? as usize;
                let mut logits = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    logits.push(d.i32()?);
                }
                Message::Done { seq, argmax, latency_us, sim_cycles, batch_size, logits }
            }
            4 => {
                let seq = d.u64()?;
                let kind = FailKind::from_u8(d.u8()?)?;
                let error = d.str()?;
                Message::Failed { seq, kind, error }
            }
            5 => Message::Shutdown,
            other => return Err(WireError::BadKind(other)),
        };
        d.finish()?;
        Ok(msg)
    }
}

/// FNV-1a 32-bit over a byte slice — cheap, dependency-free, and
/// plenty for catching torn/corrupt frames (this is an integrity
/// check, not an authenticity one).
pub fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// 64-bit FNV-1a over several byte slices — the rendezvous-hash score
/// primitive (`router::rendezvous_rank`).
pub fn fnv1a64(parts: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for part in parts {
        for &b in *part {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        // Delimit parts so ("ab","c") never collides with ("a","bc").
        h ^= 0xFF;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Little-endian payload encoder.
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Self {
        Self { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Little-endian payload decoder over a checksum-verified slice.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len()).ok_or_else(|| {
            WireError::Malformed(format!(
                "payload ends at {} but field wants bytes {}..{}",
                self.buf.len(),
                self.pos,
                self.pos.saturating_add(n)
            ))
        })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn i32(&mut self) -> Result<i32, WireError> {
        let b = self.take(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("string field is not UTF-8".into()))
    }

    /// Reject trailing garbage: a payload must be consumed exactly.
    fn finish(self) -> Result<(), WireError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(WireError::Malformed(format!(
                "{} trailing bytes after the message",
                self.buf.len() - self.pos
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: &Message) -> Message {
        let bytes = m.encode();
        let mut cur = &bytes[..];
        let back = Message::decode_from(&mut cur).expect("decode");
        assert!(cur.is_empty(), "decode must consume the whole frame");
        back
    }

    #[test]
    fn all_message_kinds_roundtrip() {
        let msgs = vec![
            Message::Hello {
                shard: "shard-0".into(),
                models: vec![
                    WireModel { name: "tiny".into(), in_c: 1, in_hw: 16 },
                    WireModel { name: "vgg16".into(), in_c: 3, in_hw: 32 },
                ],
            },
            Message::Submit {
                seq: 42,
                model: "tiny".into(),
                shape: [1, 2, 3],
                image: vec![-5, 0, 7, 123, -999, 4],
            },
            Message::Done {
                seq: 42,
                argmax: 3,
                latency_us: 123.5,
                sim_cycles: 99_999,
                batch_size: 8,
                logits: vec![i32::MIN, -1, 0, 1, i32::MAX],
            },
            Message::Failed { seq: 7, kind: FailKind::Backend, error: "boom".into() },
            Message::Shutdown,
        ];
        for m in &msgs {
            assert_eq!(&roundtrip(m), m);
        }
    }

    #[test]
    fn truncated_frames_are_rejected_at_every_length() {
        let full = Message::Submit {
            seq: 1,
            model: "m".into(),
            shape: [1, 2, 2],
            image: vec![1, 2, 3, 4],
        }
        .encode();
        for cut in 0..full.len() {
            let mut r = &full[..cut];
            let err = Message::decode_from(&mut r).expect_err("truncation must fail");
            assert!(
                matches!(err, WireError::Io(_)),
                "cut at {cut}: got {err:?}"
            );
        }
    }

    #[test]
    fn corruption_is_rejected_at_every_byte() {
        let full = Message::Failed { seq: 9, kind: FailKind::Shape, error: "bad".into() }
            .encode();
        for i in 0..full.len() {
            let mut bytes = full.clone();
            bytes[i] ^= 0x40;
            let mut r = &bytes[..];
            match Message::decode_from(&mut r) {
                // A flipped payload/checksum byte must be caught by the
                // checksum; header flips by their own typed checks; a
                // flipped length reads the checksum from the wrong
                // offset (mismatch) or runs off the buffer (Io).
                Err(_) => {}
                Ok(m) => panic!("flip at byte {i} decoded as {m:?}"),
            }
        }
    }

    #[test]
    fn future_versions_and_kinds_are_rejected() {
        let mut bytes = Message::Shutdown.encode();
        bytes[1] = WIRE_VERSION + 1;
        assert!(matches!(
            Message::decode_from(&mut &bytes[..]),
            Err(WireError::BadVersion(_))
        ));
        let mut bytes = Message::Shutdown.encode();
        bytes[0] = 0x00;
        assert!(matches!(
            Message::decode_from(&mut &bytes[..]),
            Err(WireError::BadMagic(0))
        ));
    }

    #[test]
    fn oversize_length_is_rejected_before_allocation() {
        let mut bytes = Message::Shutdown.encode();
        bytes[3..7].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(
            Message::decode_from(&mut &bytes[..]),
            Err(WireError::Oversize(_))
        ));
    }

    #[test]
    fn submit_shape_and_payload_must_agree() {
        // Hand-build a Submit whose element count contradicts its
        // shape: the decoder must reject it even though the frame
        // checksum is valid.
        let lying = Message::Submit {
            seq: 1,
            model: "m".into(),
            shape: [1, 1, 1],
            image: vec![1],
        };
        let mut bytes = lying.encode();
        // Patch shape W from 1 to 2 inside the payload (offset: 7-byte
        // header + 8 seq + 4 strlen + 1 "m" + 4 c + 4 h = 28), then
        // re-checksum so only the semantic check can catch it.
        bytes[28] = 2;
        let len = bytes.len();
        let payload = bytes[7..len - 4].to_vec();
        let sum = fnv1a32(&payload).to_le_bytes();
        bytes[len - 4..].copy_from_slice(&sum);
        assert!(matches!(
            Message::decode_from(&mut &bytes[..]),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn fail_kinds_roundtrip_and_classify_engine_errors() {
        for kind in [
            FailKind::Shape,
            FailKind::Config,
            FailKind::Backend,
            FailKind::ShardDown,
            FailKind::Timeout,
            FailKind::Protocol,
        ] {
            assert_eq!(FailKind::from_u8(kind.to_u8()).unwrap(), kind);
        }
        assert!(FailKind::from_u8(99).is_err());
        assert_eq!(
            FailKind::from_engine_error(&crate::Error::Shape("x".into())),
            FailKind::Shape
        );
        assert_eq!(
            FailKind::from_engine_error(&crate::Error::Config("x".into())),
            FailKind::Config
        );
        assert_eq!(
            FailKind::from_engine_error(&crate::Error::Coordinator("x".into())),
            FailKind::Backend
        );
    }

    #[test]
    fn fnv_hashes_are_stable_and_part_delimited() {
        // Pinned values keep the ring assignment stable across builds.
        assert_eq!(fnv1a32(b""), 0x811C_9DC5);
        assert_ne!(fnv1a64(&[b"ab", b"c"]), fnv1a64(&[b"a", b"bc"]));
        assert_eq!(fnv1a64(&[b"model", b"shard"]), fnv1a64(&[b"model", b"shard"]));
    }

    #[test]
    fn multiple_frames_stream_back_to_back() {
        let a = Message::Submit { seq: 1, model: "x".into(), shape: [1, 1, 2], image: vec![4, 5] };
        let b = Message::Shutdown;
        let mut stream = a.encode();
        stream.extend_from_slice(&b.encode());
        let mut r = &stream[..];
        assert_eq!(Message::decode_from(&mut r).unwrap(), a);
        assert_eq!(Message::decode_from(&mut r).unwrap(), b);
        assert!(r.is_empty());
    }
}
