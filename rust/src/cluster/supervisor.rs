//! Shard process supervision: spawn N `tetris shard` children, watch
//! them, restart crashes — bounded by a [`CrashLoopBreaker`].
//!
//! Each child is spawned with `--supervised` (it exits when its stdin
//! closes, so no shard outlives a dead supervisor) and announces
//! readiness by printing `tetris-shard ready addr=<ip:port>` on
//! stdout — the process-level readiness handshake, mirroring the
//! in-process worker handshake: [`Supervisor::start`] returns only
//! after **every** shard printed it, so the returned addresses are
//! live listeners. First spawns bind port 0 and report the kernel's
//! pick; restarts re-bind the same port when the kernel has released
//! it (falling back to a fresh port otherwise — `Supervisor::addrs`
//! always reports the current one). A restarted shard serves *new*
//! connections — routers are fail-fast by contract and do not
//! resubscribe.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// The stdout line a shard prints once it serves (keep in sync with
/// `cluster::shard_main`).
pub const READY_PREFIX: &str = "tetris-shard ready addr=";

/// Supervisor configuration.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Binary to exec. `None` = the current executable — tests
    /// override this with `env!("CARGO_BIN_EXE_tetris")` because their
    /// own `current_exe` is the test binary.
    pub program: Option<PathBuf>,
    /// Shard process count.
    pub shards: usize,
    /// Model-set spec forwarded verbatim to every shard (same spec +
    /// same seed ⇒ identical weights on every shard — what makes
    /// routed logits bit-exact against a single engine).
    pub models: String,
    /// Worker threads per shard engine.
    pub workers: usize,
    pub seed: u64,
    pub max_batch: usize,
    /// Crash-loop breaker: more than `max_restarts` crashes of one
    /// shard inside `restart_window` stops restarting it.
    pub max_restarts: usize,
    pub restart_window: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        Self {
            program: None,
            shards: crate::engine::env::shards(),
            models: "tiny".into(),
            workers: 1,
            seed: 0x7e7215,
            max_batch: 8,
            max_restarts: 3,
            restart_window: Duration::from_secs(30),
        }
    }
}

/// Sliding-window crash counter. `record_crash` returns `false` once
/// the window holds more than the allowed number of crashes — the
/// breaker has tripped and the shard stays down.
#[derive(Debug)]
pub struct CrashLoopBreaker {
    max_restarts: usize,
    window: Duration,
    crashes: VecDeque<Instant>,
}

impl CrashLoopBreaker {
    pub fn new(max_restarts: usize, window: Duration) -> Self {
        Self { max_restarts, window, crashes: VecDeque::new() }
    }

    /// Record a crash at `now`; `true` = restart, `false` = tripped.
    pub fn record_crash(&mut self, now: Instant) -> bool {
        self.crashes.push_back(now);
        while let Some(&front) = self.crashes.front() {
            if now.duration_since(front) > self.window {
                self.crashes.pop_front();
            } else {
                break;
            }
        }
        self.crashes.len() <= self.max_restarts
    }
}

/// The current child process of one slot (also holds its stdin: drop
/// it and a `--supervised` shard exits).
struct ChildProc {
    child: Child,
    stdin: Option<ChildStdin>,
}

/// One shard slot's state, shared between the supervisor handle and
/// the slot's monitor thread.
struct SlotShared {
    name: String,
    /// Current listen address. Restarts try to re-bind the same port;
    /// when the kernel still holds it (TIME_WAIT from the dead child's
    /// connections — `std` exposes no `SO_REUSEADDR`), the respawn
    /// falls back to a fresh port and this updates.
    addr: Mutex<SocketAddr>,
    child: Mutex<Option<ChildProc>>,
    restarts: AtomicU64,
    broken: AtomicBool,
}

/// Running supervisor: N shard children + one monitor thread each.
pub struct Supervisor {
    stop: Arc<AtomicBool>,
    slots: Vec<Arc<SlotShared>>,
    monitors: Vec<JoinHandle<()>>,
}

impl Supervisor {
    /// Spawn every shard and block until each printed its ready line.
    pub fn start(config: SupervisorConfig) -> crate::Result<Supervisor> {
        if config.shards == 0 {
            return Err(crate::Error::Config("supervisor needs at least one shard".into()));
        }
        let program = match &config.program {
            Some(p) => p.clone(),
            None => std::env::current_exe().map_err(|e| {
                crate::Error::Coordinator(format!("cannot resolve current executable: {e}"))
            })?,
        };
        let stop = Arc::new(AtomicBool::new(false));
        let mut slots = Vec::with_capacity(config.shards);
        let mut monitors = Vec::with_capacity(config.shards);
        for i in 0..config.shards {
            let name = format!("shard-{i}");
            let (proc_, reader, addr) = spawn_shard(&program, &name, None, &config)?;
            let slot = Arc::new(SlotShared {
                name: name.clone(),
                addr: Mutex::new(addr),
                child: Mutex::new(Some(proc_)),
                restarts: AtomicU64::new(0),
                broken: AtomicBool::new(false),
            });
            slots.push(Arc::clone(&slot));
            let stop = Arc::clone(&stop);
            let program = program.clone();
            let config = config.clone();
            monitors.push(std::thread::spawn(move || {
                monitor_slot(&slot, reader, &program, &config, &stop);
            }));
        }
        Ok(Supervisor { stop, slots, monitors })
    }

    /// Every slot's current listen address, slot order. Stable across
    /// restarts except when the old port was still held by the kernel.
    pub fn addrs(&self) -> Vec<SocketAddr> {
        self.slots.iter().map(|s| *s.addr.lock().unwrap()).collect()
    }

    /// Kill one shard's current child (the drill). The monitor sees
    /// the exit and restarts it on the same port unless the breaker
    /// trips. Returns `false` when the slot has no live child.
    pub fn kill_shard(&self, slot: usize) -> bool {
        let Some(slot) = self.slots.get(slot) else {
            return false;
        };
        let mut guard = slot.child.lock().unwrap();
        match guard.as_mut() {
            Some(p) => {
                let _ = p.child.kill();
                true
            }
            None => false,
        }
    }

    /// How many times a slot has been restarted.
    pub fn restarts(&self, slot: usize) -> u64 {
        self.slots.get(slot).map_or(0, |s| s.restarts.load(Ordering::SeqCst))
    }

    /// Whether a slot's crash-loop breaker tripped.
    pub fn is_broken(&self, slot: usize) -> bool {
        self.slots.get(slot).is_some_and(|s| s.broken.load(Ordering::SeqCst))
    }

    /// Stop every shard: close its stdin (graceful `--supervised`
    /// exit), escalate to kill after a grace period, join monitors.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let taken: Vec<Option<ChildProc>> =
            self.slots.iter().map(|s| s.child.lock().unwrap().take()).collect();
        for proc_ in taken.into_iter().flatten() {
            reap(proc_, Duration::from_secs(5));
        }
        for m in self.monitors.drain(..) {
            let _ = m.join();
        }
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let taken: Vec<Option<ChildProc>> =
            self.slots.iter().map(|s| s.child.lock().unwrap().take()).collect();
        for proc_ in taken.into_iter().flatten() {
            reap(proc_, Duration::from_secs(5));
        }
        for m in self.monitors.drain(..) {
            let _ = m.join();
        }
    }
}

/// Close stdin, give the child a grace period, then kill.
fn reap(mut proc_: ChildProc, grace: Duration) {
    drop(proc_.stdin.take()); // --supervised children exit on stdin EOF
    let deadline = Instant::now() + grace;
    loop {
        match proc_.child.try_wait() {
            Ok(Some(_)) => return,
            Ok(None) => {
                if Instant::now() >= deadline {
                    let _ = proc_.child.kill();
                    let _ = proc_.child.wait();
                    return;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => return,
        }
    }
}

/// Spawn one shard child and block until its ready line. `port: None`
/// binds port 0 (kernel-assigned); `Some(p)` re-binds a known port.
fn spawn_shard(
    program: &Path,
    name: &str,
    port: Option<u16>,
    config: &SupervisorConfig,
) -> crate::Result<(ChildProc, BufReader<ChildStdout>, SocketAddr)> {
    let listen = format!("127.0.0.1:{}", port.unwrap_or(0));
    let mut child = Command::new(program)
        .args([
            "shard",
            "--listen",
            &listen,
            "--name",
            name,
            "--models",
            &config.models,
            "--workers",
            &config.workers.to_string(),
            "--seed",
            &format!("{:#x}", config.seed),
            "--max-batch",
            &config.max_batch.to_string(),
            "--supervised",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(|e| {
            crate::Error::Coordinator(format!(
                "spawning `{}` for {name} failed: {e}",
                program.display()
            ))
        })?;
    let stdin = child.stdin.take();
    let stdout = child.stdout.take().ok_or_else(|| {
        crate::Error::Coordinator(format!("{name}: child stdout was not captured"))
    })?;
    let mut reader = BufReader::new(stdout);
    // The readiness handshake: forward lines until the ready
    // announcement. EOF first means the child died during startup.
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).map_err(|e| {
            crate::Error::Coordinator(format!("{name}: reading child stdout failed: {e}"))
        })?;
        if n == 0 {
            let status = child.wait().ok();
            return Err(crate::Error::Coordinator(format!(
                "{name} exited before reporting readiness (status {status:?})"
            )));
        }
        let trimmed = line.trim();
        if let Some(addr) = trimmed.strip_prefix(READY_PREFIX) {
            let addr: SocketAddr = addr.parse().map_err(|e| {
                crate::Error::Coordinator(format!("{name}: bad ready line {trimmed:?}: {e}"))
            })?;
            return Ok((ChildProc { child, stdin }, reader, addr));
        }
        println!("{name}| {trimmed}");
    }
}

/// One slot's monitor loop: forward the child's stdout, reap it on
/// exit, restart on the same port until asked to stop or the breaker
/// trips.
fn monitor_slot(
    slot: &Arc<SlotShared>,
    mut reader: BufReader<ChildStdout>,
    program: &Path,
    config: &SupervisorConfig,
    stop: &Arc<AtomicBool>,
) {
    let mut breaker = CrashLoopBreaker::new(config.max_restarts, config.restart_window);
    loop {
        // Forward output until EOF (child exited or was killed).
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => println!("{}| {}", slot.name, line.trim_end()),
            }
        }
        // Reap whatever child the slot still holds (shutdown may have
        // taken it already).
        let status = {
            let mut guard = slot.child.lock().unwrap();
            guard.take().map(|mut p| p.child.wait())
        };
        if stop.load(Ordering::SeqCst) {
            break;
        }
        eprintln!(
            "supervisor: {} exited unexpectedly ({:?})",
            slot.name,
            status.map(|s| s.map(|st| st.to_string()))
        );
        if !breaker.record_crash(Instant::now()) {
            eprintln!(
                "supervisor: {} crash-loop breaker tripped ({} crashes in {:?}); not restarting",
                slot.name, config.max_restarts + 1, config.restart_window
            );
            slot.broken.store(true, Ordering::SeqCst);
            break;
        }
        // Give the kernel a beat to release the port, then respawn —
        // same port when possible, a fresh one when the kernel still
        // holds it (dead child's TIME_WAIT connections).
        std::thread::sleep(Duration::from_millis(50));
        let port = slot.addr.lock().unwrap().port();
        let respawn = spawn_shard(program, &slot.name, Some(port), config).or_else(|e| {
            eprintln!(
                "supervisor: re-binding {} on port {port} failed ({e}); taking a fresh port",
                slot.name
            );
            spawn_shard(program, &slot.name, None, config)
        });
        match respawn {
            Ok((proc_, new_reader, addr)) => {
                *slot.addr.lock().unwrap() = addr;
                *slot.child.lock().unwrap() = Some(proc_);
                slot.restarts.fetch_add(1, Ordering::SeqCst);
                eprintln!("supervisor: {} restarted on {addr}", slot.name);
                reader = new_reader;
            }
            Err(e) => {
                eprintln!("supervisor: restarting {} failed: {e}", slot.name);
                if !breaker.record_crash(Instant::now()) {
                    slot.broken.store(true, Ordering::SeqCst);
                    break;
                }
                // Leave an empty reader so the next loop iteration
                // falls straight through to another restart attempt.
                continue;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breaker_allows_spaced_crashes_and_trips_on_bursts() {
        let t0 = Instant::now();
        let mut b = CrashLoopBreaker::new(3, Duration::from_secs(10));
        // Three crashes inside the window: still restarting.
        assert!(b.record_crash(t0));
        assert!(b.record_crash(t0 + Duration::from_secs(1)));
        assert!(b.record_crash(t0 + Duration::from_secs(2)));
        // Fourth inside the window trips it.
        assert!(!b.record_crash(t0 + Duration::from_secs(3)));

        // Crashes spaced wider than the window never accumulate.
        let mut s = CrashLoopBreaker::new(1, Duration::from_secs(5));
        assert!(s.record_crash(t0));
        assert!(s.record_crash(t0 + Duration::from_secs(6)));
        assert!(s.record_crash(t0 + Duration::from_secs(12)));
        // ...but two in quick succession do.
        assert!(!s.record_crash(t0 + Duration::from_secs(12) + Duration::from_millis(1)));
    }
}
