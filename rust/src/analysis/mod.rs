//! Bit-level statistics analysis — the measurements behind Table 1 and
//! Figure 2.

use crate::config::Mode;
use crate::model::weights::{profile_with, DensityCalibration};
use crate::model::zoo;
use crate::quant::stats::BitStats;
use crate::quant::QWeight;
use crate::util::pool::par_map;
use crate::util::rng::Rng;

/// Table 1 row: measured zero-value and zero-bit fractions per network.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub network: String,
    pub zero_weights_pct: f64,
    pub zero_bits_pct: f64,
}

/// Weights sampled per network for the table (enough that sampling noise
/// is below the displayed precision).
pub const TABLE1_SAMPLE: usize = 400_000;

/// Measure Table 1 over the calibrated generator (or any weight slice).
pub fn table1(seed: u64) -> crate::Result<Vec<Table1Row>> {
    let nets = zoo::all();
    let rows = par_map(&nets, |i, net| {
        let profile = profile_with(&net.name, Mode::Fp16, DensityCalibration::Table1)
            .expect("zoo networks always have profiles");
        let mut rng = Rng::new(seed ^ (i as u64) << 17);
        let ws = profile.generate(TABLE1_SAMPLE, &mut rng);
        let mut s = BitStats::new(Mode::Fp16);
        s.add_all(&ws);
        Table1Row {
            network: net.name.clone(),
            zero_weights_pct: s.zero_weight_fraction() * 100.0,
            zero_bits_pct: s.zero_bit_fraction() * 100.0,
        }
    });
    Ok(rows)
}

/// Measure Table 1 from explicit weights (real trained weights path).
pub fn table1_from_weights(name: &str, ws: &[QWeight], mode: Mode) -> Table1Row {
    let mut s = BitStats::new(mode);
    s.add_all(ws);
    Table1Row {
        network: name.to_string(),
        zero_weights_pct: s.zero_weight_fraction() * 100.0,
        zero_bits_pct: s.zero_bit_fraction() * 100.0,
    }
}

/// Geometric mean of Table 1 rows (the paper's GeoMean row).
pub fn table1_geomean(rows: &[Table1Row]) -> Table1Row {
    let n = rows.len() as f64;
    let gm = |f: &dyn Fn(&Table1Row) -> f64| {
        (rows.iter().map(|r| f(r).max(1e-12).ln()).sum::<f64>() / n).exp()
    };
    Table1Row {
        network: "geomean".into(),
        zero_weights_pct: gm(&|r| r.zero_weights_pct),
        zero_bits_pct: gm(&|r| r.zero_bits_pct),
    }
}

/// Figure 2: per-bit essential densities for the four models the paper
/// plots (AlexNet, GoogleNet, VGG-16, NiN), 500 kernels each.
#[derive(Debug, Clone)]
pub struct Fig2Series {
    pub network: String,
    /// Essential-bit density at positions 0..16.
    pub density: Vec<f64>,
}

/// Kernels sampled per network ("500 kernels", §II.B) and the kernel
/// size used for sampling.
pub const FIG2_KERNELS: usize = 500;
pub const FIG2_KERNEL_WEIGHTS: usize = 3 * 3 * 64; // 3×3 kernels, 64 ch

/// Measure Figure 2 under a chosen calibration.
pub fn fig2(seed: u64, calib: DensityCalibration) -> crate::Result<Vec<Fig2Series>> {
    let names = ["alexnet", "googlenet", "vgg16", "nin"];
    let series = par_map(&names, |i, name| {
        let profile = profile_with(name, Mode::Fp16, calib).expect("profiled network");
        let mut rng = Rng::new(seed ^ (0xF16 + i as u64) << 13);
        let mut s = BitStats::new(Mode::Fp16);
        for _ in 0..FIG2_KERNELS {
            let ws = profile.generate(FIG2_KERNEL_WEIGHTS, &mut rng);
            s.add_all(&ws);
        }
        Fig2Series { network: name.to_string(), density: s.essential_density_per_bit() }
    });
    Ok(series)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_anchors() {
        let rows = table1(42).unwrap();
        assert_eq!(rows.len(), 5);
        for row in &rows {
            let (_, zw, zb) = crate::model::weights::TABLE1_ANCHORS
                .iter()
                .find(|(n, _, _)| *n == row.network)
                .unwrap();
            assert!(
                (row.zero_weights_pct - zw * 100.0).abs() < 0.15,
                "{}: zero weights {} vs {}",
                row.network,
                row.zero_weights_pct,
                zw * 100.0
            );
            assert!(
                (row.zero_bits_pct - zb * 100.0).abs() < 2.0,
                "{}: zero bits {} vs {}",
                row.network,
                row.zero_bits_pct,
                zb * 100.0
            );
        }
    }

    #[test]
    fn geomean_near_paper() {
        let rows = table1(7).unwrap();
        let gm = table1_geomean(&rows);
        // Paper GeoMean: 0.135% zero weights, 68.88% zero bits.
        assert!((gm.zero_bits_pct - 68.88).abs() < 2.0, "{}", gm.zero_bits_pct);
        assert!((gm.zero_weights_pct - 0.135).abs() < 0.08, "{}", gm.zero_weights_pct);
    }

    #[test]
    fn fig2_has_cliff_and_plateau() {
        for calib in [DensityCalibration::Table1, DensityCalibration::Fig2] {
            let series = fig2(3, calib).unwrap();
            assert_eq!(series.len(), 4);
            for s in &series {
                assert_eq!(s.density.len(), 16);
                // Observation (2): bits 3–5 are a cliff (<1% essential).
                for b in [3, 4, 5] {
                    assert!(s.density[b] < 0.01, "{} bit {b}: {}", s.network, s.density[b]);
                }
                // Observation (1): other bits form a plateau, no outlier
                // position dominating. Bit 15 is the sign-magnitude MSB
                // slot (always 0) — excluded like the cliff.
                let plateau: Vec<f64> = (0..15)
                    .filter(|b| ![3, 4, 5].contains(b))
                    .map(|b| s.density[b])
                    .collect();
                let max = plateau.iter().cloned().fold(0.0, f64::max);
                let min = plateau.iter().cloned().fold(1.0, f64::min);
                assert!(max < 0.98 && min > 0.1, "{}: plateau [{min}, {max}]", s.network);
                assert!(s.density[15] < 1e-9, "{}: MSB slot must be empty", s.network);
            }
        }
    }

    #[test]
    fn table1_from_real_weights() {
        let row = table1_from_weights("test", &[0, 1, 3, 0x7FFF], Mode::Fp16);
        assert_eq!(row.zero_weights_pct, 25.0);
        // essential bits: 0 + 1 + 2 + 15 = 18 of 64 → zero 71.875%.
        assert!((row.zero_bits_pct - 71.875).abs() < 1e-9);
    }
}
