//! DaDianNao baseline timing model (Chen et al., MICRO'14) — the
//! de-facto reference design the paper normalizes against (§IV).
//!
//! Each PE holds 16 multiplier lanes; the chip retires
//! `pes × splitters_per_pe` MAC pairs per cycle regardless of operand
//! values — every zero value and zero bit costs a full cycle slot, which
//! is exactly the ineffectual computation Tetris attacks.

use super::edram::{memory_cycles, Traffic};
use super::{Accelerator, ChipActivity, LayerSample, LayerSim};
use crate::config::{AccelConfig, CalibConfig};
use crate::model::ConvLayer;

/// DaDianNao timing model.
pub struct DadnSim;

impl Accelerator for DadnSim {
    fn name(&self) -> &'static str {
        "dadn"
    }

    fn simulate_layer(
        &self,
        layer: &ConvLayer,
        _sample: &LayerSample,
        cfg: &AccelConfig,
        calib: &CalibConfig,
    ) -> LayerSim {
        let macs = layer.macs();
        let throughput = cfg.mac_throughput() as u64; // pairs / cycle
        let compute = macs.div_ceil(throughput) * calib.timing.dadn_mac_cycles;

        // Memory: weights + input feature map enter once per layer (the
        // PE SRAMs capture reuse); DaDN is compute-bound on every conv
        // layer of the zoo at the paper's bandwidth.
        let traffic = Traffic {
            weight_words: layer.weight_count() as f64,
            act_words: (layer.in_c * layer.in_hw * layer.in_hw) as f64,
        };
        let memory = memory_cycles(&traffic, cfg);
        let cycles = compute.max(memory) + calib.timing.pipeline_fill;

        let macs_f = macs as f64;
        let activity = ChipActivity {
            mults: macs_f,
            adds: macs_f,
            // Weight + activation operand reads per MAC from PE SRAM.
            sram_reads: 2.0 * macs_f,
            edram_reads: traffic.total(),
            reg_writes: macs_f, // pipeline register per MAC
            ..ChipActivity::default()
        };
        LayerSim {
            layer: layer.name.clone(),
            cycles,
            macs,
            activity,
            memory_bound: memory > compute,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::model::zoo;
    use crate::sim::sample::sample_network;

    #[test]
    fn cycles_track_macs_over_throughput() {
        let net = zoo::vgg16();
        let cfg = AccelConfig::default();
        let calib = CalibConfig::default();
        let samples = sample_network(&net, Mode::Fp16, 1).unwrap();
        let l = &net.layers[2]; // conv2_1
        let sim = DadnSim.simulate_layer(l, &samples[2], &cfg, &calib);
        let expect = l.macs().div_ceil(256) + calib.timing.pipeline_fill;
        assert_eq!(sim.cycles, expect);
        assert!(!sim.memory_bound);
    }

    #[test]
    fn dadn_insensitive_to_weight_values() {
        // DaDN must cost the same whether weights are dense or sparse —
        // that's the point of the baseline.
        let net = zoo::alexnet();
        let cfg = AccelConfig::default();
        let calib = CalibConfig::default();
        let s1 = sample_network(&net, Mode::Fp16, 1).unwrap();
        let s2 = sample_network(&net, Mode::Fp16, 2).unwrap();
        let l = &net.layers[1];
        let a = DadnSim.simulate_layer(l, &s1[1], &cfg, &calib);
        let b = DadnSim.simulate_layer(l, &s2[1], &cfg, &calib);
        assert_eq!(a.cycles, b.cycles);
    }
}
