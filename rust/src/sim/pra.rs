//! PRA (bit-pragmatic) baseline timing model (Albericio et al.,
//! MICRO'17), as enrolled by the paper: the fp16 design applied to
//! weight essential bits (§IV).
//!
//! PRA serializes over *essential bits only*: a lane-group of
//! `pra_sync_group` weights advances once every weight in the group has
//! streamed all of its essential bits — the group costs
//! `max_i popcount(w_i)` cycles (the synchronization the paper calls
//! "traverse the entire weight to probe essential bits"). The
//! bit-serial frontend needs 16× wider weight buffering to keep the
//! units fed ("large buffers must be introduced", §IV.D); the sustained
//! fraction of peak is `pra_frontend_derate`.

use super::edram::{memory_cycles, Traffic};
use super::{Accelerator, ChipActivity, LayerSample, LayerSim};
use crate::config::{AccelConfig, CalibConfig};
use crate::model::ConvLayer;
use crate::quant::essential_bits;

/// PRA timing model.
pub struct PraSim;

/// Mean serial cycles per sync group measured on the sampled lanes.
pub fn measure_serial(sample: &LayerSample, sync_group: usize) -> SerialMeasure {
    let bits = sample.mode.weight_bits() as u32;
    let mut group_cycles = 0u64;
    let mut groups = 0u64;
    let mut essential = 0u64;
    for lane in &sample.filter_lanes {
        for chunk in lane.chunks(sync_group) {
            let max_pop = chunk
                .iter()
                .map(|&w| essential_bits(w, bits))
                .max()
                .unwrap_or(0)
                .max(1); // a group never advances in zero cycles
            group_cycles += max_pop as u64;
            groups += 1;
            essential += chunk.iter().map(|&w| essential_bits(w, bits) as u64).sum::<u64>();
        }
    }
    let lanes = sample.filter_lanes.len().max(1) as f64;
    SerialMeasure {
        mean_serial_per_lane: group_cycles as f64 / lanes,
        mean_essential_per_lane: essential as f64 / lanes,
        mean_group_cycles: group_cycles as f64 / groups.max(1) as f64,
    }
}

/// Serial-schedule measurement.
#[derive(Debug, Clone, Copy)]
pub struct SerialMeasure {
    /// Σ over groups of max-popcount, per filter lane.
    pub mean_serial_per_lane: f64,
    pub mean_essential_per_lane: f64,
    pub mean_group_cycles: f64,
}

impl Accelerator for PraSim {
    fn name(&self) -> &'static str {
        "pra"
    }

    fn simulate_layer(
        &self,
        layer: &ConvLayer,
        sample: &LayerSample,
        cfg: &AccelConfig,
        calib: &CalibConfig,
    ) -> LayerSim {
        let sync = calib.timing.pra_sync_group;
        let m = measure_serial(sample, sync);
        let out_pix = (layer.out_hw() * layer.out_hw()) as f64;
        let filters = layer.out_c as f64;

        // Each PE runs `splitters_per_pe` lane-groups concurrently; a
        // group retires `sync` pairs in `max popcount` cycles.
        let lane_groups = (cfg.pes * cfg.splitters_per_pe) as f64;
        let serial_total = m.mean_serial_per_lane * filters * out_pix;
        let compute =
            (serial_total / (lane_groups * calib.timing.pra_frontend_derate)).ceil() as u64;

        // Memory: weights stream bit-serially from 16×-deep FIFOs.
        let traffic = Traffic {
            weight_words: layer.weight_count() as f64,
            act_words: (layer.in_c * layer.in_hw * layer.in_hw) as f64,
        };
        let memory = memory_cycles(&traffic, cfg);
        let cycles = compute.max(memory) + calib.timing.pipeline_fill;

        let lanes = filters * out_pix;
        let essential_total = m.mean_essential_per_lane * lanes;
        let activity = ChipActivity {
            adds: essential_total,
            shifts: essential_total, // one multi-stage shift per essential bit
            sram_reads: layer.macs() as f64,
            edram_reads: traffic.total(),
            // The compensating 16× weight buffers: the serial frontend
            // keeps `sync`-deep FIFO slices in flight per pair — the
            // dominant power term the paper blames for PRA's 3.37×
            // draw ("large buffers must be introduced", §IV.D).
            fifo_ops: layer.macs() as f64 * sync as f64,
            reg_writes: essential_total,
            ..ChipActivity::default()
        };
        LayerSim {
            layer: layer.name.clone(),
            cycles,
            macs: layer.macs(),
            activity,
            memory_bound: memory > compute,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::model::zoo;
    use crate::sim::dadn::DadnSim;
    use crate::sim::sample::sample_network;

    #[test]
    fn pra_between_dadn_and_ideal() {
        let net = zoo::vgg16();
        let cfg = AccelConfig::default();
        let calib = CalibConfig::default();
        let samples = sample_network(&net, Mode::Fp16, 11).unwrap();
        let mut pra_total = 0u64;
        let mut dadn_total = 0u64;
        for (i, l) in net.layers.iter().enumerate() {
            pra_total += PraSim.simulate_layer(l, &samples[i], &cfg, &calib).cycles;
            dadn_total += DadnSim.simulate_layer(l, &samples[i], &cfg, &calib).cycles;
        }
        let speedup = dadn_total as f64 / pra_total as f64;
        // Paper zone: ~1.15×. Allow a generous band; the report bench
        // checks the exact value.
        assert!((1.02..1.6).contains(&speedup), "PRA speedup {speedup}");
    }

    #[test]
    fn serial_measure_max_popcount_bound() {
        let net = zoo::alexnet();
        let samples = sample_network(&net, Mode::Fp16, 13).unwrap();
        let m = measure_serial(&samples[0], 16);
        // Group cycles are between 1 and the full bit width.
        assert!(m.mean_group_cycles >= 1.0 && m.mean_group_cycles <= 16.0);
        // Serial cycles ≥ essential/16 (can't beat perfect bit packing).
        assert!(m.mean_serial_per_lane >= m.mean_essential_per_lane / 16.0);
    }

    #[test]
    fn dense_weights_serialize_to_full_width() {
        use crate::sim::LayerSample;
        let sample = LayerSample {
            filter_lanes: vec![vec![0x7FFF; 32]],
            total_filters: 1,
            mode: Mode::Fp16,
        };
        let m = measure_serial(&sample, 16);
        // All 15 low bits set → every group costs 15 cycles.
        assert_eq!(m.mean_group_cycles, 15.0);
    }
}
