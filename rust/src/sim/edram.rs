//! eDRAM / on-chip memory bandwidth model.
//!
//! All three designs stream weights from on-chip eDRAM into per-PE
//! buffers (DaDN's NBin/SB, Tetris' throttle buffer, PRA's weight
//! FIFOs). The timing models race compute cycles against the cycles the
//! memory system needs to deliver the layer's weight + activation
//! traffic — a roofline: `cycles = max(compute, memory) + fixed`.

use crate::config::AccelConfig;

/// Traffic demand of one layer, in 16-bit words.
#[derive(Debug, Clone, Copy, Default)]
pub struct Traffic {
    /// Weight-stream words (kneaded streams are wider; see
    /// `KneadedWeight::storage_bits`).
    pub weight_words: f64,
    /// Activation words.
    pub act_words: f64,
}

impl Traffic {
    pub fn total(&self) -> f64 {
        self.weight_words + self.act_words
    }
}

/// Cycles the eDRAM needs to deliver `traffic` to `pes` PEs.
pub fn memory_cycles(traffic: &Traffic, cfg: &AccelConfig) -> u64 {
    // Aggregate bandwidth: words/cycle/PE × PEs.
    let bw = (cfg.edram_words_per_cycle * cfg.pes) as f64;
    let cycles = traffic.total() / bw;
    cycles.ceil() as u64 + cfg.edram_latency as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_cycles_scale_with_traffic() {
        let cfg = AccelConfig::default(); // 32 w/c × 16 PEs = 512 words/cycle
        let t1 = Traffic { weight_words: 512.0 * 100.0, act_words: 0.0 };
        let t2 = Traffic { weight_words: 512.0 * 200.0, act_words: 0.0 };
        let c1 = memory_cycles(&t1, &cfg);
        let c2 = memory_cycles(&t2, &cfg);
        assert_eq!(c1, 100 + cfg.edram_latency as u64);
        assert_eq!(c2 - c1, 100);
    }

    #[test]
    fn latency_charged_once() {
        let cfg = AccelConfig::default();
        let t = Traffic { weight_words: 1.0, act_words: 0.0 };
        assert_eq!(memory_cycles(&t, &cfg), 1 + cfg.edram_latency as u64);
    }
}
