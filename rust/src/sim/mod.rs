//! Cycle-level timing models of the three accelerators the paper
//! evaluates: Tetris (ours), DaDianNao (`dadn`, the de-facto baseline)
//! and PRA (`pra`, bit-pragmatic).
//!
//! ## Modeling approach (see DESIGN.md §2)
//!
//! The paper's cycle counts come from Vivado HLS RTL simulation. Our
//! substitute is a *sampled, lane-exact* model:
//!
//! * For every conv layer we materialize a sample of per-filter weight
//!   lanes (`in_c·k·k` weights each) from the calibrated bit-profile
//!   generator — or from real trained weights for the tiny CNN.
//! * Each accelerator model computes the **exact** cycle cost of the
//!   sampled lanes (kneaded lengths for Tetris, essential-bit serial
//!   schedules for PRA, pair counts for DaDN), then scales to the
//!   layer's full filter count and output extent. Because convolution
//!   reuses one filter's weights at every output pixel, the per-filter
//!   cost is exact and only the filter sampling introduces (measured,
//!   small) variance.
//! * Compute cycles race memory cycles roofline-style against the
//!   eDRAM bandwidth model (`edram`), and fixed pipeline overheads are
//!   charged per layer.

pub mod activation;
pub mod dadn;
pub mod edram;
pub mod pra;
pub mod sample;
pub mod tetris;
pub mod throttle;

use crate::config::{AccelConfig, CalibConfig};
use crate::model::{ConvLayer, Network};

pub use sample::LayerSample;

/// Per-component operation counts for one layer (inputs to the energy
/// model). All counts are for the whole layer (one input image).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ChipActivity {
    /// 16-bit multiplies (DaDN only).
    pub mults: f64,
    /// Segment / MAC adder operations.
    pub adds: f64,
    /// Splitter slot decodes (Tetris only).
    pub splitter_decodes: f64,
    /// Rear-adder-tree drains (Tetris) / final reductions.
    pub tree_drains: f64,
    /// Barrel-shifter operations (PRA only).
    pub shifts: f64,
    /// SRAM word reads (weights + activations).
    pub sram_reads: f64,
    /// eDRAM word reads.
    pub edram_reads: f64,
    /// FIFO/throttle-buffer accesses.
    pub fifo_ops: f64,
    /// Register writes (segment registers, pipeline regs).
    pub reg_writes: f64,
}

impl ChipActivity {
    pub fn add(&mut self, o: &ChipActivity) {
        self.mults += o.mults;
        self.adds += o.adds;
        self.splitter_decodes += o.splitter_decodes;
        self.tree_drains += o.tree_drains;
        self.shifts += o.shifts;
        self.sram_reads += o.sram_reads;
        self.edram_reads += o.edram_reads;
        self.fifo_ops += o.fifo_ops;
        self.reg_writes += o.reg_writes;
    }
}

/// Result of simulating one layer.
#[derive(Debug, Clone)]
pub struct LayerSim {
    pub layer: String,
    pub cycles: u64,
    pub macs: u64,
    pub activity: ChipActivity,
    /// Compute-bound vs memory-bound (diagnostics / ablation benches).
    pub memory_bound: bool,
}

/// Result of simulating a whole network.
#[derive(Debug, Clone)]
pub struct NetworkSim {
    pub network: String,
    pub accel: String,
    pub per_layer: Vec<LayerSim>,
    pub config: AccelConfig,
}

impl NetworkSim {
    pub fn total_cycles(&self) -> u64 {
        self.per_layer.iter().map(|l| l.cycles).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.per_layer.iter().map(|l| l.macs).sum()
    }

    /// Wall-clock inference time at the configured frequency.
    pub fn time_s(&self) -> f64 {
        self.total_cycles() as f64 * self.config.cycle_time_s()
    }

    pub fn total_activity(&self) -> ChipActivity {
        let mut a = ChipActivity::default();
        for l in &self.per_layer {
            a.add(&l.activity);
        }
        a
    }
}

/// An accelerator timing model.
pub trait Accelerator: Sync {
    fn name(&self) -> &'static str;

    /// Cycle + activity model for one layer given its sampled lanes.
    fn simulate_layer(
        &self,
        layer: &ConvLayer,
        sample: &LayerSample,
        cfg: &AccelConfig,
        calib: &CalibConfig,
    ) -> LayerSim;
}

/// Simulate every layer of a network (parallel over layers).
///
/// `seed` drives the per-layer weight sampling; the same seed gives the
/// same sampled lanes to every accelerator, so comparisons are paired.
pub fn simulate_network(
    accel: &dyn Accelerator,
    net: &Network,
    cfg: &AccelConfig,
    calib: &CalibConfig,
    seed: u64,
) -> crate::Result<NetworkSim> {
    let samples = sample::sample_network(net, cfg.mode, seed)?;
    let per_layer = crate::util::pool::par_map(&net.layers, |i, layer| {
        accel.simulate_layer(layer, &samples[i], cfg, calib)
    });
    Ok(NetworkSim {
        network: net.name.clone(),
        accel: accel.name().to_string(),
        per_layer,
        config: cfg.clone(),
    })
}

/// Simulate with externally supplied samples (real weights path).
pub fn simulate_network_with_samples(
    accel: &dyn Accelerator,
    net: &Network,
    samples: &[LayerSample],
    cfg: &AccelConfig,
    calib: &CalibConfig,
) -> NetworkSim {
    assert_eq!(samples.len(), net.layers.len());
    let per_layer = crate::util::pool::par_map(&net.layers, |i, layer| {
        accel.simulate_layer(layer, &samples[i], cfg, calib)
    });
    NetworkSim {
        network: net.name.clone(),
        accel: accel.name().to_string(),
        per_layer,
        config: cfg.clone(),
    }
}

/// Look up an accelerator model by CLI name.
pub fn accel_by_name(name: &str) -> crate::Result<Box<dyn Accelerator>> {
    match name {
        "tetris" => Ok(Box::new(tetris::TetrisSim)),
        "dadn" => Ok(Box::new(dadn::DadnSim)),
        "pra" => Ok(Box::new(pra::PraSim)),
        other => Err(crate::Error::Config(format!(
            "unknown accelerator `{other}` (want tetris|dadn|pra)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::model::zoo;

    #[test]
    fn paired_simulation_speedup_ordering() {
        // The paper's headline ordering (Fig 8): DaDN ≤ PRA ≤ Tetris-fp16
        // ≤ Tetris-int8 in speed (≥ in cycles).
        let net = zoo::alexnet();
        let calib = CalibConfig::default();
        let fp16 = AccelConfig::default();
        let int8 = AccelConfig { mode: Mode::Int8, ..AccelConfig::default() };
        let dadn = simulate_network(&dadn::DadnSim, &net, &fp16, &calib, 1).unwrap();
        let pra = simulate_network(&pra::PraSim, &net, &fp16, &calib, 1).unwrap();
        let tet = simulate_network(&tetris::TetrisSim, &net, &fp16, &calib, 1).unwrap();
        let tet8 = simulate_network(&tetris::TetrisSim, &net, &int8, &calib, 1).unwrap();
        assert!(tet.total_cycles() < pra.total_cycles(), "tetris must beat PRA");
        assert!(pra.total_cycles() < dadn.total_cycles(), "PRA must beat DaDN");
        assert!(tet8.total_cycles() < tet.total_cycles(), "int8 must beat fp16");
    }

    #[test]
    fn accel_by_name_roundtrip() {
        for n in ["tetris", "dadn", "pra"] {
            assert_eq!(accel_by_name(n).unwrap().name(), n);
        }
        assert!(accel_by_name("eyeriss").is_err());
    }
}
