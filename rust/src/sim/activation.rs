//! Activation-aware timing: measured post-ReLU sparsity feeding the
//! Tetris cycle model.
//!
//! The related work (Cnvlutin2, Laconic) shows that the bigger prize
//! beyond static weight sparsity is *dynamic* activation sparsity —
//! post-ReLU feature maps are mostly zeros, and an accelerator that
//! skips ineffectual activation operands (or processes only their
//! essential bits) wins cycles the weight-kneading side cannot see.
//! Our plan executor observes the real activation streams of every
//! walk, so this module closes the loop:
//!
//! 1. [`measure_activation_profile`] runs one traced image through a
//!    channel-scaled copy of the network with the executor's
//!    zero-activation skip lane armed
//!    (`ExecOpts::skip_zero_activations`) and reads the measured
//!    distribution out of `AllocStats` — the fraction of activation
//!    values that are exactly zero, the fraction of conv windows whose
//!    operands were *all* zero (what the executor's window skip
//!    actually elides), and the mean essential-bit count of the
//!    surviving values (Laconic's operand cost).
//! 2. [`TetrisSkipSim`] is the Tetris timing model with that profile
//!    applied: zero operands are squashed before the splitter array
//!    (compute scales by value survival), wholly-zero windows never
//!    drain the rear tree, and zero activation words are never
//!    fetched. `profile = dense` (no zeros) reproduces [`TetrisSim`]
//!    exactly.
//!
//! `tetris simulate --activations` reports the three-way comparison —
//! dense baseline (DaDN) vs Tetris vs Tetris+skip — plus the Laconic
//! essential-bit lower bound, per zoo model.

use super::sample::LayerSample;
use super::tetris::{simulate_layer_core, TetrisSim};
use super::{Accelerator, LayerSim};
use crate::config::{AccelConfig, CalibConfig};
use crate::model::weights::{synthetic_loaded_with_heads, DensityCalibration};
use crate::model::{ConvLayer, Network, Tensor};
use crate::plan::{CompiledNetwork, ExecOpts};
use crate::util::rng::Rng;

/// Activation operand width the essential-bit accounting is measured
/// against: Q8.8 fixed-point activations occupy 16-bit operands, the
/// same width `plan::exec`'s `ACT_BITS` tallies with.
pub const ACT_OPERAND_BITS: f64 = 16.0;

/// Channel divisor for the profile-capture copy: sparsity fractions
/// are ratios, so they transfer from a thin copy to the full-width
/// model, and a ÷16 copy keeps one traced image cheap even for VGG.
const PROFILE_CHANNEL_DIV: usize = 16;

/// Input extent cap for the profile-capture copy (declared extents
/// below the cap are kept).
const PROFILE_MAX_HW: usize = 64;

/// Measured post-activation distribution of one network, captured from
/// a traced plan execution with the skip lane armed.
#[derive(Debug, Clone, Copy, Default)]
pub struct ActivationProfile {
    /// Fraction of post-activation values that are exactly zero.
    pub zero_fraction: f64,
    /// Fraction of conv windows skipped outright (every operand of
    /// every input channel zero) — what the executor's skip lane
    /// actually elides.
    pub window_skip_fraction: f64,
    /// Mean essential bits per activation value (zeros included), out
    /// of [`ACT_OPERAND_BITS`].
    pub essential_bits_mean: f64,
    /// Raw trace counters behind the fractions, for display.
    pub skipped_rows: u64,
    pub skipped_windows: u64,
    pub total_windows: u64,
}

impl ActivationProfile {
    /// A profile with no zeros at all — [`TetrisSkipSim`] under it is
    /// cycle-identical to [`TetrisSim`].
    pub fn dense() -> Self {
        Self { essential_bits_mean: ACT_OPERAND_BITS, ..Self::default() }
    }

    /// Fraction of activation values the skip machinery must still
    /// process (`1 − zero_fraction`).
    pub fn value_survival(&self) -> f64 {
        (1.0 - self.zero_fraction).clamp(0.0, 1.0)
    }

    /// Fraction of conv windows that actually execute
    /// (`1 − window_skip_fraction`).
    pub fn window_survival(&self) -> f64 {
        (1.0 - self.window_skip_fraction).clamp(0.0, 1.0)
    }

    /// Laconic-style essential-bit lower bound on a cycle count: an
    /// activation-bit-serial machine processes only the essential bits
    /// of each operand, so its best case is the dense count scaled by
    /// `essential_bits_mean / ACT_OPERAND_BITS`. An optimistic bound
    /// (it assumes perfect lane balance), printed for context next to
    /// the three-way comparison, never a gating metric.
    pub fn laconic_bound_cycles(&self, dense_cycles: u64) -> u64 {
        let f = (self.essential_bits_mean / ACT_OPERAND_BITS).clamp(0.0, 1.0);
        (dense_cycles as f64 * f).ceil() as u64
    }
}

/// The Tetris timing model with a measured [`ActivationProfile`]
/// applied — see the module docs for exactly which legs scale. Not
/// constructible via `accel_by_name` (it needs a profile); `tetris
/// simulate --activations` and the hotpath bench build it from
/// [`measure_activation_profile`].
pub struct TetrisSkipSim {
    pub profile: ActivationProfile,
}

impl Accelerator for TetrisSkipSim {
    fn name(&self) -> &'static str {
        "tetris+skip"
    }

    fn simulate_layer(
        &self,
        layer: &ConvLayer,
        sample: &LayerSample,
        cfg: &AccelConfig,
        calib: &CalibConfig,
    ) -> LayerSim {
        simulate_layer_core(layer, sample, cfg, calib, Some(&self.profile))
    }
}

/// Capture a network's post-activation distribution by executing one
/// traced image through a channel-scaled copy with the skip lane
/// armed.
///
/// The copy compiles with the same synthetic calibrated weights the
/// reports use, and the input image is signed noise so ReLU produces
/// a realistic zero population. Ratios (not absolute counts) feed the
/// timing model, so the thin copy stands in for the full-width
/// network; the raw counters are kept for display only.
pub fn measure_activation_profile(
    net: &Network,
    cfg: &AccelConfig,
    seed: u64,
) -> crate::Result<ActivationProfile> {
    let hw = net.layers[0].in_hw.min(PROFILE_MAX_HW);
    let prof_net = net.scaled(PROFILE_CHANNEL_DIV, hw);
    let weights = synthetic_loaded_with_heads(
        &prof_net,
        cfg.mode,
        12,
        &prof_net.name,
        DensityCalibration::Fig2,
        seed,
    )?;
    let plan = CompiledNetwork::compile(&prof_net, &weights, cfg.ks, cfg.mode)?;
    let mut rng = Rng::new(seed ^ 0xAC71_0000);
    let mut x = Tensor::zeros(&[1, prof_net.layers[0].in_c, hw, hw]);
    for v in x.data_mut() {
        *v = rng.range_i64(-400, 400) as i32;
    }
    let opts = ExecOpts { skip_zero_activations: Some(true), ..ExecOpts::default() };
    let (_, stats) = plan.execute_traced(&x, opts)?;
    Ok(ActivationProfile {
        zero_fraction: stats.activation_zero_fraction(),
        window_skip_fraction: stats.window_skip_fraction(),
        essential_bits_mean: stats.activation_essential_bits_mean(),
        skipped_rows: stats.skipped_rows(),
        skipped_windows: stats.skipped_windows(),
        total_windows: stats.total_windows(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::model::zoo;
    use crate::sim::sample::sample_network;
    use crate::sim::simulate_network;

    #[test]
    fn measured_profile_is_sane_and_sees_relu_zeros() {
        let net = zoo::alexnet();
        let cfg = AccelConfig::default();
        let p = measure_activation_profile(&net, &cfg, 7).unwrap();
        assert!((0.0..=1.0).contains(&p.zero_fraction), "{p:?}");
        assert!((0.0..=1.0).contains(&p.window_skip_fraction), "{p:?}");
        assert!((0.0..=ACT_OPERAND_BITS).contains(&p.essential_bits_mean), "{p:?}");
        assert!(p.total_windows > 0, "{p:?}");
        // Signed noise through ReLU must leave a real zero population.
        assert!(p.zero_fraction > 0.05, "post-ReLU zeros missing: {p:?}");
        // Zeros carry no essential bits, so the mean must sit strictly
        // below the full operand width.
        assert!(p.essential_bits_mean < ACT_OPERAND_BITS, "{p:?}");
    }

    #[test]
    fn skip_model_strictly_beats_dense_tetris_when_zeros_exist() {
        let net = zoo::alexnet();
        let cfg = AccelConfig::default();
        let calib = CalibConfig::default();
        let profile = ActivationProfile {
            zero_fraction: 0.45,
            window_skip_fraction: 0.10,
            essential_bits_mean: 4.0,
            ..ActivationProfile::default()
        };
        let dense = simulate_network(&TetrisSim, &net, &cfg, &calib, 3).unwrap();
        let skip = simulate_network(&TetrisSkipSim { profile }, &net, &cfg, &calib, 3).unwrap();
        assert!(
            skip.total_cycles() < dense.total_cycles(),
            "skip {} !< dense {}",
            skip.total_cycles(),
            dense.total_cycles()
        );
    }

    #[test]
    fn dense_profile_reproduces_tetris_exactly() {
        let net = zoo::alexnet();
        let cfg = AccelConfig::default();
        let calib = CalibConfig::default();
        let samples = sample_network(&net, Mode::Fp16, 5).unwrap();
        let skip = TetrisSkipSim { profile: ActivationProfile::dense() };
        for (i, l) in net.layers.iter().enumerate() {
            let a = TetrisSim.simulate_layer(l, &samples[i], &cfg, &calib);
            let b = skip.simulate_layer(l, &samples[i], &cfg, &calib);
            assert_eq!(a.cycles, b.cycles, "layer {}", l.name);
            assert_eq!(a.activity, b.activity, "layer {}", l.name);
        }
    }

    #[test]
    fn laconic_bound_scales_by_essential_fraction() {
        let p = ActivationProfile { essential_bits_mean: 4.0, ..ActivationProfile::default() };
        assert_eq!(p.laconic_bound_cycles(1600), 400);
        assert_eq!(ActivationProfile::dense().laconic_bound_cycles(1600), 1600);
    }
}
