//! Throttle buffer + pass-mark micro-model (§III.C.2, Fig 5).
//!
//! The throttle buffer feeds kneaded weights from eDRAM to the splitter
//! array. A *pass mark* sits after the last kneaded weight of each
//! addable lane; the *pass detector* fires when every splitter's stream
//! has reached its mark, which validates the rear adder tree for the
//! final summation. This fine-grained model backs the analytic cycle
//! counts in [`super::tetris`] (see `rust/tests/microsim.rs` for the
//! cross-validation) and exercises the asynchronous-pass-mark behaviour
//! the paper describes ("the pass marks, for most of the time, are not
//! synchronized").

use std::collections::VecDeque;

use crate::kneading::KneadedLane;

/// One entry in a splitter's stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Entry {
    /// One kneaded weight's worth of work (1 cycle on the splitter,
    /// or ½ cycle in int8 mode — handled by the consumer).
    Kneaded,
    /// End of an addable lane: drain segment registers to the tree.
    PassMark,
}

/// Per-splitter stream with refill from "eDRAM".
#[derive(Debug, Clone)]
pub struct ThrottleBuffer {
    queue: VecDeque<Entry>,
    capacity: usize,
    /// Entries still waiting in eDRAM.
    backlog: VecDeque<Entry>,
    /// Refill latency in cycles when the buffer runs dry.
    refill_latency: usize,
    stall_until: u64,
    /// Total refill stall cycles observed (diagnostics).
    pub stalls: u64,
}

impl ThrottleBuffer {
    pub fn new(capacity: usize, refill_latency: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            capacity,
            backlog: VecDeque::new(),
            refill_latency,
            stall_until: 0,
            stalls: 0,
        }
    }

    /// Enqueue a lane's kneaded stream followed by its pass mark.
    pub fn push_lane(&mut self, lane: &KneadedLane) {
        for g in &lane.groups {
            for _ in 0..g.len() {
                self.backlog.push_back(Entry::Kneaded);
            }
        }
        self.backlog.push_back(Entry::PassMark);
    }

    /// Number of buffered + pending entries.
    pub fn pending(&self) -> usize {
        self.queue.len() + self.backlog.len()
    }

    /// Advance refill by one cycle: move entries from backlog while
    /// capacity allows (bandwidth: `words` entries per cycle). Entries
    /// delivered now become poppable `refill_latency` cycles later when
    /// the buffer had run dry (the eDRAM access latency).
    pub fn refill(&mut self, now: u64, words: usize) {
        for _ in 0..words {
            if self.queue.len() >= self.capacity {
                break;
            }
            match self.backlog.pop_front() {
                Some(e) => {
                    if self.queue.is_empty() && self.stall_until <= now {
                        // Dry buffer: this delivery pays the access latency.
                        self.stall_until = now + self.refill_latency as u64;
                    }
                    self.queue.push_back(e);
                }
                None => break,
            }
        }
    }

    /// Pop the next entry if available and its delivery latency has
    /// elapsed; records a stall otherwise.
    pub fn pop(&mut self, now: u64) -> Option<Entry> {
        if now < self.stall_until {
            // In-flight refill has not landed yet.
            if self.pending() > 0 {
                self.stalls += 1;
            }
            return None;
        }
        match self.queue.pop_front() {
            Some(e) => Some(e),
            None => {
                if !self.backlog.is_empty() {
                    self.stalls += 1;
                }
                None
            }
        }
    }
}

/// Pass detector over `n` splitter streams: all marks must arrive before
/// the adder tree is validated.
#[derive(Debug, Clone)]
pub struct PassDetector {
    seen: Vec<bool>,
}

impl PassDetector {
    pub fn new(n: usize) -> Self {
        Self { seen: vec![false; n] }
    }

    /// Splitter `i` reached its pass mark.
    pub fn mark(&mut self, i: usize) {
        self.seen[i] = true;
    }

    /// All marks in? (validates the rear adder tree, then resets).
    pub fn all_passed(&mut self) -> bool {
        if self.seen.iter().all(|&s| s) {
            self.seen.iter_mut().for_each(|s| *s = false);
            true
        } else {
            false
        }
    }

    pub fn pending(&self) -> usize {
        self.seen.iter().filter(|&&s| !s).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::kneading::{knead_lane, Lane};

    fn lane_of(ws: Vec<i32>) -> KneadedLane {
        let n = ws.len();
        knead_lane(&Lane::new(ws, vec![1; n]), 16, Mode::Fp16)
    }

    #[test]
    fn streams_lane_then_pass_mark() {
        let kl = lane_of(vec![0b1, 0b10, 0b100]);
        let mut tb = ThrottleBuffer::new(8, 2);
        tb.push_lane(&kl);
        assert_eq!(tb.pending(), kl.kneaded_len() + 1);
        let mut seen_kneaded = 0;
        let mut now = 0u64;
        loop {
            tb.refill(now, 8);
            match tb.pop(now) {
                Some(Entry::Kneaded) => seen_kneaded += 1,
                Some(Entry::PassMark) => break,
                None => {}
            }
            now += 1;
            assert!(now < 1000, "test runaway");
        }
        assert_eq!(seen_kneaded, kl.kneaded_len());
    }

    #[test]
    fn empty_buffer_records_stall_and_pays_latency() {
        let kl = lane_of(vec![0x7FFF; 4]);
        let mut tb = ThrottleBuffer::new(2, 3);
        tb.push_lane(&kl);
        // No refill yet: pop must stall.
        assert_eq!(tb.pop(0), None);
        assert_eq!(tb.stalls, 1);
        // A dry-buffer refill pays the access latency before delivery.
        tb.refill(1, 2);
        assert_eq!(tb.pop(1), None); // in flight (lands at cycle 4)
        assert_eq!(tb.pop(3), None);
        assert!(tb.pop(4).is_some());
        assert!(tb.stalls >= 3);
    }

    #[test]
    fn pass_detector_waits_for_all() {
        let mut pd = PassDetector::new(3);
        pd.mark(0);
        pd.mark(2);
        assert!(!pd.all_passed());
        assert_eq!(pd.pending(), 1);
        pd.mark(1);
        assert!(pd.all_passed());
        // Resets after firing.
        assert_eq!(pd.pending(), 3);
    }

    #[test]
    fn capacity_bounds_refill() {
        let kl = lane_of(vec![0b1; 64]);
        let mut tb = ThrottleBuffer::new(4, 1);
        tb.push_lane(&kl);
        tb.refill(0, 100);
        // Only `capacity` entries enter the buffer (pop after the
        // delivery latency has elapsed).
        let mut in_buffer = 0;
        while tb.pop(10).is_some() {
            in_buffer += 1;
        }
        assert_eq!(in_buffer, 4);
    }
}
