//! Tetris accelerator timing model (§III.C.2, Fig 5).
//!
//! Cycle cost: each splitter consumes one kneaded weight per cycle (two
//! in int8 mode), so a layer costs the total kneaded-weight count
//! divided by the chip's splitter throughput. The kneaded count is
//! computed **exactly** on the sampled filter lanes by running the real
//! kneading compiler, then scaled by filter count × output pixels
//! (weights are reused at every output position, so each filter's
//! kneaded stream length is exact).

use super::activation::ActivationProfile;
use super::edram::{memory_cycles, Traffic};
use super::{Accelerator, ChipActivity, LayerSample, LayerSim};
use crate::config::{AccelConfig, CalibConfig, Mode};
use crate::kneading::stats::KneadStats;
use crate::model::ConvLayer;
use crate::quant::essential_bits;

/// Tetris timing model.
pub struct TetrisSim;

/// Per-sample kneading measurement shared by cycles + energy accounting.
#[derive(Debug, Clone, Copy)]
pub struct KneadMeasure {
    /// Mean kneaded weights per filter lane.
    pub mean_kneaded_per_lane: f64,
    /// Mean essential bits per filter lane (segment-adder activity).
    pub mean_essential_per_lane: f64,
}

/// Measure kneading on the sampled lanes (exact, not statistical).
pub fn measure_kneading(sample: &LayerSample, ks: usize) -> KneadMeasure {
    let mode = sample.mode;
    let bits = mode.weight_bits() as u32;
    let mut kneaded = 0u64;
    let mut essential = 0u64;
    for lane in &sample.filter_lanes {
        let s = KneadStats::measure(lane, ks, mode);
        kneaded += s.kneaded;
        essential += lane.iter().map(|&w| essential_bits(w, bits) as u64).sum::<u64>();
    }
    let n = sample.filter_lanes.len().max(1) as f64;
    KneadMeasure {
        mean_kneaded_per_lane: kneaded as f64 / n,
        mean_essential_per_lane: essential as f64 / n,
    }
}

/// Shared cycle/activity core behind [`TetrisSim`] and the
/// activation-aware [`TetrisSkipSim`](super::activation::TetrisSkipSim):
/// with `profile = None` every conv window streams its kneaded weights
/// (the paper's dense-activation machine); with a measured
/// [`ActivationProfile`], kneaded weights paired with a zero
/// activation operand are squashed at the throttle buffer
/// (Cnvlutin2-style — compute and segment-adder activity scale by the
/// activation **value** survival fraction, which subsumes the
/// executor's coarser all-zero-window skip), whole skipped windows
/// additionally never drain the rear adder tree (tree activity scales
/// by the **window** survival fraction), and zero activation words are
/// never fetched (the activation traffic leg scales by the value
/// survival fraction). Weight traffic is left dense — the kneaded
/// stream prefetches per output row regardless of which windows in
/// the row survive.
pub(crate) fn simulate_layer_core(
    layer: &ConvLayer,
    sample: &LayerSample,
    cfg: &AccelConfig,
    calib: &CalibConfig,
    profile: Option<&ActivationProfile>,
) -> LayerSim {
    assert_eq!(sample.mode, cfg.mode, "sample precision != config mode");
    let m = measure_kneading(sample, cfg.ks);
    let out_pix = (layer.out_hw() * layer.out_hw()) as f64;
    let filters = layer.out_c as f64;
    let window_survival = profile.map_or(1.0, ActivationProfile::window_survival);
    let act_survival = profile.map_or(1.0, ActivationProfile::value_survival);

    // Total kneaded weights the splitter array must consume — slots
    // whose activation operand is zero are squashed before the
    // splitters ever see them.
    let total_kneaded = m.mean_kneaded_per_lane * filters * out_pix * act_survival;
    let throughput = cfg.kneaded_throughput() as f64;
    let mut compute = (total_kneaded / throughput).ceil();
    if cfg.mode == Mode::Int8 {
        // Halved splitters double kneaded-weight intake but double
        // the activation-window port pressure on the throttle
        // buffer — the measured gap to "2× in theory" (§III.C.3).
        compute /= calib.timing.int8_supply_derate;
    }
    let compute = compute as u64;

    // Memory: the kneaded stream is wider than raw weights — each
    // kneaded weight stores (1 + ⌈log2 KS⌉) bits per slot — and the
    // 5 KB throttle buffer cannot hold whole kneaded filters, so the
    // stream re-fetches from eDRAM once per output *row* (DaDN's
    // per-PE synapse eDRAM holds raw weights resident instead; the
    // asymmetry is the cost of the pointer metadata).
    let slot_bits = (1 + cfg.pointer_bits()) as f64;
    let kneaded_words_per_lane =
        m.mean_kneaded_per_lane * (cfg.mode.weight_bits() as f64 * slot_bits / 16.0);
    let traffic = Traffic {
        weight_words: kneaded_words_per_lane * filters * layer.out_hw() as f64,
        act_words: (layer.in_c * layer.in_hw * layer.in_hw) as f64 * act_survival,
    };
    let memory = memory_cycles(&traffic, cfg);

    let cycles = compute.max(memory) + calib.timing.pipeline_fill + calib.timing.tree_drain;

    // Activity: splitters decode every surviving slot of every kneaded
    // weight; segment adders fire once per essential bit of a
    // surviving slot; the rear tree drains once per surviving lane
    // (per output pixel per filter — wholly-skipped windows never
    // drain).
    let lanes = filters * out_pix;
    let activity = ChipActivity {
        adds: m.mean_essential_per_lane * lanes * act_survival,
        splitter_decodes: total_kneaded * cfg.mode.weight_bits() as f64,
        tree_drains: lanes * window_survival,
        sram_reads: layer.macs() as f64 * act_survival, // activation operand reads
        edram_reads: traffic.total(),
        fifo_ops: total_kneaded, // throttle-buffer pops
        reg_writes: m.mean_essential_per_lane * lanes * act_survival, // segment regs
        ..ChipActivity::default()
    };
    LayerSim {
        layer: layer.name.clone(),
        cycles,
        macs: layer.macs(),
        activity,
        memory_bound: memory > compute,
    }
}

impl Accelerator for TetrisSim {
    fn name(&self) -> &'static str {
        "tetris"
    }

    fn simulate_layer(
        &self,
        layer: &ConvLayer,
        sample: &LayerSample,
        cfg: &AccelConfig,
        calib: &CalibConfig,
    ) -> LayerSim {
        simulate_layer_core(layer, sample, cfg, calib, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::sim::dadn::DadnSim;
    use crate::sim::sample::sample_network;

    #[test]
    fn tetris_beats_dadn_on_every_vgg_layer() {
        let net = zoo::vgg16();
        let cfg = AccelConfig::default();
        let calib = CalibConfig::default();
        let samples = sample_network(&net, Mode::Fp16, 3).unwrap();
        for (i, l) in net.layers.iter().enumerate() {
            let t = TetrisSim.simulate_layer(l, &samples[i], &cfg, &calib);
            let d = DadnSim.simulate_layer(l, &samples[i], &cfg, &calib);
            assert!(
                t.cycles < d.cycles,
                "layer {}: tetris {} !< dadn {}",
                l.name,
                t.cycles,
                d.cycles
            );
        }
    }

    #[test]
    fn larger_ks_fewer_cycles() {
        let net = zoo::alexnet();
        let calib = CalibConfig::default();
        let samples = sample_network(&net, Mode::Fp16, 5).unwrap();
        let l = &net.layers[2];
        let mut cycles = Vec::new();
        for ks in [10, 16, 32] {
            let cfg = AccelConfig { ks, ..AccelConfig::default() };
            cycles.push(TetrisSim.simulate_layer(l, &samples[2], &cfg, &calib).cycles);
        }
        assert!(cycles[0] > cycles[1] && cycles[1] > cycles[2], "{cycles:?}");
    }

    #[test]
    fn kneading_measure_bounds() {
        let net = zoo::alexnet();
        let samples = sample_network(&net, Mode::Fp16, 7).unwrap();
        let m = measure_kneading(&samples[1], 16);
        let lane_len = net.layers[1].lane_len() as f64;
        // Kneaded length per lane is between essential_bits/16 (perfect
        // packing of the bit-parallel stream) and the source length.
        assert!(m.mean_kneaded_per_lane <= lane_len);
        assert!(m.mean_kneaded_per_lane >= m.mean_essential_per_lane / 16.0);
        // Fig 11 zone under the Fig 2 calibration: T_ks/T_base ∈ (0.6, 0.85).
        let tf = m.mean_kneaded_per_lane / lane_len;
        assert!((0.55..0.9).contains(&tf), "time fraction {tf}");
    }

    #[test]
    #[should_panic(expected = "sample precision != config mode")]
    fn mode_mismatch_is_rejected() {
        let net = zoo::alexnet();
        let cfg = AccelConfig { mode: Mode::Int8, ..AccelConfig::default() };
        let calib = CalibConfig::default();
        let samples = sample_network(&net, Mode::Fp16, 1).unwrap();
        TetrisSim.simulate_layer(&net.layers[0], &samples[0], &cfg, &calib);
    }
}
