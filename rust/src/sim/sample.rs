//! Layer weight sampling for the timing models.
//!
//! A [`LayerSample`] holds per-filter weight lanes. Convolution reuses a
//! filter's weights at every output pixel, so the per-filter lane cost is
//! exact; sampling only subsets the *filters* of very wide layers.

use crate::config::Mode;
use crate::model::weights::{profile_with, DensityCalibration};
use crate::model::{ConvLayer, LoadedWeights, Network};
use crate::quant::QWeight;
use crate::util::rng::Rng;

/// Cap on filters materialized per layer. Wide layers (≥512 filters)
/// have i.i.d. filter statistics, so 64 filters bound the sampling error
/// on mean kneaded length to well under 1% (see
/// `rust/tests/sampling_error.rs`).
pub const MAX_SAMPLED_FILTERS: usize = 64;

/// Sampled weight lanes for one layer.
#[derive(Debug, Clone)]
pub struct LayerSample {
    /// One lane (length `in_c·k·k`) per sampled filter.
    pub filter_lanes: Vec<Vec<QWeight>>,
    /// Total filters in the real layer (scaling factor numerator).
    pub total_filters: usize,
    /// Precision the weights were drawn in.
    pub mode: Mode,
}

impl LayerSample {
    /// Scale factor from sampled filters to the full layer.
    pub fn filter_scale(&self) -> f64 {
        self.total_filters as f64 / self.filter_lanes.len() as f64
    }

    /// All sampled weights, flattened (bit-statistics input).
    pub fn flat(&self) -> Vec<QWeight> {
        self.filter_lanes.iter().flatten().copied().collect()
    }
}

/// Draw a layer's sample from the bit profile of `network` under the
/// given density calibration.
pub fn sample_layer(
    network: &str,
    layer: &ConvLayer,
    mode: Mode,
    calib: DensityCalibration,
    rng: &mut Rng,
) -> crate::Result<LayerSample> {
    let profile = profile_with(network, mode, calib)?;
    let n_filters = layer.out_c.min(MAX_SAMPLED_FILTERS);
    let lane_len = layer.lane_len();
    let filter_lanes = (0..n_filters)
        .map(|_| profile.generate(lane_len, rng))
        .collect();
    Ok(LayerSample { filter_lanes, total_filters: layer.out_c, mode })
}

/// Samples for every layer of a network, deterministically seeded.
///
/// Uses the **Fig 2** density calibration — the one that reproduces the
/// paper's performance evaluation (Figs 8–11). Table 1 experiments call
/// `profile_with(.., DensityCalibration::Table1)` directly; see
/// `model::weights` docs for the inconsistency discussion.
pub fn sample_network(net: &Network, mode: Mode, seed: u64) -> crate::Result<Vec<LayerSample>> {
    sample_network_calibrated(net, mode, seed, DensityCalibration::Fig2)
}

/// Samples under an explicit density calibration (ablation benches).
pub fn sample_network_calibrated(
    net: &Network,
    mode: Mode,
    seed: u64,
    calib: DensityCalibration,
) -> crate::Result<Vec<LayerSample>> {
    let mut root = Rng::new(seed ^ 0x7e7215);
    let mut out = Vec::with_capacity(net.layers.len());
    for (i, layer) in net.layers.iter().enumerate() {
        let mut rng = root.fork(i as u64);
        out.push(sample_layer(&net.name, layer, mode, calib, &mut rng)?);
    }
    Ok(out)
}

/// Build samples from *real* trained weights (the tiny-CNN E2E path):
/// every filter is included, no sampling.
pub fn samples_from_loaded(net: &Network, loaded: &LoadedWeights) -> crate::Result<Vec<LayerSample>> {
    let mut out = Vec::with_capacity(net.layers.len());
    for layer in &net.layers {
        let ll = loaded.layer(&layer.name).ok_or_else(|| {
            crate::Error::Artifact(format!("weight file missing layer `{}`", layer.name))
        })?;
        let [o, i, kh, kw] = ll.shape;
        if o != layer.out_c || i != layer.in_c || kh != layer.k || kw != layer.k {
            return Err(crate::Error::Shape(format!(
                "layer `{}`: file shape {:?} != zoo shape [{},{},{},{}]",
                layer.name, ll.shape, layer.out_c, layer.in_c, layer.k, layer.k
            )));
        }
        let lane_len = layer.lane_len();
        let filter_lanes = ll.weights.chunks(lane_len).map(|c| c.to_vec()).collect();
        out.push(LayerSample { filter_lanes, total_filters: o, mode: loaded.mode });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn sample_shapes_match_layer() {
        let net = zoo::alexnet();
        let samples = sample_network(&net, Mode::Fp16, 1).unwrap();
        assert_eq!(samples.len(), 5);
        // conv1: 96 filters → capped at 64; lane = 3*11*11 = 363.
        assert_eq!(samples[0].filter_lanes.len(), 64);
        assert_eq!(samples[0].filter_lanes[0].len(), 363);
        assert_eq!(samples[0].total_filters, 96);
        assert!((samples[0].filter_scale() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let net = zoo::nin();
        let a = sample_network(&net, Mode::Fp16, 99).unwrap();
        let b = sample_network(&net, Mode::Fp16, 99).unwrap();
        assert_eq!(a[3].filter_lanes, b[3].filter_lanes);
        let c = sample_network(&net, Mode::Fp16, 100).unwrap();
        assert_ne!(a[3].filter_lanes, c[3].filter_lanes);
    }

    #[test]
    fn narrow_layers_keep_all_filters() {
        let net = zoo::tiny_cnn();
        let samples = sample_network(&net, Mode::Fp16, 1).unwrap();
        assert_eq!(samples[0].filter_lanes.len(), 8); // conv1 has 8 filters
        assert_eq!(samples[0].filter_scale(), 1.0);
    }
}
