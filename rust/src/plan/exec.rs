//! The run-time half of the split: walk the tile schedule, stream the
//! pre-kneaded lanes through SAC, never knead.
//!
//! Three walks execute the tile schedule (DESIGN.md §Streaming segment
//! pipeline, §Whole-network streaming):
//!
//! * **Streaming** ([`Walk::Streaming`], the default for batches that
//!   cover the worker budget): each segment is a producer/consumer
//!   pipeline over rolling `RingBuf` rings that slide down the
//!   image. Input rows are fed `tile_rows` at a time; every stage's
//!   `rows_ready → rows_emitted` advance
//!   ([`RowContract::rows_emitted`](super::graph::RowContract::rows_emitted))
//!   chains through the segment, new
//!   rows land in the ring while the halo rows the next window needs
//!   are *retained* across steps — so every row of every stage is
//!   computed exactly once (`halo_recompute_rows == 0`) and the
//!   final stage streams straight into the segment's output map. The
//!   cost is a sequential row order per image; parallelism comes from
//!   images and branch arms.
//! * **Tiled** ([`Walk::Tiled`], PR 3's walk, kept as the explicit
//!   baseline): stateless (image, row-tile) work items fan out via
//!   `util::pool::par_map_with`, each recomputing its tile's halo
//!   rows (overlapped tiling). More parallel slots for small batches;
//!   `halo_recompute_rows` counts the duplicated stage rows.
//! * **Pipelined** ([`Walk::Pipelined`], the whole-network extension
//!   of the streaming walk): the rings chain **across** segment
//!   boundaries — a pool's emitted rows feed the next conv's input
//!   ring directly, branch arms consume one upstream ring and write
//!   disjoint channel blocks of one concat ring — so the entire conv
//!   trunk streams as one pipeline and only the trunk output (what
//!   the GAP/flatten/FC tail consumes) ever materializes. Peak memory
//!   is input + Σ ring working sets + trunk output: flat in network
//!   depth, with `halo_recompute_rows == 0` end to end.
//!
//! All walks are bit-identical to each other and to the scalar
//! references for every tile height, thread budget and input
//! (invariant I5 over walks — `rust/tests/plan_streaming.rs`).
//!
//! Classifier heads execute for real: a [`Segment::Flatten`] reshapes
//! the spatial trunk into feature rows (free in row-major NCHW), then
//! each [`Segment::Fc`] streams its per-name compiled lanes —
//! activation-fused for every head but the stack's last — so VGG-16
//! and GoogleNet run image → logits end to end.
//!
//! Every arithmetic step mirrors a plain scalar reference exactly (same
//! gather order, same group windows, same `i64 → i32` casts): the
//! legacy `runtime::quantized::forward_scalar` pipeline for the tiny
//! CNN, and the naive MAC interpreter `model::reference` for the full
//! declared-topology zoo (FC stacks included). Pool windows use Caffe
//! ceil-mode sizing ([`PoolSpec::out_hw`]); max pools take the
//! window's in-bounds maximum (padding never wins), average pools
//! floor-divide the i64 sum by the in-bounds tap count.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::model::{PoolKind, PoolSpec, Tensor};
use crate::quant::requantize;
use crate::sac::{rear_adder_tree, split_kneaded, SegmentRegisters};
use crate::util::pool::{par_map_with, split_budget, worker_count};

use super::compiled::{CompiledConv, CompiledFc, CompiledNetwork};
use super::graph::{FusedStage, PlanOp, RowContract, Segment};

/// Which dataflow executes the tile schedule (see the module docs).
/// Results are bit-identical across walks; the walk only moves wall
/// time, memory and halo recompute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Walk {
    /// Per-segment rolling-ring producer/consumer pipeline: zero halo
    /// recompute, sequential row order per image (parallel across
    /// images/arms); each segment's output map still materializes.
    Streaming,
    /// Stateless overlapped row tiles: halo rows recomputed per tile,
    /// (image × tile) parallel slots.
    Tiled,
    /// Whole-network streaming: the rings chain across segment
    /// boundaries (pool rows feed the next conv's ring directly,
    /// branch arms share one upstream ring and one concat ring), so
    /// only the trunk output materializes and peak memory is flat in
    /// network depth. Zero halo recompute end to end.
    Pipelined,
}

/// Which conv inner loop runs inside [`conv_rows`] (shared by every
/// walk). Results are bit-identical across kernels (invariant I5,
/// property-swept in `rust/tests/plan_kernel.rs`); the kernel only
/// moves host wall time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Kernel {
    /// The decoded-lane fast path (the default): executes the flat
    /// `(act_slot, segment, sign)` schedule lowered at plan compile
    /// ([`CompiledConv::decoded`](super::DecodedConv)), processing a
    /// strip of adjacent output pixels per decoded entry
    /// (weight-stationary register blocking) over per-output-row
    /// row-band gathers. The slot-decode work happened once at
    /// compile, so the hot loop is a flat scan — but the energy
    /// counters still charge the schedule's precomputed per-window
    /// decode/add counts, keeping accounting identical to the legacy
    /// walk.
    #[default]
    Decoded,
    /// The original per-pixel walk: gather one im2col window, then
    /// [`split_kneaded`] re-decodes every kneaded weight's occupied
    /// slots for every output pixel of every filter. Kept as the
    /// bit-exact reference the decoded path is swept against.
    Legacy,
}

/// Execution-time knobs for [`CompiledNetwork::execute_opts`].
/// `None` fields fall back to the plan's compiled defaults.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecOpts {
    /// Row granularity. Tiled walk: output rows per fused tile.
    /// Streaming walk: input rows fed per ring advance. `Some(0)`
    /// materializes — one step/tile spans each fused chain's full
    /// height. `None` uses the plan's `tile_rows` and (tiled walk
    /// only) lets the executor shrink tiles to keep every worker fed
    /// on small batches (results are tile-invariant either way).
    pub tile_rows: Option<usize>,
    /// Thread budget. `None` uses `util::pool::worker_count()`.
    pub workers: Option<usize>,
    /// Dataflow. `None` first honors the plan's compiled `walk_hint`
    /// (the registry pins [`Walk::Pipelined`] when the memory budget
    /// demands whole-network streaming), then picks
    /// [`Walk::Streaming`] when the batch covers the worker budget
    /// (n ≥ workers) — serving batches stream with zero halo
    /// recompute — and [`Walk::Tiled`] otherwise, where per-tile
    /// fan-out keeps a lone image from pinning all but one worker
    /// idle.
    pub walk: Option<Walk>,
    /// Branch-arm thread split: caps how many branch arms run
    /// concurrently (`Some(1)` walks arms in sequence, so at most one
    /// arm's rings + input clone are live on top of the kept arm
    /// outputs — the auto-tuner's over-budget lever). `None` keeps the
    /// default: one arm thread per arm up to the worker budget.
    /// Scheduling only — results are bit-identical for every split
    /// (invariant I5).
    pub arm_threads: Option<usize>,
    /// Activation-aware SAC skipping: detect all-zero input rows and
    /// im2col windows in the conv inner loops and skip the SAC work
    /// (the per-filter splitter/adder walk), writing the zeros the
    /// arithmetic would have produced. Bit-exact by construction — a
    /// zero operand contributes nothing to any partial sum, so
    /// skipping changes cycles and the trace counters
    /// ([`AllocStats::skipped_windows`]), never logits (invariant I5
    /// with skipping enabled is property-swept in
    /// `rust/tests/plan_skip.rs`). `None` falls back to the plan's
    /// compiled `skip_zero_activations` default.
    pub skip_zero_activations: Option<bool>,
    /// Conv inner-loop selection: the decoded-lane fast path or the
    /// legacy per-pixel splitter walk (see [`Kernel`]). `None` falls
    /// back to the plan's compiled `kernel` default
    /// ([`Kernel::Decoded`]). Bit-exact either way — the kernel moves
    /// host time only, never logits or energy counters.
    pub kernel: Option<Kernel>,
}

impl ExecOpts {
    /// Exact tile height through the overlapped tiled walk — the PR 3
    /// baseline (tests, sweeps, and the streaming-vs-tiled bench).
    pub fn tiled(tile_rows: usize) -> Self {
        Self { tile_rows: Some(tile_rows), walk: Some(Walk::Tiled), ..Self::default() }
    }

    /// Streaming walk with an explicit advance step (input rows per
    /// ring slide); `0` feeds the whole image in one step.
    pub fn streaming(tile_rows: usize) -> Self {
        Self { tile_rows: Some(tile_rows), walk: Some(Walk::Streaming), ..Self::default() }
    }

    /// Whole-network pipelined walk with an explicit advance step —
    /// rings chained across segment boundaries, only the trunk output
    /// materializes (DESIGN.md §Whole-network streaming); `0` feeds
    /// the whole image in one step.
    pub fn pipelined(tile_rows: usize) -> Self {
        Self { tile_rows: Some(tile_rows), walk: Some(Walk::Pipelined), ..Self::default() }
    }

    /// One tile per fused chain: the materializing baseline the
    /// peak-allocation tests compare both walks against.
    pub fn materializing() -> Self {
        Self::tiled(0)
    }

    /// Cap the thread budget (branch arms split whatever this is).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }

    /// Pin the dataflow explicitly.
    pub fn with_walk(mut self, walk: Walk) -> Self {
        self.walk = Some(walk);
        self
    }

    /// Cap concurrent branch-arm threads (see [`ExecOpts::arm_threads`]).
    pub fn with_arm_threads(mut self, arm_threads: usize) -> Self {
        self.arm_threads = Some(arm_threads);
        self
    }

    /// Toggle the activation-aware skip lane explicitly (see
    /// [`ExecOpts::skip_zero_activations`]).
    pub fn with_skip_zero_activations(mut self, skip: bool) -> Self {
        self.skip_zero_activations = Some(skip);
        self
    }

    /// Pin the conv inner loop explicitly (see [`ExecOpts::kernel`]).
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = Some(kernel);
        self
    }
}

/// Execution trace for one [`CompiledNetwork::execute_traced`] call:
/// peak intermediate-buffer accounting plus the halo-recompute
/// counter.
///
/// Feature maps, branch-arm input clones and ring buffers enter
/// `current` when allocated and leave when retired; `peak` is the
/// high-water mark. Per-thread fixed scratch (the im2col gather row,
/// segment registers) is excluded — it is O(lane length) and
/// independent of tiling. `halo_rows` counts stage-output rows
/// computed more than once across tile boundaries: positive for the
/// tiled walk (it grows with `k` and `1/tile_rows`), **always zero**
/// for the streaming and pipelined walks, whose rings retain halo
/// rows instead.
#[derive(Debug, Default)]
pub struct AllocStats {
    current: AtomicU64,
    peak: AtomicU64,
    halo_rows: AtomicU64,
    skipped_rows: AtomicU64,
    skipped_windows: AtomicU64,
    total_windows: AtomicU64,
    slot_decodes: AtomicU64,
    segment_adds: AtomicU64,
    act_zero: AtomicU64,
    act_total: AtomicU64,
    act_essential: AtomicU64,
}

impl AllocStats {
    fn alloc(&self, bytes: u64) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    fn free(&self, bytes: u64) {
        self.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// High-water mark of live feature-map bytes.
    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Stage-output rows computed more than once (tile-boundary halo
    /// recompute). Zero under the streaming walk.
    pub fn halo_recompute_rows(&self) -> u64 {
        self.halo_rows.load(Ordering::Relaxed)
    }

    /// Conv output rows skipped wholesale because every in-bounds
    /// input row under them carried an all-zero mask. Always 0 with
    /// skipping off. Under the tiled walk, halo rows a tile skips are
    /// counted per tile that visits them — the counter reflects SAC
    /// work actually avoided, not distinct map rows.
    pub fn skipped_rows(&self) -> u64 {
        self.skipped_rows.load(Ordering::Relaxed)
    }

    /// Conv output windows whose SAC walk (splitter + rear adder tree
    /// per filter) was skipped; row-level skips count every window in
    /// the row. Always 0 with skipping off.
    pub fn skipped_windows(&self) -> u64 {
        self.skipped_windows.load(Ordering::Relaxed)
    }

    /// Conv output windows visited in total — the denominator for
    /// [`Self::window_skip_fraction`], counted whenever the call is
    /// traced (skipping on or off).
    pub fn total_windows(&self) -> u64 {
        self.total_windows.load(Ordering::Relaxed)
    }

    /// Splitter slot decodes the conv trunk performed (legacy kernel)
    /// or charged from the compile-time schedule (decoded kernel) —
    /// one per slot of every kneaded weight of every executed window
    /// × filter, exactly what `sim`'s SAC activity model counts.
    /// Identical across kernels for the same input (skipped windows
    /// are charged by neither). FC heads run their own splitter walk
    /// and are not counted here — the counter covers the conv trunk.
    pub fn slot_decodes(&self) -> u64 {
        self.slot_decodes.load(Ordering::Relaxed)
    }

    /// Sign-adjusted segment-register accumulations the conv trunk
    /// performed — one per essential bit routed, the paper's SAC add
    /// count. Identical across kernels for the same input.
    pub fn segment_adds(&self) -> u64 {
        self.segment_adds.load(Ordering::Relaxed)
    }

    /// Fraction of conv windows the skip lane eliminated (0.0 when
    /// nothing was counted).
    pub fn window_skip_fraction(&self) -> f64 {
        let total = self.total_windows();
        if total == 0 {
            0.0
        } else {
            self.skipped_windows() as f64 / total as f64
        }
    }

    /// Post-activation values observed at the ReLU seal points (the
    /// sample size behind the two distribution statistics below).
    pub fn activation_values(&self) -> u64 {
        self.act_total.load(Ordering::Relaxed)
    }

    /// Fraction of post-activation values that are exactly zero — the
    /// dynamic ineffectual-activation supply (Cnvlutin2's quantity),
    /// measured on the real streams this execution produced.
    pub fn activation_zero_fraction(&self) -> f64 {
        let total = self.act_total.load(Ordering::Relaxed);
        if total == 0 {
            0.0
        } else {
            self.act_zero.load(Ordering::Relaxed) as f64 / total as f64
        }
    }

    /// Mean essential (nonzero) bits per post-activation value, zeros
    /// included — the operand-width quantity a Laconic-style
    /// bit-serial activation model charges cycles for.
    pub fn activation_essential_bits_mean(&self) -> f64 {
        let total = self.act_total.load(Ordering::Relaxed);
        if total == 0 {
            0.0
        } else {
            self.act_essential.load(Ordering::Relaxed) as f64 / total as f64
        }
    }
}

/// Activations carry Q8.8 magnitudes; 16 bits bounds every essential
/// bit position the seal scans may observe.
const ACT_BITS: u32 = 16;

/// Local accumulator for the post-activation distribution one seal
/// pass observes (zeros, values, essential bits), flushed to the
/// shared [`AllocStats`] atomics once per pass.
#[derive(Default)]
struct ActTally {
    zeros: u64,
    total: u64,
    essential: u64,
}

impl ActTally {
    /// Scan one freshly sealed post-activation row: tally its values
    /// and return whether the row is all-zero (the row mask bit).
    fn seal_row(&mut self, row: &[i32]) -> bool {
        let mut all_zero = true;
        for &v in row {
            if v == 0 {
                self.zeros += 1;
            } else {
                all_zero = false;
                self.essential += u64::from(crate::quant::essential_bits(v, ACT_BITS));
            }
        }
        self.total += row.len() as u64;
        all_zero
    }

    fn flush(self, stats: Option<&AllocStats>) {
        if let Some(s) = stats {
            if self.total > 0 {
                s.act_zero.fetch_add(self.zeros, Ordering::Relaxed);
                s.act_total.fetch_add(self.total, Ordering::Relaxed);
                s.act_essential.fetch_add(self.essential, Ordering::Relaxed);
            }
        }
    }
}

/// Per-call execution context threaded through the segment walk.
struct Ctx<'a> {
    plan: &'a CompiledNetwork,
    /// Row granularity; 0 = full height (materializing).
    tile_rows: usize,
    /// Whether tiled-walk tiles may shrink for load balance (default
    /// path only — explicit `ExecOpts` sizes are honored exactly).
    adaptive: bool,
    walk: Walk,
    /// Branch-arm concurrency cap ([`ExecOpts::arm_threads`]).
    arm_threads: Option<usize>,
    /// Activation-aware skip lane on: maintain zero masks at the seal
    /// points and skip all-zero rows/windows in `conv_rows`.
    skip: bool,
    /// Conv inner-loop selection ([`ExecOpts::kernel`], resolved).
    kernel: Kernel,
    stats: Option<&'a AllocStats>,
}

impl Ctx<'_> {
    fn alloc(&self, bytes: u64) {
        if let Some(s) = self.stats {
            s.alloc(bytes);
        }
    }

    fn free(&self, bytes: u64) {
        if let Some(s) = self.stats {
            s.free(bytes);
        }
    }

    fn halo(&self, rows: u64) {
        if let Some(s) = self.stats {
            s.halo_rows.fetch_add(rows, Ordering::Relaxed);
        }
    }
}

fn tensor_bytes(t: &Tensor<i32>) -> u64 {
    (t.len() * std::mem::size_of::<i32>()) as u64
}

impl CompiledNetwork {
    /// Execute the plan on a Q8.8 input batch (N, C, H, W) with the
    /// plan's default tile height, the global worker count, and the
    /// default walk policy (see [`ExecOpts::walk`]).
    ///
    /// Returns int32 logits (N, classes) for classifier plans — FC
    /// stacks execute for real when compiled — or the final feature
    /// map for conv-only plans. The input spatial size may differ
    /// from the zoo's recorded `in_hw` — the executor derives all
    /// spatial extents from the tensor itself (used by tests/benches
    /// to run scaled workloads).
    pub fn execute(&self, x: &Tensor<i32>) -> crate::Result<Tensor<i32>> {
        self.execute_opts(x, ExecOpts::default())
    }

    /// [`Self::execute`] with explicit tile height / thread budget /
    /// walk. Results are bit-identical for every option combination
    /// (invariant I5); the options only move wall time, peak memory
    /// and halo recompute.
    pub fn execute_opts(&self, x: &Tensor<i32>, opts: ExecOpts) -> crate::Result<Tensor<i32>> {
        self.execute_inner(x, opts, None).map(|(t, _)| t)
    }

    /// [`Self::execute_opts`] plus the measured [`AllocStats`]: peak
    /// feature-map bytes (the accounting the peak-allocation tests pin
    /// fused-vs-materializing and streaming-vs-tiled claims with) and
    /// the `halo_recompute_rows` counter (which must read 0 under the
    /// streaming walk).
    pub fn execute_traced(
        &self,
        x: &Tensor<i32>,
        opts: ExecOpts,
    ) -> crate::Result<(Tensor<i32>, AllocStats)> {
        self.execute_inner(x, opts, Some(()))
    }

    fn execute_inner(
        &self,
        x: &Tensor<i32>,
        opts: ExecOpts,
        trace: Option<()>,
    ) -> crate::Result<(Tensor<i32>, AllocStats)> {
        let n = self.check_input(x)?;
        let stats = AllocStats::default();
        let (tile_rows, adaptive) = match opts.tile_rows {
            Some(t) => (t, false),
            None => (self.tile_rows, true),
        };
        let workers = opts.workers.unwrap_or_else(worker_count).max(1);
        let walk = opts.walk.or(self.walk_hint).unwrap_or(if n >= workers {
            Walk::Streaming
        } else {
            Walk::Tiled
        });
        let ctx = Ctx {
            plan: self,
            tile_rows,
            adaptive,
            walk,
            arm_threads: opts.arm_threads,
            skip: opts.skip_zero_activations.unwrap_or(self.skip_zero_activations),
            kernel: opts.kernel.unwrap_or(self.kernel),
            stats: trace.map(|()| &stats),
        };
        let input = x.clone();
        ctx.alloc(tensor_bytes(&input));
        let out = match walk {
            Walk::Pipelined => run_pipelined(&ctx, &self.schedule, input, workers)?,
            _ => run_segments(&ctx, &self.schedule, input, workers)?,
        };
        Ok((out, stats))
    }
}

/// Walk one segment list (the whole plan, or one branch arm).
fn run_segments(
    ctx: &Ctx,
    segs: &[Segment],
    mut h: Tensor<i32>,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    for seg in segs {
        if matches!(seg, Segment::Flatten) {
            // Pure reshape: row-major (N, C, H, W) → (N, C·H·W) —
            // same buffer, no bytes move, no accounting churn.
            let [n, c, hh, ww] = match *h.shape() {
                [n, c, hh, ww] => [n, c, hh, ww],
                _ => {
                    return Err(crate::Error::Shape(
                        "flatten input must be 4-D NCHW".into(),
                    ))
                }
            };
            h.reshape(&[n, c * hh * ww])?;
            continue;
        }
        let prev_bytes = tensor_bytes(&h);
        h = match seg {
            Segment::Fused(stages) => run_fused(ctx, stages, &h, workers)?,
            Segment::Branch(arms) => run_branch(ctx, arms, &h, workers)?,
            Segment::GlobalAvgPool => {
                let g = global_avg_pool(&h)?;
                ctx.alloc(tensor_bytes(&g));
                g
            }
            Segment::Flatten => unreachable!("handled above"),
            Segment::Fc { name } => {
                let fc = ctx.plan.fc_head(name).ok_or_else(|| {
                    crate::Error::Config(format!(
                        "plan has an Fc op for `{name}` but no compiled head"
                    ))
                })?;
                let out = fc_parallel(fc, &h, ctx.plan.mode, workers)?;
                ctx.alloc(tensor_bytes(&out));
                out
            }
        };
        // The consumed input retires once its consumer produced.
        ctx.free(prev_bytes);
    }
    Ok(h)
}

/// Branch arms under a shared thread budget: up to `workers` scoped
/// arm threads (they mostly sleep in their inner fan-out joins), each
/// walking its segments with a `split_budget` slice — so the arms'
/// stripes overlap without oversubscribing the host. With fewer
/// workers than arms, striping makes one arm thread walk several arms
/// in sequence, so live compute threads never exceed the budget.
/// Outputs concatenate along channels in arm order, exactly as before.
fn run_branch(
    ctx: &Ctx,
    arms: &[Vec<Segment>],
    x: &Tensor<i32>,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    let outer = ctx.arm_threads.unwrap_or(workers).min(workers).clamp(1, arms.len());
    let budgets = split_budget(workers, outer);
    let idx: Vec<usize> = (0..arms.len()).collect();
    let parts = par_map_with(outer, &idx, |i, &a| {
        ctx.alloc(tensor_bytes(x));
        run_segments(ctx, &arms[a], x.clone(), budgets[i % outer])
    });
    let mut tensors = Vec::with_capacity(parts.len());
    for p in parts {
        tensors.push(p?);
    }
    let cat = concat_channels(&tensors)?;
    ctx.alloc(tensor_bytes(&cat));
    for t in &tensors {
        ctx.free(tensor_bytes(t));
    }
    Ok(cat)
}

/// Resolved geometry of one fused stage against the actual input.
/// Crate-visible so the auto-tuner's cost model (`plan::cost`) can
/// replicate the executor's halo arithmetic over the exact same dims.
#[derive(Debug, Clone, Copy)]
pub(crate) struct StageDims {
    pub(crate) in_c: usize,
    pub(crate) in_h: usize,
    pub(crate) in_w: usize,
    pub(crate) out_c: usize,
    pub(crate) out_h: usize,
    pub(crate) out_w: usize,
}

fn is_elementwise(op: &PlanOp) -> bool {
    matches!(op, PlanOp::ReluRequant { .. })
}

/// Resolve every stage's geometry from the actual input extent (not
/// the declared topology — scaled/off-topology inputs are supported),
/// validating channels, strides and kernel fit. Shared by the fused
/// segment walks and the whole-network pipeline builder.
pub(crate) fn resolve_stage_dims(
    plan: &CompiledNetwork,
    stages: &[FusedStage],
    c0: usize,
    h0: usize,
    w0: usize,
) -> crate::Result<Vec<StageDims>> {
    let mut dims: Vec<StageDims> = Vec::with_capacity(stages.len());
    let (mut c, mut h, mut w) = (c0, h0, w0);
    for st in stages {
        let (oc, oh, ow) = match &st.op {
            PlanOp::Conv { layer, pad, stride } => {
                let conv = &plan.convs[*layer];
                if c != conv.in_c {
                    return Err(crate::Error::Shape(format!(
                        "{}: input channels {c} != weight channels {}",
                        conv.name, conv.in_c
                    )));
                }
                if *stride == 0 {
                    return Err(crate::Error::Config(format!("{}: stride 0", conv.name)));
                }
                if h + 2 * pad < conv.kh || w + 2 * pad < conv.kw {
                    return Err(crate::Error::Shape(format!(
                        "{}: {h}×{w} input (pad {pad}) smaller than {}×{} kernel",
                        conv.name, conv.kh, conv.kw
                    )));
                }
                (
                    conv.out_c,
                    (h + 2 * pad - conv.kh) / stride + 1,
                    (w + 2 * pad - conv.kw) / stride + 1,
                )
            }
            PlanOp::ReluRequant { .. } => (c, h, w),
            PlanOp::Pool(spec) => (c, spec.out_hw(h)?, spec.out_hw(w)?),
            other => {
                return Err(crate::Error::Config(format!(
                    "non-fusable op {other:?} in a fused segment"
                )))
            }
        };
        dims.push(StageDims { in_c: c, in_h: h, in_w: w, out_c: oc, out_h: oh, out_w: ow });
        (c, h, w) = (oc, oh, ow);
    }
    Ok(dims)
}

/// One fused `Conv → ReluRequant [→ Pool]` walk: resolve every
/// stage's geometry from the tensor, then dispatch on the context's
/// walk. Under the pipelined walk this only runs for tail/degenerate
/// segments (the pipeable prefix executes in [`run_pipelined`]), which
/// stream per segment.
fn run_fused(
    ctx: &Ctx,
    stages: &[FusedStage],
    x: &Tensor<i32>,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    let (n, c0, h0, w0) = match *x.shape() {
        [n, c, h, w] => (n, c, h, w),
        _ => return Err(crate::Error::Shape("fused segment input must be 4-D".into())),
    };
    let dims = resolve_stage_dims(ctx.plan, stages, c0, h0, w0)?;
    // One linear scan of the segment's (materialized) input map hands
    // stage 0 the same row-level zero masks the rings maintain for the
    // later stages — inter-segment maps are post-ReLU, so all-zero
    // rows are common and the scan is where tiled/streaming walks earn
    // their row skips.
    let zeros = if ctx.skip { ZeroMap::scan(x) } else { None };
    let zeros = zeros.as_ref();
    match ctx.walk {
        Walk::Streaming | Walk::Pipelined => {
            run_fused_streaming(ctx, stages, &dims, x, zeros, n, workers)
        }
        Walk::Tiled => run_fused_tiled(ctx, stages, &dims, x, zeros, n, workers),
    }
}

// ---------------------------------------------------------------- tiled walk

/// PR 3's overlapped tiling: one work item per (image, output-row
/// tile) of the final stage, each recomputing its halo rows. Kept as
/// the explicit baseline walk; `halo_recompute_rows` counts the
/// duplicated stage-output rows.
#[allow(clippy::too_many_arguments)]
fn run_fused_tiled(
    ctx: &Ctx,
    stages: &[FusedStage],
    dims: &[StageDims],
    x: &Tensor<i32>,
    zeros: Option<&ZeroMap>,
    n: usize,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    let last = dims.last().expect("fused segments are non-empty");
    let (oc, oh, ow) = (last.out_c, last.out_h, last.out_w);

    let mut tile = if ctx.tile_rows == 0 { oh } else { ctx.tile_rows.clamp(1, oh) };
    if ctx.adaptive && ctx.tile_rows != 0 {
        // Results are tile-invariant (I5), so the default path may
        // shrink tiles until (images × tiles) covers the budget.
        while tile > 1 && n * oh.div_ceil(tile) < workers {
            tile = tile.div_ceil(2);
        }
    }

    // One work item per (image, output-row tile) of the final stage.
    let mut items: Vec<(usize, usize, usize)> = Vec::with_capacity(n * oh.div_ceil(tile));
    for b in 0..n {
        let mut t0 = 0;
        while t0 < oh {
            let t1 = (t0 + tile).min(oh);
            items.push((b, t0, t1));
            t0 = t1;
        }
    }

    // Halo accounting: rows of each stage's output that adjacent
    // tiles both compute (backward spans overlap by up to k − stride
    // rows per stage per boundary; summing adjacent-pair overlaps
    // counts a row computed by j tiles exactly j−1 times). The tile
    // sequence is identical for every image, so one image's boundary
    // walk scales by the batch — and each boundary reuses the
    // previous iteration's spans as its predecessor's.
    if ctx.stats.is_some() && tile < oh && n > 0 {
        let m = stages.len();
        let spans_at = |t0: usize, t1: usize| -> Vec<(usize, usize)> {
            let mut spans = vec![(0usize, 0usize); m + 1];
            spans[m] = (t0, t1);
            for i in (0..m).rev() {
                spans[i] = stages[i].contract.in_span(spans[i + 1].0, spans[i + 1].1, dims[i].in_h);
            }
            spans
        };
        let mut per_image = 0u64;
        let mut prev = spans_at(0, tile.min(oh));
        let mut t0 = tile;
        while t0 < oh {
            let t1 = (t0 + tile).min(oh);
            let cur = spans_at(t0, t1);
            for i in 0..m {
                let lo = cur[i + 1].0.max(prev[i + 1].0);
                let hi = cur[i + 1].1.min(prev[i + 1].1);
                per_image += hi.saturating_sub(lo) as u64;
            }
            prev = cur;
            t0 = t1;
        }
        ctx.halo(per_image * n as u64);
    }

    let tiles = par_map_with(workers, &items, |_, &(b, t0, t1)| {
        run_tile(ctx, stages, dims, x, zeros, b, t0, t1)
    });

    let mut out: Tensor<i32> = Tensor::zeros(&[n, oc, oh, ow]);
    ctx.alloc(tensor_bytes(&out));
    for (&(b, t0, t1), res) in items.iter().zip(tiles) {
        let buf = res?;
        for f in 0..oc {
            for y in t0..t1 {
                let dst = out.idx4(b, f, y, 0);
                out.data_mut()[dst..dst + ow].copy_from_slice(buf.row(f, y));
            }
        }
        ctx.free(buf.bytes());
    }
    Ok(out)
}

/// One (image, tile) work item: produce final-stage rows `[t0, t1)` by
/// walking the fused stages over span rings. The backward pass derives
/// each stage's needed input span (tile + halo); the forward pass
/// computes exactly those rows — stage 0 reading the input tensor in
/// place, every later stage reading the previous ring — retiring each
/// ring as its consumer finishes.
#[allow(clippy::too_many_arguments)]
fn run_tile(
    ctx: &Ctx,
    stages: &[FusedStage],
    dims: &[StageDims],
    x: &Tensor<i32>,
    zeros: Option<&ZeroMap>,
    b: usize,
    t0: usize,
    t1: usize,
) -> crate::Result<RingBuf> {
    let m = stages.len();
    // spans[i] = rows of stage i's INPUT this tile needs; spans[m] is
    // the tile itself. (spans[0] is the tile's read window on the
    // input tensor — read in place, never copied.)
    let mut spans = vec![(0usize, 0usize); m + 1];
    spans[m] = (t0, t1);
    for i in (0..m).rev() {
        let (o0, o1) = spans[i + 1];
        spans[i] = stages[i].contract.in_span(o0, o1, dims[i].in_h);
    }

    let mut buf: Option<RingBuf> = None;
    for (i, st) in stages.iter().enumerate() {
        let (o0, o1) = spans[i + 1];
        let d = &dims[i];
        match &st.op {
            PlanOp::Conv { layer, pad, stride } => {
                let next = {
                    let src = row_src(&buf, x, b, zeros);
                    let mut out = RingBuf::span(d.out_c, o0, o1, d.out_w);
                    conv_rows(
                        &ctx.plan.convs[*layer],
                        &src,
                        d,
                        *pad,
                        *stride,
                        o0,
                        o1,
                        ctx.plan.mode,
                        ctx.skip,
                        ctx.kernel,
                        ctx.stats,
                        &mut RowTarget::Ring(&mut out),
                    );
                    out
                };
                retire(ctx, &mut buf, next);
            }
            PlanOp::ReluRequant { frac_bits } => {
                if buf.is_none() {
                    // Lone elementwise segment (never produced by the
                    // zoo's lowering, but kept total): seed its rows
                    // from the input tensor once.
                    let mut seeded = RingBuf::span(d.in_c, o0, o1, d.in_w);
                    for cc in 0..d.in_c {
                        for y in o0..o1 {
                            let src = x.idx4(b, cc, y, 0);
                            seeded
                                .row_mut(cc, y)
                                .copy_from_slice(&x.data()[src..src + d.in_w]);
                        }
                    }
                    ctx.alloc(seeded.bytes());
                    buf = Some(seeded);
                }
                let r = buf.as_mut().expect("seeded above");
                if ctx.skip {
                    // Requantize row by row so each row can be sealed
                    // with its zero mask (and tallied) as it finishes.
                    let mut tally = ActTally::default();
                    for cc in 0..r.c {
                        for y in r.y0..r.y1 {
                            for v in r.row_mut(cc, y) {
                                *v = requantize(*v, *frac_bits).max(0);
                            }
                            let zero = tally.seal_row(r.row(cc, y));
                            r.seal_zero(cc, y, zero);
                        }
                    }
                    tally.flush(ctx.stats);
                } else {
                    // Elementwise: same span, mutate the ring in place.
                    for v in r.data.iter_mut() {
                        *v = requantize(*v, *frac_bits).max(0);
                    }
                }
            }
            PlanOp::Pool(spec) => {
                let next = {
                    let src = row_src(&buf, x, b, zeros);
                    let mut out = RingBuf::span(d.in_c, o0, o1, d.out_w);
                    pool_rows(*spec, &src, d, o0, o1, &mut RowTarget::Ring(&mut out));
                    out
                };
                retire(ctx, &mut buf, next);
            }
            _ => unreachable!("run_fused validated the stage ops"),
        }
    }
    Ok(buf.expect("fused segments are non-empty"))
}

/// Retire the previous ring (if any) in favor of its consumer's output.
fn retire(ctx: &Ctx, buf: &mut Option<RingBuf>, next: RingBuf) {
    ctx.alloc(next.bytes());
    if let Some(old) = buf.replace(next) {
        ctx.free(old.bytes());
    }
}

// ------------------------------------------------------------ streaming walk

/// Rolling-ring streaming: one producer/consumer pipeline per image,
/// final-stage rows written straight into the output tensor's image
/// plane. Images stripe across the worker budget.
#[allow(clippy::too_many_arguments)]
fn run_fused_streaming(
    ctx: &Ctx,
    stages: &[FusedStage],
    dims: &[StageDims],
    x: &Tensor<i32>,
    zeros: Option<&ZeroMap>,
    n: usize,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    let last = dims.last().expect("fused segments are non-empty");
    let (oc, oh, ow) = (last.out_c, last.out_h, last.out_w);
    let step = if ctx.tile_rows == 0 { dims[0].in_h } else { ctx.tile_rows.max(1) };

    // Ring capacities depend only on the segment geometry and the
    // advance step — compute them once, share across every image.
    let caps = ring_caps(stages, dims, step);

    let mut out: Tensor<i32> = Tensor::zeros(&[n, oc, oh, ow]);
    ctx.alloc(tensor_bytes(&out));
    let plane = oc * oh * ow;
    let threads = workers.clamp(1, n.max(1));
    let results: Vec<crate::Result<()>> = if threads <= 1 {
        out.data_mut()
            .chunks_mut(plane.max(1))
            .enumerate()
            .map(|(b, p)| stream_image(ctx, stages, dims, x, zeros, b, p, step, &caps))
            .collect()
    } else {
        // Stripe images across scoped threads; each thread owns its
        // images' disjoint output planes, so no synchronization beyond
        // the scope join is needed and results are order-deterministic.
        type ImagePlane<'p> = (usize, &'p mut [i32]);
        let mut groups: Vec<Vec<ImagePlane>> = (0..threads).map(|_| Vec::new()).collect();
        for (b, p) in out.data_mut().chunks_mut(plane.max(1)).enumerate() {
            groups[b % threads].push((b, p));
        }
        let mut res: Vec<crate::Result<()>> = Vec::with_capacity(n);
        std::thread::scope(|s| {
            let caps = &caps;
            let handles: Vec<_> = groups
                .into_iter()
                .map(|group| {
                    s.spawn(move || {
                        group
                            .into_iter()
                            .map(|(b, p)| {
                                stream_image(ctx, stages, dims, x, zeros, b, p, step, caps)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            for h in handles {
                res.extend(h.join().expect("stream worker panicked"));
            }
        });
        res
    };
    for r in results {
        r?;
    }
    Ok(out)
}

/// Per-stage advance state shared — in lock-step — by the capacity
/// pre-pass and the compute pass, so ring capacities are exact.
struct FlowState {
    /// Output rows produced so far, per stage.
    produced: Vec<usize>,
    /// Retention floor of each stage's output: rows below are dead
    /// (no remaining reader window reaches them).
    floor: Vec<usize>,
    /// Input rows fed to stage 0.
    fed: usize,
}

impl FlowState {
    fn new(m: usize) -> Self {
        Self { produced: vec![0; m], floor: vec![0; m], fed: 0 }
    }

    /// Feed up to `step` more input rows and chain every stage's
    /// `rows_ready → rows_emitted` advance; `writes[i]` receives the
    /// new output rows `[w0, w1)` stage i computes this step. Floors
    /// update to the lowest row any remaining reader window needs —
    /// the reader walk skips past elementwise stages, which mutate
    /// their producer's storage rather than owning rows. Returns true
    /// once the input is exhausted (every stage fully produced).
    fn advance(
        &mut self,
        stages: &[FusedStage],
        dims: &[StageDims],
        step: usize,
        writes: &mut [(usize, usize)],
    ) -> bool {
        let m = stages.len();
        let h0 = dims[0].in_h;
        self.fed = (self.fed + step.max(1)).min(h0);
        let mut avail = self.fed;
        for i in 0..m {
            let e = stages[i]
                .contract
                .rows_emitted(avail, dims[i].in_h, dims[i].out_h)
                .max(self.produced[i]);
            writes[i] = (self.produced[i], e);
            self.produced[i] = e;
            avail = e;
        }
        for i in 0..m {
            let mut lo = self.produced[i];
            let mut j = i + 1;
            while j < m {
                let c = &stages[j].contract;
                let need = if self.produced[j] >= dims[j].out_h {
                    self.produced[i] // reader finished: frees the ring
                } else {
                    (self.produced[j] * c.stride).saturating_sub(c.pad)
                };
                lo = lo.min(need);
                if !is_elementwise(&stages[j].op) {
                    break;
                }
                j += 1;
            }
            self.floor[i] = self.floor[i].max(lo.min(self.produced[i]));
        }
        self.fed >= h0
    }
}

/// Shared plumbing of one streaming Conv/Pool stage: take the stage's
/// ring out, resolve the row source (input tensor for stage 0, the
/// producer's ring otherwise) and the row target (own ring grown to
/// the new watermark, or the output plane for the sink), run the
/// kernel, put the ring back. Conv and pool stages differ only in the
/// kernel they pass.
#[allow(clippy::too_many_arguments)]
fn windowed_stage(
    rings: &mut [Option<RingBuf>],
    owner: &[usize],
    i: usize,
    x: &Tensor<i32>,
    b: usize,
    zeros: Option<&ZeroMap>,
    out_plane: &mut [i32],
    d: &StageDims,
    w1: usize,
    kernel: impl FnOnce(&RowSrc, &mut RowTarget),
) {
    let mut dst = rings[i].take();
    {
        let src = if i == 0 {
            RowSrc::Tensor { x, b, zeros }
        } else {
            RowSrc::Ring(rings[owner[i - 1]].as_ref().expect("producer ring"))
        };
        let mut target = match &mut dst {
            Some(r) => {
                r.grow_to(w1);
                RowTarget::Ring(r)
            }
            None => RowTarget::Plane { data: &mut *out_plane, h: d.out_h, w: d.out_w },
        };
        kernel(&src, &mut target);
    }
    rings[i] = dst;
}

/// Exact per-stage ring capacities for one segment walk: run the
/// advance arithmetic without computing anything, recording each
/// ring's max live rows (produced watermark after a step minus the
/// retention floor before it). Depends only on the segment geometry
/// and the step, never on image contents.
fn ring_caps(stages: &[FusedStage], dims: &[StageDims], step: usize) -> Vec<usize> {
    let m = stages.len();
    let mut caps = vec![0usize; m];
    let mut floor_before = vec![0usize; m];
    let mut flow = FlowState::new(m);
    let mut writes = vec![(0usize, 0usize); m];
    loop {
        floor_before.copy_from_slice(&flow.floor);
        let done = flow.advance(stages, dims, step, &mut writes);
        for i in 0..m {
            caps[i] = caps[i].max(flow.produced[i] - floor_before[i]);
        }
        if done {
            return caps;
        }
    }
}

/// Stream one image through a fused segment: the compute pass slides
/// the pre-sized rolling rings ([`ring_caps`]) down the image — halo
/// rows are retained across steps, never recomputed — with the final
/// stage writing straight into `out_plane` (the image's slice of the
/// output tensor, (C, H, W) row-major).
#[allow(clippy::too_many_arguments)]
fn stream_image(
    ctx: &Ctx,
    stages: &[FusedStage],
    dims: &[StageDims],
    x: &Tensor<i32>,
    zeros: Option<&ZeroMap>,
    b: usize,
    out_plane: &mut [i32],
    step: usize,
    caps: &[usize],
) -> crate::Result<()> {
    let m = stages.len();
    let Some(sink) = stages.iter().rposition(|s| !is_elementwise(&s.op)) else {
        // Lone elementwise segment: seed from the input, mutate in
        // place (never produced by the zoo's lowering, kept total).
        let d = &dims[0];
        for cc in 0..d.in_c {
            for y in 0..d.in_h {
                let src = x.idx4(b, cc, y, 0);
                let dst = (cc * d.in_h + y) * d.in_w;
                out_plane[dst..dst + d.in_w]
                    .copy_from_slice(&x.data()[src..src + d.in_w]);
            }
        }
        for st in stages {
            if let PlanOp::ReluRequant { frac_bits } = &st.op {
                for v in out_plane.iter_mut() {
                    *v = requantize(*v, *frac_bits).max(0);
                }
            }
        }
        return Ok(());
    };

    // Storage owner per stage: elementwise stages mutate their
    // producer's storage; the sink writes the output plane; every
    // other Conv/Pool stage owns a rolling ring.
    let mut owner = vec![0usize; m];
    for i in 0..m {
        owner[i] = if is_elementwise(&stages[i].op) {
            debug_assert!(i > 0, "leading elementwise handled above");
            owner[i - 1]
        } else {
            i
        };
    }

    let mut rings: Vec<Option<RingBuf>> = (0..m)
        .map(|i| {
            if i != sink && !is_elementwise(&stages[i].op) {
                Some(RingBuf::with_capacity(dims[i].out_c, caps[i].max(1), dims[i].out_w))
            } else {
                None
            }
        })
        .collect();
    for r in rings.iter().flatten() {
        ctx.alloc(r.bytes());
    }

    // Compute pass, in lock-step with the pre-pass.
    let mut flow = FlowState::new(m);
    let mut writes = vec![(0usize, 0usize); m];
    loop {
        let done = flow.advance(stages, dims, step, &mut writes);
        for (i, st) in stages.iter().enumerate() {
            let (w0, w1) = writes[i];
            if w0 >= w1 {
                continue;
            }
            let d = &dims[i];
            match &st.op {
                PlanOp::Conv { layer, pad, stride } => {
                    windowed_stage(
                        &mut rings,
                        &owner,
                        i,
                        x,
                        b,
                        zeros,
                        out_plane,
                        d,
                        w1,
                        |src, dst| {
                            conv_rows(
                                &ctx.plan.convs[*layer],
                                src,
                                d,
                                *pad,
                                *stride,
                                w0,
                                w1,
                                ctx.plan.mode,
                                ctx.skip,
                                ctx.kernel,
                                ctx.stats,
                                dst,
                            )
                        },
                    );
                }
                PlanOp::Pool(spec) => {
                    windowed_stage(
                        &mut rings,
                        &owner,
                        i,
                        x,
                        b,
                        zeros,
                        out_plane,
                        d,
                        w1,
                        |src, dst| pool_rows(*spec, src, d, w0, w1, dst),
                    );
                }
                PlanOp::ReluRequant { frac_bits } => {
                    // Mutate the freshly produced rows of the owner's
                    // storage in place — retained halo rows were
                    // activated in earlier steps and must not be
                    // re-requantized.
                    let o = owner[i];
                    let mut tally = ActTally::default();
                    if o == sink {
                        for cc in 0..d.in_c {
                            for y in w0..w1 {
                                let s = (cc * d.in_h + y) * d.in_w;
                                for v in &mut out_plane[s..s + d.in_w] {
                                    *v = requantize(*v, *frac_bits).max(0);
                                }
                                if ctx.skip {
                                    // The sink plane materializes — its
                                    // masks come from the next segment's
                                    // ZeroMap scan; only tally here.
                                    tally.seal_row(&out_plane[s..s + d.in_w]);
                                }
                            }
                        }
                    } else {
                        let r = rings[o].as_mut().expect("producer ring");
                        for cc in 0..d.in_c {
                            for y in w0..w1 {
                                for v in r.row_mut(cc, y) {
                                    *v = requantize(*v, *frac_bits).max(0);
                                }
                                if ctx.skip {
                                    let zero = tally.seal_row(r.row(cc, y));
                                    r.seal_zero(cc, y, zero);
                                }
                            }
                        }
                    }
                    tally.flush(ctx.stats);
                }
                _ => unreachable!("run_fused validated the stage ops"),
            }
        }
        // Slide: drop rows no remaining reader window needs.
        for i in 0..m {
            if let Some(r) = rings[i].as_mut() {
                r.retire_below(flow.floor[i]);
            }
        }
        if done {
            break;
        }
    }
    for r in rings.iter().flatten() {
        ctx.free(r.bytes());
    }
    Ok(())
}

// ------------------------------------------------------------ pipelined walk
//
// PR 5's streaming walk still materializes every fused segment's full
// output map before the next segment starts, so peak memory tracks the
// largest feature map. The pipelined walk chains the rolling rings
// ACROSS segment boundaries: a pool's emitted rows feed the next
// conv's input ring directly, branch arms consume one upstream ring
// and write disjoint channel blocks of one concat ring, and only the
// trunk output — the map the GAP/flatten/FC tail consumes — ever
// materializes. Peak memory is input + Σ ring working sets + trunk
// output: flat in network depth (DESIGN.md §Whole-network streaming).

/// Number of leading schedule segments the pipelined walk can chain:
/// fused chains opening with a windowed (Conv/Pool) stage, and
/// branches whose every arm is a non-empty list of such chains.
/// `GlobalAvgPool`/`Flatten`/`Fc` end the prefix — they run as the
/// tail over the materialized trunk output.
fn pipeable_prefix(segs: &[Segment]) -> usize {
    fn fused_ok(fs: &[FusedStage]) -> bool {
        fs.first().is_some_and(|s| !is_elementwise(&s.op))
    }
    let mut k = 0;
    for seg in segs {
        let ok = match seg {
            Segment::Fused(fs) => fused_ok(fs),
            Segment::Branch(arms) => arms.iter().all(|arm| {
                !arm.is_empty()
                    && arm
                        .iter()
                        .all(|s| matches!(s, Segment::Fused(fs) if fused_ok(fs)))
            }),
            _ => false,
        };
        if !ok {
            break;
        }
        k += 1;
    }
    k
}

/// One windowed stage (Conv or Pool) of the whole-network pipeline,
/// with its fused activation and ring endpoints resolved.
struct PipeStage {
    /// `PlanOp::Conv { .. }` or `PlanOp::Pool(..)` only — elementwise
    /// ops fold into `relu`, nothing else survives `pipeable_prefix`.
    op: PlanOp,
    contract: RowContract,
    d: StageDims,
    /// Fused `ReluRequant` applied to this stage's freshly produced
    /// rows (its own channel block only).
    relu: Option<u32>,
    /// Ring the stage reads; ring 0 is the input tensor.
    src: usize,
    /// Ring the stage writes.
    dst: usize,
    /// Channel offset inside `dst` — branch arms share one concat
    /// ring, each writing its own channel block.
    dst_c0: usize,
}

/// One inter-stage ring of the pipeline DAG. Ring 0 is the input
/// tensor (read in place, never copied); the sink ring (no consumers)
/// is backed by the trunk-output plane. Concat rings have one producer
/// per branch arm.
struct PipeRing {
    c: usize,
    h: usize,
    w: usize,
    producers: Vec<usize>,
    consumers: Vec<usize>,
    /// Exact rolling capacity from the lock-step pre-pass; 0 for the
    /// plane-backed input and sink rings.
    cap: usize,
}

/// The whole-network pipeline over a pipeable schedule prefix. Stages
/// are in topological order (build order guarantees every ring's
/// producers were pushed before its first consumer), so one in-order
/// sweep per advance step settles the whole DAG.
struct PipePlan {
    stages: Vec<PipeStage>,
    rings: Vec<PipeRing>,
    /// The trunk-output ring (plane-backed, no consumers).
    sink: usize,
}

/// Incremental [`PipePlan`] builder: appends fused chains and branch
/// fan-outs, wiring producer/consumer edges as it goes.
struct PipeBuilder<'p> {
    plan: &'p CompiledNetwork,
    stages: Vec<PipeStage>,
    rings: Vec<PipeRing>,
}

impl PipeBuilder<'_> {
    fn new_ring(&mut self, c: usize, h: usize, w: usize) -> usize {
        self.rings.push(PipeRing {
            c,
            h,
            w,
            producers: Vec::new(),
            consumers: Vec::new(),
            cap: 0,
        });
        self.rings.len() - 1
    }

    /// Append one fused chain reading ring `src`. Elementwise stages
    /// fold into the preceding windowed stage's `relu`; each windowed
    /// stage owns a fresh ring except the chain's last, which writes
    /// `into` (a concat ring at a channel offset) when given. Returns
    /// the ring the chain ends in.
    fn chain(
        &mut self,
        fs: &[FusedStage],
        src: usize,
        into: Option<(usize, usize)>,
    ) -> crate::Result<usize> {
        let (c, h, w) = {
            let r = &self.rings[src];
            (r.c, r.h, r.w)
        };
        let dims = resolve_stage_dims(self.plan, fs, c, h, w)?;
        let windowed: Vec<usize> = (0..fs.len())
            .filter(|&i| !is_elementwise(&fs[i].op))
            .collect();
        if windowed.first() != Some(&0) {
            return Err(crate::Error::Config(
                "pipelined chain must open with a windowed stage".into(),
            ));
        }
        let mut cur = src;
        for (wi, &i) in windowed.iter().enumerate() {
            let d = dims[i];
            let last = wi + 1 == windowed.len();
            let (dst, dst_c0) = match (last, into) {
                (true, Some((ring, c0))) => (ring, c0),
                _ => (self.new_ring(d.out_c, d.out_h, d.out_w), 0),
            };
            let relu = fs[i + 1..]
                .iter()
                .take_while(|s| is_elementwise(&s.op))
                .find_map(|s| match &s.op {
                    PlanOp::ReluRequant { frac_bits } => Some(*frac_bits),
                    _ => None,
                });
            let id = self.stages.len();
            self.stages.push(PipeStage {
                op: fs[i].op.clone(),
                contract: fs[i].contract,
                d,
                relu,
                src: cur,
                dst,
                dst_c0,
            });
            self.rings[cur].consumers.push(id);
            self.rings[dst].producers.push(id);
            cur = dst;
        }
        Ok(cur)
    }
}

/// Build the whole-network pipeline for a pipeable schedule prefix at
/// the given input extent and advance step, including the exact ring
/// capacities from the lock-step pre-pass.
fn build_pipeline(
    plan: &CompiledNetwork,
    segs: &[Segment],
    c0: usize,
    h0: usize,
    w0: usize,
    step: usize,
) -> crate::Result<PipePlan> {
    let mut b = PipeBuilder { plan, stages: Vec::new(), rings: Vec::new() };
    b.new_ring(c0, h0, w0); // ring 0: the input tensor, read in place
    let mut cur = 0usize;
    for seg in segs {
        match seg {
            Segment::Fused(fs) => cur = b.chain(fs, cur, None)?,
            Segment::Branch(arms) => {
                // Resolve every arm's output extent first to size the
                // concat ring, then append each arm's chains ending in
                // it at the arm's channel offset.
                let src = cur;
                let mut arm_out: Vec<(usize, usize, usize)> = Vec::with_capacity(arms.len());
                for arm in arms {
                    let (mut c, mut h, mut w) = {
                        let r = &b.rings[src];
                        (r.c, r.h, r.w)
                    };
                    for s in arm {
                        let Segment::Fused(fs) = s else {
                            return Err(crate::Error::Config(
                                "pipelined branch arm holds a non-fused segment".into(),
                            ));
                        };
                        let dims = resolve_stage_dims(plan, fs, c, h, w)?;
                        let last = dims.last().expect("fused segments are non-empty");
                        (c, h, w) = (last.out_c, last.out_h, last.out_w);
                    }
                    arm_out.push((c, h, w));
                }
                let (_, oh, ow) = arm_out[0];
                if arm_out.iter().any(|&(_, h, w)| (h, w) != (oh, ow)) {
                    return Err(crate::Error::Shape(
                        "branch arms disagree on output extent".into(),
                    ));
                }
                let total_c: usize = arm_out.iter().map(|&(c, _, _)| c).sum();
                let concat = b.new_ring(total_c, oh, ow);
                let mut c_off = 0usize;
                for (arm, &(ac, _, _)) in arms.iter().zip(&arm_out) {
                    let mut acur = src;
                    for (si, s) in arm.iter().enumerate() {
                        let Segment::Fused(fs) = s else { unreachable!("validated above") };
                        let into = (si + 1 == arm.len()).then_some((concat, c_off));
                        acur = b.chain(fs, acur, into)?;
                    }
                    debug_assert_eq!(acur, concat, "arm must end in the concat ring");
                    c_off += ac;
                }
                cur = concat;
            }
            other => {
                return Err(crate::Error::Config(format!(
                    "non-pipeable segment {other:?} inside the pipelined prefix"
                )))
            }
        }
    }
    let sink = cur;
    let mut pp = PipePlan { stages: b.stages, rings: b.rings, sink };

    // Exact ring capacities: run the identical lock-step advance the
    // compute pass runs, recording each ring's MAX producer watermark
    // minus the retention floor before the step. The max watermark
    // (not the min the consumers see) is what bounds live slots: a
    // fast concat arm writes rows beyond the ring's min-producer
    // watermark, and those rows must not alias retained ones modulo
    // the capacity.
    let mut caps = vec![0usize; pp.rings.len()];
    let mut floor_before = vec![0usize; pp.rings.len()];
    let mut flow = PipeFlow::new(&pp);
    let mut writes = vec![(0usize, 0usize); pp.stages.len()];
    let max_iters = h0.div_ceil(step.max(1)) + pp.stages.len() + 2;
    for _ in 0..max_iters {
        floor_before.copy_from_slice(&flow.floor);
        let done = flow.advance(&pp, step, &mut writes);
        for (r, ring) in pp.rings.iter().enumerate() {
            if r == 0 || ring.consumers.is_empty() {
                continue; // plane-backed: input tensor / trunk output
            }
            caps[r] = caps[r].max(flow.ring_max[r] - floor_before[r]);
        }
        if done {
            for (ring, cap) in pp.rings.iter_mut().zip(caps) {
                ring.cap = cap;
            }
            return Ok(pp);
        }
    }
    Err(crate::Error::Config(
        "pipeline capacity pre-pass failed to converge".into(),
    ))
}

/// Lock-step advance state of the whole-network pipeline, shared — in
/// identical arithmetic — by the capacity pre-pass and the per-image
/// compute pass (the cross-segment analogue of [`FlowState`]).
struct PipeFlow {
    /// Output rows produced so far, per stage.
    produced: Vec<usize>,
    /// Per ring: min over its producers' `produced` — the watermark
    /// consumers may read (every channel block holds these rows).
    ring_prod: Vec<usize>,
    /// Per ring: max over its producers' `produced` — the write
    /// watermark that bounds live slots (capacity pre-pass).
    ring_max: Vec<usize>,
    /// Per ring: retention floor — rows below are dead (no remaining
    /// consumer window reaches them).
    floor: Vec<usize>,
    /// Input rows fed to ring 0.
    fed: usize,
}

impl PipeFlow {
    fn new(pp: &PipePlan) -> Self {
        Self {
            produced: vec![0; pp.stages.len()],
            ring_prod: vec![0; pp.rings.len()],
            ring_max: vec![0; pp.rings.len()],
            floor: vec![0; pp.rings.len()],
            fed: 0,
        }
    }

    /// Feed up to `step` more input rows and sweep the stages in topo
    /// order, chaining every `rows_ready → rows_emitted` advance
    /// through the ring watermarks; `writes[i]` receives the new
    /// output rows `[w0, w1)` stage i computes this step. Floors rise
    /// to the lowest row any remaining consumer window needs. Returns
    /// true once every stage has fully produced.
    fn advance(&mut self, pp: &PipePlan, step: usize, writes: &mut [(usize, usize)]) -> bool {
        let h0 = pp.rings[0].h;
        self.fed = (self.fed + step.max(1)).min(h0);
        self.ring_prod[0] = self.fed;
        self.ring_max[0] = self.fed;
        for (i, st) in pp.stages.iter().enumerate() {
            let avail = self.ring_prod[st.src];
            let e = st
                .contract
                .rows_emitted(avail, st.d.in_h, st.d.out_h)
                .max(self.produced[i]);
            writes[i] = (self.produced[i], e);
            self.produced[i] = e;
            let (mut mn, mut mx) = (usize::MAX, 0usize);
            for &p in &pp.rings[st.dst].producers {
                mn = mn.min(self.produced[p]);
                mx = mx.max(self.produced[p]);
            }
            self.ring_prod[st.dst] = mn;
            self.ring_max[st.dst] = mx;
        }
        for (r, ring) in pp.rings.iter().enumerate() {
            if r == 0 || ring.consumers.is_empty() {
                continue;
            }
            let mut lo = self.ring_prod[r];
            for &ci in &ring.consumers {
                let c = &pp.stages[ci];
                let need = if self.produced[ci] >= c.d.out_h {
                    self.ring_prod[r] // finished consumer frees the ring
                } else {
                    (self.produced[ci] * c.contract.stride).saturating_sub(c.contract.pad)
                };
                lo = lo.min(need);
            }
            self.floor[r] = self.floor[r].max(lo);
        }
        self.produced
            .iter()
            .zip(&pp.stages)
            .all(|(&p, st)| p >= st.d.out_h)
    }
}

/// Geometry profile of the whole-network pipeline a plan would run
/// under [`Walk::Pipelined`]: how many schedule segments chain, the
/// rolling-ring working set, the trunk-output bytes, and the fill
/// depth. Produced by [`CompiledNetwork::pipeline_summary`]; feeds the
/// pipelined peak estimate and the bench/report surfaces.
#[derive(Debug, Clone, Copy)]
pub struct PipelineSummary {
    /// Leading schedule segments chained into the pipeline.
    pub segments: usize,
    /// Σ intermediate ring bytes of ONE pipeline instance (one image
    /// in flight) at the chosen advance step.
    pub ring_bytes: u64,
    /// Bytes of the materialized trunk output, per image.
    pub out_bytes: u64,
    /// Input rows that must arrive before the first trunk-output row
    /// emerges — the pipeline's fill depth. Exact (from the lock-step
    /// flow at 1-row feeds); the composed `RowContract` kernel height
    /// bounds it from above.
    pub fill_rows: usize,
}

/// Compute the [`PipelineSummary`] for a plan at the given input
/// extent and advance step (`step == 0` feeds the whole image at
/// once). `Ok(None)` when fewer than two schedule segments are
/// pipeable — whole-network streaming degenerates to the per-segment
/// streaming walk there.
pub(crate) fn pipeline_summary(
    plan: &CompiledNetwork,
    c0: usize,
    h0: usize,
    w0: usize,
    step: usize,
) -> crate::Result<Option<PipelineSummary>> {
    let prefix = pipeable_prefix(&plan.schedule);
    if prefix < 2 {
        return Ok(None);
    }
    let step = if step == 0 { h0 } else { step };
    let pp = build_pipeline(plan, &plan.schedule[..prefix], c0, h0, w0, step)?;
    let ring_bytes: u64 = pp
        .rings
        .iter()
        .enumerate()
        .filter(|&(r, ring)| r != 0 && !ring.consumers.is_empty())
        .map(|(_, ring)| (ring.c * ring.cap * ring.w * std::mem::size_of::<i32>()) as u64)
        .sum();
    let sink = &pp.rings[pp.sink];
    let out_bytes = (sink.c * sink.h * sink.w * std::mem::size_of::<i32>()) as u64;
    // Fill depth: lock-step at 1-row feeds until the sink first emits.
    let mut flow = PipeFlow::new(&pp);
    let mut writes = vec![(0usize, 0usize); pp.stages.len()];
    let mut fill_rows = h0;
    for _ in 0..(h0 + pp.stages.len() + 2) {
        let done = flow.advance(&pp, 1, &mut writes);
        if flow.ring_prod[pp.sink] > 0 || done {
            fill_rows = flow.fed;
            break;
        }
    }
    Ok(Some(PipelineSummary { segments: prefix, ring_bytes, out_bytes, fill_rows }))
}

/// Whole-network streaming: run the pipeable schedule prefix as ONE
/// producer/consumer pipeline per image — rings chained across segment
/// boundaries, branch arms fanning out from one upstream ring into one
/// concat ring — materializing only the trunk output, then walk the
/// tail (GAP → flatten → FC) over it. Images stripe across the worker
/// budget exactly like the streaming walk; `halo_recompute_rows` stays
/// 0 end to end by construction (rings retain, never recompute).
fn run_pipelined(
    ctx: &Ctx,
    segs: &[Segment],
    input: Tensor<i32>,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    let (n, c0, h0, w0) = match *input.shape() {
        [n, c, h, w] => (n, c, h, w),
        _ => return run_segments(ctx, segs, input, workers),
    };
    let prefix = pipeable_prefix(segs);
    if prefix < 2 {
        // Nothing to chain across — fall back to the per-segment walk
        // (run_fused maps the pipelined walk onto streaming).
        return run_segments(ctx, segs, input, workers);
    }
    let step = if ctx.tile_rows == 0 { h0 } else { ctx.tile_rows.max(1) };
    let pp = build_pipeline(ctx.plan, &segs[..prefix], c0, h0, w0, step)?;
    // Ring 0 is the input tensor read in place; scan it once so stage
    // 0's convs get row masks like every ring-fed stage downstream.
    let zeros = if ctx.skip { ZeroMap::scan(&input) } else { None };
    let (oc, oh, ow) = {
        let sink = &pp.rings[pp.sink];
        (sink.c, sink.h, sink.w)
    };
    let mut out: Tensor<i32> = Tensor::zeros(&[n, oc, oh, ow]);
    ctx.alloc(tensor_bytes(&out));
    let plane = oc * oh * ow;
    let threads = workers.clamp(1, n.max(1));
    let results: Vec<crate::Result<()>> = if threads <= 1 {
        out.data_mut()
            .chunks_mut(plane.max(1))
            .enumerate()
            .map(|(b, p)| pipeline_image(ctx, &pp, &input, zeros.as_ref(), b, p, step))
            .collect()
    } else {
        // Stripe images across scoped threads; each thread owns its
        // images' disjoint output planes (same discipline as
        // run_fused_streaming).
        type ImagePlane<'p> = (usize, &'p mut [i32]);
        let mut groups: Vec<Vec<ImagePlane>> = (0..threads).map(|_| Vec::new()).collect();
        for (b, p) in out.data_mut().chunks_mut(plane.max(1)).enumerate() {
            groups[b % threads].push((b, p));
        }
        let mut res: Vec<crate::Result<()>> = Vec::with_capacity(n);
        std::thread::scope(|s| {
            let pp = &pp;
            let input = &input;
            let zeros = zeros.as_ref();
            let handles: Vec<_> = groups
                .into_iter()
                .map(|group| {
                    s.spawn(move || {
                        group
                            .into_iter()
                            .map(|(b, p)| pipeline_image(ctx, pp, input, zeros, b, p, step))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            for h in handles {
                res.extend(h.join().expect("pipeline worker panicked"));
            }
        });
        res
    };
    for r in results {
        r?;
    }
    // The input retires once the whole trunk has streamed; the tail
    // then walks the remaining segments over the trunk output.
    ctx.free(tensor_bytes(&input));
    drop(input);
    run_segments(ctx, &segs[prefix..], out, workers)
}

/// Stream one image through the whole-network pipeline: every ring
/// slides down its stage's map in lock-step with [`PipeFlow`], halo
/// rows retained across steps (never recomputed), sink stages writing
/// the trunk-output plane directly at their concat channel offsets.
#[allow(clippy::too_many_arguments)]
fn pipeline_image(
    ctx: &Ctx,
    pp: &PipePlan,
    x: &Tensor<i32>,
    zeros: Option<&ZeroMap>,
    b: usize,
    out_plane: &mut [i32],
    step: usize,
) -> crate::Result<()> {
    let mut rings: Vec<Option<RingBuf>> = pp
        .rings
        .iter()
        .enumerate()
        .map(|(r, ring)| {
            (r != 0 && !ring.consumers.is_empty())
                .then(|| RingBuf::with_capacity(ring.c, ring.cap.max(1), ring.w))
        })
        .collect();
    for r in rings.iter().flatten() {
        ctx.alloc(r.bytes());
    }

    let (sink_h, sink_w) = {
        let s = &pp.rings[pp.sink];
        (s.h, s.w)
    };
    let mut flow = PipeFlow::new(pp);
    let mut writes = vec![(0usize, 0usize); pp.stages.len()];
    let max_iters = pp.rings[0].h.div_ceil(step.max(1)) + pp.stages.len() + 2;
    let mut converged = false;
    for _ in 0..max_iters {
        let done = flow.advance(pp, step, &mut writes);
        for (i, st) in pp.stages.iter().enumerate() {
            let (w0, w1) = writes[i];
            if w0 >= w1 {
                continue;
            }
            let d = &st.d;
            // A stage never writes the ring it reads, so taking the
            // destination out leaves the source borrowable.
            let mut dst = rings[st.dst].take();
            {
                let src = if st.src == 0 {
                    RowSrc::Tensor { x, b, zeros }
                } else {
                    RowSrc::Ring(rings[st.src].as_ref().expect("upstream ring"))
                };
                let mut target = match &mut dst {
                    Some(r) => {
                        r.grow_to(w1);
                        RowTarget::RingAt { ring: r, c0: st.dst_c0 }
                    }
                    None => RowTarget::Plane {
                        data: &mut out_plane[st.dst_c0 * sink_h * sink_w..],
                        h: sink_h,
                        w: sink_w,
                    },
                };
                match &st.op {
                    PlanOp::Conv { layer, pad, stride } => conv_rows(
                        &ctx.plan.convs[*layer],
                        &src,
                        d,
                        *pad,
                        *stride,
                        w0,
                        w1,
                        ctx.plan.mode,
                        ctx.skip,
                        ctx.kernel,
                        ctx.stats,
                        &mut target,
                    ),
                    PlanOp::Pool(spec) => pool_rows(*spec, &src, d, w0, w1, &mut target),
                    _ => unreachable!("build_pipeline only emits windowed stages"),
                }
            }
            rings[st.dst] = dst;
            // Fused activation on the freshly produced rows of this
            // stage's own channel block — retained halo rows were
            // activated in earlier steps and must not be
            // re-requantized.
            if let Some(frac) = st.relu {
                let mut tally = ActTally::default();
                match rings[st.dst].as_mut() {
                    Some(r) => {
                        for cc in 0..d.out_c {
                            for y in w0..w1 {
                                for v in r.row_mut(st.dst_c0 + cc, y) {
                                    *v = requantize(*v, frac).max(0);
                                }
                                if ctx.skip {
                                    let zero = tally.seal_row(r.row(st.dst_c0 + cc, y));
                                    r.seal_zero(st.dst_c0 + cc, y, zero);
                                }
                            }
                        }
                    }
                    None => {
                        for cc in 0..d.out_c {
                            for y in w0..w1 {
                                let s = ((st.dst_c0 + cc) * sink_h + y) * sink_w;
                                for v in &mut out_plane[s..s + sink_w] {
                                    *v = requantize(*v, frac).max(0);
                                }
                                if ctx.skip {
                                    // The sink plane materializes; its
                                    // masks come from the tail segment's
                                    // ZeroMap scan. Tally only.
                                    tally.seal_row(&out_plane[s..s + sink_w]);
                                }
                            }
                        }
                    }
                }
                tally.flush(ctx.stats);
            } else if ctx.skip {
                // No fused activation (pool stages, mostly): still seal
                // the freshly produced ring rows — a pool window over
                // all-zero post-ReLU rows emits zero, and the scan
                // grounds every mask in the actual row contents, so
                // masks survive pool stages and concat channel blocks
                // by construction. No tally: these values derive from
                // already-tallied activations.
                if let Some(r) = rings[st.dst].as_mut() {
                    for cc in 0..d.out_c {
                        for y in w0..w1 {
                            let zero = r.row(st.dst_c0 + cc, y).iter().all(|&v| v == 0);
                            r.seal_zero(st.dst_c0 + cc, y, zero);
                        }
                    }
                }
            }
        }
        // Slide: drop rows no remaining consumer window needs.
        for (r, ring) in rings.iter_mut().enumerate() {
            if let Some(ring) = ring.as_mut() {
                ring.retire_below(flow.floor[r]);
            }
        }
        if done {
            converged = true;
            break;
        }
    }
    for r in rings.iter().flatten() {
        ctx.free(r.bytes());
    }
    if converged {
        Ok(())
    } else {
        Err(crate::Error::Config(
            "pipeline compute pass failed to converge".into(),
        ))
    }
}

// ------------------------------------------------------------- row storage

/// Rows `[y0, y1)` of one image's (C, rows, W) feature map, stored
/// modulo `cap` — the rolling ring of the streaming walk. With
/// `cap == y1 − y0` it degenerates to the tiled walk's span buffer
/// (global row coordinates, no wraparound in practice). Capacity is
/// exact by construction (`y1 − y0 ≤ cap` always), so a retained row
/// is never overwritten before its last reader: two live rows cannot
/// collide modulo `cap`.
struct RingBuf {
    c: usize,
    w: usize,
    cap: usize,
    /// Retention floor: rows below are dead.
    y0: usize,
    /// Produced watermark: rows `[y0, y1)` are live.
    y1: usize,
    data: Vec<i32>,
    /// Row-level zero masks for the activation-skipping lane, one slot
    /// per (channel, row-mod-cap) like `data`. Slot value `y + 1`
    /// means "row y of this channel was sealed all-zero"; anything
    /// else means "not known zero". Tagging by row id instead of a
    /// bare bool makes wraparound self-invalidating: when row
    /// `y + cap` reuses the slot, its stale tag no longer matches, so
    /// masks never need clearing as the ring slides. A missed or stale
    /// mask only disables a skip — never a correctness input.
    zrow: Vec<usize>,
}

impl RingBuf {
    /// Empty rolling ring holding at most `cap` rows at once.
    fn with_capacity(c: usize, cap: usize, w: usize) -> Self {
        debug_assert!(cap > 0);
        Self { c, w, cap, y0: 0, y1: 0, data: vec![0; c * cap * w], zrow: vec![0; c * cap] }
    }

    /// Fully live span `[y0, y1)` (the tiled walk's buffer shape).
    fn span(c: usize, y0: usize, y1: usize, w: usize) -> Self {
        debug_assert!(y1 > y0, "empty span ring");
        Self {
            c,
            w,
            cap: y1 - y0,
            y0,
            y1,
            data: vec![0; c * (y1 - y0) * w],
            zrow: vec![0; c * (y1 - y0)],
        }
    }

    /// Record whether row `y` of channel `c` is all-zero (sealed at
    /// the activation points once the row's values are final).
    #[inline]
    fn seal_zero(&mut self, c: usize, y: usize, zero: bool) {
        self.zrow[c * self.cap + y % self.cap] = if zero { y + 1 } else { 0 };
    }

    /// Whether row `y` of channel `c` was sealed all-zero. `false`
    /// means unknown — skipping is an optimization, so conservative
    /// answers are always safe.
    #[inline]
    fn row_zero(&self, c: usize, y: usize) -> bool {
        self.zrow[c * self.cap + y % self.cap] == y + 1
    }

    #[inline]
    fn slot(&self, c: usize, y: usize) -> usize {
        (c * self.cap + y % self.cap) * self.w
    }

    #[inline]
    fn get(&self, c: usize, y: usize, x: usize) -> i32 {
        debug_assert!(
            y >= self.y0 && y < self.y1,
            "row {y} outside ring [{}, {})",
            self.y0,
            self.y1
        );
        self.data[self.slot(c, y) + x]
    }

    #[inline]
    fn put(&mut self, c: usize, y: usize, x: usize, v: i32) {
        debug_assert!(
            y >= self.y0 && y < self.y0 + self.cap,
            "row {y} outside ring window [{}, {})",
            self.y0,
            self.y0 + self.cap
        );
        let i = self.slot(c, y) + x;
        self.data[i] = v;
    }

    #[inline]
    fn row(&self, c: usize, y: usize) -> &[i32] {
        debug_assert!(y >= self.y0 && y < self.y1);
        let i = self.slot(c, y);
        &self.data[i..i + self.w]
    }

    #[inline]
    fn row_mut(&mut self, c: usize, y: usize) -> &mut [i32] {
        debug_assert!(y >= self.y0 && y < self.y0 + self.cap);
        let i = self.slot(c, y);
        &mut self.data[i..i + self.w]
    }

    /// Raise the produced watermark (rows about to be written). The
    /// watermark is monotone (max), not strictly increasing per call:
    /// a concat ring's producers advance at different rates, so a slow
    /// arm may grow to a watermark a fast arm already passed.
    fn grow_to(&mut self, y1: usize) {
        let y1 = self.y1.max(y1);
        debug_assert!(
            y1 - self.y0 <= self.cap,
            "grow to {y1} overflows ring [{}, +{}]",
            self.y0,
            self.cap
        );
        self.y1 = y1;
    }

    /// Raise the retention floor (halo rows below are dead).
    fn retire_below(&mut self, y0: usize) {
        debug_assert!(y0 >= self.y0 && y0 <= self.y1);
        self.y0 = y0;
    }

    fn bytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<i32>()) as u64
    }
}

/// Per-(image, channel, row) all-zero flags for a materialized
/// feature map, scanned once per fused-segment input when activation
/// skipping is on. Inter-segment maps are post-ReLU (or pools of
/// post-ReLU rows), so whole zero rows are common; one linear scan
/// here gives stage-0 convs the same O(channels × kernel rows)
/// row-band check the rings hand every downstream stage.
struct ZeroMap {
    c: usize,
    h: usize,
    zero: Vec<bool>,
}

impl ZeroMap {
    /// Scan a 4-D NCHW map; `None` for other ranks (a flattened
    /// classifier tail never feeds a conv stage).
    fn scan(x: &Tensor<i32>) -> Option<ZeroMap> {
        let [n, c, h, w] = match *x.shape() {
            [n, c, h, w] => [n, c, h, w],
            _ => return None,
        };
        let mut zero = vec![false; n * c * h];
        for (row, flag) in x.data().chunks(w.max(1)).zip(zero.iter_mut()) {
            *flag = row.iter().all(|&v| v == 0);
        }
        Some(ZeroMap { c, h, zero })
    }

    #[inline]
    fn row_zero(&self, b: usize, c: usize, y: usize) -> bool {
        self.zero[(b * self.c + c) * self.h + y]
    }
}

/// Where a stage reads its input rows: stage 0 reads straight from
/// the (already materialized) input tensor — no seed copy — and later
/// stages read the previous stage's ring.
enum RowSrc<'a> {
    Tensor { x: &'a Tensor<i32>, b: usize, zeros: Option<&'a ZeroMap> },
    Ring(&'a RingBuf),
}

impl RowSrc<'_> {
    #[inline]
    fn get(&self, c: usize, y: usize, xx: usize) -> i32 {
        match self {
            RowSrc::Tensor { x, b, .. } => x.get4(*b, c, y, xx),
            RowSrc::Ring(r) => r.get(c, y, xx),
        }
    }

    /// Whether input row `y` of channel `c` is known all-zero (ring
    /// seal or tensor scan). `false` means unknown, which only costs a
    /// missed skip.
    #[inline]
    fn row_zero(&self, c: usize, y: usize) -> bool {
        match self {
            RowSrc::Tensor { b, zeros, .. } => zeros.is_some_and(|z| z.row_zero(*b, c, y)),
            RowSrc::Ring(r) => r.row_zero(c, y),
        }
    }

    /// The first `w` values of input row `(c, y)` as one contiguous
    /// slice — the decoded kernel's row-band gather hoists these once
    /// per output row instead of calling [`RowSrc::get`] per tap.
    #[inline]
    fn row(&self, c: usize, y: usize, w: usize) -> &[i32] {
        match self {
            RowSrc::Tensor { x, b, .. } => {
                let i = x.idx4(*b, c, y, 0);
                &x.data()[i..i + w]
            }
            RowSrc::Ring(r) => &r.row(c, y)[..w],
        }
    }
}

fn row_src<'a>(
    buf: &'a Option<RingBuf>,
    x: &'a Tensor<i32>,
    b: usize,
    zeros: Option<&'a ZeroMap>,
) -> RowSrc<'a> {
    match buf {
        Some(r) => RowSrc::Ring(r),
        None => RowSrc::Tensor { x, b, zeros },
    }
}

/// Where a stage writes its output rows: a ring (tiled span or
/// streaming rolling ring) or a full (C, H, W) plane — the streaming
/// sink writes the output tensor's image slice directly, so no
/// per-tile staging buffer ever exists.
enum RowTarget<'a> {
    Ring(&'a mut RingBuf),
    /// Ring write at a channel offset: branch arms of the pipelined
    /// walk share one concat ring, each writing its own channel block.
    RingAt { ring: &'a mut RingBuf, c0: usize },
    Plane { data: &'a mut [i32], h: usize, w: usize },
}

impl RowTarget<'_> {
    #[inline]
    fn put(&mut self, c: usize, y: usize, x: usize, v: i32) {
        match self {
            RowTarget::Ring(r) => r.put(c, y, x, v),
            RowTarget::RingAt { ring, c0 } => ring.put(*c0 + c, y, x, v),
            RowTarget::Plane { data, h, w } => data[(c * *h + y) * *w + x] = v,
        }
    }
}

// ------------------------------------------------------------------ kernels

/// Output-pixel strip width of the decoded kernel's register blocking
/// (the `P` of DESIGN.md §Decoded-lane kernel): each decoded entry is
/// read once and accumulated into `P` segment-register banks, SCNN
/// style, before one rear-adder drain per pixel.
const DECODE_BLOCK: usize = 4;

/// Per-call counters one conv kernel invocation produced, flushed to
/// the shared [`AllocStats`] atomics once by the [`conv_rows`]
/// dispatcher.
#[derive(Default)]
struct ConvTally {
    skipped_rows: u64,
    skipped_windows: u64,
    slot_decodes: u64,
    segment_adds: u64,
}

/// Integer conv over pre-kneaded filter lanes, producing output rows
/// `[o0, o1)` from its source (input tensor in place, or a ring) into
/// its target. Dispatches to the decoded-lane fast path or the legacy
/// per-pixel splitter walk ([`Kernel`]); both produce identical
/// arithmetic to the scalar references — same (c, ky, kx) gather
/// order, same group windows, same `i64 → i32` cast — and identical
/// skip/energy counters.
#[allow(clippy::too_many_arguments)]
fn conv_rows(
    conv: &CompiledConv,
    input: &RowSrc,
    d: &StageDims,
    pad: usize,
    stride: usize,
    o0: usize,
    o1: usize,
    mode: crate::config::Mode,
    skip: bool,
    kernel: Kernel,
    stats: Option<&AllocStats>,
    out: &mut RowTarget,
) {
    let tally = match kernel {
        Kernel::Decoded => conv_rows_decoded(conv, input, d, pad, stride, o0, o1, mode, skip, out),
        Kernel::Legacy => conv_rows_legacy(conv, input, d, pad, stride, o0, o1, mode, skip, out),
    };
    if let Some(s) = stats {
        s.total_windows.fetch_add(((o1 - o0) * d.out_w) as u64, Ordering::Relaxed);
        if tally.skipped_windows > 0 {
            s.skipped_windows.fetch_add(tally.skipped_windows, Ordering::Relaxed);
            s.skipped_rows.fetch_add(tally.skipped_rows, Ordering::Relaxed);
        }
        if tally.slot_decodes > 0 {
            s.slot_decodes.fetch_add(tally.slot_decodes, Ordering::Relaxed);
        }
        if tally.segment_adds > 0 {
            s.segment_adds.fetch_add(tally.segment_adds, Ordering::Relaxed);
        }
    }
}

/// The original per-pixel walk, kept verbatim as the bit-exact
/// reference the decoded path is swept against: gather one im2col
/// window, then re-decode every kneaded weight's slots per filter.
#[allow(clippy::too_many_arguments)]
fn conv_rows_legacy(
    conv: &CompiledConv,
    input: &RowSrc,
    d: &StageDims,
    pad: usize,
    stride: usize,
    o0: usize,
    o1: usize,
    mode: crate::config::Mode,
    skip: bool,
    out: &mut RowTarget,
) -> ConvTally {
    let (kh, kw) = (conv.kh, conv.kw);
    let lane_len = conv.lane_len();
    let ow = d.out_w;
    let nf = conv.lanes.len();
    // The row-band an output row reads, clipped to the input — the
    // same contract the tile/streaming walks size halos with.
    let band = RowContract { k: kh, stride, pad };
    let mut acts = vec![0i32; lane_len];
    let mut segs = SegmentRegisters::new(mode.weight_bits());
    let mut tally = ConvTally::default();
    for oy in o0..o1 {
        // Row-level skip: if every in-bounds input row under this
        // output row carries an all-zero mask, every window in the row
        // is all-zero (the out-of-band taps are padding). Write the
        // zeros SAC would have produced and move on. Bit-exact by
        // construction: convs have no bias, `split_kneaded` over an
        // all-zero window leaves every segment register 0, and
        // `rear_adder_tree` of zeros is 0 for every filter. The writes
        // are required — ring slots may hold stale wrapped-around rows.
        if skip {
            let (iy0, iy1) = band.in_band(oy, d.in_h);
            if (iy0..iy1).all(|iy| (0..d.in_c).all(|cc| input.row_zero(cc, iy))) {
                for f in 0..nf {
                    for ox in 0..ow {
                        out.put(f, oy, ox, 0);
                    }
                }
                tally.skipped_rows += 1;
                tally.skipped_windows += ow as u64;
                continue;
            }
        }
        for ox in 0..ow {
            // Gather the activation window (im2col row) in OIHW weight
            // order: (c, ky, kx) — once, shared by every filter.
            let mut idx = 0;
            for cc in 0..d.in_c {
                for ky in 0..kh {
                    for kx in 0..kw {
                        let iy = oy * stride + ky;
                        let ix = ox * stride + kx;
                        acts[idx] = if iy < pad
                            || ix < pad
                            || iy - pad >= d.in_h
                            || ix - pad >= d.in_w
                        {
                            0
                        } else {
                            input.get(cc, iy - pad, ix - pad)
                        };
                        idx += 1;
                    }
                }
            }
            // Window-level skip: the gathered window is all-zero even
            // though its rows weren't (sparse bands the row masks
            // can't see). Same bit-exact zero writes, window at a
            // time — the gather already happened, only the per-filter
            // SAC walk (the expensive part) is saved.
            if skip && acts.iter().all(|&a| a == 0) {
                for f in 0..nf {
                    out.put(f, oy, ox, 0);
                }
                tally.skipped_windows += 1;
                continue;
            }
            for (f, klane) in conv.lanes.iter().enumerate() {
                for (g, group) in klane.groups.iter().enumerate() {
                    let start = g * klane.ks;
                    let end = (start + klane.ks).min(lane_len);
                    tally.slot_decodes += split_kneaded(group, &acts[start..end], &mut segs);
                }
                tally.segment_adds += segs.add_count();
                out.put(f, oy, ox, rear_adder_tree(segs.values()) as i32);
                segs.reset();
            }
        }
    }
    tally
}

/// The decoded-lane fast path: executes the compile-time schedule
/// [`CompiledConv::decoded`] over a strip of [`DECODE_BLOCK`] adjacent
/// output pixels, with the per-output-row gather hoisted into
/// row-band slices.
///
/// Bit-exact vs the legacy walk (invariant I5) because per (window,
/// filter) every segment bank receives the identical addend sequence:
/// the schedule was lowered group-ascending, kneaded-weight-in-order,
/// occupied-bit-ascending — exactly the order `split_kneaded` visits —
/// and i64 addition per bank is order-preserved across the strip (each
/// pixel owns its own bank). Skip behaviour is also identical: the
/// same row masks and the same per-pixel window-zero check run before
/// any SAC work, so the skip counters match the legacy kernel's
/// exactly.
#[allow(clippy::too_many_arguments)]
fn conv_rows_decoded(
    conv: &CompiledConv,
    input: &RowSrc,
    d: &StageDims,
    pad: usize,
    stride: usize,
    o0: usize,
    o1: usize,
    mode: crate::config::Mode,
    skip: bool,
    out: &mut RowTarget,
) -> ConvTally {
    let (kh, kw) = (conv.kh, conv.kw);
    let lane_len = conv.lane_len();
    let ow = d.out_w;
    let nf = conv.lanes.len();
    let bits = mode.weight_bits();
    let sched = &conv.decoded;
    let band = RowContract { k: kh, stride, pad };
    // Strip scratch, allocated once per call: DECODE_BLOCK gathered
    // windows side by side and DECODE_BLOCK segment-register banks.
    let mut acts = vec![0i32; DECODE_BLOCK * lane_len];
    let mut banks = vec![0i64; DECODE_BLOCK * bits];
    let mut live = [0usize; DECODE_BLOCK];
    // Row-band slices for the current output row: one per (channel,
    // kernel row) tap, `None` when the tap row is padding. Hoisted out
    // of the per-pixel gather — the interior columns then copy
    // contiguous sub-slices with no bounds branching per tap.
    let mut rows: Vec<Option<&[i32]>> = vec![None; d.in_c * kh];
    // Output columns whose horizontal taps are all in-bounds:
    // `ox * stride >= pad` and `ox * stride + kw - 1 - pad < in_w`.
    // Everything outside is a pad-clipped prologue/epilogue column
    // that takes the per-tap clipped path (identical to legacy).
    let ox_lo = pad.div_ceil(stride.max(1));
    let ox_hi = if d.in_w + pad >= kw { (d.in_w + pad - kw) / stride.max(1) } else { 0 };
    let interior_ok = d.in_w + pad >= kw && ox_lo <= ox_hi;
    let mut tally = ConvTally::default();
    for oy in o0..o1 {
        // Row-level skip — same mask walk and zero writes as legacy.
        if skip {
            let (iy0, iy1) = band.in_band(oy, d.in_h);
            if (iy0..iy1).all(|iy| (0..d.in_c).all(|cc| input.row_zero(cc, iy))) {
                for f in 0..nf {
                    for ox in 0..ow {
                        out.put(f, oy, ox, 0);
                    }
                }
                tally.skipped_rows += 1;
                tally.skipped_windows += ow as u64;
                continue;
            }
        }
        // Hoist this output row's row-band once.
        for cc in 0..d.in_c {
            for ky in 0..kh {
                let iy = oy * stride + ky;
                rows[cc * kh + ky] = if iy < pad || iy - pad >= d.in_h {
                    None
                } else {
                    Some(input.row(cc, iy - pad, d.in_w))
                };
            }
        }
        let mut ox = 0;
        while ox < ow {
            let p = DECODE_BLOCK.min(ow - ox);
            // Gather up to P adjacent windows into the strip buffer,
            // compacting out the ones the window-level skip eliminates
            // (zero writes now, no bank assigned) so the decoded pass
            // below only touches live pixels — the skip counters stay
            // identical to the legacy kernel's.
            let mut n_live = 0;
            for j in 0..p {
                let oxx = ox + j;
                let w0 = n_live * lane_len;
                if interior_ok && oxx >= ox_lo && oxx <= ox_hi {
                    // Branch-free interior: every horizontal tap is
                    // in-bounds, so each (channel, kernel-row) tap is
                    // one contiguous copy from the row-band.
                    let x0 = oxx * stride - pad;
                    for (t, row) in rows.iter().enumerate() {
                        let dst = &mut acts[w0 + t * kw..w0 + (t + 1) * kw];
                        match row {
                            Some(r) => dst.copy_from_slice(&r[x0..x0 + kw]),
                            None => dst.fill(0),
                        }
                    }
                } else {
                    // Pad-clipped prologue/epilogue column: per-tap
                    // clip, replicating the legacy gather exactly.
                    for (t, row) in rows.iter().enumerate() {
                        for kx in 0..kw {
                            let ix = oxx * stride + kx;
                            acts[w0 + t * kw + kx] = match row {
                                Some(r) if ix >= pad && ix - pad < d.in_w => r[ix - pad],
                                _ => 0,
                            };
                        }
                    }
                }
                if skip && acts[w0..w0 + lane_len].iter().all(|&a| a == 0) {
                    for f in 0..nf {
                        out.put(f, oy, oxx, 0);
                    }
                    tally.skipped_windows += 1;
                } else {
                    live[n_live] = oxx;
                    n_live += 1;
                }
            }
            if n_live > 0 {
                // Energy accounting from the schedule's precomputed
                // per-window constants — numerically identical to what
                // the legacy splitter walk counts per executed window.
                tally.slot_decodes += sched.decodes_per_window * n_live as u64;
                tally.segment_adds += sched.adds_per_window * n_live as u64;
                for f in 0..nf {
                    banks[..n_live * bits].fill(0);
                    let lo = sched.offsets[f] as usize;
                    let hi = sched.offsets[f + 1] as usize;
                    // Weight-stationary: read each decoded triple once,
                    // accumulate it into every live pixel's bank.
                    for e in &sched.entries[lo..hi] {
                        let (slot, seg) = (e.slot as usize, e.seg as usize);
                        let sign = e.sign as i64;
                        for l in 0..n_live {
                            banks[l * bits + seg] += sign * acts[l * lane_len + slot] as i64;
                        }
                    }
                    for (l, &oxx) in live[..n_live].iter().enumerate() {
                        let drained = rear_adder_tree(&banks[l * bits..(l + 1) * bits]);
                        out.put(f, oy, oxx, drained as i32);
                    }
                }
            }
            ox += p;
        }
    }
    tally
}

// The pool/GAP/relu bodies below duplicate the scalar reference paths
// (`runtime::quantized` and the naive interpreter `model::reference`)
// ON PURPOSE: invariant I5 compares two independent implementations —
// sharing the code would blind the property tests to a bug in the
// shared half. The I5 suites exercise every one of these ops on both
// paths, so any drift fails loudly.

/// Parameterized integer pool (Caffe ceil-mode geometry), producing
/// output rows `[o0, o1)`.
fn pool_rows(
    spec: PoolSpec,
    input: &RowSrc,
    d: &StageDims,
    o0: usize,
    o1: usize,
    out: &mut RowTarget,
) {
    let (k, stride, pad) = (spec.k, spec.stride, spec.pad);
    let ow = d.out_w;
    for cc in 0..d.in_c {
        for oy in o0..o1 {
            // Window rows clipped to the input (pad taps excluded).
            let wy0 = (oy * stride).saturating_sub(pad);
            let wy1 = (oy * stride + k - pad).min(d.in_h);
            for ox in 0..ow {
                let wx0 = (ox * stride).saturating_sub(pad);
                let wx1 = (ox * stride + k - pad).min(d.in_w);
                let v = match spec.kind {
                    PoolKind::Max => {
                        let mut m = i32::MIN;
                        for y in wy0..wy1 {
                            for xx in wx0..wx1 {
                                m = m.max(input.get(cc, y, xx));
                            }
                        }
                        m
                    }
                    PoolKind::Avg => {
                        let mut s: i64 = 0;
                        for y in wy0..wy1 {
                            for xx in wx0..wx1 {
                                s += input.get(cc, y, xx) as i64;
                            }
                        }
                        let taps = ((wy1 - wy0) * (wx1 - wx0)) as i64;
                        s.div_euclid(taps) as i32
                    }
                };
                out.put(cc, oy, ox, v);
            }
        }
    }
}

/// Concatenate feature maps along the channel axis (branch arm order).
fn concat_channels(parts: &[Tensor<i32>]) -> crate::Result<Tensor<i32>> {
    let [n, _, h, w] = match parts.first().map(|p| p.shape()) {
        Some(&[n, c, h, w]) => [n, c, h, w],
        _ => return Err(crate::Error::Shape("concat needs 4-D inputs".into())),
    };
    let mut total_c = 0usize;
    for p in parts {
        match *p.shape() {
            [pn, pc, ph, pw] if pn == n && ph == h && pw == w => total_c += pc,
            _ => {
                return Err(crate::Error::Shape(format!(
                    "concat arm shape {:?} incompatible with (N={n}, H={h}, W={w})",
                    p.shape()
                )))
            }
        }
    }
    let plane = h * w;
    let mut out: Tensor<i32> = Tensor::zeros(&[n, total_c, h, w]);
    let mut c_off = 0usize;
    for p in parts {
        let pc = p.shape()[1];
        for b in 0..n {
            let src = &p.data()[b * pc * plane..(b + 1) * pc * plane];
            let dst = (b * total_c + c_off) * plane;
            out.data_mut()[dst..dst + pc * plane].copy_from_slice(src);
        }
        c_off += pc;
    }
    Ok(out)
}

/// Global average pool: i64 sum then floor division (matches jnp `//`).
fn global_avg_pool(x: &Tensor<i32>) -> crate::Result<Tensor<i32>> {
    let [n, c, h, w] = match *x.shape() {
        [n, c, h, w] => [n, c, h, w],
        _ => return Err(crate::Error::Shape("GAP input must be 4-D".into())),
    };
    let mut feats: Tensor<i32> = Tensor::zeros(&[n, c]);
    for b in 0..n {
        for cc in 0..c {
            let mut s: i64 = 0;
            for y in 0..h {
                for xx in 0..w {
                    s += x.get4(b, cc, y, xx) as i64;
                }
            }
            feats.data_mut()[b * c + cc] = s.div_euclid((h * w) as i64) as i32;
        }
    }
    Ok(feats)
}

/// One FC layer over pre-kneaded lanes, parallel across batch rows
/// within the caller's thread budget. Every head but the stack's last
/// is activation-fused (`CompiledFc::relu`): ReLU + requantization by
/// the head's `frac_bits`, mirroring the conv stages.
fn fc_parallel(
    fc: &CompiledFc,
    x: &Tensor<i32>,
    mode: crate::config::Mode,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    let [n, d] = match *x.shape() {
        [n, d] => [n, d],
        _ => {
            return Err(crate::Error::Shape(format!(
                "FC `{}` input must be 2-D (N, feat)",
                fc.name
            )))
        }
    };
    if d != fc.feat_dim {
        return Err(crate::Error::Shape(format!(
            "FC `{}` feature dim {d} != compiled {}",
            fc.name, fc.feat_dim
        )));
    }
    let items: Vec<usize> = (0..n).collect();
    let rows: Vec<Vec<i32>> = par_map_with(workers, &items, |_, &b| {
        let acts = &x.data()[b * d..(b + 1) * d];
        let mut segs = SegmentRegisters::new(mode.weight_bits());
        let mut out_row = vec![0i32; fc.classes];
        for (k, klane) in fc.lanes.iter().enumerate() {
            for (g, group) in klane.groups.iter().enumerate() {
                let start = g * klane.ks;
                let end = (start + klane.ks).min(d);
                split_kneaded(group, &acts[start..end], &mut segs);
            }
            let v = rear_adder_tree(segs.values()) as i32;
            out_row[k] = if fc.relu { requantize(v, fc.frac_bits).max(0) } else { v };
            segs.reset();
        }
        out_row
    });
    let mut out: Tensor<i32> = Tensor::zeros(&[n, fc.classes]);
    for (b, row) in rows.iter().enumerate() {
        out.data_mut()[b * fc.classes..(b + 1) * fc.classes].copy_from_slice(row);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::coordinator::SacBackend;
    use crate::model::{zoo, Network, TopoOp};
    use crate::plan::CompiledNetwork;
    use crate::util::rng::Rng;

    /// The tiny CNN with its 2×2 stride-2 pools swapped for 3×3
    /// stride-2 (ceil mode keeps the exact same 16 → 8 → 4 spatial
    /// chain, so the declared layer shapes still validate). With
    /// k > stride the pools' input windows overlap across tiles, so
    /// the tiled walk measurably recomputes halo rows — the recompute
    /// the streaming walk exists to eliminate. (The stock tiny CNN's
    /// k == stride pools have disjoint windows and no halo at all.)
    fn tiny_with_overlapping_pools() -> Network {
        let mut net = zoo::tiny_cnn();
        for op in net.schedule.iter_mut() {
            if let TopoOp::Pool(p) = op {
                *p = PoolSpec::max(3, 2, 0);
            }
        }
        net
    }

    fn image_batch(n: usize, seed: u64) -> Tensor<i32> {
        let mut t = Tensor::zeros(&[n, 1, 16, 16]);
        let mut rng = Rng::new(seed);
        for v in t.data_mut() {
            *v = rng.range_i64(-400, 400) as i32;
        }
        t
    }

    /// Wrap a single-image NCHW tensor as a full-height span ring.
    fn buf_of(x: &Tensor<i32>) -> RingBuf {
        let [n, c, h, w] = match *x.shape() {
            [n, c, h, w] => [n, c, h, w],
            _ => panic!("4-D input"),
        };
        assert_eq!(n, 1, "single image");
        let mut r = RingBuf::span(c, 0, h, w);
        r.data.copy_from_slice(x.data());
        r
    }

    fn pool_dims(c: usize, h: usize, w: usize, spec: PoolSpec) -> StageDims {
        StageDims {
            in_c: c,
            in_h: h,
            in_w: w,
            out_c: c,
            out_h: spec.out_hw(h).unwrap(),
            out_w: spec.out_hw(w).unwrap(),
        }
    }

    fn pool_to_ring(spec: PoolSpec, src: &RowSrc, d: &StageDims, o0: usize, o1: usize) -> RingBuf {
        let mut out = RingBuf::span(d.in_c, o0, o1, d.out_w);
        pool_rows(spec, src, d, o0, o1, &mut RowTarget::Ring(&mut out));
        out
    }

    #[test]
    fn execute_produces_logits_and_is_deterministic() {
        let w = SacBackend::synthetic_weights(5).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        let x = image_batch(3, 1);
        let a = plan.execute(&x).unwrap();
        let b = plan.execute(&x).unwrap();
        assert_eq!(a.shape(), &[3, 4]);
        assert_eq!(a, b);
    }

    #[test]
    fn execute_rejects_wrong_channels() {
        let w = SacBackend::synthetic_weights(5).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        assert!(plan.execute(&Tensor::zeros(&[1, 2, 16, 16])).is_err());
    }

    #[test]
    fn tile_height_budget_and_walk_never_change_logits() {
        // Invariant I5 over tilings AND walks: every tile height
        // (dividing the output rows or not), the materializing
        // baseline, every thread budget, and both dataflows produce
        // bit-identical logits.
        let w = SacBackend::synthetic_weights(9).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        let x = image_batch(2, 3);
        let want = plan.execute_opts(&x, ExecOpts::materializing()).unwrap();
        for tile in [1usize, 2, 3, 5, 7, 100] {
            for workers in [1usize, 3, 8] {
                for walk in [Walk::Tiled, Walk::Streaming] {
                    let got = plan
                        .execute_opts(
                            &x,
                            ExecOpts::tiled(tile).with_workers(workers).with_walk(walk),
                        )
                        .unwrap();
                    assert_eq!(got, want, "tile={tile} workers={workers} walk={walk:?}");
                }
            }
        }
        assert_eq!(plan.execute(&x).unwrap(), want, "default path drifted");
    }

    #[test]
    fn traced_tiled_peak_is_below_materializing_peak() {
        let w = SacBackend::synthetic_weights(4).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        let x = image_batch(1, 7);
        let (full, t_full) = plan
            .execute_traced(&x, ExecOpts::materializing().with_workers(1))
            .unwrap();
        let (tiled, t_tiled) = plan
            .execute_traced(&x, ExecOpts::tiled(1).with_workers(1))
            .unwrap();
        assert_eq!(full, tiled);
        assert!(
            t_tiled.peak_bytes() < t_full.peak_bytes(),
            "tiled peak {} not below materializing peak {}",
            t_tiled.peak_bytes(),
            t_full.peak_bytes()
        );
    }

    #[test]
    fn streaming_retains_halo_rows_instead_of_recomputing() {
        let w = SacBackend::synthetic_weights(6).unwrap();
        let plan =
            CompiledNetwork::compile(&tiny_with_overlapping_pools(), &w, 16, Mode::Fp16)
                .unwrap();
        let x = image_batch(2, 11);
        let (tiled, t_tiled) = plan
            .execute_traced(&x, ExecOpts::tiled(2).with_workers(1))
            .unwrap();
        let (streamed, t_stream) = plan
            .execute_traced(&x, ExecOpts::streaming(2).with_workers(1))
            .unwrap();
        assert_eq!(tiled, streamed, "walks diverged");
        assert!(
            t_tiled.halo_recompute_rows() > 0,
            "2-row tiles over 3×3 stride-2 pools must recompute halo rows"
        );
        assert_eq!(
            t_stream.halo_recompute_rows(),
            0,
            "streaming walk recomputed halo rows"
        );
        assert!(
            t_stream.peak_bytes() <= t_tiled.peak_bytes(),
            "streaming peak {} above tiled peak {}",
            t_stream.peak_bytes(),
            t_tiled.peak_bytes()
        );
    }

    #[test]
    fn default_walk_streams_covered_batches_and_tiles_lone_images() {
        let w = SacBackend::synthetic_weights(8).unwrap();
        let plan =
            CompiledNetwork::compile(&tiny_with_overlapping_pools(), &w, 16, Mode::Fp16)
                .unwrap();
        // Batch ≥ workers → streaming → zero halo recompute.
        let x2 = image_batch(2, 13);
        let opts = ExecOpts { workers: Some(1), ..ExecOpts::default() };
        let (_, t) = plan.execute_traced(&x2, opts).unwrap();
        assert_eq!(t.halo_recompute_rows(), 0, "covered batch should stream");
        // Lone image under a wide budget → tiled fan-out. The default
        // 4-row tiles shrink adaptively to 1-row tiles to feed 8
        // workers, so the overlapping pool windows recompute rows.
        let x1 = image_batch(1, 13);
        let opts = ExecOpts { workers: Some(8), ..ExecOpts::default() };
        let (_, t) = plan.execute_traced(&x1, opts).unwrap();
        assert!(t.halo_recompute_rows() > 0, "lone image should tile");
    }

    #[test]
    fn pool_rows_2x2_matches_legacy_truncating_maxpool_on_even_extents() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 9, -4, 3]).unwrap();
        let spec = PoolSpec::max(2, 2, 0);
        let buf = buf_of(&x);
        let p = pool_to_ring(spec, &RowSrc::Ring(&buf), &pool_dims(1, 2, 2, spec), 0, 1);
        assert_eq!((p.c, p.y1 - p.y0, p.w), (1, 1, 1));
        assert_eq!(p.data, &[9]);
        // Stage 0 reads the tensor in place — same values either way.
        let q = pool_to_ring(
            spec,
            &RowSrc::Tensor { x: &x, b: 0, zeros: None },
            &pool_dims(1, 2, 2, spec),
            0,
            1,
        );
        assert_eq!(p.data, q.data);
    }

    #[test]
    fn pool_rows_3x3_stride2_uses_ceil_windows() {
        // 1×8 row, k=3 s=2 pad=1 (the pad keeps the 1-tall height
        // legal). Width: ceil((8+2-3)/2)+1 = 5 windows, the last one
        // clipped to the single in-bounds tap at index 7 — padding
        // never wins a max, so a negative value survives there.
        let x = Tensor::from_vec(&[1, 1, 1, 8], vec![0, 1, 2, 3, 4, 5, 6, -7]).unwrap();
        let spec = PoolSpec::max(3, 2, 1);
        let buf = buf_of(&x);
        let p = pool_to_ring(spec, &RowSrc::Ring(&buf), &pool_dims(1, 1, 8, spec), 0, 1);
        assert_eq!((p.c, p.y1 - p.y0, p.w), (1, 1, 5));
        assert_eq!(p.data, &[1, 3, 5, 6, -7]);
    }

    #[test]
    fn avg_pool_rows_floor_divides_inbounds_taps() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 2, 3, -5]).unwrap();
        let buf = buf_of(&x);
        let spec = PoolSpec::avg(2, 2, 0);
        let p = pool_to_ring(spec, &RowSrc::Ring(&buf), &pool_dims(1, 2, 2, spec), 0, 1);
        // (1+2+3-5) = 1, 4 taps → 1.div_euclid(4) = 0.
        assert_eq!(p.data, &[0]);
        // Padded window clips to in-bounds taps: pad 1, k 2, stride 2 →
        // out 2×2, each window holds exactly one in-bounds value.
        let spec = PoolSpec::avg(2, 2, 1);
        let p = pool_to_ring(spec, &RowSrc::Ring(&buf), &pool_dims(1, 2, 2, spec), 0, 2);
        assert_eq!((p.c, p.y1 - p.y0, p.w), (1, 2, 2));
        assert_eq!(p.data, &[1, 2, 3, -5]);
    }

    #[test]
    fn ring_buf_wraps_rows_modulo_capacity() {
        // A 3-row ring sliding down a 6-row map: writes land modulo
        // cap, retained rows survive the slide, dead rows get reused.
        let mut r = RingBuf::with_capacity(1, 3, 2);
        r.grow_to(3);
        for y in 0..3 {
            r.row_mut(0, y).copy_from_slice(&[y as i32; 2]);
        }
        assert_eq!(r.row(0, 0), &[0, 0]);
        r.retire_below(2); // rows 0–1 dead
        r.grow_to(5); // rows 3–4 overwrite slots 0–1
        r.row_mut(0, 3).copy_from_slice(&[3, 3]);
        r.row_mut(0, 4).copy_from_slice(&[4, 4]);
        assert_eq!(r.row(0, 2), &[2, 2], "retained row survived the slide");
        assert_eq!(r.row(0, 3), &[3, 3]);
        assert_eq!(r.row(0, 4), &[4, 4]);
    }

    #[test]
    fn concat_stacks_channel_slices_in_arm_order() {
        let a = Tensor::from_vec(&[2, 1, 1, 2], vec![1, 2, 3, 4]).unwrap();
        let b = Tensor::from_vec(&[2, 2, 1, 2], vec![5, 6, 7, 8, 9, 10, 11, 12]).unwrap();
        let cat = concat_channels(&[a, b]).unwrap();
        assert_eq!(cat.shape(), &[2, 3, 1, 2]);
        assert_eq!(cat.data(), &[1, 2, 5, 6, 7, 8, 3, 4, 9, 10, 11, 12]);
        // Mismatched spatial sizes are rejected.
        let c = Tensor::from_vec(&[2, 1, 2, 1], vec![0; 4]).unwrap();
        let d = Tensor::from_vec(&[2, 1, 1, 2], vec![0; 4]).unwrap();
        assert!(concat_channels(&[c, d]).is_err());
    }

    // ------------------------------------------------ pipelined walk

    use crate::model::{ConvLayer, LoadedLayer, LoadedWeights};

    /// A small net exercising everything the pipeline must handle:
    /// stem conv, a 3-arm branch whose arms advance at different rates
    /// (1×1 fast arm, two-conv slow arm, ceil-mode-pool-led arm), a
    /// conv consuming the concat ring, and a trailing overlapping
    /// pool fused behind it.
    fn tiny_branchy() -> Network {
        let conv = |name: &str, in_c, out_c, k, stride, pad, in_hw| ConvLayer {
            name: name.to_string(),
            in_c,
            out_c,
            k,
            stride,
            pad,
            in_hw,
        };
        Network::with_schedule(
            "tiny_branchy",
            vec![
                conv("stem", 1, 4, 3, 1, 1, 16),
                conv("arm1/1x1", 4, 3, 1, 1, 0, 16),
                conv("arm2/3x3a", 4, 4, 3, 1, 1, 16),
                conv("arm2/3x3b", 4, 5, 3, 1, 1, 16),
                conv("arm3/proj", 4, 2, 1, 1, 0, 16),
                conv("tail", 10, 6, 3, 1, 1, 16),
            ],
            vec![
                TopoOp::Conv(0),
                TopoOp::Branch(vec![
                    vec![TopoOp::Conv(1)],
                    vec![TopoOp::Conv(2), TopoOp::Conv(3)],
                    vec![TopoOp::Pool(PoolSpec::max(3, 1, 1)), TopoOp::Conv(4)],
                ]),
                TopoOp::Conv(5),
                TopoOp::Pool(PoolSpec::max(3, 2, 0)), // 16 → 8, overlapping
            ],
        )
    }

    /// Varied (non-constant) weights so channel-block misplacement in
    /// the concat ring cannot cancel out.
    fn varied_weights(net: &Network) -> LoadedWeights {
        let layers = net
            .layers
            .iter()
            .map(|l| LoadedLayer {
                name: l.name.clone(),
                shape: [l.out_c, l.in_c, l.k, l.k],
                frac_bits: 8,
                weights: (0..l.weight_count()).map(|i| ((i * 37) % 25) as i32 - 12).collect(),
            })
            .collect();
        LoadedWeights { mode: Mode::Fp16, layers }
    }

    #[test]
    fn pipelined_walk_matches_other_walks_bit_exact() {
        let w = SacBackend::synthetic_weights(12).unwrap();
        let plan =
            CompiledNetwork::compile(&tiny_with_overlapping_pools(), &w, 16, Mode::Fp16)
                .unwrap();
        let x = image_batch(3, 17);
        let want = plan.execute_opts(&x, ExecOpts::materializing()).unwrap();
        for tile in [1usize, 2, 3, 5, 0] {
            for workers in [1usize, 4] {
                let (got, t) = plan
                    .execute_traced(&x, ExecOpts::pipelined(tile).with_workers(workers))
                    .unwrap();
                assert_eq!(got, want, "pipelined tile={tile} workers={workers}");
                assert_eq!(
                    t.halo_recompute_rows(),
                    0,
                    "pipelined walk recomputed halo rows (tile={tile})"
                );
            }
        }
    }

    #[test]
    fn pipelined_walk_streams_branches_from_one_upstream_ring() {
        let net = tiny_branchy();
        let w = varied_weights(&net);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let x = image_batch(2, 23);
        let want = plan.execute_opts(&x, ExecOpts::materializing()).unwrap();
        for tile in [1usize, 2, 4, 7, 0] {
            let (got, t) = plan
                .execute_traced(&x, ExecOpts::pipelined(tile).with_workers(2))
                .unwrap();
            assert_eq!(got, want, "branchy pipeline diverged at tile={tile}");
            assert_eq!(t.halo_recompute_rows(), 0);
        }
    }

    #[test]
    fn pipelined_peak_stays_below_materializing_peak() {
        let w = SacBackend::synthetic_weights(3).unwrap();
        let plan =
            CompiledNetwork::compile(&tiny_with_overlapping_pools(), &w, 16, Mode::Fp16)
                .unwrap();
        let x = image_batch(1, 29);
        let (full, t_full) = plan
            .execute_traced(&x, ExecOpts::materializing().with_workers(1))
            .unwrap();
        let (piped, t_piped) = plan
            .execute_traced(&x, ExecOpts::pipelined(2).with_workers(1))
            .unwrap();
        assert_eq!(full, piped);
        assert!(
            t_piped.peak_bytes() < t_full.peak_bytes(),
            "pipelined peak {} not below materializing peak {}",
            t_piped.peak_bytes(),
            t_full.peak_bytes()
        );
    }

    #[test]
    fn pipeline_summary_profiles_rings_and_fill_depth() {
        let w = SacBackend::synthetic_weights(7).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        let s = pipeline_summary(&plan, 1, 16, 16, 2)
            .unwrap()
            .expect("tiny CNN trunk is pipeable");
        // Three fused segments chain: conv1+pool, conv2+pool, conv3.
        assert_eq!(s.segments, 3);
        assert!(s.ring_bytes > 0, "chained rings must hold halo rows");
        // Trunk output: 16 channels × 4×4 i32.
        assert_eq!(s.out_bytes, (16 * 4 * 4 * 4) as u64);
        // The composed contract bounds the exact fill depth from
        // above: first composite window needs k − pad input rows.
        let chain = [
            RowContract { k: 3, stride: 1, pad: 1 },
            RowContract { k: 2, stride: 2, pad: 0 },
            RowContract { k: 3, stride: 1, pad: 1 },
            RowContract { k: 2, stride: 2, pad: 0 },
            RowContract { k: 3, stride: 1, pad: 1 },
        ];
        let c = RowContract::composed(chain.iter());
        assert!(s.fill_rows >= 1 && s.fill_rows <= c.k - c.pad,
            "fill depth {} outside (0, {}]", s.fill_rows, c.k - c.pad);
    }

    #[test]
    fn pipeable_prefix_stops_at_the_classifier_tail() {
        let w = SacBackend::synthetic_weights(2).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        // tiny CNN: [Fused, Fused, Fused, GAP, Fc] → prefix 3.
        assert_eq!(pipeable_prefix(&plan.schedule), 3);
    }

    // ------------------------------------------- activation skipping

    /// Images whose top ten rows are exactly zero. Convs have no bias
    /// and ReLU fixes zero, so the band survives every stage of these
    /// nets — the skip lane gets real all-zero rows to elide at every
    /// depth, not just at the input.
    fn zero_banded_batch(n: usize, seed: u64) -> Tensor<i32> {
        let mut t = Tensor::zeros(&[n, 1, 16, 16]);
        let mut rng = Rng::new(seed);
        for (i, v) in t.data_mut().iter_mut().enumerate() {
            if (i / 16) % 16 >= 10 {
                *v = rng.range_i64(-400, 400) as i32;
            }
        }
        t
    }

    #[test]
    fn ring_buf_zero_flags_invalidate_on_wraparound() {
        let mut r = RingBuf::with_capacity(1, 3, 2);
        r.grow_to(3);
        r.seal_zero(0, 1, true);
        assert!(r.row_zero(0, 1));
        assert!(!r.row_zero(0, 0), "unsealed row must read not-known-zero");
        // Row 4 reuses row 1's slot (4 % 3 == 1): the stale tag no
        // longer matches the new row id, so the flag self-invalidates
        // without any explicit clearing as the ring slides.
        r.retire_below(2);
        r.grow_to(5);
        assert!(!r.row_zero(0, 4), "stale zero flag leaked across wraparound");
        r.seal_zero(0, 4, true);
        assert!(r.row_zero(0, 4));
        r.seal_zero(0, 4, false);
        assert!(!r.row_zero(0, 4), "non-zero seal must clear the flag");
    }

    #[test]
    fn all_zero_input_skips_every_conv_window() {
        let net = tiny_with_overlapping_pools();
        let w = varied_weights(&net);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let x = Tensor::zeros(&[1, 1, 16, 16]);
        let (want, off) = plan
            .execute_traced(&x, ExecOpts::streaming(2).with_skip_zero_activations(false))
            .unwrap();
        assert_eq!(off.skipped_windows(), 0, "skip-off must never skip");
        assert_eq!(off.skipped_rows(), 0);
        assert!(off.total_windows() > 0, "traced runs count the denominator");
        for opts in [ExecOpts::tiled(2), ExecOpts::streaming(2), ExecOpts::pipelined(2)] {
            let (got, on) = plan
                .execute_traced(&x, opts.with_skip_zero_activations(true))
                .unwrap();
            assert_eq!(got, want, "skipping changed all-zero logits");
            assert_eq!(
                on.skipped_windows(),
                on.total_windows(),
                "an all-zero image must skip every conv window"
            );
            assert!((on.window_skip_fraction() - 1.0).abs() < 1e-12);
            assert!(on.activation_values() > 0, "seal points tallied nothing");
            assert!((on.activation_zero_fraction() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn skip_lane_is_bit_exact_across_walks_and_counts_its_work() {
        let net = tiny_with_overlapping_pools();
        let w = varied_weights(&net);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let x = zero_banded_batch(2, 19);
        let want = plan.execute_opts(&x, ExecOpts::materializing()).unwrap();
        for opts in [
            ExecOpts::materializing(),
            ExecOpts::tiled(2).with_workers(2),
            ExecOpts::streaming(2).with_workers(2),
            ExecOpts::pipelined(2).with_workers(2),
        ] {
            let (got, t) = plan
                .execute_traced(&x, opts.with_skip_zero_activations(true))
                .unwrap();
            assert_eq!(got, want, "skip lane changed logits");
            assert!(t.skipped_windows() > 0, "zero band produced no skips");
            assert!(t.skipped_windows() <= t.total_windows());
            let f = t.window_skip_fraction();
            assert!(f > 0.0 && f <= 1.0, "skip fraction {f} out of range");
            assert!(t.activation_values() > 0, "seal points tallied nothing");
            assert!(t.activation_zero_fraction() > 0.0, "zero band not observed");
            let eb = t.activation_essential_bits_mean();
            assert!(eb > 0.0 && eb < ACT_BITS as f64, "essential bits {eb} out of range");
        }
    }

    #[test]
    fn pipelined_masks_survive_pool_and_concat_boundaries() {
        // tiny_branchy routes the zero band through a pool-led arm, a
        // two-conv arm, a 1×1 arm, a channel concat, and a trailing
        // overlapping pool — row masks must survive `RowContract`
        // composition and the concat's channel-block offsets for the
        // tail conv to land row-level skips.
        let net = tiny_branchy();
        let w = varied_weights(&net);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let x = zero_banded_batch(2, 31);
        let want = plan.execute_opts(&x, ExecOpts::materializing()).unwrap();
        let (got, t) = plan
            .execute_traced(
                &x,
                ExecOpts::pipelined(2).with_workers(2).with_skip_zero_activations(true),
            )
            .unwrap();
        assert_eq!(got, want, "pipelined skip lane changed logits");
        assert!(t.skipped_rows() > 0, "row masks lost crossing branch/pool stages");
        assert!(t.skipped_windows() >= t.skipped_rows(), "row skips count their windows");
        assert_eq!(t.halo_recompute_rows(), 0);
    }

    // ------------------------------------------- decoded-lane kernel

    #[test]
    fn decoded_kernel_is_bit_exact_across_walks_and_matches_legacy_counters() {
        let net = tiny_with_overlapping_pools();
        let w = varied_weights(&net);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let x = zero_banded_batch(2, 23);
        let want =
            plan.execute_opts(&x, ExecOpts::materializing().with_kernel(Kernel::Legacy)).unwrap();
        for opts in [
            ExecOpts::materializing(),
            ExecOpts::tiled(2).with_workers(2),
            ExecOpts::streaming(2).with_workers(2),
            ExecOpts::pipelined(2).with_workers(2),
        ] {
            for skip in [false, true] {
                let opts = opts.with_skip_zero_activations(skip);
                let (dec, td) = plan
                    .execute_traced(&x, opts.with_kernel(Kernel::Decoded))
                    .unwrap();
                let (leg, tl) = plan
                    .execute_traced(&x, opts.with_kernel(Kernel::Legacy))
                    .unwrap();
                assert_eq!(dec, want, "decoded kernel changed logits (skip={skip})");
                assert_eq!(leg, want, "legacy kernel changed logits (skip={skip})");
                assert!(td.slot_decodes() > 0, "decoded run charged no decodes");
                assert!(td.segment_adds() > 0, "decoded run charged no adds");
                assert_eq!(
                    (td.slot_decodes(), td.segment_adds()),
                    (tl.slot_decodes(), tl.segment_adds()),
                    "kernels disagree on decode/add energy (skip={skip})"
                );
                assert_eq!(
                    (td.skipped_rows(), td.skipped_windows(), td.total_windows()),
                    (tl.skipped_rows(), tl.skipped_windows(), tl.total_windows()),
                    "kernels disagree on the skip counters (skip={skip})"
                );
                if skip {
                    assert!(td.skipped_windows() > 0, "zero band produced no skips");
                }
            }
        }
    }

    #[test]
    fn decoded_kernel_is_the_default_and_survives_branches() {
        // tiny_branchy routes a pool-led arm, a two-conv arm, a 1×1
        // arm, a concat, and a trailing overlapping pool through the
        // decoded path (no kernel pinned anywhere → Decoded default);
        // the legacy splitter walk must agree byte-for-byte and
        // counter-for-counter across the branch fan-out.
        let net = tiny_branchy();
        let w = varied_weights(&net);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        assert_eq!(plan.kernel, Kernel::Decoded, "compile must default to the fast path");
        let x = zero_banded_batch(2, 37);
        let opts = ExecOpts::pipelined(2).with_workers(2);
        let (dec, td) = plan.execute_traced(&x, opts).unwrap();
        let (leg, tl) = plan.execute_traced(&x, opts.with_kernel(Kernel::Legacy)).unwrap();
        assert_eq!(dec, leg, "default (decoded) kernel diverged from legacy");
        assert!(td.slot_decodes() > 0 && td.segment_adds() > 0);
        assert_eq!(td.slot_decodes(), tl.slot_decodes());
        assert_eq!(td.segment_adds(), tl.segment_adds());
        assert_eq!(td.total_windows(), tl.total_windows());
    }

    // Plan ≡ scalar-forward equivalence (invariant I5) lives in
    // rust/tests/plan_exec.rs (tiny CNN / VGG block) and
    // rust/tests/plan_topology.rs (full declared-topology zoo); the
    // tile-sweep extension in rust/tests/plan_tiling.rs; the
    // streaming-vs-tiled property sweep and FC-stack logits pins in
    // rust/tests/plan_streaming.rs; zero-rekneading in
    // plan_zero_knead.rs; the skip-on ≡ skip-off ≡ reference property
    // sweep in rust/tests/plan_skip.rs; the decoded ≡ legacy ≡
    // reference kernel sweep in rust/tests/plan_kernel.rs.
}
