//! The run-time half of the split: walk the tile schedule, stream the
//! pre-kneaded lanes through SAC, never knead.
//!
//! Tiled fused execution (§Perf, DESIGN.md §Tiled fused execution):
//! each `Conv → ReluRequant [→ Pool]` segment runs as one fused walk
//! over row tiles of its *final* stage — a work item computes one
//! (image, tile) stripe end to end through ring buffers holding only
//! the tile's live rows (tile + halo, [`RowContract::in_span`]), so
//! the conv's full-size pre-pool map never materializes. Halo rows at
//! tile boundaries are recomputed (overlapped tiling); fusion stops at
//! each pool on purpose — chaining walks across pools would grow the
//! halo with the receptive field and turn the recompute quadratic.
//!
//! Parallelism: (image, tile) stripes fan out via
//! `util::pool::par_map_with`, and `Branch` arms run **concurrently**,
//! each arm handed a slice of the thread budget
//! (`util::pool::split_budget`) so inception reduce convs overlap
//! without oversubscribing the host. Striped assignment plus
//! write-disjoint stitching keeps the output order deterministic: for
//! any `TETRIS_THREADS`, any budget, and any tile height, results are
//! bit-identical (invariant I5 extended over tilings).
//!
//! Every arithmetic step mirrors a plain scalar reference exactly (same
//! gather order, same group windows, same `i64 → i32` casts): the
//! legacy `runtime::quantized::forward_scalar` pipeline for the tiny
//! CNN, and the naive MAC interpreter `model::reference` for the full
//! declared-topology zoo. Pool windows use Caffe ceil-mode sizing
//! ([`PoolSpec::out_hw`]); max pools take the window's in-bounds
//! maximum (padding never wins), average pools floor-divide the i64 sum
//! by the in-bounds tap count.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::model::{PoolKind, PoolSpec, Tensor};
use crate::quant::requantize;
use crate::sac::{rear_adder_tree, split_kneaded, SegmentRegisters};
use crate::util::pool::{par_map_with, split_budget, worker_count};

use super::compiled::{CompiledConv, CompiledFc, CompiledNetwork};
use super::graph::{FusedStage, PlanOp, Segment};

/// Execution-time knobs for [`CompiledNetwork::execute_opts`].
/// `None` fields fall back to the plan's compiled defaults.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecOpts {
    /// Output rows per fused tile. `Some(0)` materializes — one tile
    /// spans each fused chain's full height, so every stage's whole
    /// map lives at once. `None` uses the plan's `tile_rows` and lets
    /// the executor shrink tiles to keep every worker fed on small
    /// batches (results are tile-invariant either way).
    pub tile_rows: Option<usize>,
    /// Thread budget. `None` uses `util::pool::worker_count()`.
    pub workers: Option<usize>,
}

impl ExecOpts {
    /// Exact tile height — no adaptive shrinking (tests and sweeps).
    pub fn tiled(tile_rows: usize) -> Self {
        Self { tile_rows: Some(tile_rows), workers: None }
    }

    /// One tile per fused chain: the materializing baseline the
    /// peak-allocation tests compare the tiled walk against.
    pub fn materializing() -> Self {
        Self::tiled(0)
    }

    /// Cap the thread budget (branch arms split whatever this is).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }
}

/// Peak intermediate-buffer accounting for one
/// [`CompiledNetwork::execute_traced`] call: feature maps, branch-arm
/// input clones and tile ring buffers enter `current` when allocated
/// and leave when retired; `peak` is the high-water mark. Per-thread
/// fixed scratch (the im2col gather row, segment registers) is
/// excluded — it is O(lane length) and independent of tiling.
#[derive(Debug, Default)]
pub struct AllocStats {
    current: AtomicU64,
    peak: AtomicU64,
}

impl AllocStats {
    fn alloc(&self, bytes: u64) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    fn free(&self, bytes: u64) {
        self.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// High-water mark of live feature-map bytes.
    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }
}

/// Per-call execution context threaded through the segment walk.
struct Ctx<'a> {
    plan: &'a CompiledNetwork,
    /// Output rows per fused tile; 0 = full height (materializing).
    tile_rows: usize,
    /// Whether tiles may shrink for load balance (default path only —
    /// explicit `ExecOpts::tiled` sizes are honored exactly).
    adaptive: bool,
    stats: Option<&'a AllocStats>,
}

impl Ctx<'_> {
    fn alloc(&self, bytes: u64) {
        if let Some(s) = self.stats {
            s.alloc(bytes);
        }
    }

    fn free(&self, bytes: u64) {
        if let Some(s) = self.stats {
            s.free(bytes);
        }
    }
}

fn tensor_bytes(t: &Tensor<i32>) -> u64 {
    (t.len() * std::mem::size_of::<i32>()) as u64
}

impl CompiledNetwork {
    /// Execute the plan on a Q8.8 input batch (N, C, H, W) with the
    /// plan's default tile height and the global worker count.
    ///
    /// Returns int32 logits (N, classes) for classifier plans, or the
    /// final feature map — (N, C', H', W'), or (N, C') after a declared
    /// global-average head — for conv-only plans. The input spatial
    /// size may differ from the zoo's recorded `in_hw` — the executor
    /// derives all spatial extents from the tensor itself (used by
    /// tests/benches to run scaled workloads).
    pub fn execute(&self, x: &Tensor<i32>) -> crate::Result<Tensor<i32>> {
        self.execute_opts(x, ExecOpts::default())
    }

    /// [`Self::execute`] with explicit tile height / thread budget.
    /// Results are bit-identical for every option combination
    /// (invariant I5); the options only move wall time and peak
    /// memory.
    pub fn execute_opts(&self, x: &Tensor<i32>, opts: ExecOpts) -> crate::Result<Tensor<i32>> {
        self.execute_inner(x, opts, None)
    }

    /// [`Self::execute_opts`] plus measured peak feature-map bytes —
    /// the accounting the peak-allocation tests pin fused-vs-
    /// materializing claims with.
    pub fn execute_traced(
        &self,
        x: &Tensor<i32>,
        opts: ExecOpts,
    ) -> crate::Result<(Tensor<i32>, u64)> {
        let stats = AllocStats::default();
        let out = self.execute_inner(x, opts, Some(&stats))?;
        Ok((out, stats.peak_bytes()))
    }

    fn execute_inner(
        &self,
        x: &Tensor<i32>,
        opts: ExecOpts,
        stats: Option<&AllocStats>,
    ) -> crate::Result<Tensor<i32>> {
        self.check_input(x)?;
        let (tile_rows, adaptive) = match opts.tile_rows {
            Some(t) => (t, false),
            None => (self.tile_rows, true),
        };
        let ctx = Ctx { plan: self, tile_rows, adaptive, stats };
        let workers = opts.workers.unwrap_or_else(worker_count).max(1);
        let input = x.clone();
        ctx.alloc(tensor_bytes(&input));
        run_segments(&ctx, &self.schedule, input, workers)
    }
}

/// Walk one segment list (the whole plan, or one branch arm).
fn run_segments(
    ctx: &Ctx,
    segs: &[Segment],
    mut h: Tensor<i32>,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    for seg in segs {
        let prev_bytes = tensor_bytes(&h);
        h = match seg {
            Segment::Fused(stages) => run_fused(ctx, stages, &h, workers)?,
            Segment::Branch(arms) => run_branch(ctx, arms, &h, workers)?,
            Segment::GlobalAvgPool => {
                let g = global_avg_pool(&h)?;
                ctx.alloc(tensor_bytes(&g));
                g
            }
            Segment::Fc => {
                let fc = ctx.plan.fc.as_ref().ok_or_else(|| {
                    crate::Error::Config("plan has an Fc op but no compiled head".into())
                })?;
                let logits = fc_parallel(fc, &h, ctx.plan.mode, workers)?;
                ctx.alloc(tensor_bytes(&logits));
                logits
            }
        };
        // The consumed input retires once its consumer produced.
        ctx.free(prev_bytes);
    }
    Ok(h)
}

/// Branch arms under a shared thread budget: up to `workers` scoped
/// arm threads (they mostly sleep in their inner fan-out joins), each
/// walking its segments with a `split_budget` slice — so the arms'
/// (image, tile) stripes overlap without oversubscribing the host.
/// With fewer workers than arms, striping makes one arm thread walk
/// several arms in sequence, so live compute threads never exceed the
/// budget. Outputs concatenate along channels in arm order, exactly
/// as before.
fn run_branch(
    ctx: &Ctx,
    arms: &[Vec<Segment>],
    x: &Tensor<i32>,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    let outer = workers.clamp(1, arms.len());
    let budgets = split_budget(workers, outer);
    let idx: Vec<usize> = (0..arms.len()).collect();
    let parts = par_map_with(outer, &idx, |i, &a| {
        ctx.alloc(tensor_bytes(x));
        run_segments(ctx, &arms[a], x.clone(), budgets[i % outer])
    });
    let mut tensors = Vec::with_capacity(parts.len());
    for p in parts {
        tensors.push(p?);
    }
    let cat = concat_channels(&tensors)?;
    ctx.alloc(tensor_bytes(&cat));
    for t in &tensors {
        ctx.free(tensor_bytes(t));
    }
    Ok(cat)
}

/// Resolved geometry of one fused stage against the actual input.
#[derive(Debug, Clone, Copy)]
struct StageDims {
    in_c: usize,
    in_h: usize,
    in_w: usize,
    out_c: usize,
    out_h: usize,
    out_w: usize,
}

/// One fused `Conv → ReluRequant [→ Pool]` walk over row tiles of its
/// final stage.
fn run_fused(
    ctx: &Ctx,
    stages: &[FusedStage],
    x: &Tensor<i32>,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    let (n, c0, h0, w0) = match *x.shape() {
        [n, c, h, w] => (n, c, h, w),
        _ => return Err(crate::Error::Shape("fused segment input must be 4-D".into())),
    };
    // Resolve every stage's geometry from the tensor (not the declared
    // topology — scaled/off-topology inputs are supported).
    let mut dims: Vec<StageDims> = Vec::with_capacity(stages.len());
    let (mut c, mut h, mut w) = (c0, h0, w0);
    for st in stages {
        let (oc, oh, ow) = match &st.op {
            PlanOp::Conv { layer, pad, stride } => {
                let conv = &ctx.plan.convs[*layer];
                if c != conv.in_c {
                    return Err(crate::Error::Shape(format!(
                        "{}: input channels {c} != weight channels {}",
                        conv.name, conv.in_c
                    )));
                }
                if *stride == 0 {
                    return Err(crate::Error::Config(format!("{}: stride 0", conv.name)));
                }
                if h + 2 * pad < conv.kh || w + 2 * pad < conv.kw {
                    return Err(crate::Error::Shape(format!(
                        "{}: {h}×{w} input (pad {pad}) smaller than {}×{} kernel",
                        conv.name, conv.kh, conv.kw
                    )));
                }
                (
                    conv.out_c,
                    (h + 2 * pad - conv.kh) / stride + 1,
                    (w + 2 * pad - conv.kw) / stride + 1,
                )
            }
            PlanOp::ReluRequant { .. } => (c, h, w),
            PlanOp::Pool(spec) => (c, spec.out_hw(h)?, spec.out_hw(w)?),
            other => {
                return Err(crate::Error::Config(format!(
                    "non-fusable op {other:?} in a fused segment"
                )))
            }
        };
        dims.push(StageDims { in_c: c, in_h: h, in_w: w, out_c: oc, out_h: oh, out_w: ow });
        (c, h, w) = (oc, oh, ow);
    }
    let last = dims.last().expect("fused segments are non-empty");
    let (oc, oh, ow) = (last.out_c, last.out_h, last.out_w);

    let mut tile = if ctx.tile_rows == 0 { oh } else { ctx.tile_rows.clamp(1, oh) };
    if ctx.adaptive && ctx.tile_rows != 0 {
        // Results are tile-invariant (I5), so the default path may
        // shrink tiles until (images × tiles) covers the budget.
        while tile > 1 && n * oh.div_ceil(tile) < workers {
            tile = tile.div_ceil(2);
        }
    }

    // One work item per (image, output-row tile) of the final stage.
    let mut items: Vec<(usize, usize, usize)> = Vec::with_capacity(n * oh.div_ceil(tile));
    for b in 0..n {
        let mut t0 = 0;
        while t0 < oh {
            let t1 = (t0 + tile).min(oh);
            items.push((b, t0, t1));
            t0 = t1;
        }
    }
    let tiles = par_map_with(workers, &items, |_, &(b, t0, t1)| {
        run_tile(ctx, stages, &dims, x, b, t0, t1)
    });

    let mut out: Tensor<i32> = Tensor::zeros(&[n, oc, oh, ow]);
    ctx.alloc(tensor_bytes(&out));
    for (&(b, t0, t1), res) in items.iter().zip(tiles) {
        let buf = res?;
        for f in 0..oc {
            for y in t0..t1 {
                let src = buf.index(f, y, 0);
                let dst = out.idx4(b, f, y, 0);
                out.data_mut()[dst..dst + ow].copy_from_slice(&buf.data[src..src + ow]);
            }
        }
        ctx.free(buf.bytes());
    }
    Ok(out)
}

/// Rows `[y0, y1)` of a single image's (C, H, W) feature map — the
/// live ring of a tile walk, addressed in global row coordinates.
struct RowBuf {
    c: usize,
    y0: usize,
    y1: usize,
    w: usize,
    data: Vec<i32>,
}

impl RowBuf {
    fn new(c: usize, y0: usize, y1: usize, w: usize) -> Self {
        Self { c, y0, y1, w, data: vec![0; c * (y1 - y0) * w] }
    }

    fn rows(&self) -> usize {
        self.y1 - self.y0
    }

    #[inline]
    fn index(&self, c: usize, y: usize, x: usize) -> usize {
        debug_assert!(
            y >= self.y0 && y < self.y1,
            "row {y} outside ring [{}, {})",
            self.y0,
            self.y1
        );
        (c * self.rows() + (y - self.y0)) * self.w + x
    }

    #[inline]
    fn get(&self, c: usize, y: usize, x: usize) -> i32 {
        self.data[self.index(c, y, x)]
    }

    fn bytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<i32>()) as u64
    }
}

/// Where a stage reads its input rows: stage 0 reads straight from
/// the (already materialized) input tensor — no seed copy — and later
/// stages read the previous stage's ring.
enum RowSrc<'a> {
    Tensor { x: &'a Tensor<i32>, b: usize },
    Ring(&'a RowBuf),
}

impl RowSrc<'_> {
    #[inline]
    fn get(&self, c: usize, y: usize, xx: usize) -> i32 {
        match self {
            RowSrc::Tensor { x, b } => x.get4(*b, c, y, xx),
            RowSrc::Ring(r) => r.get(c, y, xx),
        }
    }
}

fn row_src<'a>(buf: &'a Option<RowBuf>, x: &'a Tensor<i32>, b: usize) -> RowSrc<'a> {
    match buf {
        Some(r) => RowSrc::Ring(r),
        None => RowSrc::Tensor { x, b },
    }
}

/// Retire the previous ring (if any) in favor of its consumer's output.
fn retire(ctx: &Ctx, buf: &mut Option<RowBuf>, next: RowBuf) {
    ctx.alloc(next.bytes());
    if let Some(old) = buf.replace(next) {
        ctx.free(old.bytes());
    }
}

/// One (image, tile) work item: produce final-stage rows `[t0, t1)` by
/// walking the fused stages over ring buffers. The backward pass
/// derives each stage's needed input span (tile + halo); the forward
/// pass computes exactly those rows — stage 0 reading the input tensor
/// in place, every later stage reading the previous ring — retiring
/// each ring as its consumer finishes.
fn run_tile(
    ctx: &Ctx,
    stages: &[FusedStage],
    dims: &[StageDims],
    x: &Tensor<i32>,
    b: usize,
    t0: usize,
    t1: usize,
) -> crate::Result<RowBuf> {
    let m = stages.len();
    // spans[i] = rows of stage i's INPUT this tile needs; spans[m] is
    // the tile itself. (spans[0] is the tile's read window on the
    // input tensor — read in place, never copied.)
    let mut spans = vec![(0usize, 0usize); m + 1];
    spans[m] = (t0, t1);
    for i in (0..m).rev() {
        let (o0, o1) = spans[i + 1];
        spans[i] = stages[i].contract.in_span(o0, o1, dims[i].in_h);
    }

    let mut buf: Option<RowBuf> = None;
    for (i, st) in stages.iter().enumerate() {
        let (o0, o1) = spans[i + 1];
        let d = &dims[i];
        match &st.op {
            PlanOp::Conv { layer, pad, stride } => {
                let next = {
                    let src = row_src(&buf, x, b);
                    conv_rows(
                        &ctx.plan.convs[*layer],
                        &src,
                        d,
                        *pad,
                        *stride,
                        o0,
                        o1,
                        ctx.plan.mode,
                    )
                };
                retire(ctx, &mut buf, next);
            }
            PlanOp::ReluRequant { frac_bits } => {
                if buf.is_none() {
                    // Lone elementwise segment (never produced by the
                    // zoo's lowering, but kept total): seed its rows
                    // from the input tensor once.
                    let mut seeded = RowBuf::new(d.in_c, o0, o1, d.in_w);
                    for cc in 0..d.in_c {
                        for y in o0..o1 {
                            let src = x.idx4(b, cc, y, 0);
                            let dst = seeded.index(cc, y, 0);
                            seeded.data[dst..dst + d.in_w]
                                .copy_from_slice(&x.data()[src..src + d.in_w]);
                        }
                    }
                    ctx.alloc(seeded.bytes());
                    buf = Some(seeded);
                }
                let r = buf.as_mut().expect("seeded above");
                // Elementwise: same span, mutate the ring in place.
                for v in r.data.iter_mut() {
                    *v = requantize(*v, *frac_bits).max(0);
                }
            }
            PlanOp::Pool(spec) => {
                let next = {
                    let src = row_src(&buf, x, b);
                    pool_rows(*spec, &src, d, o0, o1)
                };
                retire(ctx, &mut buf, next);
            }
            _ => unreachable!("run_fused validated the stage ops"),
        }
    }
    Ok(buf.expect("fused segments are non-empty"))
}

/// Integer conv over pre-kneaded filter lanes, producing output rows
/// `[o0, o1)` from its source (input tensor in place, or the previous
/// ring). Identical arithmetic to the scalar references: same
/// (c, ky, kx) gather order, same group windows, same `i64 → i32`
/// cast.
#[allow(clippy::too_many_arguments)]
fn conv_rows(
    conv: &CompiledConv,
    input: &RowSrc,
    d: &StageDims,
    pad: usize,
    stride: usize,
    o0: usize,
    o1: usize,
    mode: crate::config::Mode,
) -> RowBuf {
    let (kh, kw) = (conv.kh, conv.kw);
    let lane_len = conv.lane_len();
    let ow = d.out_w;
    let mut out = RowBuf::new(conv.out_c, o0, o1, ow);
    let mut acts = vec![0i32; lane_len];
    let mut segs = SegmentRegisters::new(mode.weight_bits());
    for oy in o0..o1 {
        for ox in 0..ow {
            // Gather the activation window (im2col row) in OIHW weight
            // order: (c, ky, kx) — once, shared by every filter.
            let mut idx = 0;
            for cc in 0..d.in_c {
                for ky in 0..kh {
                    for kx in 0..kw {
                        let iy = oy * stride + ky;
                        let ix = ox * stride + kx;
                        acts[idx] = if iy < pad
                            || ix < pad
                            || iy - pad >= d.in_h
                            || ix - pad >= d.in_w
                        {
                            0
                        } else {
                            input.get(cc, iy - pad, ix - pad)
                        };
                        idx += 1;
                    }
                }
            }
            for (f, klane) in conv.lanes.iter().enumerate() {
                for (g, group) in klane.groups.iter().enumerate() {
                    let start = g * klane.ks;
                    let end = (start + klane.ks).min(lane_len);
                    split_kneaded(group, &acts[start..end], &mut segs);
                }
                let oi = out.index(f, oy, ox);
                out.data[oi] = rear_adder_tree(segs.values()) as i32;
                segs.reset();
            }
        }
    }
    out
}

// The pool/GAP/relu bodies below duplicate the scalar reference paths
// (`runtime::quantized` and the naive interpreter `model::reference`)
// ON PURPOSE: invariant I5 compares two independent implementations —
// sharing the code would blind the property tests to a bug in the
// shared half. The I5 suites exercise every one of these ops on both
// paths, so any drift fails loudly.

/// Parameterized integer pool (Caffe ceil-mode geometry) over a ring,
/// producing output rows `[o0, o1)`.
fn pool_rows(spec: PoolSpec, input: &RowSrc, d: &StageDims, o0: usize, o1: usize) -> RowBuf {
    let (k, stride, pad) = (spec.k, spec.stride, spec.pad);
    let ow = d.out_w;
    let mut out = RowBuf::new(d.in_c, o0, o1, ow);
    for cc in 0..d.in_c {
        for oy in o0..o1 {
            // Window rows clipped to the input (pad taps excluded).
            let wy0 = (oy * stride).saturating_sub(pad);
            let wy1 = (oy * stride + k - pad).min(d.in_h);
            for ox in 0..ow {
                let wx0 = (ox * stride).saturating_sub(pad);
                let wx1 = (ox * stride + k - pad).min(d.in_w);
                let v = match spec.kind {
                    PoolKind::Max => {
                        let mut m = i32::MIN;
                        for y in wy0..wy1 {
                            for xx in wx0..wx1 {
                                m = m.max(input.get(cc, y, xx));
                            }
                        }
                        m
                    }
                    PoolKind::Avg => {
                        let mut s: i64 = 0;
                        for y in wy0..wy1 {
                            for xx in wx0..wx1 {
                                s += input.get(cc, y, xx) as i64;
                            }
                        }
                        let taps = ((wy1 - wy0) * (wx1 - wx0)) as i64;
                        s.div_euclid(taps) as i32
                    }
                };
                let oi = out.index(cc, oy, ox);
                out.data[oi] = v;
            }
        }
    }
    out
}

/// Concatenate feature maps along the channel axis (branch arm order).
fn concat_channels(parts: &[Tensor<i32>]) -> crate::Result<Tensor<i32>> {
    let [n, _, h, w] = match parts.first().map(|p| p.shape()) {
        Some(&[n, c, h, w]) => [n, c, h, w],
        _ => return Err(crate::Error::Shape("concat needs 4-D inputs".into())),
    };
    let mut total_c = 0usize;
    for p in parts {
        match *p.shape() {
            [pn, pc, ph, pw] if pn == n && ph == h && pw == w => total_c += pc,
            _ => {
                return Err(crate::Error::Shape(format!(
                    "concat arm shape {:?} incompatible with (N={n}, H={h}, W={w})",
                    p.shape()
                )))
            }
        }
    }
    let plane = h * w;
    let mut out: Tensor<i32> = Tensor::zeros(&[n, total_c, h, w]);
    let mut c_off = 0usize;
    for p in parts {
        let pc = p.shape()[1];
        for b in 0..n {
            let src = &p.data()[b * pc * plane..(b + 1) * pc * plane];
            let dst = (b * total_c + c_off) * plane;
            out.data_mut()[dst..dst + pc * plane].copy_from_slice(src);
        }
        c_off += pc;
    }
    Ok(out)
}

/// Global average pool: i64 sum then floor division (matches jnp `//`).
fn global_avg_pool(x: &Tensor<i32>) -> crate::Result<Tensor<i32>> {
    let [n, c, h, w] = match *x.shape() {
        [n, c, h, w] => [n, c, h, w],
        _ => return Err(crate::Error::Shape("GAP input must be 4-D".into())),
    };
    let mut feats: Tensor<i32> = Tensor::zeros(&[n, c]);
    for b in 0..n {
        for cc in 0..c {
            let mut s: i64 = 0;
            for y in 0..h {
                for xx in 0..w {
                    s += x.get4(b, cc, y, xx) as i64;
                }
            }
            feats.data_mut()[b * c + cc] = s.div_euclid((h * w) as i64) as i32;
        }
    }
    Ok(feats)
}

/// FC head over pre-kneaded class lanes, parallel across batch rows
/// within the caller's thread budget.
fn fc_parallel(
    fc: &CompiledFc,
    x: &Tensor<i32>,
    mode: crate::config::Mode,
    workers: usize,
) -> crate::Result<Tensor<i32>> {
    let [n, d] = match *x.shape() {
        [n, d] => [n, d],
        _ => return Err(crate::Error::Shape("FC input must be 2-D (N, feat)".into())),
    };
    if d != fc.feat_dim {
        return Err(crate::Error::Shape(format!(
            "FC feature dim {d} != compiled {}",
            fc.feat_dim
        )));
    }
    let items: Vec<usize> = (0..n).collect();
    let rows: Vec<Vec<i32>> = par_map_with(workers, &items, |_, &b| {
        let acts = &x.data()[b * d..(b + 1) * d];
        let mut segs = SegmentRegisters::new(mode.weight_bits());
        let mut logits = vec![0i32; fc.classes];
        for (k, klane) in fc.lanes.iter().enumerate() {
            for (g, group) in klane.groups.iter().enumerate() {
                let start = g * klane.ks;
                let end = (start + klane.ks).min(d);
                split_kneaded(group, &acts[start..end], &mut segs);
            }
            logits[k] = rear_adder_tree(segs.values()) as i32;
            segs.reset();
        }
        logits
    });
    let mut out: Tensor<i32> = Tensor::zeros(&[n, fc.classes]);
    for (b, row) in rows.iter().enumerate() {
        out.data_mut()[b * fc.classes..(b + 1) * fc.classes].copy_from_slice(row);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::coordinator::SacBackend;
    use crate::model::zoo;
    use crate::plan::CompiledNetwork;
    use crate::util::rng::Rng;

    fn image_batch(n: usize, seed: u64) -> Tensor<i32> {
        let mut t = Tensor::zeros(&[n, 1, 16, 16]);
        let mut rng = Rng::new(seed);
        for v in t.data_mut() {
            *v = rng.range_i64(-400, 400) as i32;
        }
        t
    }

    /// Wrap a single-image NCHW tensor as a full-height ring.
    fn buf_of(x: &Tensor<i32>) -> RowBuf {
        let [n, c, h, w] = match *x.shape() {
            [n, c, h, w] => [n, c, h, w],
            _ => panic!("4-D input"),
        };
        assert_eq!(n, 1, "single image");
        RowBuf { c, y0: 0, y1: h, w, data: x.data().to_vec() }
    }

    fn pool_dims(c: usize, h: usize, w: usize, spec: PoolSpec) -> StageDims {
        StageDims {
            in_c: c,
            in_h: h,
            in_w: w,
            out_c: c,
            out_h: spec.out_hw(h).unwrap(),
            out_w: spec.out_hw(w).unwrap(),
        }
    }

    #[test]
    fn execute_produces_logits_and_is_deterministic() {
        let w = SacBackend::synthetic_weights(5).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        let x = image_batch(3, 1);
        let a = plan.execute(&x).unwrap();
        let b = plan.execute(&x).unwrap();
        assert_eq!(a.shape(), &[3, 4]);
        assert_eq!(a, b);
    }

    #[test]
    fn execute_rejects_wrong_channels() {
        let w = SacBackend::synthetic_weights(5).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        assert!(plan.execute(&Tensor::zeros(&[1, 2, 16, 16])).is_err());
    }

    #[test]
    fn tile_height_and_budget_never_change_logits() {
        // Invariant I5 over tilings: every tile height (dividing the
        // output rows or not), the materializing baseline, and every
        // thread budget produce bit-identical logits.
        let w = SacBackend::synthetic_weights(9).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        let x = image_batch(2, 3);
        let want = plan.execute_opts(&x, ExecOpts::materializing()).unwrap();
        for tile in [1usize, 2, 3, 5, 7, 100] {
            for workers in [1usize, 3, 8] {
                let got = plan
                    .execute_opts(&x, ExecOpts::tiled(tile).with_workers(workers))
                    .unwrap();
                assert_eq!(got, want, "tile={tile} workers={workers}");
            }
        }
        assert_eq!(plan.execute(&x).unwrap(), want, "default path drifted");
    }

    #[test]
    fn traced_tiled_peak_is_below_materializing_peak() {
        let w = SacBackend::synthetic_weights(4).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        let x = image_batch(1, 7);
        let (full, peak_full) = plan
            .execute_traced(&x, ExecOpts::materializing().with_workers(1))
            .unwrap();
        let (tiled, peak_tiled) = plan
            .execute_traced(&x, ExecOpts::tiled(1).with_workers(1))
            .unwrap();
        assert_eq!(full, tiled);
        assert!(
            peak_tiled < peak_full,
            "tiled peak {peak_tiled} not below materializing peak {peak_full}"
        );
    }

    #[test]
    fn pool_rows_2x2_matches_legacy_truncating_maxpool_on_even_extents() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 9, -4, 3]).unwrap();
        let spec = PoolSpec::max(2, 2, 0);
        let buf = buf_of(&x);
        let p = pool_rows(spec, &RowSrc::Ring(&buf), &pool_dims(1, 2, 2, spec), 0, 1);
        assert_eq!((p.c, p.rows(), p.w), (1, 1, 1));
        assert_eq!(p.data, &[9]);
        // Stage 0 reads the tensor in place — same values either way.
        let q = pool_rows(
            spec,
            &RowSrc::Tensor { x: &x, b: 0 },
            &pool_dims(1, 2, 2, spec),
            0,
            1,
        );
        assert_eq!(p.data, q.data);
    }

    #[test]
    fn pool_rows_3x3_stride2_uses_ceil_windows() {
        // 1×8 row, k=3 s=2 pad=1 (the pad keeps the 1-tall height
        // legal). Width: ceil((8+2-3)/2)+1 = 5 windows, the last one
        // clipped to the single in-bounds tap at index 7 — padding
        // never wins a max, so a negative value survives there.
        let x = Tensor::from_vec(&[1, 1, 1, 8], vec![0, 1, 2, 3, 4, 5, 6, -7]).unwrap();
        let spec = PoolSpec::max(3, 2, 1);
        let buf = buf_of(&x);
        let p = pool_rows(spec, &RowSrc::Ring(&buf), &pool_dims(1, 1, 8, spec), 0, 1);
        assert_eq!((p.c, p.rows(), p.w), (1, 1, 5));
        assert_eq!(p.data, &[1, 3, 5, 6, -7]);
    }

    #[test]
    fn avg_pool_rows_floor_divides_inbounds_taps() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 2, 3, -5]).unwrap();
        let buf = buf_of(&x);
        let spec = PoolSpec::avg(2, 2, 0);
        let p = pool_rows(spec, &RowSrc::Ring(&buf), &pool_dims(1, 2, 2, spec), 0, 1);
        // (1+2+3-5) = 1, 4 taps → 1.div_euclid(4) = 0.
        assert_eq!(p.data, &[0]);
        // Padded window clips to in-bounds taps: pad 1, k 2, stride 2 →
        // out 2×2, each window holds exactly one in-bounds value.
        let spec = PoolSpec::avg(2, 2, 1);
        let p = pool_rows(spec, &RowSrc::Ring(&buf), &pool_dims(1, 2, 2, spec), 0, 2);
        assert_eq!((p.c, p.rows(), p.w), (1, 2, 2));
        assert_eq!(p.data, &[1, 2, 3, -5]);
    }

    #[test]
    fn concat_stacks_channel_slices_in_arm_order() {
        let a = Tensor::from_vec(&[2, 1, 1, 2], vec![1, 2, 3, 4]).unwrap();
        let b = Tensor::from_vec(&[2, 2, 1, 2], vec![5, 6, 7, 8, 9, 10, 11, 12]).unwrap();
        let cat = concat_channels(&[a, b]).unwrap();
        assert_eq!(cat.shape(), &[2, 3, 1, 2]);
        assert_eq!(cat.data(), &[1, 2, 5, 6, 7, 8, 3, 4, 9, 10, 11, 12]);
        // Mismatched spatial sizes are rejected.
        let c = Tensor::from_vec(&[2, 1, 2, 1], vec![0; 4]).unwrap();
        let d = Tensor::from_vec(&[2, 1, 1, 2], vec![0; 4]).unwrap();
        assert!(concat_channels(&[c, d]).is_err());
    }

    // Plan ≡ scalar-forward equivalence (invariant I5) lives in
    // rust/tests/plan_exec.rs (tiny CNN / VGG block) and
    // rust/tests/plan_topology.rs (full declared-topology zoo); the
    // tile-sweep extension in rust/tests/plan_tiling.rs;
    // zero-rekneading in plan_zero_knead.rs.
}
