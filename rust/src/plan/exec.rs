//! The run-time half of the split: walk the op graph, stream the
//! pre-kneaded lanes through SAC, never knead.
//!
//! Parallelism (§Perf): the conv hot loop fans out over (image,
//! output-row) stripes via `util::pool::par_map` — each stripe gathers
//! the activation window once per output pixel and shares it across
//! every filter (the same reuse the legacy scalar path exploited), and
//! `par_map`'s striped assignment keeps the output order deterministic,
//! so results are bit-identical for any `TETRIS_THREADS` setting.
//! The FC head fans out over batch rows. Branch arms run in sequence —
//! each arm's convs already saturate the worker pool — and concatenate
//! along the channel axis in arm order.
//!
//! Every arithmetic step mirrors a plain scalar reference exactly (same
//! gather order, same group windows, same `i64 → i32` casts): the
//! legacy `runtime::quantized::forward_scalar` pipeline for the tiny
//! CNN, and the naive MAC interpreter `model::reference` for the full
//! declared-topology zoo. That is what makes invariant I5
//! — plan ≡ scalar, bit for bit — hold by construction and testable by
//! equality. Pool windows use Caffe ceil-mode sizing
//! ([`PoolSpec::out_hw`]); max pools take the window's in-bounds
//! maximum (padding never wins), average pools floor-divide the i64 sum
//! by the in-bounds tap count.

use crate::model::{PoolKind, PoolSpec, Tensor};
use crate::quant::requantize;
use crate::sac::{rear_adder_tree, split_kneaded, SegmentRegisters};
use crate::util::pool::par_map;

use super::compiled::{CompiledConv, CompiledFc, CompiledNetwork};
use super::graph::PlanOp;

impl CompiledNetwork {
    /// Execute the plan on a Q8.8 input batch (N, C, H, W).
    ///
    /// Returns int32 logits (N, classes) for classifier plans, or the
    /// final feature map — (N, C', H', W'), or (N, C') after a declared
    /// global-average head — for conv-only plans. The input spatial
    /// size may differ from the zoo's recorded `in_hw` — the executor
    /// derives all spatial extents from the tensor itself (used by
    /// tests/benches to run scaled workloads).
    pub fn execute(&self, x: &Tensor<i32>) -> crate::Result<Tensor<i32>> {
        self.check_input(x)?;
        self.run_ops(&self.ops, x.clone())
    }

    /// Walk one op list (the whole plan, or one branch arm).
    fn run_ops(&self, ops: &[PlanOp], mut h: Tensor<i32>) -> crate::Result<Tensor<i32>> {
        for op in ops {
            h = match op {
                PlanOp::Conv { layer, pad, stride } => {
                    conv_parallel(&self.convs[*layer], &h, *pad, *stride, self.mode)?
                }
                PlanOp::ReluRequant { frac_bits } => {
                    for v in h.data_mut() {
                        *v = requantize(*v, *frac_bits).max(0);
                    }
                    h
                }
                PlanOp::Pool(spec) => pool(&h, *spec)?,
                PlanOp::Branch { arms } => {
                    // derive_graph guarantees ≥2 arms; the last arm
                    // takes `h` by move instead of one more clone.
                    let (last, init) = arms.split_last().expect("branch has arms");
                    let mut parts = Vec::with_capacity(arms.len());
                    for arm in init {
                        parts.push(self.run_ops(arm, h.clone())?);
                    }
                    parts.push(self.run_ops(last, h)?);
                    concat_channels(&parts)?
                }
                PlanOp::GlobalAvgPool => global_avg_pool(&h)?,
                PlanOp::Fc => {
                    let fc = self.fc.as_ref().ok_or_else(|| {
                        crate::Error::Config("plan has an Fc op but no compiled head".into())
                    })?;
                    fc_parallel(fc, &h, self.mode)?
                }
            };
        }
        Ok(h)
    }
}

/// Integer conv over pre-kneaded filter lanes, parallel across
/// (image, output-row) stripes.
fn conv_parallel(
    conv: &CompiledConv,
    x: &Tensor<i32>,
    pad: usize,
    stride: usize,
    mode: crate::config::Mode,
) -> crate::Result<Tensor<i32>> {
    let (n, c, h, w) = match *x.shape() {
        [n, c, h, w] => (n, c, h, w),
        _ => return Err(crate::Error::Shape("conv input must be 4-D".into())),
    };
    if c != conv.in_c {
        return Err(crate::Error::Shape(format!(
            "{}: input channels {c} != weight channels {}",
            conv.name, conv.in_c
        )));
    }
    if stride == 0 {
        return Err(crate::Error::Config(format!("{}: stride 0", conv.name)));
    }
    let (kh, kw) = (conv.kh, conv.kw);
    if h + 2 * pad < kh || w + 2 * pad < kw {
        return Err(crate::Error::Shape(format!(
            "{}: {h}×{w} input (pad {pad}) smaller than {kh}×{kw} kernel",
            conv.name
        )));
    }
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let o = conv.out_c;
    let lane_len = conv.lane_len();

    // One work item per (image, output row): coarse enough that the
    // im2col gather is amortized across all filters of the row, fine
    // enough that a batch of 8 tiny-CNN images yields n·oh ≥ 128 items.
    let rows: Vec<(usize, usize)> = (0..n)
        .flat_map(|b| (0..oh).map(move |oy| (b, oy)))
        .collect();
    let row_vals: Vec<Vec<i32>> = par_map(&rows, |_, &(b, oy)| {
        let mut acts = vec![0i32; lane_len];
        let mut segs = SegmentRegisters::new(mode.weight_bits());
        let mut out_row = vec![0i32; o * ow];
        for ox in 0..ow {
            // Gather the activation window (im2col row) in OIHW weight
            // order: (c, ky, kx) — once, shared by every filter.
            let mut idx = 0;
            for cc in 0..c {
                for ky in 0..kh {
                    for kx in 0..kw {
                        let iy = oy * stride + ky;
                        let ix = ox * stride + kx;
                        acts[idx] = if iy < pad || ix < pad || iy - pad >= h || ix - pad >= w {
                            0
                        } else {
                            x.get4(b, cc, iy - pad, ix - pad)
                        };
                        idx += 1;
                    }
                }
            }
            for (f, klane) in conv.lanes.iter().enumerate() {
                for (g, group) in klane.groups.iter().enumerate() {
                    let start = g * klane.ks;
                    let end = (start + klane.ks).min(lane_len);
                    split_kneaded(group, &acts[start..end], &mut segs);
                }
                out_row[f * ow + ox] = rear_adder_tree(segs.values()) as i32;
                segs.reset();
            }
        }
        out_row
    });

    let mut out: Tensor<i32> = Tensor::zeros(&[n, o, oh, ow]);
    for (&(b, oy), row) in rows.iter().zip(&row_vals) {
        for f in 0..o {
            for ox in 0..ow {
                out.set4(b, f, oy, ox, row[f * ow + ox]);
            }
        }
    }
    Ok(out)
}

// The pool/GAP/relu bodies below duplicate the scalar reference paths
// (`runtime::quantized` and the naive interpreter `model::reference`)
// ON PURPOSE: invariant I5 compares two independent implementations —
// sharing the code would blind the property tests to a bug in the
// shared half. The I5 suites exercise every one of these ops on both
// paths, so any drift fails loudly.

/// Parameterized integer pool (Caffe ceil-mode geometry).
fn pool(x: &Tensor<i32>, spec: PoolSpec) -> crate::Result<Tensor<i32>> {
    let [n, c, h, w] = match *x.shape() {
        [n, c, h, w] => [n, c, h, w],
        _ => return Err(crate::Error::Shape("pool input must be 4-D".into())),
    };
    let (oh, ow) = (spec.out_hw(h)?, spec.out_hw(w)?);
    let (k, stride, pad) = (spec.k, spec.stride, spec.pad);
    let mut out: Tensor<i32> = Tensor::zeros(&[n, c, oh, ow]);
    for b in 0..n {
        for cc in 0..c {
            for oy in 0..oh {
                // Window rows clipped to the input (pad taps excluded).
                let y0 = (oy * stride).saturating_sub(pad);
                let y1 = (oy * stride + k - pad).min(h);
                for ox in 0..ow {
                    let x0 = (ox * stride).saturating_sub(pad);
                    let x1 = (ox * stride + k - pad).min(w);
                    let v = match spec.kind {
                        PoolKind::Max => {
                            let mut m = i32::MIN;
                            for y in y0..y1 {
                                for xx in x0..x1 {
                                    m = m.max(x.get4(b, cc, y, xx));
                                }
                            }
                            m
                        }
                        PoolKind::Avg => {
                            let mut s: i64 = 0;
                            for y in y0..y1 {
                                for xx in x0..x1 {
                                    s += x.get4(b, cc, y, xx) as i64;
                                }
                            }
                            let taps = ((y1 - y0) * (x1 - x0)) as i64;
                            s.div_euclid(taps) as i32
                        }
                    };
                    out.set4(b, cc, oy, ox, v);
                }
            }
        }
    }
    Ok(out)
}

/// Concatenate feature maps along the channel axis (branch arm order).
fn concat_channels(parts: &[Tensor<i32>]) -> crate::Result<Tensor<i32>> {
    let [n, _, h, w] = match parts.first().map(|p| p.shape()) {
        Some(&[n, c, h, w]) => [n, c, h, w],
        _ => return Err(crate::Error::Shape("concat needs 4-D inputs".into())),
    };
    let mut total_c = 0usize;
    for p in parts {
        match *p.shape() {
            [pn, pc, ph, pw] if pn == n && ph == h && pw == w => total_c += pc,
            _ => {
                return Err(crate::Error::Shape(format!(
                    "concat arm shape {:?} incompatible with (N={n}, H={h}, W={w})",
                    p.shape()
                )))
            }
        }
    }
    let plane = h * w;
    let mut out: Tensor<i32> = Tensor::zeros(&[n, total_c, h, w]);
    let mut c_off = 0usize;
    for p in parts {
        let pc = p.shape()[1];
        for b in 0..n {
            let src = &p.data()[b * pc * plane..(b + 1) * pc * plane];
            let dst = (b * total_c + c_off) * plane;
            out.data_mut()[dst..dst + pc * plane].copy_from_slice(src);
        }
        c_off += pc;
    }
    Ok(out)
}

/// Global average pool: i64 sum then floor division (matches jnp `//`).
fn global_avg_pool(x: &Tensor<i32>) -> crate::Result<Tensor<i32>> {
    let [n, c, h, w] = match *x.shape() {
        [n, c, h, w] => [n, c, h, w],
        _ => return Err(crate::Error::Shape("GAP input must be 4-D".into())),
    };
    let mut feats: Tensor<i32> = Tensor::zeros(&[n, c]);
    for b in 0..n {
        for cc in 0..c {
            let mut s: i64 = 0;
            for y in 0..h {
                for xx in 0..w {
                    s += x.get4(b, cc, y, xx) as i64;
                }
            }
            feats.data_mut()[b * c + cc] = s.div_euclid((h * w) as i64) as i32;
        }
    }
    Ok(feats)
}

/// FC head over pre-kneaded class lanes, parallel across batch rows.
fn fc_parallel(
    fc: &CompiledFc,
    x: &Tensor<i32>,
    mode: crate::config::Mode,
) -> crate::Result<Tensor<i32>> {
    let [n, d] = match *x.shape() {
        [n, d] => [n, d],
        _ => return Err(crate::Error::Shape("FC input must be 2-D (N, feat)".into())),
    };
    if d != fc.feat_dim {
        return Err(crate::Error::Shape(format!(
            "FC feature dim {d} != compiled {}",
            fc.feat_dim
        )));
    }
    let items: Vec<usize> = (0..n).collect();
    let rows: Vec<Vec<i32>> = par_map(&items, |_, &b| {
        let acts = &x.data()[b * d..(b + 1) * d];
        let mut segs = SegmentRegisters::new(mode.weight_bits());
        let mut logits = vec![0i32; fc.classes];
        for (k, klane) in fc.lanes.iter().enumerate() {
            for (g, group) in klane.groups.iter().enumerate() {
                let start = g * klane.ks;
                let end = (start + klane.ks).min(d);
                split_kneaded(group, &acts[start..end], &mut segs);
            }
            logits[k] = rear_adder_tree(segs.values()) as i32;
            segs.reset();
        }
        logits
    });
    let mut out: Tensor<i32> = Tensor::zeros(&[n, fc.classes]);
    for (b, row) in rows.iter().enumerate() {
        out.data_mut()[b * fc.classes..(b + 1) * fc.classes].copy_from_slice(row);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::coordinator::SacBackend;
    use crate::model::zoo;
    use crate::plan::CompiledNetwork;
    use crate::util::rng::Rng;

    fn image_batch(n: usize, seed: u64) -> Tensor<i32> {
        let mut t = Tensor::zeros(&[n, 1, 16, 16]);
        let mut rng = Rng::new(seed);
        for v in t.data_mut() {
            *v = rng.range_i64(-400, 400) as i32;
        }
        t
    }

    #[test]
    fn execute_produces_logits_and_is_deterministic() {
        let w = SacBackend::synthetic_weights(5).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        let x = image_batch(3, 1);
        let a = plan.execute(&x).unwrap();
        let b = plan.execute(&x).unwrap();
        assert_eq!(a.shape(), &[3, 4]);
        assert_eq!(a, b);
    }

    #[test]
    fn execute_rejects_wrong_channels() {
        let w = SacBackend::synthetic_weights(5).unwrap();
        let plan = CompiledNetwork::compile(&zoo::tiny_cnn(), &w, 16, Mode::Fp16).unwrap();
        assert!(plan.execute(&Tensor::zeros(&[1, 2, 16, 16])).is_err());
    }

    #[test]
    fn pool_2x2_matches_legacy_truncating_maxpool_on_even_extents() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 9, -4, 3]).unwrap();
        let p = pool(&x, PoolSpec::max(2, 2, 0)).unwrap();
        assert_eq!(p.shape(), &[1, 1, 1, 1]);
        assert_eq!(p.data(), &[9]);
    }

    #[test]
    fn pool_3x3_stride2_uses_ceil_windows() {
        // 1×8 row, k=3 s=2 pad=1 (the pad keeps the 1-tall height
        // legal). Width: ceil((8+2-3)/2)+1 = 5 windows, the last one
        // clipped to the single in-bounds tap at index 7 — padding
        // never wins a max, so a negative value survives there.
        let x = Tensor::from_vec(&[1, 1, 1, 8], vec![0, 1, 2, 3, 4, 5, 6, -7]).unwrap();
        let p = pool(&x, PoolSpec::max(3, 2, 1)).unwrap();
        assert_eq!(p.shape(), &[1, 1, 1, 5]);
        assert_eq!(p.data(), &[1, 3, 5, 6, -7]);
    }

    #[test]
    fn avg_pool_floor_divides_inbounds_taps() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 2, 3, -5]).unwrap();
        let p = pool(&x, PoolSpec::avg(2, 2, 0)).unwrap();
        // (1+2+3-5) = 1, 4 taps → 1.div_euclid(4) = 0.
        assert_eq!(p.data(), &[0]);
        // Padded window clips to in-bounds taps: pad 1, k 2, stride 2 →
        // out 2×2, each window holds exactly one in-bounds value.
        let p = pool(&x, PoolSpec::avg(2, 2, 1)).unwrap();
        assert_eq!(p.shape(), &[1, 1, 2, 2]);
        assert_eq!(p.data(), &[1, 2, 3, -5]);
    }

    #[test]
    fn concat_stacks_channel_slices_in_arm_order() {
        let a = Tensor::from_vec(&[2, 1, 1, 2], vec![1, 2, 3, 4]).unwrap();
        let b = Tensor::from_vec(&[2, 2, 1, 2], vec![5, 6, 7, 8, 9, 10, 11, 12]).unwrap();
        let cat = concat_channels(&[a, b]).unwrap();
        assert_eq!(cat.shape(), &[2, 3, 1, 2]);
        assert_eq!(cat.data(), &[1, 2, 5, 6, 7, 8, 3, 4, 9, 10, 11, 12]);
        // Mismatched spatial sizes are rejected.
        let c = Tensor::from_vec(&[2, 1, 2, 1], vec![0; 4]).unwrap();
        let d = Tensor::from_vec(&[2, 1, 1, 2], vec![0; 4]).unwrap();
        assert!(concat_channels(&[c, d]).is_err());
    }

    // Plan ≡ scalar-forward equivalence (invariant I5) lives in
    // rust/tests/plan_exec.rs (tiny CNN / VGG block) and
    // rust/tests/plan_topology.rs (full declared-topology zoo);
    // zero-rekneading in plan_zero_knead.rs.
}
