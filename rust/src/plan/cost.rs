//! Roofline-style analytical cost model for the schedule auto-tuner
//! (`plan::tune`).
//!
//! Every candidate schedule — a (walk, tile height) pair against a
//! compiled plan — gets a [`CostEstimate`] with three legs:
//!
//! * **memory**: predicted peak feature-map bytes, reusing the plan's
//!   walk-matched estimators (`peak_bytes_estimate` /
//!   `streaming_peak_bytes_estimate` / `pipelined_peak_bytes_estimate`)
//!   — the same arithmetic the budget ladder sizes tiles with;
//! * **traffic**: DRAM-equivalent bytes moved per image. Every segment
//!   boundary map is written once by its producer and read once by its
//!   consumer (branch concat adds one write of the concatenated map);
//!   the tiled walk additionally pays for its recomputed halo rows
//!   (re-emitted stage-output bytes), and the pipelined walk skips the
//!   whole trunk prefix — over the pipeable segments only the input
//!   map is read and the trunk output written, exactly the dataflow
//!   [`run_pipelined`](super::exec) executes;
//! * **compute**: simulated SAC cycles per image (walk-invariant — the
//!   walks move the same MACs — so it is supplied by the caller: the
//!   engine already simulates every registration, `tetris tune`
//!   simulates on demand, and `0` means "traffic-led scoring").
//!
//! [`CostEstimate::score`] is the roofline bound:
//! `max(compute_cycles, traffic_bytes / DRAM_BYTES_PER_CYCLE)`.
//!
//! **Validation contract** (pinned by `tests/plan_tune.rs`): across
//! the zoo × walks × tile heights × budgets, `execute_traced`'s
//! measured peak brackets the predicted peak within
//! [`PEAK_BRACKET_FACTOR`] on both sides, and the predicted tiled halo
//! rows equal the measured `halo_recompute_rows` **exactly** — the
//! halo arithmetic below is a line-for-line replica of the executor's
//! boundary walk over the same `resolve_stage_dims` geometry.

use super::compiled::CompiledNetwork;
use super::exec::{self, Kernel, StageDims, Walk};
use super::graph::{FusedStage, Segment};

/// DRAM-equivalent bandwidth normalizer: bytes the accelerator's
/// eDRAM/DRAM interface moves per cycle (DaDianNao-class nodes stream
/// one 16-lane fp16 word group per cycle ≈ 16 B). Converts the traffic
/// leg into cycles so it lands on the same axis as the compute leg.
pub const DRAM_BYTES_PER_CYCLE: u64 = 16;

/// Two-sided tolerance of the peak-bytes validation contract: the
/// measured peak must lie within `[predicted / 4, predicted × 4]`.
/// The estimators are per-image worst-case concurrency bounds (ring
/// bytes scale with the worker budget; a short batch stripes fewer
/// threads), so they may over-predict by up to the worker fan-out —
/// the bracket is pinned wide enough to hold zoo-wide and tight
/// enough to catch a wrong ring formula, which is off by O(depth).
pub const PEAK_BRACKET_FACTOR: u64 = 4;

/// Feature-map element width (Q8.8 stored as i32), matching both the
/// executor's `tensor_bytes` accounting and the plan estimators.
const BYTES: u64 = 4;

/// One scored schedule candidate: the cost model's three legs for a
/// (walk, tile height) pair. Produced by [`CostModel::estimate`].
#[derive(Debug, Clone, Copy)]
pub struct CostEstimate {
    /// The dataflow this candidate runs.
    pub walk: Walk,
    /// Tile height (tiled walk) / ring-advance step (streaming walks).
    pub tile_rows: usize,
    /// Predicted peak feature-map bytes per image at the model's
    /// worker fan-out (the walk-matched plan estimator).
    pub peak_bytes: u64,
    /// Predicted DRAM-equivalent bytes moved per image.
    pub traffic_bytes: u64,
    /// Predicted halo-recompute rows per image (tiled walk only;
    /// always 0 for the streaming and pipelined walks).
    pub halo_rows: u64,
    /// Simulated SAC cycles per image (0 = unknown / traffic-led).
    pub compute_cycles: u64,
}

impl CostEstimate {
    /// Roofline latency bound in cycles:
    /// `max(compute, traffic / DRAM_BYTES_PER_CYCLE)`.
    pub fn score(&self) -> u64 {
        self.compute_cycles.max(self.traffic_bytes.div_ceil(DRAM_BYTES_PER_CYCLE))
    }

    /// Whether the predicted peak stays inside a memory budget.
    pub fn fits(&self, budget_bytes: u64) -> bool {
        self.peak_bytes <= budget_bytes
    }
}

/// Traffic/halo accumulator for one schedule sweep.
#[derive(Default)]
struct Acc {
    traffic: u64,
    halo_rows: u64,
}

/// Analytical cost model over one compiled plan at a fixed worker
/// fan-out. Stateless and cheap — every estimate is pure arithmetic
/// over the plan's segment geometry; nothing executes and nothing
/// kneads.
pub struct CostModel<'a> {
    plan: &'a CompiledNetwork,
    workers: usize,
    compute_cycles: u64,
    sparsity_survival: Option<f64>,
    kernel: Kernel,
}

impl<'a> CostModel<'a> {
    /// Model `plan` at `workers` concurrent workers (clamped to ≥ 1).
    /// The conv kernel defaults to [`Kernel::Legacy`]'s per-window
    /// constant — attach the plan's actual kernel with
    /// [`CostModel::with_kernel`].
    pub fn new(plan: &'a CompiledNetwork, workers: usize) -> Self {
        Self {
            plan,
            workers: workers.max(1),
            compute_cycles: 0,
            sparsity_survival: None,
            kernel: Kernel::Legacy,
        }
    }

    /// Attach the simulated per-image SAC cycle count (the compute
    /// leg). Without it, scores are traffic-led — fine for ranking
    /// within one model, where the compute leg is walk-invariant.
    pub fn with_compute_cycles(mut self, cycles: u64) -> Self {
        self.compute_cycles = cycles;
        self
    }

    /// Attach a **measured** activation-sparsity survival fraction —
    /// the fraction of conv windows the skip lane actually executes
    /// (`1 − AllocStats::window_skip_fraction()`, captured from a
    /// traced run with `ExecOpts::skip_zero_activations` on). The
    /// compute leg is scaled by it, so the roofline can price the
    /// activation-skipping lane: a plan that skips 40% of its windows
    /// scores `max(0.6 × compute, traffic)`. Clamped to `[0, 1]`;
    /// traffic and peak legs are unchanged (skipped windows still move
    /// their input rows — the masks only gate SAC work). Like the
    /// compute leg itself, the survival fraction is walk-invariant:
    /// the walks visit the same windows over the same activations.
    pub fn with_measured_sparsity(mut self, survival: f64) -> Self {
        self.sparsity_survival = Some(survival.clamp(0.0, 1.0));
        self
    }

    /// Attach the conv kernel the plan will execute with. The decoded
    /// kernel retired the per-window slot-decode work to compile time,
    /// so its per-window compute constant is lower: the compute leg is
    /// scaled by the plan's add share — `Σ adds / (Σ decodes + Σ adds)`
    /// over every conv's decoded schedule (1.0 when the plan has no
    /// conv work to scale). Composes with
    /// [`CostModel::with_measured_sparsity`]; like that factor it is
    /// walk-invariant, so candidate *ranking* within one kernel is
    /// unchanged — this keeps the tuner's absolute scores honest when
    /// serving compares them against measured runs.
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// The decoded kernel's compute-leg scale factor for this plan
    /// (1.0 under [`Kernel::Legacy`]).
    fn kernel_factor(&self) -> f64 {
        if self.kernel == Kernel::Legacy {
            return 1.0;
        }
        let (mut decodes, mut adds) = (0u64, 0u64);
        for conv in self.plan.convs() {
            decodes += conv.decoded.decodes_per_window;
            adds += conv.decoded.adds_per_window;
        }
        if decodes + adds == 0 {
            1.0
        } else {
            adds as f64 / (decodes + adds) as f64
        }
    }

    /// Score one (walk, tile height) candidate. Errors only if the
    /// plan's geometry fails to resolve at its declared input extent
    /// (which `compile` already validated, so this is effectively
    /// infallible for zoo plans).
    pub fn estimate(&self, walk: Walk, tile_rows: usize) -> crate::Result<CostEstimate> {
        let peak_bytes = match walk {
            Walk::Tiled => self.plan.peak_bytes_estimate(tile_rows, self.workers),
            Walk::Streaming => self.plan.streaming_peak_bytes_estimate(tile_rows, self.workers),
            Walk::Pipelined => self.plan.pipelined_peak_bytes_estimate(tile_rows, self.workers),
        };
        let (traffic_bytes, halo_rows) = self.traffic(walk, tile_rows)?;
        let survival = self.sparsity_survival.unwrap_or(1.0);
        let scale = survival * self.kernel_factor();
        let compute_cycles = if scale == 1.0 {
            self.compute_cycles
        } else {
            (self.compute_cycles as f64 * scale).round() as u64
        };
        Ok(CostEstimate { walk, tile_rows, peak_bytes, traffic_bytes, halo_rows, compute_cycles })
    }

    /// Predicted tiled-walk halo-recompute rows **per image** at an
    /// explicit tile height — must equal `execute_traced`'s
    /// `halo_recompute_rows` divided by the batch size exactly (the
    /// executor disables adaptive tile shrinking under explicit
    /// `ExecOpts::tile_rows`, so the boundary walk is deterministic).
    pub fn predicted_halo_rows(&self, tile_rows: usize) -> crate::Result<u64> {
        self.traffic(Walk::Tiled, tile_rows).map(|(_, h)| h)
    }

    /// Traffic + halo legs for one candidate, per image at the plan's
    /// declared input extent.
    fn traffic(&self, walk: Walk, tile_rows: usize) -> crate::Result<(u64, u64)> {
        let (c0, hw) = self.plan.declared_in;
        let mut acc = Acc::default();
        if walk == Walk::Pipelined {
            let step = if tile_rows == 0 { hw } else { tile_rows };
            if let Some(s) = exec::pipeline_summary(self.plan, c0, hw, hw, step.max(1))? {
                if s.segments > 0 {
                    // Trunk prefix maps never materialize: the input
                    // map is read and the trunk output written, full
                    // stop. Chain dims through the prefix (discarding
                    // its would-be traffic), then charge the tail.
                    acc.traffic += map_bytes(c0, hw, hw) + s.out_bytes;
                    let sched = self.plan.schedule();
                    let mut cur = (c0, hw, hw);
                    let mut scratch = Acc::default();
                    for seg in &sched[..s.segments] {
                        cur = self.seg_pass(seg, cur, Walk::Streaming, tile_rows, &mut scratch)?;
                    }
                    for seg in &sched[s.segments..] {
                        cur = self.seg_pass(seg, cur, Walk::Streaming, tile_rows, &mut acc)?;
                    }
                    return Ok((acc.traffic, acc.halo_rows));
                }
            }
            // Nothing pipeable — the pipelined walk degenerates to the
            // per-segment streaming dataflow, so charge that.
            return self.traffic(Walk::Streaming, tile_rows);
        }
        let mut cur = (c0, hw, hw);
        for seg in self.plan.schedule() {
            cur = self.seg_pass(seg, cur, walk, tile_rows, &mut acc)?;
        }
        Ok((acc.traffic, acc.halo_rows))
    }

    /// Charge one segment's traffic (and, tiled walk, halo) and return
    /// its output extent.
    fn seg_pass(
        &self,
        seg: &Segment,
        cur: (usize, usize, usize),
        walk: Walk,
        tile_rows: usize,
        acc: &mut Acc,
    ) -> crate::Result<(usize, usize, usize)> {
        let (c, h, w) = cur;
        match seg {
            Segment::Fused(stages) => {
                let dims = exec::resolve_stage_dims(self.plan, stages, c, h, w)?;
                let last = dims.last().expect("fused segments are non-empty");
                acc.traffic +=
                    map_bytes(c, h, w) + map_bytes(last.out_c, last.out_h, last.out_w);
                if walk == Walk::Tiled {
                    let (rows, bytes) = fused_halo(stages, &dims, tile_rows);
                    acc.halo_rows += rows;
                    acc.traffic += bytes;
                }
                Ok((last.out_c, last.out_h, last.out_w))
            }
            Segment::Branch(arms) => {
                let mut out_c = 0;
                let (mut oh, mut ow) = (h, w);
                for arm in arms {
                    let mut a = (c, h, w);
                    for s in arm {
                        a = self.seg_pass(s, a, walk, tile_rows, acc)?;
                    }
                    out_c += a.0;
                    (oh, ow) = (a.1, a.2);
                }
                // Channel concat writes the joined map once.
                acc.traffic += map_bytes(out_c, oh, ow);
                Ok((out_c, oh, ow))
            }
            Segment::GlobalAvgPool => {
                acc.traffic += map_bytes(c, h, w) + c as u64 * BYTES;
                Ok((c, 1, 1))
            }
            Segment::Flatten => Ok((c * h * w, 1, 1)),
            Segment::Fc { name } => {
                let fc = self.plan.fc_head(name).ok_or_else(|| {
                    crate::Error::Config(format!(
                        "plan has an Fc op for `{name}` but no compiled head"
                    ))
                })?;
                acc.traffic += (fc.feat_dim + fc.classes) as u64 * BYTES;
                Ok((fc.classes, 1, 1))
            }
        }
    }
}

fn map_bytes(c: usize, h: usize, w: usize) -> u64 {
    (c * h * w) as u64 * BYTES
}

/// Tiled-walk halo prediction for one fused segment, per image:
/// line-for-line the executor's boundary walk (`run_fused_tiled`) —
/// adjacent tiles' backward spans overlap by up to `k − stride` rows
/// per stage per boundary; summing adjacent-pair overlaps counts a row
/// computed by `j` tiles exactly `j − 1` times. Also returns the
/// recomputed stage-output **bytes** for the traffic leg.
fn fused_halo(stages: &[FusedStage], dims: &[StageDims], tile_rows: usize) -> (u64, u64) {
    let last = dims.last().expect("fused segments are non-empty");
    let oh = last.out_h;
    if oh == 0 {
        return (0, 0);
    }
    let tile = if tile_rows == 0 { oh } else { tile_rows.clamp(1, oh) };
    if tile >= oh {
        return (0, 0);
    }
    let m = stages.len();
    let spans_at = |t0: usize, t1: usize| -> Vec<(usize, usize)> {
        let mut spans = vec![(0usize, 0usize); m + 1];
        spans[m] = (t0, t1);
        for i in (0..m).rev() {
            spans[i] = stages[i].contract.in_span(spans[i + 1].0, spans[i + 1].1, dims[i].in_h);
        }
        spans
    };
    let mut rows = 0u64;
    let mut bytes = 0u64;
    let mut prev = spans_at(0, tile.min(oh));
    let mut t0 = tile;
    while t0 < oh {
        let t1 = (t0 + tile).min(oh);
        let cur = spans_at(t0, t1);
        for i in 0..m {
            let lo = cur[i + 1].0.max(prev[i + 1].0);
            let hi = cur[i + 1].1.min(prev[i + 1].1);
            let overlap = hi.saturating_sub(lo) as u64;
            rows += overlap;
            bytes += overlap * (dims[i].out_c * dims[i].out_w) as u64 * BYTES;
        }
        prev = cur;
        t0 = t1;
    }
    (rows, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::model::weights::{synthetic_loaded, DensityCalibration};
    use crate::model::zoo;

    fn tiny_plan() -> CompiledNetwork {
        let net = zoo::tiny_cnn();
        let w = synthetic_loaded(&net, Mode::Fp16, 12, "tiny_cnn", DensityCalibration::Fig2, 7)
            .unwrap();
        CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap()
    }

    #[test]
    fn tiled_pays_halo_streaming_does_not() {
        let plan = tiny_plan();
        let model = CostModel::new(&plan, 1);
        let tiled = model.estimate(Walk::Tiled, 2).unwrap();
        let streaming = model.estimate(Walk::Streaming, 2).unwrap();
        // tiny_cnn's k=3 s=1 convs overlap at tile boundaries.
        assert!(tiled.halo_rows > 0, "tiled tile=2 must recompute halo rows");
        assert_eq!(streaming.halo_rows, 0);
        assert!(
            tiled.traffic_bytes > streaming.traffic_bytes,
            "halo recompute must show up as extra tiled traffic"
        );
    }

    #[test]
    fn materializing_tile_has_zero_halo() {
        let plan = tiny_plan();
        let model = CostModel::new(&plan, 1);
        assert_eq!(model.predicted_halo_rows(0).unwrap(), 0);
    }

    #[test]
    fn pipelined_traffic_skips_trunk_boundary_maps() {
        let plan = tiny_plan();
        let model = CostModel::new(&plan, 1);
        let streaming = model.estimate(Walk::Streaming, 2).unwrap();
        let pipelined = model.estimate(Walk::Pipelined, 2).unwrap();
        assert!(
            pipelined.traffic_bytes < streaming.traffic_bytes,
            "pipelined must not re-materialize trunk boundary maps \
             ({} !< {})",
            pipelined.traffic_bytes,
            streaming.traffic_bytes
        );
    }

    #[test]
    fn measured_sparsity_scales_the_compute_leg_only() {
        let plan = tiny_plan();
        let dense = CostModel::new(&plan, 1)
            .with_compute_cycles(1_000_000)
            .estimate(Walk::Streaming, 2)
            .unwrap();
        let sparse = CostModel::new(&plan, 1)
            .with_compute_cycles(1_000_000)
            .with_measured_sparsity(0.6)
            .estimate(Walk::Streaming, 2)
            .unwrap();
        assert_eq!(sparse.compute_cycles, 600_000, "compute leg scales by window survival");
        assert_eq!(sparse.traffic_bytes, dense.traffic_bytes, "traffic leg is mask-invariant");
        assert_eq!(sparse.peak_bytes, dense.peak_bytes, "peak leg is mask-invariant");
        // Out-of-range survivals clamp instead of inflating/negating
        // the compute leg.
        let clamped = CostModel::new(&plan, 1)
            .with_compute_cycles(1_000)
            .with_measured_sparsity(7.5)
            .estimate(Walk::Streaming, 2)
            .unwrap();
        assert_eq!(clamped.compute_cycles, 1_000);
    }

    #[test]
    fn decoded_kernel_scales_the_compute_leg_only() {
        let plan = tiny_plan();
        let legacy = CostModel::new(&plan, 1)
            .with_compute_cycles(1_000_000)
            .estimate(Walk::Streaming, 2)
            .unwrap();
        let decoded = CostModel::new(&plan, 1)
            .with_compute_cycles(1_000_000)
            .with_kernel(Kernel::Decoded)
            .estimate(Walk::Streaming, 2)
            .unwrap();
        // The factor is the plan's add share: adds / (decodes + adds),
        // strictly inside (0, 1) for any real kneaded plan — decodes
        // are width × kneaded weights, adds are the essential bits.
        let (mut d, mut a) = (0u64, 0u64);
        for conv in plan.convs() {
            d += conv.decoded.decodes_per_window;
            a += conv.decoded.adds_per_window;
        }
        assert!(d > 0 && a > 0);
        let want = (1_000_000f64 * a as f64 / (d + a) as f64).round() as u64;
        assert_eq!(decoded.compute_cycles, want, "compute leg scales by the add share");
        assert!(decoded.compute_cycles < legacy.compute_cycles);
        assert_eq!(decoded.traffic_bytes, legacy.traffic_bytes, "traffic is kernel-invariant");
        assert_eq!(decoded.peak_bytes, legacy.peak_bytes, "peak is kernel-invariant");
        // Explicitly pinning Legacy is the identity.
        let pinned = CostModel::new(&plan, 1)
            .with_compute_cycles(1_000_000)
            .with_kernel(Kernel::Legacy)
            .estimate(Walk::Streaming, 2)
            .unwrap();
        assert_eq!(pinned.compute_cycles, 1_000_000);
    }

    #[test]
    fn score_is_the_roofline_max() {
        let plan = tiny_plan();
        let model = CostModel::new(&plan, 1).with_compute_cycles(u64::MAX / 2);
        let e = model.estimate(Walk::Streaming, 2).unwrap();
        assert_eq!(e.score(), u64::MAX / 2, "compute-bound candidate scores its cycle count");
        let traffic_led = CostModel::new(&plan, 1).estimate(Walk::Streaming, 2).unwrap();
        assert_eq!(
            traffic_led.score(),
            traffic_led.traffic_bytes.div_ceil(DRAM_BYTES_PER_CYCLE)
        );
    }
}
