//! Compile-time schedule auto-tuner: one entry point that turns
//! (compiled plan × memory budget × worker count) into the
//! [`TunedSchedule`] serving runs with — walk, tile height, branch-arm
//! thread split — replacing the tile/walk selection that used to live
//! twice (in the engine registry's fallover block and the legacy
//! `SacBackend` path) with a single, memoized decision.
//!
//! ## Search space and selection rule
//!
//! Candidates are enumerated over walk ∈ {tiled, streaming, pipelined}
//! × the budget ladder's tile heights ([`TILE_LADDER`]) and scored by
//! the [`cost`](super::cost) model ([`candidates`] exposes the scored
//! table — `tetris tune` renders it). Selection is lexicographic:
//!
//! 1. **predicted-feasible first** — a candidate whose walk-matched
//!    peak estimate fits the budget always beats one that does not;
//! 2. **unpinned before pinned** — when either per-segment walk fits,
//!    the schedule leaves the walk unpinned (`walk: None`) so the
//!    executor's batch rule still picks streaming for covering batches
//!    and tiled for short ones; the pipelined walk is pinned only when
//!    the budget demands whole-network streaming;
//! 3. **lowest roofline score, largest tile on ties** — within the
//!    chosen family the compute leg is walk-invariant and the traffic
//!    leg shrinks as tiles grow (less halo recompute), so this
//!    resolves to the largest tile height that fits: exactly the
//!    budget ladder's answer, which keeps the tuner bit-compatible
//!    with the previous heuristic in every in-budget configuration.
//!
//! When **nothing** fits, the tuner serves the minimum-predicted-peak
//! schedule, sets [`TunedSchedule::over_budget`], and warns once per
//! (plan, budget, workers) — the budget ladder's silent clamp-to-1-row
//! now has an explicit diagnostic.
//!
//! ## Memoization
//!
//! `tune` results are cached per ([`CompiledNetwork::fingerprint`],
//! budget bytes, workers) in a process-wide map, so re-registering the
//! same model (engine rebuilds, multi-engine tests) never re-searches.
//!
//! ## Axes reported but not pinned
//!
//! Batch policy and kneading stride are part of the searched space but
//! advisory in the result: the executor's streaming pivot is reported
//! as [`TunedSchedule::streaming_batch_pivot`] (the walk rule is
//! already optimal under the cost model — streaming strictly dominates
//! tiled on traffic once a batch covers the workers), and re-kneading
//! at a different `ks` would violate the compile-once contract
//! (`kneads_at_build` pins), so `tetris tune` sweeps `ks` in the
//! report instead of mutating the plan.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use super::compiled::CompiledNetwork;
use super::cost::{CostEstimate, CostModel};
use super::exec::Walk;
use super::graph::Segment;

/// The tile heights the budget ladder tries, largest first, before
/// falling back to 1 row — the same ladder `tile_rows_for_budget_walk`
/// walks, exposed so the tuner's candidate table and the sizing logic
/// can never drift apart.
pub const TILE_LADDER: [usize; 7] = [64, 32, 16, 8, 4, 2, 1];

/// The tuner's pick for one (plan, budget, workers) triple — the
/// single schedule entry point both the engine registry and the legacy
/// `SacBackend` path apply via [`TunedSchedule::apply`].
#[derive(Debug, Clone)]
pub struct TunedSchedule {
    /// Pinned walk, or `None` to let the executor's batch rule choose
    /// between the per-segment walks at each call.
    pub walk: Option<Walk>,
    /// Tile height / ring-advance step.
    pub tile_rows: usize,
    /// Branch-arm thread split: `Some(n)` caps concurrent arm threads
    /// (the tuner serializes arms — `Some(1)` — when the budget is
    /// blown and the plan branches, shaving the concurrent arm working
    /// sets); `None` keeps the executor default (one thread per arm up
    /// to the worker budget).
    pub arm_threads: Option<usize>,
    /// Predicted peak bytes of the chosen schedule (for an unpinned
    /// walk: the better of the two per-segment estimates — the bound
    /// the executor's batch rule can land on).
    pub predicted_peak_bytes: u64,
    /// No candidate fit the budget; the minimum-footprint schedule is
    /// served and a one-time diagnostic was emitted.
    pub over_budget: bool,
    /// The budget this schedule was tuned for.
    pub budget_bytes: u64,
    /// The worker fan-out this schedule was tuned for.
    pub workers: usize,
    /// Smallest batch size at which an unpinned schedule streams
    /// (the executor picks the streaming walk once n ≥ workers).
    pub streaming_batch_pivot: usize,
}

impl TunedSchedule {
    /// Install this schedule as the plan's compiled defaults (the
    /// `walk_hint` + `tile_rows` every `execute` call falls back to).
    pub fn apply(&self, plan: &mut CompiledNetwork) {
        plan.walk_hint = self.walk;
        plan.tile_rows = self.tile_rows;
    }
}

/// Memoized tune results, keyed by (plan fingerprint, budget bytes,
/// workers). `BTreeMap::new` is const, so no lazy-init dance.
static CACHE: Mutex<BTreeMap<(u64, u64, usize), TunedSchedule>> = Mutex::new(BTreeMap::new());

/// One-shot over-budget diagnostics, same key as the cache (the
/// pinned-entry path bypasses the cache but must not spam).
static WARNED: Mutex<BTreeSet<(u64, u64, usize)>> = Mutex::new(BTreeSet::new());

/// Tune `plan` for a memory budget and worker fan-out: the full
/// search, memoized per ([`CompiledNetwork::fingerprint`], budget,
/// workers). This is the schedule the engine installs by default.
pub fn tune(plan: &CompiledNetwork, budget_bytes: u64, workers: usize) -> TunedSchedule {
    let workers = workers.max(1);
    let key = (plan.fingerprint(), budget_bytes, workers);
    if let Some(hit) = CACHE.lock().unwrap().get(&key) {
        return hit.clone();
    }
    let sched = search(plan, budget_bytes, workers);
    CACHE.lock().unwrap().insert(key, sched.clone());
    sched
}

/// [`tune`] with caller pins, the registry's full option surface:
///
/// * `walk: Some(_)` — the walk is pinned; only the tile is sized
///   (budget ladder under that walk's estimator) unless `tile_rows`
///   pins that too.
/// * `tile_rows: Some(_)` — honored verbatim, walk as given (no
///   fallover: an explicit tile is the caller's informed choice, so no
///   over-budget warning either).
/// * both `None` with `fallover` — the full memoized search.
/// * both `None` without `fallover` (`EngineBuilder::auto_tune(false)`)
///   — plain ladder sizing, never pins a walk, still warns when even
///   the 1-row floor blows the budget.
pub fn tune_pinned(
    plan: &CompiledNetwork,
    budget_bytes: u64,
    workers: usize,
    walk: Option<Walk>,
    tile_rows: Option<usize>,
    fallover: bool,
) -> TunedSchedule {
    let workers = workers.max(1);
    if walk.is_none() && tile_rows.is_none() && fallover {
        return tune(plan, budget_bytes, workers);
    }
    let tile = match (walk, tile_rows) {
        (_, Some(t)) => t,
        (Some(w), None) => plan.tile_rows_for_budget_walk(budget_bytes, workers, w),
        (None, None) => plan.tile_rows_for_budget(budget_bytes, workers),
    };
    let peak = predicted_peak(plan, walk, tile, workers);
    let over_budget = peak > budget_bytes;
    if over_budget && tile_rows.is_none() {
        warn_over_budget(plan, budget_bytes, workers, peak);
    }
    TunedSchedule {
        walk,
        tile_rows: tile,
        arm_threads: arm_threads_for(plan, workers, over_budget),
        predicted_peak_bytes: peak,
        over_budget,
        budget_bytes,
        workers,
        streaming_batch_pivot: workers,
    }
}

/// The full scored candidate table the selection rule ranges over —
/// walk × [`TILE_LADDER`] — for `tetris tune`'s report and the
/// validation sweep. `compute_cycles` feeds the roofline's compute
/// leg (0 = traffic-led).
pub fn candidates(
    plan: &CompiledNetwork,
    workers: usize,
    compute_cycles: u64,
) -> crate::Result<Vec<CostEstimate>> {
    // The compute leg prices the kernel the plan will actually run:
    // the decoded kernel's per-window constant is lower (slot decodes
    // retired to compile time), so its absolute scores stay honest
    // against measured runs. Kernel-invariant legs are untouched, so
    // candidate ranking never depends on this.
    let model = CostModel::new(plan, workers)
        .with_compute_cycles(compute_cycles)
        .with_kernel(plan.kernel);
    let mut out = Vec::with_capacity(3 * TILE_LADDER.len());
    for walk in [Walk::Tiled, Walk::Streaming, Walk::Pipelined] {
        for &t in &TILE_LADDER {
            out.push(model.estimate(walk, t)?);
        }
    }
    Ok(out)
}

/// The selection rule (module docs): feasible-first, unpinned-first,
/// then lowest score / largest tile — which in-budget collapses to the
/// budget ladder's answer, and over-budget to the minimum-footprint
/// candidate, pinning the pipelined walk exactly when its depth-flat
/// peak undercuts both per-segment walks.
fn search(plan: &CompiledNetwork, budget_bytes: u64, workers: usize) -> TunedSchedule {
    let t_def = plan.tile_rows_for_budget(budget_bytes, workers);
    let tiled = plan.peak_bytes_estimate(t_def, workers);
    let streaming = plan.streaming_peak_bytes_estimate(t_def, workers);
    let default_peak = tiled.min(streaming);
    let (walk, tile, peak) = if default_peak <= budget_bytes {
        (None, t_def, default_peak)
    } else {
        let rows = plan.tile_rows_for_budget_walk(budget_bytes, workers, Walk::Pipelined);
        let pip = plan.pipelined_peak_bytes_estimate(rows, workers);
        if pip < default_peak {
            (Some(Walk::Pipelined), rows, pip)
        } else {
            (None, t_def, default_peak)
        }
    };
    let over_budget = peak > budget_bytes;
    if over_budget {
        warn_over_budget(plan, budget_bytes, workers, peak);
    }
    TunedSchedule {
        walk,
        tile_rows: tile,
        arm_threads: arm_threads_for(plan, workers, over_budget),
        predicted_peak_bytes: peak,
        over_budget,
        budget_bytes,
        workers,
        streaming_batch_pivot: workers,
    }
}

/// Predicted peak of a chosen schedule: walk-matched estimate when
/// pinned, the better per-segment estimate when unpinned (the bound
/// the executor's batch rule can land on).
fn predicted_peak(
    plan: &CompiledNetwork,
    walk: Option<Walk>,
    tile_rows: usize,
    workers: usize,
) -> u64 {
    match walk {
        Some(Walk::Tiled) => plan.peak_bytes_estimate(tile_rows, workers),
        Some(Walk::Streaming) => plan.streaming_peak_bytes_estimate(tile_rows, workers),
        Some(Walk::Pipelined) => plan.pipelined_peak_bytes_estimate(tile_rows, workers),
        None => plan
            .peak_bytes_estimate(tile_rows, workers)
            .min(plan.streaming_peak_bytes_estimate(tile_rows, workers)),
    }
}

/// Branch-arm thread split: serialize arms when the budget is already
/// blown and the plan branches — `par_map_with(1, …)` walks the arms
/// in sequence, so at most one arm's rings + input clone are live on
/// top of the kept arm outputs (bit-exact either way; scheduling
/// only).
fn arm_threads_for(plan: &CompiledNetwork, workers: usize, over_budget: bool) -> Option<usize> {
    if over_budget && workers > 1 && max_branch_arms(plan.schedule()) > 1 {
        Some(1)
    } else {
        None
    }
}

/// Widest branch fan-out anywhere in a segment schedule.
fn max_branch_arms(segs: &[Segment]) -> usize {
    let mut widest = 0;
    for seg in segs {
        if let Segment::Branch(arms) = seg {
            widest = widest.max(arms.len());
            for arm in arms {
                widest = widest.max(max_branch_arms(arm));
            }
        }
    }
    widest
}

fn warn_over_budget(plan: &CompiledNetwork, budget_bytes: u64, workers: usize, peak: u64) {
    let key = (plan.fingerprint(), budget_bytes, workers);
    if WARNED.lock().unwrap().insert(key) {
        eprintln!(
            "tetris: no schedule fits the {budget_bytes}-byte memory budget at \
             {workers} workers — serving the minimum-footprint schedule \
             (predicted peak {peak} bytes); raise the budget or shrink the model"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::model::weights::{synthetic_loaded, DensityCalibration};
    use crate::model::zoo;

    fn tiny_plan() -> CompiledNetwork {
        let net = zoo::tiny_cnn();
        let w = synthetic_loaded(&net, Mode::Fp16, 12, "tiny_cnn", DensityCalibration::Fig2, 7)
            .unwrap();
        CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap()
    }

    #[test]
    fn generous_budget_reproduces_the_ladder_unpinned() {
        let plan = tiny_plan();
        let tuned = tune(&plan, u64::MAX, 4);
        assert_eq!(tuned.walk, None, "in-budget schedules stay unpinned");
        assert_eq!(tuned.tile_rows, plan.tile_rows_for_budget(u64::MAX, 4));
        assert!(!tuned.over_budget);
        assert_eq!(tuned.streaming_batch_pivot, 4);
    }

    #[test]
    fn zero_budget_flags_over_budget_and_serves_min_footprint() {
        let plan = tiny_plan();
        let tuned = tune(&plan, 0, 2);
        assert!(tuned.over_budget, "nothing fits a zero budget");
        assert!(tuned.predicted_peak_bytes > 0);
        // The pick is still the minimum of the enumerated footprints.
        let floor = predicted_peak(&plan, None, plan.tile_rows_for_budget(0, 2), 2).min(
            predicted_peak(
                &plan,
                Some(Walk::Pipelined),
                plan.tile_rows_for_budget_walk(0, 2, Walk::Pipelined),
                2,
            ),
        );
        assert_eq!(tuned.predicted_peak_bytes, floor);
    }

    #[test]
    fn memoized_results_are_stable() {
        let plan = tiny_plan();
        let a = tune(&plan, 64 * 1024 * 1024, 3);
        let b = tune(&plan, 64 * 1024 * 1024, 3);
        assert_eq!(a.walk, b.walk);
        assert_eq!(a.tile_rows, b.tile_rows);
        assert_eq!(a.predicted_peak_bytes, b.predicted_peak_bytes);
    }

    #[test]
    fn pins_are_honored_verbatim() {
        let plan = tiny_plan();
        let t = tune_pinned(&plan, u64::MAX, 2, Some(Walk::Pipelined), None, true);
        assert_eq!(t.walk, Some(Walk::Pipelined));
        assert_eq!(
            t.tile_rows,
            plan.tile_rows_for_budget_walk(u64::MAX, 2, Walk::Pipelined)
        );
        let t = tune_pinned(&plan, u64::MAX, 2, None, Some(3), true);
        assert_eq!(t.walk, None);
        assert_eq!(t.tile_rows, 3);
        let t = tune_pinned(&plan, u64::MAX, 2, None, None, false);
        assert_eq!(t.walk, None, "auto_tune(false) never pins a walk");
        assert_eq!(t.tile_rows, plan.tile_rows_for_budget(u64::MAX, 2));
    }

    #[test]
    fn candidate_table_covers_every_walk_and_ladder_tile() {
        let plan = tiny_plan();
        let table = candidates(&plan, 2, 1000).unwrap();
        assert_eq!(table.len(), 3 * TILE_LADDER.len());
        // The compute leg is priced for the plan's kernel (Decoded by
        // default): 1000 scaled by the plan's add share, identical for
        // every candidate because the factor is walk/tile-invariant.
        let want = CostModel::new(&plan, 2)
            .with_compute_cycles(1000)
            .with_kernel(plan.kernel)
            .estimate(Walk::Tiled, 1)
            .unwrap()
            .compute_cycles;
        assert!(want < 1000, "the decoded kernel's per-window constant is lower");
        assert!(table.iter().all(|c| c.compute_cycles == want));
        // The chosen in-budget schedule matches the best unpinned
        // candidate's tile (largest feasible = lowest traffic).
        let tuned = tune(&plan, u64::MAX, 2);
        let best_tile = table
            .iter()
            .filter(|c| c.walk == Walk::Tiled && c.fits(u64::MAX))
            .max_by_key(|c| c.tile_rows)
            .unwrap()
            .tile_rows;
        assert_eq!(tuned.tile_rows, best_tile);
    }
}
