//! The generic layer-graph IR and its lowering from declared zoo
//! topology.
//!
//! A plan is the lowered form of a `Network`'s explicit
//! [`TopoOp`] schedule: every conv expands to `Conv → ReluRequant`,
//! pools carry their declared geometry ([`PoolSpec`]), and
//! inception-style branching lowers to a [`PlanOp::Branch`] whose arms
//! execute over one input and concatenate along channels. Nothing is
//! *inferred* — earlier revisions recovered pooling from spatial-size
//! ratios between consecutive layers (and could only express the
//! VGG-style 2×2 stride-2 schedule); the declared IR expresses the
//! whole zoo, and lowering only *validates* that the declared shapes
//! chain (channels and spatial sizes, weight availability, one use per
//! layer).

use crate::model::{ConvLayer, LoadedLayer, LoadedWeights, Network, PoolSpec, TopoOp};

/// One node of an execution plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanOp {
    /// Convolution of compiled conv layer `layer` (index into
    /// `CompiledNetwork::convs`), zero-padded by `pad`, with `stride`.
    Conv { layer: usize, pad: usize, stride: usize },
    /// ReLU fused with the rounding right-shift requantization by
    /// `frac_bits` (see `quant::requantize`).
    ReluRequant { frac_bits: u32 },
    /// Pooling stage with its declared geometry (Caffe ceil-mode
    /// output sizing; see [`PoolSpec::out_hw`]).
    Pool(PoolSpec),
    /// Parallel arms over one input, concatenated along the channel
    /// axis in arm order (inception modules).
    Branch { arms: Vec<Vec<PlanOp>> },
    /// Global average pool: i64 sum then floor division (matches the
    /// Python pipeline's `jnp` floor-divide), (N,C,H,W) → (N,C).
    GlobalAvgPool,
    /// Flatten a (N, C, H, W) trunk into (N, C·H·W) feature rows — the
    /// entry into an FC stack that does **not** follow a
    /// `GlobalAvgPool` (VGG's fc6 consumes the raw 512×7×7 block-5
    /// map). Row-major NCHW layout makes this a pure reshape: no data
    /// moves, and the flattened order matches the OIHW order the FC
    /// weight lanes were kneaded in.
    Flatten,
    /// One fully connected layer over the pre-kneaded lanes of the
    /// weight layer `name`. Every head of a declared FC stack (VGG
    /// fc6–8, GoogleNet loss3/classifier) lowers to its own op; all
    /// but the stack's last head are activation-fused
    /// (ReLU + requantization by the weight layer's `frac_bits`),
    /// mirroring the published topologies.
    Fc { name: String },
}

/// Per-op row-tile contract: how many input rows a span of output rows
/// needs. `k`/`stride`/`pad` describe the op's window geometry along
/// the row axis — a conv's kernel height, a pool's window, or the
/// 1×1 identity for elementwise ops. The same clipped-window formula
/// serves convs (out-of-span rows are zero padding) and ceil-mode
/// pools (out-of-span rows are excluded taps), so one contract type
/// covers every fusable op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowContract {
    /// Window height (1 for elementwise ops).
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl RowContract {
    /// Contract of an elementwise op (ReluRequant): rows map 1:1.
    pub fn elementwise() -> Self {
        Self { k: 1, stride: 1, pad: 0 }
    }

    /// Input rows `[lo, hi)` needed to produce output rows `[o0, o1)`
    /// (the tile plus its halo), clipped to the real input extent
    /// `in_h`. Rows the unclipped window would read outside `[lo, hi)`
    /// are padding: zeros for a conv gather, excluded taps for a pool
    /// window — neither lives in any buffer.
    pub fn in_span(&self, o0: usize, o1: usize, in_h: usize) -> (usize, usize) {
        debug_assert!(o0 < o1, "empty output span");
        let lo = (o0 * self.stride).saturating_sub(self.pad).min(in_h);
        let hi = ((o1 - 1) * self.stride + self.k)
            .saturating_sub(self.pad)
            .clamp(lo, in_h);
        (lo, hi)
    }

    /// The clipped input-row band ONE output row reads — the
    /// single-row case of [`RowContract::in_span`]. This is the
    /// row-level zero-mask check of the activation-skipping lane: if
    /// every row in the band is a known all-zero row, every window
    /// under output row `o` is all-zero (rows the unclipped window
    /// reads outside the band are padding and contribute zeros
    /// regardless), so the whole row's SAC work can be skipped
    /// bit-exactly.
    pub fn in_band(&self, o: usize, in_h: usize) -> (usize, usize) {
        self.in_span(o, o + 1, in_h)
    }

    /// The forward dual of [`RowContract::in_span`] — the per-stage
    /// `rows_ready → rows_emitted` advance function the streaming
    /// pipeline chains through a fused segment: given that the first
    /// `ready` of `in_h` input rows exist, how many output rows (of
    /// `out_h` total) are computable, i.e. have their whole (clipped)
    /// window inside `[0, ready)`.
    ///
    /// Duality: for any output span `[o0, o1)`,
    /// `rows_emitted(in_span(o0, o1).1) >= o1` — feeding a tile's halo
    /// makes the tile emittable. Ceil-mode windows hanging off the
    /// bottom edge only complete once the *entire* input has arrived
    /// (`ready == in_h`), exactly when their clipped form is final.
    pub fn rows_emitted(&self, ready: usize, in_h: usize, out_h: usize) -> usize {
        debug_assert!(ready <= in_h, "ready {ready} beyond input {in_h}");
        if ready == in_h {
            return out_h;
        }
        // Output row o reads input rows [o·s − pad, o·s + k − pad)
        // clipped to [0, in_h); with ready < in_h the clip cannot help,
        // so o is emittable iff o·s + k − pad ≤ ready.
        if ready + self.pad < self.k {
            return 0;
        }
        (((ready + self.pad - self.k) / self.stride) + 1).min(out_h)
    }

    /// Compose `self` (upstream) with `next` (downstream) into the
    /// contract of the fused two-stage window: applying the composite
    /// to final-output rows answers "which *original* input rows does
    /// this span reach through both stages".
    ///
    /// The unclipped window algebra telescopes exactly — strides
    /// multiply, kernels chain (`(k_next − 1)·s + k`), pads accumulate
    /// (`p_next·s + p`). Clipping makes the composite *conservative*
    /// rather than exact: [`in_span`](Self::in_span)'s `lo` always
    /// matches the stage-by-stage backward chain (a span clipped to 0
    /// stays 0 through every earlier stage), while `hi` matches unless
    /// an intermediate stage's span clips at its own `in_h` (bottom
    /// padding / ceil-mode overhang), in which case the composite span
    /// is a superset of the chained one. Dually, the composite's
    /// [`rows_emitted`](Self::rows_emitted) never exceeds the chained
    /// per-stage advance, with equality at `ready == in_h`. Both
    /// directions are safe for what the composite is used for: sizing
    /// the whole-network pipeline's *fill depth* (how many input rows
    /// must arrive before the first final-output row emerges) and
    /// bounding receptive-field reach.
    pub fn then(&self, next: &RowContract) -> RowContract {
        RowContract {
            k: (next.k - 1) * self.stride + self.k,
            stride: self.stride * next.stride,
            pad: next.pad * self.stride + self.pad,
        }
    }

    /// Fold a stage chain (upstream first) into one composite contract
    /// via [`then`](Self::then); identity contract for an empty chain.
    pub fn composed<'a>(chain: impl IntoIterator<Item = &'a RowContract>) -> RowContract {
        chain
            .into_iter()
            .fold(RowContract::elementwise(), |acc, c| acc.then(c))
    }
}

/// One stage of a fused tile walk: a fusable op plus the row contract
/// lowering computed for it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedStage {
    /// `Conv`, `ReluRequant` or `Pool` only — the ops whose output
    /// rows depend on a bounded row window of their input.
    pub op: PlanOp,
    pub contract: RowContract,
}

/// One segment of the tile-scheduled execution plan. Fused segments
/// walk row tiles end to end (ring buffers, no intermediate maps);
/// the others are materialization points — their output is a whole
/// feature map (or feature vector) by nature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Segment {
    /// A `Conv → ReluRequant [→ Pool]` chain (or a lone pool opening a
    /// branch arm) executed as one fused walk over output row tiles.
    Fused(Vec<FusedStage>),
    /// Branch arms — each its own segmented schedule — executed
    /// concurrently under a shared thread budget and concatenated
    /// along channels in arm order.
    Branch(Vec<Vec<Segment>>),
    GlobalAvgPool,
    /// Reshape (N, C, H, W) → (N, C·H·W): free in row-major layout.
    Flatten,
    /// One compiled FC lane set, looked up by head name
    /// ([`CompiledNetwork::fc_head`](super::CompiledNetwork::fc_head)).
    Fc { name: String },
}

/// Group a lowered op list into the tile schedule the executor walks:
/// every conv absorbs its fused ReluRequant and, when one follows
/// immediately, the pool it feeds — so the conv's full-size output map
/// never materializes; only the (stride²-smaller) pool output does.
/// Chains are deliberately NOT fused past a pool: overlapped row tiling
/// recomputes halo rows, and a halo that crosses k-row windows at
/// every fused stage grows with the receptive field — one conv (+pool)
/// per walk keeps the recompute bounded by `pool.k − pool.stride` rows
/// per tile boundary while already eliminating the dominant buffer.
pub fn segment_plan(ops: &[PlanOp], layers: &[ConvLayer]) -> Vec<Segment> {
    let mut segs = Vec::new();
    let mut i = 0;
    while i < ops.len() {
        match &ops[i] {
            PlanOp::Conv { layer, pad, stride } => {
                let mut stages = vec![FusedStage {
                    op: ops[i].clone(),
                    contract: RowContract { k: layers[*layer].k, stride: *stride, pad: *pad },
                }];
                i += 1;
                if let Some(PlanOp::ReluRequant { .. }) = ops.get(i) {
                    stages.push(FusedStage {
                        op: ops[i].clone(),
                        contract: RowContract::elementwise(),
                    });
                    i += 1;
                }
                if let Some(PlanOp::Pool(spec)) = ops.get(i) {
                    stages.push(FusedStage {
                        op: ops[i].clone(),
                        contract: RowContract { k: spec.k, stride: spec.stride, pad: spec.pad },
                    });
                    i += 1;
                }
                segs.push(Segment::Fused(stages));
            }
            PlanOp::ReluRequant { .. } => {
                segs.push(Segment::Fused(vec![FusedStage {
                    op: ops[i].clone(),
                    contract: RowContract::elementwise(),
                }]));
                i += 1;
            }
            PlanOp::Pool(spec) => {
                segs.push(Segment::Fused(vec![FusedStage {
                    op: ops[i].clone(),
                    contract: RowContract { k: spec.k, stride: spec.stride, pad: spec.pad },
                }]));
                i += 1;
            }
            PlanOp::Branch { arms } => {
                segs.push(Segment::Branch(
                    arms.iter().map(|a| segment_plan(a, layers)).collect(),
                ));
                i += 1;
            }
            PlanOp::GlobalAvgPool => {
                segs.push(Segment::GlobalAvgPool);
                i += 1;
            }
            PlanOp::Flatten => {
                segs.push(Segment::Flatten);
                i += 1;
            }
            PlanOp::Fc { name } => {
                segs.push(Segment::Fc { name: name.clone() });
                i += 1;
            }
        }
    }
    segs
}

/// Shape state threaded through lowering: (channels, spatial size)
/// after the most recent op.
type ShapeState = Option<(usize, usize)>;

/// Validate an `fc` weight layer's reduction dim against the trunk's
/// pooled channel count — shared by the declared-Fc lowering arm and
/// the implicit-head append, so both reject mismatched heads at
/// compile time with one error shape.
fn check_fc_fits(net: &Network, fl: &LoadedLayer, state: ShapeState) -> crate::Result<()> {
    if let Some((c, _)) = state {
        let feat = fl.shape[1] * fl.shape[2] * fl.shape[3];
        if feat != c {
            return Err(crate::Error::Shape(format!(
                "{}: fc weights reduce {feat} features but the \
                 pooled trunk delivers {c}",
                net.name
            )));
        }
    }
    Ok(())
}

struct Lowering<'a> {
    net: &'a Network,
    weights: &'a LoadedWeights,
    used: Vec<bool>,
    saw_gap: bool,
    saw_fc: bool,
    /// Whether the declared FC stack is executable (every head has a
    /// weight layer) or declaration-only (none does). Set by the first
    /// `TopoOp::Fc`; a stack mixing weighted and weightless heads is
    /// rejected — executing half a classifier would serve neither the
    /// trunk nor the logits.
    fc_exec: Option<bool>,
}

impl Lowering<'_> {
    /// Lower `ops` starting from `state`; returns the lowered ops and
    /// the shape state after the last op. `depth > 0` inside branch
    /// arms (where heads and nested branches are rejected).
    fn lower(
        &mut self,
        ops: &[TopoOp],
        mut state: ShapeState,
        depth: usize,
    ) -> crate::Result<(Vec<PlanOp>, ShapeState)> {
        let mut out = Vec::with_capacity(3 * ops.len());
        for op in ops {
            if (self.saw_fc || self.saw_gap) && !matches!(op, TopoOp::Fc(_)) {
                return Err(crate::Error::Config(format!(
                    "{}: schedule continues after its classifier head",
                    self.net.name
                )));
            }
            match op {
                TopoOp::Conv(i) => {
                    let l = self.net.layers.get(*i).ok_or_else(|| {
                        crate::Error::Config(format!(
                            "{}: schedule references conv #{i} but the network has {} layers",
                            self.net.name,
                            self.net.layers.len()
                        ))
                    })?;
                    if std::mem::replace(&mut self.used[*i], true) {
                        return Err(crate::Error::Config(format!(
                            "{}: layer `{}` appears twice in the schedule",
                            self.net.name, l.name
                        )));
                    }
                    if l.stride == 0 {
                        return Err(crate::Error::Config(format!("{}: stride 0", l.name)));
                    }
                    if let Some((c, hw)) = state {
                        if l.in_c != c {
                            return Err(crate::Error::Config(format!(
                                "{}: `{}` declares {} input channels but the schedule delivers {c}",
                                self.net.name, l.name, l.in_c
                            )));
                        }
                        if l.in_hw != hw {
                            return Err(crate::Error::Config(format!(
                                "{}: `{}` declares a {}×{} input but the schedule delivers {hw}×{hw}",
                                self.net.name, l.name, l.in_hw, l.in_hw
                            )));
                        }
                    }
                    if l.in_hw + 2 * l.pad < l.k {
                        return Err(crate::Error::Shape(format!(
                            "{}: {hw}×{hw} input (pad {}) smaller than {}×{} kernel",
                            l.name,
                            l.pad,
                            l.k,
                            l.k,
                            hw = l.in_hw,
                        )));
                    }
                    let wl = self.weights.layer(&l.name).ok_or_else(|| {
                        crate::Error::Artifact(format!(
                            "{}: no weights for layer `{}`",
                            self.net.name, l.name
                        ))
                    })?;
                    let want = [l.out_c, l.in_c, l.k, l.k];
                    if wl.shape != want {
                        return Err(crate::Error::Shape(format!(
                            "{}: weight shape {:?} != topology {:?}",
                            l.name, wl.shape, want
                        )));
                    }
                    out.push(PlanOp::Conv { layer: *i, pad: l.pad, stride: l.stride });
                    out.push(PlanOp::ReluRequant { frac_bits: wl.frac_bits });
                    state = Some((l.out_c, l.out_hw()));
                }
                TopoOp::Pool(p) => {
                    let (c, hw) = state.ok_or_else(|| {
                        crate::Error::Config(format!(
                            "{}: schedule must open with a conv layer, not a pool",
                            self.net.name
                        ))
                    })?;
                    let out_hw = p.out_hw(hw)?;
                    out.push(PlanOp::Pool(*p));
                    state = Some((c, out_hw));
                }
                TopoOp::Branch(arms) => {
                    if depth > 0 {
                        return Err(crate::Error::Config(format!(
                            "{}: nested branches are not supported",
                            self.net.name
                        )));
                    }
                    let start = state.ok_or_else(|| {
                        crate::Error::Config(format!(
                            "{}: schedule must open with a conv layer, not a branch",
                            self.net.name
                        ))
                    })?;
                    if arms.len() < 2 {
                        return Err(crate::Error::Config(format!(
                            "{}: a branch needs at least two arms",
                            self.net.name
                        )));
                    }
                    let mut lowered = Vec::with_capacity(arms.len());
                    let mut total_c = 0usize;
                    let mut out_hw: Option<usize> = None;
                    for arm in arms {
                        if arm.is_empty() {
                            return Err(crate::Error::Config(format!(
                                "{}: empty branch arm",
                                self.net.name
                            )));
                        }
                        let (arm_ops, end) = self.lower(arm, Some(start), depth + 1)?;
                        let (ac, ahw) = end.expect("arm state flows from a Some start");
                        match out_hw {
                            None => out_hw = Some(ahw),
                            Some(h) if h == ahw => {}
                            Some(h) => {
                                return Err(crate::Error::Config(format!(
                                    "{}: branch arms disagree on output spatial size ({h} vs {ahw})",
                                    self.net.name
                                )));
                            }
                        }
                        total_c += ac;
                        lowered.push(arm_ops);
                    }
                    out.push(PlanOp::Branch { arms: lowered });
                    state = Some((total_c, out_hw.expect("≥2 arms")));
                }
                TopoOp::GlobalAvgPool => {
                    if depth > 0 {
                        return Err(crate::Error::Config(format!(
                            "{}: GlobalAvgPool inside a branch arm",
                            self.net.name
                        )));
                    }
                    let (c, _) = state.ok_or_else(|| {
                        crate::Error::Config(format!(
                            "{}: GlobalAvgPool before any conv layer",
                            self.net.name
                        ))
                    })?;
                    out.push(PlanOp::GlobalAvgPool);
                    // Spatial extent collapses: downstream Fc entries
                    // consume plain C features.
                    state = Some((c, 1));
                    self.saw_gap = true;
                }
                TopoOp::Fc(spec) => {
                    if depth > 0 {
                        return Err(crate::Error::Config(format!(
                            "{}: Fc inside a branch arm",
                            self.net.name
                        )));
                    }
                    let (c, hw) = state.ok_or_else(|| {
                        crate::Error::Config(format!(
                            "{}: schedule must open with a conv layer, not an fc head",
                            self.net.name
                        ))
                    })?;
                    // Flatten semantics: the head consumes C·H·W
                    // (H = W = 1 after GlobalAvgPool / a previous Fc).
                    let delivered = c * hw * hw;
                    if spec.in_features != delivered {
                        return Err(crate::Error::Shape(format!(
                            "{}: fc `{}` declares {} input features but the \
                             schedule delivers {delivered}",
                            self.net.name, spec.name, spec.in_features
                        )));
                    }
                    if spec.out_features == 0 {
                        return Err(crate::Error::Config(format!(
                            "{}: fc `{}` declares zero output features",
                            self.net.name, spec.name
                        )));
                    }
                    let weighted = self.weights.layer(&spec.name).is_some();
                    match self.fc_exec {
                        None => self.fc_exec = Some(weighted),
                        Some(prev) if prev != weighted => {
                            return Err(crate::Error::Config(format!(
                                "{}: fc stack mixes weighted and weightless heads \
                                 (`{}` breaks the pattern) — a stack executes whole \
                                 or not at all",
                                self.net.name, spec.name
                            )));
                        }
                        Some(_) => {}
                    }
                    if weighted {
                        // Executable head: the per-name FC lanes are
                        // compiled and streamed like conv lanes. Any
                        // declared stack qualifies (VGG fc6–8 over the
                        // flattened block-5 map, GoogleNet's
                        // loss3/classifier after its GAP, the tiny
                        // CNN's single `fc`).
                        let fl = self.weights.layer(&spec.name).expect("checked above");
                        let want_out = fl.shape[0];
                        let want_in = fl.shape[1] * fl.shape[2] * fl.shape[3];
                        if (want_out, want_in) != (spec.out_features, spec.in_features) {
                            return Err(crate::Error::Shape(format!(
                                "{}: fc `{}` weight shape {:?} != declared {}→{}",
                                self.net.name,
                                spec.name,
                                fl.shape,
                                spec.in_features,
                                spec.out_features
                            )));
                        }
                        // A spatial trunk flattens into feature rows
                        // first; after a GlobalAvgPool (or a previous
                        // Fc) the map is already (N, C).
                        if !self.saw_fc && !self.saw_gap {
                            out.push(PlanOp::Flatten);
                        }
                        out.push(PlanOp::Fc { name: spec.name.clone() });
                    }
                    // Declaration-only heads (a conv-only weight set)
                    // stay validated accounting topology: the plan
                    // serves the conv trunk exactly as before the head
                    // was declared.
                    state = Some((spec.out_features, 1));
                    self.saw_fc = true;
                }
            }
        }
        Ok((out, state))
    }
}

/// Lower the declared schedule of `net` into an executable op graph,
/// validating it against the weight file's layer set:
///
/// * every scheduled conv layer must have a weight entry of matching
///   OIHW shape, and every layer must be scheduled exactly once;
/// * declared shapes must chain: each conv's recorded `in_c`/`in_hw`
///   must equal what the preceding ops deliver (pool output sizes use
///   [`PoolSpec::out_hw`]'s ceil-mode arithmetic), and branch arms must
///   agree on their output spatial size;
/// * declared [`TopoOp::Fc`] entries (VGG's fc6–8, GoogleNet's
///   loss3/classifier) are shape-validated — `in_features` must equal
///   the flattened `C·H·W` the trunk delivers, chained through the FC
///   stack. When the weight set carries **every** head of the stack,
///   each lowers to its own executable [`PlanOp::Fc`] (a spatial trunk
///   gets a [`PlanOp::Flatten`] first); when it carries none, the
///   stack is declaration-only accounting topology and the plan serves
///   the conv trunk; a mixed stack is rejected;
/// * a weight layer named `fc` with **no** declared head appends
///   `GlobalAvgPool → Fc` as the classifier head — reusing a
///   schedule-declared trailing `GlobalAvgPool` (NiN) rather than
///   pooling twice.
pub fn derive_graph(net: &Network, weights: &LoadedWeights) -> crate::Result<Vec<PlanOp>> {
    if net.layers.is_empty() {
        return Err(crate::Error::Config(format!(
            "network `{}` has no conv layers to plan",
            net.name
        )));
    }
    if net.schedule.is_empty() {
        return Err(crate::Error::Config(format!(
            "network `{}` declares no schedule to lower",
            net.name
        )));
    }
    let mut lo = Lowering {
        net,
        weights,
        used: vec![false; net.layers.len()],
        saw_gap: false,
        saw_fc: false,
        fc_exec: None,
    };
    let (mut ops, state) = lo.lower(&net.schedule, None, 0)?;
    if let Some(i) = lo.used.iter().position(|u| !u) {
        return Err(crate::Error::Config(format!(
            "{}: layer `{}` never appears in the schedule",
            net.name, net.layers[i].name
        )));
    }
    if let Some(fl) = weights.layer("fc") {
        if !lo.saw_fc {
            check_fc_fits(net, fl, state)?;
            if !lo.saw_gap {
                ops.push(PlanOp::GlobalAvgPool);
            }
            ops.push(PlanOp::Fc { name: "fc".into() });
        }
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::model::{zoo, LoadedLayer, PoolKind};

    /// Minimal weight set matching a network's topology (+optional fc).
    fn weights_for(net: &Network, fc_classes: Option<usize>) -> LoadedWeights {
        let mut layers: Vec<LoadedLayer> = net
            .layers
            .iter()
            .map(|l| LoadedLayer {
                name: l.name.clone(),
                shape: [l.out_c, l.in_c, l.k, l.k],
                frac_bits: 8,
                weights: vec![1; l.weight_count() as usize],
            })
            .collect();
        if let Some(classes) = fc_classes {
            let feat = net.layers.last().unwrap().out_c;
            layers.push(LoadedLayer {
                name: "fc".into(),
                shape: [classes, feat, 1, 1],
                frac_bits: 8,
                weights: vec![1; classes * feat],
            });
        }
        LoadedWeights { mode: Mode::Fp16, layers }
    }

    fn pools_of(ops: &[PlanOp]) -> Vec<PoolSpec> {
        let mut out = Vec::new();
        for op in ops {
            match op {
                PlanOp::Pool(p) => out.push(*p),
                PlanOp::Branch { arms } => {
                    arms.iter().for_each(|a| out.extend(pools_of(a)))
                }
                _ => {}
            }
        }
        out
    }

    #[test]
    fn tiny_cnn_graph_matches_legacy_pipeline() {
        let net = zoo::tiny_cnn();
        let w = weights_for(&net, Some(4));
        let ops = derive_graph(&net, &w).unwrap();
        assert_eq!(
            ops,
            vec![
                PlanOp::Conv { layer: 0, pad: 1, stride: 1 },
                PlanOp::ReluRequant { frac_bits: 8 },
                PlanOp::Pool(PoolSpec::max(2, 2, 0)),
                PlanOp::Conv { layer: 1, pad: 1, stride: 1 },
                PlanOp::ReluRequant { frac_bits: 8 },
                PlanOp::Pool(PoolSpec::max(2, 2, 0)),
                PlanOp::Conv { layer: 2, pad: 1, stride: 1 },
                PlanOp::ReluRequant { frac_bits: 8 },
                PlanOp::GlobalAvgPool,
                PlanOp::Fc { name: "fc".into() },
            ]
        );
    }

    #[test]
    fn vgg16_graph_places_five_declared_pools() {
        let net = zoo::vgg16();
        let w = weights_for(&net, None);
        let ops = derive_graph(&net, &w).unwrap();
        // All five pools are declared now — including the one after
        // block 5 the old spatial-ratio inference could never see.
        assert_eq!(pools_of(&ops).len(), 5);
        assert!(pools_of(&ops).iter().all(|p| *p == PoolSpec::max(2, 2, 0)));
        // Conv-only weight set → no classifier head, no flatten.
        assert!(!ops.iter().any(|o| matches!(o, PlanOp::Fc { .. })));
        assert!(!ops.contains(&PlanOp::Flatten));
        assert!(!ops.contains(&PlanOp::GlobalAvgPool));
    }

    #[test]
    fn alexnet_graph_lowers_3x3_stride2_pools() {
        // AlexNet pools 3×3 stride 2 (55 → 27) — inexpressible under
        // the old ratio inference, a plain declared op now.
        let net = zoo::alexnet();
        let w = weights_for(&net, None);
        let ops = derive_graph(&net, &w).unwrap();
        let pools = pools_of(&ops);
        assert_eq!(pools.len(), 3);
        assert!(pools.iter().all(|p| *p == PoolSpec::max(3, 2, 0)));
    }

    #[test]
    fn nin_graph_ends_in_declared_global_pool() {
        let net = zoo::nin();
        let w = weights_for(&net, None);
        let ops = derive_graph(&net, &w).unwrap();
        assert_eq!(ops.last(), Some(&PlanOp::GlobalAvgPool));
        assert_eq!(pools_of(&ops).len(), 3);
    }

    #[test]
    fn googlenet_graph_lowers_inception_branches() {
        let net = zoo::googlenet();
        let w = weights_for(&net, None);
        let ops = derive_graph(&net, &w).unwrap();
        let branches: Vec<&Vec<Vec<PlanOp>>> = ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::Branch { arms } => Some(arms),
                _ => None,
            })
            .collect();
        assert_eq!(branches.len(), 9);
        for arms in &branches {
            assert_eq!(arms.len(), 4);
            // 1×1 | reduce→3×3 | reduce→5×5 | pool→proj: 1/2/2 convs
            // and a 3×3 stride-1 pool opening the fourth arm.
            let convs = |a: &[PlanOp]| {
                a.iter().filter(|o| matches!(o, PlanOp::Conv { .. })).count()
            };
            assert_eq!(convs(&arms[0]), 1);
            assert_eq!(convs(&arms[1]), 2);
            assert_eq!(convs(&arms[2]), 2);
            assert_eq!(convs(&arms[3]), 1);
            assert_eq!(arms[3][0], PlanOp::Pool(PoolSpec::max(3, 1, 1)));
        }
        // Stem + inter-module pools: 3 outside the branches, all 3×3
        // stride-2; one declared global-average head.
        let top_pools: Vec<&PoolSpec> = ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::Pool(p) => Some(p),
                _ => None,
            })
            .collect();
        assert_eq!(top_pools.len(), 3);
        assert!(top_pools.iter().all(|p| **p == PoolSpec::max(3, 2, 0)));
        assert_eq!(ops.last(), Some(&PlanOp::GlobalAvgPool));
    }

    #[test]
    fn mismatched_declared_shapes_rejected() {
        // Tamper with a declared input size: lowering must refuse.
        let mut net = zoo::tiny_cnn();
        net.layers[1].in_hw = 9;
        let w = weights_for(&net, None);
        match derive_graph(&net, &w) {
            Err(crate::Error::Config(msg)) => {
                assert!(msg.contains("schedule delivers"), "{msg}")
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        // Tamper with channels: same refusal.
        let mut net = zoo::tiny_cnn();
        net.layers[1].in_c = 9;
        let w = weights_for(&net, None);
        assert!(matches!(derive_graph(&net, &w), Err(crate::Error::Config(_))));
    }

    #[test]
    fn unscheduled_or_doubly_scheduled_layers_rejected() {
        let mut net = zoo::tiny_cnn();
        net.schedule.pop(); // conv3 never runs
        let w = weights_for(&net, None);
        match derive_graph(&net, &w) {
            Err(crate::Error::Config(msg)) => assert!(msg.contains("never appears"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
        let mut net = zoo::tiny_cnn();
        net.schedule.push(TopoOp::Conv(2));
        let w = weights_for(&net, None);
        match derive_graph(&net, &w) {
            Err(crate::Error::Config(msg)) => assert!(msg.contains("twice"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn missing_or_misshapen_weights_rejected() {
        let net = zoo::tiny_cnn();
        let mut w = weights_for(&net, None);
        w.layers.remove(1);
        assert!(matches!(derive_graph(&net, &w), Err(crate::Error::Artifact(_))));
        let mut w = weights_for(&net, None);
        w.layers[0].shape = [9, 9, 9, 9];
        assert!(matches!(derive_graph(&net, &w), Err(crate::Error::Shape(_))));
    }

    #[test]
    fn misfit_fc_head_rejected_at_lowering() {
        // The implicit head path validates the fc feature dim against
        // the trunk's pooled channels — compile-time, not execute-time.
        let net = zoo::tiny_cnn();
        let mut w = weights_for(&net, Some(4));
        w.layers.last_mut().unwrap().shape = [4, 32, 1, 1]; // trunk is 16
        match derive_graph(&net, &w) {
            Err(crate::Error::Shape(msg)) => assert!(msg.contains("pooled trunk"), "{msg}"),
            other => panic!("expected Shape error, got {other:?}"),
        }
    }

    #[test]
    fn declared_fc_heads_validate_but_stay_declaration_only() {
        // VGG-16's declared fc6–8 chain must validate against the
        // trunk (512·7·7 → 4096 → 4096 → 1000) without weights for
        // them, and must emit no executable op.
        let net = zoo::vgg16();
        let w = weights_for(&net, None);
        let ops = derive_graph(&net, &w).unwrap();
        assert!(!ops.iter().any(|o| matches!(o, PlanOp::Fc { .. })));
        // Tampering with a declared reduction dim is rejected.
        let mut bad = zoo::vgg16();
        for op in bad.schedule.iter_mut() {
            if let TopoOp::Fc(spec) = op {
                spec.in_features = 9999;
                break;
            }
        }
        match derive_graph(&bad, &w) {
            Err(crate::Error::Shape(msg)) => {
                assert!(msg.contains("schedule delivers"), "{msg}")
            }
            other => panic!("expected Shape error, got {other:?}"),
        }
        // A conv after the declared head is rejected.
        let mut cont = zoo::vgg16();
        cont.schedule.push(TopoOp::Conv(0));
        match derive_graph(&cont, &w) {
            Err(crate::Error::Config(msg)) => {
                assert!(msg.contains("classifier head"), "{msg}")
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        // GoogleNet: loss3/classifier rides after the declared GAP.
        let g = zoo::googlenet();
        let gw = weights_for(&g, None);
        let gops = derive_graph(&g, &gw).unwrap();
        assert_eq!(gops.last(), Some(&PlanOp::GlobalAvgPool));
    }

    #[test]
    fn declared_executable_fc_head_lowers() {
        // A tiny CNN that *declares* its head: GAP + Fc over the `fc`
        // weight layer lowers to an executable PlanOp::Fc.
        use crate::model::topology::FcSpec;
        let mut net = zoo::tiny_cnn();
        net.schedule.push(TopoOp::GlobalAvgPool);
        net.schedule.push(TopoOp::Fc(FcSpec::new("fc", 16, 4)));
        let w = weights_for(&net, Some(4));
        let ops = derive_graph(&net, &w).unwrap();
        assert_eq!(ops.last(), Some(&PlanOp::Fc { name: "fc".into() }));
        let gaps = ops.iter().filter(|o| **o == PlanOp::GlobalAvgPool).count();
        assert_eq!(gaps, 1, "declared GAP must not be doubled");
        // After a GAP the map is already (N, C): no flatten op.
        assert!(!ops.contains(&PlanOp::Flatten));
        // A *named* head with weights lowers too (per-name FC lanes).
        let mut named = zoo::tiny_cnn();
        named.schedule.push(TopoOp::GlobalAvgPool);
        named.schedule.push(TopoOp::Fc(FcSpec::new("fc6", 16, 4)));
        let mut nw = weights_for(&named, None);
        nw.layers.push(crate::model::LoadedLayer {
            name: "fc6".into(),
            shape: [4, 16, 1, 1],
            frac_bits: 8,
            weights: vec![1; 64],
        });
        let nops = derive_graph(&named, &nw).unwrap();
        assert_eq!(nops.last(), Some(&PlanOp::Fc { name: "fc6".into() }));
    }

    #[test]
    fn weighted_fc_stack_lowers_with_flatten() {
        // VGG-16's declared fc6–8 with weights for every head: a
        // Flatten enters the stack (the trunk is a spatial map, not a
        // GAP vector) and each head lowers to its own op. Channel-
        // scaled so the synthetic head weights stay small — the full
        // fc6 alone would be 25088×4096 values.
        use crate::model::LoadedLayer;
        let net = zoo::vgg16().scaled(16, 224);
        let mut w = weights_for(&net, None);
        for spec in net.fc_specs() {
            w.layers.push(LoadedLayer {
                name: spec.name.clone(),
                shape: [spec.out_features, spec.in_features, 1, 1],
                frac_bits: 8,
                weights: vec![1; spec.in_features * spec.out_features],
            });
        }
        let ops = derive_graph(&net, &w).unwrap();
        let fcs: Vec<&str> = ops
            .iter()
            .filter_map(|o| match o {
                PlanOp::Fc { name } => Some(name.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(fcs, ["fc6", "fc7", "fc8"]);
        assert_eq!(
            ops.iter().filter(|o| **o == PlanOp::Flatten).count(),
            1,
            "exactly one flatten, before the first head"
        );
        let flat_at = ops.iter().position(|o| *o == PlanOp::Flatten).unwrap();
        assert!(matches!(ops[flat_at + 1], PlanOp::Fc { .. }));

        // A stack with only *some* heads weighted is refused.
        let mut mixed = w.clone();
        mixed.layers.retain(|l| l.name != "fc7");
        match derive_graph(&net, &mixed) {
            Err(crate::Error::Config(msg)) => {
                assert!(msg.contains("mixes"), "{msg}")
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        // A weight shape disagreeing with the declared spec is refused.
        let mut bad = w.clone();
        bad.layers.iter_mut().find(|l| l.name == "fc7").unwrap().shape[1] = 999;
        assert!(matches!(derive_graph(&net, &bad), Err(crate::Error::Shape(_))));
    }

    #[test]
    fn nin_with_fc_weights_reuses_declared_gap() {
        // A weight file carrying `fc` on a net whose schedule already
        // ends in GlobalAvgPool must not pool twice.
        let net = zoo::nin();
        let w = weights_for(&net, Some(10));
        let ops = derive_graph(&net, &w).unwrap();
        assert_eq!(ops.last(), Some(&PlanOp::Fc { name: "fc".into() }));
        let gaps = ops.iter().filter(|o| **o == PlanOp::GlobalAvgPool).count();
        assert_eq!(gaps, 1);
    }

    #[test]
    fn avg_pool_kind_flows_through_lowering() {
        let mut net = zoo::tiny_cnn();
        net.schedule[1] = TopoOp::Pool(PoolSpec::avg(2, 2, 0));
        let w = weights_for(&net, None);
        let ops = derive_graph(&net, &w).unwrap();
        assert!(pools_of(&ops).iter().any(|p| p.kind == PoolKind::Avg));
    }

    #[test]
    fn row_contract_halo_math() {
        // AlexNet conv1 geometry: k=11, stride=4, pad=0. Output rows
        // [0, 2) need input rows [0, 15); rows [2, 4) need [8, 23).
        let c = RowContract { k: 11, stride: 4, pad: 0 };
        assert_eq!(c.in_span(0, 2, 64), (0, 15));
        assert_eq!(c.in_span(2, 4, 64), (8, 23));
        // Padded 3×3 stride-1 conv: the first tile's top halo is
        // clipped at the image edge, interior tiles reach one row up
        // and one row down.
        let c = RowContract { k: 3, stride: 1, pad: 1 };
        assert_eq!(c.in_span(0, 4, 16), (0, 6));
        assert_eq!(c.in_span(4, 8, 16), (3, 10));
        assert_eq!(c.in_span(12, 16, 16), (11, 16)); // bottom clip
        // Ceil-mode pool window hanging off the input: k=3 s=2 on 8
        // rows yields 4 windows; the last (rows 6..9) clips to 8.
        let c = RowContract { k: 3, stride: 2, pad: 0 };
        assert_eq!(c.in_span(3, 4, 8), (6, 8));
        // Elementwise: rows map 1:1.
        assert_eq!(RowContract::elementwise().in_span(5, 9, 16), (5, 9));
    }

    #[test]
    fn rows_emitted_is_the_forward_dual_of_in_span() {
        // AlexNet conv1 geometry: 15 input rows complete exactly the
        // first 2 output rows (in_span(0, 2) = (0, 15)).
        let c = RowContract { k: 11, stride: 4, pad: 0 };
        assert_eq!(c.rows_emitted(10, 64, 14), 0);
        assert_eq!(c.rows_emitted(11, 64, 14), 1);
        assert_eq!(c.rows_emitted(15, 64, 14), 2);
        assert_eq!(c.rows_emitted(64, 64, 14), 14);
        // Padded 3×3 stride-1 conv: the first row completes once two
        // real rows exist (the top halo is padding).
        let c = RowContract { k: 3, stride: 1, pad: 1 };
        assert_eq!(c.rows_emitted(1, 16, 16), 0);
        assert_eq!(c.rows_emitted(2, 16, 16), 1);
        assert_eq!(c.rows_emitted(15, 16, 16), 14);
        // The bottom row's clipped window only completes with the
        // whole input.
        assert_eq!(c.rows_emitted(16, 16, 16), 16);
        // Ceil-mode pool: the hanging last window waits for the full
        // input too (k=3 s=2 on 8 rows → 4 windows, last clipped).
        let c = RowContract { k: 3, stride: 2, pad: 0 };
        assert_eq!(c.rows_emitted(7, 8, 4), 3);
        assert_eq!(c.rows_emitted(8, 8, 4), 4);
        // Elementwise: ready maps 1:1.
        let e = RowContract::elementwise();
        assert_eq!(e.rows_emitted(5, 16, 16), 5);
        // Duality across a sweep of geometries and spans.
        for (k, s, p, in_h) in [(3, 1, 1, 16), (11, 4, 0, 35), (3, 2, 0, 8), (2, 2, 0, 16)] {
            let c = RowContract { k, stride: s, pad: p };
            let out_h = {
                // largest o with window start inside input+pad
                let padded = in_h + 2 * p;
                (padded - k) / s + 1
            };
            for o1 in 1..=out_h {
                let (_, hi) = c.in_span(0, o1, in_h);
                assert!(
                    c.rows_emitted(hi, in_h, out_h) >= o1,
                    "k{k} s{s} p{p}: span hi {hi} does not emit {o1}"
                );
            }
        }
    }

    #[test]
    fn contract_composition_telescopes_the_window_algebra() {
        // VGG block shape: 3×3 s1 p1 conv feeding a 2×2 s2 pool. The
        // composite window is 4 rows every 2, pad 1 — the familiar
        // "pool output row o reaches conv input rows [2o−1, 2o+3)".
        let conv = RowContract { k: 3, stride: 1, pad: 1 };
        let pool = RowContract { k: 2, stride: 2, pad: 0 };
        let c = conv.then(&pool);
        assert_eq!(c, RowContract { k: 4, stride: 2, pad: 1 });
        assert_eq!(c.in_span(1, 2, 16), (1, 5));
        // Elementwise is the identity on both sides.
        let e = RowContract::elementwise();
        assert_eq!(e.then(&c), c);
        assert_eq!(c.then(&e), c);
        // composed() folds upstream-first.
        let relu = RowContract::elementwise();
        assert_eq!(RowContract::composed([&conv, &relu, &pool]), c);
        assert_eq!(RowContract::composed([]), e);
    }

    #[test]
    fn composed_in_span_matches_the_backward_chain() {
        // Sweep random-ish chains: the composite's lo always equals the
        // stage-by-stage backward chain; hi equals it unless an
        // intermediate span clips at its in_h, where the composite is a
        // conservative superset. (Validated exhaustively by the
        // pipeline-design simulation; pinned here on a sweep.)
        let chains: &[&[(usize, usize, usize)]] = &[
            &[(3, 1, 1), (2, 2, 0)],
            &[(11, 4, 0), (3, 2, 0)],
            &[(3, 1, 1), (3, 1, 1), (2, 2, 0)],
            &[(1, 1, 0), (3, 2, 1), (3, 1, 2)],
            &[(5, 2, 2), (3, 2, 0), (3, 1, 1)],
        ];
        for geo in chains {
            for h0 in [7usize, 16, 33] {
                // Forward-propagate floor-mode extents.
                let mut hs = vec![h0];
                let mut ok = true;
                for &(k, s, p) in geo.iter() {
                    let h = *hs.last().unwrap();
                    if h + 2 * p < k {
                        ok = false;
                        break;
                    }
                    hs.push((h + 2 * p - k) / s + 1);
                }
                if !ok {
                    continue;
                }
                let contracts: Vec<RowContract> = geo
                    .iter()
                    .map(|&(k, s, p)| RowContract { k, stride: s, pad: p })
                    .collect();
                let comp = RowContract::composed(contracts.iter());
                let out_h = *hs.last().unwrap();
                for o0 in 0..out_h {
                    for o1 in (o0 + 1)..=out_h {
                        let (mut lo, mut hi) = (o0, o1);
                        let mut clipped = false;
                        for (i, c) in contracts.iter().enumerate().rev() {
                            let raw_hi = ((hi - 1) * c.stride + c.k).saturating_sub(c.pad);
                            if raw_hi > hs[i] {
                                clipped = true;
                            }
                            let (l, h) = c.in_span(lo, hi, hs[i]);
                            lo = l;
                            hi = h;
                        }
                        let got = comp.in_span(o0, o1, h0);
                        assert_eq!(got.0, lo, "{geo:?} h0={h0} span [{o0},{o1}): lo");
                        if clipped {
                            assert!(
                                got.1 >= hi,
                                "{geo:?} h0={h0} span [{o0},{o1}): composite hi {} < chained {hi}",
                                got.1
                            );
                        } else {
                            assert_eq!(got.1, hi, "{geo:?} h0={h0} span [{o0},{o1}): hi");
                        }
                    }
                }
                // Dual: composed rows_emitted never exceeds the chained
                // advance, and both finish at ready == h0.
                for ready in 0..=h0 {
                    let mut e = ready;
                    for (i, c) in contracts.iter().enumerate() {
                        e = c.rows_emitted(e, hs[i], hs[i + 1]);
                    }
                    let got = comp.rows_emitted(ready, h0, out_h);
                    assert!(got <= e, "{geo:?} h0={h0} ready={ready}: composite {got} > chained {e}");
                    if ready == h0 {
                        assert_eq!(got, out_h);
                        assert_eq!(e, out_h);
                    }
                }
            }
        }
    }

    #[test]
    fn segment_plan_fuses_conv_relu_pool_chains() {
        let net = zoo::tiny_cnn();
        let w = weights_for(&net, Some(4));
        let ops = derive_graph(&net, &w).unwrap();
        let segs = segment_plan(&ops, &net.layers);
        // conv1+relu+pool | conv2+relu+pool | conv3+relu | GAP | Fc.
        assert_eq!(segs.len(), 5);
        match (&segs[0], &segs[2]) {
            (Segment::Fused(a), Segment::Fused(b)) => {
                assert_eq!(a.len(), 3, "conv absorbs relu and pool");
                assert_eq!(b.len(), 2, "headless conv absorbs relu only");
                assert_eq!(a[0].contract, RowContract { k: 3, stride: 1, pad: 1 });
                assert_eq!(a[1].contract, RowContract::elementwise());
                assert_eq!(a[2].contract, RowContract { k: 2, stride: 2, pad: 0 });
            }
            other => panic!("expected fused segments, got {other:?}"),
        }
        assert_eq!(segs[3], Segment::GlobalAvgPool);
        assert_eq!(segs[4], Segment::Fc { name: "fc".into() });
    }

    #[test]
    fn segment_plan_recurses_into_branch_arms() {
        let net = zoo::inception_module("3a").unwrap();
        let w = weights_for(&net, None);
        let ops = derive_graph(&net, &w).unwrap();
        let segs = segment_plan(&ops, &net.layers);
        let arms = segs
            .iter()
            .find_map(|s| match s {
                Segment::Branch(arms) => Some(arms),
                _ => None,
            })
            .expect("inception module lowers to a branch");
        assert_eq!(arms.len(), 4);
        // Pool-proj arm: a lone pool segment, then conv+relu.
        let pool_arm = &arms[3];
        assert_eq!(pool_arm.len(), 2);
        match &pool_arm[0] {
            Segment::Fused(stages) => {
                assert_eq!(stages.len(), 1);
                assert_eq!(stages[0].contract, RowContract { k: 3, stride: 1, pad: 1 });
            }
            other => panic!("expected lone pool segment, got {other:?}"),
        }
    }
}
