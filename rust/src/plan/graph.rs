//! The generic layer-graph IR and its derivation from zoo topology.
//!
//! A plan is a flat op list (sequential chains only — inception-style
//! branching is out of scope and rejected with a config error). Pooling
//! is not stored anywhere in the zoo explicitly; it is *recovered* from
//! each layer's recorded input spatial size: a 2× drop between one
//! layer's output and the next layer's input means a 2×2 stride-2 max
//! pool sits between them (the VGG/tiny-CNN schedule). Any other ratio
//! (AlexNet/NiN's 3×3 stride-2 pools) cannot be expressed yet.

use crate::model::{LoadedWeights, Network};

/// One node of an execution plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanOp {
    /// Convolution of compiled conv layer `layer` (index into
    /// `CompiledNetwork::convs`), zero-padded by `pad`, with `stride`.
    Conv { layer: usize, pad: usize, stride: usize },
    /// ReLU fused with the rounding right-shift requantization by
    /// `frac_bits` (see `quant::requantize`).
    ReluRequant { frac_bits: u32 },
    /// 2×2 stride-2 integer max pool (truncating on odd extents).
    MaxPool2,
    /// Global average pool: i64 sum then floor division (matches the
    /// Python pipeline's `jnp` floor-divide).
    GlobalAvgPool,
    /// Fully connected head over the pre-kneaded class lanes.
    Fc,
}

/// Derive the op graph for `net` given the weight file's layer set.
///
/// * every conv layer must have a weight entry of matching OIHW shape;
/// * consecutive layers must either chain directly (`next.in_hw ==
///   out_hw`) or through one 2×2 pool (`next.in_hw * 2 == out_hw`);
/// * a weight layer named `fc` (absent from the zoo topology, which is
///   conv-only) appends `GlobalAvgPool → Fc` as the classifier head.
pub fn derive_graph(net: &Network, weights: &LoadedWeights) -> crate::Result<Vec<PlanOp>> {
    if net.layers.is_empty() {
        return Err(crate::Error::Config(format!(
            "network `{}` has no conv layers to plan",
            net.name
        )));
    }
    let mut ops = Vec::with_capacity(3 * net.layers.len() + 2);
    for (i, l) in net.layers.iter().enumerate() {
        let wl = weights.layer(&l.name).ok_or_else(|| {
            crate::Error::Artifact(format!(
                "{}: no weights for layer `{}`",
                net.name, l.name
            ))
        })?;
        let want = [l.out_c, l.in_c, l.k, l.k];
        if wl.shape != want {
            return Err(crate::Error::Shape(format!(
                "{}: weight shape {:?} != topology {:?}",
                l.name, wl.shape, want
            )));
        }
        ops.push(PlanOp::Conv { layer: i, pad: l.pad, stride: l.stride });
        ops.push(PlanOp::ReluRequant { frac_bits: wl.frac_bits });
        if let Some(next) = net.layers.get(i + 1) {
            let out = l.out_hw();
            if next.in_hw * 2 == out {
                ops.push(PlanOp::MaxPool2);
            } else if next.in_hw != out {
                return Err(crate::Error::Config(format!(
                    "{}: cannot derive pooling between `{}` (out {out}×{out}) and \
                     `{}` (in {hw}×{hw}) — only 2×2 stride-2 pools are expressible",
                    net.name,
                    l.name,
                    next.name,
                    hw = next.in_hw,
                )));
            }
        }
    }
    if weights.layer("fc").is_some() {
        ops.push(PlanOp::GlobalAvgPool);
        ops.push(PlanOp::Fc);
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::model::{zoo, LoadedLayer};

    /// Minimal weight set matching a network's topology (+optional fc).
    fn weights_for(net: &Network, fc_classes: Option<usize>) -> LoadedWeights {
        let mut layers: Vec<LoadedLayer> = net
            .layers
            .iter()
            .map(|l| LoadedLayer {
                name: l.name.clone(),
                shape: [l.out_c, l.in_c, l.k, l.k],
                frac_bits: 8,
                weights: vec![1; l.weight_count() as usize],
            })
            .collect();
        if let Some(classes) = fc_classes {
            let feat = net.layers.last().unwrap().out_c;
            layers.push(LoadedLayer {
                name: "fc".into(),
                shape: [classes, feat, 1, 1],
                frac_bits: 8,
                weights: vec![1; classes * feat],
            });
        }
        LoadedWeights { mode: Mode::Fp16, layers }
    }

    #[test]
    fn tiny_cnn_graph_matches_legacy_pipeline() {
        let net = zoo::tiny_cnn();
        let w = weights_for(&net, Some(4));
        let ops = derive_graph(&net, &w).unwrap();
        assert_eq!(
            ops,
            vec![
                PlanOp::Conv { layer: 0, pad: 1, stride: 1 },
                PlanOp::ReluRequant { frac_bits: 8 },
                PlanOp::MaxPool2,
                PlanOp::Conv { layer: 1, pad: 1, stride: 1 },
                PlanOp::ReluRequant { frac_bits: 8 },
                PlanOp::MaxPool2,
                PlanOp::Conv { layer: 2, pad: 1, stride: 1 },
                PlanOp::ReluRequant { frac_bits: 8 },
                PlanOp::GlobalAvgPool,
                PlanOp::Fc,
            ]
        );
    }

    #[test]
    fn vgg16_graph_places_four_pools() {
        let net = zoo::vgg16();
        let w = weights_for(&net, None);
        let ops = derive_graph(&net, &w).unwrap();
        let pools = ops.iter().filter(|o| **o == PlanOp::MaxPool2).count();
        // 5 blocks → 4 *internal* pool transitions (the pool after
        // block 5 has no following conv layer to betray it).
        assert_eq!(pools, 4);
        // Conv-only weight set → no classifier head.
        assert!(!ops.contains(&PlanOp::Fc));
        assert!(!ops.contains(&PlanOp::GlobalAvgPool));
    }

    #[test]
    fn underivable_pooling_is_config_error() {
        // AlexNet pools 3×3 stride 2 (55 → 27): not expressible.
        let net = zoo::alexnet();
        let w = weights_for(&net, None);
        match derive_graph(&net, &w) {
            Err(crate::Error::Config(msg)) => assert!(msg.contains("pooling")),
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn missing_or_misshapen_weights_rejected() {
        let net = zoo::tiny_cnn();
        let mut w = weights_for(&net, None);
        w.layers.remove(1);
        assert!(matches!(derive_graph(&net, &w), Err(crate::Error::Artifact(_))));
        let mut w = weights_for(&net, None);
        w.layers[0].shape = [9, 9, 9, 9];
        assert!(matches!(derive_graph(&net, &w), Err(crate::Error::Shape(_))));
    }
}
