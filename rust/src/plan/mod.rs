//! Compile-once execution plans — the compile/execute split of the
//! paper, lifted from a single hardcoded pipeline to any sequential
//! zoo topology.
//!
//! In the paper, weight kneading (§III.B) is a *compile-time* step: the
//! accelerator streams pre-kneaded weights from eDRAM and never
//! re-derives them per inference. The seed implementation instead
//! re-kneaded every filter lane on every `forward` call and hardcoded
//! the tiny CNN's layer names. This module restores the paper's split:
//!
//! * [`graph`] — a generic op graph (`Conv { pad, stride } →
//!   ReluRequant → Pool { Max|Avg, k, stride, pad } → Branch →
//!   GlobalAvgPool → Fc`) *lowered* from the explicit `TopoOp`
//!   schedule each `model::zoo` network declares, validated against
//!   the weight file's layer set. The whole evaluation zoo lowers —
//!   AlexNet/NiN's 3×3 stride-2 pools, NiN's global-average head,
//!   GoogleNet's four-arm inception branches — where earlier revisions
//!   *inferred* pooling from spatial-size ratios and could only
//!   express VGG-style chains.
//! * [`compiled`] — [`CompiledNetwork`]: kneads every conv filter lane
//!   and every FC class lane exactly once, at build time, in parallel;
//!   records the tile schedule ([`graph::segment_plan`]) plus a
//!   peak-bytes estimate serving uses to pick a tile height from a
//!   memory budget.
//! * [`exec`] — the segment executor, three dataflows over one tile
//!   schedule: the **streaming** walk (default for covered batches)
//!   runs each fused `Conv → ReluRequant [→ Pool]` segment as a
//!   producer/consumer pipeline over rolling rings that slide down
//!   the image — halo rows are retained across steps
//!   ([`graph::RowContract::rows_emitted`]), so every stage row is
//!   computed exactly once (`halo_recompute_rows == 0`) — the
//!   **tiled** walk fans (image, row-tile) stripes out with per-tile
//!   halo recompute ([`graph::RowContract::in_span`] halo math), and
//!   the **pipelined** walk chains the rings *across* segment
//!   boundaries (pool rows feed the next conv's ring directly, branch
//!   arms share one upstream ring and one concat ring), so only the
//!   trunk output ever materializes and peak memory is flat in
//!   network depth. Either way the conv's full-size pre-pool map
//!   never materializes, `Branch` arms run concurrently under
//!   `util::pool::split_budget` slices, compiled FC stacks execute
//!   through a flatten stage + per-name lanes, and output order is
//!   deterministic for any tile height, budget and walk. All three
//!   walks optionally run the **activation-aware skip lane**
//!   (`ExecOpts::skip_zero_activations`): row-level zero masks sealed
//!   at the ReLU points ride the rings (and one scan per materialized
//!   segment input) so all-zero rows/windows skip their SAC walk —
//!   bit-exact (I5), with skip counters and the measured
//!   post-activation distribution in [`AllocStats`]. The conv inner
//!   loop itself comes in two bit-identical kernels
//!   ([`ExecOpts::kernel`]): the **decoded-lane** fast path (default)
//!   executes the flat compile-time schedule
//!   ([`compiled::DecodedConv`]) over register-blocked strips of
//!   output pixels with row-band gather reuse, and the **legacy**
//!   per-pixel splitter walk is kept as the reference it is
//!   property-swept against (`rust/tests/plan_kernel.rs`).
//! * [`cost`] — the roofline-style analytical cost model behind the
//!   auto-tuner: per-candidate predicted peak bytes (the plan's
//!   walk-matched estimators), DRAM-equivalent traffic (boundary maps
//!   + tiled halo recompute; the pipelined walk skips the trunk
//!   prefix) and simulated compute cycles, validated against
//!   `execute_traced` ground truth (`tests/plan_tune.rs`).
//! * [`tune`] — the compile-time schedule auto-tuner:
//!   [`tune::tune`] turns (plan × memory budget × workers) into the
//!   [`TunedSchedule`] serving installs — walk, tile height,
//!   branch-arm thread split — memoized per plan fingerprint, with an
//!   explicit over-budget diagnostic when nothing fits. Both the
//!   engine registry and the legacy `SacBackend` path route through
//!   it, so the two serving surfaces can never disagree on a schedule.
//!
//! Losslessness invariant (DESIGN.md §I5): reusing kneaded lanes across
//! calls never changes logits — the executor is bit-identical to a
//! plain scalar MAC reference for every mode, kneading stride, and
//! thread count: the legacy `runtime::quantized::forward_scalar` on
//! the tiny CNN (`rust/tests/plan_exec.rs`) and the naive
//! declared-topology interpreter `model::reference` across the full
//! scaled zoo, inception branching included
//! (`rust/tests/plan_topology.rs`). The
//! zero-rekneading property — including one compile total across W
//! serving workers sharing an `Arc<CompiledNetwork>` — is pinned by
//! `rust/tests/plan_zero_knead.rs` via `kneading::knead_call_count`.

pub mod compiled;
pub mod cost;
pub mod exec;
pub mod graph;
pub mod tune;

pub use compiled::{
    CompiledConv, CompiledFc, CompiledNetwork, DecodedConv, DecodedEntry, DEFAULT_TILE_ROWS,
};
pub use cost::{CostEstimate, CostModel, DRAM_BYTES_PER_CYCLE, PEAK_BRACKET_FACTOR};
pub use exec::{AllocStats, ExecOpts, Kernel, PipelineSummary, Walk};
pub use graph::{derive_graph, segment_plan, FusedStage, PlanOp, RowContract, Segment};
pub use tune::{tune, tune_pinned, TunedSchedule, TILE_LADDER};
