//! Compile-once execution plans — the compile/execute split of the
//! paper, lifted from a single hardcoded pipeline to any sequential
//! zoo topology.
//!
//! In the paper, weight kneading (§III.B) is a *compile-time* step: the
//! accelerator streams pre-kneaded weights from eDRAM and never
//! re-derives them per inference. The seed implementation instead
//! re-kneaded every filter lane on every `forward` call and hardcoded
//! the tiny CNN's layer names. This module restores the paper's split:
//!
//! * [`graph`] — a generic op graph (`Conv { pad, stride } →
//!   ReluRequant → MaxPool2 → GlobalAvgPool → Fc`) *derived* from
//!   `model::zoo` topology plus the weight file's layer set, instead of
//!   hardcoded `"conv1".."conv3"/"fc"` names.
//! * [`compiled`] — [`CompiledNetwork`]: kneads every conv filter lane
//!   and every FC class lane exactly once, at build time, in parallel.
//! * [`exec`] — the executor: walks the op graph and parallelizes the
//!   conv hot loop over (image, output-row) stripes with
//!   `util::pool::par_map`, preserving deterministic output order.
//!
//! Losslessness invariant (DESIGN.md §I5): reusing kneaded lanes across
//! calls never changes logits — the executor is bit-identical to the
//! legacy scalar `runtime::quantized::forward_scalar` for every mode,
//! kneading stride, and thread count. Verified by
//! `rust/tests/plan_exec.rs`; the zero-rekneading property is pinned by
//! `rust/tests/plan_zero_knead.rs` via `kneading::knead_call_count`.

pub mod compiled;
pub mod exec;
pub mod graph;

pub use compiled::{CompiledConv, CompiledFc, CompiledNetwork};
pub use graph::{derive_graph, PlanOp};
