//! Compile-once execution plans — the compile/execute split of the
//! paper, lifted from a single hardcoded pipeline to any sequential
//! zoo topology.
//!
//! In the paper, weight kneading (§III.B) is a *compile-time* step: the
//! accelerator streams pre-kneaded weights from eDRAM and never
//! re-derives them per inference. The seed implementation instead
//! re-kneaded every filter lane on every `forward` call and hardcoded
//! the tiny CNN's layer names. This module restores the paper's split:
//!
//! * [`graph`] — a generic op graph (`Conv { pad, stride } →
//!   ReluRequant → Pool { Max|Avg, k, stride, pad } → Branch →
//!   GlobalAvgPool → Fc`) *lowered* from the explicit `TopoOp`
//!   schedule each `model::zoo` network declares, validated against
//!   the weight file's layer set. The whole evaluation zoo lowers —
//!   AlexNet/NiN's 3×3 stride-2 pools, NiN's global-average head,
//!   GoogleNet's four-arm inception branches — where earlier revisions
//!   *inferred* pooling from spatial-size ratios and could only
//!   express VGG-style chains.
//! * [`compiled`] — [`CompiledNetwork`]: kneads every conv filter lane
//!   and every FC class lane exactly once, at build time, in parallel.
//! * [`exec`] — the executor: walks the op graph (recursing into
//!   branch arms and concatenating along channels) and parallelizes
//!   the conv hot loop over (image, output-row) stripes with
//!   `util::pool::par_map`, preserving deterministic output order.
//!
//! Losslessness invariant (DESIGN.md §I5): reusing kneaded lanes across
//! calls never changes logits — the executor is bit-identical to a
//! plain scalar MAC reference for every mode, kneading stride, and
//! thread count: the legacy `runtime::quantized::forward_scalar` on
//! the tiny CNN (`rust/tests/plan_exec.rs`) and the naive
//! declared-topology interpreter `model::reference` across the full
//! scaled zoo, inception branching included
//! (`rust/tests/plan_topology.rs`). The
//! zero-rekneading property — including one compile total across W
//! serving workers sharing an `Arc<CompiledNetwork>` — is pinned by
//! `rust/tests/plan_zero_knead.rs` via `kneading::knead_call_count`.

pub mod compiled;
pub mod exec;
pub mod graph;

pub use compiled::{CompiledConv, CompiledFc, CompiledNetwork};
pub use graph::{derive_graph, PlanOp};
