//! [`CompiledNetwork`] — the compile-time half of the split.
//!
//! Building a plan kneads every conv filter lane and every FC class
//! lane exactly once (in parallel across filters), then stores only the
//! kneaded form — exactly what the accelerator keeps in eDRAM. The
//! executor (`plan::exec`) streams these lanes; it never calls back
//! into the kneading compiler.

use crate::config::Mode;
use crate::kneading::{knead_lane, KneadedLane, Lane};
use crate::model::{LoadedLayer, LoadedWeights, Network, Tensor};
use crate::util::pool::{par_map, split_budget};

use super::exec::{Kernel, PipelineSummary, Walk};
use super::graph::{derive_graph, segment_plan, FusedStage, PlanOp, Segment};

/// Default output rows per fused tile (see [`CompiledNetwork::tile_rows`]).
/// Small enough that conv→pool rings stay a few rows tall, large enough
/// that the per-tile halo recompute (≤ `pool.k − pool.stride` conv rows
/// per boundary) stays a small fraction of the tile.
pub const DEFAULT_TILE_ROWS: usize = 4;

/// One decoded SAC operation of a [`DecodedConv`] schedule: accumulate
/// `sign × acts[slot]` into segment register `seg`. The slot-decode
/// work the splitter performs per pixel under the legacy kernel
/// (walking each kneaded weight's occupied mask and pointer table)
/// happened exactly once, here, at plan compile — the executor's hot
/// loop just streams these 8-byte entries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodedEntry {
    /// Activation index into the filter's full im2col lane —
    /// *absolute* (`group × ks + pointer`), so the executor indexes
    /// one gathered window without per-group re-slicing.
    pub slot: u32,
    /// Destination segment register (the essential bit's position).
    pub seg: u8,
    /// `±1`, the kneaded weight's sign for this slot.
    pub sign: i8,
}

/// Compile-time decoded schedule for one conv layer: every filter's
/// kneaded lanes lowered into one flat entry array with CSR-style
/// per-filter offsets, plus the per-window energy counts the schedule
/// replaces — so the decoded kernel charges exactly what the legacy
/// splitter walk would have counted.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DecodedConv {
    /// All filters' entries, filter-major; within a filter the order
    /// is group-ascending, kneaded-weight-in-order, occupied-bit-
    /// ascending — the exact order `split_kneaded` accumulates in,
    /// which is what makes the decoded kernel bit-exact (I5).
    pub entries: Vec<DecodedEntry>,
    /// CSR offsets into `entries`, length `filters + 1`: filter `f`
    /// owns `entries[offsets[f]..offsets[f + 1]]`.
    pub offsets: Vec<u32>,
    /// Splitter slot decodes one executed window costs across all
    /// filters (Σ `kw.slots().len()` — what the legacy kernel counts).
    pub decodes_per_window: u64,
    /// Segment-adder accumulations one executed window costs across
    /// all filters (= `entries.len()`, one per essential bit).
    pub adds_per_window: u64,
}

/// One conv layer's compile-time product: per-filter pre-kneaded lanes
/// plus the shape metadata the executor needs (weights themselves are
/// dropped — the kneaded form is lossless, DESIGN.md §I1).
#[derive(Debug, Clone)]
pub struct CompiledConv {
    pub name: String,
    pub out_c: usize,
    pub in_c: usize,
    pub kh: usize,
    pub kw: usize,
    /// One kneaded weight lane per output filter, OIHW filter order.
    pub lanes: Vec<KneadedLane>,
    /// The lanes lowered into the decoded-lane kernel's flat schedule
    /// (DESIGN.md §Decoded-lane kernel). Derived from `lanes` at
    /// compile — pure lowering, no re-kneading.
    pub decoded: DecodedConv,
}

impl CompiledConv {
    /// Reduction length of one filter lane: `in_c × kh × kw`.
    pub fn lane_len(&self) -> usize {
        self.in_c * self.kh * self.kw
    }
}

/// Lower pre-kneaded filter lanes into the decoded kernel's flat
/// schedule. Reads the kneaded form only (the zero-knead invariant
/// holds: compile kneads once, this pass just re-indexes it), visiting
/// slots in the same order `split_kneaded` does so the executor's
/// accumulation order — and therefore every i64 partial sum — is
/// identical to the legacy walk's.
fn decode_conv_schedule(lanes: &[KneadedLane]) -> DecodedConv {
    let mut entries = Vec::new();
    let mut offsets = Vec::with_capacity(lanes.len() + 1);
    offsets.push(0u32);
    let mut decodes = 0u64;
    for lane in lanes {
        for (g, group) in lane.groups.iter().enumerate() {
            let base = g * lane.ks;
            for kw in &group.kneaded {
                decodes += kw.slots().len() as u64;
                let mut mask = kw.occupied_mask();
                while mask != 0 {
                    let b = mask.trailing_zeros() as usize;
                    mask &= mask - 1;
                    let p = kw.pointer(b);
                    entries.push(DecodedEntry {
                        slot: (base + p as usize) as u32,
                        seg: b as u8,
                        sign: group.sign_of(p) as i8,
                    });
                }
            }
        }
        offsets.push(entries.len() as u32);
    }
    let adds = entries.len() as u64;
    DecodedConv { entries, offsets, decodes_per_window: decodes, adds_per_window: adds }
}

/// One compiled fully-connected layer: one pre-kneaded lane per output
/// feature. A plan holds one of these **per declared head name**
/// (VGG's fc6/fc7/fc8 each compile their own lane set), in schedule
/// order; the stack's last head emits raw logits, every earlier head
/// is activation-fused like a conv.
#[derive(Debug, Clone)]
pub struct CompiledFc {
    /// Weight-layer / head name (`fc`, `fc6`, `loss3/classifier`, …).
    pub name: String,
    /// Output features (classes for the stack's last head).
    pub classes: usize,
    pub feat_dim: usize,
    /// Requantization shift applied when `relu` is set.
    pub frac_bits: u32,
    /// Activation-fused (every head but the stack's last).
    pub relu: bool,
    pub lanes: Vec<KneadedLane>,
}

/// A compile-once execution plan for one network.
///
/// Build with [`CompiledNetwork::compile`]; run batches with
/// [`CompiledNetwork::execute`](super::exec). Reusing one plan across
/// calls never changes logits (losslessness invariant I5) and performs
/// zero kneading after construction.
#[derive(Debug, Clone)]
pub struct CompiledNetwork {
    pub(crate) ops: Vec<PlanOp>,
    /// Tile schedule: the op graph grouped into fused
    /// `Conv → ReluRequant [→ Pool]` walks and materialization points
    /// (see [`segment_plan`]).
    pub(crate) schedule: Vec<Segment>,
    pub(crate) convs: Vec<CompiledConv>,
    /// Compiled FC heads, schedule order (empty for conv-trunk plans).
    pub(crate) fcs: Vec<CompiledFc>,
    /// Declared (channels, spatial size) of the first executed conv —
    /// the shape basis for [`Self::peak_bytes_estimate`].
    pub(crate) declared_in: (usize, usize),
    /// Output rows per fused tile for the default `execute` path
    /// (0 = full height, i.e. materialize every stage of a fused
    /// chain at once). Overridable per call via `ExecOpts`; serving
    /// picks it from a memory budget ([`Self::tile_rows_for_budget`]).
    pub tile_rows: usize,
    /// Compiled walk preference, consulted by `execute` when
    /// `ExecOpts::walk` is `None`: the engine registry pins
    /// [`Walk::Pipelined`] here when its memory budget demands
    /// whole-network streaming. `None` leaves the executor's
    /// batch-vs-workers policy in charge.
    pub walk_hint: Option<Walk>,
    /// Default for the activation-aware skip lane, consulted by
    /// `execute` when `ExecOpts::skip_zero_activations` is `None`
    /// (set by `EngineBuilder::skip_zero_activations`). Off by
    /// default — the lane is bit-exact (I5) but adds mask upkeep to
    /// every walk. Like `walk_hint`/`tile_rows` this is a scheduling
    /// knob, not plan identity: it stays out of [`Self::fingerprint`].
    pub skip_zero_activations: bool,
    /// Default conv inner loop, consulted by `execute` when
    /// `ExecOpts::kernel` is `None` — [`Kernel::Decoded`] unless a
    /// caller (`EngineBuilder::kernel`) pins the legacy walk. Like
    /// `walk_hint` this moves host time only, never logits or
    /// counters, so it stays out of [`Self::fingerprint`].
    pub kernel: Kernel,
    pub mode: Mode,
    /// Kneading stride the lanes were compiled with. Values are
    /// invariant to KS (SAC ≡ MAC for any stride); KS only moves the
    /// simulated cycle cost.
    pub ks: usize,
    /// `knead_lane` invocations performed at build time — one per conv
    /// filter plus one per FC class. The execute path adds zero more.
    pub kneads_at_build: u64,
}

/// Knead the per-filter lanes of one weight layer (parallel across
/// filters; output order is deterministic).
fn knead_filter_lanes(
    wl: &LoadedLayer,
    lane_len: usize,
    ks: usize,
    mode: Mode,
) -> Vec<KneadedLane> {
    let filters: Vec<usize> = (0..wl.shape[0]).collect();
    par_map(&filters, |_, &f| {
        let ws = wl.weights[f * lane_len..(f + 1) * lane_len].to_vec();
        knead_lane(&Lane::new(ws, vec![0; lane_len]), ks, mode)
    })
}

impl CompiledNetwork {
    /// Compile `weights` against the declared topology of `net`.
    ///
    /// Errors if the weight set does not match the topology, the
    /// declared schedule does not validate (shape chaining, branch arm
    /// agreement, one use per layer — see [`derive_graph`]), or `ks`
    /// is out of the supported 2..=256.
    pub fn compile(
        net: &Network,
        weights: &LoadedWeights,
        ks: usize,
        mode: Mode,
    ) -> crate::Result<Self> {
        if !(2..=256).contains(&ks) {
            return Err(crate::Error::Config(format!(
                "ks={ks} out of supported range 2..=256"
            )));
        }
        let ops = derive_graph(net, weights)?;
        let mut kneads_at_build = 0u64;
        let mut convs = Vec::with_capacity(net.layers.len());
        for l in &net.layers {
            let wl = weights.layer(&l.name).expect("derive_graph validated layers");
            let lane_len = l.in_c * l.k * l.k;
            kneads_at_build += l.out_c as u64;
            let lanes = knead_filter_lanes(wl, lane_len, ks, mode);
            let decoded = decode_conv_schedule(&lanes);
            convs.push(CompiledConv {
                name: l.name.clone(),
                out_c: l.out_c,
                in_c: l.in_c,
                kh: l.k,
                kw: l.k,
                lanes,
                decoded,
            });
        }
        // Compile one lane set per executable FC head, in schedule
        // order — a zoo net with a declaration-only FC stack must not
        // knead (or hold resident) lanes it will never stream. Every
        // head but the stack's last is activation-fused (the published
        // VGG fc6/fc7 carry ReLUs; a lone head emits raw logits).
        let fc_names: Vec<&str> = ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::Fc { name } => Some(name.as_str()),
                _ => None,
            })
            .collect();
        let mut fcs = Vec::with_capacity(fc_names.len());
        for (i, name) in fc_names.iter().enumerate() {
            let fl = weights.layer(name).expect("derive_graph bound every fc head");
            let classes = fl.shape[0];
            let feat_dim = fl.shape[1] * fl.shape[2] * fl.shape[3];
            kneads_at_build += classes as u64;
            fcs.push(CompiledFc {
                name: (*name).to_string(),
                classes,
                feat_dim,
                frac_bits: fl.frac_bits,
                relu: i + 1 < fc_names.len(),
                lanes: knead_filter_lanes(fl, feat_dim, ks, mode),
            });
        }
        let schedule = segment_plan(&ops, &net.layers);
        let declared_in = ops
            .iter()
            .find_map(|op| match op {
                PlanOp::Conv { layer, .. } => {
                    net.layers.get(*layer).map(|l| (l.in_c, l.in_hw))
                }
                _ => None,
            })
            .unwrap_or((0, 0));
        Ok(Self {
            ops,
            schedule,
            convs,
            fcs,
            declared_in,
            tile_rows: DEFAULT_TILE_ROWS,
            walk_hint: None,
            skip_zero_activations: false,
            kernel: Kernel::default(),
            mode,
            ks,
            kneads_at_build,
        })
    }

    /// The derived op graph (read-only view).
    pub fn ops(&self) -> &[PlanOp] {
        &self.ops
    }

    /// The tile schedule the executor walks (read-only view).
    pub fn schedule(&self) -> &[Segment] {
        &self.schedule
    }

    /// Compiled conv layers, topology order.
    pub fn convs(&self) -> &[CompiledConv] {
        &self.convs
    }

    /// The final classifier head (the stack's last compiled FC), if
    /// the plan executes one.
    pub fn fc(&self) -> Option<&CompiledFc> {
        self.fcs.last()
    }

    /// Every compiled FC head, schedule order.
    pub fn fc_heads(&self) -> &[CompiledFc] {
        &self.fcs
    }

    /// Look up a compiled FC head by name.
    pub fn fc_head(&self, name: &str) -> Option<&CompiledFc> {
        self.fcs.iter().find(|f| f.name == name)
    }

    /// Stable identity of this plan for the auto-tuner's memoization
    /// key (`plan::tune`): hashes the lowered op graph, every layer's
    /// geometry (names, channel/kernel extents, head widths), the
    /// kneading stride, precision mode and declared input extent —
    /// everything the schedule search depends on, and nothing it does
    /// not (weights don't move the memory model, so two weight sets
    /// over the same topology share tuning results).
    pub fn fingerprint(&self) -> u64 {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let mut h = DefaultHasher::new();
        format!("{:?}", self.ops).hash(&mut h);
        for c in &self.convs {
            c.name.hash(&mut h);
            (c.out_c, c.in_c, c.kh, c.kw).hash(&mut h);
        }
        for f in &self.fcs {
            f.name.hash(&mut h);
            (f.classes, f.feat_dim).hash(&mut h);
        }
        self.ks.hash(&mut h);
        format!("{:?}", self.mode).hash(&mut h);
        self.declared_in.hash(&mut h);
        h.finish()
    }

    /// Total kneaded weights across all lanes — the plan's resident
    /// "eDRAM" footprint in kneaded-weight units.
    pub fn kneaded_weights(&self) -> usize {
        let conv: usize = self
            .convs
            .iter()
            .flat_map(|c| c.lanes.iter())
            .map(KneadedLane::kneaded_len)
            .sum();
        let fc: usize = self
            .fcs
            .iter()
            .flat_map(|f| f.lanes.iter())
            .map(KneadedLane::kneaded_len)
            .sum();
        conv + fc
    }

    /// Source weights covered by all lanes (compression denominator).
    pub fn source_weights(&self) -> usize {
        let conv: usize = self
            .convs
            .iter()
            .flat_map(|c| c.lanes.iter())
            .map(KneadedLane::source_len)
            .sum();
        let fc: usize = self
            .fcs
            .iter()
            .flat_map(|f| f.lanes.iter())
            .map(KneadedLane::source_len)
            .sum();
        conv + fc
    }

    /// Logit count per image (classifier plans only).
    pub fn output_classes(&self) -> Option<usize> {
        self.fcs.last().map(|f| f.classes)
    }

    /// Coarse peak feature-map bytes for ONE image at the declared
    /// topology sizes, under a fused walk with `tile_rows` output rows
    /// per tile (0 = full height) and a `workers` thread budget.
    ///
    /// Per fused segment this counts input map + output map + one
    /// worst-case (first-tile) ring per concurrently live tile; branch
    /// arms add up because they run concurrently. Weights, per-thread
    /// scratch and allocator overhead are excluded — this is a
    /// planning heuristic for [`Self::tile_rows_for_budget`], not an
    /// accounting guarantee (the measured counterpart is
    /// `execute_traced`).
    pub fn peak_bytes_estimate(&self, tile_rows: usize, workers: usize) -> u64 {
        self.estimate(tile_rows, workers, false)
    }

    /// [`Self::peak_bytes_estimate`]'s streaming-walk counterpart: one
    /// rolling ring per intermediate stage per concurrently streamed
    /// image, no per-tile output staging (final-stage rows stream
    /// straight into the segment's output map). Structurally at or
    /// below the tiled estimate for the same tile height — the
    /// measured version of that claim (`execute_traced` peaks) is
    /// property-tested across the zoo in `rust/tests/plan_streaming.rs`.
    pub fn streaming_peak_bytes_estimate(&self, tile_rows: usize, workers: usize) -> u64 {
        self.estimate(tile_rows, workers, true)
    }

    /// The pipelined-walk counterpart of the peak estimates: under
    /// whole-network streaming the trunk never materializes its
    /// intermediate maps, so the peak is the input map + one rolling
    /// ring set per concurrently streamed image + the trunk output —
    /// flat in network depth (±ring working set). The GAP/flatten/FC
    /// tail walks over the (already counted) trunk output and only
    /// adds feature vectors, so the trunk term dominates. Falls back
    /// to the streaming estimate when fewer than two schedule segments
    /// are pipeable (the pipelined walk degenerates there).
    pub fn pipelined_peak_bytes_estimate(&self, tile_rows: usize, workers: usize) -> u64 {
        const BYTES: u64 = 4;
        let (c, hw) = self.declared_in;
        if c == 0 || hw == 0 {
            return 0;
        }
        match super::exec::pipeline_summary(self, c, hw, hw, tile_rows) {
            Ok(Some(s)) => {
                let in_bytes = (c * hw * hw) as u64 * BYTES;
                in_bytes + s.out_bytes + s.ring_bytes * workers.max(1) as u64
            }
            _ => self.streaming_peak_bytes_estimate(tile_rows, workers),
        }
    }

    /// Whole-network pipeline profile ([`PipelineSummary`]) at an
    /// explicit input extent (benches run scaled workloads) and
    /// advance step (`0` = whole image per feed). `None` when the
    /// plan's pipeable prefix is shorter than two segments or the
    /// geometry does not validate at that extent.
    pub fn pipeline_summary(&self, in_hw: usize, step: usize) -> Option<PipelineSummary> {
        let (c, _) = self.declared_in;
        if c == 0 || in_hw == 0 {
            return None;
        }
        super::exec::pipeline_summary(self, c, in_hw, in_hw, step).ok().flatten()
    }

    fn estimate(&self, tile_rows: usize, workers: usize, streaming: bool) -> u64 {
        let mut peak = 0u64;
        let (c, hw) = self.declared_in;
        if c == 0 || hw == 0 {
            return 0;
        }
        self.estimate_segs(
            &self.schedule,
            c,
            hw,
            hw,
            tile_rows,
            workers.max(1),
            streaming,
            &mut peak,
        );
        peak
    }

    /// Walk `segs` from an input of shape (c, h, w), folding each
    /// segment's peak-bytes candidate into `peak`; returns the output
    /// shape. Shapes mirror the executor's arithmetic; the declared
    /// topology already validated at compile time, so degenerate
    /// windows simply contribute zero here instead of erroring.
    #[allow(clippy::too_many_arguments)]
    fn estimate_segs(
        &self,
        segs: &[Segment],
        mut c: usize,
        mut h: usize,
        mut w: usize,
        tile_rows: usize,
        workers: usize,
        streaming: bool,
        peak: &mut u64,
    ) -> (usize, usize, usize) {
        const BYTES: u64 = 4; // i32 feature maps
        let map_bytes = |c: usize, h: usize, w: usize| (c * h * w) as u64 * BYTES;
        for seg in segs {
            match seg {
                Segment::Fused(stages) => {
                    let in_bytes = map_bytes(c, h, w);
                    // (in_c, in_h, in_w, out_c, out_w) per stage; row
                    // extents re-derived per tile below.
                    let mut dims = Vec::with_capacity(stages.len());
                    let (mut cc, mut hh, mut ww) = (c, h, w);
                    for st in stages {
                        let (oc, oh, ow) = match &st.op {
                            PlanOp::Conv { layer, pad, stride } => {
                                let cv = &self.convs[*layer];
                                let oh = (hh + 2 * pad)
                                    .checked_sub(cv.kh)
                                    .map_or(0, |d| d / stride + 1);
                                let ow = (ww + 2 * pad)
                                    .checked_sub(cv.kw)
                                    .map_or(0, |d| d / stride + 1);
                                (cv.out_c, oh, ow)
                            }
                            PlanOp::Pool(spec) => (
                                cc,
                                spec.out_hw(hh).unwrap_or(0),
                                spec.out_hw(ww).unwrap_or(0),
                            ),
                            _ => (cc, hh, ww),
                        };
                        dims.push((cc, hh, ww, oc, ow));
                        (cc, hh, ww) = (oc, oh, ow);
                    }
                    let out_bytes = map_bytes(cc, hh, ww);
                    let oh_final = hh;
                    let tile = if tile_rows == 0 { oh_final } else { tile_rows.min(oh_final) };
                    let mut ring = 0u64;
                    if tile > 0 {
                        // First-tile spans, walked backward through the
                        // contracts (the first tile carries the tallest
                        // top halo clip-free span).
                        let m = stages.len();
                        let mut spans = vec![(0usize, 0usize); m + 1];
                        spans[m] = (0, tile);
                        for i in (0..m).rev() {
                            let (o0, o1) = spans[i + 1];
                            spans[i] = stages[i].contract.in_span(o0, o1, dims[i].1);
                        }
                        if streaming {
                            // One rolling ring per intermediate
                            // Conv/Pool stage, held for the whole
                            // image walk, per concurrently streamed
                            // image. Elementwise stages mutate their
                            // producer's ring, and the SINK — the
                            // last windowed stage — streams straight
                            // into the output map, so neither owns a
                            // ring (a Conv→ReluRequant segment has
                            // none at all). The margin models the
                            // retained halo rows: the window height
                            // of the ring's next *windowed* reader —
                            // the relu between a conv and its pool
                            // retains nothing.
                            let is_elem = |s: &FusedStage| {
                                matches!(s.op, PlanOp::ReluRequant { .. })
                            };
                            let sink = stages
                                .iter()
                                .rposition(|s| !is_elem(s))
                                .unwrap_or(0);
                            let mut sum = 0u64;
                            for i in 0..m {
                                if i == sink || is_elem(&stages[i]) {
                                    continue;
                                }
                                let (_, _, _, oc, ow) = dims[i];
                                let stage_oh = dims[i + 1].1;
                                let margin = stages[i + 1..]
                                    .iter()
                                    .find(|s| !is_elem(s))
                                    .map_or(0, |s| s.contract.k);
                                let rows = (spans[i + 1].1 - spans[i + 1].0 + margin)
                                    .min(stage_oh);
                                sum += (oc * rows * ow) as u64 * BYTES;
                            }
                            ring = sum * workers as u64;
                        } else {
                            for i in 0..m {
                                let (ic, _, iw, oc, ow) = dims[i];
                                // Stage 0 reads the materialized input
                                // map in place (already counted as
                                // in_bytes); later stages read the
                                // previous ring.
                                let in_rows =
                                    if i == 0 { 0 } else { spans[i].1 - spans[i].0 };
                                let out_rows = spans[i + 1].1 - spans[i + 1].0;
                                ring = ring.max(
                                    (ic * in_rows * iw + oc * out_rows * ow) as u64 * BYTES,
                                );
                            }
                            let tiles_total = oh_final.div_ceil(tile).max(1);
                            ring *= workers.clamp(1, tiles_total) as u64;
                        }
                    }
                    *peak = (*peak).max(in_bytes + out_bytes + ring);
                    (c, h, w) = (cc, hh, ww);
                }
                Segment::Branch(arms) => {
                    let in_bytes = map_bytes(c, h, w);
                    let budgets = split_budget(workers, arms.len());
                    let mut arm_sum = 0u64;
                    let mut total_c = 0usize;
                    let (mut oh, mut ow) = (h, w);
                    for (a, arm) in arms.iter().enumerate() {
                        let mut arm_peak = 0u64;
                        let (ac, ah, aw) = self.estimate_segs(
                            arm, c, h, w, tile_rows, budgets[a], streaming, &mut arm_peak,
                        );
                        arm_sum += arm_peak;
                        total_c += ac;
                        (oh, ow) = (ah, aw);
                    }
                    let out_bytes = map_bytes(total_c, oh, ow);
                    *peak = (*peak).max(in_bytes + arm_sum + out_bytes);
                    (c, h, w) = (total_c, oh, ow);
                }
                Segment::GlobalAvgPool => {
                    *peak = (*peak).max(map_bytes(c, h, w) + c as u64 * BYTES);
                    (h, w) = (1, 1);
                }
                Segment::Flatten => {
                    // Pure reshape: (C, H, W) folds into C·H·W
                    // features, no bytes move.
                    (c, h, w) = (c * h * w, 1, 1);
                }
                Segment::Fc { name } => {
                    if let Some(fc) = self.fc_head(name) {
                        *peak = (*peak).max((c + fc.classes) as u64 * BYTES);
                        c = fc.classes;
                    }
                }
            }
        }
        (c, h, w)
    }

    /// Largest tile height whose estimated peak fits `budget_bytes`
    /// (per image, `workers` concurrent tiles) — how serving turns a
    /// memory budget into a tile size. Falls back to single-row tiles
    /// when even they exceed the budget: the estimate then simply
    /// reports the floor the topology imposes — a silent clamp at this
    /// layer, surfaced as an explicit warn-once diagnostic (and the
    /// `TunedSchedule::over_budget` flag) by the schedule auto-tuner
    /// every serving path now sizes through (`plan::tune`).
    ///
    /// The tiled estimate is the sizing bound for **both** walks: a
    /// streaming walk at the same tile height replaces each worker's
    /// per-tile ring + output staging with one rolling ring of the
    /// same span, so its peak sits at or below the tiled walk's
    /// ([`Self::streaming_peak_bytes_estimate`]; the measured
    /// counterpart is property-tested in `rust/tests/plan_streaming.rs`).
    /// One budget therefore bounds the ring depth of whichever walk
    /// `execute` picks.
    pub fn tile_rows_for_budget(&self, budget_bytes: u64, workers: usize) -> usize {
        self.tile_rows_for_budget_walk(budget_bytes, workers, Walk::Tiled)
    }

    /// Walk-aware [`Self::tile_rows_for_budget`]: size the tile height
    /// against the estimate of the walk that will actually run — the
    /// pipelined walk's ring working set is far below a segment map,
    /// so the same budget affords it much taller tiles (or fits at
    /// all where the per-segment walks cannot).
    pub fn tile_rows_for_budget_walk(
        &self,
        budget_bytes: u64,
        workers: usize,
        walk: Walk,
    ) -> usize {
        let est = |t: usize| match walk {
            Walk::Tiled => self.peak_bytes_estimate(t, workers),
            Walk::Streaming => self.streaming_peak_bytes_estimate(t, workers),
            Walk::Pipelined => self.pipelined_peak_bytes_estimate(t, workers),
        };
        for t in [64usize, 32, 16, 8, 4, 2] {
            if est(t) <= budget_bytes {
                return t;
            }
        }
        1
    }

    /// Validate that `x` is a plausible (N, C, H, W) input batch for
    /// the first conv layer the plan *executes* (the schedule need not
    /// open with layer 0); returns the batch size.
    pub fn check_input(&self, x: &Tensor<i32>) -> crate::Result<usize> {
        let first = self
            .ops
            .iter()
            .find_map(|op| match op {
                PlanOp::Conv { layer, .. } => self.convs.get(*layer),
                _ => None,
            })
            .ok_or_else(|| crate::Error::Config("plan has no conv layers".into()))?;
        match *x.shape() {
            [n, c, _, _] if c == first.in_c => Ok(n),
            [_, c, _, _] => Err(crate::Error::Shape(format!(
                "input channels {c} != plan `{}` channels {}",
                first.name, first.in_c
            ))),
            _ => Err(crate::Error::Shape("plan input must be 4-D NCHW".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kneading::unknead_group;
    use crate::model::zoo;

    fn tiny_weights(seed: u64) -> LoadedWeights {
        crate::coordinator::SacBackend::synthetic_weights(seed).unwrap()
    }

    #[test]
    fn compile_kneads_once_per_lane() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(1);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        // One lane per conv filter + one per class.
        assert_eq!(plan.convs.len(), 3);
        assert_eq!(plan.convs[0].lanes.len(), 8);
        assert_eq!(plan.convs[1].lanes.len(), 16);
        assert_eq!(plan.convs[2].lanes.len(), 16);
        let fc = plan.fc().unwrap();
        assert_eq!((fc.classes, fc.feat_dim), (4, 16));
        assert_eq!(fc.name, "fc");
        assert!(!fc.relu, "a lone head emits raw logits");
        assert_eq!(plan.kneads_at_build, 8 + 16 + 16 + 4);
        assert!(plan.kneaded_weights() > 0);
        assert!(plan.kneaded_weights() <= plan.source_weights());
    }

    #[test]
    fn compiled_lanes_are_lossless() {
        // Unkneading every stored group reproduces the source weights
        // bit-for-bit (invariant I1 held through the plan cache).
        let net = zoo::tiny_cnn();
        let w = tiny_weights(9);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        for (conv, wl) in plan.convs.iter().zip(&w.layers) {
            let lane_len = conv.lane_len();
            for (f, lane) in conv.lanes.iter().enumerate() {
                let mut back = Vec::with_capacity(lane_len);
                for g in &lane.groups {
                    back.extend(unknead_group(g, Mode::Fp16));
                }
                assert_eq!(
                    back,
                    &wl.weights[f * lane_len..(f + 1) * lane_len],
                    "{} filter {f}",
                    conv.name
                );
            }
        }
    }

    #[test]
    fn decoded_schedule_counts_match_kneaded_lanes() {
        // The schedule's precomputed per-window energy constants must
        // equal what the legacy splitter walk counts: one decode per
        // slot of every kneaded weight, one add per essential bit.
        let net = zoo::tiny_cnn();
        let w = tiny_weights(4);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        for conv in &plan.convs {
            let sched = &conv.decoded;
            let mut want_decodes = 0u64;
            let mut want_adds = 0u64;
            for lane in &conv.lanes {
                for g in &lane.groups {
                    for kw in &g.kneaded {
                        want_decodes += kw.slots().len() as u64;
                        want_adds += kw.occupancy() as u64;
                    }
                }
            }
            assert_eq!(sched.decodes_per_window, want_decodes, "{}", conv.name);
            assert_eq!(sched.adds_per_window, want_adds, "{}", conv.name);
            assert_eq!(sched.adds_per_window, sched.entries.len() as u64);
            // CSR offsets: one span per filter, covering all entries.
            assert_eq!(sched.offsets.len(), conv.lanes.len() + 1);
            assert_eq!(sched.offsets[0], 0);
            assert_eq!(*sched.offsets.last().unwrap() as usize, sched.entries.len());
            assert!(sched.offsets.windows(2).all(|p| p[0] <= p[1]));
            // Every slot indexes inside the gathered window.
            let lane_len = conv.lane_len();
            assert!(sched.entries.iter().all(|e| (e.slot as usize) < lane_len));
            assert!(sched.entries.iter().all(|e| e.sign == 1 || e.sign == -1));
        }
    }

    #[test]
    fn decoded_schedule_replays_split_kneaded() {
        // Replaying a filter's decoded entries over one gathered
        // window produces the same partial sum as the legacy
        // per-group splitter walk — the per-filter statement of the
        // decoded kernel's bit-exactness (the executor-level sweep
        // lives in rust/tests/plan_kernel.rs).
        use crate::sac::{rear_adder_tree, split_kneaded, SegmentRegisters};
        let net = zoo::tiny_cnn();
        let w = tiny_weights(8);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        for conv in &plan.convs {
            let lane_len = conv.lane_len();
            // A ramp with signs: distinct magnitudes catch slot
            // permutation bugs the all-ones vector would hide.
            let acts: Vec<i32> =
                (0..lane_len).map(|i| (i as i32 % 97) - 48).collect();
            let sched = &conv.decoded;
            for (f, lane) in conv.lanes.iter().enumerate() {
                let mut segs = SegmentRegisters::new(Mode::Fp16.weight_bits());
                for (g, group) in lane.groups.iter().enumerate() {
                    let start = g * lane.ks;
                    let end = (start + lane.ks).min(lane_len);
                    split_kneaded(group, &acts[start..end], &mut segs);
                }
                let want = rear_adder_tree(segs.values());
                let mut banks = vec![0i64; Mode::Fp16.weight_bits()];
                let lo = sched.offsets[f] as usize;
                let hi = sched.offsets[f + 1] as usize;
                for e in &sched.entries[lo..hi] {
                    banks[e.seg as usize] += e.sign as i64 * acts[e.slot as usize] as i64;
                }
                assert_eq!(
                    rear_adder_tree(&banks),
                    want,
                    "{} filter {f}: decoded replay diverged",
                    conv.name
                );
            }
        }
    }

    #[test]
    fn bad_ks_rejected() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(2);
        assert!(CompiledNetwork::compile(&net, &w, 1, Mode::Fp16).is_err());
        assert!(CompiledNetwork::compile(&net, &w, 257, Mode::Fp16).is_err());
    }

    #[test]
    fn check_input_validates_channels() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(3);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        assert_eq!(plan.check_input(&Tensor::zeros(&[2, 1, 16, 16])).unwrap(), 2);
        assert!(plan.check_input(&Tensor::zeros(&[2, 3, 16, 16])).is_err());
        assert!(plan.check_input(&Tensor::zeros(&[16, 16])).is_err());
    }

    #[test]
    fn peak_estimate_grows_with_tile_height() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(6);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let small = plan.peak_bytes_estimate(1, 1);
        let big = plan.peak_bytes_estimate(8, 1);
        let full = plan.peak_bytes_estimate(0, 1);
        assert!(small > 0);
        assert!(small <= big, "1-row tiles {small} > 8-row tiles {big}");
        assert!(big <= full, "8-row tiles {big} > materializing {full}");
        // More concurrent tiles → more live rings.
        assert!(plan.peak_bytes_estimate(2, 8) >= plan.peak_bytes_estimate(2, 1));
        // The streaming estimate is non-trivial and grows with the
        // tile height too (rings scale with the advance step).
        let s_small = plan.streaming_peak_bytes_estimate(1, 1);
        let s_big = plan.streaming_peak_bytes_estimate(8, 1);
        assert!(s_small > 0);
        assert!(s_small <= s_big);
    }

    #[test]
    fn multi_head_plans_compile_per_name_lanes() {
        use crate::model::weights::{synthetic_loaded_with_heads, DensityCalibration};
        let net = zoo::vgg16().scaled(16, 32);
        let w = synthetic_loaded_with_heads(
            &net,
            Mode::Fp16,
            10,
            "vgg16",
            DensityCalibration::Fig2,
            3,
        )
        .unwrap();
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let heads = plan.fc_heads();
        assert_eq!(heads.len(), 3);
        assert_eq!(heads[0].name, "fc6");
        assert_eq!(heads[2].name, "fc8");
        // fc6/fc7 are activation-fused, fc8 emits the logits.
        assert!(heads[0].relu && heads[1].relu && !heads[2].relu);
        // The chain's dims link: classes of head i = feat_dim of i+1.
        assert_eq!(heads[0].classes, heads[1].feat_dim);
        assert_eq!(heads[1].classes, heads[2].feat_dim);
        assert_eq!(plan.output_classes(), Some(1000));
        assert_eq!(plan.fc_head("fc7").unwrap().classes, heads[1].classes);
        assert!(plan.fc_head("fc9").is_none());
        // Head lanes count toward the knead budget: convs + classes.
        let conv_lanes: u64 = net.layers.iter().map(|l| l.out_c as u64).sum();
        let head_lanes: u64 = heads.iter().map(|f| f.classes as u64).sum();
        assert_eq!(plan.kneads_at_build, conv_lanes + head_lanes);
    }

    #[test]
    fn tile_rows_for_budget_tracks_the_budget() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(8);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        // A huge budget takes the largest candidate tile, a zero
        // budget falls back to single-row tiles.
        assert_eq!(plan.tile_rows_for_budget(u64::MAX, 4), 64);
        assert_eq!(plan.tile_rows_for_budget(0, 4), 1);
        // The chosen tile's own estimate honors the budget.
        let budget = plan.peak_bytes_estimate(4, 4);
        let rows = plan.tile_rows_for_budget(budget, 4);
        assert!(rows >= 4, "budget sized for 4-row tiles picked {rows}");
        assert!(plan.peak_bytes_estimate(rows, 4) <= budget);
    }

    #[test]
    fn pipelined_estimate_and_walk_aware_budget_sizing() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(11);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let p = plan.pipelined_peak_bytes_estimate(2, 1);
        assert!(p > 0);
        // More concurrently streamed images → more live ring sets.
        assert!(plan.pipelined_peak_bytes_estimate(2, 8) >= p);
        // Walk-aware sizing agrees with its own estimate.
        let budget = plan.pipelined_peak_bytes_estimate(4, 2);
        let rows = plan.tile_rows_for_budget_walk(budget, 2, Walk::Pipelined);
        assert!(rows >= 4, "budget sized for 4-row feeds picked {rows}");
        assert!(plan.pipelined_peak_bytes_estimate(rows, 2) <= budget);
        // The tiled delegate is unchanged.
        assert_eq!(
            plan.tile_rows_for_budget(budget, 2),
            plan.tile_rows_for_budget_walk(budget, 2, Walk::Tiled)
        );
        // The summary surfaces the chained-prefix geometry.
        let s = plan.pipeline_summary(16, 2).unwrap();
        assert_eq!(s.segments, 3);
        assert!(s.ring_bytes > 0 && s.fill_rows > 0);
        assert_eq!(s.out_bytes, (16 * 4 * 4 * 4) as u64);
    }

    #[test]
    fn schedule_records_fused_segments() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(5);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        assert_eq!(plan.tile_rows, DEFAULT_TILE_ROWS);
        assert_eq!(plan.declared_in, (1, 16));
        let fused = plan
            .schedule()
            .iter()
            .filter(|s| matches!(s, Segment::Fused(_)))
            .count();
        assert_eq!(fused, 3, "one fused walk per conv");
    }

    #[test]
    fn compile_is_deterministic_across_thread_counts() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(4);
        let a = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let b = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        // par_map preserves order, so lane vectors must be identical.
        for (ca, cb) in a.convs.iter().zip(&b.convs) {
            assert_eq!(ca.lanes, cb.lanes);
        }
    }
}
