//! [`CompiledNetwork`] — the compile-time half of the split.
//!
//! Building a plan kneads every conv filter lane and every FC class
//! lane exactly once (in parallel across filters), then stores only the
//! kneaded form — exactly what the accelerator keeps in eDRAM. The
//! executor (`plan::exec`) streams these lanes; it never calls back
//! into the kneading compiler.

use crate::config::Mode;
use crate::kneading::{knead_lane, KneadedLane, Lane};
use crate::model::{LoadedLayer, LoadedWeights, Network, Tensor};
use crate::util::pool::par_map;

use super::graph::{derive_graph, PlanOp};

/// One conv layer's compile-time product: per-filter pre-kneaded lanes
/// plus the shape metadata the executor needs (weights themselves are
/// dropped — the kneaded form is lossless, DESIGN.md §I1).
#[derive(Debug, Clone)]
pub struct CompiledConv {
    pub name: String,
    pub out_c: usize,
    pub in_c: usize,
    pub kh: usize,
    pub kw: usize,
    /// One kneaded weight lane per output filter, OIHW filter order.
    pub lanes: Vec<KneadedLane>,
}

impl CompiledConv {
    /// Reduction length of one filter lane: `in_c × kh × kw`.
    pub fn lane_len(&self) -> usize {
        self.in_c * self.kh * self.kw
    }
}

/// The classifier head: one pre-kneaded lane per class.
#[derive(Debug, Clone)]
pub struct CompiledFc {
    pub classes: usize,
    pub feat_dim: usize,
    pub lanes: Vec<KneadedLane>,
}

/// A compile-once execution plan for one network.
///
/// Build with [`CompiledNetwork::compile`]; run batches with
/// [`CompiledNetwork::execute`](super::exec). Reusing one plan across
/// calls never changes logits (losslessness invariant I5) and performs
/// zero kneading after construction.
#[derive(Debug, Clone)]
pub struct CompiledNetwork {
    pub(crate) ops: Vec<PlanOp>,
    pub(crate) convs: Vec<CompiledConv>,
    pub(crate) fc: Option<CompiledFc>,
    pub mode: Mode,
    /// Kneading stride the lanes were compiled with. Values are
    /// invariant to KS (SAC ≡ MAC for any stride); KS only moves the
    /// simulated cycle cost.
    pub ks: usize,
    /// `knead_lane` invocations performed at build time — one per conv
    /// filter plus one per FC class. The execute path adds zero more.
    pub kneads_at_build: u64,
}

/// Knead the per-filter lanes of one weight layer (parallel across
/// filters; output order is deterministic).
fn knead_filter_lanes(wl: &LoadedLayer, lane_len: usize, ks: usize, mode: Mode) -> Vec<KneadedLane> {
    let filters: Vec<usize> = (0..wl.shape[0]).collect();
    par_map(&filters, |_, &f| {
        let ws = wl.weights[f * lane_len..(f + 1) * lane_len].to_vec();
        knead_lane(&Lane::new(ws, vec![0; lane_len]), ks, mode)
    })
}

impl CompiledNetwork {
    /// Compile `weights` against the declared topology of `net`.
    ///
    /// Errors if the weight set does not match the topology, the
    /// declared schedule does not validate (shape chaining, branch arm
    /// agreement, one use per layer — see [`derive_graph`]), or `ks`
    /// is out of the supported 2..=256.
    pub fn compile(
        net: &Network,
        weights: &LoadedWeights,
        ks: usize,
        mode: Mode,
    ) -> crate::Result<Self> {
        if !(2..=256).contains(&ks) {
            return Err(crate::Error::Config(format!(
                "ks={ks} out of supported range 2..=256"
            )));
        }
        let ops = derive_graph(net, weights)?;
        let mut kneads_at_build = 0u64;
        let mut convs = Vec::with_capacity(net.layers.len());
        for l in &net.layers {
            let wl = weights.layer(&l.name).expect("derive_graph validated layers");
            let lane_len = l.in_c * l.k * l.k;
            kneads_at_build += l.out_c as u64;
            convs.push(CompiledConv {
                name: l.name.clone(),
                out_c: l.out_c,
                in_c: l.in_c,
                kh: l.k,
                kw: l.k,
                lanes: knead_filter_lanes(wl, lane_len, ks, mode),
            });
        }
        let fc = match weights.layer("fc") {
            Some(fl) => {
                let classes = fl.shape[0];
                let feat_dim = fl.shape[1] * fl.shape[2] * fl.shape[3];
                kneads_at_build += classes as u64;
                Some(CompiledFc {
                    classes,
                    feat_dim,
                    lanes: knead_filter_lanes(fl, feat_dim, ks, mode),
                })
            }
            None => None,
        };
        Ok(Self { ops, convs, fc, mode, ks, kneads_at_build })
    }

    /// The derived op graph (read-only view).
    pub fn ops(&self) -> &[PlanOp] {
        &self.ops
    }

    /// Compiled conv layers, topology order.
    pub fn convs(&self) -> &[CompiledConv] {
        &self.convs
    }

    /// The classifier head, if the weight set carried an `fc` layer.
    pub fn fc(&self) -> Option<&CompiledFc> {
        self.fc.as_ref()
    }

    /// Total kneaded weights across all lanes — the plan's resident
    /// "eDRAM" footprint in kneaded-weight units.
    pub fn kneaded_weights(&self) -> usize {
        let conv: usize = self
            .convs
            .iter()
            .flat_map(|c| c.lanes.iter())
            .map(KneadedLane::kneaded_len)
            .sum();
        let fc: usize = self
            .fc
            .iter()
            .flat_map(|f| f.lanes.iter())
            .map(KneadedLane::kneaded_len)
            .sum();
        conv + fc
    }

    /// Source weights covered by all lanes (compression denominator).
    pub fn source_weights(&self) -> usize {
        let conv: usize = self
            .convs
            .iter()
            .flat_map(|c| c.lanes.iter())
            .map(KneadedLane::source_len)
            .sum();
        let fc: usize = self
            .fc
            .iter()
            .flat_map(|f| f.lanes.iter())
            .map(KneadedLane::source_len)
            .sum();
        conv + fc
    }

    /// Logit count per image (classifier plans only).
    pub fn output_classes(&self) -> Option<usize> {
        self.fc.as_ref().map(|f| f.classes)
    }

    /// Validate that `x` is a plausible (N, C, H, W) input batch for
    /// the first conv layer the plan *executes* (the schedule need not
    /// open with layer 0); returns the batch size.
    pub fn check_input(&self, x: &Tensor<i32>) -> crate::Result<usize> {
        let first = self
            .ops
            .iter()
            .find_map(|op| match op {
                PlanOp::Conv { layer, .. } => self.convs.get(*layer),
                _ => None,
            })
            .ok_or_else(|| crate::Error::Config("plan has no conv layers".into()))?;
        match *x.shape() {
            [n, c, _, _] if c == first.in_c => Ok(n),
            [_, c, _, _] => Err(crate::Error::Shape(format!(
                "input channels {c} != plan `{}` channels {}",
                first.name, first.in_c
            ))),
            _ => Err(crate::Error::Shape("plan input must be 4-D NCHW".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kneading::unknead_group;
    use crate::model::zoo;

    fn tiny_weights(seed: u64) -> LoadedWeights {
        crate::coordinator::SacBackend::synthetic_weights(seed).unwrap()
    }

    #[test]
    fn compile_kneads_once_per_lane() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(1);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        // One lane per conv filter + one per class.
        assert_eq!(plan.convs.len(), 3);
        assert_eq!(plan.convs[0].lanes.len(), 8);
        assert_eq!(plan.convs[1].lanes.len(), 16);
        assert_eq!(plan.convs[2].lanes.len(), 16);
        let fc = plan.fc.as_ref().unwrap();
        assert_eq!((fc.classes, fc.feat_dim), (4, 16));
        assert_eq!(plan.kneads_at_build, 8 + 16 + 16 + 4);
        assert!(plan.kneaded_weights() > 0);
        assert!(plan.kneaded_weights() <= plan.source_weights());
    }

    #[test]
    fn compiled_lanes_are_lossless() {
        // Unkneading every stored group reproduces the source weights
        // bit-for-bit (invariant I1 held through the plan cache).
        let net = zoo::tiny_cnn();
        let w = tiny_weights(9);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        for (conv, wl) in plan.convs.iter().zip(&w.layers) {
            let lane_len = conv.lane_len();
            for (f, lane) in conv.lanes.iter().enumerate() {
                let mut back = Vec::with_capacity(lane_len);
                for g in &lane.groups {
                    back.extend(unknead_group(g, Mode::Fp16));
                }
                assert_eq!(
                    back,
                    &wl.weights[f * lane_len..(f + 1) * lane_len],
                    "{} filter {f}",
                    conv.name
                );
            }
        }
    }

    #[test]
    fn bad_ks_rejected() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(2);
        assert!(CompiledNetwork::compile(&net, &w, 1, Mode::Fp16).is_err());
        assert!(CompiledNetwork::compile(&net, &w, 257, Mode::Fp16).is_err());
    }

    #[test]
    fn check_input_validates_channels() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(3);
        let plan = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        assert_eq!(plan.check_input(&Tensor::zeros(&[2, 1, 16, 16])).unwrap(), 2);
        assert!(plan.check_input(&Tensor::zeros(&[2, 3, 16, 16])).is_err());
        assert!(plan.check_input(&Tensor::zeros(&[16, 16])).is_err());
    }

    #[test]
    fn compile_is_deterministic_across_thread_counts() {
        let net = zoo::tiny_cnn();
        let w = tiny_weights(4);
        let a = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        let b = CompiledNetwork::compile(&net, &w, 16, Mode::Fp16).unwrap();
        // par_map preserves order, so lane vectors must be identical.
        for (ca, cb) in a.convs.iter().zip(&b.convs) {
            assert_eq!(ca.lanes, cb.lanes);
        }
    }
}
