//! Scoped data-parallel helpers (rayon is unavailable offline).
//!
//! [`par_map`] fans a slice out over `std::thread::scope` workers with
//! striped assignment; deterministic output order. Used by the simulators
//! (per-layer parallelism) and the weight generator.
//!
//! Nested fan-out: [`par_map_with`] takes an explicit worker budget and
//! [`split_budget`] divides one budget across concurrent consumers —
//! the plan executor runs inception branch arms in parallel, handing
//! each arm a slice of the session's thread budget so the arms' inner
//! (image, tile) fan-outs never oversubscribe the host (DESIGN.md
//! §Tiled fused execution).

/// Number of worker threads to use: the `TETRIS_THREADS` fallback
/// (resolved through [`engine::env`](crate::engine::env), the one
/// place environment is read) or the available parallelism, capped
/// at 16.
pub fn worker_count() -> usize {
    match crate::engine::env::threads() {
        Some(n) => n,
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16),
    }
}

/// Parallel map over `items`, preserving order. `f` must be `Sync`; item
/// results are written into a pre-sized vector via striping (worker w
/// handles items w, w+W, w+2W, …) so no synchronization beyond the scope
/// join is needed.
pub fn par_map<T: Sync, R: Send>(items: &[T], f: impl Fn(usize, &T) -> R + Sync) -> Vec<R> {
    par_map_with(worker_count(), items, f)
}

/// [`par_map`] with an explicit worker budget instead of the global
/// [`worker_count`]. Striped assignment is a function of `(workers,
/// item index)` only, and each item's result is written to its own
/// slot, so the output is identical for every budget — parallelism
/// never changes values, only wall time.
pub fn par_map_with<T: Sync, R: Send>(
    workers: usize,
    items: &[T],
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for w in 0..workers {
            let f = &f;
            // Capture the wrapper, not the raw pointer field (edition-2021
            // closures capture disjoint fields by default).
            let out_ptr = &out_ptr;
            scope.spawn(move || {
                let mut i = w;
                while i < n {
                    let r = f(i, &items[i]);
                    // SAFETY: each index is written by exactly one worker
                    // (striping) and the scope outlives all writes.
                    unsafe { out_ptr.write(i, Some(r)) };
                    i += workers;
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker wrote every stripe")).collect()
}

struct SendPtr<T>(*mut T);

impl<T> SendPtr<T> {
    /// SAFETY: caller guarantees `i` is in bounds and not written
    /// concurrently by another thread.
    unsafe fn write(&self, i: usize, val: T) {
        unsafe { *self.0.add(i) = val };
    }
}

// SAFETY: the pointer is only dereferenced at disjoint indices inside the
// thread scope; the underlying Vec outlives the scope.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Divide a thread budget across `parts` concurrent consumers: every
/// part gets at least one worker (an idle arm would deadlock a
/// pipeline), and when the budget covers all parts the slices sum to
/// exactly `total` — the nested fan-outs collectively stay inside the
/// budget instead of each claiming all of it.
pub fn split_budget(total: usize, parts: usize) -> Vec<usize> {
    if parts == 0 {
        return Vec::new();
    }
    let total = total.max(1);
    let base = total / parts;
    let rem = total % parts;
    (0..parts).map(|i| (base + usize::from(i < rem)).max(1)).collect()
}

/// Parallel fold: map each item then combine with `merge` (associative).
pub fn par_fold<T: Sync, R: Send>(
    items: &[T],
    map: impl Fn(usize, &T) -> R + Sync,
    mut merge: impl FnMut(R, R) -> R,
) -> Option<R> {
    par_map(items, map).into_iter().reduce(&mut merge)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn par_fold_sums() {
        let items: Vec<u64> = (1..=100).collect();
        let total = par_fold(&items, |_, &x| x, |a, b| a + b).unwrap();
        assert_eq!(total, 5050);
    }

    #[test]
    fn worker_count_positive() {
        assert!(worker_count() >= 1);
    }

    #[test]
    fn par_map_with_is_budget_invariant() {
        let items: Vec<u64> = (0..257).collect();
        let want: Vec<u64> = items.iter().map(|x| x * 3 + 1).collect();
        for budget in [1usize, 2, 5, 16, 1000] {
            assert_eq!(par_map_with(budget, &items, |_, &x| x * 3 + 1), want);
        }
        // A zero budget is clamped to one worker, not a panic.
        assert_eq!(par_map_with(0, &[1u32, 2], |_, &x| x), vec![1, 2]);
    }

    #[test]
    fn split_budget_covers_every_part() {
        assert_eq!(split_budget(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(split_budget(10, 4), vec![3, 3, 2, 2]);
        // Budget smaller than the part count: everyone still gets one.
        assert_eq!(split_budget(2, 4), vec![1, 1, 1, 1]);
        assert_eq!(split_budget(0, 3), vec![1, 1, 1]);
        assert!(split_budget(5, 0).is_empty());
        // Exact split preserves the total when it covers all parts.
        assert_eq!(split_budget(16, 4).iter().sum::<usize>(), 16);
    }
}
