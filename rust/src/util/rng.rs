//! Deterministic pseudo-random number generation.
//!
//! A small, fast, seedable generator (xoshiro256**, seeded via SplitMix64)
//! plus the distributions the weight generator needs: uniform, Bernoulli,
//! Gaussian (Box–Muller) and Laplace. Every simulator / generator in the
//! crate threads an explicit [`Rng`] so whole experiments are reproducible
//! from a single `--seed`.

/// xoshiro256** — public-domain algorithm by Blackman & Vigna.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian sample from Box–Muller.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Construct from a 64-bit seed. Distinct seeds give independent
    /// streams for practical purposes.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, gauss_spare: None }
    }

    /// Derive a child RNG; used to give each layer / lane / worker its own
    /// stream so parallel generation stays deterministic.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let base = self.next_u64();
        Rng::new(base ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound). `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard Gaussian via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Laplace(0, b) — the empirical distribution of trained conv weights.
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.f64() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn laplace_is_symmetric_heavy_tailed() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mut pos = 0;
        let mut absum = 0.0;
        for _ in 0..n {
            let x = r.laplace(0.04);
            if x > 0.0 {
                pos += 1;
            }
            absum += x.abs();
        }
        let frac = pos as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01);
        // E|X| = b for Laplace(0, b).
        assert!((absum / n as f64 - 0.04).abs() < 0.002);
    }

    #[test]
    fn range_hits_endpoints() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            match r.range_i64(-3, 3) {
                -3 => lo_seen = true,
                3 => hi_seen = true,
                x => assert!((-3..=3).contains(&x)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
