//! In-repo substrates for facilities this offline environment cannot pull
//! from crates.io: deterministic RNG, JSON, CLI parsing, a micro-benchmark
//! harness, a scoped thread pool, descriptive statistics, and a small
//! property-testing runner.
//!
//! These are *production code paths* for the library (the simulators and
//! the coordinator use [`rng`], [`pool`] and [`stats`]; configs and
//! artifacts use [`json`]), not test-only shims.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
