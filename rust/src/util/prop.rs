//! Miniature property-based testing runner (proptest is unavailable
//! offline).
//!
//! [`run`] executes a property over `cases` random inputs produced by a
//! generator closure; on failure it re-runs the generator deterministically
//! and reports the failing case index + seed so the exact case can be
//! replayed. A lightweight `shrink_smaller` hook lets value-generators
//! offer simpler variants.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        let cases = crate::engine::env::prop_cases();
        Self { cases, seed: 0xC0FF_EE00 }
    }
}

/// Run a property: `gen` draws an input from the RNG, `prop` returns
/// `Err(msg)` to fail. Panics with a replay message on failure.
pub fn run<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    run_with(PropConfig::default(), name, gen, prop)
}

/// As [`run`] with explicit config.
pub fn run_with<T: std::fmt::Debug>(
    config: PropConfig,
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case}/{} (seed 0x{:x}):\n  {msg}\n  input: {input:?}",
                config.cases, config.seed
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    /// Vec of length in [min_len, max_len] with elements from `item`.
    pub fn vec_of<T>(
        rng: &mut Rng,
        min_len: usize,
        max_len: usize,
        mut item: impl FnMut(&mut Rng) -> T,
    ) -> Vec<T> {
        let len = min_len + rng.below((max_len - min_len + 1) as u64) as usize;
        (0..len).map(|_| item(rng)).collect()
    }

    /// Signed fixed-point weight with magnitude < 2^(bits-1), biased
    /// toward small magnitudes (like trained conv weights) half the time.
    pub fn weight(rng: &mut Rng, bits: u32) -> i32 {
        let bound = 1i64 << (bits - 1);
        let mag = if rng.chance(0.5) {
            // Uniform across the full range — stresses high bits.
            rng.below(bound as u64) as i64
        } else {
            // Small-magnitude regime — stresses slack handling.
            let shift = rng.below(8) as u32;
            rng.below(1 + ((bound as u64 - 1) >> shift)) as i64
        };
        let sign = if rng.chance(0.5) { -1 } else { 1 };
        (sign * mag) as i32
    }

    /// Activation value (post-ReLU: non-negative, 16-bit).
    pub fn activation(rng: &mut Rng) -> i32 {
        rng.below(1 << 15) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run("sum-commutes", |r| (r.below(100) as i64, r.below(100) as i64), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_context() {
        run_with(
            PropConfig { cases: 5, seed: 1 },
            "always-fails",
            |r| r.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn weight_gen_respects_bits() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let w = gen::weight(&mut r, 16);
            assert!(w.unsigned_abs() < (1 << 15));
            let w8 = gen::weight(&mut r, 8);
            assert!(w8.unsigned_abs() < (1 << 7));
        }
    }
}
