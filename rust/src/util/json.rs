//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Used for config files, artifact metadata (`artifacts/metadata.json`),
//! CSV/JSON report emission, and the weight-file header produced by
//! `python/compile/aot.py`. Implemented in-repo because `serde_json` is
//! unavailable offline (see `rust/Cargo.toml`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is
/// deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics (hand-rolled impls —
/// `thiserror` is unavailable offline, see `rust/Cargo.toml`).
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------- constructors ----------

    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    // ---------- accessors ----------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn require(&self, key: &str) -> Result<&Json, crate::Error> {
        match self {
            Json::Obj(o) => o
                .get(key)
                .ok_or_else(|| crate::Error::Config(format!("missing JSON field `{key}`"))),
            _ => Err(crate::Error::Config(format!(
                "expected object while looking up `{key}`"
            ))),
        }
    }

    // ---------- serialization ----------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out.push('\n');
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline_indent(out, level + 1);
                        item.write(out, Some(level + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    newline_indent(out, level);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline_indent(out, level + 1);
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(level + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    newline_indent(out, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, level: usize) {
    out.push('\n');
    for _ in 0..level {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------- parser ----------

pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON value"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not produced by our writers).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj([
            ("name", Json::Str("vgg16".into())),
            ("layers", Json::arr([Json::Num(13.0), Json::Num(3.0)])),
            ("quantized", Json::Bool(true)),
            ("scale", Json::Num(0.0078125)),
            ("none", Json::Null),
        ]);
        for s in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#"{"s": "a\"b\\c\ndAé"}"#).unwrap();
        assert_eq!(v.get("s").as_str().unwrap(), "a\"b\\c\ndAé");
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(parse("0").unwrap().as_u64().unwrap(), 0);
        assert!(parse("1e999").unwrap().as_f64().unwrap().is_infinite());
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"\\x\""] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn require_reports_missing_key() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert!(v.require("a").is_ok());
        assert!(v.require("b").is_err());
    }

    #[test]
    fn deterministic_output_order() {
        let a = parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(a.to_string_compact(), r#"{"a":2,"b":1}"#);
    }
}
