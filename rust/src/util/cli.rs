//! Tiny declarative CLI flag parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and auto-generated `--help`.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument specification + parse result.
#[derive(Debug, Default)]
pub struct Args {
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
    about: &'static str,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Self { about, ..Default::default() }
    }

    /// Declare a value option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: Some(default.to_string()), is_bool: false });
        self
    }

    /// Declare a required value option (no default).
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_bool: false });
        self
    }

    /// Declare a boolean switch (default false).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: Some("false".into()), is_bool: true });
        self
    }

    /// Parse from an explicit token list. Returns Err(help_or_error_text)
    /// on `--help` or invalid input.
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Self, String> {
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = &o.default {
                self.values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.help_text()))?
                    .clone();
                let value = if opt.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} requires a value"))?,
                    }
                };
                self.values.insert(name, value);
            } else {
                self.positional.push(tok);
            }
        }
        for o in &self.opts {
            if !self.values.contains_key(o.name) {
                return Err(format!("missing required option --{}", o.name));
            }
        }
        Ok(self)
    }

    /// Parse from `std::env::args()` skipping `skip` leading tokens
    /// (program name + already-consumed subcommands).
    pub fn parse_env(self, skip: usize) -> Result<Self, String> {
        self.parse_from(std::env::args().skip(skip))
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{}\n\nOptions:\n", self.about);
        for o in &self.opts {
            let d = match (&o.default, o.is_bool) {
                (_, true) => " (flag)".to_string(),
                (Some(d), _) => format!(" (default: {d})"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }

    // ---------- typed getters ----------

    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("option --{name}: expected integer, got `{}`", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        let raw = self.get(name);
        let parsed = if let Some(hex) = raw.strip_prefix("0x") {
            u64::from_str_radix(hex, 16)
        } else {
            raw.parse()
        };
        parsed.map_err(|_| format!("option --{name}: expected u64, got `{raw}`"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("option --{name}: expected float, got `{}`", self.get(name)))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name) == "true"
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::new("t")
            .opt("ks", "16", "kneading stride")
            .opt("network", "vgg16", "net")
            .flag("verbose", "chatty")
            .parse_from(argv(&["--ks", "32", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("ks").unwrap(), 32);
        assert_eq!(a.get("network"), "vgg16");
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let a = Args::new("t")
            .opt("mode", "fp16", "mode")
            .parse_from(argv(&["report", "--mode=int8", "fig8"]))
            .unwrap();
        assert_eq!(a.get("mode"), "int8");
        assert_eq!(a.positional(), &["report".to_string(), "fig8".to_string()]);
    }

    #[test]
    fn unknown_flag_is_error_and_help_works() {
        let r = Args::new("t").parse_from(argv(&["--nope"]));
        assert!(r.is_err());
        let h = Args::new("about me")
            .opt("x", "1", "an x")
            .parse_from(argv(&["--help"]))
            .unwrap_err();
        assert!(h.contains("about me") && h.contains("--x"));
    }

    #[test]
    fn required_option_enforced() {
        let r = Args::new("t").req("path", "p").parse_from(argv(&[]));
        assert!(r.unwrap_err().contains("--path"));
    }

    #[test]
    fn hex_u64() {
        let a = Args::new("t")
            .opt("seed", "0x7e7215", "seed")
            .parse_from(argv(&[]))
            .unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), 0x7e7215);
    }
}
