//! Descriptive statistics: running summaries, percentiles, histograms.
//!
//! Used by the coordinator's latency metrics, the bench harness, and the
//! bit-distribution analysis.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a stored sample (fine for bench sample counts).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "slice must be sorted");
    let rank = p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Fixed-bucket latency histogram (power-of-two microsecond buckets),
/// cheap enough for the coordinator's hot path.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds.
    buckets: Vec<u64>,
    summary: Summary,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 40], summary: Summary::new() }
    }

    pub fn record_us(&mut self, us: f64) {
        self.summary.add(us);
        let idx = if us < 1.0 { 0 } else { (us.log2().floor() as usize).min(self.buckets.len() - 1) };
        self.buckets[idx] += 1;
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> u64 {
        self.summary.count()
    }

    pub fn mean_us(&self) -> f64 {
        self.summary.mean()
    }

    /// Approximate percentile from the histogram buckets (upper bound of
    /// the containing bucket — conservative for SLO reporting).
    pub fn approx_percentile_us(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        self.summary.max()
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        // Merge summaries by replaying moments (sufficient for reporting).
        let (n1, n2) = (self.summary.n as f64, other.summary.n as f64);
        if n2 == 0.0 {
            return;
        }
        let mean = (self.summary.mean * n1 + other.summary.mean * n2) / (n1 + n2);
        let d = other.summary.mean - self.summary.mean;
        self.summary.m2 += other.summary.m2 + d * d * n1 * n2 / (n1 + n2);
        self.summary.mean = mean;
        self.summary.n += other.summary.n;
        self.summary.min = self.summary.min.min(other.summary.min);
        self.summary.max = self.summary.max.max(other.summary.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentile_bounds() {
        let mut h = LatencyHistogram::new();
        for _ in 0..99 {
            h.record_us(10.0);
        }
        h.record_us(5000.0);
        let p50 = h.approx_percentile_us(0.50);
        assert!(p50 <= 16.0 + 1e-9, "p50 {p50}");
        let p999 = h.approx_percentile_us(0.999);
        assert!(p999 >= 4096.0, "p999 {p999}");
    }

    #[test]
    fn histogram_merge_preserves_count_and_mean() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 0..100 {
            a.record_us(i as f64);
            b.record_us(1000.0 + i as f64);
        }
        let mean_a = a.mean_us();
        let mean_b = b.mean_us();
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!((a.mean_us() - (mean_a + mean_b) / 2.0).abs() < 1e-9);
    }
}
